"""Placement co-optimization: placement-refined vs canonical PPAC.

Runs the scenario suite for an MLPerf workload with the placement
refinement stage on, then reports — per scenario — the canonical
(paper Fig.-4 row-major floorplan) reward against the placement-refined
one, plus the NoP diagnostics the pairwise-traffic model exposes (worst /
mean hop counts, per-link contention, delivered-bandwidth congestion
factor).

    PYTHONPATH=src python examples/placement_codesign.py --workload bert

A second section anneals the placement of the paper's Table-6 case-(i)
design under a deliberately lopsided HBM mask, where the placement
headroom is visible at a glance (worst-case HBM latency drops ~40 % when
the stacks move off the canonical edge anchors).
"""

import argparse
import dataclasses
import os
import sys

import jax
import jax.numpy as jnp

from repro.core import costmodel as cm
from repro.core import env as chipenv
from repro.core import params as ps
from repro.optimizer import scenario as suite
from repro.sa import annealing as sa


def suite_section(workload: str):
    cfg = dataclasses.replace(
        suite.SMOKE_SUITE, workloads=(workload,),
        placement_sa=sa.PlacementSAConfig(n_iters=2_000))
    res = suite.run_suite(jax.random.PRNGKey(0), cfg)
    print(f"=== {workload}: placement-refined vs canonical "
          f"(smoke suite, {res.wall_time_s:.0f}s) ===")
    print(f"{'scenario':<28} {'canonical':>10} {'refined':>10} "
          f"{'gain':>8} {'src':>10}")
    for o in res.outcomes:
        print(f"{o.name:<28} {o.reward_canonical:>10.2f} "
              f"{o.best_reward:>10.2f} "
              f"{o.best_reward - o.reward_canonical:>8.3f} {o.source:>10}")
    print()


def case_study_section():
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(repo, "tests"))
    from test_costmodel import case_i_design

    # case (i) with a single left-edge HBM stack: the canonical anchor is
    # far from most of the 5x6 array, so placement has real headroom
    design = case_i_design()._replace(hbm_mask=jnp.int32(0))
    env_cfg = chipenv.EnvConfig()
    res = sa.refine_placement(jax.random.PRNGKey(0), design, env_cfg,
                              sa.PlacementSAConfig(n_iters=5_000))
    m0 = cm.evaluate(design)
    m1 = cm.evaluate(design, placement=res.best_placement)

    print("=== case (i), single left HBM stack: canonical vs refined ===")
    rows = [
        ("reward (Eq. 17)", m0.reward, m1.reward, ".2f"),
        ("worst HBM hops", m0.hops_hbm_ai, m1.hops_hbm_ai, ".1f"),
        ("mean HBM hops", m0.hops_hbm_mean, m1.hops_hbm_mean, ".2f"),
        ("worst HBM latency (ns)", m0.lat_hbm_ai_ns, m1.lat_hbm_ai_ns, ".1f"),
        ("link contention", m0.link_contention, m1.link_contention, ".2f"),
        ("congestion factor", m0.nop_congestion, m1.nop_congestion, ".3f"),
        ("comm energy (pJ/op)", m0.e_comm_pj_per_op, m1.e_comm_pj_per_op,
         ".3f"),
        ("tasks/joule", m0.tasks_per_joule, m1.tasks_per_joule, ",.0f"),
    ]
    print(f"{'metric':<24} {'canonical':>12} {'refined':>12}")
    for name, a, b, fmt in rows:
        print(f"{name:<24} {float(a):>12{fmt}} {float(b):>12{fmt}}")
    hbm = res.best_placement.hbm_ij[0]
    print(f"\nrefined HBM anchor: ({float(hbm[0]):.1f}, {float(hbm[1]):.1f})"
          f"  [canonical: (2.0, -1.0), array is 5 x 6]")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="bert",
                    help="MLPerf workload for the suite section")
    ap.add_argument("--skip-suite", action="store_true")
    args = ap.parse_args()
    if not args.skip_suite:
        suite_section(args.workload)
    case_study_section()


if __name__ == "__main__":
    main()
