"""Serving demo: continuous batching over a pool of decode slots.

Loads a small randomly-initialized model (greedy decode over random
weights is deterministic — the demo verifies engine mechanics: slot
reuse, batched decode, per-request completion).

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_REGISTRY
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine


def main():
    arch = ARCH_REGISTRY["qwen2-0.5b"].reduced()
    params = M.init_params(arch, jax.random.PRNGKey(0), jnp.float32)
    engine = ServingEngine(arch, params, n_slots=4, max_len=128)

    requests = [
        Request(uid=i, prompt=[3 + i, 10 + i, 7, 9][: 2 + i % 3],
                max_new_tokens=12)
        for i in range(8)                      # 8 requests, 4 slots
    ]
    t0 = time.time()
    done = engine.run(requests)
    dt = time.time() - t0

    total_tokens = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests / {total_tokens} tokens "
          f"in {dt:.1f}s ({total_tokens/dt:.1f} tok/s with "
          f"{engine.n_slots} slots, continuous batching)")
    for r in done:
        assert r.done, r.uid
        print(f"  req {r.uid}: prompt={r.prompt} -> {r.output}")


if __name__ == "__main__":
    main()
