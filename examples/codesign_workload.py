"""Workload co-design: optimize a chiplet accelerator FOR a specific
assigned LM architecture — the loop the paper motivates (§1) closed with
real model configs.

For each requested arch, the workload descriptor (GEMM/non-GEMM ops per
token, HBM bytes) is derived from the same config that builds the JAX
model, then the Chiplet-Gym portfolio finds the PPAC-optimal chiplet
system for decode-serving that model.

    PYTHONPATH=src python examples/codesign_workload.py --arch llama3-8b

With ``--suite``, all requested archs and a reward-weight grid run as ONE
scenario-batched engine (vmapped SA + vmapped PPO across every scenario)
and the report includes the cross-scenario Pareto frontier:

    PYTHONPATH=src python examples/codesign_workload.py \
        --arch llama3-8b,mamba2-130m --suite
"""

import argparse
import dataclasses

import jax

from repro.configs import ARCH_REGISTRY
from repro.core import costmodel as cm
from repro.core import env as chipenv
from repro.core import params as ps
from repro.core import workload as wl
from repro.optimizer import portfolio
from repro.optimizer import scenario as suite
from repro.rl import ppo
from repro.sa import annealing as sa


def run_suite(args):
    if args.mode == "prefill":
        raise SystemExit("--suite sweeps the registry, which names "
                         "decode/train workloads; use --mode decode|train")
    workloads = tuple(f"{n}:{args.mode}" for n in args.arch.split(","))
    cfg = dataclasses.replace(suite.SMOKE_SUITE, workloads=workloads)
    print(f"[suite] smoke scale (n_sa={cfg.n_sa}, n_rl={cfg.n_rl}, "
          f"sa_iters={cfg.sa.n_iters}) — for full-scale search use "
          f"`python -m repro.launch.train --arch scenario-suite`")
    res = suite.run_suite(jax.random.PRNGKey(0), cfg, verbose=True)
    print()
    print(suite.format_report(res))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b,mamba2-130m")
    ap.add_argument("--mode", default="decode",
                    choices=["decode", "prefill", "train"])
    ap.add_argument("--suite", action="store_true",
                    help="scenario-batched run over all archs x a "
                         "reward-weight grid, with Pareto report")
    args = ap.parse_args()

    if args.suite:
        run_suite(args)
        return

    for name in args.arch.split(","):
        arch = ARCH_REGISTRY[name]
        workload = wl.from_arch_config(arch, mode=args.mode)
        env_cfg = chipenv.EnvConfig(workload=workload)
        cfg = portfolio.PortfolioConfig(
            n_sa=4, n_rl=0, sa=sa.SAConfig(n_iters=30_000),
            rl=ppo.PPOConfig(n_steps=128, n_envs=4), refine=True)
        res = portfolio.optimize(jax.random.PRNGKey(0), env_cfg, cfg)
        m = cm.evaluate(res.best_design, workload)
        arch_kind = ps.ARCH_NAMES[int(res.best_design.arch_type)]
        print(f"\n=== {name} ({args.mode}) ===")
        print(f"workload: {float(workload.gemm_ops)/1e9:.2f} GMAC/task, "
              f"{float(workload.hbm_bytes)/1e6:.0f} MB/task")
        print(f"optimized: reward {res.best_reward:.1f} | "
              f"{int(m.n_dies)} chiplets ({arch_kind}) | "
              f"{int(m.n_hbm)} HBMs | {float(m.eff_tops):.0f} eff TOPS | "
              f"{float(m.tasks_per_sec):,.0f} tasks/s | "
              f"{float(m.tasks_per_joule):,.0f} tasks/J")


if __name__ == "__main__":
    main()
