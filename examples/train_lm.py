"""End-to-end driver: train an LM on the synthetic pipeline with
checkpointing + auto-resume + (optional) gradient compression.

Default preset trains a ~25M-param qwen2-family model for 300 steps on
CPU (~15 min); ``--preset 100m --steps 300`` is the assignment-scale run
(use on real hardware), ``--preset smoke`` finishes in ~1 min.

    PYTHONPATH=src python examples/train_lm.py --preset smoke
    PYTHONPATH=src python examples/train_lm.py --arch llama3-8b --preset smoke
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import ARCH_REGISTRY
from repro.data.pipeline import DataConfig, DataLoader
from repro.training import trainer as T
from repro.training.compression import CompressionConfig

PRESETS = {
    # (d_model, n_layers, n_heads, n_kv, d_ff, vocab, batch, seq, steps)
    "smoke": (64, 2, 4, 2, 128, 512, 4, 64, 20),
    "25m": (384, 8, 8, 4, 1024, 8192, 8, 256, 300),
    "100m": (768, 12, 12, 4, 2048, 32_000, 16, 512, 300),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b",
                    choices=sorted(ARCH_REGISTRY))
    ap.add_argument("--preset", default="smoke", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8", "topk"])
    args = ap.parse_args()

    d, layers, heads, kv, ff, vocab, bsz, seq, steps = PRESETS[args.preset]
    steps = args.steps or steps
    base = ARCH_REGISTRY[args.arch]
    arch = dataclasses.replace(
        base.reduced(), name=f"{args.arch}-{args.preset}",
        d_model=d, n_layers=layers, n_heads=heads,
        n_kv_heads=min(kv, heads), head_dim=d // heads,
        d_ff=ff if base.d_ff else 0, vocab_size=vocab,
        v_head_dim=d // heads)
    print(f"arch={arch.name}  params~{arch.param_count()/1e6:.1f}M  "
          f"batch={bsz}x{seq}  steps={steps}")

    cfg = T.TrainConfig(
        learning_rate=3e-4, warmup_steps=max(steps // 20, 5),
        total_steps=steps, checkpoint_every=max(steps // 4, 10),
        microbatches=2 if bsz >= 8 else 1,
        compression=CompressionConfig(scheme=args.compression),
        param_dtype=jnp.float32)
    data = DataLoader(DataConfig(batch_size=bsz, seq_len=seq,
                                 vocab_size=vocab), arch=arch)
    state, history = T.train_loop(
        arch, cfg, data, ckpt_dir=args.ckpt_dir, n_steps=steps,
        key=jax.random.PRNGKey(0),
        log_every=max(steps // 20, 1))
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NOT improved'}) | "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
