"""Quickstart: optimize a chiplet-based AI accelerator in ~a minute.

Runs a small Alg.-1 portfolio (SA population + one PPO agent + exhaustive
coordinate refinement) on the default objective (alpha, beta, gamma =
1, 1, 0.1 — throughput-weighted, Eq. 17) and prints the optimized design
point next to the paper's Table-6 case-(i) configuration.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.core import costmodel as cm
from repro.core import env as chipenv
from repro.core import params as ps
from repro.optimizer import portfolio
from repro.rl import ppo
from repro.sa import annealing as sa


def main():
    cfg = portfolio.PortfolioConfig(
        n_sa=4, n_rl=1,
        sa=sa.SAConfig(n_iters=30_000),
        rl=ppo.PPOConfig(n_steps=256, n_envs=8),
        rl_timesteps=40_960,
        refine=True)
    print("Running Chiplet-Gym portfolio optimizer "
          f"({cfg.n_sa} SA chains + {cfg.n_rl} PPO agent + refinement)...")
    res = portfolio.optimize(jax.random.PRNGKey(0), chipenv.EnvConfig(),
                             cfg, verbose=True)

    print(f"\nBest design (source: {res.source}, "
          f"reward {res.best_reward:.1f}, {res.wall_time_s:.0f}s):\n")
    print(ps.describe(res.best_design))

    m = cm.evaluate(res.best_design)
    print(f"\nPPAC: {float(m.eff_tops):.0f} effective TOPS | "
          f"{float(m.e_comm_pj_per_op):.2f} pJ/op comm | "
          f"die ${float(m.die_cost):.0f} + pkg ${float(m.pkg_cost):.0f} | "
          f"yield {float(m.die_yield):.1%} | "
          f"{int(m.n_dies)} chiplets on a "
          f"{int(m.mesh_m)}x{int(m.mesh_n)} mesh, {int(m.n_hbm)} HBMs")

    print("\nSA bests:", [f"{v:.0f}" for v in res.sa_rewards])
    print("RL bests:", [f"{v:.0f}" for v in res.rl_rewards])
    print(f"refined:  {res.refined_reward:.1f}")


if __name__ == "__main__":
    main()
