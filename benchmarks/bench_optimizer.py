"""Fig. 9-11 + Table 6 reproduction: SA vs RL convergence over seeds for
case (i) (<=64 chiplets) and case (ii) (<=128 chiplets), optimized design
point, and optimizer runtime (paper: SA 500k iters <1 min; PPO 250k steps
<20 min; our jitted versions are ~2 orders faster)."""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel as cm
from repro.core import env as chipenv
from repro.core import hw_constants as hw
from repro.core import params as ps
from repro.rl import ppo
from repro.sa import annealing as sa

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"
N_SEEDS = 10 if FULL else 4
SA_ITERS = 500_000 if FULL else 30_000
RL_STEPS = 250_000 if FULL else 40_960


def case_env(max_chiplets: int) -> chipenv.EnvConfig:
    """Paper cases: (i) 64-chiplet cap, (ii) 128-chiplet cap."""
    del max_chiplets  # the cap is enforced via the head mask below
    return chipenv.EnvConfig()


def _cap_design(dp: ps.DesignPoint, cap: int) -> ps.DesignPoint:
    return dp._replace(n_chiplets=jnp.minimum(dp.n_chiplets, cap - 1))


def run_sa_case(cap: int, seeds: int):
    """SA population; the chiplet cap is applied inside the objective."""
    env_cfg = chipenv.EnvConfig()

    def capped_run(key):
        res = sa.run(key, env_cfg, sa.SAConfig(n_iters=SA_ITERS))
        dp = _cap_design(res.best_design, cap)
        return cm.reward_only(dp, env_cfg.workload, env_cfg.weights,
                              env_cfg.hw), ps.to_flat(dp)

    keys = jax.random.split(jax.random.PRNGKey(11), seeds)
    vals, flats = jax.jit(jax.vmap(capped_run))(keys)
    return np.asarray(vals), np.asarray(flats)


def run_rl_case(cap: int, seeds: int):
    env_cfg = chipenv.EnvConfig()
    cfg = ppo.PPOConfig(n_steps=256, n_envs=8)
    vals, flats = [], []
    for s in range(seeds):
        res = ppo.train(jax.random.PRNGKey(100 + s), env_cfg, cfg,
                        total_timesteps=RL_STEPS)
        dp = _cap_design(res.best_design, cap)
        vals.append(float(cm.reward_only(dp)))
        flats.append(np.asarray(ps.to_flat(dp)))
    return np.asarray(vals), np.asarray(flats)


def run(report):
    for case, cap in (("case_i", 64), ("case_ii", 128)):
        t0 = time.time()
        sa_vals, sa_flats = run_sa_case(cap, N_SEEDS)
        sa_us = (time.time() - t0) * 1e6
        report(f"fig9_sa_{case}", sa_us / N_SEEDS,
               f"best={sa_vals.max():.1f};min={sa_vals.min():.1f};"
               f"spread={sa_vals.max()-sa_vals.min():.1f}")

        t0 = time.time()
        rl_vals, rl_flats = run_rl_case(cap, max(2, N_SEEDS // 2))
        rl_us = (time.time() - t0) * 1e6
        report(f"fig10_rl_{case}", rl_us / max(2, N_SEEDS // 2),
               f"best={rl_vals.max():.1f};min={rl_vals.min():.1f};"
               f"spread={rl_vals.max()-rl_vals.min():.1f}")

        # Fig 11: RL is the more stable optimizer in the paper; report both
        all_vals = np.concatenate([sa_vals, rl_vals])
        all_flats = np.concatenate([sa_flats, rl_flats])
        best = all_flats[np.argmax(all_vals)]
        dp = ps.from_flat(jnp.asarray(best))
        m = cm.evaluate(dp)
        report(f"table6_{case}", 0.0,
               f"reward={all_vals.max():.1f};arch={int(best[0])};"
               f"chiplets={int(m.n_dies)};hbm={int(m.n_hbm)};"
               f"mesh={int(m.mesh_m)}x{int(m.mesh_n)};"
               f"u_sys={float(m.u_sys):.2f}")
