"""Fig. 9-11 + Table 6 reproduction: SA vs RL convergence over seeds for
case (i) (<=64 chiplets) and case (ii) (<=128 chiplets), optimized design
point, and optimizer runtime (paper: SA 500k iters <1 min; PPO 250k steps
<20 min; our jitted versions are ~2 orders faster).

Also the portfolio-engine benchmark: sequential per-agent PPO loop vs the
vmapped ``ppo.train_population`` (one XLA program for all seeds), the
evolutionary arm (vmapped GA islands + archive hypervolume), a
scenario-suite smoke run, and the three-arm vs SA+RL-only archive
comparison (``--assert-evo-hv`` turns the latter into the ISSUE-5 CI
guard). ``python benchmarks/bench_optimizer.py --smoke`` writes the
measured record to ``benchmarks/BENCH_optimizer.json``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel as cm
from repro.core import env as chipenv
from repro.core import hw_constants as hw
from repro.core import params as ps
from repro.rl import ppo
from repro.sa import annealing as sa
from repro.telemetry import profile as tprof

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"
N_SEEDS = 10 if FULL else 4
SA_ITERS = 500_000 if FULL else 30_000
RL_STEPS = 250_000 if FULL else 40_960


def case_env(max_chiplets: int) -> chipenv.EnvConfig:
    """Paper cases: (i) 64-chiplet cap, (ii) 128-chiplet cap."""
    del max_chiplets  # the cap is enforced via the head mask below
    return chipenv.EnvConfig()


def _cap_design(dp: ps.DesignPoint, cap: int) -> ps.DesignPoint:
    return dp._replace(n_chiplets=jnp.minimum(dp.n_chiplets, cap - 1))


def run_sa_case(cap: int, seeds: int):
    """SA population; the chiplet cap is applied inside the objective."""
    env_cfg = chipenv.EnvConfig()

    def capped_run(key):
        res = sa.run(key, env_cfg, sa.SAConfig(n_iters=SA_ITERS))
        dp = _cap_design(res.best_design, cap)
        return cm.reward_only(dp, env_cfg.workload, env_cfg.weights,
                              env_cfg.hw), ps.to_flat(dp)

    keys = jax.random.split(jax.random.PRNGKey(11), seeds)
    vals, flats = jax.jit(jax.vmap(capped_run))(keys)
    return np.asarray(vals), np.asarray(flats)


def run_rl_case(cap: int, seeds: int):
    env_cfg = chipenv.EnvConfig()
    cfg = ppo.PPOConfig(n_steps=256, n_envs=8)
    vals, flats = [], []
    for s in range(seeds):
        res = ppo.train(jax.random.PRNGKey(100 + s), env_cfg, cfg,
                        total_timesteps=RL_STEPS)
        dp = _cap_design(res.best_design, cap)
        vals.append(float(cm.reward_only(dp)))
        flats.append(np.asarray(ps.to_flat(dp)))
    return np.asarray(vals), np.asarray(flats)


def bench_portfolio_engine(n_rl: int, rl_cfg: ppo.PPOConfig,
                           timesteps: int) -> dict:
    """Sequential per-agent loop vs vmapped train_population, same seeds.

    This is the refactor the portfolio optimizer rides on: the old
    ``optimize`` trained its RL agents in a Python loop; the new one runs
    them as a single vmapped XLA program. Returns the measured record.
    """
    key = jax.random.PRNGKey(7)
    keys = jax.random.split(key, n_rl)

    t0 = time.time()
    seq_rewards = []
    for i in range(n_rl):
        res = ppo.train(keys[i], cfg=rl_cfg, total_timesteps=timesteps)
        seq_rewards.append(float(res.best_reward))
    seq_s = time.time() - t0

    # split compile from run via the shared profiling API so the record
    # shows how much of the vectorized wall is one-time XLA compilation
    fn = jax.jit(lambda k: ppo.train_population(
        k, n_rl, cfg=rl_cfg, total_timesteps=timesteps))
    compiled, compile_s = tprof.compile_timer(
        fn, key, name="train_population")
    t0 = time.time()
    pop = compiled(key)
    jax.block_until_ready(pop)
    run_s = time.time() - t0
    vec_s = compile_s + run_s
    pop_rewards = np.asarray(pop.best_reward)

    return {
        "n_rl": n_rl,
        "n_steps": rl_cfg.n_steps,
        "n_envs": rl_cfg.n_envs,
        "timesteps_per_agent": timesteps,
        "sequential_wall_s": round(seq_s, 3),
        "vectorized_wall_s": round(vec_s, 3),
        "vectorized_compile_s": round(compile_s, 3),
        "vectorized_run_s": round(run_s, 3),
        "speedup": round(seq_s / max(vec_s, 1e-9), 2),
        "sequential_agents_per_s": round(n_rl / max(seq_s, 1e-9), 3),
        "vectorized_agents_per_s": round(n_rl / max(vec_s, 1e-9), 3),
        "best_reward_sequential": round(max(seq_rewards), 2),
        "best_reward_vectorized": round(float(pop_rewards.max()), 2),
    }


def bench_scenario_suite(smoke: bool = True) -> dict:
    """Time one scenario-batched suite (5 MLPerf workloads x 3 weights)."""
    from repro.optimizer import scenario as suite

    cfg = suite.SMOKE_SUITE if smoke else suite.SuiteConfig()
    res = suite.run_suite(jax.random.PRNGKey(0), cfg)
    return {
        "n_scenarios": len(res.outcomes),
        "n_pareto": len(res.pareto),
        "wall_time_s": round(res.wall_time_s, 3),
        "scenarios_per_s": round(
            len(res.outcomes) / max(res.wall_time_s, 1e-9), 3),
        "archive_points": int(res.archive.n_valid),
        "hypervolume": round(res.hypervolume, 4),
    }


def bench_evo_arm(smoke: bool = True) -> dict:
    """Time the GA arm: vmapped islands, one XLA program end to end."""
    from repro.optimizer import archive as ar
    from repro.optimizer import evo

    n_islands = 2
    cfg = (evo.EvoConfig(pop_size=16, n_generations=12) if smoke
           else evo.EvoConfig(pop_size=64, n_generations=60))
    fn = jax.jit(lambda k: evo.evolve_population(k, n_islands, cfg=cfg))
    key = jax.random.PRNGKey(9)
    compiled, compile_s = tprof.compile_timer(
        fn, key, name="evolve_population")
    gen_kernels = tprof.compiled_kernel_count(fn, key)
    res = compiled(key)
    jax.block_until_ready(res)            # first run (warmup)
    t0 = time.time()
    res = compiled(key)
    jax.block_until_ready(res)
    wall = time.time() - t0
    n_evals = n_islands * cfg.pop_size * (cfg.n_generations + 1)
    pts = res.archive.points.reshape(-1, 3)
    val = res.archive.valid.reshape(-1)
    flat_arc = ar.Archive(points=pts,
                          flats=res.archive.flats.reshape(pts.shape[0], -1),
                          reward=res.archive.reward.reshape(-1),
                          payload=res.archive.payload.reshape(-1),
                          valid=val)
    hv = float(ar.hypervolume(flat_arc, ar.nadir_ref(pts, val)))
    return {
        "n_islands": n_islands,
        "pop_size": cfg.pop_size,
        "n_generations": cfg.n_generations,
        "wall_s": round(wall, 3),
        "compile_s": round(compile_s, 3),
        "gen_step_kernels": gen_kernels,
        "evals_per_s": round(n_evals / max(wall, 1e-9), 1),
        "best_reward": round(float(jnp.max(res.best_reward)), 2),
        "archive_points": int(val.sum()),
        "archive_hypervolume": round(hv, 4),
    }


def bench_evo_archive(smoke: bool = True) -> dict:
    """Three-arm vs SA+RL-only: winners and archive hypervolume.

    Runs the MLPerf smoke suite twice on the SAME key — once with the
    evo arm, once without. The SA/RL key streams do not depend on
    ``n_evo`` and every arm's best refines in one lockstep superset
    sweep, so the three-arm winner must be >= scenario for scenario;
    with an ample archive capacity the three-arm insert stream is a
    strict superset too, so its hypervolume (shared nadir ref) must be
    >= as well. Both are hard CI guards under ``--assert-evo-hv``.
    """
    import dataclasses

    from repro.optimizer import archive as ar
    from repro.optimizer import scenario as suite

    base = dataclasses.replace(
        suite.SMOKE_SUITE, workloads=("mlperf",),
        weight_grid=((1.0, 1.0, 0.1),),
        placement_refine=False,            # design-space winners only
        archive_capacity=2048)             # no eviction: superset guard
    cfg3 = base
    cfg2 = dataclasses.replace(base, n_evo=0)
    res3 = suite.run_suite(jax.random.PRNGKey(0), cfg3)
    res2 = suite.run_suite(jax.random.PRNGKey(0), cfg2)
    rewards3 = [o.best_reward for o in res3.outcomes]
    rewards2 = [o.best_reward for o in res2.outcomes]
    reward_ok = all(r3 >= r2 - 1e-6 for r3, r2 in zip(rewards3, rewards2))
    pts = jnp.concatenate([res2.archive.points, res3.archive.points])
    val = jnp.concatenate([res2.archive.valid, res3.archive.valid])
    ref = ar.nadir_ref(pts, val)
    hv2 = float(ar.hypervolume(res2.archive, ref))
    hv3 = float(ar.hypervolume(res3.archive, ref))
    return {
        "n_scenarios": len(res3.outcomes),
        "rewards_three_arm": [round(r, 2) for r in rewards3],
        "rewards_sa_rl": [round(r, 2) for r in rewards2],
        "per_scenario_reward_ok": reward_ok,
        "evo_wins": sum(o.source == "evo" for o in res3.outcomes),
        "hv_sa_rl": round(hv2, 4),
        "hv_three_arm": round(hv3, 4),
        "hv_ratio": round(hv3 / max(hv2, 1e-30), 4),
        "hv_ok": hv3 >= hv2 - 1e-9,
    }


def bench_surrogate(smoke: bool = True) -> dict:
    """ISSUE-6: the learned-surrogate front-end ranker, measured.

    Four claims, all on this box and this run:

    1. rank quality — Spearman between surrogate scores and analytic
       fast-tier rewards on a held-out random pool (>= 0.8 CI gate);
    2. exactness-guard sanity — the analytic argmax of a fresh 64k pool
       sits inside the surrogate's top-k (so the re-score step recovers
       it);
    3. throughput — surrogate-ranked candidates/s (fold once, then
       score + top_k, the ranker's steady-state hot path) vs the
       analytic fast tier on the SAME 64k pool (>= 10x CI gate);
    4. equal-budget value — a full run_stage vs its mode='random'
       control (identical analytic budget AND bootstrap key stream).
    """
    import dataclasses

    from repro.core import workload as wl
    from repro.kernels import ops
    from repro.surrogate import model as sm
    from repro.surrogate import ranker as srk

    del smoke   # quality/throughput claims need the real scale
    scen = cm.stack_scenarios(
        [cm.Scenario(workload=wl.MLPERF[name])
         for name in list(wl.MLPERF)[:2]])
    hw_cfg = chipenv.EnvConfig().hw
    cfg = srk.SurrogateConfig()

    t0 = time.time()
    sres = srk.run_stage(jax.random.PRNGKey(21), scen, cfg, hw_cfg,
                         nop_fidelity="fast")
    jax.block_until_ready(sres.cand_rewards)
    stage_s = time.time() - t0
    t0 = time.time()
    rres = srk.run_stage(jax.random.PRNGKey(21), scen,
                         dataclasses.replace(cfg, mode="random"), hw_cfg,
                         nop_fidelity="fast")
    jax.block_until_ready(rres.cand_rewards)
    rand_s = time.time() - t0
    best_sur = np.asarray(sres.cand_rewards).max(axis=1)
    best_rnd = np.asarray(rres.cand_rewards).max(axis=1)

    scen0 = jax.tree_util.tree_map(lambda x: x[0], scen)
    analytic_fn = jax.jit(jax.vmap(lambda f: cm.reward_only(
        ps.from_flat(f), scen0.workload, scen0.weights, hw_cfg,
        nop_fidelity="fast")))

    # rank quality on a held-out pool (never seen in training)
    held = srk.random_flats(jax.random.PRNGKey(22), 2048)
    true_r = np.asarray(analytic_fn(held))
    pred_r = np.asarray(sm.score(sres.params, held, scen0))
    rk_t = np.argsort(np.argsort(true_r)).astype(np.float64)
    rk_p = np.argsort(np.argsort(pred_r)).astype(np.float64)
    spearman = float(np.corrcoef(rk_t, rk_p)[0, 1])

    # throughput, both sides timed on the same fresh 64k pool; warm up
    # at the FULL pool shape so neither side pays trace+compile inside
    # the timed region
    pool = srk.random_flats(jax.random.PRNGKey(23), cfg.pool_size)
    analytic_fn(pool).block_until_ready()              # compile
    reps = 3
    t0 = time.time()
    for _ in range(reps):
        pool_r = analytic_fn(pool)
    pool_r.block_until_ready()
    analytic_s = (time.time() - t0) / reps
    pool_r = np.asarray(pool_r)

    folded = sm.fold_scenario(sres.params, scen0)
    def ranked(p):
        scores = ops.surrogate_score(p, folded, backend=cfg.backend)
        return jax.lax.top_k(scores, cfg.top_k)
    jax.block_until_ready(ranked(pool))                # compile
    reps = 5
    t0 = time.time()
    for _ in range(reps):
        top_scores, top_idx = ranked(pool)
    jax.block_until_ready((top_scores, top_idx))
    ranked_s = (time.time() - t0) / reps

    analytic_cps = cfg.pool_size / max(analytic_s, 1e-9)
    ranked_cps = cfg.pool_size / max(ranked_s, 1e-9)
    argmax_in_topk = bool(int(np.argmax(pool_r))
                          in set(np.asarray(top_idx).tolist()))

    return {
        "pool_size": cfg.pool_size, "top_k": cfg.top_k,
        "bootstrap": cfg.bootstrap, "train_steps": cfg.train.steps,
        "spearman_heldout_2048": round(spearman, 4),
        "argmax_in_topk": argmax_in_topk,
        "analytic_fast_candidates_per_s": round(analytic_cps, 1),
        "surrogate_ranked_candidates_per_s": round(ranked_cps, 1),
        "throughput_ratio": round(ranked_cps / max(analytic_cps, 1e-9), 2),
        "stage_wall_s": round(stage_s, 3),
        "random_control_wall_s": round(rand_s, 3),
        "stage_best_rewards": [round(float(r), 2) for r in best_sur],
        "random_best_rewards": [round(float(r), 2) for r in best_rnd],
        "stage_beats_random": bool((best_sur >= best_rnd - 1e-6).all()),
    }


def bench_surrogate_suite() -> dict:
    """Suite with the surrogate stage vs the PR-5 three-arm baseline.

    Same key, same SA/RL/evo streams (the stage only folds its own key),
    so per-scenario winners must be >= the baseline's — the ISSUE-6
    never-worse CI guard (``--assert-surrogate``).
    """
    import dataclasses

    from repro.optimizer import scenario as suite
    from repro.surrogate import ranker as srk
    from repro.surrogate import train as strain

    stage = srk.SurrogateConfig(
        pool_size=16384, top_k=64, bootstrap=1024, capacity=8192,
        train=strain.TrainConfig(steps=800, batch_size=512))
    base = dataclasses.replace(
        suite.SMOKE_SUITE, workloads=("mlperf",),
        weight_grid=((1.0, 1.0, 0.1),), placement_refine=False,
        archive_capacity=2048)
    cfg_s = dataclasses.replace(base, surrogate=stage)
    res_s = suite.run_suite(jax.random.PRNGKey(0), cfg_s)
    res_b = suite.run_suite(jax.random.PRNGKey(0), base)
    rewards_s = [o.best_reward for o in res_s.outcomes]
    rewards_b = [o.best_reward for o in res_b.outcomes]
    return {
        "n_scenarios": len(res_s.outcomes),
        "rewards_with_surrogate": [round(r, 2) for r in rewards_s],
        "rewards_baseline": [round(r, 2) for r in rewards_b],
        "winners_ok": all(rs >= rb - 1e-6
                          for rs, rb in zip(rewards_s, rewards_b)),
        "surrogate_wins": sum(o.source == "surrogate"
                              for o in res_s.outcomes),
        "extra_analytic_evals_per_scenario": srk.analytic_budget(stage),
    }


def bench_traffic_trace(smoke: bool = True) -> dict:
    """ROADMAP-3 traffic traces, measured on this box.

    Two claims:

    1. throughput — ``evaluate_trace`` vmaps the per-step evaluator over
       the whole trace inside ONE compiled program, so its per-trace-step
       eval rate should stay close to the point-scenario rate (>= 0.5x is
       the ``--assert-trace`` floor; the trace adds the queueing /
       load-energy channels on top of each step);
    2. winners move — the same suite key on the placement-sensitive
       preset picks different winning designs under a flat trace vs a
       bursty one (the SLO channel rewards headroom that plain Eq.-17
       scoring never sees). ``--assert-trace`` requires >= 1 diverging
       scenario.
    """
    import dataclasses

    from repro.core import traffic as tr
    from repro.core import workload as wl
    from repro.optimizer import scenario as suite
    from repro.surrogate import ranker as srk

    hw_cfg = chipenv.EnvConfig().hw
    workload = wl.registry()["llama3-8b:decode"]
    weights = cm.make_weights(1.0, 1.0, 0.1)
    tcfg = tr.TRACE_PRESETS["bursty"]
    scen = tr.traced_scenario(
        cm.Scenario(workload=workload, weights=weights), tcfg, hw_cfg)
    n_designs = 512
    pool = srk.random_flats(jax.random.PRNGKey(31), n_designs)
    dp = ps.from_flat(pool)

    point_fn = jax.jit(lambda d: cm.evaluate(
        d, workload, weights, hw_cfg, nop_fidelity="fast").reward)
    trace_fn = jax.jit(lambda d: cm.evaluate_trace(
        d, scen, hw_cfg, nop_fidelity="fast").reward)
    point_fn(dp).block_until_ready()                   # compile
    trace_fn(dp).block_until_ready()
    reps = 5
    t0 = time.time()
    for _ in range(reps):
        r = point_fn(dp)
    r.block_until_ready()
    point_s = (time.time() - t0) / reps
    t0 = time.time()
    for _ in range(reps):
        r = trace_fn(dp)
    r.block_until_ready()
    trace_s = (time.time() - t0) / reps
    point_rate = n_designs / max(point_s, 1e-9)
    step_rate = n_designs * tcfg.n_steps / max(trace_s, 1e-9)

    base = dataclasses.replace(
        suite.PLACEMENT_SENSITIVE_SMOKE,
        workloads=("llama3-8b:decode", "qwen2-0.5b:decode"),
        weight_grid=((1.0, 1.0, 0.1),))
    res_flat = suite.run_suite(jax.random.PRNGKey(0),
                               dataclasses.replace(base, trace="flat"))
    res_bur = suite.run_suite(jax.random.PRNGKey(0),
                              dataclasses.replace(base, trace="bursty"))
    diverged = sum(
        not np.array_equal(of.best_flat, ob.best_flat)
        for of, ob in zip(res_flat.outcomes, res_bur.outcomes))

    return {
        "n_designs": n_designs,
        "trace_steps": tcfg.n_steps,
        "point_evals_per_s": round(point_rate, 1),
        "trace_step_evals_per_s": round(step_rate, 1),
        "per_step_ratio": round(step_rate / max(point_rate, 1e-9), 3),
        "n_scenarios": len(res_flat.outcomes),
        "winners_diverged": int(diverged),
        "flat_slo": [round(o.slo_attainment, 3) for o in res_flat.outcomes],
        "bursty_slo": [round(o.slo_attainment, 3)
                       for o in res_bur.outcomes],
        "flat_rewards": [round(o.best_reward, 2)
                         for o in res_flat.outcomes],
        "bursty_rewards": [round(o.best_reward, 2)
                           for o in res_bur.outcomes],
        "suite_wall_s": round(res_flat.wall_time_s + res_bur.wall_time_s,
                              3),
    }


def _engine_config(smoke: bool):
    """(n_rl, PPOConfig, timesteps) for the engine bench at either scale."""
    if smoke:
        return 8, ppo.PPOConfig(n_steps=64, n_envs=4, batch_size=64), 64 * 4 * 4
    return 16, ppo.PPOConfig(n_steps=256, n_envs=8, batch_size=64), 256 * 8 * 8


def run(report):
    engine = bench_portfolio_engine(*_engine_config(smoke=not FULL))
    n_rl = engine["n_rl"]
    report("portfolio_rl_sequential",
           engine["sequential_wall_s"] * 1e6 / n_rl,
           f"agents_per_s={engine['sequential_agents_per_s']}")
    report("portfolio_rl_vectorized",
           engine["vectorized_wall_s"] * 1e6 / n_rl,
           f"agents_per_s={engine['vectorized_agents_per_s']};"
           f"speedup={engine['speedup']}x")

    evo_rec = bench_evo_arm(smoke=not FULL)
    report("portfolio_evo_arm",
           evo_rec["wall_s"] * 1e6 / evo_rec["n_islands"],
           f"evals_per_s={evo_rec['evals_per_s']};"
           f"best={evo_rec['best_reward']};"
           f"archive_hv={evo_rec['archive_hypervolume']}")

    for case, cap in (("case_i", 64), ("case_ii", 128)):
        t0 = time.time()
        sa_vals, sa_flats = run_sa_case(cap, N_SEEDS)
        sa_us = (time.time() - t0) * 1e6
        report(f"fig9_sa_{case}", sa_us / N_SEEDS,
               f"best={sa_vals.max():.1f};min={sa_vals.min():.1f};"
               f"spread={sa_vals.max()-sa_vals.min():.1f}")

        t0 = time.time()
        rl_vals, rl_flats = run_rl_case(cap, max(2, N_SEEDS // 2))
        rl_us = (time.time() - t0) * 1e6
        report(f"fig10_rl_{case}", rl_us / max(2, N_SEEDS // 2),
               f"best={rl_vals.max():.1f};min={rl_vals.min():.1f};"
               f"spread={rl_vals.max()-rl_vals.min():.1f}")

        # Fig 11: RL is the more stable optimizer in the paper; report both
        all_vals = np.concatenate([sa_vals, rl_vals])
        all_flats = np.concatenate([sa_flats, rl_flats])
        best = all_flats[np.argmax(all_vals)]
        dp = ps.from_flat(jnp.asarray(best))
        m = cm.evaluate(dp)
        report(f"table6_{case}", 0.0,
               f"reward={all_vals.max():.1f};arch={int(best[0])};"
               f"chiplets={int(m.n_dies)};hbm={int(m.n_hbm)};"
               f"mesh={int(m.mesh_m)}x{int(m.mesh_n)};"
               f"u_sys={float(m.u_sys):.2f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: small agent count / iterations")
    ap.add_argument("--n-rl", type=int, default=None,
                    help="RL population size (default: 8 smoke / 16 full)")
    ap.add_argument("--assert-evo-hv", action="store_true",
                    help="fail unless the three-arm suite beats or ties "
                         "the SA+RL-only suite on every MLPerf smoke "
                         "scenario's winner AND on archive hypervolume "
                         "(fixed seed)")
    ap.add_argument("--surrogate", action="store_true",
                    help="run ONLY the surrogate ranker benchmark "
                         "(Spearman, ranked candidates/s vs the analytic "
                         "fast tier, equal-budget stage-vs-random, suite "
                         "never-worse) and merge the record into --out")
    ap.add_argument("--assert-surrogate", action="store_true",
                    help="with --surrogate: fail unless Spearman >= 0.8, "
                         "the analytic argmax is in the surrogate top-k, "
                         "ranked throughput >= 10x the analytic fast "
                         "tier, and suite winners never lose to the "
                         "three-arm baseline")
    ap.add_argument("--trace", action="store_true",
                    help="run ONLY the traffic-trace benchmark "
                         "(trace-eval throughput vs the point path, "
                         "flat-vs-bursty winner divergence on the "
                         "placement-sensitive smoke suite) and merge the "
                         "record into --out")
    ap.add_argument("--assert-trace", action="store_true",
                    help="with --trace: fail unless per-trace-step eval "
                         "throughput stays >= 0.5x the point-scenario "
                         "rate and at least one suite winner differs "
                         "between the flat and bursty traces")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "BENCH_optimizer.json"))
    args = ap.parse_args()

    if args.trace:
        print("[bench] traffic traces: 32-step trace eval vs point eval, "
              "flat-vs-bursty suite winners ...")
        trc = bench_traffic_trace(smoke=args.smoke)
        print(f"[bench]   point {trc['point_evals_per_s']:,.0f} evals/s vs "
              f"trace {trc['trace_step_evals_per_s']:,.0f} step-evals/s "
              f"-> {trc['per_step_ratio']}x per step")
        print(f"[bench]   winners diverged on {trc['winners_diverged']}/"
              f"{trc['n_scenarios']} scenarios; slo flat="
              f"{trc['flat_slo']} bursty={trc['bursty_slo']}")
        record = {}
        if os.path.exists(args.out):
            with open(args.out) as f:
                record = json.load(f)
        record["traffic_trace"] = trc
        with open(args.out, "w") as f:
            json.dump(record, f, indent=2)
            f.write("\n")
        print(f"[bench] wrote {args.out}")
        if args.assert_trace:
            fails = []
            if trc["per_step_ratio"] < 0.5:
                fails.append(f"trace eval only {trc['per_step_ratio']}x "
                             f"the point rate per step (need >= 0.5x)")
            if trc["winners_diverged"] < 1:
                fails.append("flat and bursty traces picked identical "
                             "winners on every scenario")
            if fails:
                for msg in fails:
                    print(f"[bench] FAIL: {msg}", file=sys.stderr)
                sys.exit(1)
        return

    if args.surrogate:
        print("[bench] surrogate ranker: train, Spearman, 64k-pool "
              "throughput vs analytic fast tier ...")
        sur = bench_surrogate(smoke=args.smoke)
        print(f"[bench]   spearman={sur['spearman_heldout_2048']} "
              f"argmax_in_topk={sur['argmax_in_topk']}")
        print(f"[bench]   analytic fast "
              f"{sur['analytic_fast_candidates_per_s']:,.0f} cands/s vs "
              f"ranked {sur['surrogate_ranked_candidates_per_s']:,.0f} "
              f"cands/s -> {sur['throughput_ratio']}x")
        print(f"[bench]   stage {sur['stage_wall_s']}s "
              f"best={sur['stage_best_rewards']} vs random control "
              f"{sur['random_control_wall_s']}s "
              f"best={sur['random_best_rewards']}")
        print("[bench] suite with surrogate stage vs three-arm baseline "
              "(same key) ...")
        sur_suite = bench_surrogate_suite()
        print(f"[bench]   winners_ok={sur_suite['winners_ok']} "
              f"(surrogate won {sur_suite['surrogate_wins']}/"
              f"{sur_suite['n_scenarios']})")
        record = {}
        if os.path.exists(args.out):
            with open(args.out) as f:
                record = json.load(f)
        record["surrogate"] = sur
        record["surrogate_suite"] = sur_suite
        with open(args.out, "w") as f:
            json.dump(record, f, indent=2)
            f.write("\n")
        print(f"[bench] wrote {args.out}")
        if args.assert_surrogate:
            fails = []
            if sur["spearman_heldout_2048"] < 0.8:
                fails.append(f"spearman {sur['spearman_heldout_2048']}"
                             " < 0.8")
            if not sur["argmax_in_topk"]:
                fails.append("analytic argmax not in surrogate top-k")
            if sur["throughput_ratio"] < 10.0:
                fails.append(f"ranked throughput only "
                             f"{sur['throughput_ratio']}x the analytic "
                             f"fast tier (need >= 10x)")
            if not sur_suite["winners_ok"]:
                fails.append("suite winner lost to three-arm baseline")
            if fails:
                for msg in fails:
                    print(f"[bench] FAIL: {msg}", file=sys.stderr)
                sys.exit(1)
        return

    n_rl, rl_cfg, timesteps = _engine_config(smoke=args.smoke)
    if args.n_rl:
        n_rl = args.n_rl

    print(f"[bench] portfolio engine: {n_rl} agents x {timesteps} steps, "
          f"sequential loop vs vmapped train_population ...")
    engine = bench_portfolio_engine(n_rl, rl_cfg, timesteps)
    print(f"[bench]   sequential {engine['sequential_wall_s']}s "
          f"({engine['sequential_agents_per_s']} agents/s)")
    print(f"[bench]   vectorized {engine['vectorized_wall_s']}s "
          f"({engine['vectorized_agents_per_s']} agents/s)  "
          f"-> {engine['speedup']}x")

    print("[bench] evolutionary arm (vmapped GA islands + archive) ...")
    evo_rec = bench_evo_arm(smoke=args.smoke)
    print(f"[bench]   {evo_rec['n_islands']} islands x "
          f"pop {evo_rec['pop_size']} x {evo_rec['n_generations']} gens in "
          f"{evo_rec['wall_s']}s ({evo_rec['evals_per_s']:,.0f} evals/s), "
          f"best {evo_rec['best_reward']}, archive "
          f"{evo_rec['archive_points']} pts hv "
          f"{evo_rec['archive_hypervolume']}")

    print("[bench] scenario suite (5 MLPerf workloads x 3 weightings) ...")
    suite = bench_scenario_suite(smoke=args.smoke)
    suite["mode"] = "smoke" if args.smoke else "full"
    print(f"[bench]   {suite['n_scenarios']} scenarios in "
          f"{suite['wall_time_s']}s, {suite['n_pareto']} on the frontier, "
          f"archive {suite['archive_points']} pts hv "
          f"{suite['hypervolume']}")

    print("[bench] three-arm vs SA+RL-only archive (MLPerf smoke grid) ...")
    arc_rec = bench_evo_archive(smoke=args.smoke)
    print(f"[bench]   winners >= on all {arc_rec['n_scenarios']} scenarios: "
          f"{arc_rec['per_scenario_reward_ok']} (evo won "
          f"{arc_rec['evo_wins']}); hv {arc_rec['hv_sa_rl']} -> "
          f"{arc_rec['hv_three_arm']} ({arc_rec['hv_ratio']}x)")

    record = {"mode": "smoke" if args.smoke else "full",
              "portfolio_engine": engine, "evo_arm": evo_rec,
              "scenario_suite": suite, "evo_archive": arc_rec}
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    print(f"[bench] wrote {args.out}")

    if args.assert_evo_hv:
        if not arc_rec["per_scenario_reward_ok"]:
            print("[bench] FAIL: three-arm winner below SA+RL-only on some "
                  "MLPerf smoke scenario", file=sys.stderr)
            sys.exit(1)
        if not arc_rec["hv_ok"]:
            print(f"[bench] FAIL: three-arm archive hypervolume "
                  f"{arc_rec['hv_three_arm']} < SA+RL-only "
                  f"{arc_rec['hv_sa_rl']}", file=sys.stderr)
            sys.exit(1)


if __name__ == "__main__":
    main()
