"""Cost-model evaluation throughput: the DSE hot loop across NoP tiers.

``python benchmarks/bench_costmodel.py`` measures jitted
``costmodel.evaluate`` throughput on a 64k design batch for

  - the **fast tier** (``nop_fidelity='auto'``, canonical floorplan via
    the closed-form ``placement.nop_stats_fast`` — the default hot path),
  - the **full tier** on the same canonical floorplan
    (``nop_fidelity='full'``, pairwise-traffic reduction),
  - the full tier with an **explicit placement** batch (which adds the
    fast-tier canonical baseline pass for the congestion normalization),

and records the results next to the pre-refactor (PR-1) and PR-2
reference points in ``benchmarks/BENCH_costmodel.json``.

``--smoke --assert-min-ratio 1.8`` is the CI throughput guard: the run
fails unless the fast tier delivers at least that multiple of the full
tier's designs/s (measured in the same invocation, same batch — the
committed JSON records the full-batch numbers the ratio protects).
``--placement-gain`` additionally sweeps the placement-SA reward gain
under the default vs the placement-sensitive HW preset
(``optimizer/scenario.HW_PRESETS``), exercising the congestion /
per-hop-energy channels where they bite.

Every run also benchmarks the **delta-evaluated placement SA**
(ISSUE-4): ``sa.refine_placement`` with ``delta_eval`` on vs off (the
PR-3 full-recompute path), for the default mixed move stream and the
relocation-only phase, recording wall-clock steps/s, the compiled
per-step kernel counts, and verifying the two paths' trajectories are
identical. ``--assert-min-sa-ratio`` / ``--assert-min-sa-kernel-ratio``
turn the relocation-phase ratios into CI guards; the kernel-count guard
is deterministic, the wall-clock one is a regression floor (this
2-core container's SA steps are kernel-launch-bound, so the measured
wall ratio sits well below the structural kernel ratio — see
BENCH_costmodel.json and the README's delta-evaluation section).

ISSUE-7 adds two hot-path benches: **phase-scheduled SA**
(``phase_schedule`` pins the move kind per segment so chiplet segments
statically prune the fused anchor re-scan; ``--assert-min-phased-sa-ratio``
gates its wall-clock win over the mixed delta stream) and
**delta-priced env stepping** (placement-episode PPO rollouts priced
from the carried ``PlacementEvalCache`` with ``lax.cond``-gated
vectorized auto-reset vs the cache-free scratch rollout;
``--assert-min-env-step-ratio`` gates the end-to-end step ratio).

ISSUE-10 adds the **in-scan telemetry** bench: phased placement-SA with
``PlacementSAConfig.telemetry`` off vs on at the same shape, asserting
the off path is bit-exact with a default-constructed config (identical
trajectories AND compiled kernel count) and recording the counters-on
wall overhead. ``--assert-telemetry`` turns both into CI gates
(identity hard, overhead <= 15%).

``--mapping`` records the fourth design layer's cost and gain: full-tier
``evaluate`` throughput with a traced mapping vs ``mapping=None`` (the
latter compiles the exact unmapped program), and the extra reward that
SA mapping co-annealing (``p_mapping=0.25``) buys over placement-only
refinement at the same iteration budget.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel as cm
from repro.core import params as ps
from repro.core import placement as pm
from repro.telemetry import profile as tprof

# Measured on this 2-core CPU container, same batch/protocol as below.
BEFORE = {"designs_per_s": 113208.0, "batch": 65536,
          "model": "worst-hop scalar (pre-placement refactor, PR 1)"}
PR2 = {"designs_per_s": 51260.2, "batch": 65536,
       "model": "pairwise-traffic NoP, canonical placement (PR 2, "
                "single-tier)"}
# PR-3's shipped refine_placement (full costmodel.evaluate per move),
# measured on this container at the protocol below (16 designs vmapped,
# placement-sensitive preset, 1000 iters) before the delta refactor.
PR3_SA = {"steps_per_s": 53850.0, "batch": 16, "sa_iters": 1000,
          "model": "full-recompute SA step (PR 3, evaluate() per move)"}
# PR-3's recorded placement-gain sweep (16 designs, 1000 iters).
PR3_GAIN = {"default": 1.0639, "placement-sensitive": 3.5755}


def _throughput(fn, arg, iters=5):
    fn(arg).block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        fn(arg).block_until_ready()
    return (time.time() - t0) / iters


def _placement_gain_sweep(n_designs: int, n_iters: int) -> dict:
    """Mean/max placement-SA reward gain vs canonical, per HW preset.

    Protocol matches the PR-3 recording (seeds 11/12) but at the
    rescaled iteration budget — best-so-far SA on the same chains is
    monotone in the budget, so the mean gain must stay >= the PR-3
    ``PR3_GAIN`` figures (asserted by tests/test_placement_delta.py).
    """
    from repro.core import env as chipenv
    from repro.optimizer import scenario as suite
    from repro.sa import annealing as sa

    dps = ps.random_design(jax.random.PRNGKey(11), (n_designs,))
    out = {}
    for name, hw_cfg in suite.HW_PRESETS.items():
        env_cfg = chipenv.EnvConfig(hw=hw_cfg)
        cfg = sa.PlacementSAConfig(n_iters=n_iters)
        keys = jax.random.split(jax.random.PRNGKey(12), n_designs)
        res = jax.jit(jax.vmap(
            lambda k, d: sa.refine_placement(k, d, env_cfg, cfg)))(keys, dps)
        gain = np.asarray(res.best_reward) - np.asarray(res.canonical_reward)
        out[name] = {"mean_gain": round(float(gain.mean()), 4),
                     "max_gain": round(float(gain.max()), 4),
                     "pr3_mean_gain": PR3_GAIN.get(name),
                     "n_designs": n_designs, "sa_iters": n_iters}
        print(f"[bench] placement gain ({name}): mean {gain.mean():+.4f}, "
              f"max {gain.max():+.4f} over {n_designs} designs "
              f"(PR-3 @1000 iters: {PR3_GAIN.get(name)})")
    return out


# per-step scheduled-work proxy, shared with bench_optimizer.py and the
# ci.sh kernel guards (promoted from this module's old local copy)
_count_step_kernels = tprof.compiled_kernel_count


def _placement_sa_bench(smoke: bool) -> dict:
    """Delta-evaluated vs full-recompute placement-SA step throughput.

    Runs ``sa.refine_placement`` end to end (vmapped over a design
    batch, placement-sensitive preset) with ``delta_eval`` on/off for
    the default mixed move stream and the relocation-only phase
    (``p_hbm=0`` — the move class where delta evaluation skips the
    anchor scan entirely). Records wall-clock steps/s (best of 3),
    the compiled per-step kernel counts, and asserts the two paths
    produced identical rewards (the bit-for-bit trajectory contract).
    """
    from repro.core import env as chipenv
    from repro.optimizer import scenario as suite
    from repro.sa import annealing as sa

    n_designs = 8 if smoke else 16
    n_iters = 300 if smoke else 1000
    env_cfg = chipenv.EnvConfig(hw=suite.PLACEMENT_SENSITIVE_HW)
    dps = ps.random_design(jax.random.PRNGKey(11), (n_designs,))
    keys = jax.random.split(jax.random.PRNGKey(12), n_designs)

    out = {"batch": n_designs, "sa_iters": n_iters,
           "pr3_full_recompute": PR3_SA}
    for phase, p_hbm in (("mixed", 0.5), ("relocate_only", 0.0)):
        rewards, kernels, fns = {}, {}, {}
        best = {"full": float("inf"), "delta": float("inf")}
        for name, delta in (("full", False), ("delta", True)):
            cfg = sa.PlacementSAConfig(n_iters=n_iters, delta_eval=delta,
                                       p_hbm=p_hbm)
            fn = jax.jit(jax.vmap(lambda k, d: sa.refine_placement(
                k, d, env_cfg, cfg).best_reward))
            kernels[name] = _count_step_kernels(fn, keys, dps)
            r = fn(keys, dps)
            r.block_until_ready()
            rewards[name] = np.asarray(r)
            fns[name] = fn
        # alternate the timed reps so background-load drift on the
        # 2-core container biases both paths equally, not just one
        for _ in range(4):
            for name in ("full", "delta"):
                t0 = time.time()
                fns[name](keys, dps).block_until_ready()
                best[name] = min(best[name], time.time() - t0)
        steps = {name: n_designs * n_iters / best[name]
                 for name in ("full", "delta")}
        identical = bool((rewards["delta"] == rewards["full"]).all())
        # bitwise identity is the pinned-protocol contract (holds here
        # today, asserted hard by the tier-1 trajectory tests); across
        # XLA/CPU changes FMA contraction can flip an ulp and cascade a
        # chain, so the bench only hard-fails on MATERIAL divergence
        close = bool(np.allclose(rewards["delta"], rewards["full"],
                                 rtol=5e-3, atol=1e-3))
        out[phase] = {
            "full_steps_per_s": round(steps["full"], 1),
            "delta_steps_per_s": round(steps["delta"], 1),
            "step_ratio": round(steps["delta"] / steps["full"], 3),
            "full_step_kernels": kernels["full"],
            "delta_step_kernels": kernels["delta"],
            "kernel_ratio": round(kernels["full"]
                                  / max(kernels["delta"], 1), 3),
            "trajectories_identical": identical,
            "rewards_close": close,
        }
        print(f"[bench] placement SA ({phase}): full "
              f"{steps['full']:,.0f} steps/s ({kernels['full']} kernels) "
              f"vs delta {steps['delta']:,.0f} ({kernels['delta']} "
              f"kernels) -> {steps['delta']/steps['full']:.2f}x wall, "
              f"{kernels['full']/max(kernels['delta'],1):.2f}x kernels, "
              f"identical={identical}")
    return out


def _placement_sa_phased_bench(smoke: bool) -> dict:
    """Phase-scheduled SA vs the PR-4 mixed delta stream.

    ISSUE-7 tentpole (a): the baseline is the shipped hot path (delta
    evaluation, mixed Bernoulli move stream); the contender pins the
    move kind per segment, so chiplet segments statically prune the
    fused 6-anchor re-scan instead of computing and discarding it every
    step. Same iteration budget, same keys; both runs must beat the
    canonical floorplan (phased SA explores a different move sequence,
    so reward equality is NOT expected — the correctness contract lives
    in tests/test_placement_delta.py). ``scan_unroll`` stays at 1 here:
    it is trajectory-preserving (asserted bit-for-bit in the tests) but
    measurably SLOWER on this CPU backend, where XLA executes per-kernel
    thunks regardless of unrolling, so unrolled bodies only add
    scheduling work (measured: unroll 8 ~0.5x the unroll-1 wall).
    """
    from repro.core import env as chipenv
    from repro.optimizer import scenario as suite
    from repro.sa import annealing as sa

    n_designs = 8 if smoke else 16
    n_iters = 300 if smoke else 1000
    schedule = (("chiplet", 40), ("hbm", 10))
    unroll = 1
    env_cfg = chipenv.EnvConfig(hw=suite.PLACEMENT_SENSITIVE_HW)
    dps = ps.random_design(jax.random.PRNGKey(11), (n_designs,))
    keys = jax.random.split(jax.random.PRNGKey(12), n_designs)

    cfgs = {
        "mixed_delta": sa.PlacementSAConfig(n_iters=n_iters),
        "phased": sa.PlacementSAConfig(n_iters=n_iters,
                                       phase_schedule=schedule,
                                       scan_unroll=unroll),
    }
    fns, results, kernels = {}, {}, {}
    best = {name: float("inf") for name in cfgs}
    for name, cfg in cfgs.items():
        fn = jax.jit(jax.vmap(lambda k, d, _c=cfg: sa.refine_placement(
            k, d, env_cfg, _c)))
        kernels[name] = _count_step_kernels(fn, keys, dps)
        r = fn(keys, dps)
        jax.block_until_ready(r)
        results[name] = r
        fns[name] = fn
    for _ in range(4):                      # alternating best-of-4
        for name in cfgs:
            t0 = time.time()
            jax.block_until_ready(fns[name](keys, dps))
            best[name] = min(best[name], time.time() - t0)
    steps = {name: n_designs * n_iters / best[name] for name in cfgs}
    gains = {name: np.asarray(results[name].best_reward)
             - np.asarray(results[name].canonical_reward)
             for name in cfgs}
    rec = {
        "batch": n_designs, "sa_iters": n_iters,
        "phase_schedule": [list(s) for s in schedule],
        "scan_unroll": unroll,
        "mixed_delta_steps_per_s": round(steps["mixed_delta"], 1),
        "phased_steps_per_s": round(steps["phased"], 1),
        "wall_ratio": round(steps["phased"] / steps["mixed_delta"], 3),
        "mixed_delta_step_kernels": kernels["mixed_delta"],
        "phased_step_kernels": kernels["phased"],
        "mixed_delta_mean_gain": round(float(gains["mixed_delta"].mean()), 4),
        "phased_mean_gain": round(float(gains["phased"].mean()), 4),
    }
    print(f"[bench] phased SA: mixed delta {steps['mixed_delta']:,.0f} "
          f"steps/s ({kernels['mixed_delta']} kernels) vs phased+unroll "
          f"{steps['phased']:,.0f} ({kernels['phased']} kernels) -> "
          f"{rec['wall_ratio']:.2f}x wall; mean gain "
          f"{gains['mixed_delta'].mean():+.3f} vs "
          f"{gains['phased'].mean():+.3f}")
    return rec


def _telemetry_bench(smoke: bool) -> dict:
    """In-scan telemetry counters: off-path identity + on-path overhead.

    ISSUE-10 gate, measured at the phased-SA bench shape (the hot path
    with the most per-step telemetry work — per-segment counter bins).
    Three compiled programs, same keys/designs:

      - ``off`` — ``telemetry=False`` (default): must compile the exact
        pre-telemetry program; trajectories are asserted bitwise equal
        to the baseline below.
      - ``baseline`` — the same config built without touching the
        telemetry field at all (belt and braces: a default-constructed
        config IS the off path).
      - ``on`` — ``telemetry=True``: counters ride the scan carry.
        Trajectory must still be bitwise identical (counters only read
        values the step already computed), and the wall-clock overhead
        is recorded honestly; ``--assert-telemetry`` gates it at 15%.
    """
    from repro.core import env as chipenv
    from repro.optimizer import scenario as suite
    from repro.sa import annealing as sa
    from repro.telemetry import counters as tl

    n_designs = 8 if smoke else 16
    n_iters = 300 if smoke else 1000
    schedule = (("chiplet", 40), ("hbm", 10))
    env_cfg = chipenv.EnvConfig(hw=suite.PLACEMENT_SENSITIVE_HW)
    dps = ps.random_design(jax.random.PRNGKey(11), (n_designs,))
    keys = jax.random.split(jax.random.PRNGKey(12), n_designs)

    cfgs = {
        "baseline": sa.PlacementSAConfig(n_iters=n_iters,
                                         phase_schedule=schedule),
        "off": sa.PlacementSAConfig(n_iters=n_iters,
                                    phase_schedule=schedule,
                                    telemetry=False),
        "on": sa.PlacementSAConfig(n_iters=n_iters,
                                   phase_schedule=schedule,
                                   telemetry=True),
    }
    fns, results, kernels = {}, {}, {}
    best = {name: float("inf") for name in cfgs}
    for name, cfg in cfgs.items():
        fn = jax.jit(jax.vmap(lambda k, d, _c=cfg: sa.refine_placement(
            k, d, env_cfg, _c)))
        kernels[name] = _count_step_kernels(fn, keys, dps)
        r = fn(keys, dps)
        jax.block_until_ready(r)
        results[name] = r
        fns[name] = fn
    for _ in range(4):                      # alternating best-of-4
        for name in cfgs:
            t0 = time.time()
            jax.block_until_ready(fns[name](keys, dps))
            best[name] = min(best[name], time.time() - t0)
    steps = {name: n_designs * n_iters / best[name] for name in cfgs}

    def _traj(res):
        return (np.asarray(res.best_reward), np.asarray(res.history),
                np.asarray(res.canonical_reward))

    off_identical = all(
        (a == b).all() for a, b in zip(_traj(results["off"]),
                                       _traj(results["baseline"])))
    on_identical = all(
        (a == b).all() for a, b in zip(_traj(results["on"]),
                                       _traj(results["baseline"])))
    tel = results["on"].telemetry
    summary = tl.summarize_sa(tel)
    counters_consistent = (
        sum(summary["propose"]) == n_designs * n_iters
        and sum(summary["seg_propose"]) == n_designs * n_iters
        and all(a <= p for a, p in zip(summary["accept"],
                                       summary["propose"])))
    overhead_x = best["on"] / best["off"]
    rec = {
        "batch": n_designs, "sa_iters": n_iters,
        "phase_schedule": [list(s) for s in schedule],
        "off_steps_per_s": round(steps["off"], 1),
        "on_steps_per_s": round(steps["on"], 1),
        "overhead_x": round(overhead_x, 3),
        "off_step_kernels": kernels["off"],
        "on_step_kernels": kernels["on"],
        "off_bitwise_identical": bool(off_identical),
        "on_trajectory_identical": bool(on_identical),
        "off_kernels_unchanged": kernels["off"] == kernels["baseline"],
        "counters_consistent": bool(counters_consistent),
        "accept_rate": summary["accept_rate"],
    }
    print(f"[bench] telemetry: off {steps['off']:,.0f} steps/s "
          f"({kernels['off']} kernels) vs on {steps['on']:,.0f} "
          f"({kernels['on']} kernels) -> {overhead_x:.3f}x wall overhead; "
          f"off-identical={off_identical} on-identical={on_identical} "
          f"counters-ok={counters_consistent}")
    return rec


def _env_step_bench(smoke: bool) -> dict:
    """Delta-priced vs scratch-evaluate placement-episode env stepping.

    ISSUE-7 tentpole (b): placement episodes driven by a presampled
    action stream in the exact PPO rollout shape. Three variants, same
    keys and actions:

      - ``scratch`` — the cache-free baseline: per-env
        ``auto_reset_step`` under ``jax.vmap`` (every step rebuilds the
        reset placement context) pricing each move with a scratch
        ``costmodel.evaluate``. This is what the rollout costs without
        the cache plumbing.
      - ``scratch_vec`` — ``auto_reset_step_vec`` (reset work gated
        behind a scalar ``lax.cond`` on ``any(done)``), still scratch
        pricing. Isolates the reset-gating share of the win.
      - ``delta`` — ``auto_reset_step_vec`` with ``delta_eval=True``:
        each move is priced by one fused
        ``nop_stats_delta(move_kinds='both')`` against the carried
        cache. This is the shipped PPO hot path.

    ``step_ratio`` is delta vs the cache-free scratch baseline (the
    tentpole's end-to-end claim); ``pricing_ratio`` is delta vs
    scratch_vec (the isolated delta-pricing share — modest here because
    both are kernel-launch-bound on this 2-core container). All three
    reward streams must agree to 1e-5 (same floorplans, different
    pricing), asserted here and field-by-field in tests/test_env_delta.py.
    """
    from repro.core import env as chipenv
    from repro.optimizer import scenario as suite

    n_envs = 8 if smoke else 16
    n_steps = 128 if smoke else 256
    episode_len = 64
    heads = jnp.asarray(ps.PLACEMENT_HEAD_SIZES, jnp.int32)
    acts = jax.random.randint(jax.random.PRNGKey(5),
                              (n_steps, n_envs, len(ps.PLACEMENT_HEAD_SIZES)),
                              0, heads, dtype=jnp.int32)
    keys = jax.random.split(jax.random.PRNGKey(6), n_envs)

    variants = {"scratch": (False, False),
                "scratch_vec": (False, True),
                "delta": (True, True)}
    fns, rewards = {}, {}
    best = {name: float("inf") for name in variants}
    for name, (delta, vec) in variants.items():
        cfg = chipenv.EnvConfig(hw=suite.PLACEMENT_SENSITIVE_HW,
                                placement_episode=True, delta_eval=delta,
                                episode_len=episode_len)

        def rollout(a, _cfg=cfg, _vec=vec):
            states, _ = jax.vmap(lambda k: chipenv.reset(k, _cfg))(keys)

            def body(st, at):
                if _vec:
                    st, _, r, _, _ = chipenv.auto_reset_step_vec(
                        st, at, _cfg)
                else:
                    st, _, r, _, _ = jax.vmap(
                        lambda s, ai: chipenv.auto_reset_step(
                            s, ai, _cfg))(st, at)
                return st, r

            _, rews = jax.lax.scan(body, states, a)
            return rews

        fn = jax.jit(rollout)
        rewards[name] = np.asarray(fn(acts))           # compile + warm
        fns[name] = fn
    for _ in range(4):                                 # alternating best-of-4
        for name in fns:
            t0 = time.time()
            fns[name](acts).block_until_ready()
            best[name] = min(best[name], time.time() - t0)
    steps = {name: n_envs * n_steps / best[name] for name in fns}
    agree = bool(
        np.allclose(rewards["delta"], rewards["scratch"],
                    rtol=1e-5, atol=1e-5)
        and np.allclose(rewards["delta"], rewards["scratch_vec"],
                        rtol=1e-5, atol=1e-5))
    rec = {
        "n_envs": n_envs, "n_steps": n_steps, "episode_len": episode_len,
        "scratch_steps_per_s": round(steps["scratch"], 1),
        "scratch_vec_steps_per_s": round(steps["scratch_vec"], 1),
        "delta_steps_per_s": round(steps["delta"], 1),
        "step_ratio": round(steps["delta"] / steps["scratch"], 3),
        "pricing_ratio": round(steps["delta"] / steps["scratch_vec"], 3),
        "rewards_agree": agree,
    }
    print(f"[bench] env step: scratch {steps['scratch']:,.0f} steps/s, "
          f"scratch+vec-reset {steps['scratch_vec']:,.0f}, delta "
          f"{steps['delta']:,.0f} -> {rec['step_ratio']:.2f}x end-to-end "
          f"({rec['pricing_ratio']:.2f}x pricing), agree={agree}")
    return rec


def _placement_chains_bench(smoke: bool) -> dict:
    """Multi-chain vs single-chain placement SA (ROADMAP PR-4 follow-up).

    ``PlacementSAConfig.n_chains`` vmaps several chains per design inside
    the same program. On this launch-bound container the extra chains
    ride the same kernel launches, so the honest comparison is wall
    clock for the SAME total chain count: one vmapped n_chains=4 call vs
    4 sequential n_chains=1 calls (different keys, same compiled fn).
    ``amortization`` is how much cheaper the vmapped form is; per-design
    reward gain of best-of-4 over single-chain is recorded too.
    """
    from repro.core import env as chipenv
    from repro.optimizer import scenario as suite
    from repro.sa import annealing as sa

    n_designs = 8 if smoke else 16
    n_iters = 300 if smoke else 1000
    n_chains = 4
    env_cfg = chipenv.EnvConfig(hw=suite.PLACEMENT_SENSITIVE_HW)
    dps = ps.random_design(jax.random.PRNGKey(11), (n_designs,))
    keys = jax.random.split(jax.random.PRNGKey(12), n_designs)

    fns, rewards = {}, {}
    for nc in (1, n_chains):
        cfg = sa.PlacementSAConfig(n_iters=n_iters, n_chains=nc)
        fn = jax.jit(jax.vmap(lambda k, d: sa.refine_placement(
            k, d, env_cfg, cfg).best_reward))
        rewards[nc] = np.asarray(fn(keys, dps))      # compile + warm
        fns[nc] = fn

    best = {1: float("inf"), n_chains: float("inf"), "seq": float("inf")}
    for _ in range(3):
        t0 = time.time()
        fns[1](keys, dps).block_until_ready()
        best[1] = min(best[1], time.time() - t0)
        t0 = time.time()
        fns[n_chains](keys, dps).block_until_ready()
        best[n_chains] = min(best[n_chains], time.time() - t0)
        t0 = time.time()
        for rep in range(n_chains):
            fns[1](jax.vmap(jax.random.fold_in, (0, None))(keys, rep),
                   dps).block_until_ready()
        best["seq"] = min(best["seq"], time.time() - t0)

    gain = rewards[n_chains] - rewards[1]
    rec = {
        "batch": n_designs, "sa_iters": n_iters, "n_chains": n_chains,
        "single_chain_wall_s": round(best[1], 4),
        "vmapped_chains_wall_s": round(best[n_chains], 4),
        "sequential_chains_wall_s": round(best["seq"], 4),
        # wall cost of 4x the chains inside one program vs 1 chain
        "chains_overhead_x": round(best[n_chains] / max(best[1], 1e-9), 3),
        # vmapped 4 chains vs the same 4 chains as sequential calls
        "amortization_x": round(best["seq"] / max(best[n_chains], 1e-9), 3),
        "mean_best_of_4_gain": round(float(gain.mean()), 4),
        "max_best_of_4_gain": round(float(gain.max()), 4),
    }
    print(f"[bench] placement SA chains: 1 chain {best[1]:.3f}s, "
          f"{n_chains} vmapped {best[n_chains]:.3f}s "
          f"({rec['chains_overhead_x']}x cost for {n_chains}x chains), "
          f"{n_chains} sequential {best['seq']:.3f}s "
          f"-> {rec['amortization_x']}x amortization; "
          f"best-of-{n_chains} mean gain {gain.mean():+.4f}")
    return rec


def _mapping_bench(smoke: bool, batch: int, iters: int) -> dict:
    """Mapping-layer cost and gain (fourth design layer).

    Two questions, answered on the same container and protocol as the
    tier benches above:

      - **Eval cost**: full-tier ``costmodel.evaluate`` throughput with a
        traced (canonical) mapping vs ``mapping=None`` on the same
        batch/canonical floorplan. The mapped program adds the per-slot
        stage-neighbor reduction to the NoP tail, so this records what
        mapping support costs when it IS requested (``mapping=None``
        statically compiles the exact unmapped program — zero cost, by
        construction, tested in tests/test_mapping.py).
      - **SA gain**: ``sa.refine_placement`` with mapping co-annealing
        (``p_mapping=0.25``) vs placement-only moves, same keys, same
        total iteration budget, placement-sensitive preset. The mean /
        max extra reward over the placement-only winner is the honest
        measure of what the fourth layer buys.
    """
    from repro.core import env as chipenv
    from repro.core import mapping as mpg
    from repro.optimizer import scenario as suite
    from repro.sa import annealing as sa

    n = min(batch, 16384)
    dp = ps.random_design(jax.random.PRNGKey(0), (n,))
    canon = mpg.canonical(batch_shape=(n,))

    unmapped_fn = jax.jit(
        lambda d: cm.evaluate(d, nop_fidelity="full").reward)
    dt_unmapped = _throughput(unmapped_fn, dp, iters)
    mapped_fn = jax.jit(
        lambda a: cm.evaluate(a[0], nop_fidelity="full",
                              mapping=a[1]).reward)
    dt_mapped = _throughput(mapped_fn, (dp, canon), iters)

    n_designs = 8 if smoke else 16
    n_iters = 300 if smoke else 1000
    env_cfg = chipenv.EnvConfig(hw=suite.PLACEMENT_SENSITIVE_HW)
    dps = ps.random_design(jax.random.PRNGKey(11), (n_designs,))
    keys = jax.random.split(jax.random.PRNGKey(12), n_designs)
    gains = {}
    for name, p_map in (("placement_only", 0.0), ("co_anneal", 0.25)):
        cfg = sa.PlacementSAConfig(n_iters=n_iters, p_mapping=p_map)
        res = jax.jit(jax.vmap(lambda k, d, _c=cfg: sa.refine_placement(
            k, d, env_cfg, _c).best_reward))(keys, dps)
        gains[name] = np.asarray(res)
    extra = gains["co_anneal"] - gains["placement_only"]

    rec = {
        "batch": n,
        "unmapped_designs_per_s": round(n / dt_unmapped, 1),
        "mapped_designs_per_s": round(n / dt_mapped, 1),
        "mapped_cost_x": round(dt_mapped / dt_unmapped, 3),
        "sa_batch": n_designs, "sa_iters": n_iters, "p_mapping": 0.25,
        "mapping_sa_mean_extra_gain": round(float(extra.mean()), 4),
        "mapping_sa_max_extra_gain": round(float(extra.max()), 4),
        "mapping_sa_frac_improved": round(float((extra > 0).mean()), 3),
    }
    print(f"[bench] mapping eval: unmapped {n/dt_unmapped:,.0f} designs/s "
          f"vs mapped {n/dt_mapped:,.0f} -> {rec['mapped_cost_x']:.2f}x "
          f"full-tier cost when a mapping is traced")
    print(f"[bench] mapping SA: co-anneal extra gain over placement-only "
          f"mean {extra.mean():+.4f}, max {extra.max():+.4f} "
          f"({rec['mapping_sa_frac_improved']:.0%} of designs improved)")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=65536)
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: 16k batch, 3 timing iters")
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--assert-min-ratio", type=float, default=None,
                    help="fail unless fast-tier designs/s >= RATIO x "
                         "full-tier designs/s (CI throughput guard)")
    ap.add_argument("--assert-min-sa-ratio", type=float, default=None,
                    help="fail unless the delta-evaluated placement-SA "
                         "step delivers >= RATIO x the full-recompute "
                         "step's steps/s (relocation phase, wall clock)")
    ap.add_argument("--assert-min-sa-kernel-ratio", type=float, default=None,
                    help="fail unless the full-recompute SA step "
                         "schedules >= RATIO x the delta step's compiled "
                         "kernels (deterministic structural guard)")
    ap.add_argument("--assert-min-phased-sa-ratio", type=float, default=None,
                    help="fail unless the phase-scheduled SA delivers "
                         ">= RATIO x the mixed delta stream's steps/s "
                         "(wall clock)")
    ap.add_argument("--assert-min-env-step-ratio", type=float, default=None,
                    help="fail unless delta-priced placement-episode env "
                         "steps deliver >= RATIO x the cache-free "
                         "scratch-evaluate rollout's steps/s (wall clock)")
    ap.add_argument("--assert-telemetry", action="store_true",
                    help="fail unless (a) telemetry=False compiles the "
                         "exact pre-telemetry phased-SA program (bitwise "
                         "trajectories, unchanged kernel count), "
                         "(b) telemetry=True keeps the trajectory bitwise "
                         "and costs <= 15%% wall overhead, and (c) the "
                         "counters are internally consistent")
    ap.add_argument("--placement-gain", action="store_true",
                    help="also sweep placement-SA gain per HW preset")
    ap.add_argument("--mapping", action="store_true",
                    help="also record mapped vs unmapped full-tier eval "
                         "throughput and the mapping-SA co-anneal gain")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "BENCH_costmodel.json"))
    args = ap.parse_args()

    n = args.batch if not args.smoke else min(args.batch, 16384)
    iters = args.iters if not args.smoke else 3
    dp = ps.random_design(jax.random.PRNGKey(0), (n,))

    fast_fn = jax.jit(lambda d: cm.evaluate(d).reward)
    dt_fast = _throughput(fast_fn, dp, iters)

    full_fn = jax.jit(lambda d: cm.evaluate(d, nop_fidelity="full").reward)
    dt_full = _throughput(full_fn, dp, iters)

    v = ps.decode(dp)
    m, mesh_n = cm.mesh_dims(cm.footprint_positions(v))
    plc = pm.canonical(m, mesh_n, v.hbm_mask, v.arch_type)
    plc_fn = jax.jit(lambda a: cm.evaluate(a[0], placement=a[1]).reward)
    dt_plc = _throughput(plc_fn, (dp, plc), iters)

    record = {
        "batch": n,
        "before": BEFORE,
        "pr2_single_tier": PR2,
        "fast_tier": {
            "designs_per_s": round(n / dt_fast, 1),
            "wall_s": round(dt_fast, 4),
            "model": "closed-form canonical NoP (nop_fidelity=auto/fast)",
        },
        "full_tier_canonical": {
            "designs_per_s": round(n / dt_full, 1),
            "wall_s": round(dt_full, 4),
            "model": "pairwise-traffic NoP, canonical placement "
                     "(nop_fidelity=full)",
        },
        "full_tier_explicit_placement": {
            "designs_per_s": round(n / dt_plc, 1),
            "wall_s": round(dt_plc, 4),
            "model": "pairwise-traffic NoP + fast-tier canonical baseline",
        },
    }
    ratio = dt_full / dt_fast
    print(f"[bench] fast tier:      {n/dt_fast:,.0f} designs/s "
          f"(before refactor: {BEFORE['designs_per_s']:,.0f}, "
          f"PR-2 single tier: {PR2['designs_per_s']:,.0f})")
    print(f"[bench] full tier:      {n/dt_full:,.0f} designs/s (canonical)")
    print(f"[bench] full+placement: {n/dt_plc:,.0f} designs/s")
    print(f"[bench] fast/full ratio: {ratio:.2f}x")

    sa_rec = _placement_sa_bench(args.smoke)
    record["placement_sa_step"] = sa_rec

    phased_rec = _placement_sa_phased_bench(args.smoke)
    record["placement_sa_phased"] = phased_rec

    tel_rec = _telemetry_bench(args.smoke)
    record["telemetry"] = tel_rec

    env_rec = _env_step_bench(args.smoke)
    record["env_step"] = env_rec

    record["placement_sa_chains"] = _placement_chains_bench(args.smoke)

    if args.placement_gain:
        record["placement_gain"] = _placement_gain_sweep(
            n_designs=8 if args.smoke else 16,
            n_iters=200 if args.smoke else 4000)

    if args.mapping:
        record["mapping"] = _mapping_bench(args.smoke, n, iters)

    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    print(f"[bench] wrote {args.out}")

    if args.assert_min_ratio is not None and ratio < args.assert_min_ratio:
        print(f"[bench] FAIL: fast/full throughput ratio {ratio:.2f}x "
              f"< required {args.assert_min_ratio:.2f}x", file=sys.stderr)
        sys.exit(1)
    for phase in ("mixed", "relocate_only"):
        if not sa_rec[phase]["rewards_close"]:
            print(f"[bench] FAIL: delta SA rewards diverged materially "
                  f"from the full-recompute path ({phase})",
                  file=sys.stderr)
            sys.exit(1)
    sa_ratio = sa_rec["relocate_only"]["step_ratio"]
    if (args.assert_min_sa_ratio is not None
            and sa_ratio < args.assert_min_sa_ratio):
        print(f"[bench] FAIL: delta/full SA step ratio {sa_ratio:.2f}x "
              f"< required {args.assert_min_sa_ratio:.2f}x",
              file=sys.stderr)
        sys.exit(1)
    kernel_ratio = sa_rec["relocate_only"]["kernel_ratio"]
    if (args.assert_min_sa_kernel_ratio is not None
            and kernel_ratio < args.assert_min_sa_kernel_ratio):
        print(f"[bench] FAIL: full/delta SA step kernel ratio "
              f"{kernel_ratio:.2f}x < required "
              f"{args.assert_min_sa_kernel_ratio:.2f}x", file=sys.stderr)
        sys.exit(1)
    if (args.assert_min_phased_sa_ratio is not None
            and phased_rec["wall_ratio"] < args.assert_min_phased_sa_ratio):
        print(f"[bench] FAIL: phased/mixed SA wall ratio "
              f"{phased_rec['wall_ratio']:.2f}x < required "
              f"{args.assert_min_phased_sa_ratio:.2f}x", file=sys.stderr)
        sys.exit(1)
    if not env_rec["rewards_agree"]:
        print("[bench] FAIL: delta-priced env rewards diverged from the "
              "scratch-evaluate path", file=sys.stderr)
        sys.exit(1)
    if (args.assert_min_env_step_ratio is not None
            and env_rec["step_ratio"] < args.assert_min_env_step_ratio):
        print(f"[bench] FAIL: delta/scratch env step ratio "
              f"{env_rec['step_ratio']:.2f}x < required "
              f"{args.assert_min_env_step_ratio:.2f}x", file=sys.stderr)
        sys.exit(1)
    if args.assert_telemetry:
        if not (tel_rec["off_bitwise_identical"]
                and tel_rec["off_kernels_unchanged"]):
            print("[bench] FAIL: telemetry=False is not bit-exact with "
                  "the pre-telemetry phased-SA program", file=sys.stderr)
            sys.exit(1)
        if not tel_rec["on_trajectory_identical"]:
            print("[bench] FAIL: telemetry=True perturbed the SA "
                  "trajectory", file=sys.stderr)
            sys.exit(1)
        if not tel_rec["counters_consistent"]:
            print("[bench] FAIL: telemetry counters are internally "
                  "inconsistent", file=sys.stderr)
            sys.exit(1)
        if tel_rec["overhead_x"] > 1.15:
            print(f"[bench] FAIL: telemetry-on wall overhead "
                  f"{tel_rec['overhead_x']:.3f}x > allowed 1.15x",
                  file=sys.stderr)
            sys.exit(1)


if __name__ == "__main__":
    main()
