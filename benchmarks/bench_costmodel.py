"""Cost-model evaluation throughput: the DSE hot loop, before/after the
pairwise-traffic placement refactor.

``python benchmarks/bench_costmodel.py`` measures jitted
``costmodel.evaluate`` throughput on a 64k design batch for (a) the
default canonical-placement path and (b) an explicit-placement batch
(which additionally evaluates the canonical baseline for the congestion /
per-hop-energy normalization), and records the result next to the
pre-refactor reference point in ``benchmarks/BENCH_costmodel.json``.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core import costmodel as cm
from repro.core import params as ps
from repro.core import placement as pm

# Measured on this 2-core CPU container at the PR-1 tree (worst-hop model,
# no placement threading), same batch/protocol as below.
BEFORE = {"designs_per_s": 113208.0, "batch": 65536,
          "model": "worst-hop scalar (pre-placement refactor)"}


def _throughput(fn, arg, iters=5):
    fn(arg).block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        fn(arg).block_until_ready()
    return (time.time() - t0) / iters


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=65536)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "BENCH_costmodel.json"))
    args = ap.parse_args()

    n = args.batch
    dp = ps.random_design(jax.random.PRNGKey(0), (n,))

    canon_fn = jax.jit(lambda d: cm.evaluate(d).reward)
    dt_canon = _throughput(canon_fn, dp)

    v = ps.decode(dp)
    m, mesh_n = cm.mesh_dims(cm.footprint_positions(v))
    plc = pm.canonical(m, mesh_n, v.hbm_mask, v.arch_type)
    plc_fn = jax.jit(lambda a: cm.evaluate(a[0], placement=a[1]).reward)
    dt_plc = _throughput(plc_fn, (dp, plc))

    record = {
        "batch": n,
        "before": BEFORE,
        "after_canonical": {
            "designs_per_s": round(n / dt_canon, 1),
            "wall_s": round(dt_canon, 4),
            "model": "pairwise-traffic NoP, canonical placement",
        },
        "after_explicit_placement": {
            "designs_per_s": round(n / dt_plc, 1),
            "wall_s": round(dt_plc, 4),
            "model": "pairwise-traffic NoP + canonical baseline pass",
        },
    }
    print(f"[bench] canonical: {n/dt_canon:,.0f} designs/s "
          f"(before: {BEFORE['designs_per_s']:,.0f})")
    print(f"[bench] explicit placement: {n/dt_plc:,.0f} designs/s")
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    print(f"[bench] wrote {args.out}")


if __name__ == "__main__":
    main()
