"""Cost-model evaluation throughput: the DSE hot loop across NoP tiers.

``python benchmarks/bench_costmodel.py`` measures jitted
``costmodel.evaluate`` throughput on a 64k design batch for

  - the **fast tier** (``nop_fidelity='auto'``, canonical floorplan via
    the closed-form ``placement.nop_stats_fast`` — the default hot path),
  - the **full tier** on the same canonical floorplan
    (``nop_fidelity='full'``, pairwise-traffic reduction),
  - the full tier with an **explicit placement** batch (which adds the
    fast-tier canonical baseline pass for the congestion normalization),

and records the results next to the pre-refactor (PR-1) and PR-2
reference points in ``benchmarks/BENCH_costmodel.json``.

``--smoke --assert-min-ratio 1.8`` is the CI throughput guard: the run
fails unless the fast tier delivers at least that multiple of the full
tier's designs/s (measured in the same invocation, same batch — the
committed JSON records the full-batch numbers the ratio protects).
``--placement-gain`` additionally sweeps the placement-SA reward gain
under the default vs the placement-sensitive HW preset
(``optimizer/scenario.HW_PRESETS``), exercising the congestion /
per-hop-energy channels where they bite.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel as cm
from repro.core import params as ps
from repro.core import placement as pm

# Measured on this 2-core CPU container, same batch/protocol as below.
BEFORE = {"designs_per_s": 113208.0, "batch": 65536,
          "model": "worst-hop scalar (pre-placement refactor, PR 1)"}
PR2 = {"designs_per_s": 51260.2, "batch": 65536,
       "model": "pairwise-traffic NoP, canonical placement (PR 2, "
                "single-tier)"}


def _throughput(fn, arg, iters=5):
    fn(arg).block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        fn(arg).block_until_ready()
    return (time.time() - t0) / iters


def _placement_gain_sweep(n_designs: int, n_iters: int) -> dict:
    """Mean/max placement-SA reward gain vs canonical, per HW preset."""
    from repro.core import env as chipenv
    from repro.optimizer import scenario as suite
    from repro.sa import annealing as sa

    dps = ps.random_design(jax.random.PRNGKey(11), (n_designs,))
    out = {}
    for name, hw_cfg in suite.HW_PRESETS.items():
        env_cfg = chipenv.EnvConfig(hw=hw_cfg)
        cfg = sa.PlacementSAConfig(n_iters=n_iters)
        keys = jax.random.split(jax.random.PRNGKey(12), n_designs)
        res = jax.jit(jax.vmap(
            lambda k, d: sa.refine_placement(k, d, env_cfg, cfg)))(keys, dps)
        gain = np.asarray(res.best_reward) - np.asarray(res.canonical_reward)
        out[name] = {"mean_gain": round(float(gain.mean()), 4),
                     "max_gain": round(float(gain.max()), 4),
                     "n_designs": n_designs, "sa_iters": n_iters}
        print(f"[bench] placement gain ({name}): mean {gain.mean():+.4f}, "
              f"max {gain.max():+.4f} over {n_designs} designs")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=65536)
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: 16k batch, 3 timing iters")
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--assert-min-ratio", type=float, default=None,
                    help="fail unless fast-tier designs/s >= RATIO x "
                         "full-tier designs/s (CI throughput guard)")
    ap.add_argument("--placement-gain", action="store_true",
                    help="also sweep placement-SA gain per HW preset")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "BENCH_costmodel.json"))
    args = ap.parse_args()

    n = args.batch if not args.smoke else min(args.batch, 16384)
    iters = args.iters if not args.smoke else 3
    dp = ps.random_design(jax.random.PRNGKey(0), (n,))

    fast_fn = jax.jit(lambda d: cm.evaluate(d).reward)
    dt_fast = _throughput(fast_fn, dp, iters)

    full_fn = jax.jit(lambda d: cm.evaluate(d, nop_fidelity="full").reward)
    dt_full = _throughput(full_fn, dp, iters)

    v = ps.decode(dp)
    m, mesh_n = cm.mesh_dims(cm.footprint_positions(v))
    plc = pm.canonical(m, mesh_n, v.hbm_mask, v.arch_type)
    plc_fn = jax.jit(lambda a: cm.evaluate(a[0], placement=a[1]).reward)
    dt_plc = _throughput(plc_fn, (dp, plc), iters)

    record = {
        "batch": n,
        "before": BEFORE,
        "pr2_single_tier": PR2,
        "fast_tier": {
            "designs_per_s": round(n / dt_fast, 1),
            "wall_s": round(dt_fast, 4),
            "model": "closed-form canonical NoP (nop_fidelity=auto/fast)",
        },
        "full_tier_canonical": {
            "designs_per_s": round(n / dt_full, 1),
            "wall_s": round(dt_full, 4),
            "model": "pairwise-traffic NoP, canonical placement "
                     "(nop_fidelity=full)",
        },
        "full_tier_explicit_placement": {
            "designs_per_s": round(n / dt_plc, 1),
            "wall_s": round(dt_plc, 4),
            "model": "pairwise-traffic NoP + fast-tier canonical baseline",
        },
    }
    ratio = dt_full / dt_fast
    print(f"[bench] fast tier:      {n/dt_fast:,.0f} designs/s "
          f"(before refactor: {BEFORE['designs_per_s']:,.0f}, "
          f"PR-2 single tier: {PR2['designs_per_s']:,.0f})")
    print(f"[bench] full tier:      {n/dt_full:,.0f} designs/s (canonical)")
    print(f"[bench] full+placement: {n/dt_plc:,.0f} designs/s")
    print(f"[bench] fast/full ratio: {ratio:.2f}x")

    if args.placement_gain:
        record["placement_gain"] = _placement_gain_sweep(
            n_designs=8 if args.smoke else 16,
            n_iters=200 if args.smoke else 1000)

    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    print(f"[bench] wrote {args.out}")

    if args.assert_min_ratio is not None and ratio < args.assert_min_ratio:
        print(f"[bench] FAIL: fast/full throughput ratio {ratio:.2f}x "
              f"< required {args.assert_min_ratio:.2f}x", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
