"""Framework-side benchmarks (ours): DSE evaluation throughput (vmapped
jnp vs Pallas-interpret chiplet_eval), kernel sanity timings, and env
steps/sec — the numbers behind the 'pod-scale PPO' claim."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel as cm
from repro.core import env as chipenv
from repro.core import params as ps
from repro.kernels import ops, ref


def _time(fn, *args, iters=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters, out


def run(report):
    # design-point evaluation throughput (the DSE hot loop)
    n = 8192
    dp = ps.random_design(jax.random.PRNGKey(0), (n,))
    eval_jit = jax.jit(lambda d: cm.evaluate(d).reward)
    dt, _ = _time(eval_jit, dp)
    report("dse_eval_jnp", dt * 1e6,
           f"designs_per_sec={n/dt:,.0f}")

    dt, _ = _time(lambda d: ops.chiplet_eval(d, backend="pallas"), dp,
                  iters=2, warmup=1)
    report("dse_eval_pallas_interpret", dt * 1e6,
           f"designs_per_sec={n/dt:,.0f} (interpret mode; on-TPU target "
           f"is the compiled kernel)")

    # env throughput
    venv = chipenv.VecEnv(1024)
    states, obs = venv.reset(jax.random.PRNGKey(0))
    actions = chipenv.action_space.sample(jax.random.PRNGKey(1), (1024,))
    dt, _ = _time(lambda s, a: venv.step(s, a)[2], states, actions)
    report("env_steps", dt * 1e6, f"env_steps_per_sec={1024/dt:,.0f}")

    # flash attention (interpret) vs jnp reference
    q = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 512, 64))
    k = jax.random.normal(jax.random.PRNGKey(3), (1, 4, 512, 64))
    v = jax.random.normal(jax.random.PRNGKey(4), (1, 4, 512, 64))
    dt_ref, a = _time(lambda: ref.attention_reference(q, k, v), iters=3)
    report("attention_ref_jnp", dt_ref * 1e6, "B1H4L512D64")
    err = float(jnp.abs(
        ops.attention(q, k, v, backend="pallas") - a).max())
    report("attention_pallas_allclose", 0.0, f"max_err={err:.2e}")

    # SSD scan
    bh, L, p, nn = 4, 512, 64, 64
    x = jax.random.normal(jax.random.PRNGKey(5), (bh, L, p))
    dtt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(6),
                                            (bh, L))) * 0.1
    a_ = -jnp.exp(jax.random.normal(jax.random.PRNGKey(7), (bh,)))
    b_ = jax.random.normal(jax.random.PRNGKey(8), (bh, L, nn)) * 0.5
    c_ = jax.random.normal(jax.random.PRNGKey(9), (bh, L, nn)) * 0.5
    dt_c, y = _time(lambda: ref.ssd_chunked_jnp(x, dtt, a_, b_, c_),
                    iters=3)
    report("ssd_chunked_jnp", dt_c * 1e6, f"BH{bh}L{L}P{p}N{nn}")
    err = float(jnp.abs(ref.ssd_reference(x, dtt, a_, b_, c_) - y).max())
    report("ssd_chunked_allclose", 0.0, f"max_err={err:.2e}")
