"""Objective-weight study (paper Eq. 17: user-defined alpha, beta, gamma).

The paper claims users can steer the optimizer toward throughput, cost or
energy by re-weighting the objective. We verify the *direction* of each
weight's effect on the optimized design point: an energy-weighted
objective must find designs with lower comm energy/op than a
throughput-weighted one, and a cost-weighted objective lower packaging
cost — using the same SA population for each weighting.
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel as cm
from repro.core import env as chipenv
from repro.sa import annealing as sa

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"
SA_ITERS = 100_000 if FULL else 20_000
N_CHAINS = 8 if FULL else 4

WEIGHTINGS = {
    "throughput": cm.RewardWeights(alpha=jnp.float32(1.0),
                                   beta=jnp.float32(0.1),
                                   gamma=jnp.float32(0.01)),
    "balanced": cm.RewardWeights(alpha=jnp.float32(1.0),
                                 beta=jnp.float32(1.0),
                                 gamma=jnp.float32(0.1)),
    "cost": cm.RewardWeights(alpha=jnp.float32(0.2),
                             beta=jnp.float32(5.0),
                             gamma=jnp.float32(0.1)),
    "energy": cm.RewardWeights(alpha=jnp.float32(0.2),
                               beta=jnp.float32(0.1),
                               gamma=jnp.float32(20.0)),
}


def run(report):
    results = {}
    for name, weights in WEIGHTINGS.items():
        env_cfg = chipenv.EnvConfig(weights=weights)
        t0 = time.time()
        res = sa.run_population(jax.random.PRNGKey(5), N_CHAINS, env_cfg,
                                sa.SAConfig(n_iters=SA_ITERS))
        us = (time.time() - t0) * 1e6
        best = int(np.argmax(np.asarray(res.best_reward)))
        dp = jax.tree_util.tree_map(lambda x: x[best], res.best_design)
        m = cm.evaluate(dp)     # evaluate under NEUTRAL weights
        results[name] = m
        report(f"eq17_weights_{name}", us / N_CHAINS,
               f"eff_tops={float(m.eff_tops):.0f};"
               f"pkg_cost={float(m.pkg_cost):.0f};"
               f"e_comm_pj={float(m.e_comm_pj_per_op):.3f};"
               f"chiplets={int(m.n_dies)}")

    # directional checks (the paper's qualitative claim)
    ok_cost = float(results["cost"].pkg_cost) <= \
        float(results["throughput"].pkg_cost)
    ok_energy = float(results["energy"].e_comm_pj_per_op) <= \
        float(results["throughput"].e_comm_pj_per_op) + 1e-6
    ok_thr = float(results["throughput"].eff_tops) >= \
        float(results["cost"].eff_tops) - 1e-6
    report("eq17_directional", 0.0,
           f"cost_weight_lowers_pkg={ok_cost};"
           f"energy_weight_lowers_ecomm={ok_energy};"
           f"throughput_weight_maximizes_tops={ok_thr}")
