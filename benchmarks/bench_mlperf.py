"""Fig. 12 + Table 7 reproduction: optimized chiplet systems (60- and
112-chiplet) vs the monolithic A100-class baseline on the five MLPerf
workloads — inferences/sec, inferences/joule, die + package cost.

Two modeling modes are reported (DESIGN.md §5):
  - physics: SRAM-bounded operand amortization (honest defaults),
  - paper:   literal Eq.-13 traffic + link-only comm energy, which is the
             assumption set under which the paper's 3.7x energy headline
             reproduces.
"""

from __future__ import annotations

import dataclasses
import sys
import time

import jax.numpy as jnp
import numpy as np

from repro.core import costmodel as cm
from repro.core import hw_constants as hw
from repro.core import monolithic as mono
from repro.core import params as ps
from repro.core import workload as wl

sys.path.insert(0, "tests")


def _designs():
    from test_costmodel import case_i_design, case_ii_design
    return {"60chiplet": case_i_design(), "112chiplet": case_ii_design()}


def run(report):
    paper_cfg = dataclasses.replace(
        hw.DEFAULT_HW, comm_reuse_systolic=False, e_bit_hbm_device_pj=0.0)
    for mode, cfg in (("physics", hw.DEFAULT_HW), ("paper", paper_cfg)):
        for bench, workload in wl.MLPERF.items():
            t0 = time.time()
            rows = {}
            for name, dp in _designs().items():
                m = cm.evaluate(dp, workload, cfg=cfg)
                rows[name] = m
            mm = mono.evaluate(workload, cfg=cfg,
                               iso_tops=rows["60chiplet"].eff_tops)
            us = (time.time() - t0) * 1e6
            m60 = rows["60chiplet"]
            report(
                f"fig12_{mode}_{bench}", us,
                f"inf_s_60={float(m60.tasks_per_sec):.1f};"
                f"inf_s_112={float(rows['112chiplet'].tasks_per_sec):.1f};"
                f"inf_s_mono={float(mm.tasks_per_sec/mm.n_chips_iso):.1f};"
                f"T_ratio={float(m60.eff_tops/mm.eff_tops):.2f};"
                f"E_ratio={float(mm.energy_per_task_j/m60.energy_per_task_j):.2f}")

    # cost panel (Fig. 12c): workload-independent
    m60 = cm.evaluate(_designs()["60chiplet"])
    m112 = cm.evaluate(_designs()["112chiplet"])
    mm = mono.evaluate()
    report("fig12c_cost", 0.0,
           f"die_mono_over_60={float(mm.die_cost_paper/m60.die_cost_paper):.0f}x"
           f"(paper:76x);"
           f"die_mono_over_112={float(mm.die_cost_paper/m112.die_cost_paper):.0f}x"
           f"(paper:143x);"
           f"pkg_60_over_mono={float(m60.pkg_cost/mm.pkg_cost):.2f}x(paper:1.62x);"
           f"pkg_112_over_mono={float(m112.pkg_cost/mm.pkg_cost):.2f}x(paper:2.46x);"
           f"die_phys_ratio_60={float(mm.die_cost/m60.die_cost):.2f}x")
