"""Fig. 7 + Fig. 8 reproduction: episode-length and entropy-coefficient
impact on PPO convergence; initial-temperature impact on SA."""

from __future__ import annotations

import dataclasses
import os
import time

import jax
import numpy as np

from repro.core import env as chipenv
from repro.rl import ppo
from repro.sa import annealing as sa

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"
RL_STEPS = 100_000 if FULL else 24_576
SA_ITERS = 100_000 if FULL else 20_000


def run(report):
    # Fig. 7: episode length 2 vs 10 — longer episodes raise the mean
    # episodic reward but not the cost-model value (reward/step)
    for ep_len in (2, 10):
        env_cfg = chipenv.EnvConfig(episode_len=ep_len)
        cfg = ppo.PPOConfig(n_steps=256, n_envs=8)
        t0 = time.time()
        res = ppo.train(jax.random.PRNGKey(0), env_cfg, cfg,
                        total_timesteps=RL_STEPS)
        us = (time.time() - t0) * 1e6
        ep_r = float(res.log.mean_episodic_reward[-1])
        cost_val = ep_r / ep_len      # the paper's normalization
        report(f"fig7_episode_len_{ep_len}", us,
               f"mean_episodic={ep_r:.1f};cost_model_value={cost_val:.1f};"
               f"best={float(res.best_reward):.1f}")

    # Fig. 8a: entropy coefficient 0 vs 0.1
    for ent in (0.0, 0.1):
        cfg = ppo.PPOConfig(n_steps=256, n_envs=8, ent_coef=ent)
        t0 = time.time()
        res = ppo.train(jax.random.PRNGKey(1), chipenv.EnvConfig(), cfg,
                        total_timesteps=RL_STEPS)
        us = (time.time() - t0) * 1e6
        report(f"fig8a_entropy_{ent}", us,
               f"final={float(res.log.mean_episodic_reward[-1]):.1f};"
               f"best={float(res.best_reward):.1f}")

    # Fig. 8b: SA initial temperature 1 vs 200
    for temp in (1.0, 200.0):
        cfg = sa.SAConfig(n_iters=SA_ITERS, temperature=temp)
        t0 = time.time()
        res = sa.run_population(jax.random.PRNGKey(2), 4,
                                chipenv.EnvConfig(), cfg)
        us = (time.time() - t0) * 1e6
        vals = np.asarray(res.best_reward)
        report(f"fig8b_sa_temp_{int(temp)}", us / 4,
               f"best={vals.max():.1f};mean={vals.mean():.1f}")
