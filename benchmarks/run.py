"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Set REPRO_BENCH_FULL=1 for
paper-scale seeds/iterations (default: CI-scale, ~5 min on CPU).

    PYTHONPATH=src python -m benchmarks.run [--only fig12,...]
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (bench_ablation, bench_kernels, bench_mlperf,
                        bench_optimizer, bench_weights, bench_yield)

MODULES = {
    "yield": bench_yield,          # Fig. 3
    "optimizer": bench_optimizer,  # Fig. 9-11, Table 6
    "mlperf": bench_mlperf,        # Fig. 12, Table 7
    "ablation": bench_ablation,    # Fig. 7-8
    "weights": bench_weights,      # Eq. 17 objective-weight study
    "kernels": bench_kernels,      # framework perf (ours)
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all",
                    help=f"comma list of {sorted(MODULES)}")
    args = ap.parse_args()
    names = list(MODULES) if args.only == "all" else args.only.split(",")

    print("name,us_per_call,derived")
    failed = []

    def report(name: str, us: float, derived: str = ""):
        print(f"{name},{us:.1f},{derived}", flush=True)

    for name in names:
        t0 = time.time()
        try:
            MODULES[name].run(report)
        except Exception as e:                             # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
            report(f"{name}_FAILED", 0.0, repr(e))
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
