"""Fig. 3 reproduction: (a) yield & cost/yielded-area vs die area per tech
node; (b) normalized NoP latency vs number of chiplets."""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import costmodel as cm
from repro.core import hw_constants as hw
from repro.core import params as ps


def fig3a_yield_vs_area():
    areas = np.linspace(25, 800, 32)
    rows = []
    for node, d in hw.DEFECT_DENSITY_PER_CM2.items():
        y = np.asarray(cm.die_yield(jnp.asarray(areas), d))
        cost_per_area = 1.0 / y
        rows.append((node, areas, y, cost_per_area))
    return rows


def fig3b_latency_vs_chiplets():
    base = ps.DesignPoint(*[jnp.int32(0)] * 14)._replace(
        ai_dr_2p5d=jnp.int32(19), ai_links_2p5d=jnp.int32(61),
        hbm_dr_2p5d=jnp.int32(19), hbm_links_2p5d=jnp.int32(97),
        hbm_mask=jnp.int32(29))
    counts = [2, 4, 8, 16, 32, 64, 96, 128]
    lat = []
    for n in counts:
        m = cm.evaluate(base._replace(n_chiplets=jnp.int32(n - 1)))
        lat.append(float(m.lat_ai_ai_ns))
    return counts, lat


def run(report):
    t0 = time.time()
    rows = fig3a_yield_vs_area()
    dt = (time.time() - t0) * 1e6
    for node, areas, y, cpa in rows:
        # anchors: 14nm @400mm^2 ~75%, 7nm @826mm^2 ~48%
        report(f"fig3a_yield_{node}", dt / len(rows),
               f"y(400)={np.interp(400, areas, y):.3f}"
               f";y(800)={np.interp(800, areas, y):.3f}")
    t0 = time.time()
    counts, lat = fig3b_latency_vs_chiplets()
    report("fig3b_latency_vs_chiplets", (time.time() - t0) * 1e6,
           f"lat2={lat[0]:.1f}ns;lat128={lat[-1]:.1f}ns;"
           f"monotone={all(b >= a for a, b in zip(lat, lat[1:]))}")
