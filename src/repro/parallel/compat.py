"""jax version compatibility shims shared by the sharded modules.

jax >= 0.5 exposes ``jax.shard_map`` (kw ``check_vma``); 0.4.x only has
``jax.experimental.shard_map.shard_map`` (kw ``check_rep``). Resolve once
here so every call site stays in sync when the API moves again.
"""

from __future__ import annotations

import jax

try:
    shard_map = jax.shard_map
    SHARD_MAP_UNCHECKED_KW = {"check_vma": False}
except AttributeError:
    from jax.experimental.shard_map import shard_map       # noqa: F401
    SHARD_MAP_UNCHECKED_KW = {"check_rep": False}

# ``jax.core.Tracer`` is a deprecated access path on newer jax (the
# public spelling is ``jax.Tracer``, added in 0.4.x); resolve whichever
# exists once so guard sites (costmodel eval taps) don't touch
# ``jax.core`` directly.
Tracer = getattr(jax, "Tracer", None)
if Tracer is None:  # pragma: no cover - depends on installed jax
    Tracer = jax.core.Tracer


def is_tracer(x) -> bool:
    """True when ``x`` is an abstract value from an active jax trace."""
    return isinstance(x, Tracer)
