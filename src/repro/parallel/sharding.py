"""Logical-axis sharding rules for the LM substrate (DP/TP/SP/EP).

The model code annotates activations with *logical* axes via ``lshard``;
the mapping logical axis -> mesh axis lives in ``ShardingRules``. With no
active mesh (CPU smoke tests) every annotation is a no-op, so the same
model code runs single-device and on the 512-chip production mesh.

Default production mapping (TPU v5e pods, DESIGN.md):

    batch   -> ("pod", "data")     pure data parallel over pods
    seq     -> "model"             sequence parallelism for the residual
                                   stream between blocks (Megatron-SP):
                                   cuts saved activations by the TP degree
    heads   -> "model"             tensor parallel attention
    ff      -> "model"             tensor parallel MLP
    vocab   -> "model"             sharded embedding + logits
    experts -> "model"             expert parallelism (MoE)
    kv_seq  -> "model"             decode KV caches shard the *sequence*
                                   axis (kv_heads of GQA archs are too few
                                   to shard 16 ways)

GSPMD inserts the all-gather/reduce-scatter pairs at the SP<->TP
boundaries and the all-to-alls for EP.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    batch: Tuple[str, ...] = ("pod", "data")
    seq: Optional[str] = "model"          # sequence parallelism (None = off)
    heads: Optional[str] = "model"
    ff: Optional[str] = "model"
    vocab: Optional[str] = "model"
    experts: Optional[str] = "model"
    kv_seq: Optional[str] = "model"

    def axis(self, logical: Optional[str]):
        if logical is None:
            return None
        if logical == "batch":
            return self.batch
        return getattr(self, logical)


SINGLE_POD_RULES = ShardingRules(batch=("data",))
MULTI_POD_RULES = ShardingRules(batch=("pod", "data"))


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Optional[ShardingRules] = None


_CTX = _Ctx()


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: Optional[ShardingRules] = None):
    """Activate sharding annotations for model code traced inside."""
    if rules is None:
        rules = (MULTI_POD_RULES if "pod" in mesh.axis_names
                 else SINGLE_POD_RULES)
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        with mesh:
            yield rules
    finally:
        _CTX.mesh, _CTX.rules = prev


def active_rules() -> Optional[ShardingRules]:
    return _CTX.rules


def spec(*logical_axes) -> P:
    """PartitionSpec for the given logical axes under the active rules."""
    rules = _CTX.rules
    if rules is None:
        return P()
    return P(*[rules.axis(a) for a in logical_axes])


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def best_effort_spec(mesh: Mesh, p: P, shape) -> P:
    """Drop spec entries that do not divide the dimension (e.g. 14 query
    heads over a 16-way model axis) — GSPMD would pad; replication is the
    predictable choice and is logged in the dry-run report."""
    out = []
    for dim, axis in zip(shape, tuple(p) + (None,) * (len(shape) - len(p))):
        if axis is not None and dim % _axis_size(mesh, axis) != 0:
            axis = None
        out.append(axis)
    return P(*out)


def lshard(x, *logical_axes):
    """with_sharding_constraint under the active rules (no-op without mesh)."""
    if _CTX.mesh is None or _CTX.rules is None:
        return x
    assert x.ndim == len(logical_axes), (x.shape, logical_axes)
    p = best_effort_spec(_CTX.mesh, spec(*logical_axes), x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, p))


def named_sharding(mesh: Mesh, *logical_axes,
                   rules: Optional[ShardingRules] = None) -> NamedSharding:
    rules = rules or (MULTI_POD_RULES if "pod" in mesh.axis_names
                      else SINGLE_POD_RULES)
    return NamedSharding(
        mesh, P(*[rules.axis(a) for a in logical_axes]))
