"""Distribution: logical sharding rules (DP/TP/SP/EP) and pipeline stages."""
