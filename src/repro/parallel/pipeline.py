"""Pipeline parallelism: GPipe-style microbatched stage execution.

Opt-in alternative to pure TP for very deep models (qwen3-moe's 94
layers): the layer stack splits into S stages along a dedicated "stage"
mesh axis; microbatches stream through stages with a shard_map +
collective_permute rotation. With M microbatches the bubble fraction is
(S-1)/(M+S-1) — reported per config by ``bubble_fraction``.

Implementation: the classic loop-skewed schedule. At tick t, stage s
processes microbatch (t - s); activations hop stage s -> s+1 between
ticks via ppermute. All stages run the same block code (same-kind
segments), so one program serves every stage.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.parallel.compat import SHARD_MAP_UNCHECKED_KW as _SHARD_MAP_KW
from repro.parallel.compat import shard_map as _shard_map


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)


def pipelined_forward(mesh: Mesh, stage_axis: str,
                      block_fn: Callable, n_stages: int,
                      n_microbatches: int):
    """Build fn(stage_params, x_microbatches) -> y_microbatches.

    stage_params: pytree with leading [n_stages] axis, sharded over
    ``stage_axis`` (one stage's params per mesh slice).
    x_microbatches: (M, mb, ...) activations, replicated across stages.
    block_fn(params_slice, x) -> x: one stage's computation.
    """
    assert n_stages == mesh.shape[stage_axis]

    def body(stage_params, xs):
        # inside shard_map: stage_params has its local stage slice
        # (leading axis 1), xs is the full (M, mb, d) microbatch stack.
        sp = jax.tree_util.tree_map(lambda a: a[0], stage_params)
        stage_id = jax.lax.axis_index(stage_axis)
        m, mb = xs.shape[0], xs.shape[1]
        n_ticks = n_microbatches + n_stages - 1

        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(carry, t):
            buf, outs = carry          # buf: (mb, d) current activation
            mb_idx = t - stage_id      # microbatch this stage works on
            valid = (mb_idx >= 0) & (mb_idx < n_microbatches)
            # stage 0 loads a fresh microbatch; others use the rotated buf
            x_in = jnp.where(
                stage_id == 0,
                xs[jnp.clip(mb_idx, 0, n_microbatches - 1)],
                buf)
            y = block_fn(sp, x_in)
            y = jnp.where(valid, y, buf)
            # last stage records its finished microbatch
            outs = jnp.where(
                (stage_id == n_stages - 1) & valid,
                outs.at[jnp.clip(mb_idx, 0, n_microbatches - 1)].set(y),
                outs)
            # rotate activations to the next stage
            buf_next = jax.lax.ppermute(y, stage_axis, perm)
            return (buf_next, outs), None

        buf0 = jnp.zeros_like(xs[0])
        outs0 = jnp.zeros_like(xs)
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0),
                                    jnp.arange(n_ticks))
        # only the last stage holds real outputs (others accumulated zeros);
        # psum broadcasts them to every stage replica
        outs = jax.lax.psum(outs, stage_axis)
        return outs

    other_axes = tuple(a for a in mesh.axis_names if a != stage_axis)
    del other_axes
    return _shard_map(
        body, mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(),
        **_SHARD_MAP_KW)
