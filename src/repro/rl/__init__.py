"""Pure-JAX PPO for Chiplet-Gym (paper §4.1, Table 5)."""
