"""Actor-critic MLPs for Chiplet-Gym PPO (paper §5.2.1).

Policy network  [obs_dim, 64, 64, sum(head_sizes)]  (MultiDiscrete heads)
Value network   [obs_dim, 64, 64, 1]
tanh activations, orthogonal init (SB3 defaults, which the paper uses).

Every head-structured function takes an optional ``head_sizes`` so the
same networks serve both the paper's 14 Table-1 heads (the default) and
the placement-extended 18-head action space
(``env.EnvConfig(placement_actions=True)``).
"""

from __future__ import annotations

from typing import List, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import params as ps


class MLPParams(NamedTuple):
    weights: List[jnp.ndarray]
    biases: List[jnp.ndarray]


class ACParams(NamedTuple):
    policy: MLPParams
    value: MLPParams


def _orthogonal(key, shape, scale):
    a = jax.random.normal(key, shape, jnp.float32)
    q, r = jnp.linalg.qr(a if shape[0] >= shape[1] else a.T)
    q = q * jnp.sign(jnp.diag(r))
    if shape[0] < shape[1]:
        q = q.T
    return scale * q[:shape[0], :shape[1]]


def init_mlp(key, sizes: Sequence[int], out_scale: float) -> MLPParams:
    ws, bs = [], []
    keys = jax.random.split(key, len(sizes) - 1)
    for i, k in enumerate(keys):
        scale = out_scale if i == len(sizes) - 2 else jnp.sqrt(2.0)
        ws.append(_orthogonal(k, (sizes[i], sizes[i + 1]), scale))
        bs.append(jnp.zeros((sizes[i + 1],), jnp.float32))
    return MLPParams(weights=ws, biases=bs)


def apply_mlp(p: MLPParams, x: jnp.ndarray) -> jnp.ndarray:
    h = x
    n = len(p.weights)
    for i, (w, b) in enumerate(zip(p.weights, p.biases)):
        h = h @ w + b
        if i < n - 1:
            h = jnp.tanh(h)
    return h


def init_actor_critic(key, obs_dim: int = 10,
                      hidden: Tuple[int, int] = (64, 64),
                      head_sizes: Sequence[int] = None) -> ACParams:
    hs = ps.HEAD_SIZES if head_sizes is None else tuple(head_sizes)
    kp, kv = jax.random.split(key)
    policy = init_mlp(kp, (obs_dim, *hidden, sum(hs)), out_scale=0.01)
    value = init_mlp(kv, (obs_dim, *hidden, 1), out_scale=1.0)
    return ACParams(policy=policy, value=value)


# --- MultiDiscrete categorical over the action heads -----------------------
# (default: the 14 Table-1 heads; pass env.head_sizes(cfg) for the
# placement-extended space)

def _offsets(head_sizes) -> Tuple[int, ...]:
    out, off = [], 0
    for h in head_sizes:
        out.append(off)
        off += h
    return tuple(out)


def split_logits(logits: jnp.ndarray,
                 head_sizes: Sequence[int] = None) -> List[jnp.ndarray]:
    hs = ps.HEAD_SIZES if head_sizes is None else tuple(head_sizes)
    return [logits[..., o:o + h] for o, h in zip(_offsets(hs), hs)]


def sample_action(key, logits: jnp.ndarray,
                  head_sizes: Sequence[int] = None) -> jnp.ndarray:
    """Sample one index per head; returns (..., n_heads) int32."""
    heads = split_logits(logits, head_sizes)
    keys = jax.random.split(key, len(heads))
    idx = [jax.random.categorical(k, h) for k, h in zip(keys, heads)]
    return jnp.stack(idx, axis=-1).astype(jnp.int32)


def log_prob(logits: jnp.ndarray, action: jnp.ndarray,
             head_sizes: Sequence[int] = None) -> jnp.ndarray:
    """Joint log-probability of a (..., n_heads) MultiDiscrete action."""
    heads = split_logits(logits, head_sizes)
    total = 0.0
    for i, h in enumerate(heads):
        logp = jax.nn.log_softmax(h, axis=-1)
        total = total + jnp.take_along_axis(
            logp, action[..., i:i + 1], axis=-1)[..., 0]
    return total


def entropy(logits: jnp.ndarray,
            head_sizes: Sequence[int] = None) -> jnp.ndarray:
    """Sum of per-head categorical entropies."""
    heads = split_logits(logits, head_sizes)
    total = 0.0
    for h in heads:
        logp = jax.nn.log_softmax(h, axis=-1)
        total = total - jnp.sum(jnp.exp(logp) * logp, axis=-1)
    return total


def greedy_action(logits: jnp.ndarray,
                  head_sizes: Sequence[int] = None) -> jnp.ndarray:
    heads = split_logits(logits, head_sizes)
    return jnp.stack([jnp.argmax(h, axis=-1) for h in heads],
                     axis=-1).astype(jnp.int32)


def policy_value(params: ACParams, obs: jnp.ndarray):
    logits = apply_mlp(params.policy, obs)
    value = apply_mlp(params.value, obs)[..., 0]
    return logits, value
