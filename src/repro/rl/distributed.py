"""Pod-scale data-parallel PPO via shard_map (beyond-paper scaling).

The paper trains PPO on a hexa-core CPU. Here the same update step runs
data-parallel over an entire (pod, data, model) TPU mesh: every device
owns ``n_envs`` Chiplet-Gym environments and a full policy replica;
minibatch gradients are ``pmean``-reduced across *all* mesh axes, so the
policy stays bit-identical on every device while the rollout batch scales
with the device count (512 devices x 8 envs = 4096 parallel environments).

This module is what ``launch/dryrun.py`` lowers for the ``chipletgym``
config — proving the paper's technique itself shards over the production
mesh, alongside the 10 assigned LM architectures.
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import env as chipenv
from repro.core import params as ps
from repro.parallel.compat import SHARD_MAP_UNCHECKED_KW as _SHARD_MAP_KW
from repro.parallel.compat import shard_map as _shard_map
from repro.rl import networks as nets
from repro.rl import ppo
from repro.training.optim import Adam


def _env_axes(mesh: Mesh) -> Tuple[str, ...]:
    """All mesh axes act as environment-parallel axes for RL."""
    return tuple(mesh.axis_names)


def init_carry(key, mesh: Mesh, env_cfg: chipenv.EnvConfig,
               cfg: ppo.PPOConfig, optimizer: Adam,
               scenario: chipenv.Scenario = None) -> ppo.TrainCarry:
    """Build a TrainCarry whose env fields carry a global leading axis of
    ``n_devices * n_envs`` (sharded), params replicated."""
    scenario = env_cfg.scenario() if scenario is None else scenario
    n_dev = mesh.devices.size
    total_envs = n_dev * cfg.n_envs
    k_init, k_env, k_train = jax.random.split(key, 3)
    params = nets.init_actor_critic(k_init, obs_dim=chipenv.obs_dim(env_cfg),
                                    head_sizes=chipenv.head_sizes(env_cfg))
    opt_state = optimizer.init(params)
    env_keys = jax.random.split(k_env, total_envs)
    env_states, obs = jax.vmap(
        lambda k: chipenv.reset(k, env_cfg, scenario))(env_keys)
    keys = jax.random.split(k_train, n_dev)
    return ppo.TrainCarry(
        params=params, opt_state=opt_state, env_states=env_states, obs=obs,
        key=keys,                                  # (n_dev, 2) one per shard
        best_reward=jnp.float32(-jnp.inf),
        best_action=jnp.zeros((chipenv.action_dim(env_cfg),), jnp.int32))


def carry_specs(mesh: Mesh) -> ppo.TrainCarry:
    """PartitionSpecs for each TrainCarry field."""
    env_axes = _env_axes(mesh)
    return ppo.TrainCarry(
        params=P(),                        # replicated policy
        opt_state=P(),
        env_states=P(env_axes),            # env batch sharded over all axes
        obs=P(env_axes),
        key=P(env_axes),                   # one key per device
        best_reward=P(),
        best_action=P(),
    )


def make_pod_update(mesh: Mesh, env_cfg: chipenv.EnvConfig,
                    cfg: ppo.PPOConfig, optimizer: Adam,
                    scenario: chipenv.Scenario = None):
    """One data-parallel PPO update across the whole mesh.

    Returns a jit'd function carry -> (carry, log). Gradients are averaged
    over every mesh axis; the globally best design point is all-gathered
    and argmax-selected so all replicas agree. ``scenario`` (replicated)
    selects the (workload, reward-weight) setting being optimized.
    """
    scenario = env_cfg.scenario() if scenario is None else scenario
    env_axes = _env_axes(mesh)
    n_act = chipenv.action_dim(env_cfg)
    grad_reduce = lambda g: jax.lax.pmean(g, env_axes)
    local_update = ppo.make_update_step(env_cfg, cfg, optimizer,
                                        grad_reduce=grad_reduce)

    def shard_body(carry: ppo.TrainCarry):
        # inside shard_map: env fields have their local block, key is (1,2)
        local = carry._replace(key=carry.key[0])
        local, log = local_update(local, None, scenario)

        # agree on the global best (reward, action) pair
        all_r = jax.lax.all_gather(local.best_reward, env_axes[0])
        all_a = jax.lax.all_gather(local.best_action, env_axes[0])
        for ax in env_axes[1:]:
            all_r = jax.lax.all_gather(all_r, ax).reshape(-1)
            all_a = jax.lax.all_gather(all_a, ax).reshape(-1, n_act)
        all_r = all_r.reshape(-1)
        all_a = all_a.reshape(-1, n_act)
        idx = jnp.argmax(all_r)
        best_r, best_a = all_r[idx], all_a[idx]

        out = local._replace(key=local.key[None],
                             best_reward=best_r, best_action=best_a)
        log = log._replace(
            mean_step_reward=jax.lax.pmean(log.mean_step_reward, env_axes),
            mean_episodic_reward=jax.lax.pmean(
                log.mean_episodic_reward, env_axes),
            best_reward=best_r,
            policy_loss=jax.lax.pmean(log.policy_loss, env_axes),
            value_loss=jax.lax.pmean(log.value_loss, env_axes),
            entropy=jax.lax.pmean(log.entropy, env_axes))
        return out, log

    specs = carry_specs(mesh)
    log_specs = ppo.TrainLog(*([P()] * len(ppo.TrainLog._fields)))
    sharded = _shard_map(shard_body, mesh=mesh,
                         in_specs=(specs,), out_specs=(specs, log_specs),
                         **_SHARD_MAP_KW)
    return jax.jit(sharded)


def train_scenario_population_sharded(key, scenarios: chipenv.Scenario,
                                      n_agents: int, mesh: Mesh,
                                      env_cfg: chipenv.EnvConfig = chipenv.EnvConfig(),
                                      cfg: ppo.PPOConfig = ppo.PPOConfig(),
                                      total_timesteps: int = 250_000,
                                      axis_name: str = None) -> ppo.TrainResult:
    """``ppo.train_scenario_population`` with the scenario axis sharded.

    Each device of the mesh axis owns ``S / n_shards`` scenarios and runs
    the (scenario x seed)-vmapped PPO population on its shard — the whole
    suite trains as one shard_mapped XLA program. Key derivation matches
    the unsharded function exactly (``split(key, S)`` then scenario i gets
    key i), so results are seed-for-seed identical to
    ``ppo.train_scenario_population`` — verified by the CPU smoke test in
    tests/test_distributed.py. Every TrainResult leaf keeps its leading
    scenario axis (sharded over the mesh).
    """
    axis_name = mesh.axis_names[0] if axis_name is None else axis_name
    n_scen = int(jnp.shape(scenarios.weights.alpha)[0])
    n_shards = int(mesh.shape[axis_name])
    if n_scen % n_shards:
        raise ValueError(f"scenario count {n_scen} must divide over "
                         f"mesh axis {axis_name!r} ({n_shards} shards)")
    keys = jax.random.split(key, n_scen)

    def shard_body(keys_local, scen_local):
        return jax.vmap(
            lambda k, s: ppo.train_population(k, n_agents, env_cfg, cfg,
                                              total_timesteps, s)
        )(keys_local, scen_local)

    spec = P(axis_name)
    sharded = _shard_map(shard_body, mesh=mesh,
                         in_specs=(spec, spec), out_specs=spec,
                         **_SHARD_MAP_KW)
    return jax.jit(sharded)(keys, scenarios)


def train_distributed(key, mesh: Mesh,
                      env_cfg: chipenv.EnvConfig = chipenv.EnvConfig(),
                      cfg: ppo.PPOConfig = ppo.PPOConfig(),
                      n_updates: int = 10,
                      scenario: chipenv.Scenario = None):
    """Full distributed training loop (used by launch/train.py --arch chipletgym)."""
    scenario = env_cfg.scenario() if scenario is None else scenario
    optimizer = Adam(learning_rate=cfg.learning_rate,
                     max_grad_norm=cfg.max_grad_norm)
    carry = init_carry(key, mesh, env_cfg, cfg, optimizer, scenario)

    # place carry according to its (prefix) specs
    def _put(tree, spec):
        return jax.tree_util.tree_map(
            lambda x: jax.device_put(x, NamedSharding(mesh, spec)), tree)

    specs = carry_specs(mesh)
    carry = ppo.TrainCarry(*[
        _put(getattr(carry, f), getattr(specs, f))
        for f in ppo.TrainCarry._fields])
    update = make_pod_update(mesh, env_cfg, cfg, optimizer, scenario)
    logs = []
    for _ in range(n_updates):
        carry, log = update(carry)
        logs.append(log)
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *logs)
    return carry, stacked
