"""PPO for Chiplet-Gym, pure JAX (paper §4.1 / §5.2.1, Table 5).

Faithful to the paper's Stable-Baselines3 setup: MLP actor-critic
([obs,64,64,heads] / [obs,64,64,1], tanh, orthogonal init), clipped
surrogate with per-minibatch advantage normalization, GAE(lambda),
entropy regularization, Adam with global-norm clipping.

Differences from SB3 (documented in DESIGN.md §8): the entire
rollout -> GAE -> epochs x minibatches update is a single jitted XLA
program (`lax.scan` everywhere), so a quarter-million environment steps
train in seconds on CPU and the same program data-parallelizes over a pod
(see rl/distributed.py).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import env as chipenv
from repro.core import params as ps
from repro.rl import networks as nets
from repro.telemetry import counters as tl
from repro.training.optim import Adam, apply_updates


@dataclasses.dataclass(frozen=True)
class PPOConfig:
    """Table 5 hyper-parameters (paper defaults)."""

    n_steps: int = 2048          # rollout length per env per update
    n_envs: int = 8
    batch_size: int = 64
    n_epochs: int = 10
    learning_rate: float = 3e-4
    clip_range: float = 0.2
    vf_coef: float = 0.5
    ent_coef: float = 0.1        # paper: 0.1 for exploration (Fig. 8a)
    gamma: float = 0.99
    gae_lambda: float = 0.95     # "bias-variance trade-off factor"
    max_grad_norm: float = 0.5
    # in-scan telemetry (telemetry/counters.PPOUpdateStats): per-update
    # GAE-return mean/std, policy entropy, approx-KL (k1) and clip
    # fraction, returned as TrainResult.telemetry. False (default)
    # statically compiles the exact pre-telemetry program (losses and
    # the key stream are untouched; the extra stats are computed from
    # quantities the loss already produces).
    telemetry: bool = False


class Rollout(NamedTuple):
    obs: jnp.ndarray        # (T, E, obs_dim)
    actions: jnp.ndarray    # (T, E, 14)
    log_probs: jnp.ndarray  # (T, E)
    values: jnp.ndarray     # (T, E)
    rewards: jnp.ndarray    # (T, E)
    dones: jnp.ndarray      # (T, E)


class TrainCarry(NamedTuple):
    params: nets.ACParams
    opt_state: object
    env_states: chipenv.EnvState
    obs: jnp.ndarray
    key: jnp.ndarray
    best_reward: jnp.ndarray
    best_action: jnp.ndarray     # (14,) int32


class TrainLog(NamedTuple):
    mean_step_reward: jnp.ndarray
    mean_episodic_reward: jnp.ndarray
    best_reward: jnp.ndarray
    policy_loss: jnp.ndarray
    value_loss: jnp.ndarray
    entropy: jnp.ndarray


class TrainResult(NamedTuple):
    params: nets.ACParams
    log: TrainLog                # stacked over updates
    best_design: ps.DesignPoint
    best_reward: jnp.ndarray
    best_action: jnp.ndarray     # full action incl. any placement heads
    # per-update stats (cfg.telemetry only; counters.PPOUpdateStats
    # with a leading updates axis)
    telemetry: tl.PPOUpdateStats = None


def collect_rollout(params, env_states, obs, key, env_cfg, cfg: PPOConfig,
                    scenario: chipenv.Scenario = None):
    """T steps of E vectorized environments under the current policy."""
    scenario = env_cfg.scenario() if scenario is None else scenario
    heads = chipenv.head_sizes(env_cfg)

    def step_fn(carry, _):
        states, obs, key = carry
        key, k_act = jax.random.split(key)
        logits, value = nets.policy_value(params, obs)
        action = nets.sample_action(k_act, logits, heads)   # (E, n_heads)
        logp = nets.log_prob(logits, action, heads)
        if env_cfg.placement_episode:
            # cond-gated batched reset: synchronized placement episodes
            # pay the placement-ctx + cache rebuild only on boundary
            # steps (the separately compiled reset branch can move
            # boundary obs by an ulp, so the classic design env keeps
            # the per-env path and its recorded trajectories bit-exact)
            states, obs_next, reward, done, _ = chipenv.auto_reset_step_vec(
                states, action, env_cfg, scenario)
        else:
            states, obs_next, reward, done, _ = jax.vmap(
                lambda s, a: chipenv.auto_reset_step(
                    s, a, env_cfg, scenario))(states, action)
        rec = Rollout(obs=obs, actions=action, log_probs=logp,
                      values=value, rewards=reward,
                      dones=done.astype(jnp.float32))
        return (states, obs_next, key), rec

    (env_states, obs, key), traj = jax.lax.scan(
        step_fn, (env_states, obs, key), None, length=cfg.n_steps)
    return env_states, obs, key, traj


def compute_gae(traj: Rollout, last_value, cfg: PPOConfig):
    """Generalized advantage estimation over the time axis."""

    def back(carry, inp):
        next_adv, next_value = carry
        reward, value, done = inp
        nonterminal = 1.0 - done
        delta = reward + cfg.gamma * next_value * nonterminal - value
        adv = delta + cfg.gamma * cfg.gae_lambda * nonterminal * next_adv
        return (adv, value), adv

    (_, _), advantages = jax.lax.scan(
        back, (jnp.zeros_like(last_value), last_value),
        (traj.rewards, traj.values, traj.dones), reverse=True)
    returns = advantages + traj.values
    return advantages, returns


def ppo_loss(params, batch, cfg: PPOConfig, head_sizes=None,
             extra_stats: bool = False):
    obs, actions, old_logp, advantages, returns = batch
    logits, value = nets.policy_value(params, obs)
    logp = nets.log_prob(logits, actions, head_sizes)
    ratio = jnp.exp(logp - old_logp)

    adv = (advantages - advantages.mean()) / (advantages.std() + 1e-8)
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - cfg.clip_range, 1.0 + cfg.clip_range) * adv
    policy_loss = -jnp.mean(jnp.minimum(unclipped, clipped))

    value_loss = 0.5 * jnp.mean(jnp.square(returns - value))
    ent = jnp.mean(nets.entropy(logits, head_sizes))
    total = (policy_loss + cfg.vf_coef * value_loss - cfg.ent_coef * ent)
    if extra_stats:
        # telemetry-only diagnostics from quantities already computed:
        # k1 approx-KL and the clipped-ratio fraction
        approx_kl = jnp.mean(old_logp - logp)
        clip_frac = jnp.mean(
            (jnp.abs(ratio - 1.0) > cfg.clip_range).astype(jnp.float32))
        return total, (policy_loss, value_loss, ent, approx_kl, clip_frac)
    return total, (policy_loss, value_loss, ent)


def make_update_step(env_cfg: chipenv.EnvConfig, cfg: PPOConfig,
                     optimizer: Adam, grad_reduce=None):
    """One PPO update: rollout -> GAE -> epochs x minibatches.

    ``grad_reduce`` (optional) reduces gradients across data-parallel
    devices (rl/distributed.py passes a psum-mean); identity by default.
    The returned ``update(carry, _, scenario=None)`` takes the scenario as
    a *traced* argument so one compiled update serves any (workload,
    reward-weight) setting, including vmapped batches of them.
    """
    total = cfg.n_steps * cfg.n_envs
    n_minibatches = max(total // cfg.batch_size, 1)
    heads = chipenv.head_sizes(env_cfg)
    n_act = len(heads)

    def update(carry: TrainCarry, _, scenario: chipenv.Scenario = None):
        scenario = env_cfg.scenario() if scenario is None else scenario
        params, opt_state = carry.params, carry.opt_state
        env_states, obs, key = carry.env_states, carry.obs, carry.key

        env_states, obs, key, traj = collect_rollout(
            params, env_states, obs, key, env_cfg, cfg, scenario)
        _, last_value = nets.policy_value(params, obs)
        advantages, returns = compute_gae(traj, last_value, cfg)

        # track the best design point ever visited (Alg. 1 exhaustive pick)
        flat_rewards = traj.rewards.reshape(-1)
        flat_actions = traj.actions.reshape(-1, n_act)
        idx = jnp.argmax(flat_rewards)
        cand_r, cand_a = flat_rewards[idx], flat_actions[idx]
        better = cand_r > carry.best_reward
        best_reward = jnp.where(better, cand_r, carry.best_reward)
        best_action = jnp.where(better, cand_a, carry.best_action)

        # flatten (T, E) -> (N,)
        data = (
            traj.obs.reshape(-1, traj.obs.shape[-1]),
            traj.actions.reshape(-1, n_act),
            traj.log_probs.reshape(-1),
            advantages.reshape(-1),
            returns.reshape(-1),
        )

        def epoch_fn(ep_carry, _):
            params, opt_state, key = ep_carry
            key, k_perm = jax.random.split(key)
            perm = jax.random.permutation(k_perm, total)
            shuffled = jax.tree_util.tree_map(lambda x: x[perm], data)
            batched = jax.tree_util.tree_map(
                lambda x: x[: n_minibatches * cfg.batch_size].reshape(
                    n_minibatches, cfg.batch_size, *x.shape[1:]),
                shuffled)

            def mb_fn(mb_carry, batch):
                params, opt_state = mb_carry
                (loss, aux), grads = jax.value_and_grad(
                    ppo_loss, has_aux=True)(params, batch, cfg, heads,
                                            cfg.telemetry)
                if grad_reduce is not None:
                    grads = grad_reduce(grads)
                updates, opt_state = optimizer.update(grads, opt_state, params)
                params = apply_updates(params, updates)
                return (params, opt_state), aux

            (params, opt_state), aux = jax.lax.scan(
                mb_fn, (params, opt_state), batched)
            return (params, opt_state, key), aux

        (params, opt_state, key), aux = jax.lax.scan(
            epoch_fn, (params, opt_state, key), None, length=cfg.n_epochs)
        aux_means = jax.tree_util.tree_map(jnp.mean, aux)
        if cfg.telemetry:
            pol_l, val_l, ent, approx_kl, clip_frac = aux_means
        else:
            pol_l, val_l, ent = aux_means

        mean_r = traj.rewards.mean()
        log = TrainLog(
            mean_step_reward=mean_r,
            mean_episodic_reward=mean_r * env_cfg.episode_len,
            best_reward=best_reward,
            policy_loss=pol_l, value_loss=val_l, entropy=ent)
        new_carry = TrainCarry(params=params, opt_state=opt_state,
                               env_states=env_states, obs=obs, key=key,
                               best_reward=best_reward,
                               best_action=best_action)
        if cfg.telemetry:
            stats = tl.PPOUpdateStats(
                return_mean=returns.mean(), return_std=returns.std(),
                entropy=ent, approx_kl=approx_kl, clip_frac=clip_frac)
            return new_carry, (log, stats)
        return new_carry, log

    return update


def train(key, env_cfg: chipenv.EnvConfig = chipenv.EnvConfig(),
          cfg: PPOConfig = PPOConfig(),
          total_timesteps: int = 250_000,
          scenario: chipenv.Scenario = None) -> TrainResult:
    """Train a PPO agent; returns final params + best design point found.

    The paper trains 250k timesteps in <20 min with SB3; the jitted scan
    version runs the same budget in seconds. jit/vmap-safe: ``scenario``
    is traced, so ``train_population`` vmaps this whole function.
    """
    scenario = env_cfg.scenario() if scenario is None else scenario
    k_init, k_env, k_train = jax.random.split(key, 3)
    params = nets.init_actor_critic(k_init, obs_dim=chipenv.obs_dim(env_cfg),
                                    head_sizes=chipenv.head_sizes(env_cfg))
    optimizer = Adam(learning_rate=cfg.learning_rate,
                     max_grad_norm=cfg.max_grad_norm)
    opt_state = optimizer.init(params)

    env_keys = jax.random.split(k_env, cfg.n_envs)
    env_states, obs = jax.vmap(
        lambda k: chipenv.reset(k, env_cfg, scenario))(env_keys)

    n_updates = max(total_timesteps // (cfg.n_steps * cfg.n_envs), 1)
    update = make_update_step(env_cfg, cfg, optimizer)

    carry = TrainCarry(
        params=params, opt_state=opt_state, env_states=env_states, obs=obs,
        key=k_train, best_reward=jnp.float32(-jnp.inf),
        best_action=jnp.zeros((chipenv.action_dim(env_cfg),), jnp.int32))

    carry, ys = jax.lax.scan(
        jax.jit(lambda c, x: update(c, x, scenario)),
        carry, None, length=n_updates)
    log, stats = ys if cfg.telemetry else (ys, None)
    # placement-episode actions carry no Table-1 assignment: the design
    # is drawn per episode, so best_design is a placeholder there and
    # best_action (the 4 placement heads) is the meaningful output.
    if chipenv.action_dim(env_cfg) >= ps.N_PARAMS:
        best_design = ps.from_flat(carry.best_action[: ps.N_PARAMS])
    else:
        best_design = ps.from_flat(jnp.zeros((ps.N_PARAMS,), jnp.int32))
    return TrainResult(params=carry.params, log=log,
                       best_design=best_design,
                       best_reward=carry.best_reward,
                       best_action=carry.best_action,
                       telemetry=stats)


def train_population(key, n_agents: int,
                     env_cfg: chipenv.EnvConfig = chipenv.EnvConfig(),
                     cfg: PPOConfig = PPOConfig(),
                     total_timesteps: int = 250_000,
                     scenario: chipenv.Scenario = None) -> TrainResult:
    """N PPO agents (different seeds) trained as ONE vmapped XLA program.

    Mirrors ``sa.run_population``: the Alg.-1 portfolio's RL arm stops
    being a sequential Python loop and becomes a single compiled program,
    amortizing compilation and batching every matmul across agents.

    Key derivation matches the sequential recipe exactly — agent ``i``
    trains with ``jax.random.split(key, n_agents)[i]`` — so results are
    seed-for-seed identical to ``n_agents`` separate ``train`` calls.
    Every TrainResult field gains a leading ``n_agents`` axis.
    """
    scenario = env_cfg.scenario() if scenario is None else scenario
    keys = jax.random.split(key, n_agents)
    fn = lambda k, s: train(k, env_cfg, cfg, total_timesteps, s)
    return jax.jit(jax.vmap(fn, in_axes=(0, None)))(keys, scenario)


def train_scenario_population(key, scenarios: chipenv.Scenario,
                              n_agents: int,
                              env_cfg: chipenv.EnvConfig = chipenv.EnvConfig(),
                              cfg: PPOConfig = PPOConfig(),
                              total_timesteps: int = 250_000) -> TrainResult:
    """S scenarios x N seeds of PPO in one program; results are (S, N, ...)."""
    n_scen = jnp.shape(scenarios.weights.alpha)[0]
    keys = jax.random.split(key, int(n_scen))
    return jax.jit(jax.vmap(
        lambda k, s: train_population(k, n_agents, env_cfg, cfg,
                                      total_timesteps, s)))(keys, scenarios)


def greedy_design(params: nets.ACParams, env_cfg=chipenv.EnvConfig(),
                  key=None, scenario: chipenv.Scenario = None) -> ps.DesignPoint:
    """Run the trained policy greedily from a reset obs (inference mode).

    Design-selecting configs only — placement episodes
    (``EnvConfig(placement_episode=True)``) have no design heads to
    decode, so this raises there.
    """
    if chipenv.action_dim(env_cfg) < ps.N_PARAMS:
        raise ValueError("greedy_design needs the Table-1 design heads; "
                         "placement-episode actions carry none")
    key = jax.random.PRNGKey(0) if key is None else key
    _, obs = chipenv.reset(key, env_cfg, scenario)
    logits, _ = nets.policy_value(params, obs)
    action = nets.greedy_action(logits, chipenv.head_sizes(env_cfg))
    return ps.from_flat(action[..., : ps.N_PARAMS])
