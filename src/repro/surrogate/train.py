"""Online surrogate training: one Adam lax.scan over the EvalDataset.

Reuses the repo's pure-JAX optimizer (training/optim.py, the same Adam
the PPO trainer runs on). Targets are standardized inside fit() —
``params['mu']``/``params['sd']`` carry the constants so predictions
denormalize and the scenario-conditioned head folds correctly
(model.fold_scenario).

The whole training run — minibatch sampling, forward/backward, Adam
update — is ONE ``lax.scan`` inside one jitted program; on the CI box
2000 steps x batch 2048 train in ~3s, amortized over ranking millions
of candidates (benchmarks/bench_optimizer.py --surrogate records the
measured overhead).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.surrogate import dataset as sds
from repro.surrogate import model as sm
from repro.training import optim


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 2000
    batch_size: int = 2048
    learning_rate: float = 3e-3
    hidden: int = sm.HIDDEN


@functools.partial(jax.jit, static_argnames=("cfg",))
def _fit(key, ds: sds.EvalDataset, cfg: TrainConfig):
    n = jnp.maximum(sds.size(ds), 1)
    # standardize targets over the valid rows only
    row = jnp.arange(ds.targets.shape[0])
    valid = (row < n)[:, None]
    nf = n.astype(jnp.float32)
    mu = jnp.sum(jnp.where(valid, ds.targets, 0.0), 0) / nf
    var = jnp.sum(jnp.where(valid, (ds.targets - mu) ** 2, 0.0), 0) / nf
    sd = jnp.sqrt(var) + 1e-6
    y = (ds.targets - mu) / sd
    feats = sm.featurize(ds.flats)

    k_init, k_run = jax.random.split(key)
    params = sm.init_params(k_init, hidden=cfg.hidden)
    opt = optim.Adam(learning_rate=cfg.learning_rate)
    opt_state = opt.init(params)

    def loss_fn(p, sel):
        z = sm.forward(p, feats[sel], ds.sfeats[sel])
        m = valid[sel].astype(jnp.float32)
        return jnp.sum(m * (z - y[sel]) ** 2) / jnp.maximum(jnp.sum(m), 1.0)

    def step(carry, _):
        p, s, k = carry
        k, kb = jax.random.split(k)
        # uniform over the valid prefix (n is traced; floor(u * n))
        sel = jnp.floor(jax.random.uniform(kb, (cfg.batch_size,))
                        * nf).astype(jnp.int32)
        loss, g = jax.value_and_grad(loss_fn)(p, sel)
        updates, s = opt.update(g, s, p)
        return (optim.apply_updates(p, updates), s, k), loss

    (params, _, _), losses = jax.lax.scan(
        step, (params, opt_state, k_run), None, length=cfg.steps)
    params = dict(params, mu=mu, sd=sd)
    return params, losses


def fit(key, ds: sds.EvalDataset,
        cfg: TrainConfig = TrainConfig()):
    """Train a fresh surrogate on the dataset -> (params, loss trace)."""
    return _fit(key, ds, cfg)
