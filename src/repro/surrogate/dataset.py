"""Ring-buffer EvalDataset + the costmodel eval tap that fills it.

The surrogate trains *online* from the evaluation streams the optimizer
arms already produce: every host-level (concrete, non-traced)
``costmodel.evaluate`` call can be tapped through
``costmodel.register_eval_tap`` and lands in a fixed-capacity ring
buffer of (design flat, scenario features, target vector) rows.
Evaluations inside jitted scan bodies (the SA/GA/PPO hot loops) are
traced and therefore invisible to the tap by construction — the arms'
*candidate* streams (their returned bests, the portfolio's archive
evaluation batch) are what flows through here, topped up by an explicit
bootstrap pool where the ranker needs more coverage
(surrogate/ranker.py).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import costmodel as cm
from repro.core import params as ps
from repro.surrogate import model as sm


class EvalDataset(NamedTuple):
    """Fixed-capacity ring buffer of surrogate training rows."""

    flats: jnp.ndarray      # (C, 14) int32 design indices
    sfeats: jnp.ndarray     # (C, S) f32 scenario features
    targets: jnp.ndarray    # (C, 6) f32 raw (un-standardized) targets
    count: jnp.ndarray      # () int32 — total rows ever written


def empty(capacity: int) -> EvalDataset:
    return EvalDataset(
        flats=jnp.zeros((capacity, ps.N_PARAMS), jnp.int32),
        sfeats=jnp.zeros((capacity, sm.N_SCEN_FEATURES), jnp.float32),
        targets=jnp.zeros((capacity, sm.N_TARGETS), jnp.float32),
        count=jnp.zeros((), jnp.int32))


def size(ds: EvalDataset) -> jnp.ndarray:
    """Number of valid rows (<= capacity)."""
    return jnp.minimum(ds.count, ds.flats.shape[0])


def targets_from_metrics(mtr: cm.Metrics) -> jnp.ndarray:
    """Metrics -> (..., 6) raw target rows (see model.TARGET_NAMES)."""
    return jnp.stack([
        jnp.asarray(mtr.reward_t, jnp.float32),
        jnp.asarray(mtr.reward_c, jnp.float32),
        jnp.asarray(mtr.reward_e, jnp.float32),
        jnp.log(jnp.maximum(jnp.asarray(mtr.tasks_per_sec, jnp.float32),
                            1e-30)),
        jnp.log(jnp.maximum(jnp.asarray(mtr.energy_per_task_j, jnp.float32),
                            1e-30)),
        jnp.log(jnp.maximum(jnp.asarray(mtr.total_cost, jnp.float32),
                            1e-30))], -1)


def add(ds: EvalDataset, flats: jnp.ndarray, targets: jnp.ndarray,
        sfeats: jnp.ndarray) -> EvalDataset:
    """Ring-write a batch of rows (newest rows win when over capacity)."""
    flats = jnp.asarray(flats, jnp.int32).reshape(-1, ps.N_PARAMS)
    targets = jnp.asarray(targets, jnp.float32).reshape(-1, sm.N_TARGETS)
    sfeats = jnp.broadcast_to(
        jnp.asarray(sfeats, jnp.float32),
        flats.shape[:1] + (sm.N_SCEN_FEATURES,))
    cap = ds.flats.shape[0]
    n = flats.shape[0]
    if n > cap:                              # only the tail can survive
        flats, targets, sfeats = flats[-cap:], targets[-cap:], sfeats[-cap:]
        ds = ds._replace(count=ds.count + (n - cap))
        n = cap
    idx = (ds.count + jnp.arange(n)) % cap
    return EvalDataset(
        flats=ds.flats.at[idx].set(flats),
        sfeats=ds.sfeats.at[idx].set(sfeats),
        targets=ds.targets.at[idx].set(targets),
        count=ds.count + n)


def add_metrics(ds: EvalDataset, dp: ps.DesignPoint, mtr: cm.Metrics,
                scenario: cm.Scenario) -> EvalDataset:
    """Record evaluate() results: (designs, Metrics, their scenario)."""
    return add(ds, ps.to_flat(dp), targets_from_metrics(mtr),
               sm.scenario_features(scenario))


class EvalTap:
    """A costmodel eval tap bound to one ring buffer.

    Usage::

        tap = EvalTap(capacity=8192)
        cm.register_eval_tap(tap)
        ... host-level cm.evaluate calls accumulate into tap.dataset ...
        cm.unregister_eval_tap(tap)

    The tap only ever sees concrete arrays (costmodel skips taps while
    tracing), so the ring update runs eagerly on host.
    """

    def __init__(self, capacity: int = 8192):
        self.dataset = empty(capacity)

    def __call__(self, dp: ps.DesignPoint, workload: cm.Workload,
                 weights: cm.RewardWeights, mtr: cm.Metrics) -> None:
        scen = cm.Scenario(workload=workload, weights=weights)
        sf = sm.scenario_features(scen)
        flats = ps.to_flat(dp)
        tgts = targets_from_metrics(mtr)
        # a scalar-scenario batched-design call broadcasts its one
        # scenario row over the whole design batch
        if np.ndim(sf) == 1 and np.ndim(flats) > 1:
            sf = jnp.broadcast_to(sf, flats.shape[:-1] + sf.shape)
        self.dataset = add(self.dataset, flats, tgts, sf)
