"""Learned cost-model surrogate (front-end ranker, ROADMAP item 1).

- ``model``   : featurization + MLP + scenario-conditioned head folding
- ``dataset`` : ring-buffer EvalDataset + the costmodel eval tap
- ``train``   : one-scan Adam training (training/optim.py machinery)
- ``ranker``  : surrogate_topk front filter with the exactness guard

The fused scoring kernel lives in ``repro.kernels.surrogate_score``
(Pallas) with its jnp twin dispatched by ``repro.kernels.ops``.
"""

from repro.surrogate import dataset, model, ranker, train  # noqa: F401
