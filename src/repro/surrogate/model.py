"""Learned cost-model surrogate: featurization + MLP-with-embeddings.

The front-end ranker (ROADMAP item 1): a small MLP over the 14 Table-1
design indices plus scenario features that predicts the analytic cost
model's reward terms and PPAC triple ~10x faster than the fast-tier
evaluator, so the optimizer arms can *rank* huge candidate pools and
spend analytic evaluations only on the top-k (the exactness guard —
final winners are always analytic-scored, see optimizer/ranker wiring).

Design notes (measured on the CI box, 64k-candidate pools):

- A literal 591-row embedding table with 14 per-head gathers is
  *slower* than the analytic fast tier on CPU XLA (gather-bound). The
  categorical heads are therefore embedded via **one-hot comparisons**
  whose first-layer weight rows are the learned embedding rows (same
  math, matmul-bound), ordinals enter as normalized linear features
  plus sqrt/reciprocal/product interactions, and the only gather left
  is the tiny 129-row mesh-dims table (cheap).
- Features are extracted in **integer arithmetic** on a transposed
  (14, N) view (shifts/ands/compares, one cast to f32 at the end) —
  the float-domain variant costs ~4x more and drops the ranker under
  the 10x-vs-fast-tier throughput target.
- Targets are the *weight-independent* reward terms (Eq. 17's r_t,
  r_c, r_e — ``Metrics.reward_t/c/e``) plus log tasks/s, log J/task
  and log cost, standardized. The scenario-conditioned head then folds
  any (alpha, beta, gamma) into a single (H,) readout vector at
  scoring time (:func:`fold_scenario`), so one trained model ranks
  under every reward weighting exactly in Eq.-17 structure.

The fused Pallas kernel twin lives in ``kernels/surrogate_score.py``
(same arithmetic on the 128-lane axis); ``kernels/ops.surrogate_score``
dispatches between them.
"""

from __future__ import annotations

import functools
from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import costmodel as cm
from repro.core import mapping as mpg
from repro.core import params as ps

N_FEATURES = 29
N_SCEN_FEATURES = 7
N_MAP_FEATURES = 5
HIDDEN = 32
N_TARGETS = 6
TARGET_NAMES = ("reward_t", "reward_c", "reward_e",
                "log_tasks_per_sec", "log_energy_per_task_j",
                "log_total_cost")

# (129, 2) float mesh-dims table: row p -> (m, n) for p footprint slots
_MESH = jnp.stack([jnp.asarray(cm._MESH_M, jnp.float32),
                   jnp.asarray(cm._MESH_N, jnp.float32)], -1)

# per-feature normalizers for the 24 integer features (see featurize_t)
_INT_SCALE = jnp.asarray(
    [1, 1, 1,                      # arch one-hot
     1, 1, 1, 1, 1, 1,             # hbm mask bits
     1.0 / 6.0,                    # n_hbm
     1, 1, 1,                      # binary interconnect heads (3, 7, 10)
     1.0 / 128.0, 1.0 / 20.0, 1.0 / 100.0, 1.0 / 10.0,   # c1, 4, 5, 6
     1.0 / 31.0, 1.0 / 100.0,                            # 8, 9
     1.0 / 20.0, 1.0 / 100.0, 1.0 / 10.0,                # 11, 12, 13
     1.0 / 2000.0, 1.0 / 2000.0],                        # bw products
    jnp.float32)


def featurize_t(flat_t: jnp.ndarray) -> jnp.ndarray:
    """(14, N) int32 transposed design flats -> (N_FEATURES, N) f32.

    Integer-domain until the final cast; all values < 2^24 so the f32
    arithmetic in the Pallas twin is bit-exact against this path.
    """
    flat_t = flat_t.astype(jnp.int32)
    arch = flat_t[0]
    c1 = flat_t[1]                         # n_chiplets index (n_dies - 1)
    mask = flat_t[2] + 1                   # hbm mask, 1..63
    is_lol = (arch == 2)
    n_pos = jnp.where(is_lol, (c1 + 2) >> 1, c1 + 1)
    mn = _MESH[jnp.clip(n_pos, 1, 128)]
    m, n = mn[..., 0], mn[..., 1]
    bits = [(mask >> b) & 1 for b in range(6)]
    ints = jnp.stack([
        (arch == 0).astype(jnp.int32), (arch == 1).astype(jnp.int32),
        is_lol.astype(jnp.int32), *bits, sum(bits),
        flat_t[3], flat_t[7], flat_t[10],
        c1, flat_t[4], flat_t[5], flat_t[6], flat_t[8], flat_t[9],
        flat_t[11], flat_t[12], flat_t[13],
        (flat_t[4] + 1) * (flat_t[5] + 1),
        (flat_t[11] + 1) * (flat_t[12] + 1),
    ], 0).astype(jnp.float32) * _INT_SCALE[:, None]
    cf = c1.astype(jnp.float32) + 1.0      # n_dies
    extra = jnp.stack([jnp.sqrt(cf) * (1.0 / 12.0), 1.0 / cf,
                       m * (1.0 / 16.0), n * (1.0 / 16.0),
                       (m + n) * (1.0 / 30.0)], 0)
    return jnp.concatenate([ints, extra], 0)


def featurize(flat: jnp.ndarray) -> jnp.ndarray:
    """(..., 14) int32 design flats -> (..., N_FEATURES) f32."""
    flat2 = flat.reshape(-1, ps.N_PARAMS)
    feats = featurize_t(flat2.T).T
    return feats.reshape(flat.shape[:-1] + (N_FEATURES,))


def mapping_features(mapping: mpg.Mapping, n_positions) -> jnp.ndarray:
    """Mapping -> (..., N_MAP_FEATURES) f32, centered at the canonical
    dataflow.

    Every feature is *exactly* 0.0 under ``mapping.canonical()`` (the
    traffic-summary no-op contract), so a canonical mapping contributes
    exactly nothing to the first layer regardless of the learned ``Wm``
    rows — the mapped scorer degrades bit-exactly to the unmapped one.
    """
    s = mpg.traffic_summary(mapping, n_positions)
    return jnp.stack([s.recv_frac, 1.0 - s.pull_frac, 1.0 - s.balance,
                      s.tile_hbm - 1.0, 1.0 - s.tile_u], -1)


def scenario_features(scenario: cm.Scenario) -> jnp.ndarray:
    """Scenario -> (..., N_SCEN_FEATURES) f32 conditioning vector.

    Traced scenarios (``scenario.trace`` set) carry (..., T) workload
    leaves; they are dt-weight-averaged over the trace axis first, so
    the surrogate conditions on the mean served workload.
    """
    w, wl = scenario.weights, scenario.workload
    if scenario.trace is not None:
        dt = jnp.asarray(scenario.trace.dt, jnp.float32)
        wl = jax.tree_util.tree_map(
            lambda x: jnp.sum(jnp.asarray(x, jnp.float32) * dt, axis=-1),
            wl)
    return jnp.stack([
        jnp.asarray(w.alpha, jnp.float32),
        jnp.asarray(w.beta, jnp.float32),
        jnp.asarray(w.gamma, jnp.float32),
        jnp.log1p(jnp.asarray(wl.gemm_ops, jnp.float32)) / 30.0,
        jnp.log1p(jnp.asarray(wl.nongemm_ops, jnp.float32)) / 30.0,
        jnp.log1p(jnp.asarray(wl.hbm_bytes, jnp.float32)) / 30.0,
        jnp.asarray(wl.mapping_eff, jnp.float32)], -1)


def init_params(key, hidden: int = HIDDEN) -> Dict[str, jnp.ndarray]:
    """He-initialized parameter pytree (+ identity target normalization).

    ``mu``/``sd`` are the target standardization constants the trainer
    fills in (surrogate/train.py); predictions denormalize through them.
    """
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s1 = (2.0 / N_FEATURES) ** 0.5
    s2 = (2.0 / hidden) ** 0.5
    return dict(
        W1=jax.random.normal(k1, (N_FEATURES, hidden)) * s1,
        Ws=jax.random.normal(k4, (N_SCEN_FEATURES, hidden)) * s1,
        # mapping-feature rows: zero-initialized, so an untrained (or
        # mapping-blind) model scores mapped candidates exactly like
        # their canonical-dataflow twins
        Wm=jnp.zeros((N_MAP_FEATURES, hidden)),
        b1=jnp.zeros((hidden,)),
        W2=jax.random.normal(k2, (hidden, hidden)) * s2,
        b2=jnp.zeros((hidden,)),
        W3=jax.random.normal(k3, (hidden, N_TARGETS)) * s2,
        b3=jnp.zeros((N_TARGETS,)),
        mu=jnp.zeros((N_TARGETS,)),
        sd=jnp.ones((N_TARGETS,)),
    )


def forward(params, feats: jnp.ndarray, sfeats: jnp.ndarray,
            mfeats: jnp.ndarray = None) -> jnp.ndarray:
    """(..., F) features + (..., S) scenario -> (..., 6) standardized.

    ``mfeats`` (optional, (..., N_MAP_FEATURES)) conditions on a
    mapping; omitted, the program is the pre-mapping one exactly.
    """
    h1 = feats @ params["W1"] + sfeats @ params["Ws"] + params["b1"]
    if mfeats is not None:
        h1 = h1 + mfeats @ params["Wm"]
    h1 = jax.nn.relu(h1)
    h2 = jax.nn.relu(h1 @ params["W2"] + params["b2"])
    return h2 @ params["W3"] + params["b3"]


def predict(params, flat: jnp.ndarray,
            scenario: cm.Scenario) -> jnp.ndarray:
    """(..., 14) designs -> (..., 6) denormalized target predictions."""
    z = forward(params, featurize(flat),
                jnp.broadcast_to(scenario_features(scenario),
                                 flat.shape[:-1] + (N_SCEN_FEATURES,)))
    return z * params["sd"] + params["mu"]


class FoldedParams(NamedTuple):
    """Scenario folded into the net: score(x) = w_s . h2(x) + bias_s.

    For a *fixed* scenario the conditioning term ``sfeats @ Ws`` is a
    constant first-layer bias, and the Eq.-17 combination
    ``alpha*r_t - beta*r_c - gamma*r_e`` of the three denormalized
    reward-term heads is one linear readout of h2 — so scoring costs
    exactly two (N, H) matmuls + one (N,) dot. These are the operands
    the fused Pallas kernel consumes.
    """

    W1: jnp.ndarray        # (F, H)
    b1_eff: jnp.ndarray    # (H,)  = b1 + sfeats @ Ws
    W2: jnp.ndarray        # (H, H)
    b2: jnp.ndarray        # (H,)
    w_s: jnp.ndarray       # (H,)  scenario-conditioned readout
    bias_s: jnp.ndarray    # ()    constant offset (rank-irrelevant)
    # mapping-feature first-layer rows (zero for mapping-blind models);
    # trailing+defaulted so pre-mapping FoldedParams pytrees still load
    Wm: jnp.ndarray = None  # (N_MAP_FEATURES, H)


def fold_scenario(params, scenario: cm.Scenario) -> FoldedParams:
    """Fold a fixed scenario's conditioning + Eq.-17 head combination."""
    sfeat = scenario_features(scenario)
    w = scenario.weights
    coeff = jnp.stack([jnp.asarray(w.alpha, jnp.float32),
                       -jnp.asarray(w.beta, jnp.float32),
                       -jnp.asarray(w.gamma, jnp.float32)])
    sd3, mu3, b33 = params["sd"][:3], params["mu"][:3], params["b3"][:3]
    return FoldedParams(
        W1=params["W1"],
        b1_eff=params["b1"] + sfeat @ params["Ws"],
        W2=params["W2"],
        b2=params["b2"],
        w_s=params["W3"][:, :3] @ (coeff * sd3),
        bias_s=jnp.sum(coeff * (mu3 + sd3 * b33)),
        Wm=params.get("Wm"),
    )


def score_folded(folded: FoldedParams, flat: jnp.ndarray,
                 mapping_feats: jnp.ndarray = None) -> jnp.ndarray:
    """(..., 14) designs -> (...,) predicted Eq.-17 reward (jnp path).

    ``mapping_feats`` (optional, (..., N_MAP_FEATURES) from
    :func:`mapping_features`) scores design+mapping candidates; omitted
    (or all-zero, the canonical dataflow) the score is the unmapped one.
    """
    flat2 = flat.reshape(-1, ps.N_PARAMS)
    feats = featurize_t(flat2.T).T                      # (N, F)
    h1 = feats @ folded.W1 + folded.b1_eff
    if mapping_feats is not None:
        h1 = h1 + mapping_feats.reshape(-1, N_MAP_FEATURES) @ folded.Wm
    h1 = jax.nn.relu(h1)
    h2 = jax.nn.relu(h1 @ folded.W2 + folded.b2)
    s = h2 @ folded.w_s + folded.bias_s
    return s.reshape(flat.shape[:-1])


def score(params, flat: jnp.ndarray, scenario: cm.Scenario) -> jnp.ndarray:
    """Predicted reward under ``scenario`` (folds, then scores)."""
    return score_folded(fold_scenario(params, scenario), flat)


@functools.partial(jax.jit, static_argnames=("k",))
def rank_topk_jnp(folded: FoldedParams, flat: jnp.ndarray,
                  k: int):
    """Surrogate-score a (N, 14) pool, return (top-k scores, indices)."""
    return jax.lax.top_k(score_folded(folded, flat), k)
