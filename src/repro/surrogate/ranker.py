"""Surrogate front-filter with an exactness guard (surrogate_topk).

The ranking recipe the portfolio / scenario-suite arms wire in:

1. spend a bounded *bootstrap* budget of analytic evaluations on a
   random design pool (plus whatever rows the costmodel eval tap
   already collected from the arms' candidate streams),
2. train the surrogate on that stream (surrogate/train.py, one scan),
3. surrogate-rank a pool ~10-100x larger than the analytic budget
   could ever see,
4. re-score ONLY the top-k analytically and hand those winners to the
   caller (argmax / refinement / archive).

The exactness guard: every reward the caller consumes came from the
analytic cost model — the surrogate only decides *which* candidates
get an analytic evaluation, so a bad surrogate can waste budget but
never mint a wrong winner, and the PR-5 superset contracts
(three-arm >= two-arm etc.) are untouched because the stage only adds
candidates under its own folded key stream.

``mode='random'`` is the equal-budget control: the same number of
analytic evaluations spent on uniform candidates instead of
surrogate-ranked ones (the bench/CI comparison baseline).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel as cm
from repro.core import params as ps
from repro.surrogate import dataset as sds
from repro.surrogate import model as sm
from repro.surrogate import train as strain
from repro.telemetry import journal as tj

_HEADS = jnp.asarray(ps.HEAD_SIZES, jnp.int32)


@dataclasses.dataclass(frozen=True)
class SurrogateConfig:
    """One surrogate_topk stage: budget split + ranking scale."""

    pool_size: int = 65536        # surrogate-ranked candidates / scenario
    top_k: int = 256              # analytically re-scored winners
    bootstrap: int = 4096         # analytic evals seeding the dataset
    capacity: int = 32768         # EvalDataset ring size
    train: strain.TrainConfig = strain.TrainConfig()
    backend: str = "auto"         # scoring backend (kernels/ops.py)
    mode: str = "surrogate"       # 'surrogate' | 'random' budget control


def analytic_budget(cfg: SurrogateConfig) -> int:
    """Analytic evaluations the stage spends per scenario."""
    return cfg.bootstrap + cfg.top_k


class StageResult(NamedTuple):
    cand_flats: jnp.ndarray       # (S, K, 14) candidates for the caller
    cand_rewards: jnp.ndarray     # (S, K) — ALL analytically scored
    params: Optional[dict]        # trained surrogate (None in random mode)
    dataset: Optional[sds.EvalDataset]


def random_flats(key, n: int) -> jnp.ndarray:
    """(n, 14) uniform design indices."""
    return jax.random.randint(key, (n, ps.N_PARAMS), 0, _HEADS,
                              dtype=jnp.int32)


_fold_scenario = jax.jit(sm.fold_scenario)

# score and top_k as two jitted dispatches: XLA CPU fuses the combined
# program worse than the parts (measured ~14% slower fused)
_top_k = functools.partial(jax.jit, static_argnames=("k",))(
    lambda scores, k: jax.lax.top_k(scores, k))


def rank_pool(params, pool: jnp.ndarray, scenario: cm.Scenario, k: int,
              backend: str = "auto"):
    """Surrogate-rank a (N, 14) pool -> (top-k indices, scores)."""
    from repro.kernels import ops
    folded = _fold_scenario(params, scenario)
    scores = ops.surrogate_score(pool, folded, backend=backend)
    top_scores, top_idx = _top_k(scores, k)
    return top_idx, top_scores


def surrogate_topk(key, params, scenario: cm.Scenario,
                   cfg: SurrogateConfig,
                   hw_cfg, nop_fidelity: str = "auto"):
    """Rank a fresh random pool, analytically re-score the top-k.

    Returns ((k, 14) flats, (k,) analytic rewards, (k,) surrogate
    scores). The analytic re-score is the exactness guard.
    """
    pool = random_flats(key, cfg.pool_size)
    top_idx, top_scores = rank_pool(params, pool, scenario, cfg.top_k,
                                    cfg.backend)
    top = pool[top_idx]
    rewards = jax.vmap(lambda f: cm.scenario_reward(
        ps.from_flat(f), scenario, hw_cfg,
        nop_fidelity=nop_fidelity))(top)
    return top, rewards, top_scores


def bootstrap_dataset(key, scenarios: cm.Scenario, n: int, hw_cfg,
                      nop_fidelity: str = "auto",
                      capacity: int = 32768,
                      seed_rows: Optional[sds.EvalDataset] = None):
    """Analytically evaluate a shared random pool under every scenario.

    Returns (dataset, (n, 14) pool flats, (S, n) analytic rewards).
    ``seed_rows`` (e.g. a costmodel EvalTap's ring) is folded in first,
    so the arms' tapped eval streams participate in training.
    """
    flats = random_flats(key, n)
    mtr = cm.evaluate_scenarios(ps.from_flat(flats), scenarios, hw_cfg,
                                paired=False, nop_fidelity=nop_fidelity)
    tgts = sds.targets_from_metrics(mtr)                 # (S, n, 6)
    sfeats = sm.scenario_features(scenarios)             # (S, S_FEAT)
    ds = sds.empty(capacity)
    if seed_rows is not None:
        m = int(sds.size(seed_rows))
        if m:
            ds = sds.add(ds, seed_rows.flats[:m], seed_rows.targets[:m],
                         seed_rows.sfeats[:m])
    n_scen = tgts.shape[0]
    for s in range(n_scen):
        ds = sds.add(ds, flats, tgts[s], sfeats[s])
    return ds, flats, mtr.reward


def _spearman(a: np.ndarray, b: np.ndarray) -> float:
    """Spearman rank correlation of two equal-length score vectors."""
    ra = np.argsort(np.argsort(a)).astype(np.float64)
    rb = np.argsort(np.argsort(b)).astype(np.float64)
    ra -= ra.mean()
    rb -= rb.mean()
    denom = float(np.sqrt((ra ** 2).sum() * (rb ** 2).sum()))
    return float((ra * rb).sum() / denom) if denom else 0.0


def _probe_scores(params, probe: jnp.ndarray, scenario, backend) -> np.ndarray:
    """Surrogate scores of a fixed probe pool under one scenario (used
    only for the journal's rank-drift metric; touches no key stream)."""
    from repro.kernels import ops
    folded = _fold_scenario(params, scenario)
    return np.asarray(ops.surrogate_score(probe, folded, backend=backend))


def _rank_and_rescore(params, pool, scenarios, cfg: SurrogateConfig,
                      hw_cfg, nop_fidelity):
    """Surrogate-rank ``pool`` per scenario, analytically re-score the
    winners. Returns ((S, k, 14) flats, Metrics with (S, k) leaves)."""
    n_scen = int(jnp.shape(scenarios.weights.alpha)[0])
    scen_list = [jax.tree_util.tree_map(lambda x, i=i: x[i], scenarios)
                 for i in range(n_scen)]
    tops = [rank_pool(params, pool, sc, cfg.top_k, cfg.backend)[0]
            for sc in scen_list]
    sel_flats = jnp.stack([pool[idx] for idx in tops])      # (S, k, 14)
    mtr = cm.evaluate_scenarios(ps.from_flat(sel_flats), scenarios,
                                hw_cfg, paired=True,
                                nop_fidelity=nop_fidelity)
    return sel_flats, mtr


def run_stage(key, scenarios: cm.Scenario, cfg: SurrogateConfig, hw_cfg,
              nop_fidelity: str = "auto",
              tap_dataset: Optional[sds.EvalDataset] = None,
              refit_every: int = 0) -> StageResult:
    """The full surrogate_topk stage over a batched Scenario.

    Spends exactly ``analytic_budget(cfg)`` analytic evaluations per
    scenario in BOTH modes (the bootstrap pool is shared and drawn from
    the same key stream, so mode='random' is a true equal-budget,
    equal-stream control). Returned candidates: the per-scenario
    bootstrap argmax + either the surrogate-ranked top-k (analytically
    re-scored) or ``top_k`` more uniform analytic evals.

    ``refit_every`` (surrogate mode only; 0 = off, bit-exact with the
    single-fit path) walks the scenario grid in chunks of that many
    scenarios, re-fitting before each chunk on the *growing* eval
    stream: the tapped seed rows + bootstrap, plus every earlier chunk's
    analytic re-scores folded back into the dataset — exactly the rows a
    costmodel eval tap sees from this stage, so long suites keep
    training the ranker on their own eval traffic instead of freezing it
    after the bootstrap. Chunk fit/pool keys are folds of the stage keys,
    leaving the ``refit_every=0`` stream untouched.
    """
    n_scen = int(jnp.shape(scenarios.weights.alpha)[0])
    k_boot = jax.random.fold_in(key, 0)
    k_sel = jax.random.fold_in(key, 1)
    k_train = jax.random.fold_in(key, 2)
    jr = tj.current_or_null()

    ds, boot_flats, boot_rewards = bootstrap_dataset(
        k_boot, scenarios, cfg.bootstrap, hw_cfg, nop_fidelity,
        capacity=cfg.capacity, seed_rows=tap_dataset)
    jr.event("surrogate_bootstrap", n=cfg.bootstrap,
             tap_rows=0 if tap_dataset is None else int(
                 sds.size(tap_dataset)),
             dataset_rows=int(sds.size(ds)))

    if cfg.mode == "random":
        extra = random_flats(k_sel, cfg.top_k)
        mtr = cm.evaluate_scenarios(ps.from_flat(extra), scenarios, hw_cfg,
                                    paired=False, nop_fidelity=nop_fidelity)
        sel_flats = jnp.broadcast_to(
            extra, (n_scen, cfg.top_k, ps.N_PARAMS))
        sel_rewards = mtr.reward
        params = None
    elif refit_every <= 0:
        params, _ = strain.fit(k_train, ds, cfg.train)
        jr.event("surrogate_fit", chunk=0, dataset_rows=int(sds.size(ds)))
        pool = random_flats(k_sel, cfg.pool_size)
        sel_flats, mtr = _rank_and_rescore(params, pool, scenarios, cfg,
                                           hw_cfg, nop_fidelity)
        sel_rewards = mtr.reward                            # (S, k)
    else:
        sfeats = sm.scenario_features(scenarios)            # (S, S_FEAT)
        flats_parts, reward_parts = [], []
        params = None
        # rank-drift probe: a fixed, already-evaluated pool scored under
        # the first scenario after every re-fit; Spearman between
        # consecutive fits' scores shows how much the ranking moved as
        # the suite's own eval traffic folds in. Journal-only (skipped
        # when no journal is ambient) and key-stream-free.
        drift = isinstance(jr, tj.Journal)
        probe = boot_flats[: min(512, boot_flats.shape[0])]
        scen0 = jax.tree_util.tree_map(lambda x: x[0], scenarios)
        prev_scores = None
        for c0 in range(0, n_scen, refit_every):
            chunk = jax.tree_util.tree_map(
                lambda x: x[c0:c0 + refit_every], scenarios)
            params, _ = strain.fit(jax.random.fold_in(k_train, c0), ds,
                                   cfg.train)
            jr.event("surrogate_fit", chunk=c0,
                     dataset_rows=int(sds.size(ds)))
            if drift:
                scores = _probe_scores(params, probe, scen0, cfg.backend)
                if prev_scores is not None:
                    jr.event("surrogate_rank_drift", chunk=c0,
                             spearman=_spearman(prev_scores, scores))
                prev_scores = scores
            pool = random_flats(jax.random.fold_in(k_sel, c0),
                                cfg.pool_size)
            cf, cmtr = _rank_and_rescore(params, pool, chunk, cfg,
                                         hw_cfg, nop_fidelity)
            flats_parts.append(cf)
            reward_parts.append(cmtr.reward)
            # fold this chunk's analytic eval stream back in for the
            # next chunk's fit (the eval-tap rows of this stage)
            tgts = sds.targets_from_metrics(cmtr)           # (nc, k, 6)
            for s in range(cf.shape[0]):
                ds = sds.add(ds, cf[s], tgts[s], sfeats[c0 + s])
        sel_flats = jnp.concatenate(flats_parts, axis=0)    # (S, k, 14)
        sel_rewards = jnp.concatenate(reward_parts, axis=0)

    # the bootstrap pool's per-scenario argmax rides along in both modes
    # (those analytic evals are already paid for)
    boot_best = jnp.argmax(boot_rewards, axis=1)            # (S,)
    best_flat = boot_flats[boot_best][:, None, :]           # (S, 1, 14)
    best_r = jnp.take_along_axis(boot_rewards, boot_best[:, None], 1)
    return StageResult(
        cand_flats=jnp.concatenate([sel_flats, best_flat], axis=1),
        cand_rewards=jnp.concatenate([sel_rewards, best_r], axis=1),
        params=params, dataset=ds)
