"""Mixture-of-Experts FFN with GShard-style capacity dispatch (EP-ready).

Token-choice top-k routing with per-group capacity: tokens are grouped by
batch row (G = batch, S = seq), each expert accepts at most
C = ceil(S * k / E * capacity_factor) tokens per group; overflow drops
(standard Switch/GShard semantics). The dispatch/combine einsums are the
all-to-all points — with experts sharded over the "model" mesh axis
(parallel/sharding: ``experts -> model``), GSPMD emits the EP all-to-alls
automatically.

Shapes (bf16 dispatch masks keep the transient footprint at
G x S x E x C / device-shards — the dominant MoE memory term, see
EXPERIMENTS.md §Perf for the capacity-factor hillclimb):

    x        (G, S, d)
    gates    (G, S, E)
    dispatch (G, S, E, C)   one-hot   combine (G, S, E, C) weighted
    expert_in  (E, G, C, d) -> FFN -> expert_out (E, G, C, d)

The router aux loss (load-balance) is returned for the trainer.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.parallel.sharding import lshard

CAPACITY_FACTOR = 1.25


def init_moe(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": L.truncnorm_init(ks[0], (d, e), jnp.float32),
        "wi": L.truncnorm_init(ks[1], (e, d, ff), dtype),
        "wg": L.truncnorm_init(ks[2], (e, d, ff), dtype),
        "wo": L.truncnorm_init(ks[3], (e, ff, d), dtype),
    }
    if cfg.n_shared_experts > 0:
        p["shared"] = L.init_mlp(ks[4], d,
                                 cfg.n_shared_experts * cfg.moe_d_ff,
                                 act=cfg.act, dtype=dtype)
    return p


def _capacity(cfg: ArchConfig, s: int) -> int:
    from repro.models.tuning import TUNING
    c = int(s * cfg.n_experts_per_tok * TUNING.moe_capacity_factor
            / max(cfg.n_experts, 1))
    return max(c, 1)


def moe_forward(p, cfg: ArchConfig, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (G, S, d) -> (out (G, S, d), aux_loss scalar)."""
    from repro.models.tuning import TUNING
    if TUNING.moe_scatter_dispatch:
        return moe_forward_scatter(p, cfg, x)
    g, s, d = x.shape
    e, k = cfg.n_experts, cfg.n_experts_per_tok
    c = _capacity(cfg, s)

    logits = (x.astype(jnp.float32) @ p["router"])        # (G, S, E)
    probs = jax.nn.softmax(logits, axis=-1)

    # top-k selection; renormalized combine weights
    top_w, top_idx = jax.lax.top_k(probs, k)              # (G, S, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum(frac_tokens * frac_probs)
    onehot_all = jax.nn.one_hot(top_idx, e, dtype=jnp.float32)  # (G,S,k,E)
    token_frac = onehot_all.sum(2).mean(axis=(0, 1))      # (E,)
    prob_frac = probs.mean(axis=(0, 1))
    aux = e * jnp.sum(token_frac * prob_frac)

    # capacity assignment: position of each (token, slot) in its expert
    # queue, computed over the flattened (S*k) routing decisions per group
    flat_idx = top_idx.reshape(g, s * k)                  # (G, S*k)
    onehot = jax.nn.one_hot(flat_idx, e, dtype=jnp.float32)
    pos_in_expert = (jnp.cumsum(onehot, axis=1) - 1.0) * onehot  # (G, S*k, E)
    within = (pos_in_expert < c) & (onehot > 0)
    slot = jnp.sum(pos_in_expert * within, axis=-1)       # (G, S*k)
    kept = jnp.any(within, axis=-1)                       # (G, S*k)

    slot_onehot = jax.nn.one_hot(slot, c, dtype=jnp.float32) \
        * kept[..., None]                                 # (G, S*k, C)
    # dispatch (G, S*k, E, C)
    dispatch = onehot[..., :, None] * slot_onehot[..., None, :]
    weights = top_w.reshape(g, s * k)
    combine = dispatch * weights[..., None, None]

    # fold the k slots back onto tokens: (G, S, k, E, C) -> sum over k
    dispatch_t = dispatch.reshape(g, s, k, e, c).sum(2)
    combine_t = combine.reshape(g, s, k, e, c).sum(2)

    dispatch_t = lshard(dispatch_t.astype(x.dtype),
                        "batch", None, "experts", None)
    combine_t = lshard(combine_t.astype(jnp.float32),
                       "batch", None, "experts", None)

    # all-to-all 1: tokens -> experts
    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch_t, x)
    expert_in = lshard(expert_in, "experts", "batch", None, None)

    # expert FFN (einsum over the expert axis stays local under EP)
    h = (jax.nn.silu(jnp.einsum("egcd,edf->egcf", expert_in, p["wg"]))
         * jnp.einsum("egcd,edf->egcf", expert_in, p["wi"]))
    expert_out = jnp.einsum("egcf,efd->egcd", h, p["wo"])
    expert_out = lshard(expert_out, "experts", "batch", None, None)

    # all-to-all 2: experts -> tokens
    out = jnp.einsum("gsec,egcd->gsd",
                     combine_t, expert_out.astype(jnp.float32))
    out = out.astype(x.dtype)

    if cfg.n_shared_experts > 0:
        out = out + L.apply_mlp(p["shared"], x, act=cfg.act)
    return out, aux


def moe_forward_scatter(p, cfg: ArchConfig, x
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scatter/gather MoE dispatch (beyond-paper §Perf lever).

    Same capacity semantics as the dense GShard path (top-k, per-group
    capacity, overflow drops) but token movement is index-based:

        expert_in[e, c] = x[token assigned to slot (e, c)]   (gather)
        y[token]       += w * expert_out[e, c]               (gather+add)

    Replaces the O(S*E*C*d) one-hot dispatch/combine einsums with O(S*k*d)
    data movement — on qwen3-moe the dense path burns 3.3x MODEL_FLOPS on
    dispatch alone. Numerics match the dense path exactly
    (tests/test_tuning.py::test_moe_scatter_matches_dense).
    """
    g, s, d = x.shape
    e, k = cfg.n_experts, cfg.n_experts_per_tok
    c = _capacity(cfg, s)

    logits = (x.astype(jnp.float32) @ p["router"])        # (G, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, k)              # (G, S, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    onehot_all = jax.nn.one_hot(top_idx, e, dtype=jnp.float32)
    token_frac = onehot_all.sum(2).mean(axis=(0, 1))
    prob_frac = probs.mean(axis=(0, 1))
    aux = e * jnp.sum(token_frac * prob_frac)

    # slot assignment identical to the dense path (cumsum over S*k)
    flat_idx = top_idx.reshape(g, s * k)                  # (G, N) N=S*k
    onehot = jax.nn.one_hot(flat_idx, e, dtype=jnp.float32)
    pos_in_expert = (jnp.cumsum(onehot, axis=1) - 1.0) * onehot
    within = (pos_in_expert < c) & (onehot > 0)
    slot = jnp.sum(pos_in_expert * within, axis=-1).astype(jnp.int32)
    kept = jnp.any(within, axis=-1)                       # (G, N)
    weights = top_w.reshape(g, s * k) * kept              # (G, N)

    # scatter tokens into the (E*C) expert buffer per group
    tok_of_route = jnp.repeat(
        jnp.arange(s)[None, :, None], k, axis=2).reshape(1, s * k)
    tok_of_route = jnp.broadcast_to(tok_of_route, (g, s * k))
    dest = flat_idx * c + slot                            # (G, N) in [0,E*C)
    dest = jnp.where(kept, dest, e * c)                   # drop bucket

    def per_group(xg, destg, tokg, wg):
        buf = jnp.zeros((e * c + 1, d), xg.dtype)
        buf = buf.at[destg].add(xg[tokg] * wg[:, None].astype(xg.dtype))
        return buf[: e * c].reshape(e, c, d)

    # weight applied at dispatch (equivalent to dense path's combine
    # weighting since each slot receives exactly one token)
    expert_in = jax.vmap(per_group)(x, dest, tok_of_route,
                                    jnp.ones_like(weights))
    expert_in = lshard(expert_in, "batch", "experts", None, None)

    h = (jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, p["wg"]))
         * jnp.einsum("gecd,edf->gecf", expert_in, p["wi"]))
    expert_out = jnp.einsum("gecf,efd->gecd", h, p["wo"])
    expert_out = lshard(expert_out, "batch", "experts", None, None)

    # gather back: y[token] += w * expert_out[dest]
    def per_group_back(outg, destg, tokg, wg):
        flat = jnp.concatenate(
            [outg.reshape(e * c, d), jnp.zeros((1, d), outg.dtype)])
        vals = flat[destg] * wg[:, None].astype(outg.dtype)   # (N, d)
        y = jnp.zeros((s, d), outg.dtype)
        return y.at[tokg].add(vals)

    out = jax.vmap(per_group_back)(expert_out, dest, tok_of_route,
                                   weights)
    if cfg.n_shared_experts > 0:
        out = out + L.apply_mlp(p["shared"], x, act=cfg.act)
    return out, aux
