"""Attention mixers: GQA (+ sliding window) and MLA (DeepSeek-V2).

Three execution paths share one set of parameters:
  - train/prefill: q-chunked exact softmax attention (`chunked_attention`)
    — memory-bounded for 32k prefill; on TPU the Pallas flash kernel is
    dispatched instead (kernels/ops.attention),
  - decode: single-token attention against a (possibly sequence-sharded)
    KV cache updated in place with dynamic_update_slice,
  - MLA keeps the compressed (kv_lora + rope) cache and re-expands K/V —
    the paper-faithful trade of FLOPs for cache bytes.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops as kops
from repro.models import layers as L
from repro.parallel.sharding import lshard

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# exact q-chunked attention (jnp path; flash kernel on TPU)
# ---------------------------------------------------------------------------

def chunked_attention(q, k, v, causal: bool = True, window: int = 0,
                      q_chunk: int = 512,
                      q_offset: int = 0) -> jnp.ndarray:
    """q: (B,H,L,D), k/v: (B,KV,S,D) -> (B,H,L,D); softmax in fp32.

    ``q_offset`` positions q tokens at [q_offset, q_offset+L) within the
    kv sequence (prefill continuation / decode batching).
    """
    b, h, q_len, d = q.shape
    _, hkv, kv_len, _ = k.shape
    group = h // hkv
    scale = 1.0 / (d ** 0.5)
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    k_pos = jnp.arange(kv_len)

    chunk = min(q_chunk, q_len)
    while q_len % chunk:               # largest divisor of q_len <= q_chunk
        chunk -= 1
    n_chunks = max(q_len // chunk, 1)
    qs = q.reshape(b, h, n_chunks, chunk, d)

    def one_chunk(ci):
        qc = qs[:, :, ci].astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", qc,
                       kr.astype(jnp.float32)) * scale
        q_pos = q_offset + ci * chunk + jnp.arange(chunk)
        rel = q_pos[:, None] - k_pos[None, :]
        if causal:
            s = jnp.where(rel >= 0, s, _NEG_INF)
        if window > 0:
            s = jnp.where(rel < window, s, _NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))

    from repro.models.tuning import TUNING
    if TUNING.attn_chunk_remat:
        # backward recomputes each chunk's scores (flash-style residuals)
        one_chunk = jax.checkpoint(one_chunk, prevent_cse=False)

    out = jax.lax.map(one_chunk, jnp.arange(n_chunks))     # (C, B, H, ch, D)
    out = jnp.moveaxis(out, 0, 2).reshape(b, h, q_len, d)
    return out.astype(q.dtype)


def _full_attention(q, k, v, causal, window, q_offset=0):
    """Dispatch: Pallas flash kernel on TPU, chunked jnp elsewhere."""
    if jax.default_backend() == "tpu" and q_offset == 0:
        return kops.attention(q, k, v, causal=causal, window=window)
    return chunked_attention(q, k, v, causal=causal, window=window,
                             q_offset=q_offset)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_gqa(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": L.init_dense(ks[0], d, h * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": L.init_dense(ks[1], d, kv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": L.init_dense(ks[2], d, kv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": L.init_dense(ks[3], h * hd, d, dtype=dtype),
    }


def _qkv(p, cfg: ArchConfig, x, positions):
    b, l, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = L.dense(p["wq"], x).reshape(b, l, h, hd).transpose(0, 2, 1, 3)
    k = L.dense(p["wk"], x).reshape(b, l, kv, hd).transpose(0, 2, 1, 3)
    v = L.dense(p["wv"], x).reshape(b, l, kv, hd).transpose(0, 2, 1, 3)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    q = lshard(q, "batch", "heads", None, None)
    return q, k, v


def gqa_forward(p, cfg: ArchConfig, x, window: int = 0,
                causal: bool = True) -> jnp.ndarray:
    """Training / self-contained prefill (positions 0..L-1)."""
    b, l, _ = x.shape
    positions = jnp.arange(l)[None, :]
    q, k, v = _qkv(p, cfg, x, positions)
    out = _full_attention(q, k, v, causal=causal, window=window)
    out = out.transpose(0, 2, 1, 3).reshape(b, l, -1)
    return L.dense(p["wo"], out)


def init_gqa_cache(cfg: ArchConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> dict:
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    shape = (batch, kv, max_len, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def gqa_prefill(p, cfg: ArchConfig, x, cache: dict, window: int = 0):
    """Fill cache[0:L]; returns (out, cache)."""
    b, l, _ = x.shape
    positions = jnp.arange(l)[None, :]
    q, k, v = _qkv(p, cfg, x, positions)
    cache = {
        "k": jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0)),
    }
    out = _full_attention(q, k, v, causal=True, window=window)
    out = out.transpose(0, 2, 1, 3).reshape(b, l, -1)
    return L.dense(p["wo"], out), cache


def gqa_decode(p, cfg: ArchConfig, x, cache: dict, pos,
               window: int = 0):
    """One-token decode. x: (B, 1, d); pos: scalar int position."""
    b = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    positions = jnp.full((b, 1), pos, jnp.int32)
    q = L.dense(p["wq"], x).reshape(b, 1, h, hd).transpose(0, 2, 1, 3)
    k = L.dense(p["wk"], x).reshape(b, 1, kv, hd).transpose(0, 2, 1, 3)
    v = L.dense(p["wv"], x).reshape(b, 1, kv, hd).transpose(0, 2, 1, 3)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)

    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, pos, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, pos, 0))
    cache = {"k": ck, "v": cv}

    from repro.models.tuning import TUNING
    k_pos = jnp.arange(ck.shape[2])
    valid = k_pos <= pos
    if window > 0:
        valid &= (pos - k_pos) < window

    if TUNING.gqa_grouped_einsum:
        # grouped attention: no materialized K/V repeat across query heads
        group = h // kv
        if TUNING.decode_bf16_einsum:
            # MXU-native: bf16 operands, fp32 accumulation — no f32 copy
            # of the cache is ever materialized
            qg = q.reshape(b, kv, group, hd)
            s = jnp.einsum("bkgd,bksd->bkgs", qg, ck,
                           preferred_element_type=jnp.float32) / (hd ** 0.5)
            s = jnp.where(valid[None, None, None, :], s, _NEG_INF)
            prob = jax.nn.softmax(s, axis=-1)
            out = jnp.einsum("bkgs,bksd->bkgd", prob.astype(ck.dtype), cv,
                             preferred_element_type=jnp.float32)
        else:
            qg = q.reshape(b, kv, group, hd).astype(jnp.float32)
            s = jnp.einsum("bkgd,bksd->bkgs", qg,
                           ck.astype(jnp.float32)) / (hd ** 0.5)
            s = jnp.where(valid[None, None, None, :], s, _NEG_INF)
            prob = jax.nn.softmax(s, axis=-1)
            out = jnp.einsum("bkgs,bksd->bkgd", prob,
                             cv.astype(jnp.float32))
        out = out.reshape(b, h, 1, hd)
    else:
        kr = jnp.repeat(ck, h // kv, axis=1)
        vr = jnp.repeat(cv, h // kv, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                       kr.astype(jnp.float32)) / (hd ** 0.5)
        s = jnp.where(valid[None, None, None, :], s, _NEG_INF)
        prob = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhqk,bhkd->bhqd", prob, vr.astype(jnp.float32))
    out = out.astype(x.dtype).transpose(0, 2, 1, 3).reshape(b, 1, -1)
    return L.dense(p["wo"], out), cache


# ---------------------------------------------------------------------------
# sliding-window ring-buffer cache: the KV cache holds only `window` slots;
# token t lives in slot t % window. This is what bounds decode memory for
# the SWA archs (h2o-danube, hymba) — incl. the long_500k shape.
# ---------------------------------------------------------------------------

def init_gqa_ring_cache(cfg: ArchConfig, batch: int, window: int,
                        dtype=jnp.bfloat16) -> dict:
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    shape = (batch, kv, window, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def gqa_prefill_ring(p, cfg: ArchConfig, x, cache: dict, window: int):
    """Windowed prefill; stores the last `window` tokens into the ring."""
    b, l, _ = x.shape
    positions = jnp.arange(l)[None, :]
    q, k, v = _qkv(p, cfg, x, positions)
    out = _full_attention(q, k, v, causal=True, window=window)

    w = cache["k"].shape[2]
    if l >= w:
        tail_k, tail_v = k[:, :, l - w:], v[:, :, l - w:]
        # token t -> slot t % w; roll so slot order matches
        shift = (l - w) % w
        ck = jnp.roll(tail_k, shift=shift, axis=2)
        cv = jnp.roll(tail_v, shift=shift, axis=2)
        cache = {"k": ck.astype(cache["k"].dtype),
                 "v": cv.astype(cache["v"].dtype)}
    else:
        cache = {
            "k": jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0)),
        }
    out = out.transpose(0, 2, 1, 3).reshape(b, l, -1)
    return L.dense(p["wo"], out), cache


def gqa_decode_ring(p, cfg: ArchConfig, x, cache: dict, pos, window: int):
    """One-token decode against a ring cache of `window` slots."""
    b = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    w = cache["k"].shape[2]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q = L.dense(p["wq"], x).reshape(b, 1, h, hd).transpose(0, 2, 1, 3)
    k = L.dense(p["wk"], x).reshape(b, 1, kv, hd).transpose(0, 2, 1, 3)
    v = L.dense(p["wv"], x).reshape(b, 1, kv, hd).transpose(0, 2, 1, 3)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)

    slot = jnp.mod(pos, w)
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, slot, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, slot, 0))
    cache = {"k": ck, "v": cv}

    kr = jnp.repeat(ck, h // kv, axis=1)
    vr = jnp.repeat(cv, h // kv, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / (hd ** 0.5)
    # absolute position held by slot s: latest t <= pos with t % w == s
    slots = jnp.arange(w)
    t_slot = pos - jnp.mod(pos - slots, w)
    valid = (t_slot >= 0) & (pos - t_slot < window)
    s = jnp.where(valid[None, None, None, :], s, _NEG_INF)
    prob = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", prob, vr.astype(jnp.float32))
    out = out.astype(x.dtype).transpose(0, 2, 1, 3).reshape(b, 1, -1)
    return L.dense(p["wo"], out), cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): compressed KV cache (kv_lora + shared rope key)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    hd, rhd, vhd = cfg.head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    ks = jax.random.split(key, 4)
    return {
        "wq": L.init_dense(ks[0], d, h * (hd + rhd), dtype=dtype),
        "wdkv": L.init_dense(ks[1], d, kvr + rhd, dtype=dtype),
        "wukv": L.init_dense(ks[2], kvr, h * (hd + vhd), dtype=dtype),
        "wo": L.init_dense(ks[3], h * vhd, d, dtype=dtype),
        "kv_norm": L.init_norm(None, kvr, "rmsnorm"),
    }


def _mla_qkv(p, cfg: ArchConfig, x, positions):
    b, l, _ = x.shape
    h = cfg.n_heads
    hd, rhd, vhd = cfg.head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank

    q = L.dense(p["wq"], x).reshape(b, l, h, hd + rhd).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)

    dkv = L.dense(p["wdkv"], x)                       # (B, L, kvr + rhd)
    c_kv = L.apply_norm(p["kv_norm"], dkv[..., :kvr], "rmsnorm")
    k_rope = L.apply_rope(dkv[..., None, kvr:].transpose(0, 2, 1, 3),
                          positions, cfg.rope_theta)  # (B, 1, L, rhd)
    return q_nope, q_rope, c_kv, k_rope


def _mla_expand(p, cfg: ArchConfig, c_kv):
    """c_kv (B, S, kvr) -> k_nope (B,H,S,hd), v (B,H,S,vhd)."""
    b, s, _ = c_kv.shape
    h, hd, vhd = cfg.n_heads, cfg.head_dim, cfg.v_head_dim
    kv = L.dense(p["wukv"], c_kv).reshape(b, s, h, hd + vhd)
    kv = kv.transpose(0, 2, 1, 3)
    return kv[..., :hd], kv[..., hd:]


def _mla_attend(cfg, q_nope, q_rope, k_nope, k_rope, v, pos_q, kv_len,
                causal=True):
    h = cfg.n_heads
    scale = 1.0 / ((cfg.head_dim + cfg.qk_rope_head_dim) ** 0.5)
    s = (jnp.einsum("bhqd,bhkd->bhqk", q_nope.astype(jnp.float32),
                    k_nope.astype(jnp.float32))
         + jnp.einsum("bhqd,bokd->bhqk", q_rope.astype(jnp.float32),
                      k_rope.astype(jnp.float32))) * scale
    k_pos = jnp.arange(kv_len)[None, None, None, :]
    if causal:
        s = jnp.where(pos_q[None, None, :, None] >= k_pos, s, _NEG_INF)
    prob = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", prob, v.astype(jnp.float32))


def mla_forward(p, cfg: ArchConfig, x) -> jnp.ndarray:
    b, l, _ = x.shape
    positions = jnp.arange(l)[None, :]
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, cfg, x, positions)
    k_nope, v = _mla_expand(p, cfg, c_kv)
    out = _mla_attend(cfg, q_nope, q_rope, k_nope, k_rope, v,
                      jnp.arange(l), l)
    out = out.astype(x.dtype).transpose(0, 2, 1, 3).reshape(b, l, -1)
    return L.dense(p["wo"], out)


def init_mla_cache(cfg: ArchConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> dict:
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, 1, max_len, cfg.qk_rope_head_dim), dtype),
    }


def mla_prefill(p, cfg: ArchConfig, x, cache: dict):
    b, l, _ = x.shape
    positions = jnp.arange(l)[None, :]
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, cfg, x, positions)
    cache = {
        "c_kv": jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, 0, 0)),
        "k_rope": jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
            (0, 0, 0, 0)),
    }
    k_nope, v = _mla_expand(p, cfg, c_kv)
    out = _mla_attend(cfg, q_nope, q_rope, k_nope, k_rope, v,
                      jnp.arange(l), l)
    out = out.astype(x.dtype).transpose(0, 2, 1, 3).reshape(b, l, -1)
    return L.dense(p["wo"], out), cache


def mla_decode(p, cfg: ArchConfig, x, cache: dict, pos):
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, cfg, x, positions)
    cache = {
        "c_kv": jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, pos, 0)),
        "k_rope": jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
            (0, 0, pos, 0)),
    }
    # re-expand K/V from the compressed cache (MLA's FLOPs-for-bytes trade)
    k_nope, v = _mla_expand(p, cfg, cache["c_kv"])
    kv_len = cache["c_kv"].shape[1]
    k_pos = jnp.arange(kv_len)[None, None, None, :]
    scale = 1.0 / ((cfg.head_dim + cfg.qk_rope_head_dim) ** 0.5)
    s = (jnp.einsum("bhqd,bhkd->bhqk", q_nope.astype(jnp.float32),
                    k_nope.astype(jnp.float32))
         + jnp.einsum("bhqd,bokd->bhqk", q_rope.astype(jnp.float32),
                      cache["k_rope"].astype(jnp.float32))) * scale
    s = jnp.where(k_pos <= pos, s, _NEG_INF)
    prob = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", prob, v.astype(jnp.float32))
    out = out.astype(x.dtype).transpose(0, 2, 1, 3).reshape(b, 1, -1)
    return L.dense(p["wo"], out), cache


# ---------------------------------------------------------------------------
# cross attention (enc-dec)
# ---------------------------------------------------------------------------

def cross_forward(p, cfg: ArchConfig, x, enc_kv: Tuple[jnp.ndarray, jnp.ndarray]):
    """Decoder cross-attn; enc_kv = (k, v) precomputed from encoder output."""
    b, l, _ = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = L.dense(p["wq"], x).reshape(b, l, h, hd).transpose(0, 2, 1, 3)
    k, v = enc_kv
    out = _full_attention(q, k, v, causal=False, window=0)
    out = out.transpose(0, 2, 1, 3).reshape(b, l, -1)
    return L.dense(p["wo"], out)


def cross_kv(p, cfg: ArchConfig, enc_out):
    b, s, _ = enc_out.shape
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    k = L.dense(p["wk"], enc_out).reshape(b, s, kv, hd).transpose(0, 2, 1, 3)
    v = L.dense(p["wv"], enc_out).reshape(b, s, kv, hd).transpose(0, 2, 1, 3)
    return k, v
