"""Shared NN layers: norms, MLPs, rotary embeddings, initializers.

Conventions:
  - params are plain nested dicts of jnp arrays (bf16 weights by default,
    fp32 norm scales), stackable on a leading layer axis for scan,
  - all matmuls go through ``dense`` which applies tensor-parallel
    sharding constraints via parallel/sharding.lshard,
  - math that affects numerics (norm statistics, softmax, rotary) is fp32.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import lshard

Param = dict


def truncnorm_init(key, shape, dtype=jnp.bfloat16, scale: float = 0.02):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(key, dim: int, kind: str = "rmsnorm") -> Param:
    del key
    p = {"scale": jnp.ones((dim,), jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((dim,), jnp.float32)
    return p


def apply_norm(p: Param, x: jnp.ndarray, kind: str = "rmsnorm",
               eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) / jnp.sqrt(var + eps) * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# dense / MLP
# ---------------------------------------------------------------------------

def init_dense(key, d_in: int, d_out: int, bias: bool = False,
               dtype=jnp.bfloat16, scale: float = 0.02) -> Param:
    p = {"w": truncnorm_init(key, (d_in, d_out), dtype, scale)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Param, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def init_mlp(key, d_model: int, d_ff: int, act: str = "swiglu",
             dtype=jnp.bfloat16) -> Param:
    k1, k2, k3 = jax.random.split(key, 3)
    if act == "swiglu":
        return {
            "wi": init_dense(k1, d_model, d_ff, dtype=dtype),
            "wg": init_dense(k2, d_model, d_ff, dtype=dtype),
            "wo": init_dense(k3, d_ff, d_model, dtype=dtype),
        }
    return {
        "wi": init_dense(k1, d_model, d_ff, bias=True, dtype=dtype),
        "wo": init_dense(k2, d_ff, d_model, bias=True, dtype=dtype),
    }


def apply_mlp(p: Param, x: jnp.ndarray, act: str = "swiglu") -> jnp.ndarray:
    """x: (..., d_model); hidden sharded over the ff/model axis."""
    if act == "swiglu":
        h = jax.nn.silu(dense(p["wg"], x)) * dense(p["wi"], x)
    else:
        h = jax.nn.gelu(dense(p["wi"], x))
    if h.ndim == 3:
        h = lshard(h, "batch", None, "ff")
    return dense(p["wo"], h)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10_000.0) -> jnp.ndarray:
    """x: (B, H, L, D), positions: (B, L) or (L,). fp32 rotation."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                     # (D/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, L, D/2)
    cos = jnp.cos(angles)[:, None, :, :]                   # (B, 1, L, D/2)
    sin = jnp.sin(angles)[:, None, :, :]
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., 0::2], xf[..., 1::2]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int,
                   dtype=jnp.bfloat16) -> Param:
    return {"table": truncnorm_init(key, (vocab, d_model), dtype)}


def embed(p: Param, tokens: jnp.ndarray) -> jnp.ndarray:
    return p["table"][tokens]


def unembed(p: Param, x: jnp.ndarray) -> jnp.ndarray:
    """Logits in fp32 (numerics) — caller is responsible for chunking."""
    return (x.astype(jnp.float32)
            @ p["table"].astype(jnp.float32).T)
