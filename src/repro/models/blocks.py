"""Transformer / Mamba / hybrid / enc-dec blocks + the layer plan.

A *layer plan* assigns each layer a static kind (mixer flavour, window,
MoE or dense FFN); consecutive identical kinds form *segments* whose
stacked parameters run under one ``lax.scan`` (+remat) — this keeps the
94-layer MoE's HLO compact enough to compile 512-way in the dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.parallel.sharding import lshard


@dataclasses.dataclass(frozen=True)
class LayerKind:
    mixer: str = "attention"      # attention | mamba2 | hybrid_parallel
    window: int = 0               # sliding window (0 = full)
    moe: bool = False
    cross: bool = False           # decoder block with cross-attn (enc-dec)
    causal: bool = True


def layer_plan(cfg: ArchConfig) -> List[LayerKind]:
    """Per-decoder-layer kinds for an architecture."""
    plan = []
    for i in range(cfg.n_layers):
        window = cfg.sliding_window
        if window and cfg.global_layer_every:
            if i % cfg.global_layer_every == 0 or i == cfg.n_layers - 1:
                window = 0                       # periodic global layers
        plan.append(LayerKind(
            mixer=cfg.mixer if cfg.mixer != "attention" else "attention",
            window=window,
            moe=cfg.n_experts > 0 and i >= cfg.first_dense_layers,
            cross=cfg.is_encdec,
        ))
    return plan


def segments(plan: List[LayerKind]) -> List[Tuple[LayerKind, int]]:
    """Group consecutive identical kinds -> [(kind, count), ...]."""
    segs: List[Tuple[LayerKind, int]] = []
    for kind in plan:
        if segs and segs[-1][0] == kind:
            segs[-1] = (kind, segs[-1][1] + 1)
        else:
            segs.append((kind, 1))
    return segs


# ---------------------------------------------------------------------------
# single-layer init / forward
# ---------------------------------------------------------------------------

def init_block(key, cfg: ArchConfig, kind: LayerKind,
               dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 6)
    p = {"norm1": L.init_norm(None, cfg.d_model, cfg.norm)}
    if kind.mixer in ("attention", "hybrid_parallel"):
        p["attn"] = (attn.init_mla(ks[0], cfg, dtype)
                     if cfg.attention == "mla"
                     else attn.init_gqa(ks[0], cfg, dtype))
    if kind.mixer in ("mamba2", "hybrid_parallel"):
        p["ssm"] = ssm_lib.init_mamba2(ks[1], cfg, dtype)
    if kind.mixer != "mamba2":                       # mamba blocks: no FFN
        p["norm2"] = L.init_norm(None, cfg.d_model, cfg.norm)
        if kind.moe:
            p["moe"] = moe_lib.init_moe(ks[2], cfg, dtype)
        else:
            p["mlp"] = L.init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.act,
                                  dtype)
    if kind.cross:
        p["norm_x"] = L.init_norm(None, cfg.d_model, cfg.norm)
        p["xattn"] = attn.init_gqa(ks[3], cfg, dtype)
    return p


def _mixer_forward(p, cfg, kind: LayerKind, h):
    if kind.mixer == "attention":
        if cfg.attention == "mla":
            return attn.mla_forward(p["attn"], cfg, h)
        return attn.gqa_forward(p["attn"], cfg, h, window=kind.window,
                                causal=kind.causal)
    if kind.mixer == "mamba2":
        return ssm_lib.mamba2_forward(p["ssm"], cfg, h)
    # hybrid_parallel (Hymba): attention and SSM heads fused by averaging
    a = attn.gqa_forward(p["attn"], cfg, h, window=kind.window)
    s = ssm_lib.mamba2_forward(p["ssm"], cfg, h)
    return 0.5 * (a + s)


def block_forward(p, cfg: ArchConfig, kind: LayerKind, x,
                  enc_kv=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pre-norm residual block. Returns (x, moe_aux_loss)."""
    x = lshard(x, "batch", "seq", None)
    h = L.apply_norm(p["norm1"], x, cfg.norm)
    x = x + _mixer_forward(p, cfg, kind, h)
    if kind.cross and enc_kv is not None:
        h = L.apply_norm(p["norm_x"], x, cfg.norm)
        x = x + attn.cross_forward(p["xattn"], cfg, h, enc_kv)
    aux = jnp.float32(0.0)
    if kind.mixer != "mamba2":
        h = L.apply_norm(p["norm2"], x, cfg.norm)
        if kind.moe:
            y, aux = moe_lib.moe_forward(p["moe"], cfg, h)
        else:
            y = L.apply_mlp(p["mlp"], h, act=cfg.act)
        x = x + y
    x = lshard(x, "batch", "seq", None)
    return x, aux


# ---------------------------------------------------------------------------
# caches (decode path)
# ---------------------------------------------------------------------------

def init_block_cache(cfg: ArchConfig, kind: LayerKind, batch: int,
                     max_len: int, dtype=jnp.bfloat16) -> dict:
    cache = {}
    if kind.mixer in ("attention", "hybrid_parallel"):
        eff_len = max_len if kind.window == 0 else min(max_len, kind.window)
        if cfg.attention == "mla":
            cache["attn"] = attn.init_mla_cache(cfg, batch, max_len, dtype)
        else:
            cache["attn"] = attn.init_gqa_cache(cfg, batch, eff_len, dtype)
    if kind.mixer in ("mamba2", "hybrid_parallel"):
        cache["ssm"] = ssm_lib.init_mamba2_state(cfg, batch)
    return cache


def _attn_decode(p, cfg, kind, h, cache, pos):
    if cfg.attention == "mla":
        return attn.mla_decode(p["attn"], cfg, h, cache, pos)
    if kind.window > 0:
        # ring-buffer cache for sliding windows (slot = pos % ring size)
        return attn.gqa_decode_ring(p["attn"], cfg, h, cache, pos,
                                    kind.window)
    return attn.gqa_decode(p["attn"], cfg, h, cache, pos)


def block_decode(p, cfg: ArchConfig, kind: LayerKind, x, cache: dict, pos,
                 enc_kv=None):
    """One-token decode through a block; returns (x, cache)."""
    h = L.apply_norm(p["norm1"], x, cfg.norm)
    new_cache = dict(cache)
    if kind.mixer == "attention":
        out, new_cache["attn"] = _attn_decode(p, cfg, kind, h,
                                              cache["attn"], pos)
    elif kind.mixer == "mamba2":
        out, new_cache["ssm"] = ssm_lib.mamba2_decode(p["ssm"], cfg, h,
                                                      cache["ssm"])
    else:
        a, new_cache["attn"] = _attn_decode(p, cfg, kind, h,
                                            cache["attn"], pos)
        s, new_cache["ssm"] = ssm_lib.mamba2_decode(p["ssm"], cfg, h,
                                                    cache["ssm"])
        out = 0.5 * (a + s)
    x = x + out
    if kind.cross and enc_kv is not None:
        h = L.apply_norm(p["norm_x"], x, cfg.norm)
        x = x + attn.cross_forward(p["xattn"], cfg, h, enc_kv)
    if kind.mixer != "mamba2":
        h = L.apply_norm(p["norm2"], x, cfg.norm)
        if kind.moe:
            y, _ = moe_lib.moe_forward(p["moe"], cfg, h)
        else:
            y = L.apply_mlp(p["mlp"], h, act=cfg.act)
        x = x + y
    return x, new_cache


def block_prefill(p, cfg: ArchConfig, kind: LayerKind, x, cache: dict,
                  enc_kv=None):
    """Prefill: forward + cache fill. Returns (x, cache)."""
    h = L.apply_norm(p["norm1"], x, cfg.norm)
    new_cache = dict(cache)
    if kind.mixer == "attention":
        if cfg.attention == "mla":
            out, new_cache["attn"] = attn.mla_prefill(p["attn"], cfg, h,
                                                      cache["attn"])
        else:
            out, new_cache["attn"] = _gqa_prefill_any(p, cfg, kind, h,
                                                      cache["attn"])
    elif kind.mixer == "mamba2":
        out, new_cache["ssm"] = _ssm_prefill(p, cfg, h, cache["ssm"])
    else:
        a, new_cache["attn"] = _gqa_prefill_any(p, cfg, kind, h,
                                                cache["attn"])
        s, new_cache["ssm"] = _ssm_prefill(p, cfg, h, cache["ssm"])
        out = 0.5 * (a + s)
    x = x + out
    if kind.cross and enc_kv is not None:
        h = L.apply_norm(p["norm_x"], x, cfg.norm)
        x = x + attn.cross_forward(p["xattn"], cfg, h, enc_kv)
    aux = jnp.float32(0.0)
    if kind.mixer != "mamba2":
        h = L.apply_norm(p["norm2"], x, cfg.norm)
        if kind.moe:
            y, aux = moe_lib.moe_forward(p["moe"], cfg, h)
        else:
            y = L.apply_mlp(p["mlp"], h, act=cfg.act)
        x = x + y
    return x, new_cache


def _gqa_prefill_any(p, cfg, kind, h, cache):
    if kind.window > 0:
        return attn.gqa_prefill_ring(p["attn"], cfg, h, cache, kind.window)
    return attn.gqa_prefill(p["attn"], cfg, h, cache, window=kind.window)


def _ssm_prefill(p, cfg, h, state):
    """Prefill for SSM: run the full scan, then rebuild the decode state
    by replaying the tail (conv) and folding the scan's final SSD state."""
    out = ssm_lib.mamba2_forward(p["ssm"], cfg, h)
    state = ssm_lib.mamba2_prefill_state(p["ssm"], cfg, h)
    return out, state
