"""Performance-tuning flags for the §Perf hillclimb.

Every flag defaults to the *baseline* (paper-faithful / first-pass)
behaviour; the hillclimb runner flips them one at a time and re-lowers the
cell, so EXPERIMENTS.md §Perf shows before/after per change. Flags are
process-global (consumed at trace time).

  loss_remat          — jax.checkpoint around the per-chunk LM loss body:
                        the backward pass recomputes chunk logits instead
                        of stacking (n_chunks, B, chunk, V/16) fp32
                        residuals (the dominant train-cell HBM term).
  attn_chunk_remat    — jax.checkpoint around each q-chunk of exact
                        attention: backward recomputes score matrices
                        chunk-by-chunk instead of saving all of them.
  gqa_grouped_einsum  — decode attention via grouped einsum
                        (b, kv, group, d) x (b, kv, s, d) instead of
                        jnp.repeat'ing K/V to all query heads (kills the
                        (B, H, S, D) materialization in decode).
  decode_batch_cache  — shard decode KV caches over batch only (no seq
                        sharding), eliminating GSPMD's "involuntary full
                        rematerialization" resharding copies around the
                        cache update.
  moe_capacity_factor — expert capacity factor (dispatch tensor size vs
                        drop rate trade).
"""

from __future__ import annotations

import contextlib
import dataclasses


@dataclasses.dataclass
class Tuning:
    loss_remat: bool = False
    attn_chunk_remat: bool = False
    gqa_grouped_einsum: bool = False
    decode_batch_cache: bool = False
    moe_capacity_factor: float = 1.25
    # right-size parallelism: run the cell pure-data-parallel (params
    # replicated, batch over every mesh axis) — for sub-1B models TP=16
    # is over-sharding and its activation collectives dominate
    pure_dp: bool = False
    # decode attention in bf16 with fp32 accumulation (MXU-native): no
    # materialized f32 copy of the KV cache per layer per step
    decode_bf16_einsum: bool = False
    # MoE dispatch via scatter/gather index ops instead of the dense
    # GShard one-hot einsums — removes the O(S*E*C*d) dispatch FLOPs
    # (qwen3-moe burns 3.3x MODEL_FLOPS on them; 6ND/HLO = 0.30)
    moe_scatter_dispatch: bool = False


TUNING = Tuning()


@contextlib.contextmanager
def tuned(**kw):
    """Temporarily override tuning flags (hillclimb runner)."""
    old = dataclasses.replace(TUNING)
    try:
        for k, v in kw.items():
            setattr(TUNING, k, v)
        yield TUNING
    finally:
        for f in dataclasses.fields(Tuning):
            setattr(TUNING, f.name, getattr(old, f.name))
