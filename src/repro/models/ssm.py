"""Mamba-2 (SSD) mixer: in-proj -> causal conv -> SSD scan -> gate -> out.

Follows the Mamba-2 block (arXiv:2405.21060): single projection producing
[z (gate), x, B, C, dt]; depthwise causal conv over (x, B, C); scalar
per-head A; SSD scan via kernels/ops.ssd (Pallas on TPU, chunked jnp on
CPU); gated RMSNorm before the output projection.

Decode carries (conv_state, ssd_state): the conv tail (width-1 samples)
and the (heads, N, P) recurrent state — O(1) memory in sequence length,
which is what makes ``long_500k`` decoding tractable.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops as kops
from repro.models import layers as L


def init_mamba2(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> dict:
    d = cfg.d_model
    di, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * n
    ks = jax.random.split(key, 4)
    return {
        "in_proj": L.init_dense(ks[0], d, 2 * di + 2 * n + h, dtype=dtype),
        "conv_w": L.truncnorm_init(ks[1], (cfg.ssm_conv, conv_dim), dtype,
                                   scale=0.1),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "gate_norm": L.init_norm(None, di, "rmsnorm"),
        "out_proj": L.init_dense(ks[3], di, d, dtype=dtype),
    }


def _split(cfg: ArchConfig, proj):
    di, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * n]
    dt = proj[..., di + di + 2 * n:]
    return z, xbc, dt


def _causal_conv(w, b, xbc):
    """Depthwise causal conv, width K. xbc: (B, L, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    return jax.nn.silu(out + b)


def mamba2_forward(p, cfg: ArchConfig, x) -> jnp.ndarray:
    b, l, _ = x.shape
    di, n, h, pd = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, \
        cfg.ssm_head_dim

    proj = L.dense(p["in_proj"], x)
    z, xbc, dt_raw = _split(cfg, proj)
    xbc = _causal_conv(p["conv_w"], p["conv_b"], xbc)
    xs = xbc[..., :di]
    bmat = xbc[..., di:di + n]
    cmat = xbc[..., di + n:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])                               # (h,) negative

    # -> (B*h, L, ...) kernel layout
    xh = xs.reshape(b, l, h, pd).transpose(0, 2, 1, 3).reshape(b * h, l, pd)
    dth = dt.transpose(0, 2, 1).reshape(b * h, l)
    bh = jnp.repeat(bmat[:, None], h, axis=1).reshape(b * h, l, n)
    ch = jnp.repeat(cmat[:, None], h, axis=1).reshape(b * h, l, n)
    ah = jnp.tile(a, (b,))

    y = kops.ssd(xh, dth, ah, bh, ch)                      # (B*h, L, pd)
    y = y.reshape(b, h, l, pd).transpose(0, 2, 1, 3)
    y = y + p["d_skip"][None, None, :, None] * xs.reshape(b, l, h, pd)
    y = y.reshape(b, l, di)

    y = L.apply_norm(p["gate_norm"], y, "rmsnorm") * jax.nn.silu(
        z.astype(jnp.float32))
    return L.dense(p["out_proj"], y.astype(x.dtype))


# ---------------------------------------------------------------------------
# decode path (O(1) state)
# ---------------------------------------------------------------------------

def init_mamba2_state(cfg: ArchConfig, batch: int,
                      dtype=jnp.float32) -> dict:
    di, n, h, pd = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, \
        cfg.ssm_head_dim
    conv_dim = di + 2 * n
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "ssd": jnp.zeros((batch * h, n, pd), dtype),
    }


def mamba2_prefill_state(p, cfg: ArchConfig, x) -> dict:
    """Build the decode state after consuming a full prefix x (B, L, d).

    conv state = the last (K-1) *raw* pre-conv rows; SSD state = the exact
    final recurrent state h_L = sum_j exp(cum_L - cum_j) dt_j B_j x_j^T
    (stable: all exponents non-positive).
    """
    b, l, _ = x.shape
    di, n, h, pd = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, \
        cfg.ssm_head_dim
    k = cfg.ssm_conv

    proj = L.dense(p["in_proj"], x)
    _, xbc_raw, dt_raw = _split(cfg, proj)
    # conv tail: last K-1 raw rows (zero-padded when L < K-1)
    pad = jnp.pad(xbc_raw, ((0, 0), (k - 1, 0), (0, 0)))
    conv_state = pad[:, l:l + k - 1, :].astype(jnp.float32)

    xbc = _causal_conv(p["conv_w"], p["conv_b"], xbc_raw)
    xs = xbc[..., :di]
    bmat = xbc[..., di:di + n].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])                                # (h,)

    xh = xs.reshape(b, l, h, pd).transpose(0, 2, 1, 3).astype(jnp.float32)
    dth = dt.transpose(0, 2, 1)                             # (B, h, L)
    cum = jnp.cumsum(dth * a[None, :, None], axis=-1)       # (B, h, L)
    w = jnp.exp(cum[..., -1:] - cum) * dth                  # (B, h, L)
    # h_L = sum_j w_j B_j (x_j)^T  -> (B, h, N, P)
    state = jnp.einsum("bhl,bln,bhlp->bhnp", w, bmat, xh)
    return {"conv": conv_state, "ssd": state.reshape(b * h, n, pd)}


def mamba2_decode(p, cfg: ArchConfig, x, state: dict) -> Tuple[jnp.ndarray, dict]:
    """x: (B, 1, d) -> (y (B, 1, d), state)."""
    b = x.shape[0]
    di, n, h, pd = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, \
        cfg.ssm_head_dim

    proj = L.dense(p["in_proj"], x)
    z, xbc_t, dt_raw = _split(cfg, proj)                   # (B, 1, .)
    window = jnp.concatenate(
        [state["conv"], xbc_t.astype(state["conv"].dtype)], axis=1)
    conv_out = sum(window[:, i] * p["conv_w"][i][None, :]
                   for i in range(cfg.ssm_conv)) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)                       # (B, C)
    new_conv = window[:, 1:]

    xs = conv_out[:, :di]
    bmat = conv_out[:, di:di + n]
    cmat = conv_out[:, di + n:]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])

    xh = xs.reshape(b, h, pd).reshape(b * h, pd).astype(jnp.float32)
    dth = dt.reshape(b * h)
    bh = jnp.repeat(bmat[:, None], h, axis=1).reshape(b * h, n).astype(
        jnp.float32)
    ch = jnp.repeat(cmat[:, None], h, axis=1).reshape(b * h, n).astype(
        jnp.float32)
    ah = jnp.tile(a, (b,))

    new_ssd, y = kops.ssd_decode_step(state["ssd"], xh, dth, ah, bh, ch)
    y = y.reshape(b, h, pd) + p["d_skip"][None, :, None] * xs.reshape(
        b, h, pd).astype(jnp.float32)
    y = y.reshape(b, 1, di)
    y = L.apply_norm(p["gate_norm"], y, "rmsnorm") * jax.nn.silu(
        z.astype(jnp.float32))
    out = L.dense(p["out_proj"], y.astype(x.dtype))
    return out, {"conv": new_conv, "ssd": new_ssd}
