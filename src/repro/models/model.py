"""The LM model: init / train loss / prefill / decode for all 10 archs.

Structure (decoder-only; seamless adds an encoder stack + cross-attn):

    tokens -> embed -> [frontend embeds prepended] -> segment scans
           -> final norm -> (tied or separate) unembed

Key scalability choices:
  - scan-over-layers per homogeneous segment with ``jax.checkpoint``
    (remat) around the block body: activation memory = one layer boundary
    per segment layer, HLO size = O(#segments), not O(#layers),
  - the LM loss never materializes (B, L, V) logits: a seq-chunked scan
    computes fp32 logits per chunk (vocab sharded over "model"),
  - decode caches are stacked per segment so the decode step is also a
    scan; all cache updates are in-place dynamic_update_slice.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_lib
from repro.models import blocks as B
from repro.models import layers as L
from repro.parallel.sharding import lshard

PyTree = dict
LOSS_CHUNK = 512


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_segment(key, cfg: ArchConfig, kind: B.LayerKind, count: int,
                  dtype) -> PyTree:
    keys = jax.random.split(key, count)
    per_layer = [B.init_block(k, cfg, kind, dtype) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_layer)


def init_params(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> PyTree:
    plan = B.layer_plan(cfg)
    segs = B.segments(plan)
    n_seg = len(segs)
    keys = jax.random.split(key, n_seg + 4)

    params: PyTree = {
        "embed": L.init_embedding(keys[0], cfg.vocab_size, cfg.d_model,
                                  dtype),
        "final_norm": L.init_norm(None, cfg.d_model, cfg.norm),
        "segments": [
            _init_segment(keys[2 + i], cfg, kind, count, dtype)
            for i, (kind, count) in enumerate(segs)
        ],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_dense(keys[1], cfg.d_model,
                                         cfg.vocab_size, dtype=dtype)
    if cfg.is_encdec:
        enc_kind = B.LayerKind(mixer="attention", causal=False)
        params["encoder"] = {
            "segments": [_init_segment(keys[n_seg + 2], cfg, enc_kind,
                                       cfg.encoder_layers, dtype)],
            "final_norm": L.init_norm(None, cfg.d_model, cfg.norm),
        }
    return params


# ---------------------------------------------------------------------------
# forward (training)
# ---------------------------------------------------------------------------

def _scan_segment(seg_params, cfg: ArchConfig, kind: B.LayerKind, x,
                  enc_kv=None):
    """Remat-scan over a stacked segment; accumulates MoE aux loss."""

    def body(x, p_layer):
        return B.block_forward(p_layer, cfg, kind, x, enc_kv=enc_kv)

    body = jax.checkpoint(body, prevent_cse=False)
    x, auxs = jax.lax.scan(body, x, seg_params)
    return x, jnp.sum(auxs)


def _encode(params, cfg: ArchConfig, frames):
    """Encoder stack over precomputed frame embeddings (B, S, d)."""
    enc_kind = B.LayerKind(mixer="attention", causal=False)
    x = frames
    for seg in params["encoder"]["segments"]:
        x, _ = _scan_segment(seg, cfg, enc_kind, x)
    return L.apply_norm(params["encoder"]["final_norm"], x, cfg.norm)


def backbone(params: PyTree, cfg: ArchConfig, tokens: jnp.ndarray,
             prefix_embeds: Optional[jnp.ndarray] = None,
             enc_frames: Optional[jnp.ndarray] = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (B, L) -> hidden (B, L', d), aux loss. L' includes prefix."""
    x = L.embed(params["embed"], tokens)
    if prefix_embeds is not None:        # VLM patches / modality stub
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x = lshard(x, "batch", "seq", None)

    enc_kv = None
    if cfg.is_encdec:
        assert enc_frames is not None, "enc-dec arch needs encoder frames"
        enc_out = _encode(params, cfg, enc_frames)
        # pre-compute shared cross K/V from the first decoder segment's
        # cross projections (weights per layer; K/V computed inside blocks
        # would recompute per layer — we pass enc_out and let each layer
        # derive K/V lazily through its own wk/wv)
        enc_kv = enc_out

    plan = B.layer_plan(cfg)
    segs = B.segments(plan)
    aux_total = jnp.float32(0.0)
    for seg_params, (kind, _count) in zip(params["segments"], segs):
        if cfg.is_encdec:
            x, aux = _scan_segment_encdec(seg_params, cfg, kind, x, enc_kv)
        else:
            x, aux = _scan_segment(seg_params, cfg, kind, x)
        aux_total = aux_total + aux
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    return x, aux_total


def _scan_segment_encdec(seg_params, cfg, kind, x, enc_out):
    def body(x, p_layer):
        kv = attn_lib.cross_kv(p_layer["xattn"], cfg, enc_out)
        return B.block_forward(p_layer, cfg, kind, x, enc_kv=kv)

    body = jax.checkpoint(body, prevent_cse=False)
    x, auxs = jax.lax.scan(body, x, seg_params)
    return x, jnp.sum(auxs)


def _unembed_chunk(params, cfg: ArchConfig, h_chunk):
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], h_chunk)
    else:
        logits = L.dense(params["lm_head"], h_chunk).astype(jnp.float32)
    return lshard(logits, "batch", None, "vocab")


def lm_loss(params: PyTree, cfg: ArchConfig, hidden: jnp.ndarray,
            labels: jnp.ndarray, mask: Optional[jnp.ndarray] = None
            ) -> jnp.ndarray:
    """Seq-chunked fp32 cross-entropy; never materializes full logits."""
    bsz, seq, _ = hidden.shape
    chunk = min(LOSS_CHUNK, seq)
    while seq % chunk:                 # largest divisor of seq <= LOSS_CHUNK
        chunk -= 1
    n_chunks = seq // chunk
    if mask is None:
        mask = jnp.ones((bsz, seq), jnp.float32)

    def chunk_loss(ci):
        h = jax.lax.dynamic_slice_in_dim(hidden, ci * chunk, chunk, 1)
        lab = jax.lax.dynamic_slice_in_dim(labels, ci * chunk, chunk, 1)
        msk = jax.lax.dynamic_slice_in_dim(mask, ci * chunk, chunk, 1)
        logits = _unembed_chunk(params, cfg, h)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        return jnp.sum((logz - gold) * msk), jnp.sum(msk)

    from repro.models.tuning import TUNING
    if TUNING.loss_remat:
        # backward recomputes chunk logits instead of stacking residuals
        chunk_loss = jax.checkpoint(chunk_loss, prevent_cse=False)

    def scan_body(carry, ci):
        tot, cnt = carry
        l, c = chunk_loss(ci)
        return (tot + l, cnt + c), None

    (total, count), _ = jax.lax.scan(
        scan_body, (jnp.float32(0.0), jnp.float32(0.0)),
        jnp.arange(n_chunks))
    return total / jnp.maximum(count, 1.0)


def train_loss(params: PyTree, cfg: ArchConfig, batch: Dict,
               aux_coef: float = 0.01) -> jnp.ndarray:
    """batch: tokens (B,L), labels (B,L) [+ patch_embeds / enc_frames]."""
    hidden, aux = backbone(
        params, cfg, batch["tokens"],
        prefix_embeds=batch.get("patch_embeds"),
        enc_frames=batch.get("enc_frames"))
    n_prefix = 0 if batch.get("patch_embeds") is None \
        else batch["patch_embeds"].shape[1]
    if n_prefix:
        hidden = hidden[:, n_prefix:]
    loss = lm_loss(params, cfg, hidden, batch["labels"],
                   batch.get("loss_mask"))
    return loss + aux_coef * aux


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> List[PyTree]:
    plan = B.layer_plan(cfg)
    segs = B.segments(plan)
    caches = []
    for kind, count in segs:
        per_layer = B.init_block_cache(cfg, kind, batch, max_len, dtype)
        caches.append(jax.tree_util.tree_map(
            lambda x: jnp.tile(x[None], (count,) + (1,) * x.ndim),
            per_layer))
    return caches


def prefill(params: PyTree, cfg: ArchConfig, tokens: jnp.ndarray,
            cache: List[PyTree],
            prefix_embeds: Optional[jnp.ndarray] = None,
            enc_frames: Optional[jnp.ndarray] = None):
    """Consume the prompt; returns (last-token logits, cache, enc_out)."""
    x = L.embed(params["embed"], tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x = lshard(x, "batch", "seq", None)

    enc_out = None
    if cfg.is_encdec:
        enc_out = _encode(params, cfg, enc_frames)

    plan = B.layer_plan(cfg)
    segs = B.segments(plan)
    new_caches = []
    for seg_params, seg_cache, (kind, _c) in zip(params["segments"], cache,
                                                 segs):
        def body(x, layer):
            p_layer, c_layer = layer
            kv = (attn_lib.cross_kv(p_layer["xattn"], cfg, enc_out)
                  if kind.cross else None)
            x, new_c = B.block_prefill(p_layer, cfg, kind, x, c_layer,
                                       enc_kv=kv)
            return x, new_c

        x, new_c = jax.lax.scan(body, x, (seg_params, seg_cache))
        new_caches.append(new_c)
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = _unembed_chunk(params, cfg, x[:, -1:, :])
    return logits[:, 0], new_caches, enc_out


def decode_step(params: PyTree, cfg: ArchConfig, token: jnp.ndarray,
                pos, cache: List[PyTree],
                enc_out: Optional[jnp.ndarray] = None):
    """token (B,) int32, pos scalar -> (logits (B, V'), new cache)."""
    x = L.embed(params["embed"], token[:, None])
    plan = B.layer_plan(cfg)
    segs = B.segments(plan)
    new_caches = []
    for seg_params, seg_cache, (kind, _c) in zip(params["segments"], cache,
                                                 segs):
        def body(x, layer):
            p_layer, c_layer = layer
            kv = (attn_lib.cross_kv(p_layer["xattn"], cfg, enc_out)
                  if kind.cross else None)
            x, new_c = B.block_decode(p_layer, cfg, kind, x, c_layer, pos,
                                      enc_kv=kv)
            return x, new_c

        x, new_c = jax.lax.scan(body, x, (seg_params, seg_cache))
        new_caches.append(new_c)
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = _unembed_chunk(params, cfg, x)
    return logits[:, 0], new_caches


def param_count_actual(params: PyTree) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))
