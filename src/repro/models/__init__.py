"""LM substrate: layers, attention (GQA/MLA/SWA), Mamba-2 SSD, MoE,
hybrid and enc-dec blocks, and the unified model API (model.py)."""
