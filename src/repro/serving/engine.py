"""Serving engine: batched prefill/decode with continuous batching.

A compact vLLM-style slot scheduler over the model's functional
prefill/decode API:

  - a fixed pool of B decode slots, each holding one in-flight request,
  - new requests prefill into a free slot (per-slot cache write at the
    slot's batch row); finished rows free their slot immediately,
  - every decode step advances *all* active slots in one jit'd call,
  - greedy or temperature sampling.

Slot-level cache surgery uses one batched cache of shape (B, ...) and
jax.lax.dynamic_update_index_in_dim writes — no per-request recompile.
The decode step is the exact function the dry-run lowers for the
``decode_32k`` / ``long_500k`` cells.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model as M


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, arch: ArchConfig, params, n_slots: int = 4,
                 max_len: int = 256, dtype=jnp.float32, seed: int = 0):
        self.arch = arch
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = M.init_cache(arch, n_slots, max_len, dtype)
        self.positions = np.zeros(n_slots, np.int32)       # next position
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.key = jax.random.PRNGKey(seed)

        self._prefill1 = jax.jit(
            lambda params, toks, cache: M.prefill(params, arch, toks, cache))
        self._decode = jax.jit(
            lambda params, tok, pos, cache: M.decode_step(
                params, arch, tok, pos, cache))

    # ------------------------------------------------------------------ #
    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def add_request(self, req: Request) -> bool:
        """Prefill `req` into a free slot; False if engine is full."""
        free = self._free_slots()
        if not free:
            return False
        slot = free[0]
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        # single-row prefill into a fresh single-row cache, then splice
        row_cache = M.init_cache(self.arch, 1, self.max_len,
                                 jax.tree_util.tree_leaves(
                                     self.cache)[0].dtype)
        logits, row_cache, _ = self._prefill1(self.params, toks, row_cache)
        self.cache = jax.tree_util.tree_map(
            lambda full, row: jax.lax.dynamic_update_slice_in_dim(
                full, row.astype(full.dtype), slot, axis=1),
            self.cache, row_cache)
        self.slot_req[slot] = req
        self.positions[slot] = len(req.prompt)
        first = self._sample(logits[0], req)
        req.output.append(int(first))
        return True

    def _sample(self, logits: jnp.ndarray, req: Request) -> int:
        if req.temperature <= 0.0:
            return int(jnp.argmax(logits))
        self.key, k = jax.random.split(self.key)
        return int(jax.random.categorical(k, logits / req.temperature))

    # ------------------------------------------------------------------ #
    def step(self):
        """One decode step for all active slots (continuous batching)."""
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        tokens = np.zeros(self.n_slots, np.int32)
        for i in active:
            tokens[i] = self.slot_req[i].output[-1]
        # all rows share one position scalar per step; slots may differ ->
        # decode at each distinct position group
        for pos in sorted({int(self.positions[i]) for i in active}):
            group = [i for i in active if self.positions[i] == pos]
            logits, new_cache = self._decode(
                self.params, jnp.asarray(tokens), pos, self.cache)
            # only splice back rows belonging to this position group
            rows = jnp.asarray(group)
            self.cache = jax.tree_util.tree_map(
                lambda full, new: full.at[:, rows].set(new[:, rows])
                if full.ndim >= 2 else new,
                self.cache, new_cache)
            for i in group:
                req = self.slot_req[i]
                tok = self._sample(logits[i], req)
                req.output.append(tok)
                self.positions[i] += 1
                if (len(req.output) >= req.max_new_tokens
                        or self.positions[i] >= self.max_len - 1):
                    req.done = True
                    self.slot_req[i] = None

    def run(self, requests: List[Request], max_steps: int = 512
            ) -> List[Request]:
        """Serve a request list to completion with continuous batching."""
        pending = list(requests)
        finished: List[Request] = []
        steps = 0
        while (pending or any(self.slot_req)) and steps < max_steps:
            while pending and self._free_slots():
                self.add_request(pending.pop(0))
            self.step()
            finished.extend(r for r in requests
                            if r.done and r not in finished)
            steps += 1
        return requests
