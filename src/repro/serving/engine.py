"""Serving engine: batched prefill/decode with continuous batching.

A compact vLLM-style slot scheduler over the model's functional
prefill/decode API:

  - a fixed pool of B decode slots, each holding one in-flight request,
  - new requests prefill into a free slot (per-slot cache write at the
    slot's batch row); finished rows free their slot immediately,
  - every decode step advances *all* active slots in one jit'd call,
  - greedy or temperature sampling.

Slot-level cache surgery uses one batched cache and per-leaf batch-axis
splices — no per-request recompile. Cache leaves do NOT all put the
batch at axis 1 with one row per slot: the SSD state leaves fold batch
with heads, ``(layers, B*h, n, pd)``, so :func:`cache_batch_axes`
derives each leaf's (batch axis, rows-per-slot) structurally by
comparing ``init_cache`` shapes at batch B vs batch 1. The decode step
is the exact function the dry-run lowers for the ``decode_32k`` /
``long_500k`` cells.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model as M


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


# --------------------------------------------------------------------------- #
# cache splicing: per-leaf batch-axis surgery
# --------------------------------------------------------------------------- #

def cache_batch_axes(arch: ArchConfig, n_slots: int, max_len: int,
                     dtype) -> List[Tuple[int, int]]:
    """Per-leaf ``(batch_axis, rows_per_slot)`` of the engine cache.

    Derived structurally: the batch axis of each leaf is the first axis
    whose extent differs between ``init_cache(arch, n_slots, ...)`` and
    ``init_cache(arch, 1, ...)``, and its per-slot width is that axis's
    extent at batch 1 (8 for the SSD leaves that fold batch with heads,
    1 for attention/MLA/conv leaves). ``(None, None)`` marks a leaf
    whose shape does not depend on the batch at all (only possible at
    ``n_slots == 1``, where whole-leaf replacement is the correct
    splice).
    """
    full = jax.tree_util.tree_leaves(M.init_cache(arch, n_slots, max_len,
                                                  dtype))
    one = jax.tree_util.tree_leaves(M.init_cache(arch, 1, max_len, dtype))
    axes: List[Tuple[int, int]] = []
    for f, o in zip(full, one):
        axis = per = None
        for d, (sf, so) in enumerate(zip(f.shape, o.shape)):
            if sf != so:
                axis, per = d, so
                break
        axes.append((axis, per))
    return axes


def splice_slot(cache, row_cache, axes: List[Tuple[int, int]], slot: int):
    """Write a batch-1 ``row_cache`` into ``cache`` at ``slot``.

    ``axes`` is :func:`cache_batch_axes` output, aligned with the leaf
    order of both trees. Each leaf is updated only along its own batch
    axis at offset ``slot * rows_per_slot``.
    """
    leaves, treedef = jax.tree_util.tree_flatten(cache)
    rows = treedef.flatten_up_to(row_cache)
    out = []
    for full, row, (axis, per) in zip(leaves, rows, axes):
        if axis is None:
            out.append(row.astype(full.dtype))
            continue
        out.append(jax.lax.dynamic_update_slice_in_dim(
            full, row.astype(full.dtype), slot * per, axis=axis))
    return jax.tree_util.tree_unflatten(treedef, out)


def splice_rows(cache, new_cache, axes: List[Tuple[int, int]],
                slots: np.ndarray):
    """Adopt ``new_cache`` rows for the given slots only.

    Used by :meth:`ServingEngine.step` to keep just the decoded position
    group's rows out of a full-batch decode: every leaf is updated at
    the row block of each slot in ``slots`` along its own batch axis;
    all other rows keep the prior cache contents.
    """
    leaves, treedef = jax.tree_util.tree_flatten(cache)
    news = treedef.flatten_up_to(new_cache)
    slots = np.asarray(slots, np.int32)
    out = []
    for full, new, (axis, per) in zip(leaves, news, axes):
        if axis is None:
            out.append(new.astype(full.dtype))
            continue
        idx = jnp.asarray(
            (slots[:, None] * per + np.arange(per)[None, :]).reshape(-1))
        sl = (slice(None),) * axis + (idx,)
        out.append(full.at[sl].set(new[sl].astype(full.dtype)))
    return jax.tree_util.tree_unflatten(treedef, out)


class ServingEngine:
    def __init__(self, arch: ArchConfig, params, n_slots: int = 4,
                 max_len: int = 256, dtype=jnp.float32, seed: int = 0):
        self.arch = arch
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = M.init_cache(arch, n_slots, max_len, dtype)
        self._axes = cache_batch_axes(arch, n_slots, max_len, dtype)
        self.positions = np.zeros(n_slots, np.int32)       # next position
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.key = jax.random.PRNGKey(seed)
        self.last_run_exhausted = False

        self._prefill1 = jax.jit(
            lambda params, toks, cache: M.prefill(params, arch, toks, cache))
        self._decode = jax.jit(
            lambda params, tok, pos, cache: M.decode_step(
                params, arch, tok, pos, cache))

    # ------------------------------------------------------------------ #
    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def add_request(self, req: Request) -> bool:
        """Prefill `req` into a free slot; False if engine is full."""
        free = self._free_slots()
        if not free:
            return False
        slot = free[0]
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        # single-row prefill into a fresh single-row cache, then splice
        row_cache = M.init_cache(self.arch, 1, self.max_len,
                                 jax.tree_util.tree_leaves(
                                     self.cache)[0].dtype)
        logits, row_cache, _ = self._prefill1(self.params, toks, row_cache)
        self.cache = splice_slot(self.cache, row_cache, self._axes, slot)
        self.slot_req[slot] = req
        self.positions[slot] = len(req.prompt)
        first = self._sample(logits[0], req)
        req.output.append(int(first))
        return True

    def _sample(self, logits: jnp.ndarray, req: Request) -> int:
        if req.temperature <= 0.0:
            return int(jnp.argmax(logits))
        self.key, k = jax.random.split(self.key)
        return int(jax.random.categorical(k, logits / req.temperature))

    # ------------------------------------------------------------------ #
    def step(self):
        """One decode step for all active slots (continuous batching)."""
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        tokens = np.zeros(self.n_slots, np.int32)
        for i in active:
            tokens[i] = self.slot_req[i].output[-1]
        # all rows share one position scalar per step; slots may differ ->
        # decode at each distinct position group. The groups are
        # snapshotted before the loop: each active slot is decoded exactly
        # once per step at the position it held when the step began —
        # advancing a slot must not re-enter it into a later group, and a
        # slot freed by a mid-step finish must not be dereferenced by one.
        groups: Dict[int, List[int]] = {}
        for i in active:
            groups.setdefault(int(self.positions[i]), []).append(i)
        for pos in sorted(groups):
            group = groups[pos]
            logits, new_cache = self._decode(
                self.params, jnp.asarray(tokens), pos, self.cache)
            # only splice back rows belonging to this position group
            self.cache = splice_rows(self.cache, new_cache, self._axes,
                                     np.asarray(group))
            for i in group:
                req = self.slot_req[i]
                tok = self._sample(logits[i], req)
                req.output.append(tok)
                self.positions[i] += 1
                if (len(req.output) >= req.max_new_tokens
                        or self.positions[i] >= self.max_len - 1):
                    req.done = True
                    self.slot_req[i] = None

    def run(self, requests: List[Request], max_steps: int = 512
            ) -> List[Request]:
        """Serve a request list with continuous batching.

        Runs until every request completes or ``max_steps`` decode steps
        have elapsed, admitting pending requests into free slots between
        steps. Budget exhaustion is surfaced rather than silent:
        requests still in flight (or never admitted) come back with
        ``done=False``, ``self.last_run_exhausted`` is set, and a
        ``RuntimeWarning`` is emitted.
        """
        pending = list(requests)
        steps = 0
        while ((pending or any(r is not None for r in self.slot_req))
               and steps < max_steps):
            while pending and self._free_slots():
                self.add_request(pending.pop(0))
            self.step()
            steps += 1
        self.last_run_exhausted = not all(r.done for r in requests)
        if self.last_run_exhausted:
            warnings.warn(
                f"ServingEngine.run: max_steps={max_steps} exhausted with "
                f"{sum(not r.done for r in requests)} request(s) unfinished",
                RuntimeWarning, stacklevel=2)
        return requests
