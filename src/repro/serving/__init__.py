"""Serving: KV-cache engine with continuous batching (serving/engine.py)."""
