"""Training substrate: optimizers, trainer, checkpointing, compression."""
