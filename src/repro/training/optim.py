"""Pure-JAX optimizers over pytrees (no optax dependency offline).

Used by both the PPO trainer (paper Table 5: Adam, lr 3e-4) and the LM
training substrate (AdamW + cosine schedule + global-norm clipping).
Functional API mirroring optax: ``init(params) -> state``,
``update(grads, state, params) -> (updates, state)``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: PyTree
    nu: PyTree


@dataclasses.dataclass(frozen=True)
class Adam:
    """Adam/AdamW. ``weight_decay > 0`` gives decoupled AdamW."""

    learning_rate: float | Callable[[jnp.ndarray], jnp.ndarray] = 3e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    max_grad_norm: Optional[float] = None   # global-norm clip before update

    def init(self, params: PyTree) -> AdamState:
        zeros = lambda p: jax.tree_util.tree_map(
            lambda x: jnp.zeros_like(x, dtype=jnp.float32), p)
        return AdamState(step=jnp.zeros((), jnp.int32),
                         mu=zeros(params), nu=zeros(params))

    def _lr(self, step):
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return jnp.float32(self.learning_rate)

    def update(self, grads: PyTree, state: AdamState,
               params: Optional[PyTree] = None):
        if self.max_grad_norm is not None:
            grads = clip_by_global_norm(grads, self.max_grad_norm)
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        t = step.astype(jnp.float32)
        mu_hat_scale = 1.0 / (1 - b1 ** t)
        nu_hat_scale = 1.0 / (1 - b2 ** t)
        lr = self._lr(step)

        def upd(m, v, p):
            u = -(lr * m * mu_hat_scale
                  / (jnp.sqrt(v * nu_hat_scale) + self.eps))
            if self.weight_decay > 0.0 and p is not None:
                u = u - lr * self.weight_decay * p.astype(jnp.float32)
            return u

        if params is None:
            updates = jax.tree_util.tree_map(
                lambda m, v: upd(m, v, None), mu, nu)
        else:
            updates = jax.tree_util.tree_map(upd, mu, nu, params)
        return updates, AdamState(step=step, mu=mu, nu=nu)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)), params, updates)


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda x: x * scale, tree)


def cosine_schedule(base_lr: float, warmup_steps: int, total_steps: int,
                    min_frac: float = 0.1) -> Callable:
    """Linear warmup then cosine decay to ``min_frac * base_lr``."""
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac)
                         * 0.5 * (1.0 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr
