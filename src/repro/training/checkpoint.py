"""Fault-tolerant checkpointing with elastic resharding (pure JAX + numpy).

Design (what a 1000-node deployment needs, implemented host-side):

  - **atomic writes**: checkpoints are staged to ``step_XXXX.tmp`` and
    os.rename'd into place — a mid-write node failure never corrupts the
    latest checkpoint,
  - **keep-last-k** retention with a persistent ``MANIFEST.json`` (step,
    wall time, mesh shape, metric) so a restarted job can discover the
    newest *complete* checkpoint without coordination,
  - **elastic resharding**: arrays are saved *unsharded* (gathered leaves
    via ``jax.device_get``) with their logical-axis annotations; on
    restore the loader re-places every leaf under the *current* mesh's
    NamedSharding — a job restarted on a different pod count resumes
    without format changes,
  - **self-describing layout**: one ``.npz`` per checkpoint + a pytree
    structure JSON (paths/dtypes/shapes), so tooling can inspect
    checkpoints offline.

On a real multi-host pod, per-host shard saving (process_index subsets)
drops in behind the same API; the single-controller container exercises
the full logic minus the host fan-out (documented in DESIGN.md).
"""

from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def _tree_def(tree: PyTree):
    return jax.tree_util.tree_structure(tree)


class CheckpointManager:
    """Atomic, keep-last-k checkpoint store for (params, opt_state, extra)."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self.manifest_path = os.path.join(directory, "MANIFEST.json")

    # -- manifest ---------------------------------------------------------
    def _read_manifest(self) -> List[dict]:
        if not os.path.exists(self.manifest_path):
            return []
        with open(self.manifest_path) as f:
            return json.load(f)

    def _write_manifest(self, entries: List[dict]):
        tmp = self.manifest_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(entries, f, indent=2)
        os.replace(tmp, self.manifest_path)

    def latest_step(self) -> Optional[int]:
        entries = self._read_manifest()
        return entries[-1]["step"] if entries else None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state: PyTree,
             metadata: Optional[dict] = None) -> str:
        name = f"step_{step:010d}"
        final = os.path.join(self.dir, name + ".npz")
        tmp = final + ".tmp.npz"

        flat = _flatten(state)
        np.savez(tmp.removesuffix(".npz"), **flat)
        staged = tmp  # np.savez appends .npz to the basename we passed
        if not os.path.exists(staged):
            staged = tmp.removesuffix(".npz") + ".npz"
        os.replace(staged, final)                        # atomic publish

        entries = self._read_manifest()
        entries.append({
            "step": step,
            "file": os.path.basename(final),
            "time": time.time(),
            "n_arrays": len(flat),
            "bytes": sum(v.nbytes for v in flat.values()),
            "metadata": metadata or {},
        })
        entries.sort(key=lambda e: e["step"])
        # retention
        while len(entries) > self.keep:
            victim = entries.pop(0)
            path = os.path.join(self.dir, victim["file"])
            if os.path.exists(path):
                os.remove(path)
        self._write_manifest(entries)
        return final

    # -- restore --------------------------------------------------------------
    def restore(self, like: PyTree, step: Optional[int] = None,
                shardings: Optional[PyTree] = None) -> Tuple[PyTree, int]:
        """Rebuild ``like``-structured state. ``shardings`` (optional, a
        pytree-prefix of NamedShardings) re-places leaves on the current
        mesh — this is the elastic-resharding path."""
        entries = self._read_manifest()
        if not entries:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        if step is None:
            entry = entries[-1]
        else:
            matches = [e for e in entries if e["step"] == step]
            if not matches:
                raise FileNotFoundError(f"step {step} not found")
            entry = matches[0]

        data = np.load(os.path.join(self.dir, entry["file"]))
        flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path, leaf in flat_like:
            key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                            for p in path)
            arr = data[key]
            assert arr.shape == tuple(leaf.shape), (key, arr.shape,
                                                    leaf.shape)
            leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
        state = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            state = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return state, entry["step"]

    def verify(self, step: Optional[int] = None) -> bool:
        """Integrity check: every manifest array present and loadable."""
        entries = self._read_manifest()
        if not entries:
            return False
        entry = entries[-1] if step is None else \
            next(e for e in entries if e["step"] == step)
        path = os.path.join(self.dir, entry["file"])
        if not os.path.exists(path):
            return False
        data = np.load(path)
        return len(data.files) == entry["n_arrays"]
