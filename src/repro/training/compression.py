"""Gradient compression for data-parallel all-reduce (distributed trick).

Two schemes, both with **error feedback** (the compression residual is
added back into the next step's gradient, preserving convergence):

  - int8 stochastic-rounding quantization: 4x wire reduction on the DP
    all-reduce; per-leaf scale = max|g| (robust, one extra scalar),
  - top-k sparsification: keep the k largest-|g| entries per leaf
    (magnitude compression for very-low-bandwidth cross-pod links).

The ``compressed_psum`` helper composes with shard_map: quantize ->
all-reduce in low precision -> dequantize, with the residual state
threaded through the train step (see training/trainer.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    scheme: str = "none"          # none | int8 | topk
    topk_frac: float = 0.01       # fraction kept by topk
    seed: int = 0


def init_error_state(params: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


# ---------------------------------------------------------------------------
# int8 quantization
# ---------------------------------------------------------------------------

def quantize_int8(g: jnp.ndarray, key) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    scaled = g / scale
    noise = jax.random.uniform(key, g.shape, minval=-0.5, maxval=0.5)
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# top-k sparsification
# ---------------------------------------------------------------------------

def topk_mask(g: jnp.ndarray, frac: float) -> jnp.ndarray:
    flat = jnp.abs(g.reshape(-1))
    k = max(int(flat.size * frac), 1)
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(g) >= thresh).astype(g.dtype)


# ---------------------------------------------------------------------------
# composed compressed all-reduce
# ---------------------------------------------------------------------------

def compress_grads(grads: PyTree, error: PyTree, cfg: CompressionConfig,
                   key) -> Tuple[PyTree, PyTree]:
    """Returns (compressed grads ready for psum, new error state).

    The compressed representation stays a float pytree (dequantized
    locally) so the caller's psum is unchanged; on a real pod the int8
    payload is what crosses the wire (XLA all-reduce in s8) — the numerics
    here are bit-identical to that path.
    """
    if cfg.scheme == "none":
        return grads, error

    leaves, treedef = jax.tree_util.tree_flatten(grads)
    err_leaves = jax.tree_util.tree_leaves(error)
    keys = jax.random.split(key, len(leaves))
    new_g, new_e = [], []
    for g, e, k in zip(leaves, err_leaves, keys):
        g = g.astype(jnp.float32) + e                   # error feedback
        if cfg.scheme == "int8":
            q, scale = quantize_int8(g, k)
            deq = dequantize_int8(q, scale)
        elif cfg.scheme == "topk":
            mask = topk_mask(g, cfg.topk_frac)
            deq = g * mask
        else:
            raise ValueError(cfg.scheme)
        new_g.append(deq.astype(leaves[0].dtype))
        new_e.append(g - deq)                           # residual
    return (jax.tree_util.tree_unflatten(treedef, new_g),
            jax.tree_util.tree_unflatten(treedef, new_e))


def compression_ratio(cfg: CompressionConfig) -> float:
    """Wire-bytes ratio vs fp32 all-reduce (for the roofline's collective
    term — EXPERIMENTS.md §Perf uses this for the cross-pod axis)."""
    if cfg.scheme == "int8":
        return 0.25
    if cfg.scheme == "topk":
        return cfg.topk_frac * 2.0      # value + index
    return 1.0
