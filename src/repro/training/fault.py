"""Fault-tolerance harness: failure injection, auto-restart, elasticity.

What a 1000-node fleet needs from the *framework* side (the cluster
scheduler handles machine replacement):

  - ``run_with_restarts``: drives the training loop; on any step failure,
    reloads the newest complete checkpoint and replays from there
    (deterministic data pipeline => bit-identical recovery modulo the
    failed steps),
  - ``FailureInjector``: deterministic fault schedule for tests
    (raise at step k / corrupt a checkpoint / delay a step to trip the
    straggler watchdog),
  - ``elastic_remesh``: re-places a checkpointed state onto a *different*
    mesh (fewer/more pods) using the logical sharding rules — the elastic
    scaling path (tests restore a 4-device state onto 2 devices).
"""

from __future__ import annotations

import time
from typing import Callable, Optional

import jax

from repro.parallel import sharding as shd
from repro.training import trainer as T
from repro.training.checkpoint import CheckpointManager


class FailureInjector:
    """Deterministic failure schedule: {step: kind} with kinds
    'crash' (raise RuntimeError) and 'stall' (sleep seconds)."""

    def __init__(self, schedule: Optional[dict] = None,
                 stall_seconds: float = 0.0):
        self.schedule = dict(schedule or {})
        self.stall_seconds = stall_seconds
        self.log = []

    def check(self, step: int):
        kind = self.schedule.pop(step, None)
        if kind == "crash":
            self.log.append(("crash", step))
            raise RuntimeError(f"injected node failure at step {step}")
        if kind == "stall":
            self.log.append(("stall", step))
            time.sleep(self.stall_seconds)


def run_with_restarts(arch, cfg: T.TrainConfig, make_data_iter: Callable,
                      ckpt_dir: str, total_steps: int,
                      injector: Optional[FailureInjector] = None,
                      max_restarts: int = 5, key=None,
                      verbose: bool = False):
    """Train to ``total_steps`` surviving injected failures via resume."""
    key = key if key is not None else jax.random.PRNGKey(0)
    mgr = CheckpointManager(ckpt_dir, cfg.keep_checkpoints)
    restarts = 0
    history = []

    while True:
        state = T.init_state(arch, cfg, key)
        start = 0
        if mgr.latest_step() is not None and mgr.verify():
            state, start = mgr.restore(state)
        if start >= total_steps:
            return state, history, restarts

        step_fn = jax.jit(T.make_train_step(arch, cfg))
        data_iter = make_data_iter(start)
        try:
            for i in range(start, total_steps):
                if injector is not None:
                    injector.check(i)
                batch = next(data_iter)
                state, metrics = step_fn(state, batch)
                history.append({k: float(v) for k, v in metrics.items()})
                if (i + 1) % cfg.checkpoint_every == 0 or i + 1 == total_steps:
                    mgr.save(i + 1, state,
                             metadata={"loss": history[-1]["loss"]})
            return state, history, restarts
        except RuntimeError as e:
            restarts += 1
            if verbose:
                print(f"[fault] {e} -> restart #{restarts}")
            if restarts > max_restarts:
                raise


def elastic_remesh(state, old_mesh, new_mesh,
                   rules: Optional[shd.ShardingRules] = None):
    """Re-place a train state from one mesh onto another (elastic scale).

    Checkpoints store unsharded arrays, so this is gather + re-place under
    the new mesh's logical rules; used when a job restarts with a
    different healthy-device count.
    """
    del old_mesh
    gathered = jax.tree_util.tree_map(
        lambda x: jax.device_get(x) if hasattr(x, "shape") else x, state)
    with shd.use_mesh(new_mesh, rules):
        shardings = T.state_shardings(new_mesh, gathered)

        def put(x, s):
            if x is None or not hasattr(x, "shape"):
                return x
            return jax.device_put(x, s)

        return jax.tree_util.tree_map(
            put, gathered, shardings,
            is_leaf=lambda x: x is None or hasattr(x, "shape"))
