"""LM trainer: pjit train step, microbatched grad accumulation, fault
tolerance, and the distributed-optimization tricks.

The train step is a single jit'd program over the active mesh:

    batch -> [microbatch scan: loss+grad (remat inside the model)]
          -> gradient compression (optional, error feedback)
          -> AdamW (+ global-norm clip, cosine schedule)

Fault tolerance is host-side (training/fault.py): checkpoint-every-k with
atomic publish, auto-resume from the newest complete checkpoint, and a
step watchdog that flags stragglers (on-pod: slow hosts; here: simulated
via injected delays in tests).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.parallel import sharding as shd
from repro.training import compression as comp
from repro.training.checkpoint import CheckpointManager
from repro.training.optim import Adam, apply_updates, cosine_schedule, global_norm

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0
    microbatches: int = 1            # grad accumulation factor
    compression: comp.CompressionConfig = comp.CompressionConfig()
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    param_dtype: Any = jnp.bfloat16


def make_optimizer(cfg: TrainConfig) -> Adam:
    return Adam(
        learning_rate=cosine_schedule(cfg.learning_rate, cfg.warmup_steps,
                                      cfg.total_steps),
        weight_decay=cfg.weight_decay,
        max_grad_norm=cfg.max_grad_norm)


# TrainState is a plain dict pytree: params / opt_state / step / error
TrainState = dict


def init_state(arch: ArchConfig, cfg: TrainConfig, key) -> TrainState:
    params = M.init_params(arch, key, dtype=cfg.param_dtype)
    opt = make_optimizer(cfg)
    state = dict(
        params=params,
        opt_state=opt.init(params),
        step=jnp.zeros((), jnp.int32),
        error=(comp.init_error_state(params)
               if cfg.compression.scheme != "none" else None),
    )
    return state


def make_train_step(arch: ArchConfig, cfg: TrainConfig
                    ) -> Callable[[TrainState, Dict], Tuple[TrainState, Dict]]:
    """Build the jit-able train step (call under shd.use_mesh for SPMD)."""
    opt = make_optimizer(cfg)

    def loss_fn(params, batch):
        return M.train_loss(params, arch, batch)

    def train_step(state: TrainState, batch: Dict):
        params = state["params"]

        if cfg.microbatches > 1:
            def split(x):
                b = x.shape[0]
                assert b % cfg.microbatches == 0, (b, cfg.microbatches)
                return x.reshape(cfg.microbatches, b // cfg.microbatches,
                                 *x.shape[1:])
            micro = jax.tree_util.tree_map(split, batch)

            def acc_fn(carry, mb):
                loss_sum, grad_sum = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                grad_sum = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), grad_sum, grads)
                return (loss_sum + loss, grad_sum), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_fn, (jnp.float32(0.0), zeros), micro)
            loss = loss / cfg.microbatches
            grads = jax.tree_util.tree_map(
                lambda g: g / cfg.microbatches, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        error = state["error"]
        if error is not None:
            key = jax.random.fold_in(jax.random.PRNGKey(
                cfg.compression.seed), state["step"])
            grads, error = comp.compress_grads(grads, error,
                                               cfg.compression, key)

        updates, opt_state = opt.update(grads, state["opt_state"], params)
        params = apply_updates(params, updates)
        new_state = dict(params=params, opt_state=opt_state,
                               step=state["step"] + 1, error=error)
        metrics = {"loss": loss, "grad_norm": global_norm(grads),
                   "step": state["step"] + 1}
        return new_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# sharding of params / batch for pjit
# ---------------------------------------------------------------------------

def _param_spec(path_str: str, leaf) -> P:
    """Logical placement rules by parameter path (see DESIGN.md)."""
    rules = shd.active_rules() or shd.SINGLE_POD_RULES
    mdl = rules.heads
    if leaf.ndim == 0:
        return P()
    if path_str.endswith("/b") or path_str.endswith("/bias"):
        return P(*([None] * leaf.ndim))      # biases: replicate
    # stacked-layer leading axis is never sharded; work on trailing dims
    if "embed" in path_str and "table" in path_str:
        return P(rules.vocab, None)
    if "lm_head" in path_str:
        return P(None, rules.vocab)
    if "router" in path_str:
        return P()
    if any(k in path_str for k in ("wi", "wg")) and leaf.ndim >= 2:
        if leaf.ndim == 4:   # MoE experts: (layers, E, d, ff)
            return P(None, rules.experts, None, None)
        dims = [None] * leaf.ndim
        dims[-1] = rules.ff
        return P(*dims)
    if "wo" in path_str and leaf.ndim >= 2:
        if leaf.ndim == 4:   # MoE experts: (layers, E, ff, d)
            return P(None, rules.experts, None, None)
        dims = [None] * leaf.ndim
        dims[-2] = rules.ff
        return P(*dims)
    if any(k in path_str for k in ("wq", "wukv")) and leaf.ndim >= 2:
        dims = [None] * leaf.ndim
        dims[-1] = mdl
        return P(*dims)
    if any(k in path_str for k in ("wk", "wv")) and leaf.ndim >= 2:
        return P(*([None] * leaf.ndim))      # few KV heads: replicate
    return P(*([None] * leaf.ndim))


def param_shardings(mesh: Mesh, params: PyTree) -> PyTree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        path_str = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                            for p in path)
        p = shd.best_effort_spec(mesh, _param_spec(path_str, leaf),
                                 leaf.shape)
        out.append(NamedSharding(mesh, p))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_shardings(mesh: Mesh, batch: PyTree) -> PyTree:
    rules = shd.active_rules() or shd.SINGLE_POD_RULES
    return jax.tree_util.tree_map(
        lambda x: NamedSharding(
            mesh, P(rules.batch, *([None] * (x.ndim - 1)))), batch)


def state_shardings(mesh: Mesh, state: TrainState) -> TrainState:
    from repro.training.optim import AdamState
    pshard = param_shardings(mesh, state["params"])
    opt = state["opt_state"]
    opt_shard = AdamState(step=NamedSharding(mesh, P()),
                          mu=param_shardings(mesh, opt.mu),
                          nu=param_shardings(mesh, opt.nu))
    return dict(
        params=pshard,
        opt_state=opt_shard,
        step=NamedSharding(mesh, P()),
        error=(param_shardings(mesh, state["error"])
               if state["error"] is not None else None),
    )


# ---------------------------------------------------------------------------
# the training loop (host side: checkpoints, resume, watchdog)
# ---------------------------------------------------------------------------

def train_loop(arch: ArchConfig, cfg: TrainConfig, data_iter,
               ckpt_dir: Optional[str] = None, n_steps: int = 10,
               key=None, log_every: int = 1,
               step_timeout_s: float = 300.0,
               verbose: bool = True):
    """Single-controller training loop with auto-resume + watchdog."""
    key = key if key is not None else jax.random.PRNGKey(0)
    state = init_state(arch, cfg, key)
    mgr = CheckpointManager(ckpt_dir, cfg.keep_checkpoints) if ckpt_dir \
        else None
    start = 0
    if mgr is not None and mgr.latest_step() is not None:
        state, start = mgr.restore(state)
        if verbose:
            print(f"[trainer] resumed from step {start}")

    step_fn = jax.jit(make_train_step(arch, cfg))
    history = []
    for i in range(start, start + n_steps):
        batch = next(data_iter)
        t0 = time.time()
        state, metrics = step_fn(state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        dt = time.time() - t0
        if dt > step_timeout_s:                 # straggler watchdog
            print(f"[trainer] WARNING step {i} took {dt:.1f}s "
                  f"(> {step_timeout_s}s) — straggler suspected")
        history.append(metrics)
        if verbose and (i % log_every == 0):
            print(f"[trainer] step {metrics['step']:5.0f} "
                  f"loss {metrics['loss']:.4f} "
                  f"gnorm {metrics['grad_norm']:.3f} ({dt:.2f}s)")
        if mgr is not None and (i + 1) % cfg.checkpoint_every == 0:
            mgr.save(i + 1, state, metadata={"loss": metrics["loss"]})
    if mgr is not None:
        mgr.save(start + n_steps, state,
                 metadata={"loss": history[-1]["loss"]})
    return state, history
