"""Design space of Chiplet-Gym (paper Table 1) and the action codec.

The 14 parameters and their value grids reproduce Table 1 exactly:

    Architecture type            2.5D, 5.5D mem-on-logic, 5.5D logic-on-logic
    No. of chiplets              1..128 step 1
    No. & location of HBMs       2^6 - 1 placements over {L,R,T,B,mid,3D}
    AI2AI interconnect 2.5D      CoWoS, EMIB
    AI2AI data rate 2.5D         1..20 Gbps step 1
    AI2AI link count 2.5D        50..5000 step 50
    AI2AI trace length 2.5D      1..10 mm step 1
    AI2AI interconnect 3D        SoIC, FOVEROS
    AI2AI data rate 3D           20..50 Gbps step 1
    AI2AI link count 3D          100..10000 step 100
    AI2HBM interconnect 2.5D     CoWoS, EMIB
    AI2HBM data rate 2.5D        1..20 Gbps step 1
    AI2HBM link count 2.5D       50..5000 step 50
    AI2HBM trace length 2.5D     1..10 mm step 1

Total |S| = prod(head sizes) ~= 2.4e17, matching the paper's ">2x10^17".

A design point is represented as a ``DesignPoint`` NamedTuple of int32
*indices* (not values) so PPO's MultiDiscrete heads map 1:1 onto fields.
``decode()`` turns indices into physical values; everything is jnp-friendly
and vmap-safe.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

# --- categorical encodings -------------------------------------------------

ARCH_2P5D = 0
ARCH_MEM_ON_LOGIC = 1
ARCH_LOGIC_ON_LOGIC = 2
ARCH_NAMES = ("2.5D", "5.5D-Memory-on-Logic", "5.5D-Logic-on-Logic")

IC_COWOS = 0
IC_EMIB = 1
IC_2P5D_NAMES = ("CoWoS", "EMIB")

IC_SOIC = 0
IC_FOVEROS = 1
IC_3D_NAMES = ("SoIC", "FOVEROS")

HBM_LOCATIONS = ("left", "right", "top", "bottom", "middle", "3D-stacked")
N_HBM_LOCATIONS = 6


class DesignPoint(NamedTuple):
    """Indices into each parameter grid (all int32, any batch shape)."""

    arch_type: jnp.ndarray        # 0..2
    n_chiplets: jnp.ndarray       # 0..127  -> 1..128
    hbm_mask: jnp.ndarray         # 0..62   -> bitmask 1..63
    ai_ic_2p5d: jnp.ndarray       # 0..1    -> CoWoS / EMIB
    ai_dr_2p5d: jnp.ndarray       # 0..19   -> 1..20 Gbps
    ai_links_2p5d: jnp.ndarray    # 0..99   -> 50..5000 step 50
    ai_trace_2p5d: jnp.ndarray    # 0..9    -> 1..10 mm
    ai_ic_3d: jnp.ndarray         # 0..1    -> SoIC / FOVEROS
    ai_dr_3d: jnp.ndarray         # 0..30   -> 20..50 Gbps
    ai_links_3d: jnp.ndarray      # 0..99   -> 100..10000 step 100
    hbm_ic_2p5d: jnp.ndarray      # 0..1    -> CoWoS / EMIB
    hbm_dr_2p5d: jnp.ndarray      # 0..19   -> 1..20 Gbps
    hbm_links_2p5d: jnp.ndarray   # 0..99   -> 50..5000 step 50
    hbm_trace_2p5d: jnp.ndarray   # 0..9    -> 1..10 mm


N_PARAMS = len(DesignPoint._fields)

# Number of discrete choices per head, in DesignPoint field order.
HEAD_SIZES = (3, 128, 63, 2, 20, 100, 10, 2, 31, 100, 2, 20, 100, 10)
TOTAL_LOGITS = sum(HEAD_SIZES)        # 591 (paper: 810 with an unstated
                                      # discretization; see DESIGN.md §8)
DESIGN_SPACE_SIZE = float(np.prod([float(h) for h in HEAD_SIZES]))

# Placement-mutation action heads (core/placement.py): relocate one chiplet
# slot to a 16x16 grid cell (swapping with any occupant) and re-anchor one
# HBM stack. Appended to HEAD_SIZES when EnvConfig.placement_actions is on.
PLACEMENT_HEAD_SIZES = (128, 256, 6, 256)   # slot, cell, hbm bit, hbm cell
EXT_HEAD_SIZES = HEAD_SIZES + PLACEMENT_HEAD_SIZES
N_EXT_PARAMS = len(EXT_HEAD_SIZES)

# Mapping-mutation action heads (core/mapping.py): reassign one footprint
# slot's pipeline stage and one layer group's tile index. Appended after
# the placement heads when EnvConfig.mapping_actions is on (the mapping
# layer requires the placement episode). Sizes mirror mapping.MAX_SLOTS /
# MAX_STAGES / N_LAYER_GROUPS / N_TILE (asserted in core/mapping.py).
MAPPING_HEAD_SIZES = (128, 4, 4, 8)          # slot, stage, group, tile
MAP_HEAD_SIZES = EXT_HEAD_SIZES + MAPPING_HEAD_SIZES
N_MAP_PARAMS = len(MAP_HEAD_SIZES)


class DesignValues(NamedTuple):
    """Physical values decoded from a DesignPoint (float32 throughout)."""

    arch_type: jnp.ndarray        # categorical, kept as int-valued float
    n_chiplets: jnp.ndarray       # 1..128
    hbm_mask: jnp.ndarray         # 1..63 bitmask
    ai_ic_2p5d: jnp.ndarray
    ai_dr_2p5d: jnp.ndarray       # Gbps
    ai_links_2p5d: jnp.ndarray
    ai_trace_2p5d: jnp.ndarray    # mm
    ai_ic_3d: jnp.ndarray
    ai_dr_3d: jnp.ndarray         # Gbps
    ai_links_3d: jnp.ndarray
    hbm_ic_2p5d: jnp.ndarray
    hbm_dr_2p5d: jnp.ndarray      # Gbps
    hbm_links_2p5d: jnp.ndarray
    hbm_trace_2p5d: jnp.ndarray   # mm


def clip_indices(dp: DesignPoint) -> DesignPoint:
    """Clamp every index into its legal range (SA proposals can overshoot)."""
    return DesignPoint(*[
        jnp.clip(jnp.asarray(v, jnp.int32), 0, h - 1)
        for v, h in zip(dp, HEAD_SIZES)
    ])


def decode(dp: DesignPoint) -> DesignValues:
    """Map grid indices to physical parameter values (Table 1)."""
    f = lambda x: jnp.asarray(x, jnp.float32)
    return DesignValues(
        arch_type=f(dp.arch_type),
        n_chiplets=f(dp.n_chiplets) + 1.0,
        hbm_mask=f(dp.hbm_mask) + 1.0,
        ai_ic_2p5d=f(dp.ai_ic_2p5d),
        ai_dr_2p5d=f(dp.ai_dr_2p5d) + 1.0,
        ai_links_2p5d=(f(dp.ai_links_2p5d) + 1.0) * 50.0,
        ai_trace_2p5d=f(dp.ai_trace_2p5d) + 1.0,
        ai_ic_3d=f(dp.ai_ic_3d),
        ai_dr_3d=f(dp.ai_dr_3d) + 20.0,
        ai_links_3d=(f(dp.ai_links_3d) + 1.0) * 100.0,
        hbm_ic_2p5d=f(dp.hbm_ic_2p5d),
        hbm_dr_2p5d=f(dp.hbm_dr_2p5d) + 1.0,
        hbm_links_2p5d=(f(dp.hbm_links_2p5d) + 1.0) * 50.0,
        hbm_trace_2p5d=f(dp.hbm_trace_2p5d) + 1.0,
    )


def from_flat(flat: jnp.ndarray) -> DesignPoint:
    """Build a DesignPoint from a (..., 14) int array of head indices."""
    parts = [flat[..., i] for i in range(N_PARAMS)]
    return clip_indices(DesignPoint(*parts))


def to_flat(dp: DesignPoint) -> jnp.ndarray:
    """Inverse of :func:`from_flat` — stack indices on the last axis."""
    return jnp.stack([jnp.asarray(v, jnp.int32) for v in dp], axis=-1)


def random_design(key, batch_shape=()) -> DesignPoint:
    """Uniform random design points (used by SA init and tests)."""
    import jax
    keys = jax.random.split(key, N_PARAMS)
    return DesignPoint(*[
        jax.random.randint(k, batch_shape, 0, h, dtype=jnp.int32)
        for k, h in zip(keys, HEAD_SIZES)
    ])


def hbm_count(hbm_mask: jnp.ndarray) -> jnp.ndarray:
    """Population count of the 6-bit HBM placement mask."""
    mask = jnp.asarray(hbm_mask, jnp.int32)
    bits = [(mask >> i) & 1 for i in range(N_HBM_LOCATIONS)]
    return sum(bits).astype(jnp.float32)


def describe(dp: DesignPoint) -> str:
    """Human-readable single design point (host-side, for reports)."""
    v = decode(dp)
    g = lambda x: np.asarray(x).item()
    mask = int(g(v.hbm_mask))
    locs = [n for i, n in enumerate(HBM_LOCATIONS) if mask >> i & 1]
    lines = [
        f"Architecture type       : {ARCH_NAMES[int(g(v.arch_type))]}",
        f"No. of chiplets         : {int(g(v.n_chiplets))}",
        f"No. & location of HBMs  : {len(locs)} @ {', '.join(locs)}",
        f"AI2AI interconnect 2.5D : {IC_2P5D_NAMES[int(g(v.ai_ic_2p5d))]}",
        f"AI2AI data rate 2.5D    : {g(v.ai_dr_2p5d):.0f} Gbps",
        f"AI2AI link count 2.5D   : {g(v.ai_links_2p5d):.0f}",
        f"AI2AI trace length 2.5D : {g(v.ai_trace_2p5d):.0f} mm",
        f"AI2AI interconnect 3D   : {IC_3D_NAMES[int(g(v.ai_ic_3d))]}",
        f"AI2AI data rate 3D      : {g(v.ai_dr_3d):.0f} Gbps",
        f"AI2AI link count 3D     : {g(v.ai_links_3d):.0f}",
        f"AI2HBM interconnect 2.5D: {IC_2P5D_NAMES[int(g(v.hbm_ic_2p5d))]}",
        f"AI2HBM data rate 2.5D   : {g(v.hbm_dr_2p5d):.0f} Gbps",
        f"AI2HBM link count 2.5D  : {g(v.hbm_links_2p5d):.0f}",
        f"AI2HBM trace length 2.5D: {g(v.hbm_trace_2p5d):.0f} mm",
    ]
    return "\n".join(lines)
