"""Analytical PPAC model for chiplet-based AI accelerators (paper §3).

Implements, in pure jnp (vmap/jit-safe, fully branchless):

  - throughput        Eqs. 1-5, 12-14   (systolic chiplets on a 2D NoP mesh)
  - energy            Eqs. 6-7, 15      (compute + interconnect + HBM device)
  - yield / die cost  Eqs. 8-9          (negative-binomial yield)
  - NoP latency       Eqs. 10-11        (hop model with placement, Fig. 4)
  - packaging cost    Eq. 16            (mu-regression per interconnect)
  - reward            Eq. 17            (r = alpha*T - beta*C - gamma*E)

Every design decision that the paper leaves implicit is documented in
DESIGN.md §5 and marked CAL (calibrated) below.

The model evaluates a *batch* of design points at once: every field of
``DesignPoint`` may carry an arbitrary (identical) batch shape.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hw_constants as hw
from repro.core import mapping as mpg
from repro.core import params as ps
from repro.core import placement as pm

_MAX_MESH_DIM = 16        # m, n <= 12 for P <= 128; 16 gives headroom
_TERA = 1e12
_GIGA = 1e9


# ---------------------------------------------------------------------------
# Geometry: near-square mesh factorization (precomputed lookup, §3.3.2
# "aspect ratio as close as possible to 1")
# ---------------------------------------------------------------------------

def _mesh_table(max_p: int = 129):
    ms, ns = np.zeros(max_p, np.int32), np.zeros(max_p, np.int32)
    ms[0], ns[0] = 1, 1
    for p in range(1, max_p):
        root = int(np.floor(np.sqrt(p)))
        m0 = max(1, int(round(np.sqrt(p))))
        best = 1
        for cand in range(root, 0, -1):
            if p % cand == 0:
                best = cand
                break
        # exact near-square factorization when one exists; otherwise a
        # partially-filled near-square grid (last row not full)
        if best >= m0 - 1 and (best >= 2 or p <= 2):
            ms[p], ns[p] = best, p // best
        else:
            ms[p], ns[p] = m0, int(np.ceil(p / m0))
    return jnp.asarray(ms), jnp.asarray(ns)


_MESH_M, _MESH_N = _mesh_table()


def mesh_dims(n_positions: jnp.ndarray):
    """(m, n) grid dims for `n_positions` footprint slots, aspect ~1."""
    p = jnp.clip(jnp.asarray(n_positions, jnp.int32), 1, 128)
    return _MESH_M[p].astype(jnp.float32), _MESH_N[p].astype(jnp.float32)


# ---------------------------------------------------------------------------
# HBM placement -> worst-case hop count (Fig. 4 / §3.3.2)
# ---------------------------------------------------------------------------

_GRID_I, _GRID_J = jnp.meshgrid(
    jnp.arange(_MAX_MESH_DIM, dtype=jnp.float32),
    jnp.arange(_MAX_MESH_DIM, dtype=jnp.float32),
    indexing="ij",
)


def hbm_worst_hops(m, n, hbm_mask, arch_type):
    """Legacy Fig.-4 worst-hop scan (kept as the regression oracle).

    The evaluate() path now derives hop counts from an explicit
    ``placement.Placement`` via the pairwise-traffic NoP model; under the
    canonical row-major placement that model reproduces this function
    exactly (asserted by tests/test_placement.py).

    max over AI chiplets of min over placed HBMs of mesh hop distance.

    Location semantics (paper Fig. 4): edge HBMs sit adjacent to the middle
    of their edge (1 hop to the nearest chiplet); 'middle' occupies the
    array center; '3D-stacked' stacks one HBM over the center chiplet
    (0-hop for that chiplet, vertical hop folded into 3D wire delay).
    For a pure-2.5D architecture the 3D bit degrades to 'middle' (CAL).
    """
    m = jnp.asarray(m, jnp.float32)[..., None, None]
    n = jnp.asarray(n, jnp.float32)[..., None, None]
    mask = jnp.asarray(hbm_mask, jnp.int32)[..., None, None]
    arch = jnp.asarray(arch_type, jnp.float32)[..., None, None]

    i, j = _GRID_I, _GRID_J                       # (16,16) broadcast grid
    valid = (i < m) & (j < n)
    mc, nc = (m - 1.0) / 2.0, (n - 1.0) / 2.0

    d_left = jnp.abs(i - mc) + (j + 1.0)
    d_right = jnp.abs(i - mc) + (n - j)
    d_top = (i + 1.0) + jnp.abs(j - nc)
    d_bottom = (m - i) + jnp.abs(j - nc)
    d_middle = jnp.maximum(jnp.abs(i - mc) + jnp.abs(j - nc), 1.0)
    d_stacked = jnp.abs(i - mc) + jnp.abs(j - nc)      # 0 under the stack

    # pure 2.5D cannot stack memory -> 3D bit behaves like 'middle'
    d_stacked = jnp.where(arch >= 1.0, d_stacked, d_middle)

    big = jnp.float32(1e9)
    dists = jnp.stack(
        [d_left, d_right, d_top, d_bottom, d_middle, d_stacked], axis=-1)
    bits = jnp.stack(
        [(mask >> b) & 1 for b in range(ps.N_HBM_LOCATIONS)],
        axis=-1).astype(jnp.float32)
    per_cell = jnp.min(jnp.where(bits > 0, dists, big), axis=-1)
    per_cell = jnp.where(valid, per_cell, -big)
    return jnp.max(per_cell, axis=(-2, -1))           # worst chiplet


# ---------------------------------------------------------------------------
# Yield & die cost (Eqs. 8-9)
# ---------------------------------------------------------------------------

def die_yield(area_mm2, defect_density_per_cm2, alpha=hw.YIELD_ALPHA):
    """Negative-binomial yield model, Eq. 8. d is per cm^2, A in mm^2."""
    d_mm2 = defect_density_per_cm2 / 100.0
    return (1.0 + d_mm2 * area_mm2 / alpha) ** (-alpha)


def die_cost_physical(area_mm2, cfg: hw.HWConfig):
    """Cost of one known-good die: wafer silicon / yield + KGD test."""
    y = die_yield(area_mm2, cfg.defect_density_per_cm2, cfg.yield_alpha)
    return cfg.wafer_price_per_mm2 * area_mm2 / y * (1.0 + hw.KGD_TEST_COST_FRAC)


def die_cost_taylor(area_mm2, cfg: hw.HWConfig):
    """Paper's KGD form: cost_KGD ~ A^(5/2) (§5.3.2, two-term Taylor).

    Normalized so a 26 mm^2 die costs the same as in the physical model;
    only *ratios* of this mode are meaningful (used to reproduce the
    paper's 76x/143x die-cost headline).
    """
    return cfg.wafer_price_per_mm2 * area_mm2 ** 2.5 / jnp.sqrt(26.0)


# ---------------------------------------------------------------------------
# Interconnect property lookup (Table 4), branchless by index
# ---------------------------------------------------------------------------

def _lerp_by_trace(lo, hi, trace_mm):
    """E_bit grows linearly with trace length over the Table-4 range."""
    t = (jnp.clip(trace_mm, 1.0, 10.0) - 1.0) / 9.0
    return lo + (hi - lo) * t


def e_bit_2p5d(ic_idx, trace_mm):
    lo = jnp.where(ic_idx < 0.5, hw.E_BIT_PJ_2P5D_MIN[0], hw.E_BIT_PJ_2P5D_MIN[1])
    hi = jnp.where(ic_idx < 0.5, hw.E_BIT_PJ_2P5D_MAX[0], hw.E_BIT_PJ_2P5D_MAX[1])
    return _lerp_by_trace(lo, hi, trace_mm)


def e_bit_3d(ic_idx):
    return jnp.where(ic_idx < 0.5, hw.E_BIT_PJ_3D[0], hw.E_BIT_PJ_3D[1])


# ---------------------------------------------------------------------------
# Workload descriptor (Eq. 2 terms)
# ---------------------------------------------------------------------------

class Workload(NamedTuple):
    """ops/task split (Eq. 2) + mapping efficiency + traffic shape.

    gemm_ops / nongemm_ops are MAC-equivalent operation counts per task
    (one inference / one token / one image — workload defines the task).
    ``hbm_bytes`` is the per-task DRAM traffic of the ideal mapping; it
    sets the fraction of operands that must come from HBM vs on-chip reuse.
    """

    gemm_ops: jnp.ndarray          # MACs per task (GEMM)
    nongemm_ops: jnp.ndarray       # MAC-equivalent non-GEMM ops per task
    hbm_bytes: jnp.ndarray         # DRAM bytes per task (weights+acts)
    mapping_eff: jnp.ndarray       # M_eff of Eq. 2 (U_AI_chip proxy)


GENERIC_WORKLOAD = Workload(
    gemm_ops=jnp.float32(1e9),
    nongemm_ops=jnp.float32(2e7),
    hbm_bytes=jnp.float32(25e6),
    mapping_eff=jnp.float32(0.85),
)


# ---------------------------------------------------------------------------
# Full metric bundle
# ---------------------------------------------------------------------------

class Metrics(NamedTuple):
    # geometry
    n_dies: jnp.ndarray
    n_positions: jnp.ndarray
    mesh_m: jnp.ndarray
    mesh_n: jnp.ndarray
    die_area_mm2: jnp.ndarray
    logic_area_mm2: jnp.ndarray        # per die
    pes_per_die: jnp.ndarray
    sram_mb_per_die: jnp.ndarray
    n_hbm: jnp.ndarray
    hbm_capacity_gb: jnp.ndarray
    # latency / bandwidth (pairwise-traffic NoP model)
    hops_ai_ai: jnp.ndarray            # worst over the spanned mesh region
    hops_hbm_ai: jnp.ndarray           # worst router -> nearest-HBM hops
    hops_ai_mean: jnp.ndarray          # traffic-weighted mean (occupied)
    hops_hbm_mean: jnp.ndarray         # mean chiplet -> nearest-HBM hops
    link_contention: jnp.ndarray       # operand-streams x hops per NoP link
    nop_congestion: jnp.ndarray        # bw factor vs canonical floorplan
    lat_ai_ai_ns: jnp.ndarray
    lat_hbm_ai_ns: jnp.ndarray
    cycles_per_op: jnp.ndarray
    bw_req_hbm_gbps: jnp.ndarray
    bw_act_hbm_gbps: jnp.ndarray
    bw_req_ai_gbps: jnp.ndarray
    bw_act_ai_gbps: jnp.ndarray
    u_sys: jnp.ndarray
    # throughput
    peak_tops: jnp.ndarray             # system peak (MACs/s /1e12)
    eff_tops: jnp.ndarray              # after U_chip, U_sys, cycles/op
    tasks_per_sec: jnp.ndarray
    # energy
    e_comm_pj_per_op: jnp.ndarray
    e_op_pj: jnp.ndarray
    energy_per_task_j: jnp.ndarray
    tasks_per_joule: jnp.ndarray
    # cost
    die_yield: jnp.ndarray
    die_cost: jnp.ndarray              # physical model, whole system
    die_cost_paper: jnp.ndarray        # paper's A^(5/2) form, whole system
    pkg_cost: jnp.ndarray
    total_cost: jnp.ndarray
    # reward terms (Eq. 17)
    reward_t: jnp.ndarray
    reward_c: jnp.ndarray
    reward_e: jnp.ndarray
    reward: jnp.ndarray


class RewardWeights(NamedTuple):
    alpha: jnp.ndarray = jnp.float32(1.0)
    beta: jnp.ndarray = jnp.float32(1.0)
    gamma: jnp.ndarray = jnp.float32(0.1)


def make_weights(alpha: float, beta: float, gamma: float) -> RewardWeights:
    return RewardWeights(alpha=jnp.float32(alpha), beta=jnp.float32(beta),
                         gamma=jnp.float32(gamma))


class TrafficTrace(NamedTuple):
    """A serving-load distribution: T steps of (QPS, workload mix, SLO).

    Produced by the parametric generators in :mod:`repro.core.traffic`
    (flat / diurnal / bursty / multi-tenant over the config fleet). A
    traced :class:`Scenario` pairs one of these with a workload whose
    leaves carry the matching leading ``(T,)`` axis (the per-step
    mix-weighted fleet workload); :func:`evaluate_trace` then vmaps the
    point model over T, so a 32-step trace compiles to ONE XLA program
    like any other batch dimension.

    ``dt`` are the step weights (sum to 1 for the generators); ``mix``
    records the per-step fleet composition for reports/tests (rows sum
    to 1) — the mixed workload itself already lives on the Scenario.
    The queueing proxy treats the serving engine as ``n_servers``
    decode slots (continuous batching advances every active slot per
    step, so a design's service rate splits evenly across slots — see
    ``serving/engine.py``). ``slo_weight`` prices each step's missed
    p99 SLO into the reward; ``idle_frac`` is the load-proportionality
    floor (fraction of power burned at zero utilization). With
    ``slo_weight == 0`` and ``idle_frac == 0`` every added term is an
    exact float no-op, which is what makes a length-1 flat trace
    bit-exact with the point-scenario path.
    """

    qps: jnp.ndarray                    # (T,) offered tasks/s
    dt: jnp.ndarray                     # (T,) step weights (sum 1)
    mix: jnp.ndarray                    # (T, F) fleet mix rows (sum 1)
    slo_latency_s: jnp.ndarray          # () p99 sojourn-time SLO
    slo_weight: jnp.ndarray = jnp.float32(0.0)   # reward per missed step
    idle_frac: jnp.ndarray = jnp.float32(0.0)    # energy floor at u -> 0
    n_servers: jnp.ndarray = jnp.float32(8.0)    # engine decode slots


class Scenario(NamedTuple):
    """One optimization scenario: what to run x how to trade off PPAC.

    A pure pytree of arrays, so a *batch* of scenarios (every leaf carrying
    a leading scenario axis) is a first-class traced argument: one compiled
    program can evaluate a (design x workload x reward-weight) grid, and
    ``sa.run`` / ``ppo.train`` vmap over it.

    ``trace`` is optionally a :class:`TrafficTrace`; when set, the
    workload leaves carry a leading ``(T,)`` axis and every consumer
    (:func:`evaluate_scenario`, :func:`scenario_reward`, the optimizer
    arms) scores the design against the whole trace. ``trace=None``
    keeps the pytree structure and every existing code path bit-exact.
    """

    workload: Workload = GENERIC_WORKLOAD
    weights: RewardWeights = RewardWeights()
    trace: TrafficTrace = None


def stack_scenarios(scenarios) -> Scenario:
    """Stack a sequence of scalar Scenarios into one batched Scenario."""
    import jax
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *scenarios)


def footprint_positions(v: ps.DesignValues) -> jnp.ndarray:
    """Number of interposer footprint slots (logic-on-logic stacks pair up)."""
    is_lol = (v.arch_type == ps.ARCH_LOGIC_ON_LOGIC).astype(jnp.float32)
    return jnp.where(is_lol > 0, jnp.ceil(v.n_chiplets / 2.0), v.n_chiplets)


NOP_FIDELITIES = ("auto", "fast", "full")


class EvalPrefix(NamedTuple):
    """Placement-independent intermediates of :func:`evaluate`.

    Everything up to (but excluding) the NoP tier dispatch: decoded
    design values, interposer geometry, compute/SRAM sizing, reuse
    factors, and the canonical mesh edge count. A pure pytree — built
    once per design by :func:`placement_ctx` and reused across every
    move evaluation of the placement SA, whose candidates differ only in
    their ``NoPStats``.
    """

    v: ps.DesignValues
    is_lol: jnp.ndarray
    uses_3d_mem: jnp.ndarray
    n_dies: jnp.ndarray
    n_positions: jnp.ndarray
    mesh_m: jnp.ndarray
    mesh_n: jnp.ndarray
    die_area: jnp.ndarray
    logic_area: jnp.ndarray
    pes_per_die: jnp.ndarray
    sram_mb: jnp.ndarray
    reuse: jnp.ndarray
    reuse_comm: jnp.ndarray
    n_hbm: jnp.ndarray
    n_hbm_2p5d: jnp.ndarray
    mesh_edges: jnp.ndarray


def _eval_prefix(dp: ps.DesignPoint, cfg: hw.HWConfig) -> EvalPrefix:
    """Decode + geometry + compute sizing (everything placement-free)."""
    v = ps.decode(dp)
    arch = v.arch_type
    is_lol = (arch == ps.ARCH_LOGIC_ON_LOGIC).astype(jnp.float32)   # pairs
    uses_3d_mem = ((jnp.asarray(v.hbm_mask, jnp.int32) >> 5) & 1).astype(
        jnp.float32) * (arch >= 1).astype(jnp.float32)

    # ---- geometry ---------------------------------------------------------
    n_dies = v.n_chiplets
    n_positions = footprint_positions(v)
    m, n = mesh_dims(n_positions)

    n_hbm = ps.hbm_count(v.hbm_mask)
    n_hbm_2p5d = n_hbm - uses_3d_mem          # the 3D-stacked one is free
    avail = (cfg.package_area_mm2
             - (m + n + 2.0) * hw.CHIPLET_SPACING_MM
             - n_hbm_2p5d * cfg.hbm_footprint_mm2)
    avail = jnp.maximum(avail, 1.0)
    die_area = jnp.minimum(avail / n_positions, cfg.max_chiplet_area_mm2)

    # logic area per die: TSV + keep-out for any 3D-stacked die (CAL:
    # 2 tiers x (1-0.24) = the paper's 1.52x logic density). TSV area is
    # capped at 8 % of the die for small dies (a 14 mm^2 die does not need
    # the full 2 mm^2 sized for signal+power of a near-reticle die).
    any_3d_on_die = jnp.maximum(is_lol, uses_3d_mem)
    tsv_area = jnp.minimum(cfg.tsv_area_mm2, 0.08 * die_area)
    logic_area = (die_area - any_3d_on_die * tsv_area)
    logic_area = jnp.maximum(logic_area, 0.1)
    logic_eff = 1.0 - is_lol * cfg.tsv_keepout_frac
    compute_area = logic_area * cfg.compute_area_frac * logic_eff
    sram_mb = logic_area * hw.SRAM_AREA_FRAC * logic_eff * hw.SRAM_MB_PER_MM2

    pes_per_die = compute_area * 1e6 / cfg.pe_area_um2
    reuse = jnp.sqrt(jnp.maximum(pes_per_die, 1.0))    # array-level reuse
    # DRAM-traffic amortization: cache-blocked GEMM arithmetic intensity is
    # bounded by on-chip SRAM capacity — tile dim ~ sqrt(S / 3 operands)
    # (CAL; this is why small chiplets demand relatively more HBM BW).
    # Paper-literal mode (comm_reuse_systolic=False) charges every MAC two
    # fresh operands through the fabric (Eq. 13 verbatim).
    dw_bytes = cfg.data_width_bits / 8.0
    reuse_mem = jnp.sqrt(jnp.maximum(sram_mb * 1e6 / (3.0 * dw_bytes), 1.0))
    reuse_comm = (reuse_mem if cfg.comm_reuse_systolic
                  else jnp.ones_like(reuse_mem))

    # contention is normalized per link of the canonical m x n fabric (the
    # NoP the design pays for), so sprawling a placement cannot mint links
    mesh_edges = m * (n - 1.0) + n * (m - 1.0)
    return EvalPrefix(
        v=v, is_lol=is_lol, uses_3d_mem=uses_3d_mem, n_dies=n_dies,
        n_positions=n_positions, mesh_m=m, mesh_n=n, die_area=die_area,
        logic_area=logic_area, pes_per_die=pes_per_die, sram_mb=sram_mb,
        reuse=reuse, reuse_comm=reuse_comm, n_hbm=n_hbm,
        n_hbm_2p5d=n_hbm_2p5d, mesh_edges=mesh_edges)


def _metrics_from_nop(pre: EvalPrefix, workload: Workload,
                      weights: RewardWeights, cfg: hw.HWConfig,
                      nop: pm.NoPStats, nop_canon: pm.NoPStats,
                      mapping: mpg.Mapping = None) -> Metrics:
    """NoP stats -> full PPAC metric bundle (Eqs. 10-17 suffix).

    The placement-dependent half of :func:`evaluate`: everything the NoP
    reduction feeds — latency, bandwidth/utilization, throughput, energy,
    package cost, reward. Shared verbatim between the tiered
    ``evaluate`` paths and the delta-evaluated placement SA
    (:func:`reward_from_nop`), so both score a placement identically.

    ``mapping`` (default None: the exact pre-mapping program, statically
    dispatched) additionally re-prices the dataflow-dependent channels:
    pipeline receivers cut the HBM bandwidth demand and HBM-side
    interconnect energy (3 of 4 operand streams arrive chiplet-to-
    chiplet) while raising the AI-fabric demand and forwarding energy;
    off-canonical tiles trade HBM traffic against utilization; and the
    pipeline-balance / tile factors scale ``U_chip``. Every factor is an
    exact 1.0 (or added 0.0) under ``mapping.canonical()``, so the
    canonical mapping is numerically identical to ``mapping=None``.
    """
    v = pre.v
    is_lol, uses_3d_mem = pre.is_lol, pre.uses_3d_mem
    n_dies, n_positions = pre.n_dies, pre.n_positions
    m, n = pre.mesh_m, pre.mesh_n
    die_area, n_hbm, n_hbm_2p5d = pre.die_area, pre.n_hbm, pre.n_hbm_2p5d
    reuse, reuse_comm, pes_per_die = pre.reuse, pre.reuse_comm, pre.pes_per_die
    logic_area, sram_mb = pre.logic_area, pre.sram_mb

    # ---- NoP latency (Eqs. 10-11, pairwise-traffic placement model) -------
    h_ai = nop.hops_ai_worst
    h_hbm = nop.hops_hbm_worst
    # delivered 2.5D link bandwidth scales with channel load relative to
    # the canonical floorplan (see HWConfig.nop_congestion_exp)
    congestion = ((nop_canon.link_contention + 1e-6)
                  / (nop.link_contention + 1e-6)) ** cfg.nop_congestion_exp
    congestion = jnp.clip(congestion, 0.1, 10.0)
    # per-bit interconnect energy is per *hop* in a mesh (every hop
    # re-drives the wire + router); the Table-4 E_bit figures correspond to
    # the canonical floorplan's traffic-weighted mean hop counts, so the
    # energy terms scale with the mean-hop ratio (exactly 1 at canonical).
    e_hop_hbm = jnp.clip((nop.hops_hbm_mean + 1e-6)
                         / (nop_canon.hops_hbm_mean + 1e-6), 0.1, 10.0)
    e_hop_ai = jnp.clip((nop.hops_ai_mean + 1e-6)
                        / (nop_canon.hops_ai_mean + 1e-6), 0.1, 10.0)
    wire_ai = cfg.wire_delay_ps_2p5d * v.ai_trace_2p5d / 1000.0     # ns/hop
    wire_hbm = cfg.wire_delay_ps_2p5d * v.hbm_trace_2p5d / 1000.0
    fixed = cfg.contention_delay_ns + cfg.serialization_delay_ns
    lat_ai = h_ai * (wire_ai + cfg.router_delay_ns) + fixed
    lat_hbm = h_hbm * (wire_hbm + cfg.router_delay_ns) + fixed
    lat_hbm = lat_hbm + uses_3d_mem * (cfg.wire_delay_ps_3d / 1000.0)
    # intra-pair 3D hop for logic-on-logic
    lat_3d = cfg.wire_delay_ps_3d / 1000.0 + cfg.serialization_delay_ns

    worst_lat = jnp.maximum(lat_ai, lat_hbm) + is_lol * lat_3d
    # Eq. 5: cycles/op = cycle_op* + amortized communication cycles (CAL:
    # the per-op share of the worst-case transfer latency; amortized over
    # reuse^e — e=2 spreads a tile transfer over the k x k systolic tile)
    cycles_per_op = 1.0 + worst_lat * cfg.freq_ghz / (
        reuse ** cfg.latency_amort_exp)

    # ---- bandwidth & utilization (Eqs. 12-14) -----------------------------
    ops_per_die = pes_per_die * cfg.freq_ghz * _GIGA / cycles_per_op  # MAC/s
    operand_gbps = (cfg.n_operands * cfg.data_width_bits
                    * ops_per_die / reuse_comm) / _GIGA
    bw_req_hbm = 4.0 * operand_gbps                    # Eq. 13 (src = HBM)
    bw_req_ai = 1.0 * operand_gbps                     # Eq. 13 (src = AI)
    if mapping is not None:
        ms = mpg.traffic_summary(mapping, n_positions)
        # receivers pull 1 of 4 streams from HBM; larger tiles amortize
        # more HBM traffic; forwarded streams land on the AI fabric
        bw_req_hbm = bw_req_hbm * (ms.pull_frac * ms.tile_hbm)
        bw_req_ai = bw_req_ai * (1.0 + 3.0 * ms.recv_frac)
    link_bw_hbm = v.hbm_dr_2p5d * v.hbm_links_2p5d * congestion
    if cfg.hbm_peak_cap:
        bw_act_hbm = jnp.minimum(link_bw_hbm,
                                 hw.HBM_BANDWIDTH_GBPS_PER_STACK)
    else:
        bw_act_hbm = link_bw_hbm
    bw_act_ai = v.ai_dr_2p5d * v.ai_links_2p5d * congestion
    bw_act_3d = v.ai_dr_3d * v.ai_links_3d

    u_hbm = jnp.minimum(1.0, bw_act_hbm / jnp.maximum(bw_req_hbm, 1e-6))
    u_ai = jnp.minimum(1.0, bw_act_ai / jnp.maximum(bw_req_ai, 1e-6))
    u_3d = jnp.minimum(1.0, bw_act_3d / jnp.maximum(bw_req_ai, 1e-6))
    u_sys = jnp.minimum(u_hbm, u_ai)
    u_sys = jnp.where(is_lol > 0, jnp.minimum(u_sys, u_3d), u_sys)

    # ---- throughput (Eqs. 3-4) --------------------------------------------
    u_chip = workload.mapping_eff
    if mapping is not None:
        # tile sweet-spot + pipeline-balance penalties on the mapping
        # efficiency (exactly 1.0 x 1.0 at canonical)
        u_chip = u_chip * (ms.tile_u * ms.balance)
    peak_tops = pes_per_die * n_dies * cfg.freq_ghz * _GIGA / _TERA
    eff_ops = ops_per_die * n_dies * u_sys * u_chip          # MAC/s, Eq. 3
    eff_tops = eff_ops / _TERA

    ops_per_task = workload.gemm_ops + workload.nongemm_ops
    tasks_per_sec = eff_ops / jnp.maximum(ops_per_task, 1.0)  # Eqs. 1-2

    # ---- energy (Eqs. 6-7, 15) --------------------------------------------
    e_link_hbm = e_bit_2p5d(v.hbm_ic_2p5d, v.hbm_trace_2p5d) * e_hop_hbm
    e_link_ai = e_bit_2p5d(v.ai_ic_2p5d, v.ai_trace_2p5d) * e_hop_ai
    e_link_3d = e_bit_3d(v.ai_ic_3d)
    bits_per_op_hbm = cfg.n_operands * cfg.data_width_bits / reuse_comm
    # half of the operand traffic is forwarded chiplet-to-chiplet (Fig. 5
    # dataflow: inputs broadcast through neighbours) (CAL)
    if mapping is None:
        bits_hbm_eff = bits_per_op_hbm
        bits_per_op_ai = 0.5 * bits_per_op_hbm
    else:
        # the streams a receiver no longer pulls from HBM traverse the
        # AI fabric instead (0.75 x recv_frac of the operand bits)
        bits_hbm_eff = bits_per_op_hbm * (ms.pull_frac * ms.tile_hbm)
        bits_per_op_ai = bits_per_op_hbm * (0.5 + 0.75 * ms.recv_frac)
    e_comm = (bits_hbm_eff * (e_link_hbm + cfg.e_bit_hbm_device_pj)
              + bits_per_op_ai * e_link_ai
              + is_lol * bits_per_op_ai * e_link_3d
              + uses_3d_mem * bits_hbm_eff * (e_link_3d - e_link_hbm))
    e_op_total = cfg.e_op_pj + e_comm                         # Eq. 7
    energy_per_task = ops_per_task * e_op_total * 1e-12 / u_chip
    tasks_per_joule = 1.0 / jnp.maximum(energy_per_task, 1e-30)

    # ---- cost (Eqs. 8-9, 16) ----------------------------------------------
    y_die = die_yield(die_area, cfg.defect_density_per_cm2, cfg.yield_alpha)
    die_cost = n_dies * die_cost_physical(die_area, cfg)
    die_cost_paper = n_dies * die_cost_taylor(die_area, cfg)

    # package link cost is charged for wiring the *spanned* mesh region
    # (== the canonical m x n mesh under the canonical placement); a
    # compacted placement of a partially-filled grid needs fewer link
    # lanes, a sprawled one pays for every extra edge it routes across
    l_2p5d_ai = v.ai_links_2p5d * nop.region_edges
    l_2p5d_hbm = v.hbm_links_2p5d * n_hbm_2p5d
    n_pairs = jnp.where(is_lol > 0, jnp.floor(n_dies / 2.0), 0.0)
    l_3d = v.ai_links_3d * n_pairs + v.ai_links_3d * uses_3d_mem

    mu0 = jnp.maximum(
        jnp.where(v.ai_ic_2p5d < 0.5, hw.PKG_MU0_PER_MM2[0], hw.PKG_MU0_PER_MM2[1]),
        jnp.where(v.hbm_ic_2p5d < 0.5, hw.PKG_MU0_PER_MM2[0], hw.PKG_MU0_PER_MM2[1]))
    mu2 = jnp.maximum(
        jnp.where(v.ai_ic_2p5d < 0.5, hw.PKG_MU2_FIXED[0], hw.PKG_MU2_FIXED[1]),
        jnp.where(v.hbm_ic_2p5d < 0.5, hw.PKG_MU2_FIXED[0], hw.PKG_MU2_FIXED[1]))
    mu1_ai = jnp.where(v.ai_ic_2p5d < 0.5,
                       hw.PKG_MU1_PER_LINK[0], hw.PKG_MU1_PER_LINK[1])
    mu1_hbm = jnp.where(v.hbm_ic_2p5d < 0.5,
                        hw.PKG_MU1_PER_LINK[0], hw.PKG_MU1_PER_LINK[1])
    mu1_3d = jnp.where(v.ai_ic_3d < 0.5,
                       hw.PKG_MU1_PER_LINK_3D[0], hw.PKG_MU1_PER_LINK_3D[1])
    fix_3d = jnp.where(v.ai_ic_3d < 0.5,
                       hw.PKG_3D_FIXED_PER_STACK[0], hw.PKG_3D_FIXED_PER_STACK[1])

    n_stacks = n_pairs + uses_3d_mem
    pkg_cost_raw = (mu0 * cfg.package_area_mm2
                    + mu1_ai * l_2p5d_ai + mu1_hbm * l_2p5d_hbm
                    + mu1_3d * l_3d + fix_3d * n_stacks + mu2)
    y_asm = cfg.bond_yield ** n_stacks
    pkg_cost = pkg_cost_raw / jnp.maximum(y_asm, 1e-3)

    total_cost = die_cost + pkg_cost

    # ---- reward (Eq. 17) ---------------------------------------------------
    r_t = eff_tops * cfg.reward_throughput_scale
    r_c = pkg_cost * cfg.reward_cost_scale / 10.0
    r_e = e_comm * cfg.reward_energy_scale
    reward = weights.alpha * r_t - weights.beta * r_c - weights.gamma * r_e

    return Metrics(
        n_dies=n_dies, n_positions=n_positions, mesh_m=m, mesh_n=n,
        die_area_mm2=die_area, logic_area_mm2=logic_area,
        pes_per_die=pes_per_die, sram_mb_per_die=sram_mb,
        n_hbm=n_hbm, hbm_capacity_gb=n_hbm * hw.HBM_CAPACITY_GB,
        hops_ai_ai=h_ai, hops_hbm_ai=h_hbm,
        hops_ai_mean=nop.hops_ai_mean, hops_hbm_mean=nop.hops_hbm_mean,
        link_contention=nop.link_contention, nop_congestion=congestion,
        lat_ai_ai_ns=lat_ai, lat_hbm_ai_ns=lat_hbm,
        cycles_per_op=cycles_per_op,
        bw_req_hbm_gbps=bw_req_hbm, bw_act_hbm_gbps=bw_act_hbm,
        bw_req_ai_gbps=bw_req_ai, bw_act_ai_gbps=bw_act_ai,
        u_sys=u_sys,
        peak_tops=peak_tops, eff_tops=eff_tops, tasks_per_sec=tasks_per_sec,
        e_comm_pj_per_op=e_comm, e_op_pj=e_op_total,
        energy_per_task_j=energy_per_task, tasks_per_joule=tasks_per_joule,
        die_yield=y_die, die_cost=die_cost, die_cost_paper=die_cost_paper,
        pkg_cost=pkg_cost, total_cost=total_cost,
        reward_t=r_t, reward_c=r_c, reward_e=r_e, reward=reward,
    )


# --- evaluation taps (surrogate training-data collection) ------------------
# Host-level (concrete) evaluate() calls can be observed by registered
# taps — the surrogate's EvalDataset (surrogate/dataset.py) fills itself
# from the optimizer arms' candidate streams this way. Calls made while
# tracing (inside jit/vmap/scan — the SA/GA/PPO hot loops) are skipped:
# a tap is a host-side side effect and would otherwise leak tracers.
_EVAL_TAPS: list = []


def register_eval_tap(tap) -> None:
    """Register ``tap(dp, workload, weights, metrics)`` on evaluate()."""
    if tap not in _EVAL_TAPS:
        _EVAL_TAPS.append(tap)


def unregister_eval_tap(tap) -> None:
    """Remove a previously registered eval tap (no-op if absent)."""
    if tap in _EVAL_TAPS:
        _EVAL_TAPS.remove(tap)


def _notify_eval_taps(dp, workload, weights, mtr) -> None:
    if not _EVAL_TAPS:
        return
    # compat.is_tracer: jax.core.Tracer is a deprecated access path on
    # newer jax — the shared shim resolves jax.Tracer with a fallback.
    from repro.parallel import compat
    if any(compat.is_tracer(x)
           for x in (mtr.reward, dp.arch_type, workload.gemm_ops,
                     weights.alpha)):
        return
    for tap in list(_EVAL_TAPS):
        tap(dp, workload, weights, mtr)


def evaluate(dp: ps.DesignPoint,
             workload: Workload = GENERIC_WORKLOAD,
             weights: RewardWeights = RewardWeights(),
             cfg: hw.HWConfig = hw.DEFAULT_HW,
             placement: pm.Placement = None,
             nop_fidelity: str = "auto",
             mapping: mpg.Mapping = None) -> Metrics:
    """Evaluate a (batch of) design point(s) -> full PPAC metrics.

    ``placement`` optionally places every chiplet slot / HBM stack on the
    16x16 interposer grid; ``None`` uses the canonical Fig.-4 floorplan
    (row-major chiplets, edge/middle HBM anchors), under which the
    pairwise-traffic NoP model reproduces the legacy worst-hop numbers
    exactly. The interposer geometry (die area, package cost) stays keyed
    to the design's m x n footprint; placement steers the NoP hop/traffic
    reduction.

    ``nop_fidelity`` statically selects the NoP evaluation tier:

      - ``'auto'`` (default): the closed-form **fast tier**
        (``placement.nop_stats_fast`` — one 256-cell scan, no per-slot
        pass, pre-PR-2 throughput) when ``placement`` is None, the full
        pairwise tier otherwise.
      - ``'fast'``: force the fast tier; rejects an explicit placement.
      - ``'full'``: force the full pairwise tier even for the canonical
        floorplan (materializes the canonical ``Placement``) — the two
        tiers agree on every NoP figure (tests/test_placement.py).

    With an explicit placement the canonical *baseline* pass (the
    congestion / per-hop-energy normalizer) always uses the fast tier.

    Cached/delta evaluation: only the **full** pairwise tier can be
    served from a ``placement.PlacementEvalCache`` — its ``stats`` field
    is bit-identical to this function's explicit-placement ``nop``, so
    ``reward_from_nop(placement_ctx(...), cache.stats)`` equals
    ``evaluate(..., placement=...).reward`` exactly. The **fast** tier
    is closed-form (no per-slot state exists to cache) and is itself the
    cached canonical baseline (``PlacementCtx.nop_canon``); ``'auto'``
    without a placement resolves to the fast tier and therefore cannot
    consume a cache either.
    """
    if nop_fidelity not in NOP_FIDELITIES:
        raise ValueError(f"nop_fidelity must be one of {NOP_FIDELITIES}, "
                         f"got {nop_fidelity!r}")
    if nop_fidelity == "fast" and placement is not None:
        raise ValueError(
            "nop_fidelity='fast' evaluates the canonical floorplan only; "
            "drop the explicit placement or use 'auto'/'full'")
    if nop_fidelity == "fast" and mapping is not None:
        raise ValueError(
            "nop_fidelity='fast' evaluates the canonical dataflow only; "
            "drop the explicit mapping or use 'auto'/'full'")
    pre = _eval_prefix(dp, cfg)
    v, m, n = pre.v, pre.mesh_m, pre.mesh_n
    if placement is None and nop_fidelity != "full" and mapping is None:
        # fast tier: closed-form canonical stats, no Placement materialized
        nop = pm.nop_stats_fast(m, n, pre.n_positions, v.hbm_mask,
                                v.arch_type, pre.mesh_edges)
        nop_canon = nop             # same object -> congestion exactly 1
    elif placement is None:
        placement = pm.canonical(m, n, v.hbm_mask, v.arch_type)
        nop = pm.nop_stats(placement, pre.n_positions, v.hbm_mask,
                           v.arch_type, pre.mesh_edges, mapping=mapping)
        if mapping is None:
            nop_canon = nop         # same object -> congestion exactly 1
        else:
            # congestion normalizer stays the *unmapped* canonical pass so
            # a traffic-reducing mapping is rewarded, not normalized away
            nop_canon = pm.nop_stats_fast(m, n, pre.n_positions, v.hbm_mask,
                                          v.arch_type, pre.mesh_edges)
    else:
        nop = pm.nop_stats(placement, pre.n_positions, v.hbm_mask,
                           v.arch_type, pre.mesh_edges, mapping=mapping)
        nop_canon = pm.nop_stats_fast(m, n, pre.n_positions, v.hbm_mask,
                                      v.arch_type, pre.mesh_edges)
    mtr = _metrics_from_nop(pre, workload, weights, cfg, nop, nop_canon,
                            mapping)
    _notify_eval_taps(dp, workload, weights, mtr)
    return mtr


class PlacementCtx(NamedTuple):
    """Placement-independent evaluation state for the delta-evaluated SA.

    Everything :func:`evaluate` computes that a placement move cannot
    change: the :class:`EvalPrefix`, the scenario (workload + weights),
    and the fast-tier canonical baseline the congestion / per-hop-energy
    channels normalize against. Built once per (design, scenario) by
    :func:`placement_ctx`; each SA step then costs one
    ``placement.nop_stats_delta`` + :func:`reward_from_nop` instead of a
    full ``evaluate``.
    """

    prefix: EvalPrefix
    workload: Workload
    weights: RewardWeights
    nop_canon: pm.NoPStats
    # optional TrafficTrace: when set, the workload leaves carry the
    # trace's (T,) axis and reward_from_nop scores the whole trace
    # (broadcasting — same elementwise program as evaluate_trace)
    trace: TrafficTrace = None
    # optional Mapping: the ctx-default dataflow the *_from_nop suffix
    # scores against (overridable per call — the mapping-SA hot path
    # passes candidates explicitly). The canonical baseline stays the
    # unmapped fast tier either way.
    mapping: mpg.Mapping = None


def placement_ctx(dp: ps.DesignPoint,
                  workload: Workload = GENERIC_WORKLOAD,
                  weights: RewardWeights = RewardWeights(),
                  cfg: hw.HWConfig = hw.DEFAULT_HW,
                  trace: TrafficTrace = None,
                  mapping: mpg.Mapping = None) -> PlacementCtx:
    """Precompute the placement-independent half of :func:`evaluate`."""
    pre = _eval_prefix(dp, cfg)
    nop_canon = pm.nop_stats_fast(pre.mesh_m, pre.mesh_n, pre.n_positions,
                                  pre.v.hbm_mask, pre.v.arch_type,
                                  pre.mesh_edges)
    return PlacementCtx(prefix=pre, workload=workload, weights=weights,
                        nop_canon=nop_canon, trace=trace, mapping=mapping)


# sentinel: "use the ctx's mapping" — distinct from an explicit None,
# which forces the unmapped suffix regardless of the ctx default
_USE_CTX_MAPPING = object()


def _resolve_ctx_mapping(ctx: PlacementCtx, mapping):
    return ctx.mapping if mapping is _USE_CTX_MAPPING else mapping


def metrics_from_nop(ctx: PlacementCtx, nop: pm.NoPStats,
                     cfg: hw.HWConfig,
                     mapping=_USE_CTX_MAPPING) -> Metrics:
    """Full metrics of cached/delta NoP stats under a precomputed ctx.

    ``cfg`` is deliberately required (no ``DEFAULT_HW`` fallback): it
    MUST be the HWConfig ``ctx`` was built with — a mismatch would
    silently score the suffix against the wrong calibration while the
    cached canonical baseline still reflects the right one. With
    ``nop = placement.nop_stats_cache(...).stats`` (or any chain of
    ``nop_stats_delta`` updates of it) this equals
    ``evaluate(dp, ..., placement=...)`` bit-for-bit.

    ``mapping`` defaults to the ctx's mapping; pass one explicitly to
    score a candidate dataflow (it must be the same mapping the ``nop``
    stats were computed under, exactly like the placement/cache
    contract). Pass ``None`` to force the unmapped suffix.
    """
    mapping = _resolve_ctx_mapping(ctx, mapping)
    return _metrics_from_nop(ctx.prefix, ctx.workload, ctx.weights, cfg,
                             nop, ctx.nop_canon, mapping)


def reward_from_nop(ctx: PlacementCtx, nop: pm.NoPStats,
                    cfg: hw.HWConfig,
                    mapping=_USE_CTX_MAPPING) -> jnp.ndarray:
    """Scalar objective of cached/delta NoP stats (the SA hot path).

    ``cfg`` must match the ctx (see :func:`metrics_from_nop`). Only the
    reward is consumed, so XLA dead-code-eliminates the unused metric
    branches (die cost, yield, ...) from the compiled SA step. With a
    traced ctx the per-step metrics broadcast over the workload's (T,)
    leaves and the trace-aggregated reward comes back — still one
    scalar, still delta-evaluable.
    """
    if ctx.trace is None:
        return metrics_from_nop(ctx, nop, cfg, mapping).reward
    return _trace_aggregate(metrics_from_nop(ctx, nop, cfg, mapping),
                            ctx.trace, ctx.weights).reward


def scenario_metrics_from_nop(ctx: PlacementCtx, nop: pm.NoPStats,
                              cfg: hw.HWConfig,
                              mapping=_USE_CTX_MAPPING) -> Metrics:
    """Like :func:`metrics_from_nop`, aggregated over the ctx's trace.

    For a trace-free ctx this IS :func:`metrics_from_nop` (bit-exact);
    for a traced ctx the per-step metrics are dt-weighted into one
    point-shaped bundle whose ``reward`` / ``energy_per_task_j`` carry
    the SLO penalty and load-proportional energy (see
    :func:`evaluate_trace`).
    """
    mtr = metrics_from_nop(ctx, nop, cfg, mapping)
    if ctx.trace is None:
        return mtr
    return _trace_aggregate(mtr, ctx.trace, ctx.weights).metrics


def reward_only(dp: ps.DesignPoint,
                workload: Workload = GENERIC_WORKLOAD,
                weights: RewardWeights = RewardWeights(),
                cfg: hw.HWConfig = hw.DEFAULT_HW,
                placement: pm.Placement = None,
                nop_fidelity: str = "auto",
                mapping: mpg.Mapping = None) -> jnp.ndarray:
    """Cheap scalar objective for the optimizers."""
    return evaluate(dp, workload, weights, cfg, placement,
                    nop_fidelity, mapping).reward


def evaluate_scenario(dp: ps.DesignPoint, scenario: Scenario = Scenario(),
                      cfg: hw.HWConfig = hw.DEFAULT_HW,
                      placement: pm.Placement = None,
                      nop_fidelity: str = "auto",
                      mapping: mpg.Mapping = None) -> Metrics:
    """`evaluate` keyed by a Scenario pytree (vmap over it for batches).

    A traced scenario (``scenario.trace is not None``) returns the
    trace-aggregated point-shaped :class:`Metrics` — same structure and
    shapes as the point path, so every downstream consumer (env
    observations, archive points, surrogate targets) is trace-aware for
    free. The dispatch is static (pytree structure), so trace-free
    callers compile the exact pre-trace program.
    """
    if scenario.trace is None:
        return evaluate(dp, scenario.workload, scenario.weights, cfg,
                        placement, nop_fidelity, mapping)
    return evaluate_trace(dp, scenario, cfg, placement, nop_fidelity,
                          mapping).metrics


def evaluate_scenarios(dp: ps.DesignPoint, scenarios: Scenario,
                       cfg: hw.HWConfig = hw.DEFAULT_HW,
                       paired: bool = None,
                       placements: pm.Placement = None,
                       nop_fidelity: str = "auto",
                       mappings: mpg.Mapping = None) -> Metrics:
    """Evaluate design point(s) under a *batch* of scenarios.

    ``scenarios`` carries a leading scenario axis S on every leaf. ``dp``
    is one of:
      - a single design -> Metrics (S, ...): the design under each scenario,
      - a design batch with leading axis exactly S -> Metrics (S, ...):
        design i paired with scenario i,
      - any other design batch shape B -> Metrics (S, *B, ...): the full
        cross product (every design under every scenario).
    A B == S batch defaults to *paired*; pass ``paired=False`` to force
    the cross product (or ``paired=True`` to assert pairing was intended).
    ``placements`` (optional, leading axis S, paired mode only) evaluates
    design i under scenario i with its own explicit placement;
    ``mappings`` pairs the same way for explicit dataflows.
    One compiled program for the whole (design x workload x weights) grid.
    """
    import jax
    n_scen = jnp.shape(scenarios.weights.alpha)[0]
    shape_paired = jnp.ndim(dp.arch_type) >= 1 and (
        jnp.shape(dp.arch_type)[0] == n_scen)
    if paired is None:
        paired = shape_paired
    elif paired and not shape_paired:
        raise ValueError(
            f"paired=True needs a design batch with leading axis "
            f"{n_scen}, got shape {jnp.shape(dp.arch_type)}")
    if (placements is not None or mappings is not None) and not paired:
        raise ValueError(
            "placements/mappings require paired design/scenario axes")
    in_axes = (0 if paired else None, 0,
               None if placements is None else 0,
               None if mappings is None else 0)
    return jax.vmap(
        lambda d, s, p, mp: evaluate_scenario(d, s, cfg, p, nop_fidelity, mp),
        in_axes=in_axes)(dp, scenarios, placements, mappings)


def reward_scenarios(dp: ps.DesignPoint, scenarios: Scenario,
                     cfg: hw.HWConfig = hw.DEFAULT_HW,
                     nop_fidelity: str = "auto") -> jnp.ndarray:
    """Scenario-batched scalar objective (leading axis = scenario)."""
    return evaluate_scenarios(dp, scenarios, cfg,
                              nop_fidelity=nop_fidelity).reward


def scenario_reward(dp: ps.DesignPoint, scenario: Scenario,
                    cfg: hw.HWConfig = hw.DEFAULT_HW,
                    placement: pm.Placement = None,
                    nop_fidelity: str = "auto",
                    mapping: mpg.Mapping = None) -> jnp.ndarray:
    """Scalar objective of one (possibly traced) Scenario.

    The optimizer arms' hot-path entry: identical to :func:`reward_only`
    for point scenarios (same program), the trace-aggregated reward of
    :func:`evaluate_trace` for traced ones. XLA dead-code-eliminates the
    metric channels the reward doesn't touch in both cases.
    """
    if scenario.trace is None:
        return evaluate(dp, scenario.workload, scenario.weights, cfg,
                        placement, nop_fidelity, mapping).reward
    return evaluate_trace(dp, scenario, cfg, placement, nop_fidelity,
                          mapping).reward


# ---------------------------------------------------------------------------
# Traffic traces: score a design against a serving load distribution
# ---------------------------------------------------------------------------
# Eq. 17 is affine in the workload's mapping_eff and blind to offered
# load, so a plain time-average over a trace collapses back to a point
# scenario. What actually distinguishes serving loads is (a) whether the
# design's service capacity absorbs each step's QPS within the tail SLO
# and (b) how much of its energy is load-proportional. evaluate_trace
# adds exactly those two channels on top of the per-step Eq.-17 reward:
#
#   reward = sum_t dt_t * ( r17_t
#                           - gamma * r_e_t * (f(u_t) - 1)   # idle energy
#                           - slo_weight * (1 - ok_t) )      # missed p99
#
# where f(u) = idle_frac/u + (1 - idle_frac) inflates energy/task at low
# utilization and ok_t is the p99-within-SLO indicator from an analytic
# M/D/c queueing proxy of serving/engine.py's slot scheduler. Both added
# terms are exact float no-ops when idle_frac == slo_weight == 0, so a
# length-1 flat trace is bitwise identical to the point path.

_RHO_MAX = 0.995          # clip utilization for the finite-wait formula
_OVERLOAD_PEN = 50.0      # extra p99 (in service times) per unit rho > 1
_LN100 = 4.60517019       # -ln(0.01): exponential waiting-tail p99 factor
_U_MIN = 1e-6             # utilization floor for the idle-energy ratio


class TraceMetrics(NamedTuple):
    """:func:`evaluate_trace` output: aggregated + per-step views.

    ``metrics`` is the dt-weighted point-shaped bundle (its ``reward``
    is the trace reward, its ``energy_per_task_j`` /
    ``tasks_per_joule`` include the load-proportionality inflation);
    ``per_step`` the raw (T,)-leaved point metrics. The queueing
    channels carry the leading (T,) axis (then any design batch dims).
    """

    metrics: Metrics                   # aggregated, point-shaped
    per_step: Metrics                  # raw Eq.-17 metrics, (T, ...) leaves
    rho: jnp.ndarray                   # (T, ...) offered utilization
    p99_latency_s: jnp.ndarray         # (T, ...) proxy p99 sojourn time
    slo_ok: jnp.ndarray                # (T, ...) 1.0 where p99 <= SLO
    slo_attainment: jnp.ndarray        # (...) dt-weighted fraction met
    reward_eq17: jnp.ndarray           # (...) dt-weighted plain Eq.-17
    reward: jnp.ndarray                # (...) == metrics.reward


def queueing_p99(tasks_per_sec: jnp.ndarray, qps: jnp.ndarray,
                 n_servers: jnp.ndarray):
    """Analytic M/D/c p99 sojourn-time proxy of the serving engine.

    The engine (serving/engine.py) is ``c = n_servers`` decode slots
    with continuous batching: every step advances all active slots, so
    at saturation the design completes ``tasks_per_sec`` tasks/s and
    each task occupies its slot for ``D = c / tasks_per_sec`` seconds —
    c parallel servers with deterministic service D and Poisson(qps)
    arrivals, i.e. M/D/c. Mean wait via Sakasegawa's M/M/c
    approximation halved for deterministic service, p99 from the
    exponential waiting-tail bound, plus a linear overload penalty for
    ``rho > 1`` (the clipped formula alone would saturate). CAL —
    calibrated against the discrete-event slot-scheduler simulator in
    traffic.py (tests/test_traffic.py keeps it in band).
    """
    mu = jnp.maximum(tasks_per_sec, 1e-9)
    c = n_servers
    d = c / mu                                       # service time
    rho = qps / mu
    rho_c = jnp.clip(rho, 0.0, _RHO_MAX)
    wq = 0.5 * (rho_c ** jnp.sqrt(2.0 * (c + 1.0))) / (1.0 - rho_c) * (d / c)
    p99 = d + _LN100 * wq + jnp.maximum(rho - 1.0, 0.0) * d * _OVERLOAD_PEN
    return rho, p99


def _tdim(v: jnp.ndarray, like: jnp.ndarray) -> jnp.ndarray:
    """Reshape a (T,) vector to broadcast against (T, ...) ``like``."""
    extra = max(jnp.ndim(like) - 1, 0)
    return jnp.reshape(v, jnp.shape(v) + (1,) * extra)


def _trace_aggregate(per_step: Metrics, trace: TrafficTrace,
                     weights: RewardWeights) -> TraceMetrics:
    """dt-weighted aggregation of (T,)-leaved point metrics over a trace.

    Works on both trace layouts: vmapped metrics (every leaf (T, ...),
    the :func:`evaluate_trace` path) and broadcast metrics (only
    workload-dependent leaves carry (T,), the delta/:func:`reward_from_nop`
    path) — ``dt`` broadcasts against either.
    """
    dt = trace.dt

    def wmean(x):
        return jnp.sum(_tdim(dt, x) * x, axis=0)

    rho, p99 = queueing_p99(per_step.tasks_per_sec,
                            _tdim(trace.qps, per_step.tasks_per_sec),
                            trace.n_servers)
    slo_ok = (p99 <= trace.slo_latency_s).astype(jnp.float32)
    slo_attainment = wmean(slo_ok)

    # load-proportional energy: f(u) = idle/u + (1 - idle); exactly 1.0
    # at idle_frac == 0 (and at full utilization), so the inflation is
    # an exact no-op for trace-free-equivalent configs
    u = jnp.clip(rho, _U_MIN, 1.0)
    f_load = trace.idle_frac / u + (1.0 - trace.idle_frac)
    reward_step = (per_step.reward
                   - weights.gamma * per_step.reward_e * (f_load - 1.0)
                   - trace.slo_weight * (1.0 - slo_ok))
    reward = wmean(reward_step)
    reward_eq17 = wmean(per_step.reward)

    agg = jax.tree_util.tree_map(wmean, per_step)
    agg = agg._replace(
        reward=reward,
        energy_per_task_j=wmean(per_step.energy_per_task_j * f_load),
        tasks_per_joule=wmean(per_step.tasks_per_joule / f_load))
    return TraceMetrics(metrics=agg, per_step=per_step, rho=rho,
                        p99_latency_s=p99, slo_ok=slo_ok,
                        slo_attainment=slo_attainment,
                        reward_eq17=reward_eq17, reward=reward)


def evaluate_trace(dp: ps.DesignPoint, scenario: Scenario,
                   cfg: hw.HWConfig = hw.DEFAULT_HW,
                   placement: pm.Placement = None,
                   nop_fidelity: str = "auto",
                   mapping: mpg.Mapping = None) -> TraceMetrics:
    """Score design point(s) against a traced scenario's full trace.

    vmaps :func:`evaluate` over the workload's leading (T,) axis — the
    trace is just another batch dimension, so a 32-step trace under
    ``jit`` is ONE compiled XLA program with no per-step Python
    dispatch, and ``dp`` may itself carry any batch shape (the T axis
    leads in ``per_step`` / queueing channels, batch dims follow).
    """
    if scenario.trace is None:
        raise ValueError("evaluate_trace needs scenario.trace; use "
                         "evaluate_scenario for point scenarios")
    per_step = jax.vmap(
        lambda w: evaluate(dp, w, scenario.weights, cfg, placement,
                           nop_fidelity, mapping))(scenario.workload)
    return _trace_aggregate(per_step, scenario.trace, scenario.weights)


def evaluate_trace_scenarios(dp: ps.DesignPoint, scenarios: Scenario,
                             cfg: hw.HWConfig = hw.DEFAULT_HW,
                             paired: bool = None,
                             placements: pm.Placement = None,
                             nop_fidelity: str = "auto",
                             mappings: mpg.Mapping = None) -> TraceMetrics:
    """Trace metrics under a *batch* of traced scenarios.

    The traced twin of :func:`evaluate_scenarios` (same pairing rules,
    same one-compiled-program property) returning the full
    :class:`TraceMetrics` — the suite uses it to read SLO attainment
    into the archive's objective space.
    """
    n_scen = jnp.shape(scenarios.weights.alpha)[0]
    shape_paired = jnp.ndim(dp.arch_type) >= 1 and (
        jnp.shape(dp.arch_type)[0] == n_scen)
    if paired is None:
        paired = shape_paired
    elif paired and not shape_paired:
        raise ValueError(
            f"paired=True needs a design batch with leading axis "
            f"{n_scen}, got shape {jnp.shape(dp.arch_type)}")
    if (placements is not None or mappings is not None) and not paired:
        raise ValueError(
            "placements/mappings require paired design/scenario axes")
    in_axes = (0 if paired else None, 0,
               None if placements is None else 0,
               None if mappings is None else 0)
    return jax.vmap(
        lambda d, s, p, mp: evaluate_trace(d, s, cfg, p, nop_fidelity, mp),
        in_axes=in_axes)(dp, scenarios, placements, mappings)
