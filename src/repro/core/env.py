"""Chiplet-Gym: the paper's OpenAI-Gym environment, as pure JAX functions.

The original wraps the analytical simulator in gym v0.26 with a
MultiDiscrete action space and a Box observation space (§5.2.1). Here the
environment is *functional* — ``reset`` and ``step`` are pure, jit/vmap
safe — so a pod can run millions of environment steps per second inside a
single XLA program.

Semantics follow the paper:
  - an action assigns values to *all 14 parameters* at once (the agent
    "selects values for each of the parameters in Table 1"),
  - the observation exposes the items listed in §4.1 (package-area budget,
    per-chiplet areas, AI2AI / AI2HBM latency, communication energy,
    packaging cost, throughput), padded to the 10-dim input of the paper's
    policy network with the episode step index and previous reward,
  - reward is Eq. 17,
  - episodes are ``episode_len`` steps (paper default 2, Fig. 7).

Beyond the paper: with ``EnvConfig(placement_actions=True)`` the action
gains the four ``params.PLACEMENT_HEAD_SIZES`` heads — a placement
mutation (relocate one chiplet slot, re-anchor one HBM stack) applied on
top of the canonical Fig.-4 floorplan of the design the action selects —
and the observation gains the pairwise-NoP diagnostics (mean HBM hops,
mean forwarding hops, link contention). The default (14-head) space is
bit-identical to the paper's environment.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import costmodel as cm
from repro.core import hw_constants as hw
from repro.core import params as ps
from repro.core import placement as pm
from repro.core import spaces

OBS_DIM = 10
OBS_DIM_PLACEMENT = 13   # + [hops_hbm_mean, hops_ai_mean, link_contention]


Scenario = cm.Scenario   # re-export: the traced (workload, weights) pytree


@dataclasses.dataclass(frozen=True)
class EnvConfig:
    """Static environment configuration.

    ``workload`` / ``weights`` remain here as *defaults* for backwards
    compatibility, but the traced path is the ``scenario`` argument of
    ``reset`` / ``step``: pass a ``Scenario`` (or a vmapped batch of them)
    to run many (workload x reward-weight) settings in one XLA program.
    """

    episode_len: int = 2
    weights: cm.RewardWeights = cm.RewardWeights()
    workload: cm.Workload = cm.GENERIC_WORKLOAD
    hw: hw.HWConfig = hw.DEFAULT_HW
    placement_actions: bool = False   # extend actions/obs with placement
    # NoP evaluation tier (costmodel.evaluate): 'auto' takes the closed-
    # form fast tier whenever a step carries no explicit placement
    # mutation; 'full' forces the pairwise tier everywhere.
    nop_fidelity: str = "auto"

    def scenario(self) -> cm.Scenario:
        return cm.Scenario(workload=self.workload, weights=self.weights)


def _resolve(scenario, cfg: EnvConfig) -> cm.Scenario:
    return cfg.scenario() if scenario is None else scenario


def head_sizes(cfg: EnvConfig) -> Tuple[int, ...]:
    """Action head sizes for this config (14 Table-1 heads, +4 placement)."""
    return ps.EXT_HEAD_SIZES if cfg.placement_actions else ps.HEAD_SIZES


def action_dim(cfg: EnvConfig) -> int:
    return len(head_sizes(cfg))


def obs_dim(cfg: EnvConfig) -> int:
    return OBS_DIM_PLACEMENT if cfg.placement_actions else OBS_DIM


class EnvState(NamedTuple):
    design: ps.DesignPoint      # current design point (indices)
    t: jnp.ndarray              # step within the episode (int32)
    prev_reward: jnp.ndarray    # float32
    key: jnp.ndarray            # PRNG key for reset randomization


action_space = spaces.MultiDiscrete(ps.HEAD_SIZES)
ext_action_space = action_space.concat(
    spaces.MultiDiscrete(ps.PLACEMENT_HEAD_SIZES))
# the placement-mutation heads alone (sample these to perturb a fixed
# design's floorplan without touching the Table-1 assignment)
placement_action_space = ext_action_space.subspace(ps.N_PARAMS,
                                                   ps.N_EXT_PARAMS)
observation_space = spaces.Box(-10.0, 10.0, (OBS_DIM,))
ext_observation_space = spaces.Box(-10.0, 10.0, (OBS_DIM_PLACEMENT,))


def _observe(metrics: cm.Metrics, t, prev_reward, cfg: EnvConfig):
    """Normalized observation; 10-dim, +3 NoP diagnostics when the
    placement extension is on (see module docstring)."""
    cols = [
        jnp.broadcast_to(jnp.float32(cfg.hw.package_area_mm2 / 1000.0),
                         jnp.shape(metrics.die_area_mm2)),
        jnp.broadcast_to(jnp.float32(cfg.hw.max_chiplet_area_mm2 / 400.0),
                         jnp.shape(metrics.die_area_mm2)),
        metrics.die_area_mm2 / 400.0,
        metrics.lat_ai_ai_ns / 100.0,
        metrics.lat_hbm_ai_ns / 100.0,
        metrics.e_comm_pj_per_op / 10.0,
        metrics.pkg_cost / 100.0,
        metrics.eff_tops / 1000.0,
        jnp.asarray(t, jnp.float32) / jnp.float32(cfg.episode_len),
        jnp.asarray(prev_reward, jnp.float32) / 200.0,
    ]
    if cfg.placement_actions:
        cols += [
            metrics.hops_hbm_mean / 8.0,
            metrics.hops_ai_mean / 8.0,
            metrics.link_contention / 50.0,
        ]
    return jnp.clip(jnp.stack(cols, axis=-1), -10.0, 10.0)


def _design_and_placement(action: jnp.ndarray, cfg: EnvConfig):
    """Split an action into (DesignPoint, Placement-or-None).

    Placement-extended actions mutate the canonical floorplan of the
    design they select: one chiplet relocation (with swap) + one HBM
    re-anchor. Unbatched for the extended path (the env vmaps).
    """
    design = ps.from_flat(action[..., : ps.N_PARAMS])
    if not cfg.placement_actions or action.shape[-1] == ps.N_PARAMS:
        return design, None
    if action.ndim > 1:
        raise ValueError(
            "placement-extended actions are single-design; vmap step() "
            f"over the batch instead (got action shape {action.shape})")
    v = ps.decode(design)
    n_pos = cm.footprint_positions(v)
    m, n = cm.mesh_dims(n_pos)
    base = pm.canonical(m, n, v.hbm_mask, v.arch_type)
    plc = pm.apply_action(base, action[..., ps.N_PARAMS:], n_pos)
    return design, plc


def reset(key, cfg: EnvConfig = EnvConfig(),
          scenario: cm.Scenario = None) -> Tuple[EnvState, jnp.ndarray]:
    """Start an episode from a uniformly random design point."""
    scenario = _resolve(scenario, cfg)
    k_design, k_state = jax.random.split(key)
    design = ps.random_design(k_design)
    metrics = cm.evaluate(design, scenario.workload, scenario.weights, cfg.hw,
                          nop_fidelity=cfg.nop_fidelity)
    zero = jnp.float32(0.0)
    state = EnvState(design=design, t=jnp.int32(0), prev_reward=zero,
                     key=k_state)
    return state, _observe(metrics, 0, zero, cfg)


def step(state: EnvState, action: jnp.ndarray,
         cfg: EnvConfig = EnvConfig(), scenario: cm.Scenario = None
         ) -> Tuple[EnvState, jnp.ndarray, jnp.ndarray, jnp.ndarray, cm.Metrics]:
    """Apply a full design-point assignment; returns (state', obs, r, done, metrics)."""
    scenario = _resolve(scenario, cfg)
    design, placement = _design_and_placement(action, cfg)
    # a placement mutation always needs the full pairwise tier; plain
    # design-only actions take whatever tier the config asks for
    fid = ("auto" if placement is not None and cfg.nop_fidelity == "fast"
           else cfg.nop_fidelity)
    metrics = cm.evaluate(design, scenario.workload, scenario.weights, cfg.hw,
                          placement, nop_fidelity=fid)
    reward = metrics.reward
    t_next = state.t + 1
    done = t_next >= cfg.episode_len
    obs = _observe(metrics, t_next, reward, cfg)
    new_state = EnvState(design=design, t=t_next, prev_reward=reward,
                         key=state.key)
    return new_state, obs, reward, done, metrics


def auto_reset_step(state: EnvState, action: jnp.ndarray,
                    cfg: EnvConfig = EnvConfig(),
                    scenario: cm.Scenario = None):
    """step() that re-seeds a fresh episode when done (for rollout scans)."""
    scenario = _resolve(scenario, cfg)
    new_state, obs, reward, done, metrics = step(state, action, cfg, scenario)
    k_next, k_reset = jax.random.split(new_state.key)
    reset_state, reset_obs = reset(k_reset, cfg, scenario)
    out_state = jax.tree_util.tree_map(
        lambda a, b: jnp.where(done, a, b),
        reset_state._replace(key=k_next), new_state)
    out_obs = jnp.where(done, reset_obs, obs)
    return out_state, out_obs, reward, done, metrics


class VecEnv:
    """Convenience wrapper: N independent environments via vmap."""

    def __init__(self, n_envs: int, cfg: EnvConfig = EnvConfig(),
                 scenario: cm.Scenario = None):
        self.n_envs = n_envs
        self.cfg = cfg
        scenario = _resolve(scenario, cfg)
        self._reset = jax.jit(jax.vmap(lambda k: reset(k, cfg, scenario)))
        self._step = jax.jit(
            jax.vmap(lambda s, a: auto_reset_step(s, a, cfg, scenario)))

    def reset(self, key):
        return self._reset(jax.random.split(key, self.n_envs))

    def step(self, states, actions):
        return self._step(states, actions)
