"""Chiplet-Gym: the paper's OpenAI-Gym environment, as pure JAX functions.

The original wraps the analytical simulator in gym v0.26 with a
MultiDiscrete action space and a Box observation space (§5.2.1). Here the
environment is *functional* — ``reset`` and ``step`` are pure, jit/vmap
safe — so a pod can run millions of environment steps per second inside a
single XLA program.

Semantics follow the paper:
  - an action assigns values to *all 14 parameters* at once (the agent
    "selects values for each of the parameters in Table 1"),
  - the observation exposes the items listed in §4.1 (package-area budget,
    per-chiplet areas, AI2AI / AI2HBM latency, communication energy,
    packaging cost, throughput), padded to the 10-dim input of the paper's
    policy network with the episode step index and previous reward,
  - reward is Eq. 17,
  - episodes are ``episode_len`` steps (paper default 2, Fig. 7).

Beyond the paper: with ``EnvConfig(placement_actions=True)`` the action
gains the four ``params.PLACEMENT_HEAD_SIZES`` heads — a placement
mutation (relocate one chiplet slot, re-anchor one HBM stack) applied on
top of the canonical Fig.-4 floorplan of the design the action selects —
and the observation gains the pairwise-NoP diagnostics (mean HBM hops,
mean forwarding hops, link contention). The default (14-head) space is
bit-identical to the paper's environment.

``EnvConfig(placement_episode=True)`` is the cache-carried mode: each
episode draws one random design at reset and the *whole episode* refines
its floorplan — actions are the four placement heads alone, and the
floorplan accumulates across steps instead of restarting from canonical.
A ``placement.PlacementEvalCache`` rides the env state, so each step
prices its move with ``nop_stats_delta(move_kinds='both')`` + the
placement-independent ``costmodel.placement_ctx`` prefix instead of a
full ``costmodel.evaluate`` — the delta-priced PPO rollout path
(``delta_eval=False`` keeps the scratch re-evaluation as the benchmark
baseline and test oracle; both paths agree on every Metrics field).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import costmodel as cm
from repro.core import hw_constants as hw
from repro.core import mapping as mpg
from repro.core import params as ps
from repro.core import placement as pm
from repro.core import spaces
from repro.telemetry import counters as tl

OBS_DIM = 10
OBS_DIM_PLACEMENT = 13   # + [hops_hbm_mean, hops_ai_mean, link_contention]
OBS_DIM_MAPPING = 16     # + [recv_frac, pipeline balance, tile_hbm]


Scenario = cm.Scenario   # re-export: the traced (workload, weights) pytree


@dataclasses.dataclass(frozen=True)
class EnvConfig:
    """Static environment configuration.

    ``workload`` / ``weights`` remain here as *defaults* for backwards
    compatibility, but the traced path is the ``scenario`` argument of
    ``reset`` / ``step``: pass a ``Scenario`` (or a vmapped batch of them)
    to run many (workload x reward-weight) settings in one XLA program.
    """

    episode_len: int = 2
    weights: cm.RewardWeights = cm.RewardWeights()
    workload: cm.Workload = cm.GENERIC_WORKLOAD
    hw: hw.HWConfig = hw.DEFAULT_HW
    placement_actions: bool = False   # extend actions/obs with placement
    # NoP evaluation tier (costmodel.evaluate): 'auto' takes the closed-
    # form fast tier whenever a step carries no explicit placement
    # mutation; 'full' forces the pairwise tier everywhere.
    nop_fidelity: str = "auto"
    # placement-episode mode (see module docstring): episodes refine the
    # floorplan of a per-episode random design; actions are the 4
    # placement heads, obs gains the NoP diagnostics, and the eval cache
    # rides EnvState. Mutually exclusive with placement_actions.
    placement_episode: bool = False
    # placement-episode step pricing: True carries the PlacementEvalCache
    # (delta NoP stats + prefix/suffix reward split); False re-evaluates
    # the mutated floorplan from scratch each step (bench/test oracle).
    delta_eval: bool = True
    # mapping co-exploration (core/mapping.py): the placement episode
    # additionally carries a Mapping, actions gain the four
    # params.MAPPING_HEAD_SIZES heads (reassign one slot's pipeline
    # stage + one layer group's tile), and the observation gains three
    # mapping diagnostics. Requires placement_episode. Default off —
    # the 4-head placement episode stays bit-identical.
    mapping_actions: bool = False
    # in-scan telemetry for the placement-episode path
    # (telemetry/counters.EnvCounters riding EnvState.tel): step /
    # episode / delta-vs-scratch eval counts and reward accumulators
    # that survive auto-resets. False (default) keeps EnvState.tel None
    # and statically compiles the exact pre-telemetry program; rewards,
    # observations and the key stream are untouched either way.
    telemetry: bool = False

    def scenario(self) -> cm.Scenario:
        return cm.Scenario(workload=self.workload, weights=self.weights)


def _resolve(scenario, cfg: EnvConfig) -> cm.Scenario:
    return cfg.scenario() if scenario is None else scenario


def head_sizes(cfg: EnvConfig) -> Tuple[int, ...]:
    """Action head sizes for this config (14 Table-1 heads, +4 placement;
    placement episodes use the 4 placement heads alone, +4 mapping heads
    with ``mapping_actions``)."""
    if cfg.mapping_actions and not cfg.placement_episode:
        raise ValueError("mapping_actions requires placement_episode")
    if cfg.placement_episode:
        if cfg.mapping_actions:
            return ps.PLACEMENT_HEAD_SIZES + ps.MAPPING_HEAD_SIZES
        return ps.PLACEMENT_HEAD_SIZES
    return ps.EXT_HEAD_SIZES if cfg.placement_actions else ps.HEAD_SIZES


def action_dim(cfg: EnvConfig) -> int:
    return len(head_sizes(cfg))


def obs_dim(cfg: EnvConfig) -> int:
    if cfg.mapping_actions and cfg.placement_episode:
        return OBS_DIM_MAPPING
    ext = cfg.placement_actions or cfg.placement_episode
    return OBS_DIM_PLACEMENT if ext else OBS_DIM


class EnvState(NamedTuple):
    design: ps.DesignPoint      # current design point (indices)
    t: jnp.ndarray              # step within the episode (int32)
    prev_reward: jnp.ndarray    # float32
    key: jnp.ndarray            # PRNG key for reset randomization
    # placement-episode mode only (None otherwise — the default pytree is
    # unchanged): the placement-independent eval prefix and the carried
    # floorplan + eval cache the delta step prices moves against.
    ctx: cm.PlacementCtx = None
    cache: pm.PlacementEvalCache = None
    # mapping-episode mode only (EnvConfig.mapping_actions): the carried
    # dataflow the next step mutates; starts canonical at reset.
    mapping: mpg.Mapping = None
    # placement-episode telemetry (EnvConfig.telemetry only): counters
    # that accumulate across auto-reset boundaries.
    tel: tl.EnvCounters = None


action_space = spaces.MultiDiscrete(ps.HEAD_SIZES)
ext_action_space = action_space.concat(
    spaces.MultiDiscrete(ps.PLACEMENT_HEAD_SIZES))
# the placement-mutation heads alone (sample these to perturb a fixed
# design's floorplan without touching the Table-1 assignment)
placement_action_space = ext_action_space.subspace(ps.N_PARAMS,
                                                   ps.N_EXT_PARAMS)
observation_space = spaces.Box(-10.0, 10.0, (OBS_DIM,))
ext_observation_space = spaces.Box(-10.0, 10.0, (OBS_DIM_PLACEMENT,))


def _observe(metrics: cm.Metrics, t, prev_reward, cfg: EnvConfig,
             msum: mpg.MappingSummary = None):
    """Normalized observation; 10-dim, +3 NoP diagnostics when the
    placement extension is on, +3 mapping diagnostics when
    ``mapping_actions`` is on (see module docstring)."""
    cols = [
        jnp.broadcast_to(jnp.float32(cfg.hw.package_area_mm2 / 1000.0),
                         jnp.shape(metrics.die_area_mm2)),
        jnp.broadcast_to(jnp.float32(cfg.hw.max_chiplet_area_mm2 / 400.0),
                         jnp.shape(metrics.die_area_mm2)),
        metrics.die_area_mm2 / 400.0,
        metrics.lat_ai_ai_ns / 100.0,
        metrics.lat_hbm_ai_ns / 100.0,
        metrics.e_comm_pj_per_op / 10.0,
        metrics.pkg_cost / 100.0,
        metrics.eff_tops / 1000.0,
        jnp.asarray(t, jnp.float32) / jnp.float32(cfg.episode_len),
        jnp.asarray(prev_reward, jnp.float32) / 200.0,
    ]
    if cfg.placement_actions or cfg.placement_episode:
        cols += [
            metrics.hops_hbm_mean / 8.0,
            metrics.hops_ai_mean / 8.0,
            metrics.link_contention / 50.0,
        ]
    if cfg.mapping_actions and cfg.placement_episode:
        like = jnp.shape(metrics.die_area_mm2)
        cols += [
            jnp.broadcast_to(msum.recv_frac, like),
            jnp.broadcast_to(msum.balance, like),
            jnp.broadcast_to(msum.tile_hbm / 2.0, like),
        ]
    return jnp.clip(jnp.stack(cols, axis=-1), -10.0, 10.0)


def _design_and_placement(action: jnp.ndarray, cfg: EnvConfig):
    """Split an action into (DesignPoint, Placement-or-None).

    Placement-extended actions mutate the canonical floorplan of the
    design they select: one chiplet relocation (with swap) + one HBM
    re-anchor. Unbatched for the extended path (the env vmaps).
    """
    design = ps.from_flat(action[..., : ps.N_PARAMS])
    if not cfg.placement_actions or action.shape[-1] == ps.N_PARAMS:
        return design, None
    if action.ndim > 1:
        raise ValueError(
            "placement-extended actions are single-design; vmap step() "
            f"over the batch instead (got action shape {action.shape})")
    v = ps.decode(design)
    n_pos = cm.footprint_positions(v)
    m, n = cm.mesh_dims(n_pos)
    base = pm.canonical(m, n, v.hbm_mask, v.arch_type)
    plc = pm.apply_action(base, action[..., ps.N_PARAMS:], n_pos)
    return design, plc


def reset(key, cfg: EnvConfig = EnvConfig(),
          scenario: cm.Scenario = None) -> Tuple[EnvState, jnp.ndarray]:
    """Start an episode from a uniformly random design point."""
    scenario = _resolve(scenario, cfg)
    k_design, k_state = jax.random.split(key)
    design = ps.random_design(k_design)
    if cfg.placement_episode:
        return _reset_placement(design, k_state, cfg, scenario)
    metrics = cm.evaluate_scenario(design, scenario, cfg.hw,
                                   nop_fidelity=cfg.nop_fidelity)
    zero = jnp.float32(0.0)
    state = EnvState(design=design, t=jnp.int32(0), prev_reward=zero,
                     key=k_state)
    return state, _observe(metrics, 0, zero, cfg)


def _reset_placement(design, k_state, cfg: EnvConfig, scenario):
    """Placement-episode reset: canonical floorplan + primed eval cache.

    Both pricing modes build the same cache (the scratch oracle needs
    the carried floorplan too), so reset observations are bit-equal and
    the differential test isolates the *step* pricing.
    """
    v = ps.decode(design)
    n_pos = cm.footprint_positions(v)
    m, n = cm.mesh_dims(n_pos)
    base = pm.canonical(m, n, v.hbm_mask, v.arch_type)
    ctx = cm.placement_ctx(design, scenario.workload, scenario.weights,
                           cfg.hw, trace=scenario.trace)
    cache = pm.nop_stats_cache(base, n_pos, v.hbm_mask, v.arch_type,
                               ctx.prefix.mesh_edges)
    metrics = cm.scenario_metrics_from_nop(ctx, cache.stats, cfg.hw)
    zero = jnp.float32(0.0)
    mapping = msum = None
    if cfg.mapping_actions:
        # episodes start from the paper's canonical dataflow — an exact
        # no-op, so the reset metrics/reward stay bit-equal to the
        # mapping-free placement episode
        mapping = mpg.canonical()
        msum = mpg.traffic_summary(mapping, n_pos)
    tel = tl.init_env() if cfg.telemetry else None
    state = EnvState(design=design, t=jnp.int32(0), prev_reward=zero,
                     key=k_state, ctx=ctx, cache=cache, mapping=mapping,
                     tel=tel)
    return state, _observe(metrics, 0, zero, cfg, msum)


def step(state: EnvState, action: jnp.ndarray,
         cfg: EnvConfig = EnvConfig(), scenario: cm.Scenario = None
         ) -> Tuple[EnvState, jnp.ndarray, jnp.ndarray, jnp.ndarray, cm.Metrics]:
    """Apply a full design-point assignment; returns (state', obs, r, done, metrics)."""
    scenario = _resolve(scenario, cfg)
    if cfg.placement_episode:
        return _step_placement(state, action, cfg, scenario)
    design, placement = _design_and_placement(action, cfg)
    # a placement mutation always needs the full pairwise tier; plain
    # design-only actions take whatever tier the config asks for
    fid = ("auto" if placement is not None and cfg.nop_fidelity == "fast"
           else cfg.nop_fidelity)
    metrics = cm.evaluate_scenario(design, scenario, cfg.hw, placement,
                                   nop_fidelity=fid)
    reward = metrics.reward
    t_next = state.t + 1
    done = t_next >= cfg.episode_len
    obs = _observe(metrics, t_next, reward, cfg)
    new_state = EnvState(design=design, t=t_next, prev_reward=reward,
                         key=state.key)
    return new_state, obs, reward, done, metrics


def _step_placement(state: EnvState, action: jnp.ndarray,
                    cfg: EnvConfig, scenario):
    """Placement-episode step: mutate the carried floorplan and price it.

    The 4-head action [slot, target_cell, hbm_idx, hbm_target_cell]
    relocates one chiplet slot AND re-anchors one HBM stack (either can
    be a no-op by targeting the current cell/anchor). With
    ``cfg.delta_eval`` the move is priced by one fused
    ``nop_stats_delta(move_kinds='both')`` against the carried cache —
    no full evaluate, no per-step anchor re-scan beyond the single
    updated stack row; otherwise the mutated floorplan is re-scored from
    scratch with ``costmodel.evaluate`` (same numbers, benchmark
    baseline). Unbatched (the env vmaps).
    """
    if action.ndim > 1:
        raise ValueError(
            "placement-episode actions are single-env; vmap step() over "
            f"the batch instead (got action shape {action.shape})")
    v = ps.decode(state.design)
    n_pos = cm.footprint_positions(v)
    a = jnp.asarray(action, jnp.int32)
    mapping = msum = None
    if cfg.mapping_actions:
        mapping = mpg.apply_action(state.mapping,
                                   a[len(ps.PLACEMENT_HEAD_SIZES):], n_pos)
        msum = mpg.traffic_summary(mapping, n_pos)
    if cfg.delta_eval:
        # one fused delta: relocate + re-anchor, one tail — equivalent to
        # apply_action on the carried floorplan (placement.nop_stats_delta
        # docstring), so the scratch path below is its exact oracle.
        # Both grid-cell heads are normalized identically: an
        # out-of-space action must price the same as its clipped twin on
        # every path, not silently misprice via a clamped gather.
        tgt = jnp.clip(a[3], 0, pm.N_CELLS - 1)
        ti, tj = pm.cell_ij(tgt)
        move = pm.PlacementMove(kind=jnp.int32(1), slot=a[0],
                                cell=jnp.clip(a[1], 0, pm.N_CELLS - 1),
                                hbm=a[2],
                                anchor=jnp.stack([ti, tj], axis=-1))
        cache = pm.nop_stats_delta(state.cache, move, n_pos, v.hbm_mask,
                                   v.arch_type, state.ctx.prefix.mesh_edges,
                                   move_kinds="both", mapping=mapping)
        metrics = cm.scenario_metrics_from_nop(state.ctx, cache.stats,
                                               cfg.hw, mapping=mapping)
    else:
        plc = pm.apply_action(state.cache.placement, a, n_pos)
        metrics = cm.evaluate_scenario(state.design, scenario, cfg.hw, plc,
                                       mapping=mapping)
        # keep the carried floorplan current; the stats fields go stale
        # but are never read on this path (pricing is from-scratch)
        cache = state.cache._replace(placement=plc)
    reward = metrics.reward
    t_next = state.t + 1
    done = t_next >= cfg.episode_len
    obs = _observe(metrics, t_next, reward, cfg, msum)
    tel = state.tel
    if cfg.telemetry:
        tel = tl.env_step_update(tel, reward, cfg.delta_eval)
    new_state = state._replace(t=t_next, prev_reward=reward, cache=cache,
                               mapping=mapping, tel=tel)
    return new_state, obs, reward, done, metrics


def auto_reset_step(state: EnvState, action: jnp.ndarray,
                    cfg: EnvConfig = EnvConfig(),
                    scenario: cm.Scenario = None):
    """step() that re-seeds a fresh episode when done (for rollout scans)."""
    scenario = _resolve(scenario, cfg)
    new_state, obs, reward, done, metrics = step(state, action, cfg, scenario)
    k_next, k_reset = jax.random.split(new_state.key)
    reset_state, reset_obs = reset(k_reset, cfg, scenario)
    out_state = jax.tree_util.tree_map(
        lambda a, b: jnp.where(done, a, b),
        reset_state._replace(key=k_next), new_state)
    if cfg.telemetry and cfg.placement_episode:
        # counters accumulate across episode boundaries: carry the
        # stepped counters forward (not the fresh-episode zeros the
        # where-combine picked) and count the completed episode
        out_state = out_state._replace(
            tel=tl.env_episode_update(new_state.tel, done))
    out_obs = jnp.where(done, reset_obs, obs)
    return out_state, out_obs, reward, done, metrics


def auto_reset_step_vec(states: EnvState, actions: jnp.ndarray,
                        cfg: EnvConfig = EnvConfig(),
                        scenario: cm.Scenario = None):
    """Batched ``auto_reset_step``: the reset work runs only on boundary
    steps.

    Bit-identical outputs to ``jax.vmap(auto_reset_step)``, but the
    fresh-episode computation (for placement episodes that is a full
    ``placement_ctx`` + anchor-scan cache rebuild — far more than a
    delta-priced step) sits under a scalar ``lax.cond`` on "any env
    finished". Rollout-scanned envs reset together and share
    ``episode_len``, so their clocks stay synchronized and the cond
    predicate is False on all but one step in ``episode_len`` — the
    reset branch is skipped instead of computed-and-discarded every
    step, which is what keeps delta-priced placement rollouts delta
    priced. (Under an outer vmap — e.g. ``train_population`` — the cond
    lowers to a select and this degrades gracefully to the old cost.)
    """
    scenario = _resolve(scenario, cfg)
    new_states, obs, reward, done, metrics = jax.vmap(
        lambda s, a: step(s, a, cfg, scenario))(states, actions)
    keys = jax.vmap(jax.random.split)(new_states.key)   # (E, 2, 2)

    def boundary(_):
        reset_states, reset_obs = jax.vmap(
            lambda k: reset(k, cfg, scenario))(keys[:, 1])
        out_states = jax.tree_util.tree_map(
            lambda a, b: jnp.where(
                done.reshape(done.shape + (1,) * (a.ndim - 1)), a, b),
            reset_states._replace(key=keys[:, 0]), new_states)
        if cfg.telemetry and cfg.placement_episode:
            # same contract as auto_reset_step: counters survive the
            # boundary and the finished episodes are counted per env
            out_states = out_states._replace(
                tel=tl.env_episode_update(new_states.tel, done))
        out_obs = jnp.where(done[:, None], reset_obs, obs)
        return out_states, out_obs

    out_states, out_obs = jax.lax.cond(
        jnp.any(done), boundary, lambda _: (new_states, obs), None)
    return out_states, out_obs, reward, done, metrics


class VecEnv:
    """Convenience wrapper: N independent environments via vmap."""

    def __init__(self, n_envs: int, cfg: EnvConfig = EnvConfig(),
                 scenario: cm.Scenario = None):
        self.n_envs = n_envs
        self.cfg = cfg
        scenario = _resolve(scenario, cfg)
        self._reset = jax.jit(jax.vmap(lambda k: reset(k, cfg, scenario)))
        self._step = jax.jit(
            jax.vmap(lambda s, a: auto_reset_step(s, a, cfg, scenario)))

    def reset(self, key):
        return self._reset(jax.random.split(key, self.n_envs))

    def step(self, states, actions):
        return self._step(states, actions)
