"""Mapping/dataflow co-exploration: the fourth design layer (ROADMAP 4).

The paper pins the intra/inter-chiplet dataflow to a fixed
weight-stationary mapping: every chiplet pulls all four operand streams
of Eq. 13 from its nearest HBM stack and forwards one stream through the
mesh (Fig. 5). Gemini (arXiv 2312.16436) and Monad (arXiv 2302.11256)
show the mapping axis — tiling and layer-pipelining across chiplets —
moves PPAC as much as resource allocation does, so this module makes the
mapping an explicit, optimizable pytree threaded through the evaluator
exactly the way ``placement.Placement`` was:

  - ``Mapping`` — per-layer-group tile-size indices plus a
    chiplet-pipeline *stage* assignment over the footprint slots.
  - ``canonical()`` — the paper's fixed dataflow: every slot in stage 0
    (no layer pipelining) and every layer group at the calibrated
    weight-stationary tile (``CANON_TILE``). Under the canonical
    mapping every derived factor below is *exactly* 1.0 / 0.0, so the
    mapped evaluation path is an exact float no-op relative to the
    unmapped one (and ``mapping=None`` never traces it at all).

Semantics of the two axes:

  - **Stages** partition the active slots into a layer pipeline. A slot
    in stage ``s > 0`` whose predecessor stage ``s - 1`` is non-empty is
    a *receiver*: three of its four operand streams arrive
    chiplet-to-chiplet from the previous stage (activations forwarded
    along the pipeline) instead of being pulled from HBM — the per-slot
    HBM weight drops from 4 to 1 and the NoP picks up the forwarded
    streams over the distance to the previous stage's centroid
    (``placement._stats_tail``). Unbalanced pipelines stall: throughput
    follows the largest stage (``balance`` below).
  - **Tile indices** move the per-layer-group tile size off the
    calibrated weight-stationary point. Larger tiles amortize more HBM
    traffic (``tile_hbm < 1``) but fall off the utilization sweet spot
    in either direction (``tile_u <= 1``) — the classic mapping
    trade-off, quadratic around the canonical tile.

Pure jnp, branchless, batch-generic; importable by ``placement`` (which
must not import ``costmodel``).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import params as ps

MAX_SLOTS = 128                 # mirrors placement.MAX_SLOTS (<= Table 1)
MAX_STAGES = 4                  # pipeline depth cap (diminishing returns)
N_LAYER_GROUPS = 4              # coarse layer buckets sharing a tile size
N_TILE = 8                      # tile-size grid points per group
CANON_TILE = 3                  # the paper's weight-stationary tile index

# Calibration of the tile trade-off (CAL): one grid step away from the
# canonical tile halves/doubles nothing dramatic — +/-1 step changes HBM
# traffic by 2^0.35 ~ 1.27x and costs ~3% utilization, so the optimum
# moves off canonical only when the design is actually HBM-bound.
TILE_HBM_EXP = 0.35             # log2 HBM-traffic change per tile step
TILE_U_PEN = 0.03               # quadratic utilization penalty per step^2

# Flat encoding (serialization + kernel packing):
#   [0:MAX_SLOTS)                     per-slot stage ids (int-valued)
#   [MAX_SLOTS:MAX_SLOTS+N_GROUPS)    per-group tile indices (int-valued)
FLAT_DIM = MAX_SLOTS + N_LAYER_GROUPS


class Mapping(NamedTuple):
    """One dataflow assignment: per-group tiles + per-slot pipeline stage.

    ``stage[s]`` is the pipeline stage of footprint slot ``s`` (only the
    first ``n_positions`` slots of a design are active; inactive slots'
    stages are ignored by every consumer). ``tile_idx[g]`` indexes the
    tile-size grid of layer group ``g``.
    """

    tile_idx: jnp.ndarray       # (..., N_LAYER_GROUPS) int32 in [0, N_TILE)
    stage: jnp.ndarray          # (..., MAX_SLOTS) int32 in [0, MAX_STAGES)


class MappingSummary(NamedTuple):
    """Placement-free traffic/utilization factors of one mapping.

    Every field is an exact float no-op value under ``canonical()``:
    ``recv_frac = fwd_hop_frac = 0.0``, the rest exactly ``1.0`` — the
    contract that keeps the canonical mapping bit-compatible with the
    unmapped evaluation suffix.
    """

    recv_frac: jnp.ndarray      # receiver slots / active slots
    pull_frac: jnp.ndarray      # fraction of Eq.-13 HBM streams kept
    balance: jnp.ndarray        # pipeline balance (1.0 = no stall)
    tile_hbm: jnp.ndarray       # HBM-traffic multiplier from the tiles
    tile_u: jnp.ndarray         # utilization multiplier from the tiles


def canonical(batch_shape=()) -> Mapping:
    """The paper's fixed weight-stationary dataflow as a ``Mapping``.

    All slots in stage 0 (no layer pipelining), every layer group at the
    calibrated tile. Evaluating under this mapping is numerically
    identical to ``mapping=None`` (tests/test_mapping.py pins it).
    """
    return Mapping(
        tile_idx=jnp.full(tuple(batch_shape) + (N_LAYER_GROUPS,),
                          CANON_TILE, jnp.int32),
        stage=jnp.zeros(tuple(batch_shape) + (MAX_SLOTS,), jnp.int32))


def clip_mapping(mapping: Mapping) -> Mapping:
    """Clamp both index fields into their legal ranges (GA/SA proposals)."""
    return Mapping(
        tile_idx=jnp.clip(jnp.asarray(mapping.tile_idx, jnp.int32),
                          0, N_TILE - 1),
        stage=jnp.clip(jnp.asarray(mapping.stage, jnp.int32),
                       0, MAX_STAGES - 1))


def active_mask(n_positions) -> jnp.ndarray:
    """(..., MAX_SLOTS) float 0/1 mask of the active footprint slots."""
    n_pos = jnp.asarray(n_positions, jnp.float32)
    slot = jnp.arange(MAX_SLOTS, dtype=jnp.float32)
    return (slot < n_pos[..., None]).astype(jnp.float32)


def stage_counts(mapping: Mapping, n_positions) -> jnp.ndarray:
    """(..., MAX_STAGES) active-slot count per pipeline stage."""
    stage = jnp.clip(jnp.asarray(mapping.stage, jnp.int32),
                     0, MAX_STAGES - 1)
    active = active_mask(n_positions)
    oh = (stage[..., None] == jnp.arange(MAX_STAGES)).astype(jnp.float32)
    return jnp.sum(active[..., None] * oh, axis=-2)


def receiver_mask(mapping: Mapping, n_positions) -> jnp.ndarray:
    """(..., MAX_SLOTS) float mask of pipeline *receiver* slots.

    A receiver is an active slot in stage ``s > 0`` whose predecessor
    stage ``s - 1`` holds at least one active slot — the slots whose
    operand streams arrive chiplet-to-chiplet instead of from HBM. A
    stage assignment with an empty predecessor degrades gracefully: the
    orphaned stage keeps pulling from HBM (no free traffic).
    """
    stage = jnp.clip(jnp.asarray(mapping.stage, jnp.int32),
                     0, MAX_STAGES - 1)
    active = active_mask(n_positions)
    cnt = stage_counts(mapping, n_positions)
    prev_cnt = jnp.take_along_axis(
        cnt, jnp.clip(stage - 1, 0, MAX_STAGES - 1), axis=-1)
    return (active * (stage > 0).astype(jnp.float32)
            * (prev_cnt > 0).astype(jnp.float32))


def traffic_summary(mapping: Mapping, n_positions) -> MappingSummary:
    """Placement-free mapped-traffic factors (see :class:`MappingSummary`).

    Shared by the :mod:`costmodel` evaluation suffix (bandwidth demand,
    interconnect energy, utilization) and the surrogate/env feature
    extractors, so every consumer prices a mapping identically.
    """
    n_pos = jnp.maximum(jnp.asarray(n_positions, jnp.float32), 1.0)
    recv = receiver_mask(mapping, n_positions)
    n_recv = jnp.sum(recv, axis=-1)
    recv_frac = n_recv / n_pos
    pull_frac = 1.0 - 0.75 * recv_frac    # 3 of 4 streams forwarded

    cnt = stage_counts(mapping, n_positions)
    n_stages = jnp.sum((cnt > 0).astype(jnp.float32), axis=-1)
    max_cnt = jnp.max(cnt, axis=-1)
    # throughput follows the largest stage: perfectly balanced pipelines
    # (and the single-stage canonical) score exactly 1.0
    balance = n_pos / jnp.maximum(n_stages * max_cnt, 1.0)

    s = (jnp.asarray(mapping.tile_idx, jnp.float32)
         - jnp.float32(CANON_TILE))
    s_mean = jnp.mean(s, axis=-1)
    s_sq = jnp.mean(s * s, axis=-1)
    tile_hbm = jnp.exp2(-TILE_HBM_EXP * s_mean)
    tile_u = 1.0 / (1.0 + TILE_U_PEN * s_sq)
    return MappingSummary(recv_frac=recv_frac, pull_frac=pull_frac,
                          balance=balance, tile_hbm=tile_hbm,
                          tile_u=tile_u)


def assign_stage(mapping: Mapping, slot, stage_val, n_positions) -> Mapping:
    """Move one active slot to pipeline stage ``stage_val``.

    ``slot`` is reduced mod ``n_positions`` (every action index maps to
    an active slot, mirroring ``placement.relocate_chiplet``); the write
    is a one-hot select, not an ``.at[]`` scatter, for the same
    vmapped-CPU reason as ``placement.nop_stats_delta``. Unbatched.
    """
    n_pos = jnp.maximum(jnp.asarray(n_positions, jnp.int32), 1)
    s = jnp.mod(jnp.asarray(slot, jnp.int32), n_pos)
    val = jnp.clip(jnp.asarray(stage_val, jnp.int32), 0, MAX_STAGES - 1)
    sel = jnp.arange(MAX_SLOTS, dtype=jnp.int32) == s
    return mapping._replace(stage=jnp.where(sel, val, mapping.stage))


def assign_tile(mapping: Mapping, group, tile_val) -> Mapping:
    """Set one layer group's tile index (one-hot select). Unbatched."""
    g = jnp.clip(jnp.asarray(group, jnp.int32), 0, N_LAYER_GROUPS - 1)
    val = jnp.clip(jnp.asarray(tile_val, jnp.int32), 0, N_TILE - 1)
    sel = jnp.arange(N_LAYER_GROUPS, dtype=jnp.int32) == g
    return mapping._replace(tile_idx=jnp.where(sel, val, mapping.tile_idx))


def apply_action(mapping: Mapping, mp_action, n_positions) -> Mapping:
    """Apply one 4-head mapping action (env/PPO extension).

    ``mp_action`` = [slot, stage, group, tile] indices (the
    ``params.MAPPING_HEAD_SIZES`` heads). Both assignments apply each
    step; either is a no-op when it re-states the current value.
    Unbatched (the env vmaps).
    """
    a = jnp.asarray(mp_action, jnp.int32)
    mapping = assign_stage(mapping, a[..., 0], a[..., 1], n_positions)
    return assign_tile(mapping, a[..., 2], a[..., 3])


def random_mapping(key, n_positions, batch_shape=()) -> Mapping:
    """Uniform random legal mapping (tests / GA seeding)."""
    import jax
    k_t, k_s = jax.random.split(key)
    del n_positions   # stages on inactive slots are ignored downstream
    return Mapping(
        tile_idx=jax.random.randint(
            k_t, tuple(batch_shape) + (N_LAYER_GROUPS,), 0, N_TILE,
            dtype=jnp.int32),
        stage=jax.random.randint(
            k_s, tuple(batch_shape) + (MAX_SLOTS,), 0, MAX_STAGES,
            dtype=jnp.int32))


def to_flat(mapping: Mapping) -> jnp.ndarray:
    """(..., FLAT_DIM) float32: [stages | tile indices]."""
    return jnp.concatenate([
        jnp.asarray(mapping.stage, jnp.float32),
        jnp.asarray(mapping.tile_idx, jnp.float32)], axis=-1)


def from_flat(flat: jnp.ndarray) -> Mapping:
    """Inverse of :func:`to_flat` (clipped to the legal grids)."""
    return clip_mapping(Mapping(
        tile_idx=jnp.asarray(flat[..., MAX_SLOTS:FLAT_DIM], jnp.int32),
        stage=jnp.asarray(flat[..., :MAX_SLOTS], jnp.int32)))


# sanity: the slot axis must agree with placement.MAX_SLOTS (placement
# imports us, so assert on the shared params-level constant instead),
# and the env action heads must mirror this module's grids
assert MAX_SLOTS == 128 and ps.N_HBM_LOCATIONS == 6
assert ps.MAPPING_HEAD_SIZES == (MAX_SLOTS, MAX_STAGES,
                                 N_LAYER_GROUPS, N_TILE)
