"""Minimal Gym-style space definitions (offline stand-in for gym v0.26).

Only what Chiplet-Gym needs: ``MultiDiscrete`` for the 14-parameter action
space and ``Box`` for the observation space, both JAX-native (sampling via
jax.random, no numpy RNG state).
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class MultiDiscrete:
    """Cartesian product of discrete heads, actions are index vectors."""

    def __init__(self, nvec: Sequence[int]):
        self.nvec = tuple(int(n) for n in nvec)
        assert all(n >= 1 for n in self.nvec)

    @property
    def shape(self) -> Tuple[int, ...]:
        return (len(self.nvec),)

    @property
    def n_heads(self) -> int:
        return len(self.nvec)

    @property
    def total_logits(self) -> int:
        return sum(self.nvec)

    def sample(self, key, batch_shape=()) -> jnp.ndarray:
        keys = jax.random.split(key, len(self.nvec))
        cols = [jax.random.randint(k, batch_shape, 0, n, dtype=jnp.int32)
                for k, n in zip(keys, self.nvec)]
        return jnp.stack(cols, axis=-1)

    def contains(self, x) -> bool:
        x = np.asarray(x)
        if x.shape[-1] != len(self.nvec):
            return False
        lo = (x >= 0).all()
        hi = (x < np.asarray(self.nvec)).all()
        return bool(lo and hi)

    def subspace(self, start: int, stop: int) -> "MultiDiscrete":
        """The MultiDiscrete over heads [start, stop) — used to split the
        extended Chiplet-Gym action into its design / placement parts."""
        return MultiDiscrete(self.nvec[start:stop])

    def concat(self, other: "MultiDiscrete") -> "MultiDiscrete":
        """Cartesian product with another MultiDiscrete (head-wise append)."""
        return MultiDiscrete(self.nvec + other.nvec)

    def __repr__(self):
        return f"MultiDiscrete({list(self.nvec)})"


class Box:
    """Continuous box space (float32)."""

    def __init__(self, low, high, shape: Tuple[int, ...]):
        self.low = jnp.broadcast_to(jnp.float32(low), shape)
        self.high = jnp.broadcast_to(jnp.float32(high), shape)
        self.shape = shape

    def sample(self, key, batch_shape=()) -> jnp.ndarray:
        u = jax.random.uniform(key, batch_shape + self.shape)
        return self.low + u * (self.high - self.low)

    def contains(self, x) -> bool:
        x = np.asarray(x)
        return (x.shape[-len(self.shape):] == self.shape
                and bool((x >= np.asarray(self.low) - 1e-6).all())
                and bool((x <= np.asarray(self.high) + 1e-6).all()))

    def __repr__(self):
        return f"Box(shape={self.shape})"
