"""Workload descriptors (paper Table 7 + the 10 assigned architectures).

A *task* is the paper's unit of work (Eq. 1): one inference for the MLPerf
CV models, one sequence for BERT, one generated/training token for the LM
architectures. ``Workload`` carries the Eq.-2 terms: GEMM ops/task,
non-GEMM ops/task, HBM bytes/task, mapping efficiency.

For the assigned LM architectures the descriptors are *derived from the
same config dataclasses that build the JAX models* (``from_arch_config``),
closing the co-design loop: the DSE optimizes a chiplet accelerator for the
exact workload the LM stack trains/serves. ``tests/test_workload.py``
cross-checks the analytical FLOPs against ``compiled.cost_analysis()`` of a
real compiled step.
"""

from __future__ import annotations

from typing import Dict, Sequence, Tuple

import jax.numpy as jnp

from repro.core.costmodel import Workload

_G = 1e9
_M = 1e6


def make(gemm_gflops: float, nongemm_frac: float, hbm_mbytes: float,
         mapping_eff: float) -> Workload:
    """gemm_gflops is the paper's FLOPs/task; MACs = FLOPs / 2."""
    gemm_macs = gemm_gflops * _G / 2.0
    return Workload(
        gemm_ops=jnp.float32(gemm_macs),
        nongemm_ops=jnp.float32(gemm_macs * nongemm_frac),
        hbm_bytes=jnp.float32(hbm_mbytes * _M),
        mapping_eff=jnp.float32(mapping_eff),
    )


# ---------------------------------------------------------------------------
# Paper Table 7 (MLPerf benchmark features). FLOPs/forward-pass verbatim;
# non-GEMM fraction and mapping efficiency are documented estimates
# (BN/ReLU/pool for CV, softmax/layernorm for NLP; depthwise convs map
# poorly onto systolic arrays, hence EfficientDet's low M_eff).
# ---------------------------------------------------------------------------

MLPERF: Dict[str, Workload] = {
    "resnet50": make(4.0, 0.02, 60.0, 0.80),
    "efficientdet": make(410.0, 0.05, 120.0, 0.60),
    "maskrcnn": make(447.0, 0.04, 350.0, 0.70),
    "3dunet": make(947.0, 0.02, 500.0, 0.80),
    "bert": make(32.0, 0.03, 440.0, 0.85),
}

MLPERF_DOMAINS = {
    "resnet50": "Image classification (ImageNet)",
    "efficientdet": "Light-weight object detection (COCO 2017)",
    "maskrcnn": "Heavy-weight object detection (COCO 2014)",
    "3dunet": "Biomedical image segmentation (KiTS19)",
    "bert": "Natural Language Processing (Wikipedia 2020)",
}


# ---------------------------------------------------------------------------
# Assigned-architecture workloads, derived from the model configs
# ---------------------------------------------------------------------------

def from_arch_config(arch_cfg, mode: str = "decode",
                     seq_len: int = 4096) -> Workload:
    """Derive the Eq.-2 descriptor from an ``ArchConfig``.

    ``arch_cfg`` duck-types ``repro.configs.base.ArchConfig``:
    ``param_count()``, ``active_param_count()``, ``flops_per_token(seq)``.

    mode:
      - "decode":  task = one generated token (weights stream from HBM)
      - "prefill": task = one prompt token (weights amortized over seq)
      - "train":   task = one training token (3x forward FLOPs)
    """
    active = float(arch_cfg.active_param_count())
    fwd_flops = float(arch_cfg.flops_per_token(seq_len))
    fwd_macs = fwd_flops / 2.0

    if mode == "train":
        gemm = 3.0 * fwd_macs
        hbm_bytes = 2.0 * active / 8.0 + 64.0 * arch_cfg.d_model
    elif mode == "prefill":
        gemm = fwd_macs
        hbm_bytes = 2.0 * active / max(seq_len, 1) + 16.0 * arch_cfg.d_model
    else:  # decode: every token streams the full active weights
        gemm = fwd_macs
        hbm_bytes = 2.0 * active + 4.0 * arch_cfg.d_model * arch_cfg.n_layers
    nongemm = 0.03 * gemm
    m_eff = 0.85 if mode != "decode" else 0.60   # decode is GEMV-like
    return Workload(
        gemm_ops=jnp.float32(gemm),
        nongemm_ops=jnp.float32(nongemm),
        hbm_bytes=jnp.float32(hbm_bytes),
        mapping_eff=jnp.float32(m_eff),
    )


def registry() -> Dict[str, Workload]:
    """All named workloads (MLPerf + assigned archs, decode + train)."""
    out = dict(MLPERF)
    try:
        from repro.configs import ARCH_REGISTRY
        for name, cfg in ARCH_REGISTRY.items():
            out[f"{name}:train"] = from_arch_config(cfg, "train")
            out[f"{name}:decode"] = from_arch_config(cfg, "decode")
    except ModuleNotFoundError as exc:
        # only the bootstrap case (configs not built yet) is benign; a
        # transitive import failure inside repro.configs is a real bug
        # and must surface, not silently shrink the registry
        if exc.name not in ("repro.configs", "repro"):
            raise
    return out


def resolve(names: Sequence[str]) -> Tuple[Tuple[str, ...], Tuple[Workload, ...]]:
    """Look up workloads by registry name; returns (names, workloads).

    Accepts glob-ish shortcuts: ``"mlperf"`` expands to the five MLPerf
    benchmarks, ``"archs:decode"`` / ``"archs:train"`` to every assigned
    architecture in that mode, ``"all"`` to the whole registry.
    """
    reg = registry()
    out_names = []
    for name in names:
        if name == "all":
            out_names.extend(reg.keys())
        elif name == "mlperf":
            out_names.extend(MLPERF.keys())
        elif name in ("archs:decode", "archs:train"):
            mode = name.split(":")[1]
            matched = [k for k in reg if k.endswith(f":{mode}")]
            if not matched:
                raise KeyError(
                    f"group {name!r} matched no workloads (arch configs "
                    f"unavailable?); known: {sorted(reg)}")
            out_names.extend(matched)
        elif name in reg:
            out_names.append(name)
        else:
            raise KeyError(
                f"unknown workload {name!r}; known: {sorted(reg)}")
    # dedupe, order-preserving
    seen, uniq = set(), []
    for n in out_names:
        if n not in seen:
            seen.add(n)
            uniq.append(n)
    return tuple(uniq), tuple(reg[n] for n in uniq)
