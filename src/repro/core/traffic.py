"""Parametric traffic-trace generators over the config fleet (ROADMAP 3).

Real deployments don't see the paper's point workloads: QPS moves
diurnally or in bursts, the served model mix shifts, and tail-latency
SLOs bound how much of a design's throughput is actually usable. This
module samples :class:`repro.core.costmodel.TrafficTrace` tensors —
``(T, workload-mix, QPS)`` — from four parametric families over the
assigned model-config fleet, and attaches them to scenarios so the whole
optimizer stack scores designs against serving *distributions*:

  - ``flat``:          constant QPS, constant mix (the SLO/energy terms
                       still bite — a point scenario under load),
  - ``diurnal``:       one sinusoidal day, mix drifting with per-model
                       phases,
  - ``bursty``:        Bernoulli load spikes of ``peak`` x the baseline
                       with lognormal jitter,
  - ``multi-tenant``:  n_tenants fleet models with phase-shifted
                       intensities sharing the box.

Every generator is deterministic under its PRNG key, emits mix rows that
sum to 1, and normalizes QPS so the dt-weighted offered load equals
``load`` x the dt-weighted monolithic-baseline service rate of the mixed
workload (design-independent, so traces are comparable across designs).

The discrete-event simulator at the bottom is the calibration oracle for
``costmodel.queueing_p99``: it mirrors ``serving/engine.py``'s slot
scheduler (c slots, deterministic per-task occupancy c/mu, FIFO
admission) and tests/test_traffic.py keeps the analytic proxy in band.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel as cm
from repro.core import hw_constants as hw
from repro.core import monolithic as mono
from repro.core import workload as wl


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """One trace family + its knobs (see module docstring)."""

    kind: str = "flat"            # flat | diurnal | bursty | multi-tenant
    n_steps: int = 32             # T
    load: float = 1.5             # mean offered QPS / mono reference rate
    peak: float = 3.0             # burst / diurnal peak multiplier
    burst_prob: float = 0.15      # bursty: fraction of steps in a burst
    mix_spread: float = 0.25      # traffic fraction from the rest of the fleet
    n_tenants: int = 4            # multi-tenant: co-resident fleet models
    slo_mult: float = 2.0         # SLO = slo_mult * c / mono reference rate
    slo_weight: float = 30.0      # reward penalty per fully-missed trace
    idle_frac: float = 0.35       # power floor at zero utilization
    n_servers: int = 8            # queueing servers (engine decode slots)
    fleet: Tuple[str, ...] = ("archs:decode",)   # mix pool (workload names)
    seed: int = 0


KINDS = ("flat", "diurnal", "bursty", "multi-tenant")

TRACE_PRESETS: Dict[str, TraceConfig] = {
    kind: TraceConfig(kind=kind) for kind in KINDS
}


def fleet_workloads(cfg: TraceConfig):
    """Resolve the mix pool -> (names, stacked Workload with (F,) leaves)."""
    names, workloads = wl.resolve(cfg.fleet)
    return names, jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *workloads)


def _load_shape(key, cfg: TraceConfig, phases=None) -> jnp.ndarray:
    """(T,) relative load curve of the family (scale fixed by make_trace).

    ``phases`` carries the multi-tenant phase vector drawn once by
    :func:`make_trace` and shared with :func:`_mix_rows`, so the offered
    load is the superposition of the *same* tenant intensities that
    shape the mix (None falls back to drawing from ``key`` — identical
    values, since make_trace draws from the same key).
    """
    t = jnp.arange(cfg.n_steps, dtype=jnp.float32)
    phase = 2.0 * jnp.pi * t / cfg.n_steps
    if cfg.kind == "flat":
        return jnp.ones(cfg.n_steps, jnp.float32)
    if cfg.kind == "diurnal":
        # one day: trough -> peak -> trough, peak-to-trough = cfg.peak
        return 1.0 + (cfg.peak - 1.0) * 0.5 * (1.0 - jnp.cos(phase))
    if cfg.kind == "bursty":
        k_b, k_j = jax.random.split(key)
        burst = jax.random.bernoulli(k_b, cfg.burst_prob,
                                     (cfg.n_steps,)).astype(jnp.float32)
        jitter = jnp.exp(0.2 * jax.random.normal(k_j, (cfg.n_steps,)))
        return (1.0 + (cfg.peak - 1.0) * burst) * jitter
    if cfg.kind == "multi-tenant":
        # superposition of the tenants' phase-shifted days
        if phases is None:
            phases = jax.random.uniform(key, (cfg.n_tenants,),
                                        maxval=2.0 * jnp.pi)
        return jnp.mean(1.0 + (cfg.peak - 1.0) * 0.5
                        * (1.0 - jnp.cos(phase[:, None] + phases[None, :])),
                        axis=-1)
    raise ValueError(f"unknown trace kind {cfg.kind!r}; one of {KINDS}")


def _mix_rows(key, cfg: TraceConfig, n_fleet: int,
              phases=None) -> jnp.ndarray:
    """(T, 1 + F) mix rows: column 0 = the scenario's own workload.

    Every row sums to 1; the own-workload column carries
    ``1 - mix_spread`` and the fleet columns share ``mix_spread``
    according to the family's drift profile. For multi-tenant traces
    ``phases`` is the tenant phase vector drawn once by
    :func:`make_trace` and shared with :func:`_load_shape`, so the mix
    rows are the *same* tenant intensities whose superposition drives
    the offered load (``key`` still selects which fleet models are the
    tenants).
    """
    t = jnp.arange(cfg.n_steps, dtype=jnp.float32)
    phase = 2.0 * jnp.pi * t / cfg.n_steps
    if cfg.kind == "flat":
        p = jnp.full((cfg.n_steps, n_fleet), 1.0 / n_fleet)
    elif cfg.kind in ("diurnal", "bursty"):
        # smooth per-model drift: softmax over phase-shifted sinusoids
        phases = jax.random.uniform(key, (n_fleet,), maxval=2.0 * jnp.pi)
        logits = jnp.sin(phase[:, None] + phases[None, :])
        p = jax.nn.softmax(logits, axis=-1)
    elif cfg.kind == "multi-tenant":
        k_sel, k_ph = jax.random.split(key)
        n_t = min(cfg.n_tenants, n_fleet)
        sel = jax.random.permutation(k_sel, n_fleet)[:n_t]
        if phases is None:
            phases = jax.random.uniform(k_ph, (cfg.n_tenants,),
                                        maxval=2.0 * jnp.pi)
        phases = phases[:n_t]
        inten = 1.0 + (cfg.peak - 1.0) * 0.5 * (
            1.0 - jnp.cos(phase[:, None] + phases[None, :]))   # (T, n_t)
        p = jnp.zeros((cfg.n_steps, n_fleet))
        p = p.at[:, sel].set(inten / jnp.sum(inten, -1, keepdims=True))
    else:
        raise ValueError(f"unknown trace kind {cfg.kind!r}; one of {KINDS}")
    own = jnp.full((cfg.n_steps, 1), 1.0 - cfg.mix_spread)
    return jnp.concatenate([own, cfg.mix_spread * p], axis=-1)


def make_trace(key, workload: cm.Workload, cfg: TraceConfig,
               hw_cfg: hw.HWConfig = hw.DEFAULT_HW):
    """Sample one trace -> (traced Workload with (T,) leaves, TrafficTrace).

    ``workload`` is the scenario's own point workload; the traced
    workload is the per-step convex mix of it with the fleet pool. QPS
    is anchored to the *monolithic baseline's* service rate on the
    mixed workload (design-independent): the dt-weighted offered load
    is exactly ``cfg.load`` x the dt-weighted reference rate, and the
    p99 SLO is ``cfg.slo_mult`` x the reference service time
    ``n_servers / reference rate``.
    """
    k_shape, k_mix = jax.random.split(jnp.asarray(key))
    _, fleet = fleet_workloads(cfg)
    n_fleet = jnp.shape(fleet.gemm_ops)[0]
    # multi-tenant: one phase vector drives both the offered load and the
    # mix, so the peak-load step is the peak-intensity step of the same
    # tenants (drawn from k_shape -> the load curve matches pre-fix traces)
    phases = None
    if cfg.kind == "multi-tenant":
        phases = jax.random.uniform(k_shape, (cfg.n_tenants,),
                                    maxval=2.0 * jnp.pi)
    mix = _mix_rows(k_mix, cfg, n_fleet, phases=phases)      # (T, 1+F)
    traced_wl = jax.tree_util.tree_map(
        lambda own, fl: mix[:, 0] * own + mix[:, 1:] @ fl, workload, fleet)

    # design-independent QPS anchor: the monolithic baseline's rate on
    # each step's mixed workload
    mu_ref = jax.vmap(lambda w: mono.evaluate(w, hw_cfg).tasks_per_sec)(
        traced_wl)                                           # (T,)
    dt = jnp.full((cfg.n_steps,), 1.0 / cfg.n_steps)
    shape = _load_shape(k_shape, cfg, phases=phases)
    weighted = mu_ref * shape
    norm = jnp.sum(dt * weighted) / jnp.maximum(
        jnp.sum(dt * mu_ref), 1e-30)
    qps = cfg.load * weighted / jnp.maximum(norm, 1e-30)
    mu_mean = jnp.sum(dt * mu_ref)

    trace = cm.TrafficTrace(
        qps=qps, dt=dt, mix=mix,
        slo_latency_s=cfg.slo_mult * cfg.n_servers
        / jnp.maximum(mu_mean, 1e-30),
        slo_weight=jnp.float32(cfg.slo_weight),
        idle_frac=jnp.float32(cfg.idle_frac),
        n_servers=jnp.float32(cfg.n_servers))
    return traced_wl, trace


def traced_scenario(scenario: cm.Scenario, cfg: TraceConfig,
                    hw_cfg: hw.HWConfig = hw.DEFAULT_HW,
                    index: int = 0) -> cm.Scenario:
    """Attach a sampled trace to one point scenario (key = seed, index)."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), index)
    traced_wl, trace = make_trace(key, scenario.workload, cfg, hw_cfg)
    return cm.Scenario(workload=traced_wl, weights=scenario.weights,
                       trace=trace)


def apply_trace(scenarios: cm.Scenario, cfg: TraceConfig,
                hw_cfg: hw.HWConfig = hw.DEFAULT_HW) -> cm.Scenario:
    """Trace every scenario of a stacked batch (one sampled trace each).

    Scenario ``s`` gets the key ``fold_in(PRNGKey(cfg.seed), s)``, so
    the batch is deterministic under the config and independent of the
    suite's optimizer key streams.
    """
    n_scen = int(jnp.shape(scenarios.weights.alpha)[0])
    scalars = [jax.tree_util.tree_map(lambda x: x[s], scenarios)
               for s in range(n_scen)]
    return cm.stack_scenarios([
        traced_scenario(sc, cfg, hw_cfg, index=s)
        for s, sc in enumerate(scalars)])


def resolve_trace(name_or_cfg) -> TraceConfig:
    """A preset name, a TraceConfig (passthrough), or None -> None."""
    if name_or_cfg is None or isinstance(name_or_cfg, TraceConfig):
        return name_or_cfg
    if name_or_cfg in TRACE_PRESETS:
        return TRACE_PRESETS[name_or_cfg]
    raise ValueError(f"unknown trace preset {name_or_cfg!r}; "
                     f"one of {sorted(TRACE_PRESETS)} or a TraceConfig")


# --------------------------------------------------------------------------- #
# calibration oracle: discrete-event twin of serving/engine.py's scheduler
# --------------------------------------------------------------------------- #

def slot_scheduler_p99_sim(qps: float, tasks_per_sec: float, n_servers: int,
                           n_tasks: int = 4000, seed: int = 0) -> float:
    """p99 sojourn time of the engine's slot scheduler (numpy, host-only).

    Mirrors ``serving/engine.py``: ``n_servers`` slots, FIFO admission,
    every decode step advances all active slots, so a task occupies its
    slot for a deterministic ``D = n_servers / tasks_per_sec`` seconds
    and the system is an M/D/c queue. This is the oracle
    ``costmodel.queueing_p99`` is calibrated against.
    """
    rng = np.random.default_rng(seed)
    d = n_servers / tasks_per_sec
    arrivals = np.cumsum(rng.exponential(1.0 / qps, n_tasks))
    free = np.zeros(n_servers)
    sojourn = np.empty(n_tasks)
    for i, t in enumerate(arrivals):
        j = int(np.argmin(free))
        start = max(t, free[j])
        free[j] = start + d
        sojourn[i] = free[j] - t
    return float(np.percentile(sojourn, 99.0))
