"""Monolithic (A100-class) baseline model for the paper's §5.3 comparison.

Same PE/SRAM density assumptions as the chiplet model (iso-node, 7 nm) so
the comparison isolates *integration architecture*, exactly as the paper
intends. Implements:

  - single-die throughput / energy (no NoP; on-die systolic reuse),
  - die cost at 826 mm^2 (48 % yield with the calibrated d=0.1/cm^2),
  - CoWoS package cost for die + 4 HBM stacks,
  - iso-throughput system energy: to match a chiplet system that is k times
    faster, ceil(k) monolithic chips must be linked off-board (PCB/NVLink
    class, ~10x on-package energy/bit — paper's [4] citation).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import hw_constants as hw
from repro.core import costmodel as cm


class MonoMetrics(NamedTuple):
    die_area_mm2: jnp.ndarray
    pes: jnp.ndarray
    peak_tops: jnp.ndarray
    eff_tops: jnp.ndarray
    tasks_per_sec: jnp.ndarray
    e_comm_pj_per_op: jnp.ndarray
    energy_per_task_j: jnp.ndarray
    tasks_per_joule: jnp.ndarray
    die_yield: jnp.ndarray
    die_cost: jnp.ndarray
    die_cost_paper: jnp.ndarray
    pkg_cost: jnp.ndarray
    n_chips_iso: jnp.ndarray          # chips needed to match iso-throughput


def evaluate(workload: cm.Workload = cm.GENERIC_WORKLOAD,
             cfg: hw.HWConfig = hw.DEFAULT_HW,
             iso_tops: jnp.ndarray | float | None = None) -> MonoMetrics:
    """Evaluate the 826 mm^2 monolithic baseline.

    If ``iso_tops`` (the chiplet system's effective TOPS) is given and
    exceeds one chip's throughput, the system is built from
    ceil(iso/chip) chips with off-board interconnect energy added.
    """
    area = jnp.float32(hw.MONO_DIE_AREA_MM2)
    compute_area = area * cfg.compute_area_frac
    pes = compute_area * 1e6 / cfg.pe_area_um2
    reuse = jnp.sqrt(pes)
    # SRAM-capacity-bounded DRAM amortization (same model as costmodel.py)
    sram_bytes = area * hw.SRAM_AREA_FRAC * hw.SRAM_MB_PER_MM2 * 1e6
    dw_bytes = cfg.data_width_bits / 8.0
    reuse_mem = jnp.sqrt(sram_bytes / (3.0 * dw_bytes))
    reuse_comm = reuse_mem if cfg.comm_reuse_systolic else jnp.float32(1.0)

    # on-die data movement: cross-die wire latency folded into cycles/op
    die_span_mm = jnp.sqrt(area)
    lat_ns = die_span_mm * 0.10 + 1.0          # repeated global wire + ctrl
    cycles_per_op = 1.0 + lat_ns * cfg.freq_ghz / (
        reuse ** cfg.latency_amort_exp)

    ops_per_sec = pes * cfg.freq_ghz * 1e9 / cycles_per_op
    operand_gbps = (cfg.n_operands * cfg.data_width_bits
                    * ops_per_sec / reuse_comm) / 1e9
    bw_act = hw.MONO_HBM_COUNT * hw.HBM_BANDWIDTH_GBPS_PER_STACK
    u_sys = jnp.minimum(1.0, bw_act / jnp.maximum(operand_gbps, 1e-6))

    u_chip = workload.mapping_eff
    peak_tops = pes * cfg.freq_ghz * 1e9 / 1e12
    eff_ops = ops_per_sec * u_sys * u_chip
    eff_tops = eff_ops / 1e12

    n_chips = jnp.float32(1.0)
    if iso_tops is not None:
        n_chips = jnp.maximum(1.0, jnp.ceil(jnp.asarray(iso_tops) / eff_tops))

    # energy: HBM over CoWoS interposer (on-package) + device access energy;
    # with >1 chips, half the operand traffic crosses the PCB at 10x energy
    bits_per_op = cfg.n_operands * cfg.data_width_bits / reuse_comm
    e_hbm_link = 0.35                                # CoWoS mid (Table 4)
    # with multi-chip model parallelism, ~a quarter of operand traffic
    # crosses the board-level link (activations + reduce) (CAL)
    cross_frac = jnp.where(n_chips > 1.0, 0.25, 0.0)
    e_comm = (bits_per_op * (e_hbm_link + cfg.e_bit_hbm_device_pj)
              + cross_frac * bits_per_op * hw.E_BIT_PJ_OFFBOARD)
    e_op_total = cfg.e_op_pj + e_comm
    ops_per_task = workload.gemm_ops + workload.nongemm_ops
    energy_per_task = ops_per_task * e_op_total * 1e-12 / u_chip

    y = cm.die_yield(area, cfg.defect_density_per_cm2, cfg.yield_alpha)
    die_cost = n_chips * cm.die_cost_physical(area, cfg)
    die_cost_paper = n_chips * cm.die_cost_taylor(area, cfg)

    # CoWoS package: full-area interposer + HBM PHY links (1024 b x 4 stacks)
    pkg_cost = n_chips * (hw.PKG_MU0_PER_MM2[0] * cfg.package_area_mm2
                          + hw.PKG_MU1_PER_LINK[0] * 1024.0 * hw.MONO_HBM_COUNT
                          + hw.PKG_MU2_FIXED[0])

    tasks_per_sec = n_chips * eff_ops / jnp.maximum(ops_per_task, 1.0)
    return MonoMetrics(
        die_area_mm2=area, pes=pes, peak_tops=peak_tops, eff_tops=eff_tops,
        tasks_per_sec=tasks_per_sec,
        e_comm_pj_per_op=e_comm, energy_per_task_j=energy_per_task,
        tasks_per_joule=1.0 / jnp.maximum(energy_per_task, 1e-30),
        die_yield=y, die_cost=die_cost, die_cost_paper=die_cost_paper,
        pkg_cost=pkg_cost, n_chips_iso=n_chips,
    )
