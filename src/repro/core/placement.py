"""Explicit interposer placement and the pairwise-traffic NoP model.

The paper's design space is "resource allocation, placement, and packaging
architecture", but the original model collapses placement into a 6-bit HBM
location mask plus a worst-case hop scalar. This module makes placement a
first-class, optimizable layer of the DSE engine:

  - ``Placement`` — a pure pytree assigning every chiplet footprint slot a
    cell of the 16x16 interposer routing grid, and every HBM stack a
    (possibly fractional) grid coordinate. Fractional HBM coordinates are
    what make the paper's Fig.-4 anchors exactly representable (an edge
    stack sits adjacent to the *middle* of its edge, which is between two
    rows when the row count is even).
  - ``canonical`` — the paper's Fig.-4 floorplan: chiplets fill the m x n
    footprint grid row-major, HBM stacks sit at the six canonical anchors
    (left / right / top / bottom / middle / 3D-stacked).
  - ``nop_stats`` — the pairwise-traffic NoP reduction: a Manhattan hop
    matrix between chiplet cells and HBM anchors, contracted against the
    Fig.-5 dataflow traffic pattern (4 operand streams pulled from the
    nearest HBM per chiplet, 1 forwarded chiplet-to-chiplet stream fanning
    out from the array's traffic centroid), reduced to worst / mean hop
    counts and a per-link contention figure.

Worst-case figures reduce over the *spanned mesh region* (the bounding box
of occupied cells): NoP routers exist at every cell of the floorplan, and
the worst transfer is the worst router-to-endpoint path — exactly the
paper's Fig.-4 convention. This is what makes the model degrade *exactly*
to the legacy ``hbm_worst_hops`` / ``m + n - 2`` numbers under the
canonical placement (``tests/test_placement.py`` brute-forces the
equivalence over every footprint count and HBM mask). Mean latency and
contention are traffic-weighted over the occupied cells only, so they do
respond to intra-box relocations.

Everything is branchless jnp: every function accepts arbitrary (identical)
batch shapes on all arguments and is jit/vmap-safe. This module must not
import ``costmodel`` (costmodel imports us); mesh dims are passed in.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import mapping as mpg
from repro.core import params as ps

GRID = 16                      # interposer routing grid is GRID x GRID
N_CELLS = GRID * GRID          # 256 cells
MAX_SLOTS = 128                # chiplet footprint slots (Table 1: <=128)
N_HBM = ps.N_HBM_LOCATIONS     # 6 stacks, one per location bit

_BIG = jnp.float32(1e9)

# Flat encoding layout (serialization + kernel packing):
#   [0:MAX_SLOTS)                  chiplet cell ids (int-valued)
#   [MAX_SLOTS:MAX_SLOTS+2*N_HBM)  hbm (i0, j0, i1, j1, ...) coordinates
FLAT_DIM = MAX_SLOTS + 2 * N_HBM


class Placement(NamedTuple):
    """Grid-cell assignment for chiplet slots + HBM stacks.

    ``chiplet_cell[s] = i * GRID + j`` places footprint slot ``s`` at grid
    cell (i, j); only the first ``n_positions`` slots of a design are
    active. ``hbm_ij[b]`` is the (i, j) coordinate of the HBM stack for
    location bit ``b`` (only bits set in the design's mask matter);
    fractional and just-off-grid values (the edge anchors sit at row/col
    -1 or m/n) are legal.
    """

    chiplet_cell: jnp.ndarray   # (..., MAX_SLOTS) int32
    hbm_ij: jnp.ndarray         # (..., N_HBM, 2) float32


class NoPStats(NamedTuple):
    """Pairwise-traffic NoP reduction of one placement.

    Worst figures reduce over the spanned mesh region (router-worst, the
    Fig.-4 convention); mean figures are traffic-weighted over occupied
    chiplet cells; ``link_contention`` is operand-streams x hops per mesh
    link (a uniform-load channel proxy) — by default per link of the
    spanned region, or of an explicitly provided fabric (costmodel passes
    the canonical m x n mesh, the fabric the design actually pays for, so
    sprawling a placement cannot mint free links). ``region_edges`` is
    the link count needed to wire the spanned region (drives the package
    link cost; equals the canonical mesh edge count under the canonical
    placement).
    """

    hops_ai_worst: jnp.ndarray
    hops_ai_mean: jnp.ndarray
    hops_hbm_worst: jnp.ndarray
    hops_hbm_mean: jnp.ndarray
    link_contention: jnp.ndarray
    region_edges: jnp.ndarray


def cell_ij(cell: jnp.ndarray):
    """Split cell ids into float (i, j) grid coordinates."""
    c = jnp.asarray(cell, jnp.int32)
    return (c // GRID).astype(jnp.float32), (c % GRID).astype(jnp.float32)


def canonical_anchors(m, n) -> jnp.ndarray:
    """The Fig.-4 HBM anchor coordinates, (..., 6, 2).

    Edge stacks sit adjacent to the middle of their edge (one hop
    off-grid), 'middle' and '3D-stacked' at the array center.
    """
    m = jnp.asarray(m, jnp.float32)
    n = jnp.asarray(n, jnp.float32)
    mc, nc = (m - 1.0) / 2.0, (n - 1.0) / 2.0
    return jnp.stack([
        jnp.stack([mc, jnp.full_like(nc, -1.0)], axis=-1),   # left
        jnp.stack([mc, n], axis=-1),                         # right
        jnp.stack([jnp.full_like(mc, -1.0), nc], axis=-1),   # top
        jnp.stack([m, nc], axis=-1),                         # bottom
        jnp.stack([mc, nc], axis=-1),                        # middle
        jnp.stack([mc, nc], axis=-1),                        # 3D-stacked
    ], axis=-2)                                       # (..., 6, 2)


def canonical(m, n, hbm_mask, arch_type) -> Placement:
    """The paper's Fig.-4 floorplan as an explicit ``Placement``.

    Chiplet slot ``s`` occupies cell (s // n, s % n) — row-major over the
    m x n footprint grid. HBM anchors: edge stacks adjacent to the middle
    of their edge (one hop off-grid), 'middle' and '3D-stacked' at the
    array center. ``m``/``n`` may carry any batch shape.
    """
    m = jnp.asarray(m, jnp.float32)
    n = jnp.asarray(n, jnp.float32)
    del hbm_mask, arch_type   # anchors exist for all six bits; the mask
    #                           and arch select/clamp them in nop_stats
    slot = jnp.arange(MAX_SLOTS, dtype=jnp.int32)
    n_i = jnp.maximum(n.astype(jnp.int32), 1)[..., None]
    i = jnp.minimum(slot // n_i, GRID - 1)
    j = jnp.minimum(slot % n_i, GRID - 1)
    cells = i * GRID + j                              # (..., 128)
    return Placement(chiplet_cell=cells, hbm_ij=canonical_anchors(m, n))


def _mask_bits(hbm_mask) -> jnp.ndarray:
    """HBM location mask -> (..., 6) float 0/1 indicator per bit."""
    mask = jnp.asarray(hbm_mask, jnp.int32)
    return jnp.stack([(mask >> b) & 1 for b in range(N_HBM)],
                     axis=-1).astype(jnp.float32)


def hbm_floors(hbm_mask, arch_type) -> jnp.ndarray:
    """Per-anchor minimum hop count (..., 6).

    Every stack is at least one mesh hop away from any chiplet it feeds,
    except a 3D stack (bit 5) under a 3D-capable architecture, which sits
    directly above the chiplet at its coordinate (the vertical hop is
    folded into the 3D wire delay). A pure-2.5D design degrades the 3D bit
    to a regular ('middle'-like) stack.
    """
    del hbm_mask
    arch = jnp.asarray(arch_type, jnp.float32)
    floor3d = jnp.where(arch >= 1.0, 0.0, 1.0)
    ones = jnp.ones_like(arch)
    return jnp.stack([ones, ones, ones, ones, ones, floor3d], axis=-1)


def _nearest_stack_cells(hbm_ij, floors, bits):
    """Nearest-placed-stack distance at every router of the 16x16 grid.

    Returns (gi, gj, d_cell): the (256,) cell coordinates and the
    (..., 256) floored min-over-placed-stacks distance, computed as a
    chained 6-anchor minimum so no (..., 6, 256) broadcast is ever
    materialized — this is where both NoP tiers' throughput comes from.
    """
    cell = jnp.arange(N_CELLS, dtype=jnp.float32)
    gi, gj = jnp.floor(cell / GRID), cell % GRID      # (256,)
    d_cell = jnp.broadcast_to(_BIG, jnp.broadcast_shapes(
        hbm_ij.shape[:-2], floors.shape[:-1], bits.shape[:-1]) + (N_CELLS,))
    for b in range(N_HBM):
        hi = hbm_ij[..., b, 0][..., None]
        hj = hbm_ij[..., b, 1][..., None]
        db = jnp.maximum(jnp.abs(gi - hi) + jnp.abs(gj - hj),
                         floors[..., b][..., None])
        d_cell = jnp.minimum(
            d_cell, jnp.where(bits[..., b][..., None] > 0, db, _BIG))
    return gi, gj, d_cell


def _stats_tail(chiplet_cell, d_cell, d_hbm, n_positions, mesh_edges=None,
                mapping=None):
    """Per-slot/per-link reduction shared by the full tier and the delta
    path: (cells, router distances, per-slot distances) -> NoPStats.

    Returns ``(stats, sum_ci, sum_cj)`` — the active-cell coordinate sums
    are exact in float32 (cells are small integers), so the delta path
    caches them and serves the profile-guided proposal centroid without
    re-reducing the slot axis. Every op here matches the pre-delta
    ``nop_stats`` body exactly; the delta path inherits bit-identical
    stats from sharing it.

    ``mapping`` (a :class:`repro.core.mapping.Mapping`, default None)
    reshapes the Fig.-5 operand-stream traffic matrix: a pipeline
    *receiver* slot (see ``mapping.receiver_mask``) pulls 1 instead of 4
    streams from HBM and picks up 3 forwarded streams over the distance
    to its predecessor stage's centroid, so ``hops_ai_mean`` becomes the
    traffic-weighted forwarding mean and ``link_contention`` prices the
    re-shaped stream set. ``mapping=None`` traces the exact pre-mapping
    program (static dispatch, bitwise); the canonical all-stage-0
    mapping adds exact float no-ops (0-valued receiver sums), so it is
    numerically identical too.
    """
    n_pos = jnp.asarray(n_positions, jnp.float32)

    ci, cj = cell_ij(chiplet_cell)                    # (..., 128)
    slot = jnp.arange(MAX_SLOTS, dtype=jnp.float32)
    active = (slot < n_pos[..., None]).astype(jnp.float32)

    # ---- spanned mesh region (bounding box of occupied cells) -------------
    i_max = jnp.max(jnp.where(active > 0, ci, -_BIG), axis=-1)
    i_min = jnp.min(jnp.where(active > 0, ci, _BIG), axis=-1)
    j_max = jnp.max(jnp.where(active > 0, cj, -_BIG), axis=-1)
    j_min = jnp.min(jnp.where(active > 0, cj, _BIG), axis=-1)
    hops_ai_worst = (i_max - i_min) + (j_max - j_min)   # region diameter

    hops_hbm_mean = jnp.sum(active * d_hbm, axis=-1) / jnp.maximum(n_pos, 1.0)

    # worst over every router of the spanned region (masked to the
    # bounding box) — the Fig.-4 convention, and the exact-degradation
    # anchor to the legacy model.
    cell = jnp.arange(N_CELLS, dtype=jnp.float32)
    gi, gj = jnp.floor(cell / GRID), cell % GRID      # (256,)
    in_box = ((gi >= i_min[..., None]) & (gi <= i_max[..., None])
              & (gj >= j_min[..., None]) & (gj <= j_max[..., None]))
    hops_hbm_worst = jnp.max(jnp.where(in_box, d_cell, -_BIG), axis=-1)

    # ---- chiplet-to-chiplet forwarding (broadcast from the centroid) ------
    sum_ci = jnp.sum(active * ci, axis=-1)
    sum_cj = jnp.sum(active * cj, axis=-1)
    cent_i = sum_ci / jnp.maximum(n_pos, 1.0)
    cent_j = sum_cj / jnp.maximum(n_pos, 1.0)
    d_cent = (jnp.abs(ci - cent_i[..., None])
              + jnp.abs(cj - cent_j[..., None]))

    # ---- per-link contention: operand-streams x hops per mesh link --------
    # 4 HBM-sourced streams per chiplet (Eq. 13) + 1 forwarded AI stream.
    bm = i_max - i_min + 1.0
    bn = j_max - j_min + 1.0
    region_edges = bm * (bn - 1.0) + bn * (bm - 1.0)
    edges = region_edges if mesh_edges is None else jnp.asarray(
        mesh_edges, jnp.float32)
    if mapping is None:
        hops_ai_mean = (jnp.sum(active * d_cent, axis=-1)
                        / jnp.maximum(n_pos, 1.0))
        stream_hops = (4.0 * jnp.sum(active * d_hbm, axis=-1)
                       + jnp.sum(active * d_cent, axis=-1))
    else:
        # mapped traffic: receiver slots swap 3 HBM pulls for 3 streams
        # forwarded from the previous pipeline stage's centroid. With no
        # receivers every added term is an exact 0.0, reproducing the
        # unmapped figures bit-for-bit.
        stage = jnp.clip(jnp.asarray(mapping.stage, jnp.int32),
                         0, mpg.MAX_STAGES - 1)
        oh = (stage[..., None]
              == jnp.arange(mpg.MAX_STAGES)).astype(jnp.float32)
        act_oh = active[..., None] * oh                 # (..., 128, S)
        cnt = jnp.sum(act_oh, axis=-2)                  # (..., S)
        cent_si = (jnp.sum(act_oh * ci[..., None], axis=-2)
                   / jnp.maximum(cnt, 1.0))
        cent_sj = (jnp.sum(act_oh * cj[..., None], axis=-2)
                   / jnp.maximum(cnt, 1.0))
        prev = jnp.clip(stage - 1, 0, mpg.MAX_STAGES - 1)
        recv = (active * (stage > 0).astype(jnp.float32)
                * (jnp.take_along_axis(cnt, prev, axis=-1)
                   > 0).astype(jnp.float32))
        d_prev = (jnp.abs(ci - jnp.take_along_axis(cent_si, prev, axis=-1))
                  + jnp.abs(cj - jnp.take_along_axis(cent_sj, prev,
                                                     axis=-1)))
        n_recv = jnp.sum(recv, axis=-1)
        fwd_hops = jnp.sum(recv * d_prev, axis=-1)
        hops_ai_mean = ((jnp.sum(active * d_cent, axis=-1)
                         + 3.0 * fwd_hops)
                        / (jnp.maximum(n_pos, 1.0) + 3.0 * n_recv))
        stream_hops = (4.0 * jnp.sum(active * d_hbm, axis=-1)
                       - 3.0 * jnp.sum(recv * d_hbm, axis=-1)
                       + jnp.sum(active * d_cent, axis=-1)
                       + 3.0 * fwd_hops)
    link_contention = stream_hops / jnp.maximum(edges, 1.0)

    stats = NoPStats(hops_ai_worst=hops_ai_worst, hops_ai_mean=hops_ai_mean,
                     hops_hbm_worst=hops_hbm_worst,
                     hops_hbm_mean=hops_hbm_mean,
                     link_contention=link_contention,
                     region_edges=region_edges)
    return stats, sum_ci, sum_cj


def nop_stats(placement: Placement, n_positions, hbm_mask,
              arch_type, mesh_edges=None, mapping=None) -> NoPStats:
    """Reduce (hop matrix x Fig.-5 traffic) -> worst/mean latency terms.

    All arguments may carry an identical batch shape; placement leaves
    carry it too (before the slot / anchor axes). ``mesh_edges``
    optionally fixes the contention denominator to a given NoP fabric
    size (defaults to the spanned region's own edge count). ``mapping``
    optionally reshapes the operand-stream traffic (see
    :func:`_stats_tail`); ``None`` is the exact pre-mapping program.
    """
    mask = jnp.asarray(hbm_mask, jnp.int32)
    floors = hbm_floors(mask, arch_type)              # (..., 6)
    bits = _mask_bits(mask)

    # one fused router scan, then per-slot distances are *gathered* from
    # it (chiplet cells are integer grid cells) instead of recomputed —
    # the fast-path fusion of the two-tier NoP refactor.
    _, _, d_cell = _nearest_stack_cells(placement.hbm_ij, floors, bits)

    # per occupied slot: min over placed stacks (the Fig.-5 dataflow pulls
    # operands from the nearest stack), gathered from the cell scan
    d_hbm = jnp.take_along_axis(
        d_cell, jnp.asarray(placement.chiplet_cell, jnp.int32), axis=-1)
    stats, _, _ = _stats_tail(placement.chiplet_cell, d_cell, d_hbm,
                              n_positions, mesh_edges, mapping)
    return stats


def nop_stats_fast(m, n, n_positions, hbm_mask, arch_type,
                   mesh_edges=None) -> NoPStats:
    """Closed-form fast tier: canonical-floorplan NoP stats.

    Equals ``nop_stats(canonical(m, n, ...), ...)`` on every field (the
    canonical row-major fill is derived analytically: cell (i, j) is
    occupied iff ``j < n`` and ``i * n + j < n_positions``, and the fill
    spans the full m x n box for every ``mesh_dims`` factorization), but
    skips the 128-slot pass and never materializes a ``Placement`` —
    one 256-cell scan total, the pre-PR-2 evaluation cost. This is the
    ``nop_fidelity='fast'`` tier of ``costmodel.evaluate``.
    """
    m = jnp.asarray(m, jnp.float32)
    n = jnp.asarray(n, jnp.float32)
    n_pos = jnp.asarray(n_positions, jnp.float32)
    mask = jnp.asarray(hbm_mask, jnp.int32)

    anchors = canonical_anchors(m, n)                 # (..., 6, 2)
    floors = hbm_floors(mask, arch_type)              # (..., 6)
    bits = _mask_bits(mask)
    gi, gj, d_cell = _nearest_stack_cells(anchors, floors, bits)

    mb, nb, pb = m[..., None], n[..., None], n_pos[..., None]
    in_box = (gi < mb) & (gj < nb)
    occ = ((gj < nb) & (gi * nb + gj < pb)).astype(jnp.float32)

    inv = 1.0 / jnp.maximum(n_pos, 1.0)
    hops_hbm_worst = jnp.max(jnp.where(in_box, d_cell, -_BIG), axis=-1)
    sum_hbm = jnp.sum(occ * d_cell, axis=-1)
    hops_hbm_mean = sum_hbm * inv

    # centroid of the canonical row-major fill, closed form: f full rows
    # of n cells plus k leftover cells in row f (sums of integer ranges,
    # exactly representable -> bit-equal to the full tier's slot sums)
    f = jnp.floor(n_pos / jnp.maximum(n, 1.0))
    k = n_pos - f * n
    cent_i = (n * f * (f - 1.0) / 2.0 + k * f) * inv
    cent_j = (f * n * (n - 1.0) / 2.0 + k * (k - 1.0) / 2.0) * inv
    d_cent = (jnp.abs(gi - cent_i[..., None])
              + jnp.abs(gj - cent_j[..., None]))
    sum_cent = jnp.sum(occ * d_cent, axis=-1)
    hops_ai_mean = sum_cent * inv

    hops_ai_worst = (m - 1.0) + (n - 1.0)
    region_edges = m * (n - 1.0) + n * (m - 1.0)
    edges = region_edges if mesh_edges is None else jnp.asarray(
        mesh_edges, jnp.float32)
    link_contention = (4.0 * sum_hbm + sum_cent) / jnp.maximum(edges, 1.0)
    return NoPStats(hops_ai_worst=hops_ai_worst, hops_ai_mean=hops_ai_mean,
                    hops_hbm_worst=hops_hbm_worst, hops_hbm_mean=hops_hbm_mean,
                    link_contention=link_contention,
                    region_edges=region_edges)


# ---------------------------------------------------------------------------
# Mutations (env/PPO actions) and random placements (SA moves)
# ---------------------------------------------------------------------------

def relocate_chiplet(placement: Placement, slot, target_cell,
                     n_positions) -> Placement:
    """Move one active slot to ``target_cell`` (swap with any occupant).

    ``slot`` is reduced mod ``n_positions`` so every action index maps to
    an active slot. If another active slot already occupies the target
    cell, the two swap cells, keeping the placement collision-free.
    Unbatched (vmap for batches).
    """
    cells = placement.chiplet_cell
    n_pos = jnp.maximum(jnp.asarray(n_positions, jnp.int32), 1)
    s = jnp.mod(jnp.asarray(slot, jnp.int32), n_pos)
    tgt = jnp.clip(jnp.asarray(target_cell, jnp.int32), 0, N_CELLS - 1)

    idx = jnp.arange(MAX_SLOTS, dtype=jnp.int32)
    occupied = (cells == tgt) & (idx < n_pos)
    occ_slot = jnp.argmax(occupied)                   # first occupant if any
    has_occ = jnp.any(occupied)

    old = cells[s]
    cells = cells.at[s].set(tgt)
    # swap: the displaced occupant takes the moved slot's old cell
    swap_to = jnp.where(has_occ & (occ_slot != s), old, cells[occ_slot])
    cells = cells.at[occ_slot].set(swap_to)
    return placement._replace(chiplet_cell=cells)


def move_hbm(placement: Placement, hbm_idx, target_cell) -> Placement:
    """Re-anchor one HBM stack at an (integer) grid cell. Unbatched."""
    b = jnp.clip(jnp.asarray(hbm_idx, jnp.int32), 0, N_HBM - 1)
    tgt = jnp.clip(jnp.asarray(target_cell, jnp.int32), 0, N_CELLS - 1)
    ti, tj = cell_ij(tgt)
    new = jnp.stack([ti, tj], axis=-1)
    return placement._replace(hbm_ij=placement.hbm_ij.at[b].set(new))


def apply_action(placement: Placement, pl_action, n_positions) -> Placement:
    """Apply one 4-head placement-mutation action (env/PPO extension).

    ``pl_action`` = [slot, target_cell, hbm_idx, hbm_target_cell] indices
    (the ``PLACEMENT_HEAD_SIZES`` heads). Both mutations apply each step;
    the policy can make either a no-op by targeting the current cell.
    Unbatched (the env vmaps).
    """
    a = jnp.asarray(pl_action, jnp.int32)
    placement = relocate_chiplet(placement, a[..., 0], a[..., 1], n_positions)
    return move_hbm(placement, a[..., 2], a[..., 3])


def random_cell_in_box(key, m, n):
    """Uniform random cell id inside the m x n footprint box."""
    ku, kv = jax.random.split(key)
    i = jnp.floor(jax.random.uniform(ku) * m).astype(jnp.int32)
    j = jnp.floor(jax.random.uniform(kv) * n).astype(jnp.int32)
    return jnp.clip(i, 0, GRID - 1) * GRID + jnp.clip(j, 0, GRID - 1)


def random_hbm_anchor(key, m, n):
    """Uniform random continuous anchor in [-1, m] x [-1, n]."""
    ku, kv = jax.random.split(key)
    i = -1.0 + jax.random.uniform(ku) * (m + 1.0)
    j = -1.0 + jax.random.uniform(kv) * (n + 1.0)
    return jnp.stack([i, j], axis=-1)


def _active_centroid(chiplet_cell, n_positions, cell_sums=None):
    """(i, j) centroid of the active slots' cells. Batch-generic.

    ``cell_sums`` optionally supplies precomputed ``(sum_ci, sum_cj)``
    active-coordinate sums (e.g. from a ``PlacementEvalCache``) — cells
    are small integers, so the sums are exact in float32 and the cached
    value is bit-identical to re-reducing the slot axis here.
    """
    n_pos = jnp.asarray(n_positions, jnp.float32)
    inv = 1.0 / jnp.maximum(n_pos, 1.0)
    if cell_sums is None:
        ci, cj = cell_ij(chiplet_cell)
        slot = jnp.arange(MAX_SLOTS, dtype=jnp.float32)
        active = (slot < n_pos[..., None]).astype(jnp.float32)
        cell_sums = (jnp.sum(active * ci, axis=-1),
                     jnp.sum(active * cj, axis=-1))
    return cell_sums[0] * inv, cell_sums[1] * inv


def traffic_attractor(placement: Placement, n_positions, hbm_mask,
                      cell_sums=None):
    """(i, j) of the placement's traffic centroid.

    The Fig.-5 dataflow pulls 4 operand streams from the nearest HBM
    stack and fans 1 forwarded stream out from the chiplet centroid, so
    the traffic-optimal neighbourhood is between the active-slot centroid
    and the placed stack nearest to it — this returns their midpoint.
    Batch-generic on all arguments. ``cell_sums`` as in
    :func:`_active_centroid`.
    """
    cent_i, cent_j = _active_centroid(placement.chiplet_cell, n_positions,
                                      cell_sums)

    mask = jnp.asarray(hbm_mask, jnp.int32)
    bits = _mask_bits(mask)
    d = (jnp.abs(placement.hbm_ij[..., 0] - cent_i[..., None])
         + jnp.abs(placement.hbm_ij[..., 1] - cent_j[..., None]))
    b = jnp.argmin(jnp.where(bits > 0, d, _BIG), axis=-1)
    hi = jnp.take_along_axis(placement.hbm_ij[..., 0], b[..., None],
                             axis=-1)[..., 0]
    hj = jnp.take_along_axis(placement.hbm_ij[..., 1], b[..., None],
                             axis=-1)[..., 0]
    return 0.5 * (cent_i + hi), 0.5 * (cent_j + hj)


def guided_cell(key, placement: Placement, n_positions, hbm_mask, m, n,
                sigma=1.25, cell_sums=None):
    """Profile-guided relocate target: a cell near the traffic attractor.

    Gaussian jitter (``sigma`` in hops) around :func:`traffic_attractor`,
    rounded and clipped to the m x n footprint box. Unbatched (SA vmaps).
    ``cell_sums`` as in :func:`_active_centroid`.
    """
    ai, aj = traffic_attractor(placement, n_positions, hbm_mask, cell_sums)
    di, dj = sigma * jax.random.normal(key, (2,))
    i = jnp.clip(jnp.round(ai + di), 0.0, m - 1.0).astype(jnp.int32)
    j = jnp.clip(jnp.round(aj + dj), 0.0, n - 1.0).astype(jnp.int32)
    return i * GRID + j


def guided_anchor(key, placement: Placement, n_positions, m, n, sigma=1.25,
                  cell_sums=None):
    """Profile-guided HBM re-anchor: near the active-chiplet centroid.

    A stack serves every chiplet, so its traffic-optimal anchor tracks
    the centroid of the occupied cells (continuous coordinates, clipped
    to the legal [-1, m] x [-1, n] band). Unbatched (SA vmaps).
    ``cell_sums`` as in :func:`_active_centroid`.
    """
    cent_i, cent_j = _active_centroid(placement.chiplet_cell, n_positions,
                                      cell_sums)
    di, dj = sigma * jax.random.normal(key, (2,))
    i = jnp.clip(cent_i + di, -1.0, m)
    j = jnp.clip(cent_j + dj, -1.0, n)
    return jnp.stack([i, j], axis=-1)


def select_placed_bit(key, hbm_mask):
    """Uniformly choose one *set* bit of the HBM mask (for SA moves)."""
    mask = jnp.asarray(hbm_mask, jnp.int32)
    bits = _mask_bits(mask)
    n_set = jnp.maximum(jnp.sum(bits, axis=-1), 1.0)
    k = jnp.floor(jax.random.uniform(key) * n_set) + 1.0    # 1..n_set
    cum = jnp.cumsum(bits, axis=-1)
    return jnp.argmax((cum >= k).astype(jnp.int32), axis=-1)


# ---------------------------------------------------------------------------
# Delta evaluation (incremental NoP stats for the placement SA inner loop)
# ---------------------------------------------------------------------------

class PlacementMove(NamedTuple):
    """One SA/env placement mutation, as data (ISSUE-4 tentpole).

    ``kind`` selects the branch: 0 relocates/swaps chiplet slot ``slot``
    to cell ``cell`` (exact :func:`relocate_chiplet` semantics, slot
    reduced mod n_positions, occupant swapped out), 1 re-anchors HBM
    stack ``hbm`` at the continuous coordinate ``anchor``. The unused
    half of the move is ignored. Unbatched (the SA chain vmaps).
    """

    kind: jnp.ndarray       # () int32: 0 = chiplet move, 1 = HBM re-anchor
    slot: jnp.ndarray       # () int32
    cell: jnp.ndarray       # () int32 target cell id
    hbm: jnp.ndarray        # () int32 stack bit
    anchor: jnp.ndarray     # (2,) float32 target anchor (i, j)


class PlacementEvalCache(NamedTuple):
    """Cached per-slot / per-link state of one full NoP evaluation.

    Carried through the placement-SA ``lax.scan`` so a candidate move is
    scored by *delta* — only the state the move touches is recomputed:

      - ``d_cell``: the hop row reduced over the placed stacks (nearest
        placed-stack distance per router of the 16x16 grid) — the full
        tier's expensive six-anchor scan. A chiplet move reuses it
        verbatim; only an HBM re-anchor rebuilds it.
      - ``d_hbm``: the per-slot gather of ``d_cell`` (each slot's operand
        hop count — the per-slot latency/energy contribution).
      - ``sum_ci``/``sum_cj``: active-cell coordinate sums (exact in
        float32 — integer-valued), serving the profile-guided proposal
        centroid without re-reducing the slot axis.
      - ``stats``: the current placement's :class:`NoPStats` (incl. the
        per-link contention the congestion channel reads).

    Deliberately O(cells), not O(stacks x cells): an earlier fat variant
    cached all six per-stack rows, but selecting/carrying a (6, 256)
    array per accept cost more memory traffic than the one fused scan it
    saved. Every field is reduced with the same ops as a fresh
    :func:`nop_stats`, so cached and recomputed stats agree bit-for-bit
    (the differential-oracle contract of tests/test_placement_delta.py).
    """

    placement: Placement
    d_cell: jnp.ndarray         # (N_CELLS,)
    d_hbm: jnp.ndarray          # (MAX_SLOTS,)
    sum_ci: jnp.ndarray         # ()
    sum_cj: jnp.ndarray         # ()
    stats: NoPStats


def nop_stats_cache(placement: Placement, n_positions, hbm_mask,
                    arch_type, mesh_edges=None,
                    mapping=None) -> PlacementEvalCache:
    """Full evaluation that also returns the cached per-slot/per-link
    state :func:`nop_stats_delta` updates incrementally.

    ``cache.stats`` equals ``nop_stats(placement, ..., mapping)``
    bit-for-bit. The cached geometry (``d_cell`` / ``d_hbm`` / cell
    sums) is mapping-independent, so :func:`nop_stats_remap` can
    re-contract it under a different mapping without touching the anchor
    scan. Unbatched (vmap for batches).
    """
    mask = jnp.asarray(hbm_mask, jnp.int32)
    floors = hbm_floors(mask, arch_type)
    bits = _mask_bits(mask)
    _, _, d_cell = _nearest_stack_cells(placement.hbm_ij, floors, bits)
    d_hbm = jnp.take_along_axis(
        d_cell, jnp.asarray(placement.chiplet_cell, jnp.int32), axis=-1)
    stats, sum_ci, sum_cj = _stats_tail(placement.chiplet_cell, d_cell,
                                        d_hbm, n_positions, mesh_edges,
                                        mapping)
    return PlacementEvalCache(placement=placement, d_cell=d_cell,
                              d_hbm=d_hbm, sum_ci=sum_ci, sum_cj=sum_cj,
                              stats=stats)


def apply_move(placement: Placement, move: PlacementMove,
               n_positions) -> Placement:
    """Apply one :class:`PlacementMove` (the oracle-side mirror of what
    :func:`nop_stats_delta` does to its cached placement). Unbatched."""
    cells_c = relocate_chiplet(placement, move.slot, move.cell,
                               n_positions).chiplet_cell
    b = jnp.clip(jnp.asarray(move.hbm, jnp.int32), 0, N_HBM - 1)
    hbm_h = placement.hbm_ij.at[b].set(
        jnp.asarray(move.anchor, jnp.float32))
    is_hbm = jnp.asarray(move.kind, jnp.int32) > 0
    return Placement(
        chiplet_cell=jnp.where(is_hbm, placement.chiplet_cell, cells_c),
        hbm_ij=jnp.where(is_hbm, hbm_h, placement.hbm_ij))


def nop_stats_delta(cache: PlacementEvalCache, move: PlacementMove,
                    n_positions, hbm_mask, arch_type, mesh_edges=None,
                    move_kinds: str = "mixed",
                    mapping=None) -> PlacementEvalCache:
    """Post-move NoP stats by incremental update — O(slots) per move.

    A chiplet relocate/swap leaves the router scan ``d_cell`` untouched:
    only the moved/swapped slots' gathered distances and the slot-axis
    reductions change, so the six-anchor row scan — the full tier's
    dominant cost — is skipped entirely. An HBM re-anchor rebuilds
    ``d_cell`` with one fused :func:`_nearest_stack_cells` scan over the
    candidate anchors; the slot geometry is reused. Both branches end in
    the shared :func:`_stats_tail`, so the returned ``cache.stats``
    equals a fresh ``nop_stats(apply_move(...), ...)`` bit-for-bit while
    also — via ``costmodel.reward_from_nop`` — skipping the whole
    placement-independent cost-model prefix and the per-move canonical
    baseline.

    ``move_kinds`` statically prunes the dead branch: ``'chiplet'``
    promises ``move.kind == 0`` for every move (no anchor scan is even
    traced — the cheapest path, used by ``PlacementSAConfig(p_hbm=0)``
    relocation-only annealing), ``'hbm'`` promises ``kind == 1``,
    ``'both'`` applies the relocate AND the re-anchor in one fused
    update (``move.kind`` is ignored; one anchor scan + one gather +
    one tail — the cache-carried env-step path, equivalent to
    ``apply_action`` when ``move.anchor`` is the integer cell of the
    fourth action head), and ``'mixed'`` (default) handles the two
    single-move kinds branchlessly. Unbatched (the SA chain vmaps).

    ``mapping`` fixes the dataflow the candidate is contracted against
    (the co-annealing SA passes the chain's *current* mapping; a
    mapping-only move goes through :func:`nop_stats_remap` instead).
    """
    if move_kinds not in ("mixed", "chiplet", "hbm", "both"):
        raise ValueError(f"move_kinds must be 'mixed', 'chiplet', 'hbm' "
                         f"or 'both', got {move_kinds!r}")
    plc = cache.placement
    mask = jnp.asarray(hbm_mask, jnp.int32)
    is_hbm = jnp.asarray(move.kind, jnp.int32) > 0

    # -- chiplet relocate/swap branch: cells change, d_cell reused ---------
    if move_kinds != "hbm":
        cells_c = relocate_chiplet(plc, move.slot, move.cell,
                                   n_positions).chiplet_cell

    # -- HBM re-anchor branch: anchors change, cells reused ----------------
    # (one-hot select, not an .at[] scatter: a vmapped dynamic-index
    # scatter is a serial gather/scatter pair on CPU XLA and was slower
    # than the full recompute it replaced; the select vectorizes)
    if move_kinds != "chiplet":
        floors = hbm_floors(mask, arch_type)
        bits = _mask_bits(mask)
        b = jnp.clip(jnp.asarray(move.hbm, jnp.int32), 0, N_HBM - 1)
        onehot = jnp.arange(N_HBM, dtype=jnp.int32) == b      # (6,)
        anchor = jnp.asarray(move.anchor, jnp.float32)
        hbm_h = jnp.where(onehot[..., None], anchor[..., None, :],
                          plc.hbm_ij)
        _, _, d_cell_h = _nearest_stack_cells(hbm_h, floors, bits)

    # -- branchless select + shared reduction tail -------------------------
    if move_kinds == "chiplet":
        cells_new, hbm_new, d_cell_new = cells_c, plc.hbm_ij, cache.d_cell
    elif move_kinds == "hbm":
        cells_new, hbm_new, d_cell_new = plc.chiplet_cell, hbm_h, d_cell_h
    elif move_kinds == "both":
        cells_new, hbm_new, d_cell_new = cells_c, hbm_h, d_cell_h
    else:
        cells_new = jnp.where(is_hbm, plc.chiplet_cell, cells_c)
        hbm_new = jnp.where(is_hbm, hbm_h, plc.hbm_ij)
        d_cell_new = jnp.where(is_hbm, d_cell_h, cache.d_cell)
    d_hbm_new = jnp.take_along_axis(
        d_cell_new, jnp.asarray(cells_new, jnp.int32), axis=-1)
    stats, sum_ci, sum_cj = _stats_tail(cells_new, d_cell_new, d_hbm_new,
                                        n_positions, mesh_edges, mapping)
    return PlacementEvalCache(
        placement=Placement(chiplet_cell=cells_new, hbm_ij=hbm_new),
        d_cell=d_cell_new, d_hbm=d_hbm_new,
        sum_ci=sum_ci, sum_cj=sum_cj, stats=stats)


def nop_stats_remap(cache: PlacementEvalCache, mapping, n_positions,
                    mesh_edges=None) -> PlacementEvalCache:
    """Re-contract the cached traffic rows under a new mapping.

    A mapping move leaves the placement — and with it the anchor scan
    ``d_cell``, the per-slot gather ``d_hbm``, and the cell sums —
    untouched; only the :func:`_stats_tail` contraction changes (the
    touched stage boundary's traffic rows re-weight). This is the
    cheapest delta kind of the co-annealing SA: no anchor scan, no
    gather. ``cache.stats`` of the result equals a fresh
    ``nop_stats(cache.placement, ..., mapping=mapping)`` bit-for-bit
    (shared tail). Unbatched (the SA chain vmaps).
    """
    stats, _, _ = _stats_tail(cache.placement.chiplet_cell, cache.d_cell,
                              cache.d_hbm, n_positions, mesh_edges,
                              mapping)
    return cache._replace(stats=stats)


def commit_move(cache: PlacementEvalCache, cand: PlacementEvalCache,
                accept) -> PlacementEvalCache:
    """Accept/reject select: keep the candidate cache iff ``accept``.

    A plain elementwise select over the O(cells) cache pytree — the SA
    step's only per-accept cost. Unbatched (vmap for batches).
    """
    acc = jnp.asarray(accept)
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(acc, a, b), cand, cache)


# ---------------------------------------------------------------------------
# Flat codec (serialization, kernel packing)
# ---------------------------------------------------------------------------

def to_flat(placement: Placement) -> jnp.ndarray:
    """(..., FLAT_DIM) float32: [cells | hbm i/j interleaved]."""
    cells = jnp.asarray(placement.chiplet_cell, jnp.float32)
    hbm = placement.hbm_ij.reshape(*placement.hbm_ij.shape[:-2], 2 * N_HBM)
    return jnp.concatenate([cells, hbm], axis=-1)


def from_flat(flat: jnp.ndarray) -> Placement:
    """Inverse of :func:`to_flat`."""
    cells = jnp.asarray(flat[..., :MAX_SLOTS], jnp.int32)
    hbm = flat[..., MAX_SLOTS:FLAT_DIM].reshape(*flat.shape[:-1], N_HBM, 2)
    return Placement(chiplet_cell=cells, hbm_ij=jnp.asarray(hbm, jnp.float32))
