"""Hardware constants for the Chiplet-Gym analytical PPAC model.

Every number here is either (a) taken verbatim from the paper (Tables 3, 4,
Section 5.1), or (b) a calibration decision documented in DESIGN.md §5 made
to reproduce the paper's stated anchor results (yields 48 %/97 %/98 %,
1.52x 3D logic density, 75 % yield @ 400 mm^2 @ 14 nm, ...).

Units convention (soft types, everything is plain float so the model stays
jnp-traceable):
    area        mm^2
    energy      pJ  (per bit / per op)
    delay       ns
    data rate   Gbps
    cost        $ (arbitrary but consistent unit, P0-normalized)
    frequency   GHz
"""

from __future__ import annotations

import dataclasses

# ---------------------------------------------------------------------------
# Package geometry (paper §5.1)
# ---------------------------------------------------------------------------

PACKAGE_AREA_MM2 = 900.0          # fixed package area dedicated to AI + HBM
CHIPLET_SPACING_MM = 1.0          # 1 mm spacing to avoid thermal hotspots
MAX_CHIPLET_AREA_MM2 = 400.0      # yield >= 75 % @ 14 nm constraint (Fig. 3)
COMPUTE_AREA_FRAC = 0.40          # 40 % compute
SRAM_AREA_FRAC = 0.40             # 40 % on-chip SRAM
OTHER_AREA_FRAC = 0.20            # control / IO / NoC / routing
TSV_AREA_MM2 = 2.0                # <=2 mm^2 reserved for TSV in 3D stacks
TSV_KEEPOUT_FRAC = 0.24           # keep-out overhead per 3D-stacked die.
                                  # 2 tiers x (1 - 0.24) = 1.52x logic
                                  # density — matches the paper's 1.52x.

# HBM chiplet: 16 GB (8-stack x 16 Gb) HBM3, integrated memory controller.
HBM_CAPACITY_GB = 16.0
HBM_FOOTPRINT_MM2 = 26.0          # calibrated: reproduces the paper's die
                                  # sizes 26 mm^2 (60-chiplet) and ~14 mm^2
                                  # (112-chiplet) with 4 HBMs placed in 2.5D.
MAX_HBM_CHIPLETS = 6              # 6 candidate locations (2^6-1 placements)
HBM_BANDWIDTH_GBPS_PER_STACK = 6553.0   # HBM3 ~819 GB/s/stack

# ---------------------------------------------------------------------------
# Compute micro-architecture (paper §5.3.2: 14 nm PDK @ 1 GHz; cost scaled
# to 7 nm for iso-comparison with the A100-class monolithic baseline)
# ---------------------------------------------------------------------------

FREQ_GHZ = 1.0                    # all chiplets at 1 GHz (paper synthesis)
PE_AREA_UM2_14NM = 2400.0         # MAC + register file @ 14 nm
PE_AREA_UM2_7NM = 1200.0          # ~2x density scaling 14 nm -> 7 nm
E_OP_PJ = 0.8                     # energy per MAC (incl. regfile) @ 14 nm
E_OP_PJ_7NM = 0.55                # scaled MAC energy @ 7 nm
SRAM_MB_PER_MM2 = 1.0             # on-chip SRAM density (14 nm, with ECC)
DATA_WIDTH_BITS = 16.0            # bf16 operands
N_OPERANDS = 2.0                  # N_o of Eq. 13 (two multiplier operands)

# Systolic-array operand reuse: an operand streamed into a k x k array is
# reused ~k times (weight-stationary row/column reuse). reuse = sqrt(PE_tot)
# is the amortization factor applied to both the BW requirement (Eq. 13)
# and the per-op communication latency/energy (Eqs. 5, 15).
# (Documented simplification — the paper amortizes implicitly.)

# ---------------------------------------------------------------------------
# NoP latency (paper Table 3 + Kite-style router constants)
# ---------------------------------------------------------------------------

WIRE_DELAY_PS_PER_MM_2P5D = 17.2  # Table 3: 1 mm hop -> 17.2 ps
WIRE_DELAY_PS_2P5D = 17.2         # per-hop @ 1 mm
WIRE_DELAY_PS_3D = 1.6            # Table 3: 0.08 mm hop -> 1.6 ps
ROUTER_DELAY_NS = 2.0             # t_r: ~2 cycles @ 1 GHz (Kite-class)
CONTENTION_DELAY_NS = 1.0         # T_c: fixed estimate (workload-level)
SERIALIZATION_DELAY_NS = 0.5      # T_s: flit serialization estimate

# ---------------------------------------------------------------------------
# Interconnect families (paper Table 4)
# index order: [CoWoS, EMIB] for 2.5D; [SoIC, FOVEROS] for 3D
# ---------------------------------------------------------------------------

# Energy per bit at minimum (1 mm) trace; linearly interpolated to the max
# of the Table-4 range at 10 mm trace (E_bit ∝ trace length, §3.4.2).
E_BIT_PJ_2P5D_MIN = (0.20, 0.17)  # CoWoS, EMIB  @ 1 mm
E_BIT_PJ_2P5D_MAX = (0.50, 0.70)  # CoWoS, EMIB  @ 10 mm
E_BIT_PJ_3D = (0.15, 0.04)        # SoIC (0.1~0.2 mid), FOVEROS (<0.05)

BUMP_PITCH_UM_2P5D = (35.0, 50.0)     # CoWoS 30-40, EMIB 45-55 (mid)
BOND_PITCH_UM_3D = (9.0, 10.0)        # SoIC hybrid bond 9 um, FOVEROS <10 um

# HBM device-side access energy (core + PHY), on top of the link energy.
E_BIT_PJ_HBM_DEVICE = 3.5
# Off-board (PCB / NVLink-class) link energy: one order of magnitude above
# on-package 2.5D (paper [4]): 10 x CoWoS-mid 0.35 pJ/bit.
E_BIT_PJ_OFFBOARD = 3.5

# ---------------------------------------------------------------------------
# Yield / die cost (paper Eq. 8-9; calibration in DESIGN.md §5)
# ---------------------------------------------------------------------------

YIELD_ALPHA = 3.0                 # negative-binomial cluster parameter
DEFECT_DENSITY_PER_CM2 = {        # reproduces the paper's stated yields
    "7nm": 0.10,                  # 48 % @ 826 mm^2, 97 % @ 26, 98 % @ 14
    "14nm": 0.0754,               # 75 % @ 400 mm^2 (Fig. 3 anchor)
}
WAFER_PRICE_PER_MM2 = {           # $ per mm^2 of *candidate* silicon
    "7nm": 0.25,                  # ~$17k / 300 mm wafer
    "14nm": 0.09,
}
KGD_TEST_COST_FRAC = 0.05         # known-good-die test cost adder

# ---------------------------------------------------------------------------
# Packaging cost regression C_P = mu0*A_P + mu1*L + mu2   (paper Eq. 16,
# regression-parameter structure from Tang & Xie; values calibrated so the
# optimized 60-chiplet EMIB+SoIC package lands ~1.6x the monolithic CoWoS
# package — the paper's stated 1.62x)
# index order: [CoWoS, EMIB]
# ---------------------------------------------------------------------------

PKG_MU0_PER_MM2 = (0.35, 0.035)       # interposer vs bridge: area term.
                                      # CoWoS pays a full-area silicon
                                      # interposer; EMIB only embeds small
                                      # bridges (CAL: yields the paper's
                                      # ~1.6x chiplet/mono package ratio)
PKG_MU1_PER_LINK = (0.0018, 0.0012)   # per-link routing/layer term
PKG_MU2_FIXED = (18.0, 8.0)           # NRE-ish fixed term per package
PKG_MU1_PER_LINK_3D = (0.0005, 0.00065)  # SoIC, FOVEROS per-bond term
PKG_3D_FIXED_PER_STACK = (1.5, 2.0)      # per-stack bonding/processing cost

BOND_YIELD = 0.99                 # chiplet I/O pad bonding yield (paper)
BOND_YIELD_PERFECT = 1.0          # TSMC near-perfect hybrid bonding / repair

# ---------------------------------------------------------------------------
# Reward normalization (Eq. 17). The paper reports cost-model values of
# ~150-190 for alpha,beta,gamma=[1,1,0.1]; these scales put our metrics in
# the same numeric regime (calibration, not physics).
# ---------------------------------------------------------------------------

REWARD_THROUGHPUT_SCALE = 0.7     # per effective TOPS
REWARD_COST_SCALE = 1.0           # per $ of packaging cost
REWARD_ENERGY_SCALE = 10.0        # per pJ/op of communication energy

# ---------------------------------------------------------------------------
# Monolithic baseline (A100-class, paper §5.3.2)
# ---------------------------------------------------------------------------

MONO_DIE_AREA_MM2 = 826.0
MONO_TECH = "7nm"
MONO_HBM_COUNT = 4                # iso-memory with the 4-HBM chiplet design
MONO_FREQ_GHZ = 1.0

# TPU v5e-class roofline constants (for analysis/roofline.py, not the
# chiplet cost model): see assignment spec.
TPU_PEAK_FLOPS_BF16 = 197e12
TPU_HBM_BW_BYTES = 819e9
TPU_ICI_BW_BYTES_PER_LINK = 50e9


@dataclasses.dataclass(frozen=True)
class HWConfig:
    """Bundles the tunable constants so experiments can override them."""

    package_area_mm2: float = PACKAGE_AREA_MM2
    max_chiplet_area_mm2: float = MAX_CHIPLET_AREA_MM2
    hbm_footprint_mm2: float = HBM_FOOTPRINT_MM2
    compute_area_frac: float = COMPUTE_AREA_FRAC
    tsv_area_mm2: float = TSV_AREA_MM2
    tsv_keepout_frac: float = TSV_KEEPOUT_FRAC
    freq_ghz: float = FREQ_GHZ
    pe_area_um2: float = PE_AREA_UM2_7NM     # cost & density @ 7 nm for the
    e_op_pj: float = E_OP_PJ_7NM             # iso-node A100 comparison
    data_width_bits: float = DATA_WIDTH_BITS
    n_operands: float = N_OPERANDS
    router_delay_ns: float = ROUTER_DELAY_NS
    contention_delay_ns: float = CONTENTION_DELAY_NS
    serialization_delay_ns: float = SERIALIZATION_DELAY_NS
    wire_delay_ps_2p5d: float = WIRE_DELAY_PS_2P5D
    wire_delay_ps_3d: float = WIRE_DELAY_PS_3D
    e_bit_hbm_device_pj: float = E_BIT_PJ_HBM_DEVICE
    yield_alpha: float = YIELD_ALPHA
    defect_density_per_cm2: float = DEFECT_DENSITY_PER_CM2["7nm"]
    wafer_price_per_mm2: float = WAFER_PRICE_PER_MM2["7nm"]
    bond_yield: float = BOND_YIELD
    reward_throughput_scale: float = REWARD_THROUGHPUT_SCALE
    reward_cost_scale: float = REWARD_COST_SCALE
    reward_energy_scale: float = REWARD_ENERGY_SCALE
    # Operand-traffic amortization mode. True (default) amortizes interconnect
    # traffic by systolic reuse (physically defensible). False reproduces the
    # paper's literal Eq. 13 (every MAC pulls N_o fresh operands through the
    # package fabric) — used by bench_mlperf's "paper-mode" headline numbers.
    comm_reuse_systolic: bool = True
    # Exponent e in cycles/op = 1 + L*f/reuse^e (Eq. 5 amortization). e=2
    # amortizes a transfer over a full k x k weight tile (double-buffered,
    # streaming NoP — latency mostly hidden); e=1 charges it per operand
    # row (paper-literal, latency-pessimistic). Default tile-level.
    latency_amort_exp: float = 2.0
    # Cap AI2HBM bandwidth at the physical per-stack HBM3 peak (819 GB/s).
    # The paper sizes bandwidth purely by links x data-rate; disable to
    # reproduce its headline utilization numbers.
    hbm_peak_cap: bool = True
    # NoP congestion sensitivity of the pairwise-traffic placement model:
    # delivered 2.5D link bandwidth scales with
    # (canonical_link_contention / link_contention) ** nop_congestion_exp,
    # i.e. a placement that lowers the traffic-weighted channel load below
    # the canonical Fig.-4 floorplan's sustains proportionally more
    # concurrent operand streams (and vice versa). The factor is exactly 1
    # under the canonical placement, preserving every paper number; 0
    # disables the channel entirely.
    nop_congestion_exp: float = 1.0


DEFAULT_HW = HWConfig()
