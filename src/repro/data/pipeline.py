"""Data pipeline: deterministic synthetic LM streams + host sharding.

A production loader is mostly plumbing around three invariants, all
implemented and tested here:

  - **determinism**: batch t of stream (seed, shard) is a pure function of
    (seed, shard, t) — restart-safe without data-state checkpoints (the
    trainer checkpoints only the step counter),
  - **host sharding**: each data-parallel host pulls a disjoint shard
    (shard = process_index on a real pod),
  - **packing**: documents of random length packed into fixed (B, L+1)
    token panels with EOS separators; labels = inputs shifted by one;
    loss mask zeroes cross-document boundaries.

Modality stubs for the [vlm]/[audio] archs produce the precomputed
patch/frame embeddings the assignment specifies.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig

EOS = 2


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch_size: int = 8            # per shard
    seq_len: int = 128
    vocab_size: int = 512
    seed: int = 0
    shard: int = 0
    n_shards: int = 1
    mean_doc_len: int = 64


def synthetic_batch(cfg: DataConfig, step: int) -> Dict[str, jnp.ndarray]:
    """Pure function (cfg, step) -> packed LM batch."""
    seed = np.uint32(
        (cfg.seed * 1_000_003 + cfg.shard * 7_919 + step) & 0x7FFFFFFF)
    rng = np.random.default_rng(seed)
    total = cfg.batch_size * (cfg.seq_len + 1)
    toks = rng.integers(3, cfg.vocab_size, size=total, dtype=np.int32)
    # EOS-separated documents of geometric length
    pos = 0
    while pos < total:
        doc = max(int(rng.geometric(1.0 / cfg.mean_doc_len)), 2)
        pos += doc
        if pos < total:
            toks[pos - 1] = EOS
    panel = toks.reshape(cfg.batch_size, cfg.seq_len + 1)
    tokens = jnp.asarray(panel[:, :-1])
    labels = jnp.asarray(panel[:, 1:])
    mask = jnp.asarray((panel[:, 1:] != EOS).astype(np.float32))
    return {"tokens": tokens, "labels": labels, "loss_mask": mask}


def add_modality_stub(batch: Dict, arch: ArchConfig, bsz: int,
                      seq: int, key=None) -> Dict:
    """Attach precomputed patch/frame embeddings per the frontend stub."""
    key = key if key is not None else jax.random.PRNGKey(0)
    if arch.frontend == "vision_patches":
        batch = dict(batch)
        batch["patch_embeds"] = 0.02 * jax.random.normal(
            key, (bsz, arch.frontend_tokens, arch.d_model), jnp.float32)
    elif arch.frontend == "audio_frames" and arch.is_encdec:
        batch = dict(batch)
        batch["enc_frames"] = 0.02 * jax.random.normal(
            key, (bsz, seq, arch.d_model), jnp.float32)
    return batch


class DataLoader:
    """Iterator facade with prefetch-like lookahead (synchronous here;
    on a pod this wraps an async host thread)."""

    def __init__(self, cfg: DataConfig, arch: Optional[ArchConfig] = None):
        self.cfg = cfg
        self.arch = arch
        self.step = 0

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        return self

    def __next__(self) -> Dict[str, jnp.ndarray]:
        batch = synthetic_batch(self.cfg, self.step)
        if self.arch is not None:
            batch = add_modality_stub(batch, self.arch, self.cfg.batch_size,
                                      self.cfg.seq_len,
                                      jax.random.PRNGKey(self.step))
        self.step += 1
        return batch

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, state: dict):
        self.step = int(state["step"])
