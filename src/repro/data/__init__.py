"""Data pipeline: deterministic synthetic streams, host sharding, packing."""
