"""h2o-danube-3-4b [dense]: 24L d_model=3840 32H (GQA kv=8) d_ff=10240,
sliding-window attention. [arXiv:2401.16818; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
    d_ff=10_240, vocab_size=32_000,
    attention="gqa", rope_theta=1e4, sliding_window=4_096,
    act="swiglu", norm="rmsnorm",
    source="arXiv:2401.16818 (llama+mistral mix, SWA)",
)
