"""starcoder2-3b [dense]: 30L d_model=3072 24H (GQA kv=2) d_ff=12288.
[arXiv:2402.19173; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
    d_ff=12_288, vocab_size=49_152,
    attention="gqa", qkv_bias=True, rope_theta=1e5,
    act="gelu", norm="layernorm",
    source="arXiv:2402.19173 (GQA, RoPE, GELU MLP)",
)
