"""Architecture registry: the 10 assigned archs + the paper's own workload."""

from repro.configs.base import (ArchConfig, ShapeConfig, SHAPES,
                                SHAPES_BY_NAME, shape_applicable)
from repro.configs.mamba2_130m import CONFIG as MAMBA2_130M
from repro.configs.qwen2_0_5b import CONFIG as QWEN2_0_5B
from repro.configs.starcoder2_3b import CONFIG as STARCODER2_3B
from repro.configs.h2o_danube3_4b import CONFIG as H2O_DANUBE3_4B
from repro.configs.llama3_8b import CONFIG as LLAMA3_8B
from repro.configs.qwen3_moe_235b import CONFIG as QWEN3_MOE_235B
from repro.configs.deepseek_v2_lite import CONFIG as DEEPSEEK_V2_LITE
from repro.configs.llava_next_mistral_7b import CONFIG as LLAVA_NEXT_MISTRAL_7B
from repro.configs.seamless_m4t_v2 import CONFIG as SEAMLESS_M4T_V2
from repro.configs.hymba_1_5b import CONFIG as HYMBA_1_5B
from repro.configs.chipletgym import CONFIG as CHIPLETGYM

ARCH_REGISTRY = {
    c.name: c for c in (
        MAMBA2_130M, QWEN2_0_5B, STARCODER2_3B, H2O_DANUBE3_4B, LLAMA3_8B,
        QWEN3_MOE_235B, DEEPSEEK_V2_LITE, LLAVA_NEXT_MISTRAL_7B,
        SEAMLESS_M4T_V2, HYMBA_1_5B,
    )
}

# the paper's own RL workload is dry-runnable but not an LM cell
EXTRA_REGISTRY = {CHIPLETGYM.name: CHIPLETGYM}


def get(name: str) -> ArchConfig:
    if name in ARCH_REGISTRY:
        return ARCH_REGISTRY[name]
    if name in EXTRA_REGISTRY:
        return EXTRA_REGISTRY[name]
    raise KeyError(f"unknown arch '{name}'; have "
                   f"{sorted(ARCH_REGISTRY) + sorted(EXTRA_REGISTRY)}")
