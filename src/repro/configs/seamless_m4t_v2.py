"""seamless-m4t-large-v2 [audio]: enc-dec 24L+24L d_model=1024 16H
d_ff=8192 vocab=256206; audio frontend STUB (precomputed frame embeddings).
[arXiv:2308.11596; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8_192, vocab_size=256_206,
    attention="gqa", rope_theta=1e4,
    encoder_layers=24,
    frontend="audio_frames", frontend_tokens=0,   # encoder input = frames
    act="gelu", norm="layernorm",
    source="arXiv:2308.11596 (enc-dec, multimodal; frontend stubbed)",
)
