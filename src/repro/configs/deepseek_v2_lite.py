"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H, MLA kv_lora=512,
2 shared + 64 routed experts top-6, expert d_ff=1408, first layer dense.
[arXiv:2405.04434; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=10_944, vocab_size=102_400,
    attention="mla", kv_lora_rank=512, qk_rope_head_dim=64, v_head_dim=128,
    rope_theta=1e4,
    n_experts=64, n_experts_per_tok=6, n_shared_experts=2,
    moe_d_ff=1_408, first_dense_layers=1,
    act="swiglu", norm="rmsnorm",
    source="arXiv:2405.04434 (MLA kv_lora=512, 2 shared + routed top-6)",
)
