"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4),
128 experts top-8, expert d_ff=1536. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1_536, vocab_size=151_936,
    attention="gqa", rope_theta=1e6,
    n_experts=128, n_experts_per_tok=8, moe_d_ff=1_536,
    act="swiglu", norm="rmsnorm",
    source="hf:Qwen/Qwen3 MoE family (128e top-8)",
)
