"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504,
parallel attention + mamba heads per layer, ssm_state=16, SWA with
periodic global layers. [arXiv:2411.13676; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5_504, vocab_size=32_001,
    attention="gqa", rope_theta=1e4,
    sliding_window=1_024, global_layer_every=16,   # layers 0,16 (+ last) full
    mixer="hybrid_parallel",
    ssm_state=16, ssm_head_dim=50, ssm_expand=2, ssm_conv=4,
    act="swiglu", norm="rmsnorm",
    source="arXiv:2411.13676 (parallel attn+mamba heads)",
)
