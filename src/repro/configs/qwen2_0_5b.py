"""qwen2-0.5b [dense]: 24L d_model=896 14H (GQA kv=2) d_ff=4864.
[arXiv:2407.10671; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-0.5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4_864, vocab_size=151_936,
    attention="gqa", qkv_bias=True, rope_theta=1e6,
    act="swiglu", norm="rmsnorm", tie_embeddings=True,
    source="arXiv:2407.10671 (GQA, QKV bias)",
)
