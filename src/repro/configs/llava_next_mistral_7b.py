"""llava-next-mistral-7b [vlm]: mistral-7B backbone (32L d_model=4096 32H
GQA kv=8 d_ff=14336), anyres vision tiling as a STUB frontend delivering
precomputed patch embeddings. [hf:llava-hf/llava-v1.6-mistral-7b-hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14_336, vocab_size=32_000,
    attention="gqa", rope_theta=1e6,
    frontend="vision_patches", frontend_tokens=2_880,   # 5 anyres tiles x 576
    act="swiglu", norm="rmsnorm",
    source="hf:llava-v1.6-mistral-7b (anyres tiling; frontend stubbed)",
)
