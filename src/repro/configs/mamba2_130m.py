"""mamba2-130m [ssm]: 24L d_model=768, attention-free SSD, ssm_state=128.
[arXiv:2405.21060; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=0, vocab_size=50_280,
    attention="none", mixer="mamba2",
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
    norm="rmsnorm", tie_embeddings=True,
    source="arXiv:2405.21060 (SSD / state-space duality)",
)
