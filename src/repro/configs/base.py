"""Unified architecture config for the 10 assigned architectures.

One frozen dataclass covers dense / MoE / SSM / hybrid / enc-dec / VLM /
audio families; each ``src/repro/configs/<id>.py`` instantiates the exact
published configuration. ``reduced()`` yields the same *family* at smoke
scale (tests run one forward/train step on CPU).

``param_count`` / ``active_param_count`` / ``flops_per_token`` feed both
the Chiplet-Gym workload descriptors (core/workload.py) and the roofline's
MODEL_FLOPS = 6*N*D accounting (analysis/roofline.py).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads

    # attention flavour
    attention: str = "gqa"            # gqa | mla | none
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0           # 0 = full attention
    global_layer_every: int = 0       # hybrid: every k-th layer full attn

    # MLA (DeepSeek-V2)
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 64
    v_head_dim: int = 0               # 0 -> head_dim

    # MoE
    n_experts: int = 0
    n_experts_per_tok: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0                 # per-expert FFN width
    first_dense_layers: int = 0       # leading dense layers (DeepSeek)

    # SSM (Mamba-2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4

    mixer: str = "attention"          # attention | mamba2 | hybrid_parallel

    # encoder-decoder
    encoder_layers: int = 0           # >0 -> enc-dec (n_layers = decoder)

    # modality frontend (STUB: precomputed embeddings via input_specs)
    frontend: str = "none"            # none | vision_patches | audio_frames
    frontend_tokens: int = 0

    act: str = "swiglu"               # swiglu | gelu
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    tie_embeddings: bool = False
    source: str = ""                  # provenance note from the assignment

    # ------------------------------------------------------------------ #
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.v_head_dim == 0:
            object.__setattr__(self, "v_head_dim", self.head_dim)

    # --- derived dims -------------------------------------------------- #
    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM / SWA / hybrid)."""
        if self.mixer in ("mamba2", "hybrid_parallel"):
            return True
        return self.sliding_window > 0 and self.global_layer_every == 0

    # --- parameter accounting ------------------------------------------ #
    def _attn_params(self) -> int:
        d, hd = self.d_model, self.head_dim
        if self.attention == "mla":
            kvr, rhd, vhd = self.kv_lora_rank, self.qk_rope_head_dim, \
                self.v_head_dim
            p = d * self.n_heads * (hd + rhd)              # q proj
            p += d * (kvr + rhd)                           # kv down + k_rope
            p += kvr * self.n_heads * (hd + vhd)           # kv up
            p += self.n_heads * vhd * d                    # o proj
            return p
        q = d * self.n_heads * hd
        kv = 2 * d * self.n_kv_heads * hd
        o = self.n_heads * hd * d
        return q + kv + o

    def _mlp_params(self, width: Optional[int] = None) -> int:
        ff = width if width is not None else self.d_ff
        mult = 3 if self.act == "swiglu" else 2
        return mult * self.d_model * ff

    def _ssm_params(self) -> int:
        d, di = self.d_model, self.ssm_d_inner
        n, h = self.ssm_state, self.ssm_heads
        in_proj = d * (2 * di + 2 * n + h)   # x, z, B, C, dt
        conv = self.ssm_conv * (di + 2 * n)
        out = di * d
        return in_proj + conv + out + 2 * h  # + A_log, D

    def _layer_params(self, layer_idx: int) -> int:
        p = 2 * self.d_model                              # norms
        if self.mixer == "mamba2":
            return p + self._ssm_params()
        if self.mixer == "hybrid_parallel":
            return p + self._attn_params() + self._ssm_params() \
                + self._mlp_params()
        p += self._attn_params()
        if self.n_experts > 0 and layer_idx >= self.first_dense_layers:
            p += self.n_experts * self._mlp_params(self.moe_d_ff)
            p += self.n_shared_experts * self._mlp_params(self.moe_d_ff)
            p += self.d_model * self.n_experts            # router
        else:
            p += self._mlp_params()
        return p

    def param_count(self) -> int:
        p = self.vocab_size * self.d_model                # embedding
        if not self.tie_embeddings:
            p += self.vocab_size * self.d_model           # lm head
        p += sum(self._layer_params(i) for i in range(self.n_layers))
        if self.is_encdec:
            enc_layer = (2 * self.d_model + self._attn_params()
                         + self._mlp_params())
            cross = self.n_layers * (self._attn_params() + self.d_model)
            p += self.encoder_layers * enc_layer + cross
        p += self.d_model                                 # final norm
        return p

    def active_param_count(self) -> int:
        """Per-token active params (MoE: top-k + shared only)."""
        if self.n_experts == 0:
            return self.param_count()
        p = self.param_count()
        moe_layers = self.n_layers - self.first_dense_layers
        inactive = (self.n_experts - self.n_experts_per_tok)
        p -= moe_layers * inactive * self._mlp_params(self.moe_d_ff)
        return p

    def flops_per_token(self, seq_len: int = 4096) -> float:
        """Forward FLOPs per token: 2*N_active(non-embed) + attention."""
        n_active = self.active_param_count() \
            - self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        flops = 2.0 * n_active
        flops += 2.0 * self.vocab_size * self.d_model     # lm head matmul
        if self.mixer != "mamba2" and self.attention != "none":
            eff_ctx = min(seq_len, self.sliding_window) \
                if self.sliding_window > 0 else seq_len
            per_layer = 2.0 * 2.0 * self.n_heads * self.head_dim * eff_ctx / 2
            flops += self.n_layers * per_layer
        if self.mixer in ("mamba2", "hybrid_parallel"):
            per_layer = 2.0 * self.ssm_d_inner * self.ssm_state * 2
            flops += self.n_layers * per_layer
        return flops

    # --- smoke-scale family twin ---------------------------------------- #
    def reduced(self) -> "ArchConfig":
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=512,
            kv_lora_rank=32 if self.kv_lora_rank else 0,
            qk_rope_head_dim=8 if self.attention == "mla" else 64,
            v_head_dim=16,
            n_experts=4 if self.n_experts else 0,
            n_experts_per_tok=2 if self.n_experts else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            moe_d_ff=32 if self.moe_d_ff else 0,
            first_dense_layers=min(self.first_dense_layers, 1),
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            sliding_window=32 if self.sliding_window else 0,
            global_layer_every=2 if self.global_layer_every else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            frontend_tokens=8 if self.frontend_tokens else 0,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runs?, reason). long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 512k-token KV cache / "
                       "quadratic attention — skipped per assignment")
    return True, ""
