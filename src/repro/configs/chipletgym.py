"""chipletgym [rl]: the paper's own training workload — distributed PPO
over the Chiplet-Gym environment (policy [10,64,64,591], value
[10,64,64,1], Table-5 hyper-parameters). Dry-runs the rl/distributed.py
pod update alongside the 10 assigned LM architectures."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chipletgym", family="rl",
    n_layers=2, d_model=64, n_heads=1, n_kv_heads=1,
    d_ff=64, vocab_size=591,
    attention="none", mixer="attention",
    source="this paper (Chiplet-Gym PPO)",
)
