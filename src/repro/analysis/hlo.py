"""HLO program-cost analysis: while-aware FLOPs / bytes / collectives.

XLA's ``compiled.cost_analysis()`` reports a scan body ONCE — it does not
multiply by loop trip counts (verified: an 8-iteration scan of matmuls
reports one matmul). Every model here scans over layers, loss chunks and
attention chunks, so we walk the optimized HLO ourselves:

  1. parse computations and build a name -> shape symbol table,
  2. per computation: dot FLOPs (2 * numel(result) * K_contracted),
     HBM-traffic proxy bytes (operand+result bytes of fusion / dot /
     custom-call / copy / dynamic-(update-)slice ops — post-fusion, each
     such op's operands/results cross HBM on TPU), and collective operand
     bytes by kind,
  3. propagate through the call graph with ``while`` trip-count
     multipliers (``backend_config={"known_trip_count":{"n":...}}``).

All counts are per *device* (the SPMD-partitioned module).
"""

from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# Ops whose operands/results represent real HBM traffic on the TPU
# target. Elementwise chains (multiply/add/convert/broadcast/reshape/...)
# fuse into their consumers on TPU and are deliberately excluded — the
# CPU backend under-fuses, and counting its raw elementwise ops would
# overstate the memory term ~100x (measured on qwen2 train_4k).
_BYTES_OPS = {
    "fusion", "dot", "custom-call", "copy", "dynamic-update-slice",
    "dynamic-slice", "convolution", "scatter", "gather",
} | set(COLLECTIVE_KINDS) | {k + "-start" for k in COLLECTIVE_KINDS}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(
    r"^\s*(?:ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
# type group is lazy: tuple types can contain `/*index=N*/` comments and
# layout braces; the op name is the last bare word before the operand paren
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*"
    r"(.*?)\s+"
    r"([\w\-]+)\(([^)]*)\)(.*)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_CALLEE_RE = re.compile(r"(?:calls=|to_apply=|condition=|body=|branch_"
                        r"computations=\{)(%[\w.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _split_operands(operands_str: str) -> List[str]:
    """Split an operand list on TOP-LEVEL commas only.

    Inline operand types contain commas inside brackets
    (``f32[16,16]{1,0} %gte.3``); a naive ``str.split(",")`` shatters
    them and the trailing-token name extraction then yields ``"16]{1"``
    instead of ``%gte.3``, silently dropping every shape lookup for
    rank>=2 operands (dot K-dims, operand bytes).
    """
    out, cur, depth = [], [], 0
    for ch in operands_str:
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
            continue
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth = max(0, depth - 1)
        cur.append(ch)
    out.append("".join(cur))
    return [o.strip() for o in out if o.strip()]


def _parse_shape(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype in _DTYPE_BYTES:
            out.append((dtype,
                        [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _parse_shape(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


def _numel(type_str: str) -> int:
    total = 0
    for _dtype, dims in _parse_shape(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class _Comp:
    name: str
    flops: float = 0.0
    bytes: float = 0.0
    score_bytes: float = 0.0
    bytes_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    # (callee, multiplier) edges
    calls: List[Tuple[str, float]] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ProgramCosts:
    flops: float
    bytes: float
    collective_bytes: float
    collective_breakdown: Dict[str, float]
    n_whiles: int
    unknown_trip_whiles: int
    bytes_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    # HBM bytes attributable to materialized attention-score tensors
    # (rank>=4, both trailing dims >= 512). The Pallas flash kernel keeps
    # these in VMEM on the TPU target; `bytes - score_bytes` is the
    # kernel-adjusted memory term used by the §Perf flash iteration.
    score_bytes: float = 0.0


def _score_like_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _parse_shape(type_str):
        if len(dims) >= 4 and dims[-1] >= 512 and dims[-2] >= 512:
            n = 1
            for d in dims:
                n *= d
            total += n * _DTYPE_BYTES[dtype]
    return total


def program_costs(hlo_text: str) -> ProgramCosts:
    comps: Dict[str, _Comp] = {}
    shapes: Dict[str, str] = {}
    current: Optional[_Comp] = None
    entry: Optional[str] = None
    n_whiles = 0
    unknown_trips = 0

    # pass 1: symbol table (instruction result types, global — names unique)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m:
            shapes[m.group(1)] = m.group(2)

    # pass 2: computations
    for line in hlo_text.splitlines():
        h = _COMP_HDR_RE.match(line)
        if h and line.rstrip().endswith("{"):
            current = _Comp(h.group(1))
            comps[current.name] = current
            if line.lstrip().startswith("ENTRY"):
                entry = current.name
            continue
        if current is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, op, operands_str, tail = m.groups()
        operands = [o.split(" ")[-1] for o in _split_operands(operands_str)]

        if op == "dot":
            k = 1
            lhs = operands[0] if operands else None
            cm = _LHS_CONTRACT_RE.search(tail)
            if lhs in shapes and cm:
                parsed = _parse_shape(shapes[lhs])
                if parsed:
                    dims = parsed[0][1]
                    for ci in cm.group(1).split(","):
                        if ci:
                            idx = int(ci)
                            if idx < len(dims):
                                k *= dims[idx]
            current.flops += 2.0 * _numel(type_str) * k

        if op == "while":
            n_whiles += 1
            tm = _TRIP_RE.search(tail)
            trips = float(tm.group(1)) if tm else 1.0
            if not tm:
                unknown_trips += 1
            for callee in _CALLEE_RE.findall(tail):
                current.calls.append((callee, trips))
            continue

        # non-while callees (fusion/call/conditional/reduce etc.)
        for callee in _CALLEE_RE.findall(tail):
            current.calls.append((callee, 1.0))

        base = op
        # fusions carry their root op in the name (e.g.
        # %bitcast_dynamic-update-slice_fusion.5): DUS-rooted fusions are
        # in-place on TPU (buffer aliased, only the update region moves)
        if base == "fusion" and "dynamic-update-slice" in name:
            base = "dynamic-update-slice"
            operands = [o for o in operands
                        if shapes.get(o, "") != type_str] or operands
            operands = ["<none>"] + operands        # mimic DUS arg layout
        elif base == "fusion" and "slice" in name and "update" not in name:
            base = "dynamic-slice"      # slice-rooted fusions read the slice
        if base in _BYTES_OPS:
            if base == "dynamic-slice":
                # physically reads only the slice: count the result
                nbytes = 2 * _shape_bytes(type_str)
                sbytes = 2 * _score_like_bytes(type_str)
            elif base == "dynamic-update-slice":
                # read-modify-write of the update region only; the update
                # operand is the largest non-index operand after operand 0
                upd = max((_shape_bytes(shapes.get(o, ""))
                           for o in operands[1:]), default=0)
                nbytes = 2 * upd
                sbytes = 2 * max((_score_like_bytes(shapes.get(o, ""))
                                  for o in operands[1:]), default=0)
            else:
                nbytes = _shape_bytes(type_str)
                sbytes = _score_like_bytes(type_str)
                for o in operands:
                    nbytes += _shape_bytes(shapes.get(o, ""))
                    sbytes += _score_like_bytes(shapes.get(o, ""))
            current.bytes += nbytes
            current.score_bytes += sbytes
            current.bytes_by_kind[base] += nbytes

        for kind in COLLECTIVE_KINDS:
            if base == kind or base == kind + "-start":
                cb = sum(_shape_bytes(shapes.get(o, "")) for o in operands)
                if cb == 0:
                    cb = _shape_bytes(type_str)
                current.coll[kind] += cb
                break

    # pass 3: propagate through the call graph (memoized)
    memo: Dict[str, tuple] = {}

    def total(name: str, depth=0):
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        if comp is None or depth > 64:
            return 0.0, 0.0, 0.0, {}, {}
        f, b, s = comp.flops, comp.bytes, comp.score_bytes
        c = dict(comp.coll)
        kb = dict(comp.bytes_by_kind)
        for callee, mult in comp.calls:
            cf, cb, cs, cc, ckb = total(callee, depth + 1)
            f += mult * cf
            b += mult * cb
            s += mult * cs
            for k, v in cc.items():
                c[k] = c.get(k, 0.0) + mult * v
            for k, v in ckb.items():
                kb[k] = kb.get(k, 0.0) + mult * v
        memo[name] = (f, b, s, c, kb)
        return memo[name]

    if entry is None and comps:
        entry = list(comps)[-1]
    f, b, s, c, kb = total(entry) if entry else (0.0, 0.0, 0.0, {}, {})
    return ProgramCosts(
        flops=f, bytes=b,
        collective_bytes=sum(c.values()),
        collective_breakdown={k: float(v) for k, v in c.items()},
        n_whiles=n_whiles, unknown_trip_whiles=unknown_trips,
        bytes_by_kind={k: float(v) for k, v in kb.items()},
        score_bytes=s)


def collective_bytes(hlo_text: str) -> Tuple[float, Dict[str, float]]:
    """While-aware collective operand bytes (total, per kind)."""
    pc = program_costs(hlo_text)
    return pc.collective_bytes, pc.collective_breakdown


def count_ops(hlo_text: str, names=("fusion", "custom-call", "while",
                                    "dynamic-update-slice")) -> Dict[str, int]:
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m and m.group(3) in names:
            counts[m.group(3)] += 1
    return dict(counts)
