"""Recompute roofline reports from persisted HLO (no recompilation).

The dry-run stores each cell's optimized HLO under results/dryrun/hlo/;
whenever the analyzer (analysis/hlo.py) improves, this tool refreshes the
JSON records in place:

    PYTHONPATH=src python -m repro.analysis.reanalyze [results/dryrun]
"""

from __future__ import annotations

import glob
import gzip
import json
import os
import sys

from repro.analysis import roofline as RL
from repro.configs import ARCH_REGISTRY, SHAPES_BY_NAME


def reanalyze_dir(out_dir: str) -> int:
    n = 0
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok" or rec.get("arch") == "chipletgym":
            continue
        stem = os.path.basename(path).removesuffix(".json")
        hlo_path = os.path.join(out_dir, "hlo", stem + ".hlo.gz")
        if not os.path.exists(hlo_path):
            continue
        with gzip.open(hlo_path, "rt") as f:
            hlo_text = f.read()
        arch = ARCH_REGISTRY[rec["arch"]]
        shape = SHAPES_BY_NAME[rec["shape"]]
        report = RL.analyze(arch, shape, rec["mesh"], rec["n_devices"],
                            rec.get("cost", {}), hlo_text,
                            rec.get("memory_analysis"))
        rec["roofline"] = report.to_dict()
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        n += 1
    return n


if __name__ == "__main__":
    target = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")
    print(f"re-analyzed {reanalyze_dir(os.path.abspath(target))} records")
