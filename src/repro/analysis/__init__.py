"""Roofline analysis: HLO cost/collective accounting (analysis/roofline.py)."""
