"""Roofline analysis from compiled dry-run artifacts (assignment §Roofline).

Per (arch x shape x mesh) cell, derived from the SPMD-partitioned module
(cost_analysis + HLO text — per *device*):

    compute term    = HLO_FLOPs_dev / peak_FLOPs          (197 TF/s bf16)
    memory term     = HLO_bytes_dev / HBM_bw              (819 GB/s)
    collective term = collective_bytes_dev / link_bw      (~50 GB/s/link,
                      3 ICI links per v5e chip when >1 mesh axis is used)

plus MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per device-step and
the usefulness ratio MODEL_FLOPS / HLO_FLOPs (catches remat/redundancy).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional

from repro.analysis import hlo as hlo_lib
from repro.configs.base import ArchConfig, ShapeConfig

PEAK_FLOPS = 197e12           # bf16 / chip (TPU v5e-class)
HBM_BW = 819e9                # bytes/s / chip
LINK_BW = 50e9                # bytes/s per ICI link
N_LINKS = 3                   # usable links per chip in a 2D/3D torus slice


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    collective_breakdown: Dict[str, int]
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops_per_device: float
    useful_ratio: float              # MODEL_FLOPS / HLO_FLOPs
    peak_memory_bytes: Optional[float] = None
    note: str = ""
    # materialized attention-score traffic (VMEM-resident under the Pallas
    # flash kernel on the TPU target) and the kernel-adjusted memory term
    score_bytes: float = 0.0
    t_memory_flash: float = 0.0
    bytes_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def step_time_s(self) -> float:
        """Roofline-optimistic step time: max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the roofline step: how close the cell
        is to pure compute-bound at MODEL_FLOPS (the score in §Perf)."""
        if self.step_time_s <= 0:
            return 0.0
        return (self.model_flops_per_device / PEAK_FLOPS) / self.step_time_s

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["t_step"] = self.step_time_s
        d["roofline_fraction"] = self.roofline_fraction
        return d


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS for one step: 6*N_active*D (train) / 2*N_active*D
    (inference) where D = tokens processed in the step."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence per step
    return 2.0 * n_active * shape.global_batch


def analyze(arch_cfg: ArchConfig, shape: ShapeConfig, mesh_name: str,
            n_devices: int, cost: Dict, hlo_text: str,
            memory_stats: Optional[str] = None,
            note: str = "") -> RooflineReport:
    # while-aware program costs from the HLO (cost_analysis() reports scan
    # bodies only once — see analysis/hlo.py docstring); the raw
    # cost_analysis dict is kept in the dry-run record for reference.
    pc = hlo_lib.program_costs(hlo_text)
    flops_dev = pc.flops
    bytes_dev = pc.bytes
    coll_bytes, breakdown = pc.collective_bytes, pc.collective_breakdown

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_bytes / (LINK_BW * N_LINKS)

    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    bottleneck = max(terms, key=terms.get)

    mf_total = model_flops(arch_cfg, shape)
    mf_dev = mf_total / n_devices
    useful = mf_dev / flops_dev if flops_dev > 0 else 0.0

    peak_mem = None
    if memory_stats:
        peak_mem = _parse_peak_memory(memory_stats)

    return RooflineReport(
        arch=arch_cfg.name, shape=shape.name, mesh=mesh_name,
        n_devices=n_devices,
        flops_per_device=flops_dev, bytes_per_device=bytes_dev,
        collective_bytes=float(coll_bytes),
        collective_breakdown=breakdown,
        t_compute=t_compute, t_memory=t_memory, t_collective=t_coll,
        bottleneck=bottleneck,
        model_flops_per_device=mf_dev, useful_ratio=useful,
        peak_memory_bytes=peak_mem, note=note,
        score_bytes=pc.score_bytes,
        t_memory_flash=(bytes_dev - pc.score_bytes) / HBM_BW,
        bytes_by_kind=pc.bytes_by_kind,
    )


def _parse_peak_memory(stats: str) -> Optional[float]:
    import re
    m = re.search(r"(\d+(?:\.\d+)?)\s*(GiB|MiB|KiB|B)", stats)
    if not m:
        return None
    val, unit = float(m.group(1)), m.group(2)
    mult = {"B": 1, "KiB": 2**10, "MiB": 2**20, "GiB": 2**30}[unit]
    return val * mult


def format_table(reports) -> str:
    """Markdown roofline table for EXPERIMENTS.md."""
    header = ("| arch | shape | mesh | t_comp (ms) | t_mem (ms) | "
              "t_coll (ms) | bottleneck | 6ND/HLO | roofline frac |")
    sep = "|" + "---|" * 9
    rows = [header, sep]
    for r in reports:
        rows.append(
            f"| {r.arch} | {r.shape} | {r.mesh} "
            f"| {r.t_compute*1e3:.2f} | {r.t_memory*1e3:.2f} "
            f"| {r.t_collective*1e3:.2f} | {r.bottleneck} "
            f"| {r.useful_ratio:.2f} | {r.roofline_fraction:.2%} |")
    return "\n".join(rows)


def save_reports(reports, path: str):
    with open(path, "w") as f:
        json.dump([r.to_dict() for r in reports], f, indent=2)


def load_reports(path: str):
    with open(path) as f:
        data = json.load(f)
    out = []
    for d in data:
        d.pop("t_step", None)
        d.pop("roofline_fraction", None)
        out.append(RooflineReport(**d))
    return out
