"""Structured run journal: host-side span/event JSONL sink.

One ``Journal`` per run writes newline-delimited JSON records to a file
(or any file-like). Three record kinds:

- ``{"kind": "run_begin", "run": <id>, "ts": ..., "env": {...}}`` —
  opens the journal with an environment fingerprint (JAX version,
  backend, device/CPU counts, platform).
- ``{"kind": "event", "name": ..., "ts": ..., "span": <parent>, ...}``
  — point-in-time facts (convergence curves, surrogate fits, compile
  timings, archive hypervolume samples).
- ``{"kind": "span_begin"/"span", "name": ..., "ts": ..., "dur_s": ...,
  "parent": ...}`` — nested wall-clock stages (suite arms under their
  ``fold_in`` keys, refine sweeps, placement, mapping). ``span_begin``
  is written at entry so a crashed run still shows where it died;
  ``span`` at exit carries the duration.

Arbitrary extra fields are allowed on every record and are sanitized to
plain JSON (numpy / JAX scalars and arrays included). The module also
keeps an ambient *current journal* (``use(j)`` / ``current()``) so deep
call sites — the surrogate ranker's refit loop, ``profile.compile_timer``
— can emit events without threading a journal argument through every
signature. ``scripts/telemetry_report.py`` renders a journal back into a
human-readable run summary.
"""

from __future__ import annotations

import contextlib
import json
import os
import platform
import sys
import time
import uuid


def environment_fingerprint() -> dict:
    """Best-effort snapshot of the software/hardware environment."""
    fp = {
        "python": sys.version.split()[0],
        "platform": platform.platform(),
        "cpu_count": os.cpu_count(),
    }
    try:
        import jax
        fp["jax"] = jax.__version__
        fp["backend"] = jax.default_backend()
        fp["device_count"] = jax.device_count()
        fp["devices"] = [str(d) for d in jax.devices()]
    except Exception as e:  # pragma: no cover - jax is always present here
        fp["jax_error"] = repr(e)
    return fp


def _jsonable(x):
    """Recursively coerce numpy/JAX scalars and arrays to plain JSON."""
    if x is None or isinstance(x, (bool, int, float, str)):
        return x
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if hasattr(x, "item") and getattr(x, "ndim", None) == 0:
        return _jsonable(x.item())
    if hasattr(x, "tolist"):
        return _jsonable(x.tolist())
    return str(x)


class Journal:
    """Append-only JSONL journal with nested spans.

    Not thread-safe by design: the suite/portfolio drivers are
    single-threaded host loops around compiled programs.
    """

    def __init__(self, path_or_file, run_id=None, fingerprint=True):
        if hasattr(path_or_file, "write"):
            self._f = path_or_file
            self._owns = False
            self.path = getattr(path_or_file, "name", None)
        else:
            self._f = open(path_or_file, "a", encoding="utf-8")
            self._owns = True
            self.path = str(path_or_file)
        self.run_id = run_id or uuid.uuid4().hex[:12]
        self._stack = []          # names of open spans, outermost first
        self._closed = False
        if fingerprint:
            self._write({"kind": "run_begin",
                         "env": environment_fingerprint()})

    # -- low-level ---------------------------------------------------------

    def _write(self, rec: dict) -> None:
        if self._closed:
            return
        rec = {"ts": time.time(), "run": self.run_id, **rec}
        self._f.write(json.dumps(_jsonable(rec), sort_keys=False) + "\n")
        self._f.flush()

    # -- public API --------------------------------------------------------

    def event(self, name: str, **fields) -> None:
        self._write({"kind": "event", "name": name,
                     "span": self._stack[-1] if self._stack else None,
                     **fields})

    @contextlib.contextmanager
    def span(self, name: str, **fields):
        parent = self._stack[-1] if self._stack else None
        self._write({"kind": "span_begin", "name": name,
                     "parent": parent, **fields})
        self._stack.append(name)
        t0 = time.perf_counter()
        try:
            yield self
        finally:
            dur = time.perf_counter() - t0
            self._stack.pop()
            self._write({"kind": "span", "name": name, "parent": parent,
                         "dur_s": dur, **fields})

    def close(self) -> None:
        if not self._closed:
            self._write({"kind": "run_end"})
            if self._owns:
                self._f.close()
            self._closed = True

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class NullJournal:
    """No-op drop-in so call sites can write ``jr.event(...)`` without
    ``if journal is not None`` at every line."""

    run_id = None
    path = None

    def event(self, name, **fields):
        pass

    @contextlib.contextmanager
    def span(self, name, **fields):
        yield self

    def close(self):
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL = NullJournal()


def or_null(journal) -> "Journal | NullJournal":
    return journal if journal is not None else NULL


# -- ambient current journal ----------------------------------------------

_CURRENT = None


def current():
    """The ambient journal set by ``use(...)``, or None."""
    return _CURRENT


def current_or_null():
    return or_null(_CURRENT)


@contextlib.contextmanager
def use(journal):
    """Make ``journal`` the ambient journal inside the block, so deep
    call sites (ranker refits, compile_timer) can emit without plumbing."""
    global _CURRENT
    prev = _CURRENT
    _CURRENT = journal
    try:
        yield journal
    finally:
        _CURRENT = prev


def load(path) -> list:
    """Read a JSONL journal back into a list of record dicts."""
    records = []
    with open(path, "r", encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if line:
                records.append(json.loads(line))
    return records
