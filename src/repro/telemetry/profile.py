"""Unified profiling hooks: compiled-kernel counts, compile timing,
and an optional ``jax.profiler`` trace context.

``compiled_kernel_count`` is the promoted (previously benchmark-local)
``_count_step_kernels`` from ``benchmarks/bench_costmodel.py``: both
benchmark drivers and the ``scripts/ci.sh`` kernel-ratio guards now
share this one implementation, so a counting-rule change cannot drift
the CI gate away from the recorded bench numbers.

``compile_timer`` times an explicit lower+compile and emits a
``compile`` event to the ambient journal (``telemetry.journal.use``),
making compilation cost visible in run journals without extra plumbing.
"""

from __future__ import annotations

import contextlib
import re
import time

from repro.telemetry import journal as tj


def compiled_kernel_count(fn, *args, scope: str = "while") -> int:
    """Count device kernels in ``fn``'s compiled HLO for ``args``.

    ``scope="while"`` (the historical bench guard behavior) counts
    inside the largest ``while_body`` — i.e. the per-iteration cost of
    the dominant ``lax.scan``/``while_loop``; returns 0 when the program
    has no loop. ``scope="module"`` counts the whole module. Counted ops
    are the launch-bearing ones on CPU/TPU backends: fusion, reduce,
    gather, scatter, sort, dot.
    """
    txt = fn.lower(*args).compile().as_text()
    if scope == "while":
        # historical rule first (the recorded bench numbers and CI ratio
        # guards were measured against it); XLA does not always name loop
        # bodies %while_body — some programs keep %region_N.M — so when
        # the name-based extraction finds nothing, follow the while ops'
        # body= references instead
        bodies = re.findall(r"%while_body[^\{]*\{(.*?)\n\}", txt, re.S)
        if not bodies:
            for name in set(re.findall(r"body=%?([\w\.\-]+)", txt)):
                m = re.search(
                    r"^\s*%?" + re.escape(name) + r" \([^\)]*\)[^\{]*\{"
                    r"(.*?)\n\s*\}", txt, re.S | re.M)
                if m:
                    bodies.append(m.group(1))
        if not bodies:
            return 0
        txt = max(bodies, key=len)
    elif scope != "module":
        raise ValueError(f"unknown scope {scope!r}")
    return len(re.findall(
        r"= \S+ (?:fusion|reduce|gather|scatter|sort|dot)\(", txt))


def compile_timer(fn, *args, name: str = None):
    """Explicitly lower+compile ``fn`` for ``args``; returns
    ``(compiled, wall_s)``. Emits a ``compile`` event (name + duration)
    to the ambient journal when one is active."""
    t0 = time.perf_counter()
    compiled = fn.lower(*args).compile()
    wall = time.perf_counter() - t0
    tj.current_or_null().event(
        "compile", target=name or getattr(fn, "__name__", repr(fn)),
        dur_s=wall)
    return compiled, wall


@contextlib.contextmanager
def trace_profile(log_dir):
    """Wrap a block in ``jax.profiler.trace(log_dir)`` when available;
    silently a no-op when ``log_dir`` is falsy or the profiler backend
    is missing (keeps callers unconditional)."""
    if not log_dir:
        yield
        return
    try:
        import jax.profiler as _prof
        ctx = _prof.trace(str(log_dir))
    except Exception:
        yield
        return
    with ctx:
        yield
    tj.current_or_null().event("profiler_trace", log_dir=str(log_dir))
