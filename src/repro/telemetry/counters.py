"""JAX-resident telemetry counters for the search hot loops.

Every optimizer hot loop in this repo — the placement-SA scan, the GA
generation scan, the PPO update scan, placement-episode rollouts — runs
as one opaque XLA program; the only host-level observation point is
``costmodel.register_eval_tap``, which deliberately skips traced calls.
The pytrees in this module ride *inside* those ``lax.scan`` carries (or
are emitted as per-step scan outputs), so acceptance rates, archive
churn and convergence dynamics are measured exactly where they happen.

Contract (mirrors the repo's ``mapping=None`` convention): telemetry is
OFF by default everywhere (``telemetry=False`` config fields /
``tel=None`` state fields), and the off path statically compiles the
exact pre-telemetry program — bit-for-bit, CI-gated against the
recorded PR-4 SA trajectories. Turning telemetry ON adds counter
arithmetic on values the step already computes; it draws no randomness
and never perturbs the trajectory (asserted in tests/test_telemetry.py
and by ``bench_costmodel.py --assert-telemetry``).

All counters are small fixed-shape device arrays, so they vmap cleanly
over scenario / design / chain axes and cost O(1) memory per carry.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# SA placement-refinement counters (sa/annealing.py)
# --------------------------------------------------------------------------

class SACounters(NamedTuple):
    """Per-chain counters of ``sa.refine_placement``.

    ``propose`` / ``accept`` count proposals and accepted moves per move
    kind (index 0 = chiplet relocate/swap, 1 = HBM re-anchor; a mapping
    move rides kind 0 — it neutralizes its placement half). ``improve``
    counts best-so-far improvements. ``seg_propose`` / ``seg_accept``
    resolve the same counts per phase-schedule segment (one bin total
    when no schedule is set). ``accept_curve`` is filled after the scan:
    the cumulative accepted-move count at the same stride as
    ``PlacementResult.history`` (so acceptance-rate curves line up with
    the best-so-far trace).
    """

    propose: jnp.ndarray       # (2,) int32, per move kind
    accept: jnp.ndarray        # (2,) int32
    improve: jnp.ndarray       # () int32, best-so-far improvements
    seg_propose: jnp.ndarray   # (n_segments,) int32
    seg_accept: jnp.ndarray    # (n_segments,) int32
    accept_curve: jnp.ndarray = None   # (n_records,) int32, post-scan


def init_sa(n_segments: int = 1) -> SACounters:
    return SACounters(
        propose=jnp.zeros((2,), jnp.int32),
        accept=jnp.zeros((2,), jnp.int32),
        improve=jnp.int32(0),
        seg_propose=jnp.zeros((n_segments,), jnp.int32),
        seg_accept=jnp.zeros((n_segments,), jnp.int32))


def sa_update(c: SACounters, kind, accept, improved,
              seg: int = 0) -> SACounters:
    """One SA step's counter update. ``kind`` is the (traced) move kind,
    ``accept`` / ``improved`` the step's accept and best-so-far booleans,
    ``seg`` the *static* phase-segment index. Pure arithmetic on values
    the step already computed — no randomness, no trajectory impact."""
    oh = (jnp.arange(2, dtype=jnp.int32)
          == jnp.asarray(kind, jnp.int32)).astype(jnp.int32)
    acc = jnp.asarray(accept).astype(jnp.int32)
    return c._replace(
        propose=c.propose + oh,
        accept=c.accept + oh * acc,
        improve=c.improve + jnp.asarray(improved).astype(jnp.int32),
        seg_propose=c.seg_propose.at[seg].add(jnp.int32(1)),
        seg_accept=c.seg_accept.at[seg].add(acc))


def merge_sa(a: SACounters, b: SACounters) -> SACounters:
    """Sum two rounds' counters; accept curves concatenate with the
    second curve offset by the first round's final count (the curve
    stays a cumulative accepted-move count)."""
    curve = None
    if a.accept_curve is not None and b.accept_curve is not None:
        curve = jnp.concatenate(
            [a.accept_curve, b.accept_curve + a.accept_curve[-1]])
    return SACounters(
        propose=a.propose + b.propose,
        accept=a.accept + b.accept,
        improve=a.improve + b.improve,
        seg_propose=a.seg_propose + b.seg_propose,
        seg_accept=a.seg_accept + b.seg_accept,
        accept_curve=curve)


def summarize_sa(c: SACounters) -> dict:
    """Host-side summary dict (plain Python scalars/lists, JSON-safe).
    Accepts counters with or without leading batch axes (summed over)."""
    prop = np.asarray(c.propose).reshape(-1, 2).sum(axis=0)
    acc = np.asarray(c.accept).reshape(-1, 2).sum(axis=0)
    n_seg = np.asarray(c.seg_propose).shape[-1]
    sprop = np.asarray(c.seg_propose).reshape(-1, n_seg).sum(axis=0)
    sacc = np.asarray(c.seg_accept).reshape(-1, n_seg).sum(axis=0)
    out = {
        "propose": [int(x) for x in prop],
        "accept": [int(x) for x in acc],
        "improve": int(np.asarray(c.improve).sum()),
        "accept_rate": [float(a / max(p, 1))
                        for a, p in zip(acc, prop)],
        "seg_propose": [int(x) for x in sprop],
        "seg_accept": [int(x) for x in sacc],
        "seg_accept_rate": [float(a / max(p, 1))
                            for a, p in zip(sacc, sprop)],
    }
    if c.accept_curve is not None:
        curve = np.asarray(c.accept_curve)
        out["accept_curve"] = [int(x) for x in curve.reshape(
            -1, curve.shape[-1])[0]] if curve.ndim > 1 else \
            [int(x) for x in curve]
    return out


# --------------------------------------------------------------------------
# Placement-episode env counters (core/env.py)
# --------------------------------------------------------------------------

class EnvCounters(NamedTuple):
    """Per-env counters riding ``env.EnvState`` through rollout scans.

    ``delta_evals`` / ``scratch_evals`` split step pricing by path (the
    delta-vs-scratch eval count of the ISSUE); ``episodes`` counts
    auto-reset boundaries, and the reward accumulators survive resets
    (the auto-reset combine carries the *stepped* counters forward, not
    the fresh-episode zeros)."""

    steps: jnp.ndarray          # () int32
    episodes: jnp.ndarray       # () int32, completed episodes
    delta_evals: jnp.ndarray    # () int32, delta-priced step evals
    scratch_evals: jnp.ndarray  # () int32, from-scratch step evals
    reward_sum: jnp.ndarray     # () float32
    best_reward: jnp.ndarray    # () float32


def init_env() -> EnvCounters:
    return EnvCounters(
        steps=jnp.int32(0), episodes=jnp.int32(0),
        delta_evals=jnp.int32(0), scratch_evals=jnp.int32(0),
        reward_sum=jnp.float32(0.0),
        best_reward=jnp.float32(-jnp.inf))


def env_step_update(c: EnvCounters, reward, delta_eval: bool) -> EnvCounters:
    one = jnp.int32(1)
    r = jnp.asarray(reward, jnp.float32)
    return c._replace(
        steps=c.steps + one,
        delta_evals=c.delta_evals + (one if delta_eval else 0),
        scratch_evals=c.scratch_evals + (0 if delta_eval else one),
        reward_sum=c.reward_sum + r,
        best_reward=jnp.maximum(c.best_reward, r))


def env_episode_update(c: EnvCounters, done) -> EnvCounters:
    return c._replace(
        episodes=c.episodes + jnp.asarray(done).astype(jnp.int32))


def summarize_env(c: EnvCounters) -> dict:
    steps = int(np.asarray(c.steps).sum())
    return {
        "steps": steps,
        "episodes": int(np.asarray(c.episodes).sum()),
        "delta_evals": int(np.asarray(c.delta_evals).sum()),
        "scratch_evals": int(np.asarray(c.scratch_evals).sum()),
        "mean_step_reward": float(np.asarray(c.reward_sum).sum()
                                  / max(steps, 1)),
        "best_reward": float(np.asarray(c.best_reward).max()),
    }


# --------------------------------------------------------------------------
# GA per-generation stats (optimizer/evo.py)
# --------------------------------------------------------------------------

class EvoGenStats(NamedTuple):
    """Per-generation scan outputs of ``evo.evolve`` (leading axis:
    generations). ``diversity`` is the mean pairwise gene-disagreement
    fraction of the offspring population (1 = all genomes distinct
    everywhere, 0 = converged); insert/evict counts are archive-row
    membership deltas; ``archive_hv`` samples the live archive's exact
    hypervolume w.r.t. its own nadir point each generation."""

    diversity: jnp.ndarray        # () float32
    mean_fitness: jnp.ndarray     # () float32
    archive_inserts: jnp.ndarray  # () int32
    archive_evicts: jnp.ndarray   # () int32
    archive_n: jnp.ndarray        # () int32, valid rows after insert
    archive_hv: jnp.ndarray       # () float32


def population_diversity(pop: jnp.ndarray) -> jnp.ndarray:
    """Mean pairwise Hamming fraction of an int (P, G) population."""
    neq = pop[:, None, :] != pop[None, :, :]
    return jnp.mean(neq.astype(jnp.float32))


def archive_delta(old_arc, new_arc):
    """(inserts, evicts): membership changes between two archive states,
    by exact point-row equality (cheap: capacity^2 comparisons)."""
    eq = jnp.all(old_arc.points[:, None, :] == new_arc.points[None, :, :],
                 axis=-1)                                   # (C, C) old x new
    old_survives = jnp.any(eq & new_arc.valid[None, :], axis=1)
    new_is_old = jnp.any(eq & old_arc.valid[:, None], axis=0)
    evicts = jnp.sum((old_arc.valid & ~old_survives).astype(jnp.int32))
    inserts = jnp.sum((new_arc.valid & ~new_is_old).astype(jnp.int32))
    return inserts, evicts


def summarize_evo(stats: EvoGenStats) -> dict:
    """Host-side summary; accepts stats stacked over generations (and
    any leading island/scenario axes — curves use the first row)."""
    def curve(x):
        a = np.asarray(x, np.float64)
        a = a.reshape(-1, a.shape[-1])[0]
        return [float(v) for v in a]
    return {
        "diversity": curve(stats.diversity),
        "mean_fitness": curve(stats.mean_fitness),
        "archive_inserts": int(np.asarray(stats.archive_inserts).sum()),
        "archive_evicts": int(np.asarray(stats.archive_evicts).sum()),
        "archive_hv": curve(stats.archive_hv),
        "final_archive_n": int(np.asarray(stats.archive_n).reshape(
            -1, np.asarray(stats.archive_n).shape[-1])[0][-1]),
    }


# --------------------------------------------------------------------------
# PPO per-update stats (rl/ppo.py)
# --------------------------------------------------------------------------

class PPOUpdateStats(NamedTuple):
    """Per-update scan outputs of ``ppo.train`` (leading axis: updates).
    ``approx_kl`` is the k1 estimator mean(old_logp - new_logp) over all
    minibatches; ``clip_frac`` the fraction of ratios clipped."""

    return_mean: jnp.ndarray   # () float32, mean GAE return
    return_std: jnp.ndarray    # () float32
    entropy: jnp.ndarray       # () float32, mean policy entropy
    approx_kl: jnp.ndarray     # () float32
    clip_frac: jnp.ndarray     # () float32


def summarize_ppo(stats: PPOUpdateStats) -> dict:
    def curve(x):
        a = np.asarray(x, np.float64)
        a = a.reshape(-1, a.shape[-1])[0]
        return [float(v) for v in a]
    return {
        "return_mean": curve(stats.return_mean),
        "entropy": curve(stats.entropy),
        "approx_kl": curve(stats.approx_kl),
        "clip_frac": curve(stats.clip_frac),
    }
