"""Observability: in-scan counters, structured run journal, profiling.

Three layers (see each module's docstring):

- ``telemetry.counters`` — JAX-resident counter pytrees carried through
  the SA / GA / PPO / env ``lax.scan`` hot loops (default off = the
  exact pre-telemetry program, bitwise).
- ``telemetry.journal`` — host-side span/event JSONL sink for suite and
  portfolio runs; rendered by ``scripts/telemetry_report.py``.
- ``telemetry.profile`` — shared compiled-kernel counting, compile
  timing, and an optional ``jax.profiler`` trace context.
"""
