"""Dispatching wrappers for the Pallas kernels.

Each op picks the best backend for the current platform:
  - on TPU: the Pallas kernel (compiled),
  - on CPU (this container): the mathematically-identical pure-jnp path
    (fast), with ``backend="pallas"`` forcing interpret-mode Pallas for
    validation (tests/test_kernels.py does exactly that).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import costmodel as cm
from repro.core import hw_constants as hw
from repro.core import params as ps
from repro.kernels import chiplet_eval as _ce
from repro.kernels import flash_attention as _fa
from repro.kernels import ref as _ref
from repro.kernels import ssd_scan as _ssd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attention(q, k, v, causal: bool = True, scale: float | None = None,
              window: int = 0, backend: str = "auto",
              block_q: int = _fa.DEFAULT_BLOCK_Q,
              block_k: int = _fa.DEFAULT_BLOCK_K) -> jnp.ndarray:
    """Flash attention with GQA + optional sliding window.

    backend: "auto" (pallas on TPU, jnp ref elsewhere), "pallas", "ref".
    """
    if backend == "pallas" or (backend == "auto" and _on_tpu()):
        return _fa.flash_attention(q, k, v, causal=causal, scale=scale,
                                   window=window, block_q=block_q,
                                   block_k=block_k,
                                   interpret=not _on_tpu())
    return _ref.attention_reference(q, k, v, causal=causal, scale=scale,
                                    window=window)


# ---------------------------------------------------------------------------
# SSD scan (Mamba-2)
# ---------------------------------------------------------------------------

def ssd(x, dt, a, b, c, chunk: int = _ssd.DEFAULT_CHUNK,
        backend: str = "auto") -> jnp.ndarray:
    """Chunked SSD scan; (BH, L, P) API (see kernels/ssd_scan.py)."""
    if backend == "pallas" or (backend == "auto" and _on_tpu()):
        return _ssd.ssd_scan(x, dt, a, b, c, chunk=chunk,
                             interpret=not _on_tpu())
    if backend == "ref":
        return _ref.ssd_reference(x, dt, a, b, c)
    return _ref.ssd_chunked_jnp(x, dt, a, b, c, chunk=chunk)


ssd_decode_step = _ref.ssd_decode_step


# ---------------------------------------------------------------------------
# Chiplet-Gym batched design evaluation
# ---------------------------------------------------------------------------

def chiplet_eval(dp: ps.DesignPoint,
                 workload: cm.Workload = cm.GENERIC_WORKLOAD,
                 weights: cm.RewardWeights = cm.RewardWeights(),
                 cfg: hw.HWConfig = hw.DEFAULT_HW,
                 backend: str = "auto",
                 placement=None,
                 nop_fidelity: str = "auto",
                 mapping=None) -> jnp.ndarray:
    """Evaluate a batch of design points -> (N, 12) metric matrix:
    [reward, eff_tops, e_comm_pj, pkg_cost, die_cost, u_sys,
     lat_hbm_ns, lat_ai_ns, hops_hbm_mean, hops_ai_mean,
     link_contention, hops_hbm_worst].

    ``placement`` is an optional batched ``placement.Placement``; None
    evaluates the canonical Fig.-4 floorplan. ``mapping`` is an optional
    batched ``mapping.Mapping``; None evaluates the canonical (paper)
    weight-stationary dataflow, an explicit mapping forces the full
    pairwise NoP tier (mirroring ``costmodel.evaluate``).
    ``nop_fidelity`` picks the NoP tier (see ``costmodel.evaluate``):
    'auto' takes the closed-form fast tier whenever ``placement`` and
    ``mapping`` are None — on the Pallas path that also skips the
    host-side canonical-baseline resolution entirely."""
    from repro.core import mapping as _mpg
    from repro.core import placement as _pm
    if nop_fidelity not in cm.NOP_FIDELITIES:
        raise ValueError(f"nop_fidelity must be one of {cm.NOP_FIDELITIES}, "
                         f"got {nop_fidelity!r}")
    if nop_fidelity == "fast" and placement is not None:
        raise ValueError(
            "nop_fidelity='fast' evaluates the canonical floorplan only; "
            "drop the explicit placement or use 'auto'/'full'")
    if nop_fidelity == "fast" and mapping is not None:
        raise ValueError(
            "nop_fidelity='fast' evaluates the canonical dataflow only; "
            "drop the explicit mapping or use 'auto'/'full'")
    fast = (placement is None and nop_fidelity != "full"
            and mapping is None)
    flat = ps.to_flat(dp)
    n = flat.shape[0]
    wl_vals = (float(workload.gemm_ops), float(workload.nongemm_ops),
               float(workload.hbm_bytes), float(workload.mapping_eff))
    w_vals = (float(weights.alpha), float(weights.beta), float(weights.gamma))
    if backend == "pallas" or (backend == "auto" and _on_tpu()):
        if fast:
            padded = _ce.pad_designs(dp, nop_fidelity="fast")
            out = _ce.evaluate_batch(padded, None, wl_vals, w_vals, cfg,
                                     interpret=not _on_tpu(),
                                     nop_fidelity="fast")
        else:
            resolved = _ce._design_placement(dp, placement)
            padded = _ce.pad_designs(dp, _resolved=resolved,
                                     mapping=mapping)
            cells = _ce.pad_cells(dp, resolved[0])
            stage = (None if mapping is None
                     else _ce.pad_stage(mapping))
            out = _ce.evaluate_batch(padded, cells, wl_vals, w_vals, cfg,
                                     interpret=not _on_tpu(),
                                     stage_padded=stage)
        return out[:n]
    pflat = None if placement is None else _pm.to_flat(placement)
    mflat = None if mapping is None else _mpg.to_flat(mapping)
    return _ref.chiplet_eval_reference(flat, wl_vals, w_vals, cfg, pflat,
                                       nop_fidelity, mflat)


def surrogate_score(flat, folded, backend: str = "auto") -> jnp.ndarray:
    """Fused surrogate scoring: (N, 14) design flats -> (N,) scores.

    ``folded`` is a scenario-folded ``surrogate.model.FoldedParams``
    (one readout vector per scenario — see model.fold_scenario).
    backend: "auto" (pallas on TPU, jnp model path elsewhere),
    "pallas" (interpret-mode off-TPU), "ref".
    """
    if backend == "pallas" or (backend == "auto" and _on_tpu()):
        from repro.kernels import surrogate_score as _ss
        return _ss.surrogate_score(flat, folded, interpret=not _on_tpu())
    return _ref.surrogate_score_reference(flat, folded)


def decode_attention(q, k, v, pos, scale=None, window: int = 0,
                     backend: str = "auto"):
    """Single-token GQA decode attention against a (B, KV, S, D) cache."""
    from repro.kernels import decode_attention as _da
    if backend == "pallas" or (backend == "auto" and _on_tpu()):
        return _da.decode_attention(q, k, v, pos, scale=scale,
                                    window=window,
                                    interpret=not _on_tpu())
    return _ref.decode_attention_reference(q, k, v, pos, scale=scale,
                                           window=window)
