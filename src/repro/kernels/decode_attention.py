"""Pallas TPU kernel: single-token GQA decode attention.

The §Perf decode hillclimb (EXPERIMENTS.md) showed the XLA path cannot
express the ideal decode step: removing the f32 cache cast re-exposed a
GSPMD resharding (opt3 refuted). This kernel IS that ideal step, on the
TPU target:

  - KV cache blocks stream HBM -> VMEM in bf16; scores accumulate in
    fp32 VREGs (MXU-native) — no materialized f32 cache copy,
  - GQA grouped natively: the q tile is (group, D) per kv head — no
    repeat of K/V across query heads,
  - online softmax across cache blocks with position masking (supports
    ragged fill: positions > pos are masked, so one compiled kernel
    serves every step),
  - grid = (batch * kv_heads, cache_blocks); running (acc, m, l) in VMEM
    scratch across the sequential cache axis.

ops.decode_attention dispatches it on TPU; interpret mode validates on
CPU against the grouped-einsum oracle (tests/test_kernels.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_S = 512
_NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *,
                   scale: float, block_s: int, num_blocks: int,
                   window: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[0, 0]
    q = q_ref[0].astype(jnp.float32) * scale          # (G, D)
    k = k_ref[0]                                      # (BS, D) bf16/f32
    v = v_ref[0]

    # MXU: low-precision operands, fp32 accumulation
    s = jax.lax.dot_general(
        q.astype(k.dtype), k,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)           # (G, BS)

    k_pos = si * block_s + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    valid = k_pos <= pos
    if window > 0:
        valid &= (pos - k_pos) < window
    s = jnp.where(valid, s, _NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = (acc_ref[...] * corr
                    + jax.lax.dot_general(
                        p.astype(v.dtype), v,
                        (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(si == num_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "window", "block_s", "interpret"))
def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     pos: jnp.ndarray, scale: float | None = None,
                     window: int = 0,
                     block_s: int = DEFAULT_BLOCK_S,
                     interpret: bool = True) -> jnp.ndarray:
    """q: (B, Hq, D) one token; k/v: (B, KV, S, D) cache; pos: () int32.

    Returns (B, Hq, D). Positions > pos (unfilled cache) are masked.
    """
    b, hq, d = q.shape
    _, kv, s_len, _ = k.shape
    assert hq % kv == 0
    group = hq // kv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    bs = min(block_s, s_len)
    assert s_len % bs == 0, (s_len, bs)
    n_blocks = s_len // bs

    qr = q.reshape(b * kv, group, d)
    kr = k.reshape(b * kv, s_len, d)
    vr = v.reshape(b * kv, s_len, d)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1, 1)

    kernel = functools.partial(
        _decode_kernel, scale=scale, block_s=bs, num_blocks=n_blocks,
        window=window)
    out = pl.pallas_call(
        kernel,
        grid=(b * kv, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1), lambda h, si: (0, 0)),
            pl.BlockSpec((1, group, d), lambda h, si: (h, 0, 0)),
            pl.BlockSpec((1, bs, d), lambda h, si: (h, si, 0)),
            pl.BlockSpec((1, bs, d), lambda h, si: (h, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, group, d), lambda h, si: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kv, group, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, d), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, qr, kr, vr)
    return out.reshape(b, hq, d)
