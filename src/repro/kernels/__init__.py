"""Pallas TPU kernels for the framework's compute hot-spots.

- ``chiplet_eval``     — batched Chiplet-Gym PPAC evaluation (DSE hot loop)
- ``flash_attention``  — tiled online-softmax attention (GQA/causal/SWA)
- ``ssd_scan``         — Mamba-2 SSD chunked scan
- ``decode_attention`` — single-token GQA decode vs a KV cache (bf16
  operands, fp32 accumulation, grouped heads — the TPU-native resolution
  of the decode cell's refuted XLA-path optimization, EXPERIMENTS.md §Perf)

``ops.py`` holds the dispatching jit wrappers, ``ref.py`` the pure-jnp
oracles. All kernels validate in interpret mode on CPU (tests).
"""
