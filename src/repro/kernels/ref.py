"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests).

- ``attention_reference``     : naive softmax attention (GQA/causal/SWA)
- ``ssd_reference``           : exact sequential SSD recurrence (lax.scan)
- ``ssd_chunked_jnp``         : fast chunked SSD (same math as the kernel,
                                pure jnp — the CPU path of ops.ssd)
- ``chiplet_eval_reference``  : the core cost model itself
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import costmodel as cm
from repro.core import hw_constants as hw
from repro.core import params as ps
from repro.core import placement as pm

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attention_reference(q, k, v, causal: bool = True,
                        scale: float | None = None,
                        window: int = 0) -> jnp.ndarray:
    """q: (B, Hq, L, D); k/v: (B, Hkv, S, D) -> (B, Hq, L, D). fp32 softmax."""
    batch, hq, q_len, d = q.shape
    _, hkv, kv_len, _ = k.shape
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(q_len)[:, None]
    k_pos = jnp.arange(kv_len)[None, :]
    if causal:
        s = jnp.where(q_pos >= k_pos, s, _NEG_INF)
    if window > 0:
        s = jnp.where(q_pos - k_pos < window, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# SSD (Mamba-2)
# ---------------------------------------------------------------------------

def ssd_reference(x, dt, a, b, c) -> jnp.ndarray:
    """Exact sequential recurrence. Same (BH, L, ...) API as the kernel."""
    bh, seq, p = x.shape
    n = b.shape[-1]

    def per_seq(x1, dt1, a1, b1, c1):
        def step(h, inp):
            xt, dtt, bt, ct = inp
            h = (jnp.exp(dtt * a1) * h
                 + dtt * bt[:, None] * xt[None, :])       # (N, P)
            y = ct @ h                                     # (P,)
            return h, y

        h0 = jnp.zeros((n, p), jnp.float32)
        _, ys = jax.lax.scan(step, h0,
                             (x1.astype(jnp.float32), dt1.astype(jnp.float32),
                              b1.astype(jnp.float32), c1.astype(jnp.float32)))
        return ys

    return jax.vmap(per_seq)(x, dt, a, b, c).astype(x.dtype)


def ssd_chunked_jnp(x, dt, a, b, c, chunk: int = 128) -> jnp.ndarray:
    """Chunked SSD, pure jnp — mirrors the Pallas kernel math exactly."""
    bh, seq, p = x.shape
    n = b.shape[-1]
    ch = min(chunk, seq)
    assert seq % ch == 0
    n_chunks = seq // ch

    xr = x.reshape(bh, n_chunks, ch, p).astype(jnp.float32)
    dtr = dt.reshape(bh, n_chunks, ch).astype(jnp.float32)
    br = b.reshape(bh, n_chunks, ch, n).astype(jnp.float32)
    cr = c.reshape(bh, n_chunks, ch, n).astype(jnp.float32)
    a = a.astype(jnp.float32)

    ii = jnp.arange(ch)[:, None]
    jj = jnp.arange(ch)[None, :]
    causal = ii >= jj

    def per_chunk(h_prev, inp):
        xc, dtc, bc, cc, a1 = inp
        cum = jnp.cumsum(dtc * a1)
        diff = cum[:, None] - cum[None, :]
        decay = jnp.where(causal, jnp.exp(jnp.where(causal, diff, 0.0)), 0.0)
        scores = (cc @ bc.T) * decay * dtc[None, :]
        y_intra = scores @ xc
        y_cross = jnp.exp(cum)[:, None] * (cc @ h_prev)
        w = jnp.exp(cum[-1] - cum) * dtc
        h_new = jnp.exp(cum[-1]) * h_prev + (bc * w[:, None]).T @ xc
        return h_new, y_intra + y_cross

    def per_seq(xs, dts, bs, cs, a1):
        h0 = jnp.zeros((n, p), jnp.float32)
        a_rep = jnp.broadcast_to(a1, (n_chunks,))
        _, ys = jax.lax.scan(per_chunk, h0, (xs, dts, bs, cs, a_rep))
        return ys.reshape(seq, p)

    return jax.vmap(per_seq)(xr, dtr, br, cr, a).astype(x.dtype)


def ssd_decode_step(h, x_t, dt_t, a, b_t, c_t):
    """One-token recurrence update (serving path). h: (BH, N, P)."""
    decay = jnp.exp(dt_t * a)[:, None, None]
    h = decay * h + (dt_t[:, None] * b_t)[:, :, None] * x_t[:, None, :]
    y = jnp.einsum("gn,gnp->gp", c_t, h)
    return h, y


# ---------------------------------------------------------------------------
# Chiplet-Gym design evaluation
# ---------------------------------------------------------------------------

def chiplet_eval_reference(designs_flat: jnp.ndarray,
                           workload_vals: Tuple[float, float, float, float],
                           weight_vals: Tuple[float, float, float],
                           cfg: hw.HWConfig = hw.DEFAULT_HW,
                           placement_flat: jnp.ndarray | None = None,
                           nop_fidelity: str = "auto",
                           mapping_flat: jnp.ndarray | None = None
                           ) -> jnp.ndarray:
    """(N, >=14) index array -> (N, 12) metrics matching the Pallas kernel.

    Columns: [reward, eff_tops, e_comm_pj, pkg_cost, die_cost, u_sys,
    lat_hbm_ns, lat_ai_ns, hops_hbm_mean, hops_ai_mean, link_contention,
    hops_hbm_worst]. ``placement_flat`` is an optional (N, pm.FLAT_DIM)
    ``placement.to_flat`` batch; None evaluates the canonical floorplan.
    ``mapping_flat`` is an optional (N, mapping.FLAT_DIM)
    ``mapping.to_flat`` batch; None evaluates the canonical (paper)
    weight-stationary dataflow.
    """
    from repro.core import mapping as mpg
    dp = ps.from_flat(designs_flat[:, : ps.N_PARAMS].astype(jnp.int32))
    workload = cm.Workload(
        gemm_ops=jnp.float32(workload_vals[0]),
        nongemm_ops=jnp.float32(workload_vals[1]),
        hbm_bytes=jnp.float32(workload_vals[2]),
        mapping_eff=jnp.float32(workload_vals[3]))
    weights = cm.RewardWeights(alpha=jnp.float32(weight_vals[0]),
                               beta=jnp.float32(weight_vals[1]),
                               gamma=jnp.float32(weight_vals[2]))
    placement = (None if placement_flat is None
                 else pm.from_flat(placement_flat))
    mapping = (None if mapping_flat is None
               else mpg.from_flat(mapping_flat))
    m = cm.evaluate(dp, workload, weights, cfg, placement, nop_fidelity,
                    mapping=mapping)
    return jnp.stack([m.reward, m.eff_tops, m.e_comm_pj_per_op, m.pkg_cost,
                      m.die_cost, m.u_sys, m.lat_hbm_ai_ns, m.lat_ai_ai_ns,
                      m.hops_hbm_mean, m.hops_ai_mean, m.link_contention,
                      m.hops_hbm_ai],
                     axis=-1)


@jax.jit
def _surrogate_score_jit(flat, folded):
    from repro.surrogate import model as sm
    return sm.score_folded(folded, flat)


def surrogate_score_reference(flat: jnp.ndarray, folded) -> jnp.ndarray:
    """Oracle for the fused surrogate scoring kernel.

    flat: (N, 14) int design indices; folded: a scenario-folded
    ``surrogate.model.FoldedParams``. Returns (N,) predicted Eq.-17
    rewards — the pure-jnp model path the Pallas kernel must match.
    (Jitted: this is also the CPU production path of
    ``ops.surrogate_score``, the ranker's hot loop.)
    """
    return _surrogate_score_jit(jnp.asarray(flat, jnp.int32), folded)


def decode_attention_reference(q, k, v, pos, scale=None, window: int = 0):
    """Oracle for the single-token decode kernel.

    q: (B, Hq, D); k/v: (B, KV, S, D); pos: scalar. fp32 throughout.
    """
    b, hq, d = q.shape
    _, kv, s_len, _ = k.shape
    group = hq // kv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    qg = q.reshape(b, kv, group, d).astype(jnp.float32) * scale
    s = jnp.einsum("bkgd,bksd->bkgs", qg, k.astype(jnp.float32))
    k_pos = jnp.arange(s_len)
    valid = k_pos <= pos
    if window > 0:
        valid &= (pos - k_pos) < window
    s = jnp.where(valid[None, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, d).astype(q.dtype)
