"""Pallas TPU flash attention (GQA/causal) — the LM substrate's hot spot.

Online-softmax tiled attention adapted to the TPU memory hierarchy:

  - grid = (batch * q_heads, num_q_blocks, num_kv_blocks); the kv axis is
    the innermost (sequential on TPU), so running max / sum / accumulator
    live in VMEM scratch across kv steps of one (head, q-block),
  - q/k/v tiles are (BLOCK_Q, head_dim) / (BLOCK_K, head_dim); head_dim is
    a 128-lane multiple for the assigned archs — MXU-shaped matmuls,
  - softmax statistics are fp32 regardless of input dtype (bf16-safe),
  - GQA: q head h reads kv head h // group at BlockSpec index-map level —
    no materialized KV replication,
  - causal blocks above the diagonal are masked (full-block skip is a
    documented TODO for real-TPU tuning; interpret-mode correctness first).

``ops.attention`` is the dispatching wrapper; ``ref.attention_reference``
is the pure-jnp oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
_NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref,
                 acc_ref, m_ref, l_ref, *,
                 scale: float, causal: bool, window: int,
                 block_q: int, block_k: int, num_kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (BQ, D)
    k = k_ref[0].astype(jnp.float32)                  # (BK, D)
    v = v_ref[0].astype(jnp.float32)                  # (BK, D)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (BQ, BK)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    if causal:
        s = jnp.where(q_pos >= k_pos, s, _NEG_INF)
    if window > 0:  # sliding-window attention (h2o-danube / hymba)
        s = jnp.where(q_pos - k_pos < window, s, _NEG_INF)

    m_prev = m_ref[...]                                # (BQ, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                             # (BQ, BK)
    correction = jnp.exp(m_prev - m_new)               # (BQ, 1)

    l_ref[...] = l_ref[...] * correction + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = (acc_ref[...] * correction
                    + jnp.dot(p, v, preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "window",
                     "block_q", "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, scale: float | None = None,
                    window: int = 0,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = True) -> jnp.ndarray:
    """q: (B, Hq, L, D); k/v: (B, Hkv, S, D); Hq % Hkv == 0 -> (B, Hq, L, D).

    ``window > 0`` enables causal sliding-window masking (token i attends
    to [i-window+1, i]).
    """
    batch, hq, q_len, d = q.shape
    _, hkv, kv_len, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)

    bq = min(block_q, q_len)
    bk = min(block_k, kv_len)
    assert q_len % bq == 0 and kv_len % bk == 0, (q_len, bq, kv_len, bk)
    n_q = q_len // bq
    n_k = kv_len // bk

    qr = q.reshape(batch * hq, q_len, d)
    kr = k.reshape(batch * hkv, kv_len, d)
    vr = v.reshape(batch * hkv, kv_len, d)

    def kv_index(h, qi, ki):
        b_idx = h // hq
        h_idx = (h % hq) // group
        return (b_idx * hkv + h_idx, ki, 0)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        block_q=bq, block_k=bk, num_kv_blocks=n_k)

    out = pl.pallas_call(
        kernel,
        grid=(batch * hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((batch * hq, q_len, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),      # output accumulator
            pltpu.VMEM((bq, 1), jnp.float32),      # running max
            pltpu.VMEM((bq, 1), jnp.float32),      # running sum
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(batch, hq, q_len, d)
