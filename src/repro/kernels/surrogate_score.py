"""Pallas TPU kernel: fused surrogate candidate scoring.

The surrogate ranker's hot loop (surrogate/ranker.py) scores millions
of Table-1 design points per second against a trained
MLP-with-embeddings (surrogate/model.py). The kernel fuses, per VMEM
tile of ``BLOCK_N`` designs on the sublane axis:

  1. featurization — the categorical one-hot embeddings, normalized
     ordinals, HBM-mask bit extracts and bandwidth-product interactions
     of ``model.featurize_t``, computed on the 128-lane axis (all
     inputs are small integers, so the f32 arithmetic is bit-exact
     against the int32 reference path), with the mesh-dims lookup as a
     one-hot matmul against the same (256, 128) table
     ``chiplet_eval`` uses — TPU-native, no gather;
  2. the 2-layer MLP — two MXU (B, 128) x (128, 128) matmuls over the
     zero-padded weight operands;
  3. the scenario-conditioned head — the Eq.-17 (alpha, beta, gamma)
     combination pre-folded into a single readout vector + first-layer
     bias by ``model.fold_scenario``, applied as one lane reduction.

  inputs:  designs f32 (N, 128)  — cols 0..13 = Table-1 grid indices
           mesh    f32 (256,128) — col 0 = m, col 1 = n (shared table)
           w1      f32 (128,128) — rows 0..28 = W1, cols 0..H-1
           w2      f32 (128,128) — rows/cols 0..H-1 = W2
           vecs    f32 (8, 128)  — row 0 = b1_eff, row 1 = b2,
                                   row 2 = w_s, row 3 col 0 = bias_s
  output:  scores  f32 (N, 128)  — col 0 = predicted Eq.-17 reward

``kernels/ref.surrogate_score_reference`` is the interpret-mode twin
(the pure-jnp model path); ``tests/test_kernels.py`` asserts parity.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import chiplet_eval as _ce
from repro.surrogate import model as sm

BLOCK_N = 256
LANES = 128


def _bit(x, b):
    return jnp.floor(x / (2.0 ** b)) % 2.0


def _kernel(design_ref, mesh_ref, w1_ref, w2_ref, vec_ref, out_ref):
    raw = design_ref[...].astype(jnp.float32)            # (B, 128)
    b = raw.shape[0]

    arch = raw[:, 0]
    c1 = raw[:, 1]
    mask = raw[:, 2] + 1.0
    is_lol = (arch == 2.0).astype(jnp.float32)

    # footprint positions + mesh dims (one-hot matmul, like chiplet_eval)
    n_pos = jnp.where(is_lol > 0, jnp.floor((c1 + 2.0) / 2.0), c1 + 1.0)
    onehot = (jax.lax.broadcasted_iota(jnp.float32, (b, 256), 1)
              == n_pos[:, None]).astype(jnp.float32)
    mn = jnp.dot(onehot, mesh_ref[...],
                 preferred_element_type=jnp.float32)
    m, n = mn[:, 0], mn[:, 1]

    bits = [_bit(mask, i) for i in range(6)]
    cf = c1 + 1.0                                        # n_dies
    feats = jnp.stack([
        (arch == 0.0).astype(jnp.float32),
        (arch == 1.0).astype(jnp.float32),
        is_lol, *bits, sum(bits) * (1.0 / 6.0),
        raw[:, 3], raw[:, 7], raw[:, 10],
        c1 * (1.0 / 128.0), raw[:, 4] * (1.0 / 20.0),
        raw[:, 5] * (1.0 / 100.0), raw[:, 6] * (1.0 / 10.0),
        raw[:, 8] * (1.0 / 31.0), raw[:, 9] * (1.0 / 100.0),
        raw[:, 11] * (1.0 / 20.0), raw[:, 12] * (1.0 / 100.0),
        raw[:, 13] * (1.0 / 10.0),
        (raw[:, 4] + 1.0) * (raw[:, 5] + 1.0) * (1.0 / 2000.0),
        (raw[:, 11] + 1.0) * (raw[:, 12] + 1.0) * (1.0 / 2000.0),
        jnp.sqrt(cf) * (1.0 / 12.0), 1.0 / cf,
        m * (1.0 / 16.0), n * (1.0 / 16.0), (m + n) * (1.0 / 30.0),
    ], axis=-1)                                          # (B, 29)
    feats = jnp.pad(feats, ((0, 0), (0, LANES - sm.N_FEATURES)))

    vecs = vec_ref[...]
    h1 = jax.nn.relu(jnp.dot(feats, w1_ref[...],
                             preferred_element_type=jnp.float32)
                     + vecs[0][None, :])
    h2 = jax.nn.relu(jnp.dot(h1, w2_ref[...],
                             preferred_element_type=jnp.float32)
                     + vecs[1][None, :])
    score = jnp.sum(h2 * vecs[2][None, :], axis=1) + vecs[3, 0]
    out_ref[...] = jnp.pad(score[:, None], ((0, 0), (0, LANES - 1)))


def pack_folded(folded: sm.FoldedParams):
    """FoldedParams -> the zero-padded (w1, w2, vecs) kernel operands."""
    h = folded.W2.shape[0]
    w1 = jnp.zeros((LANES, LANES), jnp.float32)
    w1 = w1.at[: sm.N_FEATURES, :h].set(folded.W1.astype(jnp.float32))
    w2 = jnp.zeros((LANES, LANES), jnp.float32)
    w2 = w2.at[:h, :h].set(folded.W2.astype(jnp.float32))
    vecs = jnp.zeros((8, LANES), jnp.float32)
    vecs = vecs.at[0, :h].set(folded.b1_eff.astype(jnp.float32))
    vecs = vecs.at[1, :h].set(folded.b2.astype(jnp.float32))
    vecs = vecs.at[2, :h].set(folded.w_s.astype(jnp.float32))
    vecs = vecs.at[3, 0].set(folded.bias_s.astype(jnp.float32))
    return w1, w2, vecs


def pad_flats(flat: jnp.ndarray, block_n: int = BLOCK_N) -> jnp.ndarray:
    """(N, 14) int design flats -> (N_padded, 128) f32 kernel input."""
    x = jnp.asarray(flat, jnp.float32)
    n_pad = (-x.shape[0]) % block_n
    return jnp.pad(x, ((0, n_pad), (0, LANES - x.shape[1])))


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def score_batch(designs_padded: jnp.ndarray, w1: jnp.ndarray,
                w2: jnp.ndarray, vecs: jnp.ndarray,
                interpret: bool = True,
                block_n: int = BLOCK_N) -> jnp.ndarray:
    """Run the kernel on padded designs; returns (N_padded,) scores."""
    n = designs_padded.shape[0]
    assert n % block_n == 0, f"batch {n} must be a multiple of {block_n}"
    mesh_tab = jnp.asarray(_ce._mesh_tables())
    tile = pl.BlockSpec((block_n, LANES), lambda i: (i, 0))
    whole = lambda shape: pl.BlockSpec(shape, lambda i: (0, 0))
    out = pl.pallas_call(
        _kernel,
        grid=(n // block_n,),
        in_specs=[tile, whole((256, LANES)), whole((LANES, LANES)),
                  whole((LANES, LANES)), whole((8, LANES))],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((n, LANES), jnp.float32),
        interpret=interpret,
    )(designs_padded.astype(jnp.float32), mesh_tab, w1, w2, vecs)
    return out[:, 0]


def surrogate_score(flat: jnp.ndarray, folded: sm.FoldedParams,
                    interpret: bool = True,
                    block_n: int = BLOCK_N) -> jnp.ndarray:
    """(N, 14) design flats -> (N,) surrogate scores via the kernel."""
    n = flat.shape[0]
    padded = pad_flats(flat, block_n)
    w1, w2, vecs = pack_folded(folded)
    return score_batch(padded, w1, w2, vecs, interpret=interpret,
                       block_n=block_n)[:n]
