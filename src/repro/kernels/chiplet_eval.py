"""Pallas TPU kernel: batched Chiplet-Gym design-point evaluation.

This is the DSE hot loop — the portfolio optimizer evaluates millions of
design points (SA proposals, PPO rollouts, exhaustive refinement sweeps).
The kernel evaluates a VMEM-resident tile of design points entirely on the
VPU:

  layout:  a tile of ``BLOCK_N`` design points occupies the sublane axis;
           the 128 chiplet placement slots, the 16x16 routing-grid scan
           (2 x 128 lanes) and the 14 design fields live on the 128-lane
           axis. The mesh-dims lookup (the Table of near-square
           factorizations) is a one-hot matmul — TPU-native, no gather.

  inputs:  designs  f32 (N, 128)   — cols 0..13 = Table-1 grid indices,
                                     cols 14..25 = HBM anchor (i, j) pairs
           cells    f32 (N, 128)   — placement cell id per chiplet slot
           mesh_tab f32 (256, 128) — col 0 = m, col 1 = n, row = #positions
  output:  metrics  f32 (N, 128)   — cols 0..11 =
           [reward, eff_tops, e_comm_pj, pkg_cost, die_cost, u_sys,
            lat_hbm_ns, lat_ai_ns, hops_hbm_mean, hops_ai_mean,
            link_contention, hops_hbm_worst]

The NoP section implements the pairwise-traffic placement model of
``core/placement.py``: worst-case hops reduce over the spanned mesh
region, means and contention are traffic-weighted over the occupied
slots — all on the lane axis. ``pad_designs`` / ``pad_cells`` build the
canonical Fig.-4 floorplan when no explicit placement is given.

The arithmetic mirrors ``repro.core.costmodel.evaluate`` term by term;
``tests/test_kernels.py`` sweeps shapes and asserts allclose against the
pure-jnp oracle (``kernels/ref.py``).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import costmodel as cm
from repro.core import hw_constants as hw
from repro.core import params as ps
from repro.core import placement as pm

BLOCK_N = 256
LANES = 128
N_OUT = 12
_GRID = 16          # 16x16 placement grid = 256 cells = 2 x 128 lanes
_HBM_COL = 14       # designs cols 14..25 hold the 6 HBM (i, j) anchors
_CANON_COL = 26     # cols 26..28: canonical-floorplan link contention,
#                     mean HBM hops, mean AI hops (host-computed baselines)
_TILE_COL = 29      # cols 29..32: per-layer-group tile indices (mapping
#                     tier only; the per-slot pipeline stages stream as
#                     their own (N, 128) operand)


def _mesh_tables() -> np.ndarray:
    """(256, 128) table: row p -> [m, n, 0...] for p footprint positions."""
    tab = np.zeros((256, LANES), np.float32)
    m = np.asarray(cm._MESH_M)
    n = np.asarray(cm._MESH_N)
    tab[: len(m), 0] = m
    tab[: len(n), 1] = n
    return tab


def _bit(x, b):
    return jnp.floor(x / (2.0 ** b)) % 2.0


def _kernel(design_ref, cells_ref, mesh_ref, out_ref, *,
            workload_vals: Tuple[float, float, float, float],
            weight_vals: Tuple[float, float, float],
            cfg: hw.HWConfig,
            nop_fidelity: str = "full",
            stage_ref=None):
    gemm_ops, nongemm_ops, _hbm_bytes, mapping_eff = workload_vals
    w_alpha, w_beta, w_gamma = weight_vals
    with_mapping = stage_ref is not None
    assert not (with_mapping and nop_fidelity == "fast"), \
        "the fast tier evaluates the canonical dataflow only"

    raw = design_ref[...].astype(jnp.float32)          # (B, 128)
    b = raw.shape[0]

    # ---- decode Table-1 indices -> values (cols 0..13) --------------------
    arch = raw[:, 0]
    n_dies = raw[:, 1] + 1.0
    mask = raw[:, 2] + 1.0
    ai_ic = raw[:, 3]
    ai_dr = raw[:, 4] + 1.0
    ai_links = (raw[:, 5] + 1.0) * 50.0
    ai_trace = raw[:, 6] + 1.0
    ic3d = raw[:, 7]
    dr3d = raw[:, 8] + 20.0
    links3d = (raw[:, 9] + 1.0) * 100.0
    hbm_ic = raw[:, 10]
    hbm_dr = raw[:, 11] + 1.0
    hbm_links = (raw[:, 12] + 1.0) * 50.0
    hbm_trace = raw[:, 13] + 1.0

    is_lol = (arch == 2.0).astype(jnp.float32)
    uses_3d_mem = _bit(mask, 5) * (arch >= 1.0).astype(jnp.float32)

    # ---- geometry ----------------------------------------------------------
    n_pos = jnp.where(is_lol > 0, jnp.ceil(n_dies / 2.0), n_dies)
    onehot = (jax.lax.broadcasted_iota(jnp.float32, (b, 256), 1)
              == n_pos[:, None]).astype(jnp.float32)       # (B, 256)
    mn = jnp.dot(onehot, mesh_ref[...],
                 preferred_element_type=jnp.float32)        # (B, 128)
    m, n = mn[:, 0], mn[:, 1]

    bits = [_bit(mask, i) for i in range(6)]
    n_hbm = sum(bits)
    n_hbm_2p5d = n_hbm - uses_3d_mem
    avail = (cfg.package_area_mm2 - (m + n + 2.0) * hw.CHIPLET_SPACING_MM
             - n_hbm_2p5d * cfg.hbm_footprint_mm2)
    avail = jnp.maximum(avail, 1.0)
    die_area = jnp.minimum(avail / n_pos, cfg.max_chiplet_area_mm2)

    any_3d = jnp.maximum(is_lol, uses_3d_mem)
    tsv_area = jnp.minimum(cfg.tsv_area_mm2, 0.08 * die_area)
    logic_area = jnp.maximum(die_area - any_3d * tsv_area, 0.1)
    logic_eff = 1.0 - is_lol * cfg.tsv_keepout_frac
    compute_area = logic_area * cfg.compute_area_frac * logic_eff
    sram_mb = logic_area * hw.SRAM_AREA_FRAC * logic_eff * hw.SRAM_MB_PER_MM2

    pes = compute_area * 1e6 / cfg.pe_area_um2
    reuse = jnp.sqrt(jnp.maximum(pes, 1.0))
    dw_bytes = cfg.data_width_bits / 8.0
    reuse_mem = jnp.sqrt(jnp.maximum(sram_mb * 1e6 / (3.0 * dw_bytes), 1.0))
    reuse_comm = reuse_mem if cfg.comm_reuse_systolic else jnp.ones_like(reuse_mem)

    # ---- pairwise-traffic NoP reduction (core/placement.py, lane axis) ----
    lane = jax.lax.broadcasted_iota(jnp.float32, (b, LANES), 1)
    big = jnp.float32(1e9)

    if nop_fidelity == "fast":
        # fast tier: the canonical Fig.-4 floorplan derived analytically
        # on the lane axis (cell (i, j) occupied iff j < n and
        # i*n + j < n_pos) — no cells input, no host-side canonical
        # baseline columns, congestion / per-hop-energy ratios exactly 1.
        mc, ncc = (m - 1.0) / 2.0, (n - 1.0) / 2.0
        neg1 = jnp.full_like(m, -1.0)
        fast_anchors = [(mc, neg1), (mc, n), (neg1, ncc), (m, ncc),
                        (mc, ncc), (mc, ncc)]
        floor_3d = jnp.where(arch >= 1.0, 0.0, 1.0)

        def min_anchor_dist_fast(i, j):
            dmin = jnp.full_like(i, big)
            for bi, (hi, hj) in enumerate(fast_anchors):
                floor = floor_3d if bi == 5 else jnp.ones_like(arch)
                d = jnp.maximum(jnp.abs(i - hi[:, None])
                                + jnp.abs(j - hj[:, None]), floor[:, None])
                dmin = jnp.minimum(dmin,
                                   jnp.where(bits[bi][:, None] > 0, d, big))
            return dmin

        def half_stats(cell_idx):
            i = jnp.floor(cell_idx / _GRID)
            j = cell_idx % _GRID
            occ = ((j < n[:, None])
                   & (i * n[:, None] + j < n_pos[:, None])).astype(
                       jnp.float32)
            in_box = (i < m[:, None]) & (j < n[:, None])
            return i, j, occ, in_box, min_anchor_dist_fast(i, j)

        halves = [half_stats(lane), half_stats(lane + LANES)]
        inv_pos = 1.0 / jnp.maximum(n_pos, 1.0)
        sum_hbm = sum(jnp.sum(occ * d, axis=1)
                      for _, _, occ, _, d in halves)
        h_hbm = jnp.maximum(
            *[jnp.max(jnp.where(in_box, d, -big), axis=1)
              for _, _, _, in_box, d in halves])
        h_hbm_mean = sum_hbm * inv_pos

        # canonical row-major centroid, closed form: f full rows of n
        # cells plus k leftover cells in row f
        f_rows = jnp.floor(n_pos / jnp.maximum(n, 1.0))
        k_rem = n_pos - f_rows * n
        cent_i = (n * f_rows * (f_rows - 1.0) / 2.0
                  + k_rem * f_rows) * inv_pos
        cent_j = (f_rows * n * (n - 1.0) / 2.0
                  + k_rem * (k_rem - 1.0) / 2.0) * inv_pos
        sum_cent = sum(
            jnp.sum(occ * (jnp.abs(i - cent_i[:, None])
                           + jnp.abs(j - cent_j[:, None])), axis=1)
            for i, j, occ, _, _ in halves)
        h_ai_mean = sum_cent * inv_pos

        h_ai = (m - 1.0) + (n - 1.0)
        mesh_edges = m * (n - 1.0) + n * (m - 1.0)
        box_edges = mesh_edges
        contention = (4.0 * sum_hbm + sum_cent) / jnp.maximum(mesh_edges,
                                                              1.0)
        congestion = jnp.ones_like(m)
        e_hop_hbm = jnp.ones_like(m)
        e_hop_ai = jnp.ones_like(m)
    else:
        cells = cells_ref[...].astype(jnp.float32)     # (B, 128) cell ids
        ci = jnp.floor(cells / _GRID)
        cj = cells - jnp.floor(cells / _GRID) * _GRID
        active = lane < n_pos[:, None]

        # spanned mesh region (bounding box of occupied cells)
        i_max = jnp.max(jnp.where(active, ci, -big), axis=1)
        i_min = jnp.min(jnp.where(active, ci, big), axis=1)
        j_max = jnp.max(jnp.where(active, cj, -big), axis=1)
        j_min = jnp.min(jnp.where(active, cj, big), axis=1)
        h_ai = (i_max - i_min) + (j_max - j_min)

        # HBM anchors (cols 14..25) + per-anchor hop floors
        anchors = []
        for bi in range(6):
            hi = raw[:, _HBM_COL + 2 * bi]
            hj = raw[:, _HBM_COL + 2 * bi + 1]
            floor = (jnp.where(arch >= 1.0, 0.0, 1.0) if bi == 5
                     else jnp.ones_like(arch))
            anchors.append((hi, hj, floor))

        def min_anchor_dist(i, j):
            dmin = jnp.full_like(i, big)
            for bit, (hi, hj, floor) in zip(bits, anchors):
                d = jnp.maximum(jnp.abs(i - hi[:, None])
                                + jnp.abs(j - hj[:, None]), floor[:, None])
                dmin = jnp.minimum(dmin, jnp.where(bit[:, None] > 0, d, big))
            return dmin

        # nearest-stack distance *field* over the 16x16 grid, one 128-lane
        # row per grid half — the only two min_anchor_dist passes left
        def grid_half(cell_idx):
            i = jnp.floor(cell_idx / _GRID)
            j = cell_idx % _GRID
            return i, j, min_anchor_dist(i, j)

        gi0, gj0, gd0 = grid_half(lane)                # cells   0..127
        gi1, gj1, gd1 = grid_half(lane + LANES)        # cells 128..255

        # per occupied slot -> nearest stack: MXU one-hot gather. Each
        # slot's one-hot row selects exactly one lane of a grid-half
        # field, so the f32 matmul reproduces min_anchor_dist(ci, cj)
        # bit-exactly (one selected value + zeros) without a third
        # per-slot anchor scan.
        oh0 = (cells[:, :, None] == lane[:, None, :]).astype(jnp.float32)
        oh1 = (cells[:, :, None]
               == (lane[:, None, :] + LANES)).astype(jnp.float32)
        gather_dims = (((2,), (1,)), ((0,), (0,)))     # (B,S,C) x (B,C)
        d_hbm = (jax.lax.dot_general(oh0, gd0, gather_dims,
                                     preferred_element_type=jnp.float32)
                 + jax.lax.dot_general(oh1, gd1, gather_dims,
                                       preferred_element_type=jnp.float32))
        inv_pos = 1.0 / jnp.maximum(n_pos, 1.0)
        sum_hbm = jnp.sum(jnp.where(active, d_hbm, 0.0), axis=1)
        h_hbm_mean = sum_hbm * inv_pos

        # worst router of the spanned region, reusing the field rows
        def half_worst(i, j, d):
            in_box = ((i >= i_min[:, None]) & (i <= i_max[:, None])
                      & (j >= j_min[:, None]) & (j <= j_max[:, None]))
            return jnp.max(jnp.where(in_box, d, -big), axis=1)

        h_hbm = jnp.maximum(half_worst(gi0, gj0, gd0),
                            half_worst(gi1, gj1, gd1))

        # chiplet-to-chiplet forwarding fans out from the traffic centroid
        cent_i = jnp.sum(jnp.where(active, ci, 0.0), axis=1) * inv_pos
        cent_j = jnp.sum(jnp.where(active, cj, 0.0), axis=1) * inv_pos
        d_cent = (jnp.abs(ci - cent_i[:, None])
                  + jnp.abs(cj - cent_j[:, None]))
        sum_cent = jnp.sum(jnp.where(active, d_cent, 0.0), axis=1)
        h_ai_mean = sum_cent * inv_pos

        # per-link contention over the canonical m x n fabric (the NoP the
        # design pays for); delivered 2.5D bandwidth scales vs the
        # canonical floorplan's channel load
        bm = i_max - i_min + 1.0
        bn = j_max - j_min + 1.0
        box_edges = bm * (bn - 1.0) + bn * (bm - 1.0)
        mesh_edges = m * (n - 1.0) + n * (m - 1.0)
        contention = (4.0 * sum_hbm + sum_cent) / jnp.maximum(mesh_edges, 1.0)
        if with_mapping:
            # mapped Fig.-5 traffic (core/placement._stats_tail mapped
            # branch): a pipeline receiver swaps 3 of its 4 HBM pulls
            # for 3 streams forwarded from the previous stage's
            # centroid. The stage one-hot select over the 4 pipeline
            # stages extends the anchor gather: per-stage centroids
            # reduce over the 128-lane slot axis, then each slot
            # one-hot-selects its predecessor stage's centroid and
            # count — all lane-axis VPU work, no scatter.
            active_f = active.astype(jnp.float32)
            stg = jnp.clip(stage_ref[...].astype(jnp.float32), 0.0, 3.0)
            cnts, cent_si, cent_sj = [], [], []
            for s in range(4):
                oh_s = active_f * (stg == float(s)).astype(jnp.float32)
                c = jnp.sum(oh_s, axis=1)
                inv_c = 1.0 / jnp.maximum(c, 1.0)
                cnts.append(c)
                cent_si.append(jnp.sum(oh_s * ci, axis=1) * inv_c)
                cent_sj.append(jnp.sum(oh_s * cj, axis=1) * inv_c)
            prev_i = jnp.zeros_like(stg)
            prev_j = jnp.zeros_like(stg)
            prev_cnt = jnp.zeros_like(stg)
            for s in range(4):
                sel = (stg == float(s)).astype(jnp.float32)
                p = max(s - 1, 0)
                prev_i = prev_i + sel * cent_si[p][:, None]
                prev_j = prev_j + sel * cent_sj[p][:, None]
                prev_cnt = prev_cnt + sel * cnts[p][:, None]
            recv = (active_f * (stg > 0.0).astype(jnp.float32)
                    * (prev_cnt > 0.0).astype(jnp.float32))
            d_prev = jnp.abs(ci - prev_i) + jnp.abs(cj - prev_j)
            n_recv = jnp.sum(recv, axis=1)
            fwd_hops = jnp.sum(recv * d_prev, axis=1)
            # reciprocal form so zero receivers reproduce the unmapped
            # `sum_cent * inv_pos` bit-for-bit (x + 0.0 == x, and the
            # denominator collapses to exactly max(n_pos, 1))
            h_ai_mean = ((sum_cent + 3.0 * fwd_hops)
                         * (1.0 / (jnp.maximum(n_pos, 1.0)
                                   + 3.0 * n_recv)))
            stream_hops = (4.0 * sum_hbm
                           - 3.0 * jnp.sum(recv * d_hbm, axis=1)
                           + sum_cent + 3.0 * fwd_hops)
            contention = stream_hops / jnp.maximum(mesh_edges, 1.0)
            # placement-free mapped-traffic factors (mapping.traffic_summary)
            recv_frac = n_recv / jnp.maximum(n_pos, 1.0)
            pull_frac = 1.0 - 0.75 * recv_frac
            n_stages = sum((c > 0.0).astype(jnp.float32) for c in cnts)
            max_cnt = functools.reduce(jnp.maximum, cnts)
            balance = (jnp.maximum(n_pos, 1.0)
                       / jnp.maximum(n_stages * max_cnt, 1.0))
            tiles = raw[:, _TILE_COL: _TILE_COL + 4] - 3.0   # vs CANON_TILE
            s_mean = jnp.mean(tiles, axis=1)
            s_sq = jnp.mean(tiles * tiles, axis=1)
            tile_hbm = jnp.exp2(-0.35 * s_mean)
            tile_u = 1.0 / (1.0 + 0.03 * s_sq)
        canon_contention = raw[:, _CANON_COL]
        congestion = ((canon_contention + 1e-6)
                      / (contention + 1e-6)) ** cfg.nop_congestion_exp
        congestion = jnp.clip(congestion, 0.1, 10.0)
        # per-hop interconnect energy ratios vs the canonical floorplan
        e_hop_hbm = jnp.clip((h_hbm_mean + 1e-6)
                             / (raw[:, _CANON_COL + 1] + 1e-6), 0.1, 10.0)
        e_hop_ai = jnp.clip((h_ai_mean + 1e-6)
                            / (raw[:, _CANON_COL + 2] + 1e-6), 0.1, 10.0)

    # ---- latency (Eqs. 10-11) ---------------------------------------------
    wire_ai = cfg.wire_delay_ps_2p5d * ai_trace / 1000.0
    wire_hbm = cfg.wire_delay_ps_2p5d * hbm_trace / 1000.0
    fixed = cfg.contention_delay_ns + cfg.serialization_delay_ns
    lat_ai = h_ai * (wire_ai + cfg.router_delay_ns) + fixed
    lat_hbm = (h_hbm * (wire_hbm + cfg.router_delay_ns) + fixed
               + uses_3d_mem * (cfg.wire_delay_ps_3d / 1000.0))
    lat_3d = cfg.wire_delay_ps_3d / 1000.0 + cfg.serialization_delay_ns
    worst_lat = jnp.maximum(lat_ai, lat_hbm) + is_lol * lat_3d
    cycles_per_op = 1.0 + worst_lat * cfg.freq_ghz / (
        reuse ** cfg.latency_amort_exp)

    # ---- bandwidth / utilization (Eqs. 12-14) ------------------------------
    ops_per_die = pes * cfg.freq_ghz * 1e9 / cycles_per_op
    operand_gbps = (cfg.n_operands * cfg.data_width_bits
                    * ops_per_die / reuse_comm) / 1e9
    bw_req_hbm = 4.0 * operand_gbps
    bw_req_ai = operand_gbps
    if with_mapping:
        # receivers pull 1 of 4 streams from HBM; larger tiles amortize
        # more HBM traffic; forwarded streams land on the AI fabric
        bw_req_hbm = bw_req_hbm * (pull_frac * tile_hbm)
        bw_req_ai = bw_req_ai * (1.0 + 3.0 * recv_frac)
    link_bw_hbm = hbm_dr * hbm_links * congestion
    bw_act_hbm = (jnp.minimum(link_bw_hbm, hw.HBM_BANDWIDTH_GBPS_PER_STACK)
                  if cfg.hbm_peak_cap else link_bw_hbm)
    u_hbm = jnp.minimum(1.0, bw_act_hbm / jnp.maximum(bw_req_hbm, 1e-6))
    u_ai = jnp.minimum(1.0, ai_dr * ai_links * congestion
                       / jnp.maximum(bw_req_ai, 1e-6))
    u_3d = jnp.minimum(1.0, dr3d * links3d / jnp.maximum(bw_req_ai, 1e-6))
    u_sys = jnp.minimum(u_hbm, u_ai)
    u_sys = jnp.where(is_lol > 0, jnp.minimum(u_sys, u_3d), u_sys)

    # ---- throughput ---------------------------------------------------------
    u_chip = mapping_eff
    if with_mapping:
        # tile sweet-spot + pipeline-balance penalties (1.0 at canonical)
        u_chip = u_chip * (tile_u * balance)
    eff_ops = ops_per_die * n_dies * u_sys * u_chip
    eff_tops = eff_ops / 1e12

    # ---- energy -------------------------------------------------------------
    def lerp(lo, hi, tr):
        t = (jnp.clip(tr, 1.0, 10.0) - 1.0) / 9.0
        return lo + (hi - lo) * t

    e_hbm_link = lerp(jnp.where(hbm_ic < 0.5, hw.E_BIT_PJ_2P5D_MIN[0],
                                hw.E_BIT_PJ_2P5D_MIN[1]),
                      jnp.where(hbm_ic < 0.5, hw.E_BIT_PJ_2P5D_MAX[0],
                                hw.E_BIT_PJ_2P5D_MAX[1]),
                      hbm_trace) * e_hop_hbm
    e_ai_link = lerp(jnp.where(ai_ic < 0.5, hw.E_BIT_PJ_2P5D_MIN[0],
                               hw.E_BIT_PJ_2P5D_MIN[1]),
                     jnp.where(ai_ic < 0.5, hw.E_BIT_PJ_2P5D_MAX[0],
                               hw.E_BIT_PJ_2P5D_MAX[1]),
                     ai_trace) * e_hop_ai
    e_3d = jnp.where(ic3d < 0.5, hw.E_BIT_PJ_3D[0], hw.E_BIT_PJ_3D[1])
    bits_hbm = cfg.n_operands * cfg.data_width_bits / reuse_comm
    if with_mapping:
        # streams a receiver no longer pulls from HBM traverse the AI
        # fabric instead (0.75 x recv_frac of the operand bits)
        bits_hbm = bits_hbm * (pull_frac * tile_hbm)
        bits_ai = (cfg.n_operands * cfg.data_width_bits / reuse_comm
                   * (0.5 + 0.75 * recv_frac))
    else:
        bits_ai = 0.5 * bits_hbm
    e_comm = (bits_hbm * (e_hbm_link + cfg.e_bit_hbm_device_pj)
              + bits_ai * e_ai_link + is_lol * bits_ai * e_3d
              + uses_3d_mem * bits_hbm * (e_3d - e_hbm_link))

    # ---- cost ---------------------------------------------------------------
    d_mm2 = cfg.defect_density_per_cm2 / 100.0
    y_die = (1.0 + d_mm2 * die_area / cfg.yield_alpha) ** (-cfg.yield_alpha)
    die_cost = (n_dies * cfg.wafer_price_per_mm2 * die_area / y_die
                * (1.0 + hw.KGD_TEST_COST_FRAC))

    # package link cost wires the *spanned* mesh region (== m x n canonical)
    l_ai = ai_links * box_edges
    l_hbm = hbm_links * n_hbm_2p5d
    n_pairs = jnp.where(is_lol > 0, jnp.floor(n_dies / 2.0), 0.0)
    l_3d = links3d * n_pairs + links3d * uses_3d_mem

    mu0 = jnp.maximum(
        jnp.where(ai_ic < 0.5, hw.PKG_MU0_PER_MM2[0], hw.PKG_MU0_PER_MM2[1]),
        jnp.where(hbm_ic < 0.5, hw.PKG_MU0_PER_MM2[0], hw.PKG_MU0_PER_MM2[1]))
    mu2 = jnp.maximum(
        jnp.where(ai_ic < 0.5, hw.PKG_MU2_FIXED[0], hw.PKG_MU2_FIXED[1]),
        jnp.where(hbm_ic < 0.5, hw.PKG_MU2_FIXED[0], hw.PKG_MU2_FIXED[1]))
    mu1_ai = jnp.where(ai_ic < 0.5, hw.PKG_MU1_PER_LINK[0],
                       hw.PKG_MU1_PER_LINK[1])
    mu1_hbm = jnp.where(hbm_ic < 0.5, hw.PKG_MU1_PER_LINK[0],
                        hw.PKG_MU1_PER_LINK[1])
    mu1_3d = jnp.where(ic3d < 0.5, hw.PKG_MU1_PER_LINK_3D[0],
                       hw.PKG_MU1_PER_LINK_3D[1])
    fix_3d = jnp.where(ic3d < 0.5, hw.PKG_3D_FIXED_PER_STACK[0],
                       hw.PKG_3D_FIXED_PER_STACK[1])
    n_stacks = n_pairs + uses_3d_mem
    pkg_raw = (mu0 * cfg.package_area_mm2 + mu1_ai * l_ai + mu1_hbm * l_hbm
               + mu1_3d * l_3d + fix_3d * n_stacks + mu2)
    y_asm = cfg.bond_yield ** n_stacks
    pkg_cost = pkg_raw / jnp.maximum(y_asm, 1e-3)

    # ---- reward (Eq. 17) ----------------------------------------------------
    r_t = eff_tops * cfg.reward_throughput_scale
    r_c = pkg_cost * cfg.reward_cost_scale / 10.0
    r_e = e_comm * cfg.reward_energy_scale
    reward = w_alpha * r_t - w_beta * r_c - w_gamma * r_e

    out = jnp.stack([reward, eff_tops, e_comm, pkg_cost, die_cost,
                     u_sys, lat_hbm, lat_ai, h_hbm_mean, h_ai_mean,
                     contention, h_hbm], axis=-1)            # (B, 12)
    pad = jnp.zeros((b, LANES - N_OUT), jnp.float32)
    out_ref[...] = jnp.concatenate([out, pad], axis=-1)


@functools.partial(jax.jit, static_argnames=("workload_vals", "weight_vals",
                                             "cfg", "interpret", "block_n",
                                             "nop_fidelity"))
def evaluate_batch(designs_padded: jnp.ndarray,
                   cells_padded: jnp.ndarray,
                   workload_vals: Tuple[float, float, float, float],
                   weight_vals: Tuple[float, float, float],
                   cfg: hw.HWConfig = hw.DEFAULT_HW,
                   interpret: bool = True,
                   block_n: int = BLOCK_N,
                   nop_fidelity: str = "full",
                   stage_padded: jnp.ndarray = None) -> jnp.ndarray:
    """Run the kernel on padded (designs, cells); returns (N, 12) metrics.

    ``designs_padded`` / ``cells_padded`` come from :func:`pad_designs` /
    :func:`pad_cells` (which default to the canonical Fig.-4 floorplan).
    ``nop_fidelity='fast'`` statically selects the closed-form canonical
    NoP tier: the kernel derives the Fig.-4 floorplan analytically on the
    lane axis, the host-side canonical-baseline columns are unused, and
    ``cells_padded`` may be None (no cells operand is even streamed).
    ``stage_padded`` (from :func:`pad_stage`, full tier only) streams the
    per-slot pipeline stages of an explicit mapping; the tile indices
    ride the designs array cols 29..32 (``pad_designs(mapping=...)``).
    """
    n = designs_padded.shape[0]
    assert n % block_n == 0, f"batch {n} must be a multiple of {block_n}"
    assert not (stage_padded is not None and nop_fidelity == "fast"), \
        "the fast tier evaluates the canonical dataflow only"
    mesh_tab = jnp.asarray(_mesh_tables())
    kernel = functools.partial(_kernel, workload_vals=workload_vals,
                               weight_vals=weight_vals, cfg=cfg,
                               nop_fidelity=nop_fidelity)
    design_spec = pl.BlockSpec((block_n, LANES), lambda i: (i, 0))
    mesh_spec = pl.BlockSpec((256, LANES), lambda i: (0, 0))
    out_kw = dict(
        grid=(n // block_n,),
        out_specs=pl.BlockSpec((block_n, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, LANES), jnp.float32),
        interpret=interpret,
    )
    if nop_fidelity == "fast":
        # fast tier never reads cells_ref — drop the operand entirely
        # instead of DMA-ing a dead (N, 128) array through the grid
        def kernel_fast(design_ref, mesh_ref, out_ref):
            kernel(design_ref, None, mesh_ref, out_ref)

        out = pl.pallas_call(
            kernel_fast, in_specs=[design_spec, mesh_spec], **out_kw,
        )(designs_padded.astype(jnp.float32), mesh_tab)
    elif stage_padded is not None:
        assert cells_padded.shape == designs_padded.shape
        assert stage_padded.shape == designs_padded.shape

        def kernel_map(design_ref, c_ref, s_ref, mesh_ref, out_ref):
            kernel(design_ref, c_ref, mesh_ref, out_ref, stage_ref=s_ref)

        out = pl.pallas_call(
            kernel_map,
            in_specs=[design_spec, design_spec, design_spec, mesh_spec],
            **out_kw,
        )(designs_padded.astype(jnp.float32),
          cells_padded.astype(jnp.float32),
          stage_padded.astype(jnp.float32), mesh_tab)
    else:
        assert cells_padded.shape == designs_padded.shape
        out = pl.pallas_call(
            kernel, in_specs=[design_spec, design_spec, mesh_spec], **out_kw,
        )(designs_padded.astype(jnp.float32),
          cells_padded.astype(jnp.float32), mesh_tab)
    return out[:, :N_OUT]


def _design_placement(dp: ps.DesignPoint, placement: pm.Placement = None):
    """Resolve (placement, canonical NoP baselines) for a design batch.

    The canonical baselines come from the closed-form fast tier (no
    canonical ``Placement`` is reduced), matching what
    ``costmodel.evaluate`` normalizes against on its full-tier path.
    """
    v = ps.decode(dp)
    n_pos = cm.footprint_positions(v)
    m, n = cm.mesh_dims(n_pos)
    canon = pm.canonical(m, n, v.hbm_mask, v.arch_type)
    canon_stats = pm.nop_stats_fast(m, n, n_pos, v.hbm_mask, v.arch_type)
    return (canon if placement is None else placement), canon_stats


def pad_designs(dp: ps.DesignPoint, placement: pm.Placement = None,
                block_n: int = BLOCK_N, _resolved=None,
                nop_fidelity: str = "full",
                mapping=None) -> jnp.ndarray:
    """(B,)-batched DesignPoint -> (N_padded, 128) f32 kernel input.

    Cols 0..13 carry the Table-1 indices, cols 14..25 the six HBM anchor
    (i, j) coordinates of ``placement`` (canonical when None), col 26 the
    canonical floorplan's link contention (the congestion baseline).
    ``_resolved`` lets callers pass a precomputed ``_design_placement``
    result to avoid re-running the canonical baseline (ops.chiplet_eval).
    ``nop_fidelity='fast'`` skips the anchor/baseline resolution entirely
    (the fast-tier kernel derives the canonical floorplan itself).
    ``mapping`` (a batched ``mapping.Mapping``) additionally packs the
    per-layer-group tile indices into cols 29..32 — its per-slot stages
    stream separately via :func:`pad_stage`.
    """
    flat = ps.to_flat(dp).astype(jnp.float32)          # (B, 14)
    if nop_fidelity != "fast":
        placement, canon = (_design_placement(dp, placement)
                            if _resolved is None else _resolved)
        hbm = placement.hbm_ij.reshape(flat.shape[0], 2 * pm.N_HBM)
        cols = [flat, hbm, canon.link_contention[:, None],
                canon.hops_hbm_mean[:, None], canon.hops_ai_mean[:, None]]
        if mapping is not None:
            cols.append(jnp.asarray(mapping.tile_idx, jnp.float32))
        flat = jnp.concatenate(cols, axis=-1)
    n = flat.shape[0]
    n_pad = (-n) % block_n
    return jnp.pad(flat, ((0, n_pad), (0, LANES - flat.shape[1])))


def pad_cells(dp: ps.DesignPoint, placement: pm.Placement = None,
              block_n: int = BLOCK_N) -> jnp.ndarray:
    """(B,)-batched placement -> (N_padded, 128) f32 chiplet cell ids."""
    if placement is None:
        placement, _ = _design_placement(dp, None)
    cells = jnp.asarray(placement.chiplet_cell, jnp.float32)   # (B, 128)
    n_pad = (-cells.shape[0]) % block_n
    return jnp.pad(cells, ((0, n_pad), (0, 0)))


def pad_stage(mapping, block_n: int = BLOCK_N) -> jnp.ndarray:
    """(B,)-batched ``mapping.Mapping`` -> (N_padded, 128) f32 stages."""
    stage = jnp.asarray(mapping.stage, jnp.float32)            # (B, 128)
    n_pad = (-stage.shape[0]) % block_n
    return jnp.pad(stage, ((0, n_pad), (0, 0)))
