"""Pallas TPU kernel: batched Chiplet-Gym design-point evaluation.

This is the DSE hot loop — the portfolio optimizer evaluates millions of
design points (SA proposals, PPO rollouts, exhaustive refinement sweeps).
The kernel evaluates a VMEM-resident tile of design points entirely on the
VPU:

  layout:  a tile of ``BLOCK_N`` design points occupies the sublane axis;
           the 16x16 placement grid (the Fig.-4 max-min hop reduction) and
           the 14 design fields live on the 128-lane axis. The mesh-dims
           lookup (the Table of near-square factorizations) is a one-hot
           matmul — TPU-native, no gather.

  inputs:  designs  f32 (N, 128)   — cols 0..13 = Table-1 grid indices
           mesh_tab f32 (256, 128) — col 0 = m, col 1 = n, row = #positions
  output:  metrics  f32 (N, 128)   — cols 0..7 =
           [reward, eff_tops, e_comm_pj, pkg_cost, die_cost, u_sys,
            lat_hbm_ns, lat_ai_ns]

The arithmetic mirrors ``repro.core.costmodel.evaluate`` term by term;
``tests/test_kernels.py`` sweeps shapes and asserts allclose against the
pure-jnp oracle (``kernels/ref.py``).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import costmodel as cm
from repro.core import hw_constants as hw
from repro.core import params as ps

BLOCK_N = 256
LANES = 128
N_OUT = 8
_GRID = 16          # 16x16 placement grid = 256 cells = 2 x 128 lanes


def _mesh_tables() -> np.ndarray:
    """(256, 128) table: row p -> [m, n, 0...] for p footprint positions."""
    tab = np.zeros((256, LANES), np.float32)
    m = np.asarray(cm._MESH_M)
    n = np.asarray(cm._MESH_N)
    tab[: len(m), 0] = m
    tab[: len(n), 1] = n
    return tab


def _bit(x, b):
    return jnp.floor(x / (2.0 ** b)) % 2.0


def _kernel(design_ref, mesh_ref, out_ref, *,
            workload_vals: Tuple[float, float, float, float],
            weight_vals: Tuple[float, float, float],
            cfg: hw.HWConfig):
    gemm_ops, nongemm_ops, _hbm_bytes, mapping_eff = workload_vals
    w_alpha, w_beta, w_gamma = weight_vals

    raw = design_ref[...].astype(jnp.float32)          # (B, 128)
    b = raw.shape[0]

    # ---- decode Table-1 indices -> values (cols 0..13) --------------------
    arch = raw[:, 0]
    n_dies = raw[:, 1] + 1.0
    mask = raw[:, 2] + 1.0
    ai_ic = raw[:, 3]
    ai_dr = raw[:, 4] + 1.0
    ai_links = (raw[:, 5] + 1.0) * 50.0
    ai_trace = raw[:, 6] + 1.0
    ic3d = raw[:, 7]
    dr3d = raw[:, 8] + 20.0
    links3d = (raw[:, 9] + 1.0) * 100.0
    hbm_ic = raw[:, 10]
    hbm_dr = raw[:, 11] + 1.0
    hbm_links = (raw[:, 12] + 1.0) * 50.0
    hbm_trace = raw[:, 13] + 1.0

    is_lol = (arch == 2.0).astype(jnp.float32)
    uses_3d_mem = _bit(mask, 5) * (arch >= 1.0).astype(jnp.float32)

    # ---- geometry ----------------------------------------------------------
    n_pos = jnp.where(is_lol > 0, jnp.ceil(n_dies / 2.0), n_dies)
    onehot = (jax.lax.broadcasted_iota(jnp.float32, (b, 256), 1)
              == n_pos[:, None]).astype(jnp.float32)       # (B, 256)
    mn = jnp.dot(onehot, mesh_ref[...],
                 preferred_element_type=jnp.float32)        # (B, 128)
    m, n = mn[:, 0], mn[:, 1]

    bits = [_bit(mask, i) for i in range(6)]
    n_hbm = sum(bits)
    n_hbm_2p5d = n_hbm - uses_3d_mem
    avail = (cfg.package_area_mm2 - (m + n + 2.0) * hw.CHIPLET_SPACING_MM
             - n_hbm_2p5d * cfg.hbm_footprint_mm2)
    avail = jnp.maximum(avail, 1.0)
    die_area = jnp.minimum(avail / n_pos, cfg.max_chiplet_area_mm2)

    any_3d = jnp.maximum(is_lol, uses_3d_mem)
    tsv_area = jnp.minimum(cfg.tsv_area_mm2, 0.08 * die_area)
    logic_area = jnp.maximum(die_area - any_3d * tsv_area, 0.1)
    logic_eff = 1.0 - is_lol * cfg.tsv_keepout_frac
    compute_area = logic_area * cfg.compute_area_frac * logic_eff
    sram_mb = logic_area * hw.SRAM_AREA_FRAC * logic_eff * hw.SRAM_MB_PER_MM2

    pes = compute_area * 1e6 / cfg.pe_area_um2
    reuse = jnp.sqrt(jnp.maximum(pes, 1.0))
    dw_bytes = cfg.data_width_bits / 8.0
    reuse_mem = jnp.sqrt(jnp.maximum(sram_mb * 1e6 / (3.0 * dw_bytes), 1.0))
    reuse_comm = reuse_mem if cfg.comm_reuse_systolic else jnp.ones_like(reuse_mem)

    # ---- worst-case HBM->AI hops over the 16x16 grid (2 x 128 lanes) ------
    lane = jax.lax.broadcasted_iota(jnp.float32, (b, LANES), 1)

    def cell_minmax(cell_idx):
        i = jnp.floor(cell_idx / _GRID)
        j = cell_idx % _GRID
        mc = (m[:, None] - 1.0) / 2.0
        nc = (n[:, None] - 1.0) / 2.0
        valid = (i < m[:, None]) & (j < n[:, None])
        d_l = jnp.abs(i - mc) + (j + 1.0)
        d_r = jnp.abs(i - mc) + (n[:, None] - j)
        d_t = (i + 1.0) + jnp.abs(j - nc)
        d_b = (m[:, None] - i) + jnp.abs(j - nc)
        d_m = jnp.maximum(jnp.abs(i - mc) + jnp.abs(j - nc), 1.0)
        d_s3 = jnp.abs(i - mc) + jnp.abs(j - nc)
        d_s = jnp.where(arch[:, None] >= 1.0, d_s3, d_m)
        big = jnp.float32(1e9)
        dmin = jnp.full_like(d_l, big)
        for bit, d in zip(bits, (d_l, d_r, d_t, d_b, d_m, d_s)):
            dmin = jnp.minimum(dmin, jnp.where(bit[:, None] > 0, d, big))
        return jnp.max(jnp.where(valid, dmin, -big), axis=1)

    h_hbm = jnp.maximum(cell_minmax(lane), cell_minmax(lane + LANES))
    h_ai = m + n - 2.0

    # ---- latency (Eqs. 10-11) ---------------------------------------------
    wire_ai = cfg.wire_delay_ps_2p5d * ai_trace / 1000.0
    wire_hbm = cfg.wire_delay_ps_2p5d * hbm_trace / 1000.0
    fixed = cfg.contention_delay_ns + cfg.serialization_delay_ns
    lat_ai = h_ai * (wire_ai + cfg.router_delay_ns) + fixed
    lat_hbm = (h_hbm * (wire_hbm + cfg.router_delay_ns) + fixed
               + uses_3d_mem * (cfg.wire_delay_ps_3d / 1000.0))
    lat_3d = cfg.wire_delay_ps_3d / 1000.0 + cfg.serialization_delay_ns
    worst_lat = jnp.maximum(lat_ai, lat_hbm) + is_lol * lat_3d
    cycles_per_op = 1.0 + worst_lat * cfg.freq_ghz / (
        reuse ** cfg.latency_amort_exp)

    # ---- bandwidth / utilization (Eqs. 12-14) ------------------------------
    ops_per_die = pes * cfg.freq_ghz * 1e9 / cycles_per_op
    operand_gbps = (cfg.n_operands * cfg.data_width_bits
                    * ops_per_die / reuse_comm) / 1e9
    bw_req_hbm = 4.0 * operand_gbps
    bw_req_ai = operand_gbps
    link_bw_hbm = hbm_dr * hbm_links
    bw_act_hbm = (jnp.minimum(link_bw_hbm, hw.HBM_BANDWIDTH_GBPS_PER_STACK)
                  if cfg.hbm_peak_cap else link_bw_hbm)
    u_hbm = jnp.minimum(1.0, bw_act_hbm / jnp.maximum(bw_req_hbm, 1e-6))
    u_ai = jnp.minimum(1.0, ai_dr * ai_links / jnp.maximum(bw_req_ai, 1e-6))
    u_3d = jnp.minimum(1.0, dr3d * links3d / jnp.maximum(bw_req_ai, 1e-6))
    u_sys = jnp.minimum(u_hbm, u_ai)
    u_sys = jnp.where(is_lol > 0, jnp.minimum(u_sys, u_3d), u_sys)

    # ---- throughput ---------------------------------------------------------
    eff_ops = ops_per_die * n_dies * u_sys * mapping_eff
    eff_tops = eff_ops / 1e12

    # ---- energy -------------------------------------------------------------
    def lerp(lo, hi, tr):
        t = (jnp.clip(tr, 1.0, 10.0) - 1.0) / 9.0
        return lo + (hi - lo) * t

    e_hbm_link = lerp(jnp.where(hbm_ic < 0.5, hw.E_BIT_PJ_2P5D_MIN[0],
                                hw.E_BIT_PJ_2P5D_MIN[1]),
                      jnp.where(hbm_ic < 0.5, hw.E_BIT_PJ_2P5D_MAX[0],
                                hw.E_BIT_PJ_2P5D_MAX[1]), hbm_trace)
    e_ai_link = lerp(jnp.where(ai_ic < 0.5, hw.E_BIT_PJ_2P5D_MIN[0],
                               hw.E_BIT_PJ_2P5D_MIN[1]),
                     jnp.where(ai_ic < 0.5, hw.E_BIT_PJ_2P5D_MAX[0],
                               hw.E_BIT_PJ_2P5D_MAX[1]), ai_trace)
    e_3d = jnp.where(ic3d < 0.5, hw.E_BIT_PJ_3D[0], hw.E_BIT_PJ_3D[1])
    bits_hbm = cfg.n_operands * cfg.data_width_bits / reuse_comm
    bits_ai = 0.5 * bits_hbm
    e_comm = (bits_hbm * (e_hbm_link + cfg.e_bit_hbm_device_pj)
              + bits_ai * e_ai_link + is_lol * bits_ai * e_3d
              + uses_3d_mem * bits_hbm * (e_3d - e_hbm_link))

    # ---- cost ---------------------------------------------------------------
    d_mm2 = cfg.defect_density_per_cm2 / 100.0
    y_die = (1.0 + d_mm2 * die_area / cfg.yield_alpha) ** (-cfg.yield_alpha)
    die_cost = (n_dies * cfg.wafer_price_per_mm2 * die_area / y_die
                * (1.0 + hw.KGD_TEST_COST_FRAC))

    mesh_edges = m * (n - 1.0) + n * (m - 1.0)
    l_ai = ai_links * mesh_edges
    l_hbm = hbm_links * n_hbm_2p5d
    n_pairs = jnp.where(is_lol > 0, jnp.floor(n_dies / 2.0), 0.0)
    l_3d = links3d * n_pairs + links3d * uses_3d_mem

    mu0 = jnp.maximum(
        jnp.where(ai_ic < 0.5, hw.PKG_MU0_PER_MM2[0], hw.PKG_MU0_PER_MM2[1]),
        jnp.where(hbm_ic < 0.5, hw.PKG_MU0_PER_MM2[0], hw.PKG_MU0_PER_MM2[1]))
    mu2 = jnp.maximum(
        jnp.where(ai_ic < 0.5, hw.PKG_MU2_FIXED[0], hw.PKG_MU2_FIXED[1]),
        jnp.where(hbm_ic < 0.5, hw.PKG_MU2_FIXED[0], hw.PKG_MU2_FIXED[1]))
    mu1_ai = jnp.where(ai_ic < 0.5, hw.PKG_MU1_PER_LINK[0],
                       hw.PKG_MU1_PER_LINK[1])
    mu1_hbm = jnp.where(hbm_ic < 0.5, hw.PKG_MU1_PER_LINK[0],
                        hw.PKG_MU1_PER_LINK[1])
    mu1_3d = jnp.where(ic3d < 0.5, hw.PKG_MU1_PER_LINK_3D[0],
                       hw.PKG_MU1_PER_LINK_3D[1])
    fix_3d = jnp.where(ic3d < 0.5, hw.PKG_3D_FIXED_PER_STACK[0],
                       hw.PKG_3D_FIXED_PER_STACK[1])
    n_stacks = n_pairs + uses_3d_mem
    pkg_raw = (mu0 * cfg.package_area_mm2 + mu1_ai * l_ai + mu1_hbm * l_hbm
               + mu1_3d * l_3d + fix_3d * n_stacks + mu2)
    y_asm = cfg.bond_yield ** n_stacks
    pkg_cost = pkg_raw / jnp.maximum(y_asm, 1e-3)

    # ---- reward (Eq. 17) ----------------------------------------------------
    r_t = eff_tops * cfg.reward_throughput_scale
    r_c = pkg_cost * cfg.reward_cost_scale / 10.0
    r_e = e_comm * cfg.reward_energy_scale
    reward = w_alpha * r_t - w_beta * r_c - w_gamma * r_e

    out = jnp.stack([reward, eff_tops, e_comm, pkg_cost, die_cost,
                     u_sys, lat_hbm, lat_ai], axis=-1)       # (B, 8)
    pad = jnp.zeros((b, LANES - N_OUT), jnp.float32)
    out_ref[...] = jnp.concatenate([out, pad], axis=-1)


@functools.partial(jax.jit, static_argnames=("workload_vals", "weight_vals",
                                             "cfg", "interpret", "block_n"))
def evaluate_batch(designs_padded: jnp.ndarray,
                   workload_vals: Tuple[float, float, float, float],
                   weight_vals: Tuple[float, float, float],
                   cfg: hw.HWConfig = hw.DEFAULT_HW,
                   interpret: bool = True,
                   block_n: int = BLOCK_N) -> jnp.ndarray:
    """Run the kernel on (N, 128) padded designs; returns (N, 8) metrics."""
    n = designs_padded.shape[0]
    assert n % block_n == 0, f"batch {n} must be a multiple of {block_n}"
    mesh_tab = jnp.asarray(_mesh_tables())
    kernel = functools.partial(_kernel, workload_vals=workload_vals,
                               weight_vals=weight_vals, cfg=cfg)
    out = pl.pallas_call(
        kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, LANES), lambda i: (i, 0)),
            pl.BlockSpec((256, LANES), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, LANES), jnp.float32),
        interpret=interpret,
    )(designs_padded.astype(jnp.float32), mesh_tab)
    return out[:, :N_OUT]


def pad_designs(dp: ps.DesignPoint, block_n: int = BLOCK_N) -> jnp.ndarray:
    """(B,)-batched DesignPoint -> (N_padded, 128) f32 kernel input."""
    flat = ps.to_flat(dp).astype(jnp.float32)          # (B, 14)
    n = flat.shape[0]
    n_pad = (-n) % block_n
    flat = jnp.pad(flat, ((0, n_pad), (0, LANES - ps.N_PARAMS)))
    return flat
