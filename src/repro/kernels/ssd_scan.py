"""Pallas TPU kernel: Mamba-2 SSD (state-space duality) chunked scan.

The SSD recurrence per head (A scalar-per-head, state h in R^{N x P}):

    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t^T
    y_t = C_t^T h_t

TPU adaptation: the sequential scan becomes a *chunked* algorithm — within
a chunk everything is dense matmuls (MXU work: (CH x N) @ (N x CH),
(CH x CH) @ (CH x P)), and only an (N x P) state crosses chunk boundaries,
carried in VMEM scratch across the innermost (sequential) grid axis:

    grid = (batch * heads, num_chunks)
    per-chunk:  y_intra = ((C B^T) .* decay .* dt_j) @ x      (causal within)
                y_cross = exp(cum) .* (C @ h_prev)
                h_new   = exp(cum_L) h_prev + (B .* w)^T @ x

All decay factors are exp of non-positive numbers (A < 0, dt > 0) — no
overflow; statistics in fp32. ``ref.ssd_reference`` is the exact
sequential oracle; ``ref.ssd_chunked_jnp`` is the fast pure-jnp chunked
equivalent used by the model layer on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _ssd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, state_ref, *,
                chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)        # (CH, P)
    dt = dt_ref[0].astype(jnp.float32)      # (CH,)
    bmat = b_ref[0].astype(jnp.float32)     # (CH, N)
    cmat = c_ref[0].astype(jnp.float32)     # (CH, N)
    a = a_ref[0, 0].astype(jnp.float32)     # scalar (negative)

    da = dt * a                             # (CH,) non-positive
    cum = jnp.cumsum(da)                    # (CH,)

    # causal decay matrix: decay[i, j] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, None] - cum[None, :]      # (CH, CH)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = ii >= jj
    decay = jnp.where(causal, jnp.exp(jnp.where(causal, diff, 0.0)), 0.0)

    scores = jnp.dot(cmat, bmat.T, preferred_element_type=jnp.float32)
    scores = scores * decay * dt[None, :]   # weight by dt_j
    y_intra = jnp.dot(scores, x, preferred_element_type=jnp.float32)

    h_prev = state_ref[...]                 # (N, P)
    y_cross = jnp.exp(cum)[:, None] * jnp.dot(
        cmat, h_prev, preferred_element_type=jnp.float32)

    w = jnp.exp(cum[-1] - cum) * dt         # (CH,)
    h_new = (jnp.exp(cum[-1]) * h_prev
             + jnp.dot((bmat * w[:, None]).T, x,
                       preferred_element_type=jnp.float32))

    state_ref[...] = h_new
    y_ref[0] = (y_intra + y_cross).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
             b: jnp.ndarray, c: jnp.ndarray,
             chunk: int = DEFAULT_CHUNK,
             interpret: bool = True) -> jnp.ndarray:
    """Chunked SSD scan.

    x:  (BH, L, P)  per-(batch*head) inputs
    dt: (BH, L)     positive step sizes (post-softplus)
    a:  (BH,)       negative per-head decay
    b:  (BH, L, N)  input projection (already broadcast over head groups)
    c:  (BH, L, N)  output projection
    returns y: (BH, L, P)
    """
    bh, seq, p = x.shape
    n = b.shape[-1]
    ch = min(chunk, seq)
    assert seq % ch == 0, (seq, ch)
    n_chunks = seq // ch

    kernel = functools.partial(_ssd_kernel, chunk=ch)
    return pl.pallas_call(
        kernel,
        grid=(bh, n_chunks),
        in_specs=[
            pl.BlockSpec((1, ch, p), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, ch), lambda i, ci: (i, ci)),
            pl.BlockSpec((1, ch, n), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, ch, n), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, 1), lambda i, ci: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, ch, p), lambda i, ci: (i, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, seq, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, b, c, a.reshape(bh, 1))
