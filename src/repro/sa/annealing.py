"""Modified simulated annealing (paper Algorithm 2), vectorized in JAX.

The paper's modification: instead of the Metropolis criterion
``exp(-(O_curr - O_cand)/t)`` (numerically unstable for their reward
ranges), a candidate that *worsens* the objective is still accepted when
``rand() < t`` with ``t = temp / iteration`` — pure temperature-scheduled
random acceptance. Defaults follow §5.2.2: initial temperature 200,
step size 10, 500k iterations (<1 min).

Beyond the paper: chains are vmapped, so a whole SA *population* runs as
one XLA program (the Alg.-1 portfolio runs 20+ chains in one call), and
the same program shards over a pod (optimizer/portfolio.py).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import costmodel as cm
from repro.core import env as chipenv
from repro.core import params as ps

_HEADS = jnp.asarray(ps.HEAD_SIZES, jnp.float32)


@dataclasses.dataclass(frozen=True)
class SAConfig:
    n_iters: int = 500_000
    temperature: float = 200.0
    step_size: float = 10.0


class SAState(NamedTuple):
    x_curr: jnp.ndarray       # (14,) float — continuous index space
    o_curr: jnp.ndarray
    x_best: jnp.ndarray
    o_best: jnp.ndarray
    key: jnp.ndarray


class SAResult(NamedTuple):
    best_design: ps.DesignPoint
    best_reward: jnp.ndarray
    history: jnp.ndarray      # (n_records,) best-so-far trace


def _objective(x: jnp.ndarray, env_cfg: chipenv.EnvConfig,
               scenario: cm.Scenario = None) -> jnp.ndarray:
    """Evaluate a continuous index-space point (rounded to the grid)."""
    scenario = env_cfg.scenario() if scenario is None else scenario
    idx = jnp.clip(jnp.round(x), 0.0, _HEADS - 1.0).astype(jnp.int32)
    dp = ps.from_flat(idx)
    return cm.reward_only(dp, scenario.workload, scenario.weights, env_cfg.hw)


def run(key, env_cfg: chipenv.EnvConfig = chipenv.EnvConfig(),
        cfg: SAConfig = SAConfig(), record_every: int = 1000,
        scenario: cm.Scenario = None) -> SAResult:
    """One SA chain (Algorithm 2). jit/vmap-safe.

    ``scenario`` is a traced (workload, weights) pytree; vmap over it to
    anneal many scenarios inside one XLA program.
    """
    scenario = env_cfg.scenario() if scenario is None else scenario
    k_init, k_run = jax.random.split(key)
    x0 = jax.random.uniform(k_init, (ps.N_PARAMS,)) * (_HEADS - 1.0)
    o0 = _objective(x0, env_cfg, scenario)
    state = SAState(x_curr=x0, o_curr=o0, x_best=x0, o_best=o0, key=k_run)

    def step(state: SAState, it):
        key, k_prop, k_acc = jax.random.split(state.key, 3)
        delta = jax.random.uniform(
            k_prop, (ps.N_PARAMS,), minval=-1.0, maxval=1.0) * cfg.step_size
        x_cand = jnp.clip(state.x_curr + delta, 0.0, _HEADS - 1.0)
        o_cand = _objective(x_cand, env_cfg, scenario)

        better_best = o_cand > state.o_best
        x_best = jnp.where(better_best, x_cand, state.x_best)
        o_best = jnp.where(better_best, o_cand, state.o_best)

        # paper's acceptance: better, OR rand() < t = temp/iteration
        t = cfg.temperature / (it + 1.0)
        accept = (o_cand > state.o_curr) | (jax.random.uniform(k_acc) < t)
        x_curr = jnp.where(accept, x_cand, state.x_curr)
        o_curr = jnp.where(accept, o_cand, state.o_curr)

        return SAState(x_curr, o_curr, x_best, o_best, key), o_best

    iters = jnp.arange(cfg.n_iters, dtype=jnp.float32)
    state, trace = jax.lax.scan(step, state, iters)
    history = trace[::record_every]
    idx = jnp.clip(jnp.round(state.x_best), 0.0, _HEADS - 1.0).astype(jnp.int32)
    return SAResult(best_design=ps.from_flat(idx),
                    best_reward=state.o_best, history=history)


def run_population(key, n_chains: int,
                   env_cfg: chipenv.EnvConfig = chipenv.EnvConfig(),
                   cfg: SAConfig = SAConfig(),
                   record_every: int = 1000,
                   scenario: cm.Scenario = None) -> SAResult:
    """N independent chains in one vmapped program; results stacked."""
    scenario = env_cfg.scenario() if scenario is None else scenario
    keys = jax.random.split(key, n_chains)
    return jax.jit(jax.vmap(
        lambda k: run(k, env_cfg, cfg, record_every, scenario)))(keys)


def run_scenario_population(key, scenarios: cm.Scenario, n_chains: int,
                            env_cfg: chipenv.EnvConfig = chipenv.EnvConfig(),
                            cfg: SAConfig = SAConfig(),
                            record_every: int = 1000) -> SAResult:
    """S scenarios x N chains as ONE vmapped XLA program.

    ``scenarios`` carries a leading scenario axis S on every leaf; results
    are stacked (S, n_chains). Each scenario gets an independent key split.
    """
    n_scen = jnp.shape(scenarios.weights.alpha)[0]
    keys = jax.random.split(key, int(n_scen))
    return jax.jit(jax.vmap(
        lambda k, s: run_population(k, n_chains, env_cfg, cfg,
                                    record_every, s)))(keys, scenarios)
