"""Modified simulated annealing (paper Algorithm 2), vectorized in JAX.

The paper's modification: instead of the Metropolis criterion
``exp(-(O_curr - O_cand)/t)`` (numerically unstable for their reward
ranges), a candidate that *worsens* the objective is still accepted when
``rand() < t`` with ``t = temp / iteration`` — pure temperature-scheduled
random acceptance. Defaults follow §5.2.2: initial temperature 200,
step size 10, 500k iterations (<1 min).

Beyond the paper: chains are vmapped, so a whole SA *population* runs as
one XLA program (the Alg.-1 portfolio runs 20+ chains in one call), and
the same program shards over a pod (optimizer/portfolio.py).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import costmodel as cm
from repro.core import env as chipenv
from repro.core import mapping as mpg
from repro.core import params as ps
from repro.core import placement as pm
from repro.telemetry import counters as tl

_HEADS = jnp.asarray(ps.HEAD_SIZES, jnp.float32)


@dataclasses.dataclass(frozen=True)
class SAConfig:
    n_iters: int = 500_000
    temperature: float = 200.0
    step_size: float = 10.0
    # with a surrogate passed to run(): per iteration, draw this many
    # candidate moves, let the surrogate pick the most promising one,
    # and spend the single analytic evaluation on it (acceptance and the
    # best-so-far bookkeeping stay purely analytic). 0 keeps the paper's
    # single-proposal Algorithm 2 and its key stream bit-exact.
    surrogate_proposals: int = 0


class SAState(NamedTuple):
    x_curr: jnp.ndarray       # (14,) float — continuous index space
    o_curr: jnp.ndarray
    x_best: jnp.ndarray
    o_best: jnp.ndarray
    key: jnp.ndarray


class SAResult(NamedTuple):
    best_design: ps.DesignPoint
    best_reward: jnp.ndarray
    history: jnp.ndarray      # (n_records,) best-so-far trace


def _objective(x: jnp.ndarray, env_cfg: chipenv.EnvConfig,
               scenario: cm.Scenario = None) -> jnp.ndarray:
    """Evaluate a continuous index-space point (rounded to the grid)."""
    scenario = env_cfg.scenario() if scenario is None else scenario
    idx = jnp.clip(jnp.round(x), 0.0, _HEADS - 1.0).astype(jnp.int32)
    dp = ps.from_flat(idx)
    return cm.scenario_reward(dp, scenario, env_cfg.hw,
                              nop_fidelity=env_cfg.nop_fidelity)


def run(key, env_cfg: chipenv.EnvConfig = chipenv.EnvConfig(),
        cfg: SAConfig = SAConfig(), record_every: int = 1000,
        scenario: cm.Scenario = None, surrogate=None) -> SAResult:
    """One SA chain (Algorithm 2). jit/vmap-safe.

    ``scenario`` is a traced (workload, weights) pytree; vmap over it to
    anneal many scenarios inside one XLA program.

    ``surrogate`` is an optional scenario-folded
    ``surrogate.model.FoldedParams``: with
    ``cfg.surrogate_proposals = Q > 0`` each step proposes Q moves,
    surrogate-ranks them, and analytically evaluates only the winner —
    the accept test and the returned rewards stay analytic.
    """
    scenario = env_cfg.scenario() if scenario is None else scenario
    use_sur = surrogate is not None and cfg.surrogate_proposals > 0
    if use_sur:
        from repro.surrogate import model as sm
    k_init, k_run = jax.random.split(key)
    x0 = jax.random.uniform(k_init, (ps.N_PARAMS,)) * (_HEADS - 1.0)
    o0 = _objective(x0, env_cfg, scenario)
    state = SAState(x_curr=x0, o_curr=o0, x_best=x0, o_best=o0, key=k_run)

    def step(state: SAState, it):
        key, k_prop, k_acc = jax.random.split(state.key, 3)
        if use_sur:
            delta = jax.random.uniform(
                k_prop, (cfg.surrogate_proposals, ps.N_PARAMS),
                minval=-1.0, maxval=1.0) * cfg.step_size
            cands = jnp.clip(state.x_curr + delta, 0.0, _HEADS - 1.0)
            scores = sm.score_folded(
                surrogate, jnp.round(cands).astype(jnp.int32))
            x_cand = cands[jnp.argmax(scores)]
        else:
            delta = jax.random.uniform(
                k_prop, (ps.N_PARAMS,), minval=-1.0,
                maxval=1.0) * cfg.step_size
            x_cand = jnp.clip(state.x_curr + delta, 0.0, _HEADS - 1.0)
        o_cand = _objective(x_cand, env_cfg, scenario)

        better_best = o_cand > state.o_best
        x_best = jnp.where(better_best, x_cand, state.x_best)
        o_best = jnp.where(better_best, o_cand, state.o_best)

        # paper's acceptance: better, OR rand() < t = temp/iteration
        t = cfg.temperature / (it + 1.0)
        accept = (o_cand > state.o_curr) | (jax.random.uniform(k_acc) < t)
        x_curr = jnp.where(accept, x_cand, state.x_curr)
        o_curr = jnp.where(accept, o_cand, state.o_curr)

        return SAState(x_curr, o_curr, x_best, o_best, key), o_best

    iters = jnp.arange(cfg.n_iters, dtype=jnp.float32)
    state, trace = jax.lax.scan(step, state, iters)
    history = trace[::record_every]
    idx = jnp.clip(jnp.round(state.x_best), 0.0, _HEADS - 1.0).astype(jnp.int32)
    return SAResult(best_design=ps.from_flat(idx),
                    best_reward=state.o_best, history=history)


def run_population(key, n_chains: int,
                   env_cfg: chipenv.EnvConfig = chipenv.EnvConfig(),
                   cfg: SAConfig = SAConfig(),
                   record_every: int = 1000,
                   scenario: cm.Scenario = None,
                   surrogate=None) -> SAResult:
    """N independent chains in one vmapped program; results stacked."""
    scenario = env_cfg.scenario() if scenario is None else scenario
    keys = jax.random.split(key, n_chains)
    return jax.jit(jax.vmap(
        lambda k: run(k, env_cfg, cfg, record_every, scenario,
                      surrogate)))(keys)


def run_scenario_population(key, scenarios: cm.Scenario, n_chains: int,
                            env_cfg: chipenv.EnvConfig = chipenv.EnvConfig(),
                            cfg: SAConfig = SAConfig(),
                            record_every: int = 1000) -> SAResult:
    """S scenarios x N chains as ONE vmapped XLA program.

    ``scenarios`` carries a leading scenario axis S on every leaf; results
    are stacked (S, n_chains). Each scenario gets an independent key split.
    """
    n_scen = jnp.shape(scenarios.weights.alpha)[0]
    keys = jax.random.split(key, int(n_scen))
    return jax.jit(jax.vmap(
        lambda k, s: run_population(k, n_chains, env_cfg, cfg,
                                    record_every, s)))(keys, scenarios)


# ---------------------------------------------------------------------------
# Placement refinement (swap / relocate / HBM re-anchor annealing)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlacementSAConfig:
    """SA over the placement of a *fixed* design (core/placement.py).

    ``profile_guided`` biases proposal moves toward the placement's
    traffic centroid (midpoint of the active-chiplet centroid and the
    nearest placed HBM stack, ``placement.traffic_attractor``) instead of
    uniform random cells: a fraction ``p_guided`` of the moves samples a
    Gaussian (std ``guide_sigma`` hops) around the attractor, the rest
    stay uniform to keep the chain ergodic.

    ``delta_eval`` (default) scores each candidate move incrementally: a
    ``placement.PlacementEvalCache`` rides the ``lax.scan`` carry,
    ``nop_stats_delta`` updates only the state the move touches, and
    ``costmodel.reward_from_nop`` skips the placement-independent model
    prefix. Accept/reject selects the cached vs candidate cache; the
    trajectory is bit-identical to the full-recompute path (asserted in
    tests/test_placement_delta.py), which stays available as
    ``delta_eval=False`` for benchmarking. The default iteration budget
    is 4x the pre-delta 3000 (ROADMAP follow-up) — a deliberate
    coverage-over-wall-time trade: the delta step is ~2x lighter in
    compiled kernels but only 1.0-2.5x faster in wall clock on the
    launch-bound CI container (BENCH_costmodel.json placement_sa_step),
    so default refinement spends more wall time than PR 3 in exchange
    for the measured gain bump (+3.58 -> +3.69 mean on the recorded
    sweep). ``record_every`` scales with the budget so the history
    length stays 61.
    """

    n_iters: int = 12_000
    temperature: float = 20.0
    p_hbm: float = 0.5            # fraction of moves that re-anchor a stack
    # fraction of moves that mutate the *mapping* (core/mapping.py)
    # instead of the placement: a mapping move reassigns one slot's
    # pipeline stage (or one layer group's tile index) and neutralizes
    # the placement move (the slot relocates onto its own cell — an
    # identity swap), so one fused nop_stats_delta prices both kinds.
    # 0.0 (default) statically dispatches to the pre-mapping program —
    # bit-for-bit the PR-4 trajectories, no mapping state in the carry.
    # Mapping randomness is folded off the existing 8-way key split, so
    # the placement move stream is untouched either way.
    p_mapping: float = 0.0
    # alternating pinned-kind phases instead of the Bernoulli(p_hbm) move
    # mix: a tuple of ("chiplet" | "hbm", segment_length) pairs forming
    # one cycle, e.g. (("chiplet", 40), ("hbm", 10)). Each segment runs
    # with the move kind pinned, so its step program is statically pruned
    # via nop_stats_delta(move_kinds=...) — chiplet segments never trace
    # the fused 6-anchor re-scan. n_iters must be a multiple of the cycle
    # length. None (default) keeps the mixed Bernoulli stream and its
    # key-split layout bit-for-bit (the recorded-trajectory oracle).
    phase_schedule: tuple = None
    # lax.scan unroll factor for the SA step loop. Value-preserving (the
    # per-step computation is unchanged — trajectories stay bit-exact)
    # but folds k steps into one while-loop round, amortizing per-step
    # kernel-launch overhead on launch-bound hosts.
    scan_unroll: int = 1
    profile_guided: bool = True   # bias moves toward the traffic centroid
    p_guided: float = 0.5         # fraction of guided (vs uniform) moves
    guide_sigma: float = 1.25     # Gaussian jitter of guided moves (hops)
    record_every: int = 200       # best-so-far history stride
    delta_eval: bool = True       # incremental move scoring (cache carry)
    # vmap this many independent chains per design and keep the best —
    # on the launch-bound CI container extra chains amortize the per-step
    # kernel launches the delta step is bottlenecked on (ROADMAP PR-4
    # follow-up; chains-vs-single wall clock in bench_costmodel.py).
    # n_chains=1 preserves the PR-4 key-split layout bit-for-bit (the
    # recorded-trajectory oracle runs against it).
    n_chains: int = 1
    # in-scan telemetry (telemetry/counters.SACounters): per-move-kind
    # and per-phase-segment propose/accept counts, best-so-far improve
    # count, and a cumulative accepted-move curve at the history stride,
    # returned as PlacementResult.telemetry. False (default) statically
    # compiles the exact pre-telemetry program — bit-for-bit the
    # recorded trajectories. True adds counter arithmetic on values the
    # step already computes (no randomness, trajectory unchanged; the
    # identity + overhead are gated by bench_costmodel --assert-telemetry).
    telemetry: bool = False
    # acceptance-band schedule adaptation (requires phase_schedule):
    # split the budget into adapt_rounds host-driven rounds; after each,
    # segments whose measured acceptance rate is above the band grow by
    # adapt_factor (hot segments keep exploring), below-band segments
    # shrink (cold segments stop wasting proposals). Lengths stay within
    # [1, base * adapt_max_scale]; each round's budget is rounded down
    # to whole cycles. False (default) = bit-exact single-shot program.
    # Host-driven: not jit/vmap-safe (rates must be concrete).
    adapt_schedule: bool = False
    adapt_band: tuple = (0.15, 0.45)
    adapt_factor: float = 2.0
    adapt_rounds: int = 4
    adapt_max_scale: int = 8


def _validated_phase_schedule(cfg: PlacementSAConfig):
    """Normalize cfg.phase_schedule to ((kind, len), ...) or None.

    Raises ValueError on unknown kinds, non-positive segment lengths, or
    an n_iters that is not a whole number of cycles (the scan structure
    needs a static cycle count).
    """
    if cfg.phase_schedule is None:
        return None
    if cfg.p_mapping > 0.0:
        raise ValueError("phase_schedule and p_mapping > 0 are mutually "
                         "exclusive (mapping moves need the mixed-kind "
                         "Bernoulli stream)")
    segs = tuple((str(k), int(ln)) for k, ln in cfg.phase_schedule)
    if not segs:
        raise ValueError("phase_schedule must be None or a non-empty tuple "
                         "of (kind, length) pairs")
    for kname, ln in segs:
        if kname not in ("chiplet", "hbm"):
            raise ValueError(f"phase_schedule kind must be 'chiplet' or "
                             f"'hbm', got {kname!r}")
        if ln <= 0:
            raise ValueError(f"phase_schedule segment lengths must be "
                             f"positive, got {ln}")
    cycle = sum(ln for _, ln in segs)
    if cfg.n_iters % cycle != 0:
        raise ValueError(f"n_iters ({cfg.n_iters}) must be a multiple of "
                         f"the phase_schedule cycle length ({cycle})")
    return segs


class PlacementResult(NamedTuple):
    best_placement: pm.Placement
    best_reward: jnp.ndarray
    canonical_reward: jnp.ndarray    # reward under the Fig.-4 floorplan
    history: jnp.ndarray = None      # best-so-far, every record_every iters
    # co-annealed dataflow (cfg.p_mapping > 0 only; None otherwise —
    # the best placement/reward were then scored under the canonical
    # mapping, i.e. the pre-mapping objective)
    best_mapping: mpg.Mapping = None
    # in-scan counters (cfg.telemetry only; telemetry/counters.SACounters)
    telemetry: tl.SACounters = None


def refine_placement(key, design: ps.DesignPoint,
                     env_cfg: chipenv.EnvConfig = chipenv.EnvConfig(),
                     cfg: PlacementSAConfig = PlacementSAConfig(),
                     scenario: cm.Scenario = None,
                     init_placement: pm.Placement = None) -> PlacementResult:
    """Anneal the placement of one design under one scenario.

    Moves: relocate one active chiplet slot (swapping with any occupant)
    to either a profile-guided cell near the traffic attractor or a
    uniform random cell of the m x n footprint box (see
    ``PlacementSAConfig.profile_guided``), or re-anchor one *placed* HBM
    stack (guided: near the chiplet centroid; uniform: anywhere in
    [-1, m] x [-1, n]). The incumbent starts at ``init_placement`` when
    given (e.g. the placement that produced an RL winner's reward), else
    at the canonical floorplan; the best-so-far covers both, so the
    result is never worse than either. jit/vmap-safe: vmap over a
    scenario axis (and a paired design axis) to refine a whole suite in
    one program.

    With ``cfg.delta_eval`` the scan carry holds a
    ``placement.PlacementEvalCache`` instead of a bare placement: each
    proposal becomes a ``PlacementMove``, ``nop_stats_delta`` rebuilds
    only the touched per-slot/per-link state, and the reward comes from
    ``costmodel.reward_from_nop`` under a precomputed
    ``costmodel.placement_ctx`` — same accept/reject trajectory as the
    full-recompute path (bit-for-bit, tests/test_placement_delta.py) at
    a multiple of its step throughput.

    ``cfg.n_chains > 1`` anneals several independent chains (same
    incumbent, split RNG streams) vmapped inside the same program and
    returns the best chain's result — extra chains ride the same kernel
    launches, so on the launch-bound container they are much cheaper
    than sequential restarts (bench_costmodel.py records the ratio).

    ``cfg.phase_schedule`` replaces the Bernoulli move mix with
    alternating pinned-kind segments whose step programs are statically
    pruned (chiplet segments skip the fused 6-anchor re-scan entirely),
    and ``cfg.scan_unroll`` folds several steps per while-loop round —
    together the scan-free hot path benched as ``placement_sa_phased``
    in BENCH_costmodel.json. Both default off; the defaults reproduce
    the PR-4 recorded trajectories bit-for-bit.
    """
    scenario = env_cfg.scenario() if scenario is None else scenario
    if cfg.adapt_schedule:
        # host-driven acceptance-band schedule adaptation (PR-7
        # follow-up): rounds of the ordinary compiled chain with
        # telemetry forced on, re-shaping the schedule between rounds.
        return _refine_placement_adaptive(key, design, env_cfg, cfg,
                                          scenario, init_placement)
    v = ps.decode(design)
    n_pos = cm.footprint_positions(v)
    m, n = cm.mesh_dims(n_pos)
    base = pm.canonical(m, n, v.hbm_mask, v.arch_type)
    ctx = cm.placement_ctx(design, scenario.workload, scenario.weights,
                           env_cfg.hw, trace=scenario.trace)
    mesh_edges = ctx.prefix.mesh_edges

    use_mapping = cfg.p_mapping > 0.0
    # static telemetry dispatch (the mapping=None convention): when off,
    # no counter state enters any carry and the compiled program is the
    # exact pre-telemetry one; when on, counters ride the end of the
    # carry tuple and the scan additionally emits a cumulative
    # accepted-move trace. Counters only read values the step already
    # computed, so the trajectory itself is identical either way.
    use_tel = cfg.telemetry

    def objective(plc: pm.Placement, mapping=None) -> jnp.ndarray:
        return cm.scenario_reward(design, scenario, env_cfg.hw, plc,
                                  mapping=mapping)

    # canonical baseline through the closed-form fast tier (no Placement)
    r0 = cm.scenario_reward(design, scenario, env_cfg.hw,
                            nop_fidelity=env_cfg.nop_fidelity)
    if init_placement is None:
        start, r_start = base, r0
    else:
        r_init = objective(init_placement)
        better = r_init > r0
        start = jax.tree_util.tree_map(
            lambda a, b: jnp.where(better, a, b), init_placement, base)
        r_start = jnp.maximum(r_init, r0)

    def propose(plc, key, cell_sums=None, pin_kind=None, mapping=None):
        """One swap/relocate/re-anchor proposal as a PlacementMove.

        Shared between the delta and full-recompute steps — the key
        split layout is part of the bit-for-bit trajectory contract.
        ``cell_sums`` lets the delta step serve the profile-guided
        centroid from the cache instead of re-reducing the slot axis.
        ``pin_kind`` (0 chiplet / 1 hbm) statically pins the move kind
        for phase-scheduled segments; the 8-way split layout is kept
        either way so pinned and mixed streams draw the same slot /
        cell / anchor / accept randomness per iteration.

        With ``mapping`` (the mapping-co-annealed chain) the return
        grows a candidate mapping: mapping randomness is *folded off*
        the split keys (the placement stream is untouched), and a
        mapping move neutralizes the placement move by relocating the
        chosen slot onto its own cell — an identity swap — so the same
        fused delta step prices both move kinds.
        """
        key, k_kind, k_slot, k_cell, k_bit, k_anchor, k_acc, k_mix = (
            jax.random.split(key, 8))

        # chiplet relocate / swap proposal
        slot = jax.random.randint(k_slot, (), 0, pm.MAX_SLOTS)
        cell = pm.random_cell_in_box(k_cell, m, n)
        anchor = pm.random_hbm_anchor(k_anchor, m, n)
        if cfg.profile_guided:
            guided = jax.random.uniform(k_mix) < cfg.p_guided
            g_cell = pm.guided_cell(k_cell, plc, n_pos, v.hbm_mask, m, n,
                                    cfg.guide_sigma, cell_sums)
            g_anchor = pm.guided_anchor(k_anchor, plc, n_pos, m, n,
                                        cfg.guide_sigma, cell_sums)
            cell = jnp.where(guided, g_cell, cell)
            anchor = jnp.where(guided, g_anchor, anchor)
        # HBM re-anchor proposal (uniform over the placed stacks)
        bit = pm.select_placed_bit(k_bit, v.hbm_mask)
        if pin_kind is None:
            use_hbm = jax.random.uniform(k_kind) < cfg.p_hbm
            kind = use_hbm.astype(jnp.int32)
        else:
            kind = jnp.int32(pin_kind)
        move = pm.PlacementMove(kind=kind, slot=slot,
                                cell=cell, hbm=bit, anchor=anchor)
        if mapping is None:
            return move, key, k_acc
        is_map = (jax.random.uniform(jax.random.fold_in(k_kind, 1))
                  < cfg.p_mapping)
        m_slot = jax.random.randint(
            jax.random.fold_in(k_slot, 1), (), 0, pm.MAX_SLOTS)
        m_stage = jax.random.randint(
            jax.random.fold_in(k_cell, 1), (), 0, mpg.MAX_STAGES)
        m_tile = jax.random.randint(
            jax.random.fold_in(k_cell, 2), (), 0, mpg.N_TILE)
        use_tile = (jax.random.uniform(jax.random.fold_in(k_kind, 2))
                    < 0.25)
        mut_stage = mpg.assign_stage(mapping, m_slot, m_stage, n_pos)
        mut_tile = mpg.assign_tile(
            mapping, jnp.mod(m_slot, mpg.N_LAYER_GROUPS), m_tile)
        mutated = jax.tree_util.tree_map(
            lambda a, b: jnp.where(use_tile, b, a), mut_stage, mut_tile)
        cand_map = jax.tree_util.tree_map(
            lambda a, b: jnp.where(is_map, b, a), mapping, mutated)
        # neutralize the placement half of a mapping move: relocating a
        # slot onto its own cell swaps it with itself (exact identity)
        slot_eff = jnp.mod(move.slot,
                           jnp.maximum(jnp.asarray(n_pos, jnp.int32), 1))
        own_cell = jnp.take(plc.chiplet_cell, slot_eff)
        move = move._replace(
            kind=jnp.where(is_map, jnp.int32(0), move.kind),
            cell=jnp.where(is_map, own_cell, move.cell))
        return move, key, k_acc, cand_map

    def make_step_full(pin_kind=None, seg=0):
        """PR-3 semantics: one full costmodel.evaluate per candidate
        (kept as the delta benchmark baseline and trajectory oracle).
        ``seg`` is the static phase-segment index telemetry bins into."""
        def step_full(state, it):
            if use_tel:
                state, tel = state[:-1], state[-1]
            if use_mapping:
                plc, r_curr, best, r_best, mapping, best_map, key = state
                move, key, k_acc, cand_map = propose(
                    plc, key, pin_kind=pin_kind, mapping=mapping)
            else:
                plc, r_curr, best, r_best, key = state
                move, key, k_acc = propose(plc, key, pin_kind=pin_kind)
                cand_map = None
            cand = pm.apply_move(plc, move, n_pos)
            r_cand = objective(cand, cand_map)

            better_best = r_cand > r_best
            best = jax.tree_util.tree_map(
                lambda a, b: jnp.where(better_best, a, b), cand, best)
            r_best = jnp.where(better_best, r_cand, r_best)

            t = cfg.temperature / (it + 1.0)
            accept = (r_cand > r_curr) | (jax.random.uniform(k_acc) < t)
            plc = jax.tree_util.tree_map(
                lambda a, b: jnp.where(accept, a, b), cand, plc)
            r_curr = jnp.where(accept, r_cand, r_curr)
            if use_mapping:
                best_map = jax.tree_util.tree_map(
                    lambda a, b: jnp.where(better_best, a, b), cand_map,
                    best_map)
                mapping = jax.tree_util.tree_map(
                    lambda a, b: jnp.where(accept, a, b), cand_map, mapping)
                out = (plc, r_curr, best, r_best, mapping, best_map, key)
            else:
                out = (plc, r_curr, best, r_best, key)
            if use_tel:
                tel = tl.sa_update(tel, move.kind, accept, better_best, seg)
                return out + (tel,), (r_best, jnp.sum(tel.accept))
            return out, r_best
        return step_full

    # p_hbm pins the move kind at 0 or 1 -> statically prune the dead
    # delta branch (a relocation-only chain never traces the anchor scan).
    # Mapping moves ride kind 0 (identity relocate), so a mapping-enabled
    # chain can never prune the chiplet branch away.
    move_kinds = ("chiplet" if cfg.p_hbm <= 0.0
                  else "hbm" if cfg.p_hbm >= 1.0 and not use_mapping
                  else "mixed")

    def make_step_delta(mk, pin_kind=None, seg=0):
        """Cache-carried step: delta NoP stats + suffix-only reward;
        accept/reject folds the candidate back via pm.commit_move.
        ``mk`` statically prunes the untaken delta branch; phased
        segments pass mk='chiplet'/'hbm' with the matching pin. ``seg``
        is the static phase-segment index telemetry bins into."""
        def step_delta(state, it):
            if use_tel:
                state, tel = state[:-1], state[-1]
            if use_mapping:
                cache, r_curr, best, r_best, mapping, best_map, key = state
                move, key, k_acc, cand_map = propose(
                    cache.placement, key, (cache.sum_ci, cache.sum_cj),
                    pin_kind=pin_kind, mapping=mapping)
            else:
                cache, r_curr, best, r_best, key = state
                move, key, k_acc = propose(cache.placement, key,
                                           (cache.sum_ci, cache.sum_cj),
                                           pin_kind=pin_kind)
                cand_map = None
            cand = pm.nop_stats_delta(cache, move, n_pos, v.hbm_mask,
                                      v.arch_type, mesh_edges,
                                      move_kinds=mk, mapping=cand_map)
            r_cand = cm.reward_from_nop(ctx, cand.stats, env_cfg.hw,
                                        mapping=cand_map)

            better_best = r_cand > r_best
            best = jax.tree_util.tree_map(
                lambda a, b: jnp.where(better_best, a, b), cand.placement,
                best)
            r_best = jnp.where(better_best, r_cand, r_best)

            t = cfg.temperature / (it + 1.0)
            accept = (r_cand > r_curr) | (jax.random.uniform(k_acc) < t)
            cache = pm.commit_move(cache, cand, accept)
            r_curr = jnp.where(accept, r_cand, r_curr)
            if use_mapping:
                best_map = jax.tree_util.tree_map(
                    lambda a, b: jnp.where(better_best, a, b), cand_map,
                    best_map)
                mapping = jax.tree_util.tree_map(
                    lambda a, b: jnp.where(accept, a, b), cand_map, mapping)
                out = (cache, r_curr, best, r_best, mapping, best_map, key)
            else:
                out = (cache, r_curr, best, r_best, key)
            if use_tel:
                tel = tl.sa_update(tel, move.kind, accept, better_best, seg)
                return out + (tel,), (r_best, jnp.sum(tel.accept))
            return out, r_best
        return step_delta

    segs = _validated_phase_schedule(cfg)
    n_segments = 1 if segs is None else len(segs)

    def _chain(chain_key):
        incumbent = start if not cfg.delta_eval else pm.nop_stats_cache(
            start, n_pos, v.hbm_mask, v.arch_type, mesh_edges)
        if use_mapping:
            # the incumbent dataflow is the canonical (paper) mapping —
            # exactly the objective r_start was scored under
            map0 = mpg.canonical()
            state = (incumbent, r_start, start, r_start, map0, map0,
                     chain_key)
        else:
            state = (incumbent, r_start, start, r_start, chain_key)
        if use_tel:
            state = state + (tl.init_sa(n_segments),)
        best_map = None
        if segs is None:
            step = (make_step_delta(move_kinds) if cfg.delta_eval
                    else make_step_full())
            iters = jnp.arange(cfg.n_iters, dtype=jnp.float32)
            final, trace = jax.lax.scan(
                step, state, iters, unroll=cfg.scan_unroll)
        else:
            # phase-scheduled chain: an outer scan over cycles; each
            # cycle runs one statically-pruned inner scan per segment
            # (chiplet segments never trace the 6-anchor re-scan).
            # Temperature follows the *global* iteration index, so the
            # schedule only changes which kind each iteration draws.
            cycle = sum(ln for _, ln in segs)
            # without telemetry, segments of the same kind share one
            # step closure (seg is unused); with telemetry each segment
            # bins its counters separately, so build one step per slot.
            steps = {}
            seg_steps = []
            for si, (kname, _) in enumerate(segs):
                pin = 0 if kname == "chiplet" else 1
                seg = si if use_tel else 0
                if use_tel or kname not in steps:
                    steps_key = si if use_tel else kname
                    steps[steps_key] = (
                        make_step_delta(kname, pin, seg) if cfg.delta_eval
                        else make_step_full(pin, seg))
                seg_steps.append(steps[si if use_tel else kname])

            def cycle_body(st, c):
                traces = []
                off = 0
                for si, (kname, ln) in enumerate(segs):
                    iters = (c * cycle + off
                             + jnp.arange(ln)).astype(jnp.float32)
                    st, tr = jax.lax.scan(
                        seg_steps[si], st, iters,
                        unroll=min(cfg.scan_unroll, ln))
                    traces.append(tr)
                    off += ln
                if use_tel:
                    return st, tuple(
                        jnp.concatenate([t[i] for t in traces])
                        for i in range(2))
                return st, jnp.concatenate(traces)

            n_cycles = cfg.n_iters // cycle
            final, trace2 = jax.lax.scan(
                cycle_body, state, jnp.arange(n_cycles))
            if use_tel:
                trace = tuple(t.reshape(cfg.n_iters) for t in trace2)
            else:
                trace = trace2.reshape(cfg.n_iters)
        # the carry layout is positional: (incumbent, r_curr, best,
        # r_best, ...) with mapping/best_map/key in the middle and the
        # optional telemetry counters always last
        best, r_best = final[2], final[3]
        if use_mapping:
            best_map = final[5]
        tel = final[-1] if use_tel else None
        if use_tel:
            trace, acc_trace = trace
        # strided best-so-far trace + the final value (the stride rarely
        # lands on the last iteration; history[-1] must equal best_reward)
        history = jnp.concatenate([trace[:: cfg.record_every], trace[-1:]])
        if use_tel:
            curve = jnp.concatenate(
                [acc_trace[:: cfg.record_every], acc_trace[-1:]])
            tel = tel._replace(accept_curve=curve)
        return best, r_best, history, best_map, tel

    if cfg.n_chains <= 1:
        best, r_best, history, best_map, telemetry = _chain(key)
    else:
        # several chains per design in one program: same incumbent,
        # independent RNG streams; keep the best chain's result. Chain 0
        # reuses the caller's key verbatim, so n_chains > 1 reproduces
        # the single-chain trajectory among its candidates and the
        # result is never worse than n_chains=1 on the same key.
        chain_keys = jnp.concatenate(
            [key[None], jax.random.split(key, cfg.n_chains - 1)])
        bests, r_bests, histories, best_maps, tels = jax.vmap(
            _chain)(chain_keys)
        win = jnp.argmax(r_bests)
        best = jax.tree_util.tree_map(
            lambda x: jnp.take(x, win, axis=0), bests)
        r_best = jnp.take(r_bests, win)
        history = jnp.take(histories, win, axis=0)
        best_map = jax.tree_util.tree_map(
            lambda x: jnp.take(x, win, axis=0), best_maps)
        telemetry = jax.tree_util.tree_map(
            lambda x: jnp.take(x, win, axis=0), tels)
    return PlacementResult(best_placement=best, best_reward=r_best,
                           canonical_reward=r0, history=history,
                           best_mapping=best_map, telemetry=telemetry)


def _adapted_schedule(segs, rates, cfg: PlacementSAConfig,
                      base_segs=None):
    """One acceptance-band adaptation step over a phase schedule.

    ``segs`` is the current ((kind, len), ...) tuple, ``rates`` the
    measured per-segment acceptance rates (len(segs) floats), ``base_segs``
    the original schedule whose lengths bound the scaling. Segments whose
    acceptance rate is above the band grow by ``adapt_factor`` (the chain
    is still finding acceptable moves there — let it keep exploring);
    below-band segments shrink (cold segments waste proposals). Lengths
    stay in [1, base * adapt_max_scale]. Pure (host-side ints) and
    unit-tested directly.
    """
    base_segs = segs if base_segs is None else base_segs
    lo, hi = cfg.adapt_band
    out = []
    for (kname, ln), (_, base_ln), rate in zip(segs, base_segs, rates):
        if rate > hi:
            ln = int(round(ln * cfg.adapt_factor))
        elif rate < lo:
            ln = int(round(ln / cfg.adapt_factor))
        ln = max(1, min(ln, int(base_ln) * cfg.adapt_max_scale))
        out.append((kname, ln))
    return tuple(out)


def _refine_placement_adaptive(key, design, env_cfg, cfg, scenario,
                               init_placement):
    """Acceptance-band adaptive phase scheduling (PR-7 follow-up).

    Host-driven: splits ``cfg.n_iters`` into ``cfg.adapt_rounds`` rounds
    of the ordinary compiled chain (telemetry forced on), feeding each
    round's per-segment acceptance rates into ``_adapted_schedule`` to
    reshape the next round's schedule. Rounds chain through
    ``init_placement`` (each starts from the previous best, so the best
    reward is monotone across rounds); keys fold per round off the
    caller's key. Not jit/vmap-safe — the rates must be concrete — and
    each distinct schedule shape compiles its own program; this mode is
    for host-level tuning runs, not the vmapped suite path.

    Returns a PlacementResult whose history is the concatenation of the
    rounds' best-so-far traces and whose telemetry merges the rounds'
    counters (accept_curve stays cumulative across rounds). The
    schedules taken are emitted as an ``sa_adapt`` event when an
    ambient journal (telemetry.journal.use) is active.
    """
    import numpy as np

    segs = _validated_phase_schedule(cfg)
    if segs is None:
        raise ValueError("adapt_schedule=True requires a phase_schedule")
    rounds = max(int(cfg.adapt_rounds), 1)
    budget = cfg.n_iters // rounds
    if budget < sum(ln for _, ln in segs):
        raise ValueError(
            f"n_iters ({cfg.n_iters}) too small for {rounds} adaptation "
            f"rounds of at least one cycle each")

    cur = segs
    plc = init_placement
    res = None
    merged = None
    histories = []
    schedules = []
    for r in range(rounds):
        cycle = sum(ln for _, ln in cur)
        n_iters = max(budget // cycle, 1) * cycle
        rcfg = dataclasses.replace(
            cfg, adapt_schedule=False, telemetry=True,
            phase_schedule=cur, n_iters=n_iters,
            record_every=min(cfg.record_every, n_iters))
        res = refine_placement(jax.random.fold_in(key, r), design,
                               env_cfg, rcfg, scenario,
                               init_placement=plc)
        plc = res.best_placement
        histories.append(res.history)
        schedules.append(cur)
        merged = (res.telemetry if merged is None
                  else tl.merge_sa(merged, res.telemetry))
        rates = [
            float(a) / max(float(p), 1.0)
            for a, p in zip(np.asarray(res.telemetry.seg_accept),
                            np.asarray(res.telemetry.seg_propose))]
        cur = _adapted_schedule(cur, rates, cfg, base_segs=segs)
    from repro.telemetry import journal as tj
    tj.current_or_null().event(
        "sa_adapt", schedules=[list(map(list, s)) for s in schedules],
        rounds=rounds)
    return PlacementResult(
        best_placement=res.best_placement, best_reward=res.best_reward,
        canonical_reward=res.canonical_reward,
        history=jnp.concatenate(histories), best_mapping=None,
        telemetry=merged)


def refine_placement_scenarios(key, designs: ps.DesignPoint,
                               scenarios: cm.Scenario,
                               env_cfg: chipenv.EnvConfig = chipenv.EnvConfig(),
                               cfg: PlacementSAConfig = PlacementSAConfig()
                               ) -> PlacementResult:
    """Placement-refine S suite winners as ONE vmapped XLA program.

    ``designs`` carries a leading axis S paired with ``scenarios`` (the
    per-scenario winners); swap/relocate/re-anchor chains run batched over
    the scenario axis — no host loop per winner.
    """
    n_scen = jnp.shape(scenarios.weights.alpha)[0]
    keys = jax.random.split(key, int(n_scen))
    return jax.jit(jax.vmap(
        lambda k, d, s: refine_placement(k, d, env_cfg, cfg, s)))(
            keys, designs, scenarios)
