"""Modified simulated annealing (paper Algorithm 2), vectorized in JAX.

The paper's modification: instead of the Metropolis criterion
``exp(-(O_curr - O_cand)/t)`` (numerically unstable for their reward
ranges), a candidate that *worsens* the objective is still accepted when
``rand() < t`` with ``t = temp / iteration`` — pure temperature-scheduled
random acceptance. Defaults follow §5.2.2: initial temperature 200,
step size 10, 500k iterations (<1 min).

Beyond the paper: chains are vmapped, so a whole SA *population* runs as
one XLA program (the Alg.-1 portfolio runs 20+ chains in one call), and
the same program shards over a pod (optimizer/portfolio.py).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import costmodel as cm
from repro.core import env as chipenv
from repro.core import mapping as mpg
from repro.core import params as ps
from repro.core import placement as pm

_HEADS = jnp.asarray(ps.HEAD_SIZES, jnp.float32)


@dataclasses.dataclass(frozen=True)
class SAConfig:
    n_iters: int = 500_000
    temperature: float = 200.0
    step_size: float = 10.0
    # with a surrogate passed to run(): per iteration, draw this many
    # candidate moves, let the surrogate pick the most promising one,
    # and spend the single analytic evaluation on it (acceptance and the
    # best-so-far bookkeeping stay purely analytic). 0 keeps the paper's
    # single-proposal Algorithm 2 and its key stream bit-exact.
    surrogate_proposals: int = 0


class SAState(NamedTuple):
    x_curr: jnp.ndarray       # (14,) float — continuous index space
    o_curr: jnp.ndarray
    x_best: jnp.ndarray
    o_best: jnp.ndarray
    key: jnp.ndarray


class SAResult(NamedTuple):
    best_design: ps.DesignPoint
    best_reward: jnp.ndarray
    history: jnp.ndarray      # (n_records,) best-so-far trace


def _objective(x: jnp.ndarray, env_cfg: chipenv.EnvConfig,
               scenario: cm.Scenario = None) -> jnp.ndarray:
    """Evaluate a continuous index-space point (rounded to the grid)."""
    scenario = env_cfg.scenario() if scenario is None else scenario
    idx = jnp.clip(jnp.round(x), 0.0, _HEADS - 1.0).astype(jnp.int32)
    dp = ps.from_flat(idx)
    return cm.scenario_reward(dp, scenario, env_cfg.hw,
                              nop_fidelity=env_cfg.nop_fidelity)


def run(key, env_cfg: chipenv.EnvConfig = chipenv.EnvConfig(),
        cfg: SAConfig = SAConfig(), record_every: int = 1000,
        scenario: cm.Scenario = None, surrogate=None) -> SAResult:
    """One SA chain (Algorithm 2). jit/vmap-safe.

    ``scenario`` is a traced (workload, weights) pytree; vmap over it to
    anneal many scenarios inside one XLA program.

    ``surrogate`` is an optional scenario-folded
    ``surrogate.model.FoldedParams``: with
    ``cfg.surrogate_proposals = Q > 0`` each step proposes Q moves,
    surrogate-ranks them, and analytically evaluates only the winner —
    the accept test and the returned rewards stay analytic.
    """
    scenario = env_cfg.scenario() if scenario is None else scenario
    use_sur = surrogate is not None and cfg.surrogate_proposals > 0
    if use_sur:
        from repro.surrogate import model as sm
    k_init, k_run = jax.random.split(key)
    x0 = jax.random.uniform(k_init, (ps.N_PARAMS,)) * (_HEADS - 1.0)
    o0 = _objective(x0, env_cfg, scenario)
    state = SAState(x_curr=x0, o_curr=o0, x_best=x0, o_best=o0, key=k_run)

    def step(state: SAState, it):
        key, k_prop, k_acc = jax.random.split(state.key, 3)
        if use_sur:
            delta = jax.random.uniform(
                k_prop, (cfg.surrogate_proposals, ps.N_PARAMS),
                minval=-1.0, maxval=1.0) * cfg.step_size
            cands = jnp.clip(state.x_curr + delta, 0.0, _HEADS - 1.0)
            scores = sm.score_folded(
                surrogate, jnp.round(cands).astype(jnp.int32))
            x_cand = cands[jnp.argmax(scores)]
        else:
            delta = jax.random.uniform(
                k_prop, (ps.N_PARAMS,), minval=-1.0,
                maxval=1.0) * cfg.step_size
            x_cand = jnp.clip(state.x_curr + delta, 0.0, _HEADS - 1.0)
        o_cand = _objective(x_cand, env_cfg, scenario)

        better_best = o_cand > state.o_best
        x_best = jnp.where(better_best, x_cand, state.x_best)
        o_best = jnp.where(better_best, o_cand, state.o_best)

        # paper's acceptance: better, OR rand() < t = temp/iteration
        t = cfg.temperature / (it + 1.0)
        accept = (o_cand > state.o_curr) | (jax.random.uniform(k_acc) < t)
        x_curr = jnp.where(accept, x_cand, state.x_curr)
        o_curr = jnp.where(accept, o_cand, state.o_curr)

        return SAState(x_curr, o_curr, x_best, o_best, key), o_best

    iters = jnp.arange(cfg.n_iters, dtype=jnp.float32)
    state, trace = jax.lax.scan(step, state, iters)
    history = trace[::record_every]
    idx = jnp.clip(jnp.round(state.x_best), 0.0, _HEADS - 1.0).astype(jnp.int32)
    return SAResult(best_design=ps.from_flat(idx),
                    best_reward=state.o_best, history=history)


def run_population(key, n_chains: int,
                   env_cfg: chipenv.EnvConfig = chipenv.EnvConfig(),
                   cfg: SAConfig = SAConfig(),
                   record_every: int = 1000,
                   scenario: cm.Scenario = None,
                   surrogate=None) -> SAResult:
    """N independent chains in one vmapped program; results stacked."""
    scenario = env_cfg.scenario() if scenario is None else scenario
    keys = jax.random.split(key, n_chains)
    return jax.jit(jax.vmap(
        lambda k: run(k, env_cfg, cfg, record_every, scenario,
                      surrogate)))(keys)


def run_scenario_population(key, scenarios: cm.Scenario, n_chains: int,
                            env_cfg: chipenv.EnvConfig = chipenv.EnvConfig(),
                            cfg: SAConfig = SAConfig(),
                            record_every: int = 1000) -> SAResult:
    """S scenarios x N chains as ONE vmapped XLA program.

    ``scenarios`` carries a leading scenario axis S on every leaf; results
    are stacked (S, n_chains). Each scenario gets an independent key split.
    """
    n_scen = jnp.shape(scenarios.weights.alpha)[0]
    keys = jax.random.split(key, int(n_scen))
    return jax.jit(jax.vmap(
        lambda k, s: run_population(k, n_chains, env_cfg, cfg,
                                    record_every, s)))(keys, scenarios)


# ---------------------------------------------------------------------------
# Placement refinement (swap / relocate / HBM re-anchor annealing)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlacementSAConfig:
    """SA over the placement of a *fixed* design (core/placement.py).

    ``profile_guided`` biases proposal moves toward the placement's
    traffic centroid (midpoint of the active-chiplet centroid and the
    nearest placed HBM stack, ``placement.traffic_attractor``) instead of
    uniform random cells: a fraction ``p_guided`` of the moves samples a
    Gaussian (std ``guide_sigma`` hops) around the attractor, the rest
    stay uniform to keep the chain ergodic.

    ``delta_eval`` (default) scores each candidate move incrementally: a
    ``placement.PlacementEvalCache`` rides the ``lax.scan`` carry,
    ``nop_stats_delta`` updates only the state the move touches, and
    ``costmodel.reward_from_nop`` skips the placement-independent model
    prefix. Accept/reject selects the cached vs candidate cache; the
    trajectory is bit-identical to the full-recompute path (asserted in
    tests/test_placement_delta.py), which stays available as
    ``delta_eval=False`` for benchmarking. The default iteration budget
    is 4x the pre-delta 3000 (ROADMAP follow-up) — a deliberate
    coverage-over-wall-time trade: the delta step is ~2x lighter in
    compiled kernels but only 1.0-2.5x faster in wall clock on the
    launch-bound CI container (BENCH_costmodel.json placement_sa_step),
    so default refinement spends more wall time than PR 3 in exchange
    for the measured gain bump (+3.58 -> +3.69 mean on the recorded
    sweep). ``record_every`` scales with the budget so the history
    length stays 61.
    """

    n_iters: int = 12_000
    temperature: float = 20.0
    p_hbm: float = 0.5            # fraction of moves that re-anchor a stack
    # fraction of moves that mutate the *mapping* (core/mapping.py)
    # instead of the placement: a mapping move reassigns one slot's
    # pipeline stage (or one layer group's tile index) and neutralizes
    # the placement move (the slot relocates onto its own cell — an
    # identity swap), so one fused nop_stats_delta prices both kinds.
    # 0.0 (default) statically dispatches to the pre-mapping program —
    # bit-for-bit the PR-4 trajectories, no mapping state in the carry.
    # Mapping randomness is folded off the existing 8-way key split, so
    # the placement move stream is untouched either way.
    p_mapping: float = 0.0
    # alternating pinned-kind phases instead of the Bernoulli(p_hbm) move
    # mix: a tuple of ("chiplet" | "hbm", segment_length) pairs forming
    # one cycle, e.g. (("chiplet", 40), ("hbm", 10)). Each segment runs
    # with the move kind pinned, so its step program is statically pruned
    # via nop_stats_delta(move_kinds=...) — chiplet segments never trace
    # the fused 6-anchor re-scan. n_iters must be a multiple of the cycle
    # length. None (default) keeps the mixed Bernoulli stream and its
    # key-split layout bit-for-bit (the recorded-trajectory oracle).
    phase_schedule: tuple = None
    # lax.scan unroll factor for the SA step loop. Value-preserving (the
    # per-step computation is unchanged — trajectories stay bit-exact)
    # but folds k steps into one while-loop round, amortizing per-step
    # kernel-launch overhead on launch-bound hosts.
    scan_unroll: int = 1
    profile_guided: bool = True   # bias moves toward the traffic centroid
    p_guided: float = 0.5         # fraction of guided (vs uniform) moves
    guide_sigma: float = 1.25     # Gaussian jitter of guided moves (hops)
    record_every: int = 200       # best-so-far history stride
    delta_eval: bool = True       # incremental move scoring (cache carry)
    # vmap this many independent chains per design and keep the best —
    # on the launch-bound CI container extra chains amortize the per-step
    # kernel launches the delta step is bottlenecked on (ROADMAP PR-4
    # follow-up; chains-vs-single wall clock in bench_costmodel.py).
    # n_chains=1 preserves the PR-4 key-split layout bit-for-bit (the
    # recorded-trajectory oracle runs against it).
    n_chains: int = 1


def _validated_phase_schedule(cfg: PlacementSAConfig):
    """Normalize cfg.phase_schedule to ((kind, len), ...) or None.

    Raises ValueError on unknown kinds, non-positive segment lengths, or
    an n_iters that is not a whole number of cycles (the scan structure
    needs a static cycle count).
    """
    if cfg.phase_schedule is None:
        return None
    if cfg.p_mapping > 0.0:
        raise ValueError("phase_schedule and p_mapping > 0 are mutually "
                         "exclusive (mapping moves need the mixed-kind "
                         "Bernoulli stream)")
    segs = tuple((str(k), int(ln)) for k, ln in cfg.phase_schedule)
    if not segs:
        raise ValueError("phase_schedule must be None or a non-empty tuple "
                         "of (kind, length) pairs")
    for kname, ln in segs:
        if kname not in ("chiplet", "hbm"):
            raise ValueError(f"phase_schedule kind must be 'chiplet' or "
                             f"'hbm', got {kname!r}")
        if ln <= 0:
            raise ValueError(f"phase_schedule segment lengths must be "
                             f"positive, got {ln}")
    cycle = sum(ln for _, ln in segs)
    if cfg.n_iters % cycle != 0:
        raise ValueError(f"n_iters ({cfg.n_iters}) must be a multiple of "
                         f"the phase_schedule cycle length ({cycle})")
    return segs


class PlacementResult(NamedTuple):
    best_placement: pm.Placement
    best_reward: jnp.ndarray
    canonical_reward: jnp.ndarray    # reward under the Fig.-4 floorplan
    history: jnp.ndarray = None      # best-so-far, every record_every iters
    # co-annealed dataflow (cfg.p_mapping > 0 only; None otherwise —
    # the best placement/reward were then scored under the canonical
    # mapping, i.e. the pre-mapping objective)
    best_mapping: mpg.Mapping = None


def refine_placement(key, design: ps.DesignPoint,
                     env_cfg: chipenv.EnvConfig = chipenv.EnvConfig(),
                     cfg: PlacementSAConfig = PlacementSAConfig(),
                     scenario: cm.Scenario = None,
                     init_placement: pm.Placement = None) -> PlacementResult:
    """Anneal the placement of one design under one scenario.

    Moves: relocate one active chiplet slot (swapping with any occupant)
    to either a profile-guided cell near the traffic attractor or a
    uniform random cell of the m x n footprint box (see
    ``PlacementSAConfig.profile_guided``), or re-anchor one *placed* HBM
    stack (guided: near the chiplet centroid; uniform: anywhere in
    [-1, m] x [-1, n]). The incumbent starts at ``init_placement`` when
    given (e.g. the placement that produced an RL winner's reward), else
    at the canonical floorplan; the best-so-far covers both, so the
    result is never worse than either. jit/vmap-safe: vmap over a
    scenario axis (and a paired design axis) to refine a whole suite in
    one program.

    With ``cfg.delta_eval`` the scan carry holds a
    ``placement.PlacementEvalCache`` instead of a bare placement: each
    proposal becomes a ``PlacementMove``, ``nop_stats_delta`` rebuilds
    only the touched per-slot/per-link state, and the reward comes from
    ``costmodel.reward_from_nop`` under a precomputed
    ``costmodel.placement_ctx`` — same accept/reject trajectory as the
    full-recompute path (bit-for-bit, tests/test_placement_delta.py) at
    a multiple of its step throughput.

    ``cfg.n_chains > 1`` anneals several independent chains (same
    incumbent, split RNG streams) vmapped inside the same program and
    returns the best chain's result — extra chains ride the same kernel
    launches, so on the launch-bound container they are much cheaper
    than sequential restarts (bench_costmodel.py records the ratio).

    ``cfg.phase_schedule`` replaces the Bernoulli move mix with
    alternating pinned-kind segments whose step programs are statically
    pruned (chiplet segments skip the fused 6-anchor re-scan entirely),
    and ``cfg.scan_unroll`` folds several steps per while-loop round —
    together the scan-free hot path benched as ``placement_sa_phased``
    in BENCH_costmodel.json. Both default off; the defaults reproduce
    the PR-4 recorded trajectories bit-for-bit.
    """
    scenario = env_cfg.scenario() if scenario is None else scenario
    v = ps.decode(design)
    n_pos = cm.footprint_positions(v)
    m, n = cm.mesh_dims(n_pos)
    base = pm.canonical(m, n, v.hbm_mask, v.arch_type)
    ctx = cm.placement_ctx(design, scenario.workload, scenario.weights,
                           env_cfg.hw, trace=scenario.trace)
    mesh_edges = ctx.prefix.mesh_edges

    use_mapping = cfg.p_mapping > 0.0

    def objective(plc: pm.Placement, mapping=None) -> jnp.ndarray:
        return cm.scenario_reward(design, scenario, env_cfg.hw, plc,
                                  mapping=mapping)

    # canonical baseline through the closed-form fast tier (no Placement)
    r0 = cm.scenario_reward(design, scenario, env_cfg.hw,
                            nop_fidelity=env_cfg.nop_fidelity)
    if init_placement is None:
        start, r_start = base, r0
    else:
        r_init = objective(init_placement)
        better = r_init > r0
        start = jax.tree_util.tree_map(
            lambda a, b: jnp.where(better, a, b), init_placement, base)
        r_start = jnp.maximum(r_init, r0)

    def propose(plc, key, cell_sums=None, pin_kind=None, mapping=None):
        """One swap/relocate/re-anchor proposal as a PlacementMove.

        Shared between the delta and full-recompute steps — the key
        split layout is part of the bit-for-bit trajectory contract.
        ``cell_sums`` lets the delta step serve the profile-guided
        centroid from the cache instead of re-reducing the slot axis.
        ``pin_kind`` (0 chiplet / 1 hbm) statically pins the move kind
        for phase-scheduled segments; the 8-way split layout is kept
        either way so pinned and mixed streams draw the same slot /
        cell / anchor / accept randomness per iteration.

        With ``mapping`` (the mapping-co-annealed chain) the return
        grows a candidate mapping: mapping randomness is *folded off*
        the split keys (the placement stream is untouched), and a
        mapping move neutralizes the placement move by relocating the
        chosen slot onto its own cell — an identity swap — so the same
        fused delta step prices both move kinds.
        """
        key, k_kind, k_slot, k_cell, k_bit, k_anchor, k_acc, k_mix = (
            jax.random.split(key, 8))

        # chiplet relocate / swap proposal
        slot = jax.random.randint(k_slot, (), 0, pm.MAX_SLOTS)
        cell = pm.random_cell_in_box(k_cell, m, n)
        anchor = pm.random_hbm_anchor(k_anchor, m, n)
        if cfg.profile_guided:
            guided = jax.random.uniform(k_mix) < cfg.p_guided
            g_cell = pm.guided_cell(k_cell, plc, n_pos, v.hbm_mask, m, n,
                                    cfg.guide_sigma, cell_sums)
            g_anchor = pm.guided_anchor(k_anchor, plc, n_pos, m, n,
                                        cfg.guide_sigma, cell_sums)
            cell = jnp.where(guided, g_cell, cell)
            anchor = jnp.where(guided, g_anchor, anchor)
        # HBM re-anchor proposal (uniform over the placed stacks)
        bit = pm.select_placed_bit(k_bit, v.hbm_mask)
        if pin_kind is None:
            use_hbm = jax.random.uniform(k_kind) < cfg.p_hbm
            kind = use_hbm.astype(jnp.int32)
        else:
            kind = jnp.int32(pin_kind)
        move = pm.PlacementMove(kind=kind, slot=slot,
                                cell=cell, hbm=bit, anchor=anchor)
        if mapping is None:
            return move, key, k_acc
        is_map = (jax.random.uniform(jax.random.fold_in(k_kind, 1))
                  < cfg.p_mapping)
        m_slot = jax.random.randint(
            jax.random.fold_in(k_slot, 1), (), 0, pm.MAX_SLOTS)
        m_stage = jax.random.randint(
            jax.random.fold_in(k_cell, 1), (), 0, mpg.MAX_STAGES)
        m_tile = jax.random.randint(
            jax.random.fold_in(k_cell, 2), (), 0, mpg.N_TILE)
        use_tile = (jax.random.uniform(jax.random.fold_in(k_kind, 2))
                    < 0.25)
        mut_stage = mpg.assign_stage(mapping, m_slot, m_stage, n_pos)
        mut_tile = mpg.assign_tile(
            mapping, jnp.mod(m_slot, mpg.N_LAYER_GROUPS), m_tile)
        mutated = jax.tree_util.tree_map(
            lambda a, b: jnp.where(use_tile, b, a), mut_stage, mut_tile)
        cand_map = jax.tree_util.tree_map(
            lambda a, b: jnp.where(is_map, b, a), mapping, mutated)
        # neutralize the placement half of a mapping move: relocating a
        # slot onto its own cell swaps it with itself (exact identity)
        slot_eff = jnp.mod(move.slot,
                           jnp.maximum(jnp.asarray(n_pos, jnp.int32), 1))
        own_cell = jnp.take(plc.chiplet_cell, slot_eff)
        move = move._replace(
            kind=jnp.where(is_map, jnp.int32(0), move.kind),
            cell=jnp.where(is_map, own_cell, move.cell))
        return move, key, k_acc, cand_map

    def make_step_full(pin_kind=None):
        """PR-3 semantics: one full costmodel.evaluate per candidate
        (kept as the delta benchmark baseline and trajectory oracle)."""
        def step_full(state, it):
            if use_mapping:
                plc, r_curr, best, r_best, mapping, best_map, key = state
                move, key, k_acc, cand_map = propose(
                    plc, key, pin_kind=pin_kind, mapping=mapping)
            else:
                plc, r_curr, best, r_best, key = state
                move, key, k_acc = propose(plc, key, pin_kind=pin_kind)
                cand_map = None
            cand = pm.apply_move(plc, move, n_pos)
            r_cand = objective(cand, cand_map)

            better_best = r_cand > r_best
            best = jax.tree_util.tree_map(
                lambda a, b: jnp.where(better_best, a, b), cand, best)
            r_best = jnp.where(better_best, r_cand, r_best)

            t = cfg.temperature / (it + 1.0)
            accept = (r_cand > r_curr) | (jax.random.uniform(k_acc) < t)
            plc = jax.tree_util.tree_map(
                lambda a, b: jnp.where(accept, a, b), cand, plc)
            r_curr = jnp.where(accept, r_cand, r_curr)
            if use_mapping:
                best_map = jax.tree_util.tree_map(
                    lambda a, b: jnp.where(better_best, a, b), cand_map,
                    best_map)
                mapping = jax.tree_util.tree_map(
                    lambda a, b: jnp.where(accept, a, b), cand_map, mapping)
                return (plc, r_curr, best, r_best, mapping, best_map,
                        key), r_best
            return (plc, r_curr, best, r_best, key), r_best
        return step_full

    # p_hbm pins the move kind at 0 or 1 -> statically prune the dead
    # delta branch (a relocation-only chain never traces the anchor scan).
    # Mapping moves ride kind 0 (identity relocate), so a mapping-enabled
    # chain can never prune the chiplet branch away.
    move_kinds = ("chiplet" if cfg.p_hbm <= 0.0
                  else "hbm" if cfg.p_hbm >= 1.0 and not use_mapping
                  else "mixed")

    def make_step_delta(mk, pin_kind=None):
        """Cache-carried step: delta NoP stats + suffix-only reward;
        accept/reject folds the candidate back via pm.commit_move.
        ``mk`` statically prunes the untaken delta branch; phased
        segments pass mk='chiplet'/'hbm' with the matching pin."""
        def step_delta(state, it):
            if use_mapping:
                cache, r_curr, best, r_best, mapping, best_map, key = state
                move, key, k_acc, cand_map = propose(
                    cache.placement, key, (cache.sum_ci, cache.sum_cj),
                    pin_kind=pin_kind, mapping=mapping)
            else:
                cache, r_curr, best, r_best, key = state
                move, key, k_acc = propose(cache.placement, key,
                                           (cache.sum_ci, cache.sum_cj),
                                           pin_kind=pin_kind)
                cand_map = None
            cand = pm.nop_stats_delta(cache, move, n_pos, v.hbm_mask,
                                      v.arch_type, mesh_edges,
                                      move_kinds=mk, mapping=cand_map)
            r_cand = cm.reward_from_nop(ctx, cand.stats, env_cfg.hw,
                                        mapping=cand_map)

            better_best = r_cand > r_best
            best = jax.tree_util.tree_map(
                lambda a, b: jnp.where(better_best, a, b), cand.placement,
                best)
            r_best = jnp.where(better_best, r_cand, r_best)

            t = cfg.temperature / (it + 1.0)
            accept = (r_cand > r_curr) | (jax.random.uniform(k_acc) < t)
            cache = pm.commit_move(cache, cand, accept)
            r_curr = jnp.where(accept, r_cand, r_curr)
            if use_mapping:
                best_map = jax.tree_util.tree_map(
                    lambda a, b: jnp.where(better_best, a, b), cand_map,
                    best_map)
                mapping = jax.tree_util.tree_map(
                    lambda a, b: jnp.where(accept, a, b), cand_map, mapping)
                return (cache, r_curr, best, r_best, mapping, best_map,
                        key), r_best
            return (cache, r_curr, best, r_best, key), r_best
        return step_delta

    segs = _validated_phase_schedule(cfg)

    def _chain(chain_key):
        incumbent = start if not cfg.delta_eval else pm.nop_stats_cache(
            start, n_pos, v.hbm_mask, v.arch_type, mesh_edges)
        if use_mapping:
            # the incumbent dataflow is the canonical (paper) mapping —
            # exactly the objective r_start was scored under
            map0 = mpg.canonical()
            state = (incumbent, r_start, start, r_start, map0, map0,
                     chain_key)
        else:
            state = (incumbent, r_start, start, r_start, chain_key)
        best_map = None
        if segs is None:
            step = (make_step_delta(move_kinds) if cfg.delta_eval
                    else make_step_full())
            iters = jnp.arange(cfg.n_iters, dtype=jnp.float32)
            if use_mapping:
                (_, _, best, r_best, _, best_map, _), trace = jax.lax.scan(
                    step, state, iters, unroll=cfg.scan_unroll)
            else:
                (_, _, best, r_best, _), trace = jax.lax.scan(
                    step, state, iters, unroll=cfg.scan_unroll)
        else:
            # phase-scheduled chain: an outer scan over cycles; each
            # cycle runs one statically-pruned inner scan per segment
            # (chiplet segments never trace the 6-anchor re-scan).
            # Temperature follows the *global* iteration index, so the
            # schedule only changes which kind each iteration draws.
            cycle = sum(ln for _, ln in segs)
            steps = {}
            for kname, _ in segs:
                if kname not in steps:
                    pin = 0 if kname == "chiplet" else 1
                    steps[kname] = (make_step_delta(kname, pin)
                                    if cfg.delta_eval
                                    else make_step_full(pin))

            def cycle_body(st, c):
                traces = []
                off = 0
                for kname, ln in segs:
                    iters = (c * cycle + off
                             + jnp.arange(ln)).astype(jnp.float32)
                    st, tr = jax.lax.scan(
                        steps[kname], st, iters,
                        unroll=min(cfg.scan_unroll, ln))
                    traces.append(tr)
                    off += ln
                return st, jnp.concatenate(traces)

            n_cycles = cfg.n_iters // cycle
            (_, _, best, r_best, _), trace2 = jax.lax.scan(
                cycle_body, state, jnp.arange(n_cycles))
            trace = trace2.reshape(cfg.n_iters)
        # strided best-so-far trace + the final value (the stride rarely
        # lands on the last iteration; history[-1] must equal best_reward)
        history = jnp.concatenate([trace[:: cfg.record_every], trace[-1:]])
        return best, r_best, history, best_map

    if cfg.n_chains <= 1:
        best, r_best, history, best_map = _chain(key)
    else:
        # several chains per design in one program: same incumbent,
        # independent RNG streams; keep the best chain's result. Chain 0
        # reuses the caller's key verbatim, so n_chains > 1 reproduces
        # the single-chain trajectory among its candidates and the
        # result is never worse than n_chains=1 on the same key.
        chain_keys = jnp.concatenate(
            [key[None], jax.random.split(key, cfg.n_chains - 1)])
        bests, r_bests, histories, best_maps = jax.vmap(_chain)(chain_keys)
        win = jnp.argmax(r_bests)
        best = jax.tree_util.tree_map(
            lambda x: jnp.take(x, win, axis=0), bests)
        r_best = jnp.take(r_bests, win)
        history = jnp.take(histories, win, axis=0)
        best_map = jax.tree_util.tree_map(
            lambda x: jnp.take(x, win, axis=0), best_maps)
    return PlacementResult(best_placement=best, best_reward=r_best,
                           canonical_reward=r0, history=history,
                           best_mapping=best_map)


def refine_placement_scenarios(key, designs: ps.DesignPoint,
                               scenarios: cm.Scenario,
                               env_cfg: chipenv.EnvConfig = chipenv.EnvConfig(),
                               cfg: PlacementSAConfig = PlacementSAConfig()
                               ) -> PlacementResult:
    """Placement-refine S suite winners as ONE vmapped XLA program.

    ``designs`` carries a leading axis S paired with ``scenarios`` (the
    per-scenario winners); swap/relocate/re-anchor chains run batched over
    the scenario axis — no host loop per winner.
    """
    n_scen = jnp.shape(scenarios.weights.alpha)[0]
    keys = jax.random.split(key, int(n_scen))
    return jax.jit(jax.vmap(
        lambda k, d, s: refine_placement(k, d, env_cfg, cfg, s)))(
            keys, designs, scenarios)
