"""Modified simulated annealing (paper Algorithm 2)."""
