"""Portfolio optimizer (paper Algorithm 1) + local refinement.

Runs ``n_sa`` SA chains and ``n_rl`` PPO agents (different seeds), then an
exhaustive argmax across all produced design points — exactly the paper's
robustness recipe ("we train multiple RL models and SA algorithms with
different seed values ... perform an exhaustive search across the
outcomes").

Beyond the paper: a final *coordinate-descent exhaustive refinement* —
for each of the 14 parameters in turn, sweep its entire Table-1 grid while
holding the others fixed (591 evaluations per sweep, vectorized) until a
fixed point. This provably never worsens the objective and usually adds a
few percent on top of the raw RL/SA winners.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel as cm
from repro.core import env as chipenv
from repro.core import params as ps
from repro.rl import ppo
from repro.sa import annealing as sa


@dataclasses.dataclass(frozen=True)
class PortfolioConfig:
    n_sa: int = 20
    n_rl: int = 20
    sa: sa.SAConfig = sa.SAConfig(n_iters=100_000)
    rl: ppo.PPOConfig = ppo.PPOConfig()
    rl_timesteps: int = 250_000
    refine: bool = True
    max_refine_sweeps: int = 8


class PortfolioResult(NamedTuple):
    best_design: ps.DesignPoint
    best_reward: float
    sa_rewards: np.ndarray          # (n_sa,)
    rl_rewards: np.ndarray          # (n_rl,)
    refined_reward: float
    wall_time_s: float
    source: str                     # 'sa' | 'rl' | 'refined'


@functools.partial(jax.jit, static_argnums=(2,))
def _sweep_rewards(cands, scenario: cm.Scenario, hw_cfg):
    """Rewards of a (K, 14) candidate batch under one scenario.

    Module-level jit with the scenario as a traced argument, so the
    compilation cache is shared across scenarios (a suite refines many
    winners) instead of re-tracing a fresh closure per scenario.
    """
    return jax.vmap(
        lambda c: cm.reward_only(ps.from_flat(c), scenario.workload,
                                 scenario.weights, hw_cfg))(cands)


def coordinate_refine(flat: jnp.ndarray, env_cfg: chipenv.EnvConfig,
                      max_sweeps: int = 8, scenario: cm.Scenario = None):
    """Exhaustive per-coordinate sweep until a fixed point."""
    scenario = env_cfg.scenario() if scenario is None else scenario
    best = jnp.asarray(flat, jnp.int32)
    best_r = float(_sweep_rewards(best[None], scenario, env_cfg.hw)[0])
    for _ in range(max_sweeps):
        improved = False
        for dim, head in enumerate(ps.HEAD_SIZES):
            cand = jnp.tile(best[None, :], (head, 1))
            cand = cand.at[:, dim].set(jnp.arange(head, dtype=jnp.int32))
            rewards = _sweep_rewards(cand, scenario, env_cfg.hw)
            idx = int(jnp.argmax(rewards))
            r = float(rewards[idx])
            if r > best_r + 1e-6:
                best = cand[idx]
                best_r = r
                improved = True
        if not improved:
            break
    return best, best_r


def optimize(key, env_cfg: chipenv.EnvConfig = chipenv.EnvConfig(),
             cfg: PortfolioConfig = PortfolioConfig(),
             verbose: bool = False,
             scenario: cm.Scenario = None) -> PortfolioResult:
    """Algorithm 1: best of {n_sa SA chains} U {n_rl RL agents} (+refine).

    Both arms are single vmapped XLA programs: ``sa.run_population`` for
    the chains and ``ppo.train_population`` for the agents — no per-agent
    Python loop anywhere on the hot path.
    """
    t0 = time.time()
    scenario = env_cfg.scenario() if scenario is None else scenario
    k_sa, k_rl = jax.random.split(key)

    # --- SA population (one vmapped program) -------------------------------
    sa_res = sa.run_population(k_sa, cfg.n_sa, env_cfg, cfg.sa,
                               scenario=scenario)
    sa_rewards = np.asarray(sa_res.best_reward)
    sa_flats = np.asarray(ps.to_flat(sa_res.best_design))

    # --- RL population (one vmapped program, seed-compatible with the old
    # sequential loop) ------------------------------------------------------
    if cfg.n_rl > 0:
        rl_res = ppo.train_population(k_rl, cfg.n_rl, env_cfg, cfg.rl,
                                      total_timesteps=cfg.rl_timesteps,
                                      scenario=scenario)
        rl_rewards_arr = np.asarray(rl_res.best_reward, np.float32)
        rl_flats = np.asarray(ps.to_flat(rl_res.best_design))   # (n_rl, 14)
        if verbose:
            for i, r in enumerate(rl_rewards_arr):
                print(f"  [portfolio] RL agent {i}: best={float(r):.2f}")
    else:
        rl_rewards_arr = np.zeros((0,), np.float32)
        rl_flats = np.zeros((0, ps.N_PARAMS), np.int32)

    # --- exhaustive argmax over all outcomes (Alg. 1 lines 5-11) -----------
    all_flats = np.concatenate([sa_flats, rl_flats], axis=0)
    all_rewards = np.concatenate([sa_rewards, rl_rewards_arr])
    top = int(np.argmax(all_rewards))
    best_flat = jnp.asarray(all_flats[top], jnp.int32)
    best_r = float(all_rewards[top])
    source = "sa" if top < len(sa_rewards) else "rl"

    refined_r = best_r
    if cfg.refine:
        refined_flat, refined_r = coordinate_refine(
            best_flat, env_cfg, cfg.max_refine_sweeps, scenario)
        if refined_r > best_r:
            best_flat, source = refined_flat, "refined"

    return PortfolioResult(
        best_design=ps.from_flat(best_flat),
        best_reward=max(best_r, refined_r),
        sa_rewards=sa_rewards,
        rl_rewards=rl_rewards_arr,
        refined_reward=refined_r,
        wall_time_s=time.time() - t0,
        source=source,
    )
