"""Portfolio optimizer (paper Algorithm 1) + local refinement.

Runs ``n_sa`` SA chains and ``n_rl`` PPO agents (different seeds), then an
exhaustive argmax across all produced design points — exactly the paper's
robustness recipe ("we train multiple RL models and SA algorithms with
different seed values ... perform an exhaustive search across the
outcomes").

Beyond the paper: a final *coordinate-descent exhaustive refinement* —
for each of the 14 parameters in turn, sweep its entire Table-1 grid while
holding the others fixed (591 evaluations per sweep, vectorized) until a
fixed point. This provably never worsens the objective and usually adds a
few percent on top of the raw RL/SA winners.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel as cm
from repro.core import env as chipenv
from repro.core import params as ps
from repro.rl import ppo
from repro.sa import annealing as sa


@dataclasses.dataclass(frozen=True)
class PortfolioConfig:
    n_sa: int = 20
    n_rl: int = 20
    sa: sa.SAConfig = sa.SAConfig(n_iters=100_000)
    rl: ppo.PPOConfig = ppo.PPOConfig()
    rl_timesteps: int = 250_000
    refine: bool = True
    max_refine_sweeps: int = 8


class PortfolioResult(NamedTuple):
    best_design: ps.DesignPoint
    best_reward: float
    sa_rewards: np.ndarray          # (n_sa,)
    rl_rewards: np.ndarray          # (n_rl,)
    refined_reward: float
    wall_time_s: float
    source: str                     # 'sa' | 'rl' | 'refined'


def _objective_fn(env_cfg):
    def f(flat_idx):
        return cm.reward_only(ps.from_flat(flat_idx), env_cfg.workload,
                              env_cfg.weights, env_cfg.hw)
    return jax.jit(f)


def coordinate_refine(flat: jnp.ndarray, env_cfg: chipenv.EnvConfig,
                      max_sweeps: int = 8):
    """Exhaustive per-coordinate sweep until a fixed point."""
    obj = _objective_fn(env_cfg)
    best = jnp.asarray(flat, jnp.int32)
    best_r = float(obj(best))
    for _ in range(max_sweeps):
        improved = False
        for dim, head in enumerate(ps.HEAD_SIZES):
            cand = jnp.tile(best[None, :], (head, 1))
            cand = cand.at[:, dim].set(jnp.arange(head, dtype=jnp.int32))
            rewards = jax.vmap(obj)(cand)
            idx = int(jnp.argmax(rewards))
            r = float(rewards[idx])
            if r > best_r + 1e-6:
                best = cand[idx]
                best_r = r
                improved = True
        if not improved:
            break
    return best, best_r


def optimize(key, env_cfg: chipenv.EnvConfig = chipenv.EnvConfig(),
             cfg: PortfolioConfig = PortfolioConfig(),
             verbose: bool = False) -> PortfolioResult:
    """Algorithm 1: best of {n_sa SA chains} U {n_rl RL agents} (+refine)."""
    t0 = time.time()
    k_sa, k_rl = jax.random.split(key)

    # --- SA population (one vmapped program) -------------------------------
    sa_res = sa.run_population(k_sa, cfg.n_sa, env_cfg, cfg.sa)
    sa_rewards = np.asarray(sa_res.best_reward)
    sa_flats = np.asarray(ps.to_flat(sa_res.best_design))

    # --- RL agents ----------------------------------------------------------
    rl_rewards: List[float] = []
    rl_flats: List[np.ndarray] = []
    rl_keys = jax.random.split(k_rl, cfg.n_rl)
    for i in range(cfg.n_rl):
        res = ppo.train(rl_keys[i], env_cfg, cfg.rl,
                        total_timesteps=cfg.rl_timesteps)
        rl_rewards.append(float(res.best_reward))
        rl_flats.append(np.asarray(ps.to_flat(res.best_design)))
        if verbose:
            print(f"  [portfolio] RL agent {i}: best={rl_rewards[-1]:.2f}")
    rl_rewards_arr = np.asarray(rl_rewards, np.float32)

    # --- exhaustive argmax over all outcomes (Alg. 1 lines 5-11) -----------
    all_flats = np.concatenate(
        [sa_flats, np.stack(rl_flats)] if rl_flats else [sa_flats], axis=0)
    all_rewards = np.concatenate([sa_rewards, rl_rewards_arr]) \
        if rl_flats else sa_rewards
    top = int(np.argmax(all_rewards))
    best_flat = jnp.asarray(all_flats[top], jnp.int32)
    best_r = float(all_rewards[top])
    source = "sa" if top < len(sa_rewards) else "rl"

    refined_r = best_r
    if cfg.refine:
        refined_flat, refined_r = coordinate_refine(
            best_flat, env_cfg, cfg.max_refine_sweeps)
        if refined_r > best_r:
            best_flat, source = refined_flat, "refined"

    return PortfolioResult(
        best_design=ps.from_flat(best_flat),
        best_reward=max(best_r, refined_r),
        sa_rewards=sa_rewards,
        rl_rewards=rl_rewards_arr,
        refined_reward=refined_r,
        wall_time_s=time.time() - t0,
        source=source,
    )
