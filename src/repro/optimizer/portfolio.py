"""Portfolio optimizer (paper Algorithm 1) + local refinement.

Runs ``n_sa`` SA chains, ``n_rl`` PPO agents, and ``n_evo`` GA islands
(different seeds), then an exhaustive argmax across all produced design
points — the paper's robustness recipe ("we train multiple RL models and
SA algorithms with different seed values ... perform an exhaustive search
across the outcomes") extended with the evolutionary third arm
(optimizer/evo.py).

Beyond the paper:

- a *coordinate-descent exhaustive refinement* — for each of the 14
  parameters in turn, sweep its entire Table-1 grid while holding the
  others fixed (591 evaluations per sweep, vectorized) until a fixed
  point. Every arm's best candidate is refined in one lockstep batched
  sweep, so enabling an extra arm can never lower the final reward (the
  refine set only grows).
- a shared :class:`repro.optimizer.archive.Archive`: every candidate any
  arm produced (plus the GA's own generation-live archive) competes for
  one non-dominated (tasks/s up, J/task down, cost down) front, returned
  in :class:`PortfolioResult` — the multi-objective answer next to the
  scalarized winner.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel as cm
from repro.core import env as chipenv
from repro.core import params as ps
from repro.optimizer import archive as ar
from repro.optimizer import evo
from repro.rl import ppo
from repro.sa import annealing as sa
from repro.surrogate import dataset as sds
from repro.surrogate import ranker as srk
from repro.telemetry import counters as tl
from repro.telemetry import journal as tj


@dataclasses.dataclass(frozen=True)
class PortfolioConfig:
    n_sa: int = 20
    n_rl: int = 20
    n_evo: int = 1                  # GA islands (0 disables the third arm)
    refine: bool = True
    max_refine_sweeps: int = 8
    refine_placement: bool = True
    # mapping/dataflow stage (core/mapping.py): co-anneal the winner's
    # (placement, mapping) seeded from the placement-refined floorplan,
    # under fold_in(key, 8) — no other key stream moves. The mapped
    # result is kept only when it beats the placement stage's reward, so
    # enabling it never lowers the portfolio winner. Requires
    # refine_placement.
    refine_mapping: bool = False
    archive_capacity: int = 64      # shared Pareto archive size
    # NOTE: placement_sa must precede the `sa` field — that field shadows
    # the annealing module for later annotations in this class body.
    placement_sa: sa.PlacementSAConfig = sa.PlacementSAConfig()
    # SA config for the mapping stage; None derives it from placement_sa
    # (p_mapping=0.25, phase_schedule off).
    placement_sa_mapping: sa.PlacementSAConfig = None
    sa: sa.SAConfig = sa.SAConfig(n_iters=100_000)
    rl: ppo.PPOConfig = ppo.PPOConfig()
    rl_timesteps: int = 250_000
    evo: evo.EvoConfig = evo.EvoConfig()
    # surrogate front-filter stage (None disables; see surrogate/ranker.py).
    # Runs under its own folded key, so enabling it never perturbs the
    # SA/RL/GA streams — candidates (all analytically re-scored) only ADD.
    surrogate: srk.SurrogateConfig = None


class PortfolioResult(NamedTuple):
    best_design: ps.DesignPoint
    best_reward: float
    sa_rewards: np.ndarray          # (n_sa,)
    rl_rewards: np.ndarray          # (n_rl,)
    refined_reward: float
    wall_time_s: float
    source: str                     # 'sa'|'rl'|'evo'|'refined'|'surrogate'
    placement: object = None        # placement.Placement of the winner
    placement_reward: float = None  # >= best_reward by construction
    evo_rewards: np.ndarray = None  # (n_evo,)
    archive: ar.Archive = None      # shared cross-arm Pareto archive
    surrogate_rewards: np.ndarray = None   # (K,) analytic top-k rewards
    # mapping/dataflow stage (None unless cfg.refine_mapping won): the
    # winner's mapping.Mapping and its reward (>= placement_reward)
    mapping: object = None
    mapping_reward: float = None


@functools.partial(jax.jit, static_argnums=(2, 3))
def _sweep_rewards(cands, scenario: cm.Scenario, hw_cfg,
                   nop_fidelity: str = "auto"):
    """Rewards of a (K, 14) candidate batch under one scenario.

    Module-level jit with the scenario as a traced argument, so the
    compilation cache is shared across scenarios (a suite refines many
    winners) instead of re-tracing a fresh closure per scenario.
    """
    return jax.vmap(
        lambda c: cm.scenario_reward(ps.from_flat(c), scenario, hw_cfg,
                                     nop_fidelity=nop_fidelity))(cands)


def coordinate_refine(flat: jnp.ndarray, env_cfg: chipenv.EnvConfig,
                      max_sweeps: int = 8, scenario: cm.Scenario = None):
    """Exhaustive per-coordinate sweep until a fixed point."""
    scenario = env_cfg.scenario() if scenario is None else scenario
    fid = env_cfg.nop_fidelity
    best = jnp.asarray(flat, jnp.int32)
    best_r = float(_sweep_rewards(best[None], scenario, env_cfg.hw, fid)[0])
    for _ in range(max_sweeps):
        improved = False
        for dim, head in enumerate(ps.HEAD_SIZES):
            cand = jnp.tile(best[None, :], (head, 1))
            cand = cand.at[:, dim].set(jnp.arange(head, dtype=jnp.int32))
            rewards = _sweep_rewards(cand, scenario, env_cfg.hw, fid)
            idx = int(jnp.argmax(rewards))
            r = float(rewards[idx])
            if r > best_r + 1e-6:
                best = cand[idx]
                best_r = r
                improved = True
        if not improved:
            break
    return best, best_r


@functools.partial(jax.jit, static_argnums=(2, 3))
def _sweep_all_scenarios(flats, scenarios: cm.Scenario, hw_cfg,
                         nop_fidelity: str = "auto", placements=None):
    """ONE full coordinate sweep for every scenario winner in lockstep.

    ``flats`` is (S, 14) — winner i refined under scenario i. For each of
    the 14 dims the whole Table-1 grid is evaluated for *all* scenarios in
    a single (S, head) vmapped batch; no host loop over winners.
    ``placements`` (optional, leading axis S) scores every candidate
    design under scenario i's *refined floorplan* instead of the
    canonical one — the post-placement design re-sweep of
    ``scenario.run_suite`` (placement-aware candidates share the
    fast-tier canonical baseline exactly like ``costmodel.evaluate``).
    Returns (flats', rewards') after one sweep.
    """
    # an explicit placement needs the full pairwise tier (mirrors env.step)
    fid = ("auto" if placements is not None and nop_fidelity == "fast"
           else nop_fidelity)
    # a placement annealed for design i is only collision-free over that
    # design's active slots; a candidate with MORE footprint slots would
    # activate stale (possibly overlapping) cells and mint a bogus
    # 0-hop reward — reject any candidate that grows the footprint
    n_pos_cap = (None if placements is None else
                 cm.footprint_positions(ps.decode(ps.from_flat(flats))))

    def reward_sc(c, s, p, cap):
        r = cm.scenario_reward(ps.from_flat(c), s, hw_cfg, p,
                               nop_fidelity=fid)
        if cap is None:
            return r
        n_pos_c = cm.footprint_positions(ps.decode(ps.from_flat(c)))
        return jnp.where(n_pos_c <= cap, r, jnp.float32(-1e30))

    p_axis = None if placements is None else 0
    cur_r = jax.vmap(reward_sc, in_axes=(0, 0, p_axis, p_axis))(
        flats, scenarios, placements, n_pos_cap)                  # (S,)
    for dim, head in enumerate(ps.HEAD_SIZES):
        cand = jnp.tile(flats[:, None, :], (1, head, 1))          # (S, H, 14)
        cand = cand.at[:, :, dim].set(jnp.arange(head, dtype=jnp.int32))
        rewards = jax.vmap(lambda c, s, p, cap: jax.vmap(
            lambda cc: reward_sc(cc, s, p, cap))(c),
            in_axes=(0, 0, p_axis, p_axis))(
                cand, scenarios, placements, n_pos_cap)           # (S, H)
        idx = jnp.argmax(rewards, axis=1)
        best_r = jnp.take_along_axis(rewards, idx[:, None], axis=1)[:, 0]
        best_c = jnp.take_along_axis(
            cand, idx[:, None, None], axis=1)[:, 0, :]
        improved = best_r > cur_r + 1e-6
        flats = jnp.where(improved[:, None], best_c, flats)
        cur_r = jnp.where(improved, best_r, cur_r)
    return flats, cur_r


def coordinate_refine_batch(flats, scenarios: cm.Scenario,
                            env_cfg: chipenv.EnvConfig,
                            max_sweeps: int = 8, placements=None):
    """Batched :func:`coordinate_refine`: all S scenario winners sweep as
    one jitted vmapped program per sweep, stopping when no winner moves.

    With ``placements`` (a ``placement.Placement`` batch, leading axis S)
    the lockstep sweep co-optimizes the *design* grid under each
    winner's refined floorplan — candidate rewards are evaluated with
    the explicit placement threaded through ``costmodel.evaluate``.
    Candidates that *grow* the footprint are rejected in-place (the
    annealed placement is only collision-free over the slots the design
    it was annealed for actually uses); shrinking stays legal.
    Returns (flats (S, 14) int32, rewards (S,) float) as numpy arrays.
    """
    flats = jnp.asarray(flats, jnp.int32)
    rewards = None
    for _ in range(max_sweeps):
        new_flats, rewards = _sweep_all_scenarios(flats, scenarios,
                                                  env_cfg.hw,
                                                  env_cfg.nop_fidelity,
                                                  placements)
        if bool(jnp.all(new_flats == flats)):
            flats = new_flats
            break
        flats = new_flats
    if rewards is None:
        rewards = jax.vmap(lambda c, s: cm.scenario_reward(
            ps.from_flat(c), s, env_cfg.hw,
            nop_fidelity=env_cfg.nop_fidelity))(flats, scenarios)
    return np.asarray(flats), np.asarray(rewards)


def optimize(key, env_cfg: chipenv.EnvConfig = chipenv.EnvConfig(),
             cfg: PortfolioConfig = PortfolioConfig(),
             verbose: bool = False,
             scenario: cm.Scenario = None,
             journal=None) -> PortfolioResult:
    """Algorithm 1: best of {SA chains} U {RL agents} U {GA islands}.

    Every arm is a single vmapped XLA program (``sa.run_population``,
    ``ppo.train_population``, ``evo.evolve_population``) — no per-agent
    Python loop anywhere on the hot path. The best candidate of *each*
    arm is coordinate-refined in one lockstep batched sweep, and every
    candidate feeds the shared Pareto archive. The SA/RL key streams do
    not depend on ``n_evo``, so enabling the third arm only ever grows
    the candidate and refine sets: ``best_reward`` with the evo arm is
    >= the SA+RL-only portfolio's on the same key, scenario for
    scenario (asserted by tests/test_evo.py and the smoke bench).

    ``journal`` (optional ``telemetry.journal.Journal``) receives one
    span per stage plus per-arm convergence events; it is also installed
    as the ambient journal for the duration of the run. ``None`` falls
    back to the ambient journal; with neither, emits are no-ops.
    """
    if journal is None:
        journal = tj.current()
    jr = tj.or_null(journal)
    with tj.use(journal):
        return _optimize(jr, key, env_cfg, cfg, verbose, scenario)


def _optimize(jr, key, env_cfg, cfg: PortfolioConfig, verbose, scenario):
    t0 = time.time()
    scenario = env_cfg.scenario() if scenario is None else scenario
    k_sa, k_rl = jax.random.split(key)
    k_evo = jax.random.fold_in(key, 3)

    # --- SA population (one vmapped program) -------------------------------
    with jr.span("arm:sa", key_stream="split(key)[0]", n_chains=cfg.n_sa,
                 n_iters=cfg.sa.n_iters):
        sa_res = sa.run_population(k_sa, cfg.n_sa, env_cfg, cfg.sa,
                                   scenario=scenario)
        jr.event("arm_convergence", arm="sa",
                 best=np.asarray(sa_res.best_reward),
                 curve=np.asarray(sa_res.history).max(axis=0))
    sa_rewards = np.asarray(sa_res.best_reward)
    sa_flats = np.asarray(ps.to_flat(sa_res.best_design))

    # --- RL population (one vmapped program, seed-compatible with the old
    # sequential loop) ------------------------------------------------------
    if cfg.n_rl > 0:
        with jr.span("arm:rl", key_stream="split(key)[1]",
                     n_agents=cfg.n_rl, timesteps=cfg.rl_timesteps):
            rl_res = ppo.train_population(k_rl, cfg.n_rl, env_cfg, cfg.rl,
                                          total_timesteps=cfg.rl_timesteps,
                                          scenario=scenario)
            jr.event("arm_convergence", arm="rl",
                     best=np.asarray(rl_res.best_reward),
                     curve=np.asarray(rl_res.log.best_reward).max(axis=0))
        rl_rewards_arr = np.asarray(rl_res.best_reward, np.float32)
        rl_flats = np.asarray(ps.to_flat(rl_res.best_design))   # (n_rl, 14)
        rl_actions = np.asarray(rl_res.best_action)   # incl. placement heads
        if verbose:
            for i, r in enumerate(rl_rewards_arr):
                print(f"  [portfolio] RL agent {i}: best={float(r):.2f}")
    else:
        rl_rewards_arr = np.zeros((0,), np.float32)
        rl_flats = np.zeros((0, ps.N_PARAMS), np.int32)
        rl_actions = np.zeros((0, chipenv.action_dim(env_cfg)), np.int32)

    # --- GA islands (one vmapped program, archive riding the scan) ---------
    evo_archive = None
    if cfg.n_evo > 0:
        with jr.span("arm:evo", key_stream="fold_in(key, 3)",
                     n_islands=cfg.n_evo,
                     n_generations=cfg.evo.n_generations):
            evo_res = evo.evolve_population(k_evo, cfg.n_evo, env_cfg,
                                            cfg.evo, scenario=scenario)
            jr.event("arm_convergence", arm="evo",
                     best=np.asarray(evo_res.best_reward),
                     curve=np.asarray(evo_res.history).max(axis=0))
            if evo_res.telemetry is not None:
                st = evo_res.telemetry
                jr.event("evo_stats",
                         diversity=np.asarray(st.diversity).mean(axis=0),
                         archive_hv=np.asarray(st.archive_hv).max(axis=0),
                         archive_n=np.asarray(st.archive_n).max(axis=0))
        evo_rewards_arr = np.asarray(evo_res.best_reward, np.float32)
        evo_flats = np.asarray(ps.to_flat(evo_res.best_design))
        evo_genomes = np.asarray(evo_res.best_genome)   # incl. plc genes
        evo_archive = evo_res.archive               # (n_evo, C, ...) stacked
    else:
        evo_rewards_arr = np.zeros((0,), np.float32)
        evo_flats = np.zeros((0, ps.N_PARAMS), np.int32)
        evo_genomes = np.zeros((0, ps.N_PARAMS), np.int32)

    # --- exhaustive argmax over all outcomes (Alg. 1 lines 5-11) -----------
    arm_segments = [("sa", sa_rewards, sa_flats),
                    ("rl", rl_rewards_arr, rl_flats),
                    ("evo", evo_rewards_arr, evo_flats)]
    all_flats = np.concatenate([f for _, _, f in arm_segments], axis=0)
    all_rewards = np.concatenate([r for _, r, _ in arm_segments])
    labels = sum(([nm] * len(r) for nm, r, _ in arm_segments), [])
    top = int(np.argmax(all_rewards))
    best_flat = jnp.asarray(all_flats[top], jnp.int32)
    best_r = float(all_rewards[top])
    source = labels[top]

    # --- per-arm lockstep refinement (one batched sweep program) -----------
    refined_r = best_r
    refine_flats = np.zeros((0, ps.N_PARAMS), np.int32)
    refine_rewards = np.zeros((0,), np.float32)
    if cfg.refine:
        arm_best = np.stack([f[np.argmax(r)] for _, r, f in arm_segments
                             if len(r)], axis=0)
        n_arms = arm_best.shape[0]
        scen_rep = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(jnp.asarray(x),
                                       (n_arms,) + jnp.shape(x)), scenario)
        with jr.span("refine", rows=n_arms, sweeps=cfg.max_refine_sweeps):
            refine_flats, refine_rewards = coordinate_refine_batch(
                arm_best, scen_rep, env_cfg, cfg.max_refine_sweeps)
        j = int(np.argmax(refine_rewards))
        refined_r = float(refine_rewards[j])
        if refined_r > best_r:
            best_flat, source = jnp.asarray(refine_flats[j]), "refined"

    best_design = ps.from_flat(best_flat)

    # --- shared Pareto archive: every candidate from every arm -------------
    arc = ar.empty(cfg.archive_capacity)
    cand_flats = np.concatenate([all_flats, refine_flats], axis=0)
    cand_labels = labels + ["refined"] * len(refine_rewards)
    arm_ids = {"sa": 0, "rl": 1, "evo": 2, "refined": 3, "surrogate": 4}
    # the archive evaluation below is the portfolio's one concrete
    # (host-level) cost-model call — with a surrogate stage configured it
    # doubles as the eval tap site feeding the training ring buffer
    tap = None
    if cfg.surrogate is not None:
        tap = sds.EvalTap(capacity=cfg.surrogate.capacity)
        cm.register_eval_tap(tap)
    try:
        if len(cand_labels):
            mtr = cm.evaluate_scenario(
                ps.from_flat(jnp.asarray(cand_flats, jnp.int32)),
                scenario, env_cfg.hw, nop_fidelity=env_cfg.nop_fidelity)
            # reward mirrors the archived point (canonical-floorplan eval
            # of the stored flats), NOT the arm-reported best — an RL/evo
            # reward achieved via a placement mutation belongs to
            # (design, placement) pairs the 14-index row can't reproduce
            arc = ar.insert_batch(
                arc, ar.point_from_metrics(mtr),
                jnp.asarray(cand_flats, jnp.int32),
                reward=mtr.reward,
                payload=jnp.asarray([arm_ids[l] for l in cand_labels],
                                    jnp.int32))

        # --- surrogate front-filter stage (see surrogate/ranker.py) --------
        overall_r = max(best_r, refined_r)
        sur_rewards_arr = None
        if cfg.surrogate is not None:
            scen_b = jax.tree_util.tree_map(
                lambda x: jnp.asarray(x)[None], scenario)
            with jr.span("surrogate", key_stream="fold_in(key, 7)",
                         mode=cfg.surrogate.mode):
                sres = srk.run_stage(
                    jax.random.fold_in(key, 7), scen_b, cfg.surrogate,
                    env_cfg.hw, nop_fidelity=env_cfg.nop_fidelity,
                    tap_dataset=tap.dataset)
            sur_flats = np.asarray(sres.cand_flats[0])
            sur_rewards_arr = np.asarray(sres.cand_rewards[0], np.float32)
            s_mtr = cm.evaluate_scenario(
                ps.from_flat(jnp.asarray(sur_flats, jnp.int32)),
                scenario, env_cfg.hw, nop_fidelity=env_cfg.nop_fidelity)
            arc = ar.insert_batch(
                arc, ar.point_from_metrics(s_mtr),
                jnp.asarray(sur_flats, jnp.int32), reward=s_mtr.reward,
                payload=jnp.full((sur_flats.shape[0],),
                                 arm_ids["surrogate"], jnp.int32))
            j = int(np.argmax(sur_rewards_arr))
            if float(sur_rewards_arr[j]) > overall_r:
                overall_r = float(sur_rewards_arr[j])
                best_flat = jnp.asarray(sur_flats[j], jnp.int32)
                best_design = ps.from_flat(best_flat)
                source = "surrogate"
    finally:
        if tap is not None:
            cm.unregister_eval_tap(tap)
    if evo_archive is not None:
        # the GA's generation-live fronts (stacked over islands): every
        # point an island ever archived competes for the shared front too.
        # (point, reward) pairs are as-achieved; with placement_genes the
        # 14-index slice alone may not reproduce them — the full genome
        # stays in EvoResult.archive.flats
        n_pts = evo_archive.valid.size
        arc = ar.insert_batch(
            arc, evo_archive.points.reshape(n_pts, -1),
            evo_archive.flats.reshape(n_pts, -1)[:, : ps.N_PARAMS],
            reward=evo_archive.reward.reshape(n_pts),
            payload=jnp.full((n_pts,), arm_ids["evo"], jnp.int32),
            valid=evo_archive.valid.reshape(n_pts))
    # an RL winner trained with placement actions (or an evo winner with
    # placement genes) achieved its reward *with* a placement mutation —
    # recover it so the returned (design, placement, placement_reward)
    # triple stays reproducible and the placement stage starts from it
    init_plc = None
    if (env_cfg.placement_actions and source == "rl"
            and rl_actions.shape[1] > ps.N_PARAMS):
        win_act = jnp.asarray(rl_actions[top - len(sa_rewards)], jnp.int32)
        _, init_plc = chipenv._design_and_placement(win_act, env_cfg)
    elif source == "evo" and cfg.evo.placement_genes:
        win_g = jnp.asarray(
            evo_genomes[top - len(sa_rewards) - len(rl_rewards_arr)],
            jnp.int32)
        _, init_plc = evo.genome_placement(win_g)
    placement, placement_r = init_plc, overall_r
    if cfg.refine_placement:
        with jr.span("placement", key_stream="fold_in(key, 2)",
                     n_iters=cfg.placement_sa.n_iters):
            pres = sa.refine_placement(
                jax.random.fold_in(key, 2), best_design, env_cfg,
                cfg.placement_sa, scenario, init_placement=init_plc)
            if pres.telemetry is not None:
                jr.event("sa_accept", stage="placement",
                         **tl.summarize_sa(pres.telemetry))
        placement = pres.best_placement
        placement_r = float(pres.best_reward)

    # --- mapping/dataflow stage: (placement, mapping) co-anneal seeded
    # from the refined floorplan; kept only if it beats the placement
    # stage (never-worse by construction) ----------------------------------
    mapping, mapping_r = None, None
    if cfg.refine_mapping:
        if not cfg.refine_placement:
            raise ValueError("refine_mapping requires refine_placement "
                             "(the stage anneals on top of the refined "
                             "floorplan)")
        map_sa = cfg.placement_sa_mapping
        if map_sa is None:
            map_sa = dataclasses.replace(cfg.placement_sa, p_mapping=0.25,
                                         phase_schedule=None)
        with jr.span("mapping", key_stream="fold_in(key, 8)",
                     n_iters=map_sa.n_iters):
            mres = sa.refine_placement(
                jax.random.fold_in(key, 8), best_design, env_cfg,
                map_sa, scenario, init_placement=placement)
        if float(mres.best_reward) > placement_r + 1e-6:
            placement = mres.best_placement
            mapping = mres.best_mapping
            mapping_r = float(mres.best_reward)
            placement_r = mapping_r

    jr.event("portfolio_end", best_reward=overall_r, source=source,
             placement_reward=placement_r, mapping_reward=mapping_r,
             wall_time_s=time.time() - t0)
    return PortfolioResult(
        best_design=best_design,
        best_reward=overall_r,
        sa_rewards=sa_rewards,
        rl_rewards=rl_rewards_arr,
        refined_reward=refined_r,
        wall_time_s=time.time() - t0,
        source=source,
        placement=placement,
        placement_reward=placement_r,
        evo_rewards=evo_rewards_arr,
        archive=arc,
        surrogate_rewards=sur_rewards_arr,
        mapping=mapping,
        mapping_reward=mapping_r,
    )
