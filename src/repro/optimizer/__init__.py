"""Portfolio optimizer (paper Algorithm 1, three arms: SA + PPO + GA),
scenario-batched suite, and the JAX-resident Pareto archive."""
