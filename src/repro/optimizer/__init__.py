"""Portfolio optimizer (paper Algorithm 1) + scenario-batched suite."""
