"""Portfolio optimizer (paper Algorithm 1)."""
