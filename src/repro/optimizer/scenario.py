"""Scenario-batched design-space exploration (ScenarioSuite).

The paper optimizes one accelerator for one workload under one reward
weighting. Production co-design (cf. Monad's multi-workload specialization,
Gemini's joint co-exploration) needs the *grid*: every workload in the
registry x every objective trade-off. This module runs the Algorithm-1
portfolio across a (workload x reward-weight) scenario grid where both
arms — the SA chains and the PPO agents — execute as scenario-vmapped XLA
programs, then reports per-scenario winners plus the cross-scenario Pareto
frontier over (throughput, energy/task, cost).

    PYTHONPATH=src python -m repro.launch.train --arch scenario-suite \
        --workloads mlperf --smoke
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel as cm
from repro.core import env as chipenv
from repro.core import params as ps
from repro.core import workload as wl
from repro.optimizer import portfolio
from repro.rl import ppo
from repro.sa import annealing as sa

# (alpha, beta, gamma) objective trade-offs swept by default (Eq. 17):
# balanced (paper default), throughput-first, cost-first, energy-aware.
DEFAULT_WEIGHT_GRID: Tuple[Tuple[float, float, float], ...] = (
    (1.0, 1.0, 0.1),
    (2.0, 0.5, 0.1),
    (0.5, 2.0, 0.1),
)


@dataclasses.dataclass(frozen=True)
class SuiteConfig:
    """One scenario-suite run: workloads x weight grid x portfolio scale."""

    workloads: Tuple[str, ...] = ("mlperf",)
    weight_grid: Tuple[Tuple[float, float, float], ...] = DEFAULT_WEIGHT_GRID
    n_sa: int = 8
    n_rl: int = 4
    sa: sa.SAConfig = sa.SAConfig(n_iters=20_000)
    rl: ppo.PPOConfig = ppo.PPOConfig(n_steps=128, n_envs=4)
    rl_timesteps: int = 128 * 4 * 4
    refine: bool = True
    max_refine_sweeps: int = 2
    env: chipenv.EnvConfig = chipenv.EnvConfig()


SMOKE_SUITE = SuiteConfig(
    n_sa=2, n_rl=2,
    sa=sa.SAConfig(n_iters=2_000),
    rl=ppo.PPOConfig(n_steps=32, n_envs=2, batch_size=32),
    rl_timesteps=32 * 2 * 2,
    refine=True, max_refine_sweeps=1,
)


@dataclasses.dataclass(frozen=True)
class ScenarioOutcome:
    """Winner of one (workload, reward-weight) scenario."""

    name: str
    workload_name: str
    weights: Tuple[float, float, float]
    best_flat: np.ndarray           # (14,) int32 design indices
    best_reward: float
    source: str                     # 'sa' | 'rl' | 'refined'
    tasks_per_sec: float
    energy_per_task_j: float
    total_cost: float
    eff_tops: float


@dataclasses.dataclass(frozen=True)
class SuiteResult:
    outcomes: List[ScenarioOutcome]
    pareto: List[int]               # indices into outcomes, non-dominated
    wall_time_s: float


def build_scenarios(cfg: SuiteConfig) -> Tuple[List[str], List[str],
                                               cm.Scenario]:
    """Resolve the grid -> (scenario names, workload names, batched Scenario)."""
    wl_names, workloads = wl.resolve(cfg.workloads)
    names, wnames, scalars = [], [], []
    for wname, workload in zip(wl_names, workloads):
        for a, b, g in cfg.weight_grid:
            names.append(f"{wname}|a={a:g},b={b:g},g={g:g}")
            wnames.append(wname)
            scalars.append(cm.Scenario(workload=workload,
                                       weights=cm.make_weights(a, b, g)))
    return names, wnames, cm.stack_scenarios(scalars)


def pareto_indices(points: np.ndarray,
                   maximize: Sequence[bool]) -> List[int]:
    """Indices of the non-dominated rows of ``points`` (S, D)."""
    pts = np.asarray(points, np.float64).copy()
    for d, mx in enumerate(maximize):
        if not mx:
            pts[:, d] = -pts[:, d]
    out = []
    for i in range(pts.shape[0]):
        dominated = np.any(
            np.all(pts >= pts[i], axis=1) & np.any(pts > pts[i], axis=1))
        if not dominated:
            out.append(i)
    return out


def run_suite(key, cfg: SuiteConfig = SuiteConfig(),
              verbose: bool = False) -> SuiteResult:
    """Portfolio-optimize every scenario in the grid; both arms vectorized.

    The SA arm runs (S scenarios x n_sa chains) as one XLA program, the RL
    arm (S scenarios x n_rl agents) as another — the only Python loop left
    is the cheap per-winner coordinate refinement.
    """
    t0 = time.time()
    names, wnames, scenarios = build_scenarios(cfg)
    n_scen = len(names)
    k_sa, k_rl = jax.random.split(jnp.asarray(key))

    cand_rewards = []                                   # each (S, K)
    cand_flats = []                                     # each (S, K, 14)
    if cfg.n_sa > 0:
        sa_res = sa.run_scenario_population(
            k_sa, scenarios, cfg.n_sa, cfg.env, cfg.sa)
        cand_rewards.append(np.asarray(sa_res.best_reward))
        cand_flats.append(np.asarray(ps.to_flat(sa_res.best_design)))
    if cfg.n_rl > 0:
        rl_res = ppo.train_scenario_population(
            k_rl, scenarios, cfg.n_rl, cfg.env, cfg.rl,
            total_timesteps=cfg.rl_timesteps)
        cand_rewards.append(np.asarray(rl_res.best_reward))
        cand_flats.append(np.asarray(ps.to_flat(rl_res.best_design)))
    if not cand_rewards:
        raise ValueError("SuiteConfig needs n_sa > 0 or n_rl > 0")

    n_sa = cfg.n_sa
    rewards = np.concatenate(cand_rewards, axis=1)      # (S, n_sa + n_rl)
    flats = np.concatenate(cand_flats, axis=1)          # (S, ..., 14)

    # per-scenario argmax + refinement (host side, cheap)
    winner_flats = np.zeros((n_scen, ps.N_PARAMS), np.int32)
    winner_rewards = np.zeros((n_scen,), np.float64)
    sources: List[str] = []
    for s in range(n_scen):
        top = int(np.argmax(rewards[s]))
        best_flat = jnp.asarray(flats[s, top], jnp.int32)
        best_r = float(rewards[s, top])
        source = "sa" if top < n_sa else "rl"
        if cfg.refine:
            scen_s = jax.tree_util.tree_map(lambda x: x[s], scenarios)
            refined_flat, refined_r = portfolio.coordinate_refine(
                best_flat, cfg.env, cfg.max_refine_sweeps, scen_s)
            if refined_r > best_r:
                best_flat, best_r, source = refined_flat, refined_r, "refined"
        winner_flats[s] = np.asarray(best_flat)
        winner_rewards[s] = best_r
        sources.append(source)
        if verbose:
            print(f"  [suite] {names[s]}: reward={best_r:.1f} ({source})")

    # scenario-batched PPAC evaluation of all winners in one program
    dp_batch = ps.from_flat(jnp.asarray(winner_flats))
    metrics = cm.evaluate_scenarios(dp_batch, scenarios, cfg.env.hw)

    outcomes = []
    for s in range(n_scen):
        outcomes.append(ScenarioOutcome(
            name=names[s], workload_name=wnames[s],
            weights=(float(scenarios.weights.alpha[s]),
                     float(scenarios.weights.beta[s]),
                     float(scenarios.weights.gamma[s])),
            best_flat=winner_flats[s],
            best_reward=float(winner_rewards[s]),
            source=sources[s],
            tasks_per_sec=float(metrics.tasks_per_sec[s]),
            energy_per_task_j=float(metrics.energy_per_task_j[s]),
            total_cost=float(metrics.total_cost[s]),
            eff_tops=float(metrics.eff_tops[s]),
        ))

    triples = np.stack([
        [o.tasks_per_sec, o.energy_per_task_j, o.total_cost]
        for o in outcomes])
    pareto = pareto_indices(triples, maximize=(True, False, False))
    return SuiteResult(outcomes=outcomes, pareto=pareto,
                       wall_time_s=time.time() - t0)


def format_report(res: SuiteResult) -> str:
    """Human-readable per-scenario table + Pareto frontier."""
    lines = [f"{'scenario':<42} {'reward':>9} {'tasks/s':>12} "
             f"{'J/task':>10} {'cost':>9} {'src':>8}"]
    for i, o in enumerate(res.outcomes):
        star = "*" if i in res.pareto else " "
        lines.append(
            f"{star}{o.name:<41} {o.best_reward:>9.1f} "
            f"{o.tasks_per_sec:>12,.0f} {o.energy_per_task_j:>10.2e} "
            f"{o.total_cost:>9.0f} {o.source:>8}")
    lines.append(f"\nPareto frontier (throughput vs energy vs cost): "
                 f"{len(res.pareto)}/{len(res.outcomes)} scenarios (*), "
                 f"suite wall-time {res.wall_time_s:.1f}s")
    return "\n".join(lines)


def to_json(res: SuiteResult) -> Dict:
    """JSON-serializable summary (per-scenario winners + frontier)."""
    return {
        "wall_time_s": res.wall_time_s,
        "pareto": list(res.pareto),
        "scenarios": [{
            "name": o.name,
            "workload": o.workload_name,
            "weights": list(o.weights),
            "design": [int(x) for x in o.best_flat],
            "reward": o.best_reward,
            "source": o.source,
            "tasks_per_sec": o.tasks_per_sec,
            "energy_per_task_j": o.energy_per_task_j,
            "total_cost": o.total_cost,
            "eff_tops": o.eff_tops,
        } for o in res.outcomes],
    }


def save_json(res: SuiteResult, path: str) -> None:
    with open(path, "w") as f:
        json.dump(to_json(res), f, indent=2)
