"""Scenario-batched design-space exploration (ScenarioSuite).

The paper optimizes one accelerator for one workload under one reward
weighting. Production co-design (cf. Monad's multi-workload specialization,
Gemini's joint co-exploration) needs the *grid*: every workload in the
registry x every objective trade-off. This module runs the Algorithm-1
portfolio across a (workload x reward-weight) scenario grid where both
arms — the SA chains and the PPO agents — execute as scenario-vmapped XLA
programs, then reports per-scenario winners plus the cross-scenario Pareto
frontier over (throughput, energy/task, cost).

    PYTHONPATH=src python -m repro.launch.train --arch scenario-suite \
        --workloads mlperf --smoke
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel as cm
from repro.core import env as chipenv
from repro.core import hw_constants as hw
from repro.core import mapping as mpg
from repro.core import monolithic as mono
from repro.core import params as ps
from repro.core import placement as pm
from repro.core import traffic as tr
from repro.core import workload as wl
from repro.optimizer import archive as ar
from repro.optimizer import evo as evo_mod
from repro.optimizer import portfolio
from repro.rl import ppo
from repro.sa import annealing as sa
from repro.surrogate import ranker as srk
from repro.telemetry import counters as tl
from repro.telemetry import journal as tj

# (alpha, beta, gamma) objective trade-offs swept by default (Eq. 17):
# balanced (paper default), throughput-first, cost-first, energy-aware.
DEFAULT_WEIGHT_GRID: Tuple[Tuple[float, float, float], ...] = (
    (1.0, 1.0, 0.1),
    (2.0, 0.5, 0.1),
    (0.5, 2.0, 0.1),
)

# Under the default calibration most winners are compute-bound and
# latency is amortized over reuse^2, so the placement channels (NoP
# congestion, per-hop energy) barely move the reward. The
# placement-sensitive regime charges the paper-literal Eq.-13 operand
# traffic (no systolic reuse amortization) and per-operand-row latency
# (amortization exponent 1), which is where explicit placement
# co-optimization actually bites (ROADMAP PR-2 follow-up).
PLACEMENT_SENSITIVE_HW = dataclasses.replace(
    hw.DEFAULT_HW, comm_reuse_systolic=False, latency_amort_exp=1.0)

HW_PRESETS = {
    "default": hw.DEFAULT_HW,
    "placement-sensitive": PLACEMENT_SENSITIVE_HW,
}


def with_hw_preset(cfg: "SuiteConfig", preset: str) -> "SuiteConfig":
    """Re-key a suite config onto one of the named HW presets."""
    if preset not in HW_PRESETS:
        raise ValueError(f"unknown HW preset {preset!r}; "
                         f"choose from {sorted(HW_PRESETS)}")
    return dataclasses.replace(
        cfg, env=dataclasses.replace(cfg.env, hw=HW_PRESETS[preset]))


@dataclasses.dataclass(frozen=True)
class SuiteConfig:
    """One scenario-suite run: workloads x weight grid x portfolio scale."""

    workloads: Tuple[str, ...] = ("mlperf",)
    weight_grid: Tuple[Tuple[float, float, float], ...] = DEFAULT_WEIGHT_GRID
    n_sa: int = 8
    n_rl: int = 4
    n_evo: int = 1                  # GA islands per scenario (third arm)
    refine: bool = True
    max_refine_sweeps: int = 2
    placement_refine: bool = True
    # capacity of the suite-level cross-arm, cross-scenario Pareto archive
    # (normalized objective space; see run_suite)
    archive_capacity: int = 128
    # one extra lockstep design sweep under the refined floorplans (the
    # PlacementEvalCache-backed placement stage feeds its winners back
    # into portfolio.coordinate_refine_batch via `placements=`)
    post_placement_sweep: bool = True
    # NOTE: placement_sa must precede the `sa` field — that field shadows
    # the annealing module for later annotations in this class body.
    # 4x the pre-delta 2000 iters: delta evaluation made steps cheap
    # (PlacementSAConfig.delta_eval), spend the recovered budget on
    # coverage (ROADMAP PR-3 follow-up).
    placement_sa: sa.PlacementSAConfig = sa.PlacementSAConfig(n_iters=8_000)
    # mapping co-exploration stage (core/mapping.py): anneal each winner's
    # (placement, mapping) jointly, seeded from the placement-refined
    # floorplan, under fold_in(key, 8) — the SA/RL/GA/placement/surrogate
    # key streams are untouched. The mapped candidate replaces a winner
    # only when it beats it, so enabling the stage never lowers any
    # scenario's reward (the ci.sh gate holds by construction). False
    # (default) skips the stage entirely — bit-exact with the
    # three-layer suite.
    mapping_refine: bool = False
    # SA config for the mapping stage; None derives it from placement_sa
    # (p_mapping=0.25, phase_schedule off — it is mutually exclusive
    # with mapping moves).
    placement_sa_mapping: sa.PlacementSAConfig = None
    sa: sa.SAConfig = sa.SAConfig(n_iters=20_000)
    rl: ppo.PPOConfig = ppo.PPOConfig(n_steps=128, n_envs=4)
    rl_timesteps: int = 128 * 4 * 4
    evo: evo_mod.EvoConfig = evo_mod.EvoConfig(pop_size=32,
                                               n_generations=40)
    env: chipenv.EnvConfig = chipenv.EnvConfig()
    # surrogate front-filter arm (None disables; surrogate/ranker.py): a
    # learned ranker proposes candidates that are always analytically
    # re-scored before competing. Runs under fold_in(key, 7), so the
    # SA/RL/GA/placement key streams are untouched and enabling it only
    # grows the candidate + refine sets (never-worse by construction).
    surrogate: srk.SurrogateConfig = None
    # periodic surrogate re-fit cadence (scenarios per re-fit; 0 = off =
    # single fit, bit-exact with the PR-6 stage). With refits on, the
    # stage folds each chunk's analytic re-scores back into the eval
    # dataset before the next fit — the ROADMAP item-1 follow-up of
    # training on the suite's own tapped eval traffic during long runs.
    surrogate_refit_every: int = 0
    # traffic trace (core/traffic.py): a preset name ('flat', 'diurnal',
    # 'bursty', 'multi-tenant'), a traffic.TraceConfig, or None (point
    # scenarios, bit-exact with the pre-trace suite). When set, every
    # scenario is scored against its sampled serving-load distribution
    # (SLO attainment + load-proportional energy) by all arms, and the
    # suite archive gains SLO attainment as a fourth objective.
    trace: object = None


SMOKE_SUITE = SuiteConfig(
    n_sa=2, n_rl=2, n_evo=1,
    sa=sa.SAConfig(n_iters=2_000),
    rl=ppo.PPOConfig(n_steps=32, n_envs=2, batch_size=32),
    rl_timesteps=32 * 2 * 2,
    evo=evo_mod.EvoConfig(pop_size=8, n_generations=6, archive_capacity=32),
    refine=True, max_refine_sweeps=1,
    placement_sa=sa.PlacementSAConfig(n_iters=500),
)

# the same grids re-keyed onto the regime where placement co-optimization
# has leverage (see PLACEMENT_SENSITIVE_HW above)
PLACEMENT_SENSITIVE_SUITE = with_hw_preset(SuiteConfig(), "placement-sensitive")
PLACEMENT_SENSITIVE_SMOKE = with_hw_preset(SMOKE_SUITE, "placement-sensitive")

# the placement-sensitive grids with the fourth (mapping/dataflow) layer
# co-annealed on top of the refined floorplans — the regime where
# layer-pipelined forwarding and tile-size trades have leverage
MAPPING_SUITE = dataclasses.replace(PLACEMENT_SENSITIVE_SUITE,
                                    mapping_refine=True)
MAPPING_SMOKE = dataclasses.replace(PLACEMENT_SENSITIVE_SMOKE,
                                    mapping_refine=True)


@dataclasses.dataclass(frozen=True)
class ScenarioOutcome:
    """Winner of one (workload, reward-weight) scenario."""

    name: str
    workload_name: str
    weights: Tuple[float, float, float]
    best_flat: np.ndarray           # (14,) int32 design indices
    best_reward: float              # with the refined placement (if any)
    source: str   # 'sa'|'rl'|'evo'|'surrogate'|'refined'|'placement'|'codesign'
    tasks_per_sec: float
    energy_per_task_j: float
    total_cost: float
    eff_tops: float
    # explicit-placement co-optimization (core/placement.py)
    reward_canonical: float = None  # winner under the Fig.-4 floorplan
    placement_cells: np.ndarray = None   # (128,) grid cell per slot
    placement_hbm_ij: np.ndarray = None  # (6, 2) HBM anchor coords
    # mapping/dataflow co-exploration (core/mapping.py); None when the
    # mapping stage was off. reward_premapping is the winner before the
    # stage ran — best_reward - reward_premapping is the honest mapping
    # gain (0.0 when the canonical dataflow stayed on top).
    reward_premapping: float = None
    mapping_stage: np.ndarray = None     # (128,) pipeline stage per slot
    mapping_tile: np.ndarray = None      # (4,) tile index per layer group
    # traffic-trace channels (None on point-scenario suites)
    slo_attainment: float = None    # dt-weighted fraction of steps in SLO
    p99_latency_s: float = None     # worst trace step's proxy p99 sojourn


@dataclasses.dataclass(frozen=True)
class SuiteResult:
    outcomes: List[ScenarioOutcome]
    pareto: List[int]               # indices into outcomes, non-dominated
    wall_time_s: float
    # frontier after normalizing tasks/s and J/task by each workload's
    # monolithic baseline (the raw frontier favors light workloads)
    pareto_normalized: List[int] = dataclasses.field(default_factory=list)
    # cross-arm, cross-scenario Pareto archive (optimizer/archive.py):
    # every candidate any arm produced competes in monolithic-normalized
    # objective space; both frontier index lists above are archive-backed
    archive: ar.Archive = None
    hypervolume: float = 0.0        # archive HV w.r.t. its nadir ref


def build_scenarios(cfg: SuiteConfig) -> Tuple[List[str], List[str],
                                               cm.Scenario]:
    """Resolve the grid -> (scenario names, workload names, batched Scenario).

    With ``cfg.trace`` set the stacked batch is run through
    :func:`repro.core.traffic.apply_trace` — every scenario gets its own
    sampled serving-load trace (keyed by the trace config's seed and the
    scenario index, independent of the optimizer key streams).
    """
    wl_names, workloads = wl.resolve(cfg.workloads)
    names, wnames, scalars = [], [], []
    tcfg = tr.resolve_trace(cfg.trace)
    tag = "" if tcfg is None else f"|trace={tcfg.kind}"
    for wname, workload in zip(wl_names, workloads):
        for a, b, g in cfg.weight_grid:
            names.append(f"{wname}|a={a:g},b={b:g},g={g:g}{tag}")
            wnames.append(wname)
            scalars.append(cm.Scenario(workload=workload,
                                       weights=cm.make_weights(a, b, g)))
    scenarios = cm.stack_scenarios(scalars)
    if tcfg is not None:
        scenarios = tr.apply_trace(scenarios, tcfg, cfg.env.hw)
    return names, wnames, scenarios


def pareto_indices(points: np.ndarray,
                   maximize: Sequence[bool]) -> List[int]:
    """Indices of the non-dominated rows of ``points`` (S, D)."""
    pts = np.asarray(points, np.float64).copy()
    for d, mx in enumerate(maximize):
        if not mx:
            pts[:, d] = -pts[:, d]
    out = []
    for i in range(pts.shape[0]):
        dominated = np.any(
            np.all(pts >= pts[i], axis=1) & np.any(pts > pts[i], axis=1))
        if not dominated:
            out.append(i)
    return out


def run_suite(key, cfg: SuiteConfig = SuiteConfig(),
              verbose: bool = False, journal=None) -> SuiteResult:
    """Portfolio-optimize every scenario in the grid; every stage vectorized.

    ``journal`` (a :class:`repro.telemetry.journal.Journal`, optional)
    receives one span per suite stage — the arms under their key-stream
    labels, refinement, placement, mapping — plus per-arm convergence
    events and the suite-archive hypervolume. While the suite runs the
    journal is also installed as the ambient journal, so deep call sites
    (the surrogate ranker's refit loop, ``profile.compile_timer``, the
    adaptive placement-SA schedule) emit into the same stream. With
    ``journal=None`` the ambient journal (if any) is used; with neither,
    every emit is a no-op.

    The SA arm runs (S scenarios x n_sa chains) as one XLA program, the
    RL arm (S scenarios x n_rl agents) as another, the GA arm
    (S scenarios x n_evo islands, archive riding the generation scan) as
    a third; coordinate refinement sweeps every arm's per-scenario best
    in lockstep (one jitted program per sweep — the refine set grows
    with the arms, so enabling an arm never lowers any scenario's
    winner); the placement-refinement stage anneals all S winners'
    floorplans as one vmapped program. No host loop per winner anywhere.

    Every candidate every arm produced — plus the GA islands' own live
    archives and the final (placement-refined) winners — feeds one
    suite-level :class:`repro.optimizer.archive.Archive` in
    monolithic-normalized objective space; the reported ``pareto`` /
    ``pareto_normalized`` index lists are read back from archive
    membership rather than a host-side filter.
    """
    if journal is None:
        journal = tj.current()
    jr = tj.or_null(journal)
    with tj.use(journal):
        return _run_suite(jr, key, cfg, verbose)


def _run_suite(jr, key, cfg: SuiteConfig, verbose: bool) -> SuiteResult:
    t0 = time.time()
    names, wnames, scenarios = build_scenarios(cfg)
    n_scen = len(names)
    jr.event("suite_config", n_scenarios=n_scen, scenarios=names,
             workloads=list(cfg.workloads), n_sa=cfg.n_sa, n_rl=cfg.n_rl,
             n_evo=cfg.n_evo, surrogate=cfg.surrogate is not None,
             mapping_refine=cfg.mapping_refine,
             trace=None if cfg.trace is None else str(cfg.trace))
    k_sa, k_rl, k_pl = jax.random.split(jnp.asarray(key), 3)
    # folded, not split: the SA/RL streams must not depend on n_evo
    k_evo = jax.random.fold_in(jnp.asarray(key), 4)

    cand_rewards = []                                   # each (S, K_arm)
    cand_flats = []                                     # each (S, K_arm, 14)
    arm_slices = []                                     # (name, lo, hi)
    evo_archives = None
    # per-island leaves are (S, n_islands, T); reduce the island axis for
    # the journal's per-scenario curves (2-D leaves pass through)
    def _over_islands(a, red):
        a = np.asarray(a)
        return red(a, axis=1) if a.ndim >= 3 else a

    if cfg.n_sa > 0:
        with jr.span("arm:sa", key_stream="split(key, 3)[0]",
                     n_chains=cfg.n_sa, n_iters=cfg.sa.n_iters):
            sa_res = sa.run_scenario_population(
                k_sa, scenarios, cfg.n_sa, cfg.env, cfg.sa)
            jr.event("arm_convergence", arm="sa",
                     best=np.asarray(sa_res.best_reward).max(axis=1),
                     curve=_over_islands(sa_res.history, np.max))
        cand_rewards.append(np.asarray(sa_res.best_reward))
        cand_flats.append(np.asarray(ps.to_flat(sa_res.best_design)))
        arm_slices.append(("sa", 0, cfg.n_sa))
    if cfg.n_rl > 0:
        with jr.span("arm:rl", key_stream="split(key, 3)[1]",
                     n_agents=cfg.n_rl, timesteps=cfg.rl_timesteps):
            rl_res = ppo.train_scenario_population(
                k_rl, scenarios, cfg.n_rl, cfg.env, cfg.rl,
                total_timesteps=cfg.rl_timesteps)
            jr.event("arm_convergence", arm="rl",
                     best=np.asarray(rl_res.best_reward).max(axis=1),
                     curve=_over_islands(rl_res.log.best_reward, np.max))
            if rl_res.telemetry is not None:
                st = rl_res.telemetry
                jr.event("ppo_stats",
                         entropy=_over_islands(st.entropy, np.mean),
                         approx_kl=_over_islands(st.approx_kl, np.mean),
                         clip_frac=_over_islands(st.clip_frac, np.mean),
                         return_mean=_over_islands(st.return_mean, np.mean))
        cand_rewards.append(np.asarray(rl_res.best_reward))
        cand_flats.append(np.asarray(ps.to_flat(rl_res.best_design)))
        lo = arm_slices[-1][2] if arm_slices else 0
        arm_slices.append(("rl", lo, lo + cfg.n_rl))
    if cfg.n_evo > 0:
        with jr.span("arm:evo", key_stream="fold_in(key, 4)",
                     n_islands=cfg.n_evo,
                     n_generations=cfg.evo.n_generations):
            evo_res = evo_mod.evolve_scenario_population(
                k_evo, scenarios, cfg.n_evo, cfg.env, cfg.evo)
            jr.event("arm_convergence", arm="evo",
                     best=np.asarray(evo_res.best_reward).max(axis=1),
                     curve=_over_islands(evo_res.history, np.max))
            if evo_res.telemetry is not None:
                st = evo_res.telemetry
                jr.event("evo_stats",
                         diversity=_over_islands(st.diversity, np.mean),
                         archive_hv=_over_islands(st.archive_hv, np.max),
                         archive_n=_over_islands(st.archive_n, np.max),
                         inserts=_over_islands(st.archive_inserts, np.sum),
                         evicts=_over_islands(st.archive_evicts, np.sum))
        cand_rewards.append(np.asarray(evo_res.best_reward))
        cand_flats.append(np.asarray(ps.to_flat(evo_res.best_design)))
        evo_archives = evo_res.archive     # leaves (S, n_evo, C, ...)
        lo = arm_slices[-1][2] if arm_slices else 0
        arm_slices.append(("evo", lo, lo + cfg.n_evo))
    if cfg.surrogate is not None:
        with jr.span("surrogate", key_stream="fold_in(key, 7)",
                     mode=cfg.surrogate.mode,
                     refit_every=cfg.surrogate_refit_every):
            sur_stage = srk.run_stage(
                jax.random.fold_in(jnp.asarray(key), 7), scenarios,
                cfg.surrogate, cfg.env.hw,
                nop_fidelity=cfg.env.nop_fidelity,
                refit_every=cfg.surrogate_refit_every)
        cand_rewards.append(np.asarray(sur_stage.cand_rewards))
        cand_flats.append(np.asarray(sur_stage.cand_flats))
        lo = arm_slices[-1][2] if arm_slices else 0
        arm_slices.append(
            ("surrogate", lo, lo + sur_stage.cand_rewards.shape[1]))
    if not cand_rewards:
        raise ValueError("SuiteConfig needs n_sa, n_rl or n_evo > 0")

    rewards = np.concatenate(cand_rewards, axis=1)      # (S, K)
    flats = np.concatenate(cand_flats, axis=1)          # (S, K, 14)

    # per-arm, per-scenario argmax (host, trivial) ...
    n_arms = len(arm_slices)
    rows = np.arange(n_scen)
    arm_flats = np.stack(
        [flats[rows, lo + np.argmax(rewards[:, lo:hi], axis=1)]
         for _, lo, hi in arm_slices], axis=1)          # (S, A, 14)
    arm_rewards = np.stack(
        [rewards[:, lo:hi].max(axis=1) for _, lo, hi in arm_slices],
        axis=1).astype(np.float64)                      # (S, A)
    arm_names = [nm for nm, _, _ in arm_slices]

    top_arm = np.argmax(arm_rewards, axis=1)
    winner_flats = arm_flats[rows, top_arm].astype(np.int32)
    winner_rewards = arm_rewards[rows, top_arm]
    sources = [arm_names[a] for a in top_arm]

    # ... then ONE batched coordinate sweep over every arm's best for
    # every scenario at a time ((S*A) rows in lockstep)
    refined_flats = np.zeros((n_scen, 0, ps.N_PARAMS), np.int32)
    if cfg.refine:
        rep_scen = jax.tree_util.tree_map(
            lambda x: jnp.repeat(x, n_arms, axis=0), scenarios)
        with jr.span("refine", rows=n_scen * n_arms,
                     sweeps=cfg.max_refine_sweeps):
            re_flats, re_r = portfolio.coordinate_refine_batch(
                arm_flats.reshape(n_scen * n_arms, ps.N_PARAMS), rep_scen,
                cfg.env, cfg.max_refine_sweeps)
        refined_flats = re_flats.reshape(n_scen, n_arms, ps.N_PARAMS)
        re_r = np.asarray(re_r, np.float64).reshape(n_scen, n_arms)
        for s in range(n_scen):
            j = int(np.argmax(re_r[s]))
            if re_r[s, j] > winner_rewards[s] + 1e-6:
                winner_flats[s] = refined_flats[s, j]
                winner_rewards[s] = re_r[s, j]
                sources[s] = "refined"

    dp_batch = ps.from_flat(jnp.asarray(winner_flats))

    # placement-refinement stage: anneal all S winners' floorplans in one
    # vmapped program (swap/relocate/re-anchor moves, scenario axis; the
    # SA carries a PlacementEvalCache so every move is delta-evaluated)
    placements = None
    canonical_rewards = winner_rewards.copy()
    if cfg.placement_refine:
        with jr.span("placement", key_stream="split(key, 3)[2]",
                     n_iters=cfg.placement_sa.n_iters):
            pres = sa.refine_placement_scenarios(
                k_pl, dp_batch, scenarios, cfg.env, cfg.placement_sa)
            if pres.telemetry is not None:
                for s in range(n_scen):
                    jr.event("sa_accept", stage="placement",
                             scenario=names[s],
                             **tl.summarize_sa(jax.tree_util.tree_map(
                                 lambda x, s=s: x[s], pres.telemetry)))
        placements = pres.best_placement
        canonical_rewards = np.asarray(pres.canonical_reward, np.float64)
        placed_rewards = np.asarray(pres.best_reward, np.float64)
        for s in range(n_scen):
            if placed_rewards[s] > winner_rewards[s] + 1e-6:
                sources[s] = "placement"
            winner_rewards[s] = max(winner_rewards[s], placed_rewards[s])

        # feed the refined floorplans back into the design grid: one more
        # lockstep coordinate sweep scoring every Table-1 candidate WITH
        # its scenario's annealed placement (design<->placement co-descent)
        if cfg.refine and cfg.post_placement_sweep:
            with jr.span("refine:post_placement", rows=n_scen,
                         sweeps=cfg.max_refine_sweeps):
                re_flats, re_r = portfolio.coordinate_refine_batch(
                    winner_flats, scenarios, cfg.env,
                    cfg.max_refine_sweeps, placements=placements)
            changed = False
            for s in range(n_scen):
                if re_r[s] > winner_rewards[s] + 1e-6:
                    winner_flats[s] = re_flats[s]
                    winner_rewards[s] = re_r[s]
                    sources[s] = "codesign"
                    changed = True
            if changed:
                dp_batch = ps.from_flat(jnp.asarray(winner_flats))
                # canonical reference tracks the (possibly new) designs;
                # a swept design's annealed-for-the-old-design floorplan
                # may score below its own canonical — for those rows the
                # canonical floorplan IS the best known placement, so
                # swap it in (keeps best >= canonical AND the reported
                # metrics/placement consistent with the reported reward)
                canonical_rewards = np.asarray(
                    cm.evaluate_scenarios(dp_batch, scenarios,
                                          cfg.env.hw).reward, np.float64)
                v_new = ps.decode(dp_batch)
                n_pos_new = cm.footprint_positions(v_new)
                m_new, n_new = cm.mesh_dims(n_pos_new)
                canon_plc = pm.canonical(m_new, n_new, v_new.hbm_mask,
                                         v_new.arch_type)
                use_canon = jnp.asarray(
                    canonical_rewards >= winner_rewards)
                placements = jax.tree_util.tree_map(
                    lambda c, p: jnp.where(
                        use_canon.reshape((-1,) + (1,) * (p.ndim - 1)),
                        c, p), canon_plc, placements)
                winner_rewards = np.maximum(winner_rewards,
                                            canonical_rewards)

    # mapping/dataflow stage: co-anneal (placement, mapping) for all S
    # winners in one vmapped program, seeded from the refined floorplans.
    # Swap-in only-if-better per scenario: rows the canonical dataflow
    # still wins keep their placement AND the canonical mapping (an exact
    # no-op in the cost model), so reported metrics always match the
    # reported reward and the stage can only raise winners.
    mappings = None
    premap_rewards = None
    if cfg.mapping_refine:
        if not cfg.placement_refine:
            raise ValueError("mapping_refine requires placement_refine "
                             "(the stage anneals on top of the refined "
                             "floorplans)")
        premap_rewards = winner_rewards.copy()
        map_sa = cfg.placement_sa_mapping
        if map_sa is None:
            map_sa = dataclasses.replace(cfg.placement_sa, p_mapping=0.25,
                                         phase_schedule=None)
        k_map = jax.random.fold_in(jnp.asarray(key), 8)
        map_keys = jax.random.split(k_map, n_scen)
        with jr.span("mapping", key_stream="fold_in(key, 8)",
                     n_iters=map_sa.n_iters):
            mres = jax.jit(jax.vmap(
                lambda k, d, s, p: sa.refine_placement(
                    k, d, cfg.env, map_sa, s, init_placement=p)))(
                        map_keys, dp_batch, scenarios, placements)
        map_rewards = np.asarray(mres.best_reward, np.float64)
        better = map_rewards > winner_rewards + 1e-6
        for s in range(n_scen):
            if better[s]:
                winner_rewards[s] = map_rewards[s]
                sources[s] = "mapping"
        sel = jnp.asarray(better)
        placements = jax.tree_util.tree_map(
            lambda m, p: jnp.where(
                sel.reshape((-1,) + (1,) * (p.ndim - 1)), m, p),
            mres.best_placement, placements)
        mappings = jax.tree_util.tree_map(
            lambda m, c: jnp.where(
                sel.reshape((-1,) + (1,) * (c.ndim - 1)), m, c),
            mres.best_mapping, mpg.canonical(batch_shape=(n_scen,)))

    if verbose:
        for s in range(n_scen):
            print(f"  [suite] {names[s]}: reward={winner_rewards[s]:.1f} "
                  f"({sources[s]})")

    # scenario-batched PPAC evaluation of all winners in one program
    # (traced suites go through the TraceMetrics twin to also read the
    # SLO / p99 channels into the outcomes and the fourth objective)
    traced = scenarios.trace is not None
    win_slo = win_p99 = None
    with jr.span("evaluate", traced=traced):
        if traced:
            tm = cm.evaluate_trace_scenarios(dp_batch, scenarios,
                                             cfg.env.hw,
                                             placements=placements,
                                             mappings=mappings)
            metrics = tm.metrics
            win_slo = np.asarray(tm.slo_attainment, np.float64)   # (S,)
            win_p99 = np.asarray(jnp.max(tm.p99_latency_s, axis=1),
                                 np.float64)                      # (S,)
        else:
            metrics = cm.evaluate_scenarios(dp_batch, scenarios,
                                            cfg.env.hw,
                                            placements=placements,
                                            mappings=mappings)

    outcomes = []
    for s in range(n_scen):
        outcomes.append(ScenarioOutcome(
            name=names[s], workload_name=wnames[s],
            weights=(float(scenarios.weights.alpha[s]),
                     float(scenarios.weights.beta[s]),
                     float(scenarios.weights.gamma[s])),
            best_flat=winner_flats[s],
            best_reward=float(winner_rewards[s]),
            source=sources[s],
            tasks_per_sec=float(metrics.tasks_per_sec[s]),
            energy_per_task_j=float(metrics.energy_per_task_j[s]),
            total_cost=float(metrics.total_cost[s]),
            eff_tops=float(metrics.eff_tops[s]),
            reward_canonical=float(canonical_rewards[s]),
            placement_cells=(None if placements is None else
                             np.asarray(placements.chiplet_cell[s])),
            placement_hbm_ij=(None if placements is None else
                              np.asarray(placements.hbm_ij[s])),
            reward_premapping=(None if premap_rewards is None
                               else float(premap_rewards[s])),
            mapping_stage=(None if mappings is None else
                           np.asarray(mappings.stage[s])),
            mapping_tile=(None if mappings is None else
                          np.asarray(mappings.tile_idx[s])),
            slo_attainment=(None if win_slo is None
                            else float(win_slo[s])),
            p99_latency_s=(None if win_p99 is None
                           else float(win_p99[s])),
        ))

    triples = np.stack([
        [o.tasks_per_sec, o.energy_per_task_j, o.total_cost]
        for o in outcomes])
    n_obj = 3
    if traced:
        # SLO attainment joins the winners' / archive's objective space
        triples = np.concatenate([triples, win_slo[:, None]], axis=1)
        n_obj = 4

    # per-workload normalization: tasks/s and J/task relative to the
    # iso-node monolithic baseline evaluated on the *same* workload, so
    # heavy workloads compete on speedup rather than raw task rate
    mono_m = jax.vmap(lambda w: mono.evaluate(w, cfg.env.hw))(
        scenarios.workload)
    mono_t = np.asarray(mono_m.tasks_per_sec, np.float64)
    mono_j = np.asarray(mono_m.energy_per_task_j, np.float64)
    if traced:
        # traced workload leaves carry (S, T): dt-weight the baseline
        # over the trace so normalization matches the aggregated metrics
        dt = np.asarray(scenarios.trace.dt, np.float64)           # (S, T)
        mono_t = np.sum(dt * mono_t, axis=1)
        mono_j = np.sum(dt * mono_j, axis=1)
    mono_t = np.maximum(mono_t, 1e-30)
    mono_j = np.maximum(mono_j, 1e-30)
    norm = triples.copy()
    norm[:, 0] = triples[:, 0] / mono_t
    norm[:, 1] = triples[:, 1] / mono_j

    # archive-backed frontiers over the S winners: insert into a fresh
    # fixed-capacity store, read membership back (the non-domination test
    # lives in one code path, optimizer/archive.py). The archive collapses
    # exact-duplicate points to one entry; the report wants every tied
    # scenario listed, so re-expand ties against the surviving points.
    def _front(pts: np.ndarray) -> List[int]:
        a = ar.insert_batch(
            ar.empty(n_scen, n_obj=n_obj), jnp.asarray(pts, jnp.float32),
            jnp.asarray(winner_flats),
            reward=jnp.asarray(winner_rewards, jnp.float32),
            payload=jnp.arange(n_scen, dtype=jnp.int32))
        surviving = ar.contents(a)["points"]            # (F, n_obj) f32
        pts32 = np.asarray(pts, np.float32)
        return [s for s in range(n_scen)
                if (pts32[s] == surviving).all(axis=1).any()]

    pareto = _front(triples)
    pareto_norm = _front(norm)

    # suite-level cross-arm archive (normalized space): every candidate
    # every arm produced, every point the GA islands archived, and the
    # final winners, competing for one bounded non-dominated store
    suite_arc = ar.empty(cfg.archive_capacity, n_obj=n_obj)
    cand_all = np.concatenate([flats, refined_flats], axis=1)  # (S, K', 14)
    n_cand = cand_all.shape[1]
    cand_dp = ps.from_flat(jnp.asarray(cand_all, jnp.int32))
    if traced:
        cand_tm = cm.evaluate_trace_scenarios(cand_dp, scenarios,
                                              cfg.env.hw)
        cand_m = cand_tm.metrics
        cand_slo = np.asarray(cand_tm.slo_attainment, np.float64)
    else:
        cand_m = cm.evaluate_scenarios(cand_dp, scenarios, cfg.env.hw)
    cand_cols = [
        np.asarray(cand_m.tasks_per_sec, np.float64) / mono_t[:, None],
        np.asarray(cand_m.energy_per_task_j, np.float64) / mono_j[:, None],
        np.asarray(cand_m.total_cost, np.float64)]
    if traced:
        cand_cols.append(cand_slo)
    cand_pts = np.stack(cand_cols, axis=-1)
    cand_rw = np.asarray(cand_m.reward, np.float64)
    suite_arc = ar.insert_batch(
        suite_arc, jnp.asarray(cand_pts.reshape(-1, n_obj), jnp.float32),
        jnp.asarray(cand_all.reshape(-1, ps.N_PARAMS)),
        reward=jnp.asarray(cand_rw.reshape(-1), jnp.float32),
        payload=jnp.repeat(jnp.arange(n_scen, dtype=jnp.int32), n_cand))
    if evo_archives is not None:
        # zero the invalid sentinel rows before normalizing (dividing the
        # float32-max sentinel can overflow; the rows stay masked anyway)
        pts = np.where(np.asarray(evo_archives.valid)[..., None],
                       np.asarray(evo_archives.points, np.float64), 0.0)
        pts[..., 0] /= mono_t[:, None, None]
        pts[..., 1] /= mono_j[:, None, None]
        n_isl, n_arc = pts.shape[1], pts.shape[2]
        g_dim = evo_archives.flats.shape[-1]
        evo_flats = np.asarray(evo_archives.flats).reshape(
            -1, g_dim)[:, : ps.N_PARAMS]
        if traced:
            # SLO column via re-evaluation of the Table-1 genes under the
            # canonical floorplan (placement genes, if any, are dropped —
            # queueing only sees throughput, where that is a small effect)
            evo_tm = cm.evaluate_trace_scenarios(
                ps.from_flat(jnp.asarray(
                    evo_flats.reshape(n_scen, n_isl * n_arc, ps.N_PARAMS),
                    jnp.int32)), scenarios, cfg.env.hw)
            evo_slo = np.asarray(evo_tm.slo_attainment,
                                 np.float64).reshape(pts.shape[:-1])
            pts = np.concatenate([pts, evo_slo[..., None]], axis=-1)
        suite_arc = ar.insert_batch(
            suite_arc, jnp.asarray(pts.reshape(-1, n_obj), jnp.float32),
            jnp.asarray(evo_flats),
            reward=jnp.asarray(evo_archives.reward).reshape(-1),
            payload=jnp.repeat(jnp.arange(n_scen, dtype=jnp.int32),
                               n_isl * n_arc),
            valid=jnp.asarray(evo_archives.valid).reshape(-1))
    suite_arc = ar.insert_batch(
        suite_arc, jnp.asarray(norm, jnp.float32),
        jnp.asarray(winner_flats),
        reward=jnp.asarray(winner_rewards, jnp.float32),
        payload=jnp.arange(n_scen, dtype=jnp.int32))
    hv = float(ar.hypervolume(
        suite_arc, ar.nadir_ref(suite_arc.points, suite_arc.valid)))

    jr.event("suite_archive", hypervolume=hv,
             n_points=int(suite_arc.n_valid),
             capacity=cfg.archive_capacity)
    jr.event("suite_end", wall_time_s=time.time() - t0,
             winners=[{"scenario": names[s],
                       "reward": float(winner_rewards[s]),
                       "source": sources[s]} for s in range(n_scen)])

    return SuiteResult(outcomes=outcomes, pareto=pareto,
                       wall_time_s=time.time() - t0,
                       pareto_normalized=pareto_norm,
                       archive=suite_arc, hypervolume=hv)


def format_report(res: SuiteResult) -> str:
    """Human-readable per-scenario table + both Pareto frontiers."""
    lines = [f"{'scenario':<43} {'reward':>9} {'plc-gain':>9} {'tasks/s':>12} "
             f"{'J/task':>10} {'cost':>9} {'src':>9}"]
    for i, o in enumerate(res.outcomes):
        star = "*" if i in res.pareto else " "
        plus = "+" if i in res.pareto_normalized else " "
        gain = (0.0 if o.reward_canonical is None
                else o.best_reward - o.reward_canonical)
        slo = ("" if o.slo_attainment is None
               else f" slo={o.slo_attainment:.2f}"
                    f" p99={o.p99_latency_s:.2e}s")
        mgain = ("" if o.reward_premapping is None
                 else f" map+={o.best_reward - o.reward_premapping:.3f}")
        lines.append(
            f"{star}{plus}{o.name:<41} {o.best_reward:>9.1f} {gain:>9.3f} "
            f"{o.tasks_per_sec:>12,.0f} {o.energy_per_task_j:>10.2e} "
            f"{o.total_cost:>9.0f} {o.source:>9}{slo}{mgain}")
    lines.append(f"\nPareto frontier (raw tasks/s vs J/task vs cost): "
                 f"{len(res.pareto)}/{len(res.outcomes)} scenarios (*); "
                 f"monolithic-normalized frontier: "
                 f"{len(res.pareto_normalized)}/{len(res.outcomes)} (+); "
                 f"suite wall-time {res.wall_time_s:.1f}s")
    if res.archive is not None:
        lines.append(f"Cross-arm archive: {int(res.archive.n_valid)} "
                     f"non-dominated points (capacity "
                     f"{res.archive.capacity}), hypervolume "
                     f"{res.hypervolume:.4g} (normalized space, nadir ref)")
    return "\n".join(lines)


def to_json(res: SuiteResult) -> Dict:
    """JSON-serializable summary (per-scenario winners + frontiers)."""
    arc = None
    if res.archive is not None:
        c = ar.contents(res.archive)
        arc = {
            "capacity": res.archive.capacity,
            "n": int(c["points"].shape[0]),
            "hypervolume": res.hypervolume,
            # rows: (speedup vs monolithic, J/task ratio, cost $[, SLO
            # attainment when the suite ran under a traffic trace])
            "points": [[float(x) for x in p] for p in c["points"]],
            "reward": [float(r) for r in c["reward"]],
            "scenario": [int(p) for p in c["payload"]],
            "designs": [[int(x) for x in f] for f in c["flats"]],
        }
    return {
        "wall_time_s": res.wall_time_s,
        "pareto": list(res.pareto),
        "pareto_normalized": list(res.pareto_normalized),
        "hypervolume": res.hypervolume,
        "archive": arc,
        "scenarios": [{
            "name": o.name,
            "workload": o.workload_name,
            "weights": list(o.weights),
            "design": [int(x) for x in o.best_flat],
            "reward": o.best_reward,
            "reward_canonical": o.reward_canonical,
            "source": o.source,
            "tasks_per_sec": o.tasks_per_sec,
            "energy_per_task_j": o.energy_per_task_j,
            "total_cost": o.total_cost,
            "eff_tops": o.eff_tops,
            "slo_attainment": o.slo_attainment,
            "p99_latency_s": o.p99_latency_s,
            "placement_cells": (None if o.placement_cells is None else
                                [int(c) for c in o.placement_cells]),
            "placement_hbm_ij": (None if o.placement_hbm_ij is None else
                                 [[float(x) for x in ij]
                                  for ij in o.placement_hbm_ij]),
            "reward_premapping": o.reward_premapping,
            "mapping_stage": (None if o.mapping_stage is None else
                              [int(x) for x in o.mapping_stage]),
            "mapping_tile": (None if o.mapping_tile is None else
                             [int(x) for x in o.mapping_tile]),
        } for o in res.outcomes],
    }


def save_json(res: SuiteResult, path: str) -> None:
    with open(path, "w") as f:
        json.dump(to_json(res), f, indent=2)
