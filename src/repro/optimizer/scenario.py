"""Scenario-batched design-space exploration (ScenarioSuite).

The paper optimizes one accelerator for one workload under one reward
weighting. Production co-design (cf. Monad's multi-workload specialization,
Gemini's joint co-exploration) needs the *grid*: every workload in the
registry x every objective trade-off. This module runs the Algorithm-1
portfolio across a (workload x reward-weight) scenario grid where both
arms — the SA chains and the PPO agents — execute as scenario-vmapped XLA
programs, then reports per-scenario winners plus the cross-scenario Pareto
frontier over (throughput, energy/task, cost).

    PYTHONPATH=src python -m repro.launch.train --arch scenario-suite \
        --workloads mlperf --smoke
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel as cm
from repro.core import env as chipenv
from repro.core import hw_constants as hw
from repro.core import monolithic as mono
from repro.core import params as ps
from repro.core import placement as pm
from repro.core import workload as wl
from repro.optimizer import portfolio
from repro.rl import ppo
from repro.sa import annealing as sa

# (alpha, beta, gamma) objective trade-offs swept by default (Eq. 17):
# balanced (paper default), throughput-first, cost-first, energy-aware.
DEFAULT_WEIGHT_GRID: Tuple[Tuple[float, float, float], ...] = (
    (1.0, 1.0, 0.1),
    (2.0, 0.5, 0.1),
    (0.5, 2.0, 0.1),
)

# Under the default calibration most winners are compute-bound and
# latency is amortized over reuse^2, so the placement channels (NoP
# congestion, per-hop energy) barely move the reward. The
# placement-sensitive regime charges the paper-literal Eq.-13 operand
# traffic (no systolic reuse amortization) and per-operand-row latency
# (amortization exponent 1), which is where explicit placement
# co-optimization actually bites (ROADMAP PR-2 follow-up).
PLACEMENT_SENSITIVE_HW = dataclasses.replace(
    hw.DEFAULT_HW, comm_reuse_systolic=False, latency_amort_exp=1.0)

HW_PRESETS = {
    "default": hw.DEFAULT_HW,
    "placement-sensitive": PLACEMENT_SENSITIVE_HW,
}


def with_hw_preset(cfg: "SuiteConfig", preset: str) -> "SuiteConfig":
    """Re-key a suite config onto one of the named HW presets."""
    if preset not in HW_PRESETS:
        raise ValueError(f"unknown HW preset {preset!r}; "
                         f"choose from {sorted(HW_PRESETS)}")
    return dataclasses.replace(
        cfg, env=dataclasses.replace(cfg.env, hw=HW_PRESETS[preset]))


@dataclasses.dataclass(frozen=True)
class SuiteConfig:
    """One scenario-suite run: workloads x weight grid x portfolio scale."""

    workloads: Tuple[str, ...] = ("mlperf",)
    weight_grid: Tuple[Tuple[float, float, float], ...] = DEFAULT_WEIGHT_GRID
    n_sa: int = 8
    n_rl: int = 4
    refine: bool = True
    max_refine_sweeps: int = 2
    placement_refine: bool = True
    # one extra lockstep design sweep under the refined floorplans (the
    # PlacementEvalCache-backed placement stage feeds its winners back
    # into portfolio.coordinate_refine_batch via `placements=`)
    post_placement_sweep: bool = True
    # NOTE: placement_sa must precede the `sa` field — that field shadows
    # the annealing module for later annotations in this class body.
    # 4x the pre-delta 2000 iters: delta evaluation made steps cheap
    # (PlacementSAConfig.delta_eval), spend the recovered budget on
    # coverage (ROADMAP PR-3 follow-up).
    placement_sa: sa.PlacementSAConfig = sa.PlacementSAConfig(n_iters=8_000)
    sa: sa.SAConfig = sa.SAConfig(n_iters=20_000)
    rl: ppo.PPOConfig = ppo.PPOConfig(n_steps=128, n_envs=4)
    rl_timesteps: int = 128 * 4 * 4
    env: chipenv.EnvConfig = chipenv.EnvConfig()


SMOKE_SUITE = SuiteConfig(
    n_sa=2, n_rl=2,
    sa=sa.SAConfig(n_iters=2_000),
    rl=ppo.PPOConfig(n_steps=32, n_envs=2, batch_size=32),
    rl_timesteps=32 * 2 * 2,
    refine=True, max_refine_sweeps=1,
    placement_sa=sa.PlacementSAConfig(n_iters=500),
)

# the same grids re-keyed onto the regime where placement co-optimization
# has leverage (see PLACEMENT_SENSITIVE_HW above)
PLACEMENT_SENSITIVE_SUITE = with_hw_preset(SuiteConfig(), "placement-sensitive")
PLACEMENT_SENSITIVE_SMOKE = with_hw_preset(SMOKE_SUITE, "placement-sensitive")


@dataclasses.dataclass(frozen=True)
class ScenarioOutcome:
    """Winner of one (workload, reward-weight) scenario."""

    name: str
    workload_name: str
    weights: Tuple[float, float, float]
    best_flat: np.ndarray           # (14,) int32 design indices
    best_reward: float              # with the refined placement (if any)
    source: str                     # 'sa' | 'rl' | 'refined' | 'placement'
    tasks_per_sec: float
    energy_per_task_j: float
    total_cost: float
    eff_tops: float
    # explicit-placement co-optimization (core/placement.py)
    reward_canonical: float = None  # winner under the Fig.-4 floorplan
    placement_cells: np.ndarray = None   # (128,) grid cell per slot
    placement_hbm_ij: np.ndarray = None  # (6, 2) HBM anchor coords


@dataclasses.dataclass(frozen=True)
class SuiteResult:
    outcomes: List[ScenarioOutcome]
    pareto: List[int]               # indices into outcomes, non-dominated
    wall_time_s: float
    # frontier after normalizing tasks/s and J/task by each workload's
    # monolithic baseline (the raw frontier favors light workloads)
    pareto_normalized: List[int] = dataclasses.field(default_factory=list)


def build_scenarios(cfg: SuiteConfig) -> Tuple[List[str], List[str],
                                               cm.Scenario]:
    """Resolve the grid -> (scenario names, workload names, batched Scenario)."""
    wl_names, workloads = wl.resolve(cfg.workloads)
    names, wnames, scalars = [], [], []
    for wname, workload in zip(wl_names, workloads):
        for a, b, g in cfg.weight_grid:
            names.append(f"{wname}|a={a:g},b={b:g},g={g:g}")
            wnames.append(wname)
            scalars.append(cm.Scenario(workload=workload,
                                       weights=cm.make_weights(a, b, g)))
    return names, wnames, cm.stack_scenarios(scalars)


def pareto_indices(points: np.ndarray,
                   maximize: Sequence[bool]) -> List[int]:
    """Indices of the non-dominated rows of ``points`` (S, D)."""
    pts = np.asarray(points, np.float64).copy()
    for d, mx in enumerate(maximize):
        if not mx:
            pts[:, d] = -pts[:, d]
    out = []
    for i in range(pts.shape[0]):
        dominated = np.any(
            np.all(pts >= pts[i], axis=1) & np.any(pts > pts[i], axis=1))
        if not dominated:
            out.append(i)
    return out


def run_suite(key, cfg: SuiteConfig = SuiteConfig(),
              verbose: bool = False) -> SuiteResult:
    """Portfolio-optimize every scenario in the grid; every stage vectorized.

    The SA arm runs (S scenarios x n_sa chains) as one XLA program, the RL
    arm (S scenarios x n_rl agents) as another; coordinate refinement
    sweeps all S winners in lockstep (one jitted program per sweep); the
    placement-refinement stage anneals all S winners' floorplans as one
    vmapped program. No host loop per winner anywhere.
    """
    t0 = time.time()
    names, wnames, scenarios = build_scenarios(cfg)
    n_scen = len(names)
    k_sa, k_rl, k_pl = jax.random.split(jnp.asarray(key), 3)

    cand_rewards = []                                   # each (S, K)
    cand_flats = []                                     # each (S, K, 14)
    if cfg.n_sa > 0:
        sa_res = sa.run_scenario_population(
            k_sa, scenarios, cfg.n_sa, cfg.env, cfg.sa)
        cand_rewards.append(np.asarray(sa_res.best_reward))
        cand_flats.append(np.asarray(ps.to_flat(sa_res.best_design)))
    if cfg.n_rl > 0:
        rl_res = ppo.train_scenario_population(
            k_rl, scenarios, cfg.n_rl, cfg.env, cfg.rl,
            total_timesteps=cfg.rl_timesteps)
        cand_rewards.append(np.asarray(rl_res.best_reward))
        cand_flats.append(np.asarray(ps.to_flat(rl_res.best_design)))
    if not cand_rewards:
        raise ValueError("SuiteConfig needs n_sa > 0 or n_rl > 0")

    n_sa = cfg.n_sa
    rewards = np.concatenate(cand_rewards, axis=1)      # (S, n_sa + n_rl)
    flats = np.concatenate(cand_flats, axis=1)          # (S, ..., 14)

    # per-scenario argmax (host, trivial) ...
    top = np.argmax(rewards, axis=1)                    # (S,)
    winner_flats = flats[np.arange(n_scen), top].astype(np.int32)
    winner_rewards = rewards[np.arange(n_scen), top].astype(np.float64)
    sources = ["sa" if t < n_sa else "rl" for t in top]

    # ... then ONE batched coordinate sweep over all S winners at a time
    if cfg.refine:
        refined_flats, refined_r = portfolio.coordinate_refine_batch(
            winner_flats, scenarios, cfg.env, cfg.max_refine_sweeps)
        for s in range(n_scen):
            if refined_r[s] > winner_rewards[s] + 1e-6:
                winner_flats[s] = refined_flats[s]
                winner_rewards[s] = refined_r[s]
                sources[s] = "refined"

    dp_batch = ps.from_flat(jnp.asarray(winner_flats))

    # placement-refinement stage: anneal all S winners' floorplans in one
    # vmapped program (swap/relocate/re-anchor moves, scenario axis; the
    # SA carries a PlacementEvalCache so every move is delta-evaluated)
    placements = None
    canonical_rewards = winner_rewards.copy()
    if cfg.placement_refine:
        pres = sa.refine_placement_scenarios(
            k_pl, dp_batch, scenarios, cfg.env, cfg.placement_sa)
        placements = pres.best_placement
        canonical_rewards = np.asarray(pres.canonical_reward, np.float64)
        placed_rewards = np.asarray(pres.best_reward, np.float64)
        for s in range(n_scen):
            if placed_rewards[s] > winner_rewards[s] + 1e-6:
                sources[s] = "placement"
            winner_rewards[s] = max(winner_rewards[s], placed_rewards[s])

        # feed the refined floorplans back into the design grid: one more
        # lockstep coordinate sweep scoring every Table-1 candidate WITH
        # its scenario's annealed placement (design<->placement co-descent)
        if cfg.refine and cfg.post_placement_sweep:
            re_flats, re_r = portfolio.coordinate_refine_batch(
                winner_flats, scenarios, cfg.env, cfg.max_refine_sweeps,
                placements=placements)
            changed = False
            for s in range(n_scen):
                if re_r[s] > winner_rewards[s] + 1e-6:
                    winner_flats[s] = re_flats[s]
                    winner_rewards[s] = re_r[s]
                    sources[s] = "codesign"
                    changed = True
            if changed:
                dp_batch = ps.from_flat(jnp.asarray(winner_flats))
                # canonical reference tracks the (possibly new) designs;
                # a swept design's annealed-for-the-old-design floorplan
                # may score below its own canonical — for those rows the
                # canonical floorplan IS the best known placement, so
                # swap it in (keeps best >= canonical AND the reported
                # metrics/placement consistent with the reported reward)
                canonical_rewards = np.asarray(
                    cm.evaluate_scenarios(dp_batch, scenarios,
                                          cfg.env.hw).reward, np.float64)
                v_new = ps.decode(dp_batch)
                n_pos_new = cm.footprint_positions(v_new)
                m_new, n_new = cm.mesh_dims(n_pos_new)
                canon_plc = pm.canonical(m_new, n_new, v_new.hbm_mask,
                                         v_new.arch_type)
                use_canon = jnp.asarray(
                    canonical_rewards >= winner_rewards)
                placements = jax.tree_util.tree_map(
                    lambda c, p: jnp.where(
                        use_canon.reshape((-1,) + (1,) * (p.ndim - 1)),
                        c, p), canon_plc, placements)
                winner_rewards = np.maximum(winner_rewards,
                                            canonical_rewards)

    if verbose:
        for s in range(n_scen):
            print(f"  [suite] {names[s]}: reward={winner_rewards[s]:.1f} "
                  f"({sources[s]})")

    # scenario-batched PPAC evaluation of all winners in one program
    metrics = cm.evaluate_scenarios(dp_batch, scenarios, cfg.env.hw,
                                    placements=placements)

    outcomes = []
    for s in range(n_scen):
        outcomes.append(ScenarioOutcome(
            name=names[s], workload_name=wnames[s],
            weights=(float(scenarios.weights.alpha[s]),
                     float(scenarios.weights.beta[s]),
                     float(scenarios.weights.gamma[s])),
            best_flat=winner_flats[s],
            best_reward=float(winner_rewards[s]),
            source=sources[s],
            tasks_per_sec=float(metrics.tasks_per_sec[s]),
            energy_per_task_j=float(metrics.energy_per_task_j[s]),
            total_cost=float(metrics.total_cost[s]),
            eff_tops=float(metrics.eff_tops[s]),
            reward_canonical=float(canonical_rewards[s]),
            placement_cells=(None if placements is None else
                             np.asarray(placements.chiplet_cell[s])),
            placement_hbm_ij=(None if placements is None else
                              np.asarray(placements.hbm_ij[s])),
        ))

    triples = np.stack([
        [o.tasks_per_sec, o.energy_per_task_j, o.total_cost]
        for o in outcomes])
    pareto = pareto_indices(triples, maximize=(True, False, False))

    # per-workload-normalized frontier: tasks/s and J/task relative to the
    # iso-node monolithic baseline evaluated on the *same* workload, so
    # heavy workloads compete on speedup rather than raw task rate
    mono_m = jax.vmap(lambda w: mono.evaluate(w, cfg.env.hw))(
        scenarios.workload)
    norm = triples.copy()
    norm[:, 0] = triples[:, 0] / np.maximum(
        np.asarray(mono_m.tasks_per_sec, np.float64), 1e-30)
    norm[:, 1] = triples[:, 1] / np.maximum(
        np.asarray(mono_m.energy_per_task_j, np.float64), 1e-30)
    pareto_norm = pareto_indices(norm, maximize=(True, False, False))
    return SuiteResult(outcomes=outcomes, pareto=pareto,
                       wall_time_s=time.time() - t0,
                       pareto_normalized=pareto_norm)


def format_report(res: SuiteResult) -> str:
    """Human-readable per-scenario table + both Pareto frontiers."""
    lines = [f"{'scenario':<43} {'reward':>9} {'plc-gain':>9} {'tasks/s':>12} "
             f"{'J/task':>10} {'cost':>9} {'src':>9}"]
    for i, o in enumerate(res.outcomes):
        star = "*" if i in res.pareto else " "
        plus = "+" if i in res.pareto_normalized else " "
        gain = (0.0 if o.reward_canonical is None
                else o.best_reward - o.reward_canonical)
        lines.append(
            f"{star}{plus}{o.name:<41} {o.best_reward:>9.1f} {gain:>9.3f} "
            f"{o.tasks_per_sec:>12,.0f} {o.energy_per_task_j:>10.2e} "
            f"{o.total_cost:>9.0f} {o.source:>9}")
    lines.append(f"\nPareto frontier (raw tasks/s vs J/task vs cost): "
                 f"{len(res.pareto)}/{len(res.outcomes)} scenarios (*); "
                 f"monolithic-normalized frontier: "
                 f"{len(res.pareto_normalized)}/{len(res.outcomes)} (+); "
                 f"suite wall-time {res.wall_time_s:.1f}s")
    return "\n".join(lines)


def to_json(res: SuiteResult) -> Dict:
    """JSON-serializable summary (per-scenario winners + frontiers)."""
    return {
        "wall_time_s": res.wall_time_s,
        "pareto": list(res.pareto),
        "pareto_normalized": list(res.pareto_normalized),
        "scenarios": [{
            "name": o.name,
            "workload": o.workload_name,
            "weights": list(o.weights),
            "design": [int(x) for x in o.best_flat],
            "reward": o.best_reward,
            "reward_canonical": o.reward_canonical,
            "source": o.source,
            "tasks_per_sec": o.tasks_per_sec,
            "energy_per_task_j": o.energy_per_task_j,
            "total_cost": o.total_cost,
            "eff_tops": o.eff_tops,
            "placement_cells": (None if o.placement_cells is None else
                                [int(c) for c in o.placement_cells]),
            "placement_hbm_ij": (None if o.placement_hbm_ij is None else
                                 [[float(x) for x in ij]
                                  for ij in o.placement_hbm_ij]),
        } for o in res.outcomes],
    }


def save_json(res: SuiteResult, path: str) -> None:
    with open(path, "w") as f:
        json.dump(to_json(res), f, indent=2)
