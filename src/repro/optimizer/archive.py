"""Fixed-capacity JAX-resident Pareto archive over PPAC objectives.

The suite's old Pareto frontier was a host-side post-hoc filter over the
per-scenario *scalarized* winners. This module makes the non-dominated
set a first-class on-device data structure (cf. Gemini's maintained
co-exploration frontier, Monad's evolutionary multi-objective search):
an :class:`Archive` is a pure pytree of fixed-shape arrays, so
:func:`insert_batch` is jit/vmap/scan-safe — the evolutionary arm
(optimizer/evo.py) carries one through its generation ``lax.scan``, and
the portfolio / scenario suite feed the same structure from all three
arms (SA chains, PPO agents, GA populations).

Objective convention
--------------------
A point is the raw PPAC triple ``(tasks_per_sec, energy_per_task_j,
total_cost)`` with directions :data:`MAXIMIZE` = (up, down, down) — or,
for traffic-traced suites, the 4-tuple extended with SLO attainment
(up, :data:`MAXIMIZE_SLO`). Every routine infers the objective count
from the trailing axis; internally everything is flipped to
minimization via :func:`_signs`; callers never see the flipped space.

Implementation notes (PR-4 container lessons): no scatters anywhere —
membership updates are argsort + gather (``take``) and masked
``where`` selects, which beat vmapped dynamic ``.at[].set`` on the
launch-bound CPU backend. Eviction beyond capacity drops the most
crowded interior points first (NSGA-II crowding distance; boundary
points are never evicted before interior ones).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import params as ps

N_OBJ = 3
MAXIMIZE = (True, False, False)        # tasks/s UP, J/task DOWN, cost DOWN
MAXIMIZE_SLO = MAXIMIZE + (True,)      # + trace SLO attainment UP
_DIRECTIONS = {3: MAXIMIZE, 4: MAXIMIZE_SLO}
_BIG = jnp.float32(3.0e38)             # sentinel for invalid rows (min space)


def _signs(n_obj: int) -> jnp.ndarray:
    """(n_obj,) +-1 flip vector of the objective convention."""
    dirs = _DIRECTIONS.get(int(n_obj))
    if dirs is None:
        raise ValueError(f"unsupported objective count {n_obj}; "
                         f"one of {sorted(_DIRECTIONS)}")
    return jnp.asarray([-1.0 if up else 1.0 for up in dirs], jnp.float32)


_SIGNS = _signs(N_OBJ)


class Archive(NamedTuple):
    """Fixed-capacity non-dominated store (pure pytree, all shapes static).

    ``points`` rows are only meaningful where ``valid``; invalid rows are
    filled with dominated sentinels and never win a dominance test.
    ``flats`` carries the genome that produced each point (the 14 Table-1
    indices, or 18 with placement genes), ``reward`` the scalarized
    objective it scored, ``payload`` a caller-defined int tag (scenario
    index, arm id, ...).
    """

    points: jnp.ndarray        # (C, n_obj) float32, raw objective convention
    flats: jnp.ndarray         # (C, G) int32 genomes
    reward: jnp.ndarray        # (C,)  float32
    payload: jnp.ndarray       # (C,)  int32
    valid: jnp.ndarray         # (C,)  bool

    @property
    def capacity(self) -> int:
        return self.valid.shape[-1]

    @property
    def n_valid(self) -> jnp.ndarray:
        return jnp.sum(self.valid, axis=-1)


def empty(capacity: int, genome_dim: int = ps.N_PARAMS,
          n_obj: int = N_OBJ) -> Archive:
    """An all-invalid archive of the given capacity."""
    # dominated sentinel: worst value on every objective (raw convention)
    return Archive(
        points=jnp.broadcast_to(_BIG * _signs(n_obj), (capacity, n_obj)),
        flats=jnp.zeros((capacity, genome_dim), jnp.int32),
        reward=jnp.full((capacity,), -jnp.inf, jnp.float32),
        payload=jnp.full((capacity,), -1, jnp.int32),
        valid=jnp.zeros((capacity,), bool),
    )


def _to_min(points: jnp.ndarray) -> jnp.ndarray:
    """Flip the raw convention into all-minimize space."""
    points = jnp.asarray(points, jnp.float32)
    return points * _signs(points.shape[-1])


def point_from_metrics(mtr) -> jnp.ndarray:
    """The archive objective triple of a ``costmodel.Metrics`` bundle.

    Single owner of the Metrics -> point column mapping; must stay in
    lockstep with :data:`MAXIMIZE` / :data:`_SIGNS`.
    """
    return jnp.stack([mtr.tasks_per_sec, mtr.energy_per_task_j,
                      mtr.total_cost], axis=-1)


def point_with_slo(mtr, slo_attainment) -> jnp.ndarray:
    """PPAC triple + trace SLO attainment -> 4-objective archive point."""
    return jnp.concatenate(
        [point_from_metrics(mtr),
         jnp.asarray(slo_attainment, jnp.float32)[..., None]], axis=-1)


def non_dominated_mask(points: jnp.ndarray,
                       valid: jnp.ndarray = None) -> jnp.ndarray:
    """Boolean mask of the non-dominated rows of ``points`` (N, n_obj).

    Raw objective convention. A valid row is dominated iff some other
    valid row is <= on every (minimized) objective and < on at least one.
    """
    pts = _to_min(points)
    if valid is None:
        valid = jnp.ones(pts.shape[:-1], bool)
    pts = jnp.where(valid[..., None], pts, _BIG)
    a, b = pts[:, None, :], pts[None, :, :]
    dominates = ((a <= b).all(-1) & (a < b).any(-1)
                 & valid[:, None] & valid[None, :])
    return valid & ~dominates.any(axis=0)


def _crowding(pts_min: jnp.ndarray, keep: jnp.ndarray) -> jnp.ndarray:
    """NSGA-II crowding distance of the kept rows (-inf elsewhere).

    Boundary points of every objective get +inf so capacity eviction
    always drops the most crowded *interior* point first.
    """
    n = keep.shape[0]
    total = jnp.sum(keep)
    cd = jnp.zeros((n,), jnp.float32)
    rank = jnp.arange(n)
    for d in range(pts_min.shape[-1]):
        v = jnp.where(keep, pts_min[:, d], jnp.inf)
        order = jnp.argsort(v)
        vs = v[order]
        prev = jnp.concatenate([jnp.full((1,), -jnp.inf), vs[:-1]])
        nxt = jnp.concatenate([vs[1:], jnp.full((1,), jnp.inf)])
        span = jnp.take(vs, jnp.clip(total - 1, 0, n - 1)) - vs[0]
        is_boundary = (rank == 0) | (rank == total - 1)
        contrib = jnp.where(is_boundary, jnp.inf,
                            (nxt - prev) / jnp.maximum(span, 1e-30))
        contrib = jnp.where(rank < total, contrib, 0.0)
        cd = cd + jnp.take(contrib, jnp.argsort(order))
    return jnp.where(keep, cd, -jnp.inf)


def _hv_contrib(pts_min: jnp.ndarray, keep: jnp.ndarray) -> jnp.ndarray:
    """Leave-one-out hypervolume contribution of the kept rows.

    Min-space points in, -inf on non-kept rows out (same ranking-key
    contract as :func:`_crowding`). The reference is the kept rows'
    nadir pushed out by a margin (the jit-safe twin of
    :func:`nadir_ref`), so every kept point encloses positive volume and
    extreme points keep large contributions. O(n^3 log n) — the
    opt-in ``eviction='hv'`` quality mode, not the default.
    """
    n = keep.shape[0]
    any_keep = keep.any()
    hi = jnp.max(jnp.where(keep[:, None], pts_min, -_BIG), axis=0)
    lo = jnp.min(jnp.where(keep[:, None], pts_min, _BIG), axis=0)
    pad = 0.1 * jnp.maximum(hi - lo, 0.01 * jnp.abs(hi) + 1e-9)
    refm = jnp.where(any_keep, hi + pad, jnp.ones_like(hi))
    base = jnp.where(keep[:, None], jnp.minimum(pts_min, refm), refm)
    hv_all = _hv_min(base, refm)

    def without(i):
        drop = jnp.arange(n) == i
        return _hv_min(jnp.where(drop[:, None], refm, base), refm)

    contrib = hv_all - jax.vmap(without)(jnp.arange(n))
    return jnp.where(keep, contrib, -jnp.inf)


def insert_batch(archive: Archive, points: jnp.ndarray, flats: jnp.ndarray,
                 reward: jnp.ndarray = None, payload: jnp.ndarray = None,
                 valid: jnp.ndarray = None,
                 eviction: str = "crowding") -> Archive:
    """Insert a (B, n_obj) batch of points; return the updated archive.

    Pure-functional and jit/scan-safe: forms the (C+B)-row union, runs
    one masked pairwise dominance test, drops exact-duplicate points
    (keeping the first occurrence, so re-inserting an archive's own
    contents is a no-op), and — only when the surviving front exceeds
    capacity — evicts by crowding distance. Order-insensitive up to
    ties: permuting the rows of one batch changes at most which of two
    entries with *identical objectives* survives.

    ``eviction`` picks the capacity-eviction key (a static string):
    ``'crowding'`` (default, NSGA-II crowding distance) or ``'hv'``
    (leave-one-out exclusive hypervolume contribution — evicts the
    point whose removal costs the least dominated volume; slower but
    directly optimizes the reported archive metric).
    """
    if eviction not in ("crowding", "hv"):
        raise ValueError(f"eviction must be 'crowding' or 'hv', "
                         f"got {eviction!r}")
    points = jnp.asarray(points, jnp.float32)
    b = points.shape[0]
    flats = jnp.asarray(flats, jnp.int32)
    if reward is None:
        reward = jnp.full((b,), -jnp.inf, jnp.float32)
    if payload is None:
        payload = jnp.full((b,), -1, jnp.int32)
    if valid is None:
        valid = jnp.ones((b,), bool)
    valid = valid & jnp.isfinite(points).all(-1)

    pts_u = jnp.concatenate([archive.points, points])
    flats_u = jnp.concatenate([archive.flats, flats])
    rew_u = jnp.concatenate([archive.reward,
                             jnp.asarray(reward, jnp.float32)])
    pay_u = jnp.concatenate([archive.payload,
                             jnp.asarray(payload, jnp.int32)])
    val_u = jnp.concatenate([archive.valid, valid])

    pm = jnp.where(val_u[:, None], _to_min(pts_u), _BIG)
    a, bb = pm[:, None, :], pm[None, :, :]
    both = val_u[:, None] & val_u[None, :]
    dominated = ((a <= bb).all(-1) & (a < bb).any(-1) & both).any(axis=0)
    idx = jnp.arange(pm.shape[0])
    dup = ((a == bb).all(-1) & both & (idx[:, None] < idx[None, :])).any(0)
    keep = val_u & ~dominated & ~dup

    cap = archive.capacity
    key = _crowding(pm, keep) if eviction == "crowding" else _hv_contrib(
        pm, keep)
    sel = jnp.argsort(-key)[:cap]          # stable: kept rows first
    return Archive(points=jnp.take(pts_u, sel, axis=0),
                   flats=jnp.take(flats_u, sel, axis=0),
                   reward=jnp.take(rew_u, sel),
                   payload=jnp.take(pay_u, sel),
                   valid=jnp.take(keep, sel))


def merge(dst: Archive, src: Archive, eviction: str = "crowding") -> Archive:
    """Insert every valid entry of ``src`` into ``dst``."""
    return insert_batch(dst, src.points, src.flats, reward=src.reward,
                        payload=src.payload, valid=src.valid,
                        eviction=eviction)


def hypervolume(archive: Archive, ref) -> jnp.ndarray:
    """Exact hypervolume dominated by the archive w.r.t. ``ref``.

    ``ref`` is a raw-convention point (tasks/s lower bound, J/task and
    cost upper bounds, SLO-attainment lower bound when 4-D) that every
    counted point should dominate; points beyond it are clipped and
    contribute zero volume. Exact recursive sweep: slices along the last
    (minimized) objective, recursing to a 2-D staircase base case —
    O(C^(d-1) log C), fully vectorized (sort + cummin + nested vmap), no
    host callbacks, so it can run inside a jitted program.
    """
    refm = _to_min(jnp.asarray(ref, jnp.float32))
    pm = jnp.where(archive.valid[:, None],
                   jnp.minimum(_to_min(archive.points), refm), refm)
    return _hv_min(pm, refm)


def _hv_min(pm: jnp.ndarray, refm: jnp.ndarray) -> jnp.ndarray:
    """Hypervolume sweep core in min space (see :func:`hypervolume`).

    Rows must already be clipped to ``refm`` (invalid rows set equal to
    it, so they enclose zero volume). Recursive over the objective
    count: the 2-D base case is the sorted staircase, a d-D volume is
    the sum over last-axis slices of the (d-1)-D volume of the points
    active in that slice. For d == 3 this unrolls to exactly the
    pre-generalization sweep (same op sequence, bitwise identical).
    """
    d = pm.shape[-1]
    if d == 2:
        o = jnp.argsort(pm[:, 0])
        xs, ys = jnp.take(pm[:, 0], o), jnp.take(pm[:, 1], o)
        ymin = jax.lax.cummin(ys)
        xn = jnp.concatenate([xs[1:], refm[0:1]])
        return jnp.sum(jnp.maximum(xn - xs, 0.0)
                       * jnp.maximum(refm[1] - ymin, 0.0))

    order = jnp.argsort(pm[:, d - 1])
    front = jnp.take(pm[:, :d - 1], order, axis=0)
    z = jnp.take(pm[:, d - 1], order)
    heights = jnp.concatenate([z[1:], refm[d - 1:d]]) - z
    n = z.shape[0]

    def slice_vol(k):
        active = (jnp.arange(n) <= k)[:, None]
        return _hv_min(jnp.where(active, front, refm[:d - 1]),
                       refm[:d - 1])

    vols = jax.vmap(slice_vol)(jnp.arange(n))
    return jnp.sum(vols * jnp.maximum(heights, 0.0))


def nadir_ref(points: jnp.ndarray, valid: jnp.ndarray = None,
              margin: float = 0.1):
    """A reference point weakly dominated by every valid point.

    Raw convention in and out. The componentwise worst (nadir) of the
    valid points, pushed ``margin`` of the objective span further, so
    nadir points still enclose positive volume. Deterministic given the
    points, which makes it a *shared* ref for comparing archives: pass
    the concatenation of both archives' (points, valid).
    """
    pm = _to_min(points)
    if valid is None:
        valid = jnp.ones(pm.shape[:-1], bool)
    any_valid = valid.any()
    hi = jnp.max(jnp.where(valid[..., None], pm, -_BIG), axis=0)
    lo = jnp.min(jnp.where(valid[..., None], pm, _BIG), axis=0)
    pad = margin * jnp.maximum(hi - lo, 0.01 * jnp.abs(hi) + 1e-9)
    refm = jnp.where(any_valid, hi + pad, jnp.ones_like(hi))
    return refm * _signs(pm.shape[-1])


def contents(archive: Archive) -> dict:
    """Host-side extraction of the valid rows (for reports / JSON)."""
    import numpy as np
    valid = np.asarray(archive.valid)
    return {
        "points": np.asarray(archive.points)[valid],
        "flats": np.asarray(archive.flats)[valid],
        "reward": np.asarray(archive.reward)[valid],
        "payload": np.asarray(archive.payload)[valid],
    }
