"""Evolutionary search arm: a fully vmapped genetic algorithm + archive.

The paper's robustness recipe combines RL with non-RL optimizers; Monad
(PAPERS.md) shows evolutionary multi-objective search is the natural fit
for chiplet PPAC trade-offs. This module is the portfolio's third arm: a
generational GA over the 14-index Table-1 design space (plus, optionally,
the four placement-mutation genes of ``params.PLACEMENT_HEAD_SIZES``)
with tournament selection, uniform crossover and per-index mutation.

One generation — selection, crossover, mutation, the vmapped population
evaluation, and the Pareto-archive insertion — is one step of a single
``lax.scan``, so an entire ``evolve`` run compiles to ONE XLA program
whose kernel count is independent of the population size (asserted by
tests/test_evo.py); there is no per-individual dispatch anywhere.

A :class:`repro.optimizer.archive.Archive` rides the scan carry: every
individual ever evaluated competes for the non-dominated (tasks/s up,
J/task down, cost down) front, so the multi-objective frontier is a live
on-device by-product of the scalarized search, not a post-hoc filter.

API mirrors the SA arm: :func:`evolve` ~ ``sa.run``,
:func:`evolve_population` ~ ``sa.run_population``,
:func:`evolve_scenario_population` ~ ``sa.run_scenario_population``.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import costmodel as cm
from repro.core import env as chipenv
from repro.core import mapping as mpg
from repro.core import params as ps
from repro.core import placement as pm
from repro.optimizer import archive as ar
from repro.telemetry import counters as tl


@dataclasses.dataclass(frozen=True)
class EvoConfig:
    """Generational GA over the Table-1 grid (+ optional placement genes).

    ``placement_genes`` extends the genome with the four placement
    heads (relocate one chiplet slot, re-anchor one HBM stack — the same
    action-space extension ``EnvConfig(placement_actions=True)`` gives
    the RL arm); each individual is then scored under its mutated
    floorplan through the full pairwise NoP tier, exactly like an
    RL placement action.

    ``archive_capacity`` sizes the Pareto archive carried through the
    generation scan; it is returned in :class:`EvoResult` and fed back
    into the portfolio / suite shared archive.
    """

    pop_size: int = 32
    n_generations: int = 50
    tournament_k: int = 3
    p_crossover: float = 0.9
    p_mutate: float = 0.1          # per-gene uniform resample probability
    # fraction of mutating genes that take a +-1 ordinal *creep* step
    # (clipped to the gene's grid) instead of a uniform resample. The
    # Table-1 heads are ordinal (PE counts, SRAM sizes, link widths), so
    # local steps preserve fitness correlation; 0.0 keeps the original
    # pure-resample operator AND its key stream bit-exact (the creep
    # bits are folded from the resample key on a static branch).
    p_creep: float = 0.0
    # per generation, this many uniform proposals are scored by a
    # surrogate (when one is passed to evolve()) and the argmax is
    # injected into the offspring; its *fitness* still comes from the
    # analytic evaluation like every other individual. 0 disables.
    surrogate_proposals: int = 0
    placement_genes: bool = False
    # extend the genome further with the four mapping heads
    # (params.MAPPING_HEAD_SIZES: reassign one slot's pipeline stage +
    # one layer group's tile on top of the canonical dataflow) — the GA
    # then co-evolves (design, placement, mapping). Requires
    # placement_genes (the genome layout appends after the placement
    # genes). Default off: the 18-gene path stays bit-exact.
    mapping_genes: bool = False
    archive_capacity: int = 64
    # island-model migration (evolve_population): every migrate_every
    # generations each island's current best genome emigrates to its
    # ring neighbour, replacing that island's current worst individual —
    # one jnp.roll + one one-hot select per epoch, batched over the
    # island axis, so the compiled kernel count is island-invariant
    # (tests/test_evo.py). 0 (default) keeps the PR-5 independent-island
    # vmap path and its per-island key streams bit-exact.
    migrate_every: int = 0
    # archive.insert_batch eviction key ('crowding' default | 'hv' for
    # leave-one-out hypervolume-contribution eviction)
    archive_eviction: str = "crowding"
    # in-scan telemetry (telemetry/counters.EvoGenStats): per-generation
    # population diversity, mean fitness, archive insert/evict counts
    # and a live hypervolume sample, emitted alongside the best-so-far
    # history and returned as EvoResult.telemetry. False (default)
    # statically compiles the exact pre-telemetry program — the GA key
    # stream and every result leaf stay bit-for-bit. Stats only read
    # values the generation already computed (plus an O(capacity^2)
    # archive diff and one HV sweep per generation).
    telemetry: bool = False


class EvoResult(NamedTuple):
    best_design: ps.DesignPoint
    best_reward: jnp.ndarray
    history: jnp.ndarray           # (n_generations,) best-so-far trace
    archive: ar.Archive            # live non-dominated PPAC front
    best_genome: jnp.ndarray       # (G,) int32 — incl. placement genes
    # per-generation stats (cfg.telemetry only; counters.EvoGenStats
    # with a leading generation axis)
    telemetry: tl.EvoGenStats = None


def genome_head_sizes(cfg: EvoConfig) -> Tuple[int, ...]:
    """Per-gene grid sizes (14 Table-1 heads, +4 with placement genes,
    +4 more with mapping genes)."""
    if cfg.mapping_genes:
        if not cfg.placement_genes:
            raise ValueError("mapping_genes requires placement_genes")
        return ps.MAP_HEAD_SIZES
    return ps.EXT_HEAD_SIZES if cfg.placement_genes else ps.HEAD_SIZES


def genome_placement(genome: jnp.ndarray):
    """Decode an 18-gene genome -> (DesignPoint, Placement).

    The placement genes mutate the canonical Fig.-4 floorplan of the
    design the genome selects, mirroring ``env._design_and_placement``.
    22-gene genomes (mapping genes appended) decode identically — the
    placement slice is positional; use :func:`genome_mapping` for the
    mapping tail.
    """
    design = ps.from_flat(genome[..., : ps.N_PARAMS])
    v = ps.decode(design)
    n_pos = cm.footprint_positions(v)
    m, n = cm.mesh_dims(n_pos)
    base = pm.canonical(m, n, v.hbm_mask, v.arch_type)
    plc = pm.apply_action(
        base, genome[..., ps.N_PARAMS: ps.N_EXT_PARAMS], n_pos)
    return design, plc


def genome_mapping(genome: jnp.ndarray) -> mpg.Mapping:
    """Decode the mapping tail of a 22-gene genome -> Mapping.

    The four mapping genes apply one stage reassignment and one tile
    assignment on top of the canonical dataflow (the same single-action
    semantics as the env's mapping heads). Unbatched (callers vmap).
    """
    design = ps.from_flat(genome[..., : ps.N_PARAMS])
    n_pos = cm.footprint_positions(ps.decode(design))
    return mpg.apply_action(mpg.canonical(),
                            genome[..., ps.N_EXT_PARAMS:], n_pos)


def _eval_genome(genome: jnp.ndarray, env_cfg: chipenv.EnvConfig,
                 scenario: cm.Scenario, placement_genes: bool,
                 mapping_genes: bool = False):
    """One genome -> (reward, raw PPAC objective triple)."""
    fid = env_cfg.nop_fidelity
    mapping = None
    if placement_genes:
        design, plc = genome_placement(genome)
        # a mutated placement always needs the full pairwise tier
        fid = "auto" if fid == "fast" else fid
        if mapping_genes:
            mapping = genome_mapping(genome)
    else:
        design, plc = ps.from_flat(genome[..., : ps.N_PARAMS]), None
    mtr = cm.evaluate_scenario(design, scenario, env_cfg.hw, plc,
                               nop_fidelity=fid, mapping=mapping)
    return mtr.reward, ar.point_from_metrics(mtr)


def evolve(key, env_cfg: chipenv.EnvConfig = chipenv.EnvConfig(),
           cfg: EvoConfig = EvoConfig(),
           scenario: cm.Scenario = None,
           surrogate=None) -> EvoResult:
    """One GA run (single scalarized objective + live Pareto archive).

    jit/vmap-safe; ``scenario`` is a traced (workload, weights) pytree —
    vmap over it to evolve many scenarios inside one XLA program.

    ``surrogate`` is an optional scenario-folded
    ``surrogate.model.FoldedParams``: with
    ``cfg.surrogate_proposals > 0`` each generation injects the
    surrogate-argmax of that many uniform proposals into the offspring
    (selection/elitism still run on analytic fitness only).
    """
    scenario = env_cfg.scenario() if scenario is None else scenario
    heads = jnp.asarray(genome_head_sizes(cfg), jnp.int32)
    eval_pop = _make_eval_pop(env_cfg, scenario, cfg.placement_genes,
                              cfg.mapping_genes)
    carry0 = _init_carry(key, cfg, heads, eval_pop)
    generation = _make_generation(cfg, heads, eval_pop, surrogate)
    (_, _, best_g, best_r, arc, _), ys = jax.lax.scan(
        generation, carry0, None, length=cfg.n_generations)
    history, stats = ys if cfg.telemetry else (ys, None)
    return EvoResult(best_design=ps.from_flat(best_g[: ps.N_PARAMS]),
                     best_reward=best_r, history=history, archive=arc,
                     best_genome=best_g, telemetry=stats)


def _make_eval_pop(env_cfg, scenario, placement_genes,
                   mapping_genes=False):
    def eval_pop(pop):
        return jax.vmap(
            lambda g: _eval_genome(g, env_cfg, scenario,
                                   placement_genes, mapping_genes))(pop)
    return eval_pop


def _init_carry(key, cfg: EvoConfig, heads, eval_pop):
    """Seed population + archive; the carry of the generation scan."""
    n_genes = int(heads.shape[0])
    k_init, k_run = jax.random.split(key)
    pop0 = jax.random.randint(k_init, (cfg.pop_size, n_genes), 0, heads,
                              dtype=jnp.int32)
    fit0, obj0 = eval_pop(pop0)
    arc0 = ar.insert_batch(ar.empty(cfg.archive_capacity, n_genes),
                           obj0, pop0, reward=fit0,
                           eviction=cfg.archive_eviction)
    i0 = jnp.argmax(fit0)
    return (pop0, fit0, pop0[i0], fit0[i0], arc0, k_run)


def _make_generation(cfg: EvoConfig, heads, eval_pop, surrogate=None):
    """One GA generation as a scan step (shared by evolve and the
    migrating island path, so both compile the same per-island program
    and the per-island key streams match the independent runs)."""
    n_genes = int(heads.shape[0])
    pop_n = cfg.pop_size
    use_sur = surrogate is not None and cfg.surrogate_proposals > 0
    if use_sur:
        from repro.surrogate import model as sm

    def generation(carry, _):
        pop, fit, best_g, best_r, arc, key = carry
        key, k_ta, k_tb, k_xon, k_xmask, k_mmask, k_mval = (
            jax.random.split(key, 7))

        def tournament(k):
            cand = jax.random.randint(k, (pop_n, cfg.tournament_k), 0, pop_n)
            win = jnp.argmax(fit[cand], axis=1)
            return cand[jnp.arange(pop_n), win]

        pa = pop[tournament(k_ta)]
        pb = pop[tournament(k_tb)]
        cross = jax.random.bernoulli(k_xon, cfg.p_crossover, (pop_n, 1))
        xmask = jax.random.bernoulli(k_xmask, 0.5, (pop_n, n_genes))
        child = jnp.where(cross & xmask, pb, pa)
        mmask = jax.random.bernoulli(k_mmask, cfg.p_mutate,
                                     (pop_n, n_genes))
        mval = jax.random.randint(k_mval, (pop_n, n_genes), 0, heads,
                                  dtype=jnp.int32)
        if cfg.p_creep > 0.0:
            # static branch + keys folded from k_mval: the p_creep=0
            # default consumes exactly the original key stream
            creep = jax.random.bernoulli(
                jax.random.fold_in(k_mval, 1), cfg.p_creep,
                (pop_n, n_genes))
            step = jnp.where(
                jax.random.bernoulli(jax.random.fold_in(k_mval, 2), 0.5,
                                     (pop_n, n_genes)), 1, -1)
            mval = jnp.where(creep, jnp.clip(child + step, 0, heads - 1),
                             mval)
        child = jnp.where(mmask, mval, child)
        if use_sur:
            # surrogate-guided immigrant: best of Q uniform proposals by
            # predicted reward, injected after the elite slot — its real
            # fitness (and any selection pressure) stays analytic
            props = jax.random.randint(
                jax.random.fold_in(k_mval, 3),
                (cfg.surrogate_proposals, n_genes), 0, heads,
                dtype=jnp.int32)
            s = sm.score_folded(surrogate, props[:, : ps.N_PARAMS])
            child = child.at[1].set(props[jnp.argmax(s)])
        child = child.at[0].set(best_g)        # elitism (static index)

        fit_c, obj_c = eval_pop(child)
        arc_prev = arc
        arc = ar.insert_batch(arc, obj_c, child, reward=fit_c,
                              eviction=cfg.archive_eviction)
        i = jnp.argmax(fit_c)
        better = fit_c[i] > best_r
        best_g = jnp.where(better, child[i], best_g)
        best_r = jnp.where(better, fit_c[i], best_r)
        if cfg.telemetry:
            inserts, evicts = tl.archive_delta(arc_prev, arc)
            stats = tl.EvoGenStats(
                diversity=tl.population_diversity(child),
                mean_fitness=jnp.mean(fit_c),
                archive_inserts=inserts, archive_evicts=evicts,
                archive_n=jnp.sum(arc.valid.astype(jnp.int32)),
                archive_hv=ar.hypervolume(
                    arc, ar.nadir_ref(arc.points, arc.valid)))
            return (child, fit_c, best_g, best_r, arc, key), (best_r,
                                                              stats)
        return (child, fit_c, best_g, best_r, arc, key), best_r

    return generation


def evolve_population(key, n_islands: int,
                      env_cfg: chipenv.EnvConfig = chipenv.EnvConfig(),
                      cfg: EvoConfig = EvoConfig(),
                      scenario: cm.Scenario = None,
                      surrogate=None) -> EvoResult:
    """N GA islands in one vmapped program; results stacked.

    With ``cfg.migrate_every = 0`` (default) the islands are fully
    independent — the PR-5 path, bit-exact. With ``migrate_every > 0``
    the islands synchronize every that-many generations: each island's
    current best genome emigrates along a ring (``jnp.roll`` over the
    island axis) and replaces the receiving island's current worst
    individual. The epoch is one vmapped generation + one branchless
    one-hot exchange, so kernel counts stay island-invariant.
    """
    scenario = env_cfg.scenario() if scenario is None else scenario
    keys = jax.random.split(key, n_islands)
    if cfg.migrate_every <= 0:
        return jax.jit(jax.vmap(
            lambda k: evolve(k, env_cfg, cfg, scenario, surrogate)))(keys)
    return _evolve_islands(keys, env_cfg, cfg, scenario, surrogate)


def _evolve_islands(keys, env_cfg, cfg: EvoConfig, scenario,
                    surrogate=None) -> EvoResult:
    """Ring-migrating island GA: one scan over generations of a vmapped
    generation step plus a branchless migration exchange."""
    heads = jnp.asarray(genome_head_sizes(cfg), jnp.int32)
    eval_pop = _make_eval_pop(env_cfg, scenario, cfg.placement_genes,
                              cfg.mapping_genes)
    generation = _make_generation(cfg, heads, eval_pop, surrogate)
    pop_n = cfg.pop_size

    def run(keys):
        carry0 = jax.vmap(
            lambda k: _init_carry(k, cfg, heads, eval_pop))(keys)
        vgen = jax.vmap(lambda c: generation(c, None))

        def epoch(vcarry, g):
            vcarry, ys = vgen(vcarry)
            pop, fit, best_g, best_rc, arc, key = vcarry
            do = ((g + 1) % cfg.migrate_every) == 0
            # emigrant: each island's best individual, selected by a
            # one-hot sum (fitness is island-independent, so the fitness
            # travels with the genome)
            oh_b = (jnp.arange(pop_n)[None, :]
                    == jnp.argmax(fit, axis=1)[:, None])
            mig = jnp.sum(jnp.where(oh_b[:, :, None], pop, 0), axis=1)
            mig_fit = jnp.sum(jnp.where(oh_b, fit, 0.0), axis=1)
            in_g = jnp.roll(mig, 1, axis=0)
            in_f = jnp.roll(mig_fit, 1, axis=0)
            # immigrant replaces the receiving island's current worst
            oh_w = (jnp.arange(pop_n)[None, :]
                    == jnp.argmin(fit, axis=1)[:, None])
            sel = do & oh_w
            pop = jnp.where(sel[:, :, None], in_g[:, None, :], pop)
            fit = jnp.where(sel, in_f[:, None], fit)
            return (pop, fit, best_g, best_rc, arc, key), ys

        carry, hist = jax.lax.scan(epoch, carry0,
                                   jnp.arange(cfg.n_generations))
        (_, _, best_g, best_r, arc, _) = carry
        # scan stacks generations first; callers expect (islands, gens)
        hist = jax.tree_util.tree_map(
            lambda x: jnp.swapaxes(x, 0, 1), hist)
        return best_g, best_r, hist, arc

    best_g, best_r, hist, arc = jax.jit(run)(keys)
    history, stats = hist if cfg.telemetry else (hist, None)
    return EvoResult(best_design=ps.from_flat(best_g[:, : ps.N_PARAMS]),
                     best_reward=best_r, history=history, archive=arc,
                     best_genome=best_g, telemetry=stats)


def evolve_scenario_population(key, scenarios: cm.Scenario, n_islands: int,
                               env_cfg: chipenv.EnvConfig = chipenv.EnvConfig(),
                               cfg: EvoConfig = EvoConfig()) -> EvoResult:
    """S scenarios x N islands as ONE vmapped XLA program.

    ``scenarios`` carries a leading scenario axis S on every leaf;
    results (including the per-scenario archives) are stacked
    (S, n_islands). Mirrors ``sa.run_scenario_population``.
    """
    n_scen = jnp.shape(scenarios.weights.alpha)[0]
    keys = jax.random.split(key, int(n_scen))
    return jax.jit(jax.vmap(
        lambda k, s: evolve_population(k, n_islands, env_cfg, cfg, s)))(
            keys, scenarios)
