"""Production mesh definitions (assignment MULTI-POD DRY-RUN spec).

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state. The caller (only
``launch/dryrun.py`` and pod launchers) is responsible for having set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before the first
jax import when running the dry-run on CPU.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 (256-chip pod) or 2x16x16 (2-pod, 512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (launch/dryrun.py does this)")
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return Mesh(dev_array, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    """Small mesh for CI tests (8 fake devices)."""
    n = int(np.prod(shape))
    dev_array = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(dev_array, axes)
