import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Perf hillclimb runner (assignment §Perf): re-lowers a dry-run cell under
# tuning-flag overrides (models/tuning.py), one change at a time, and
# prints hypothesis -> before -> after per iteration. Results land next to
# the baselines as <arch>__<shape>__<mesh>__<tag>.json.

import argparse
import json

from repro.launch.dryrun import RESULTS_DIR, run_cell
from repro.models.tuning import tuned

# Iteration plans for the three selected cells + one bonus cell
# (EXPERIMENTS.md §Perf documents the selection criteria and the napkin
# math per hypothesis).
PLANS = {
    # 1. most representative of the paper's technique: the LLM-serving
    #    accelerator (the paper's own target domain is inference)
    ("llama3-8b", "decode_32k"): [
        ("opt1_grouped_gqa",
         dict(gqa_grouped_einsum=True),
         "H1: decode t_mem is dominated by jnp.repeat'ing the KV cache to "
         "32 q-heads (4x traffic for kv=8); grouped einsum removes it -> "
         "expect large memory-term drop"),
        ("opt2_batch_cache",
         dict(gqa_grouped_einsum=True, decode_batch_cache=True),
         "H2: seq-sharded cache update triggers GSPMD involuntary full "
         "rematerialization copies; batch-only cache sharding removes the "
         "resharding pair -> copy/DUS bytes way down"),
        ("opt3_bf16_einsum",
         dict(gqa_grouped_einsum=True, decode_bf16_einsum=True),
         "H3: the remaining 142 GB/dev is a bf16->f32 convert of the whole "
         "KV cache per layer (f32 score einsum); bf16 operands with fp32 "
         "accumulation (MXU-native) eliminate the converted copy -> "
         "expect t_mem toward the ~3 GB/dev cache+params floor"),
    ],
    # 2. most collective-bound cell (t_coll/t_comp = 3.0)
    ("qwen2-0.5b", "train_4k"): [
        ("opt1_loss_remat",
         dict(loss_remat=True),
         "H1: backward stacks per-chunk fp32 logits (8, B, 512, V/16) as "
         "scan residuals (2.5 GB/step/device); remat-ing the loss chunk "
         "recomputes them -> expect t_mem down ~2x, small t_coll change"),
        ("opt2_attn_remat",
         dict(loss_remat=True, attn_chunk_remat=True),
         "H2: per-chunk attention scores saved for backward add ~34 GB; "
         "nested chunk remat recomputes them -> t_mem down further"),
        ("opt3_pure_dp",
         dict(loss_remat=True, attn_chunk_remat=True, pure_dp=True),
         "H3: a 0.5B model over-sharded at TP=16 pays 38.7 GB/dev of "
         "activation all-reduce; pure 256-way DP replicates the 1 GB "
         "params and reduces only ~2 GB fp32 grads -> t_coll down ~10x"),
    ],
    # 3. worst roofline fraction among train cells (decode cells are
    #    intrinsically ~0 by the 2ND/step definition)
    ("mamba2-130m", "train_4k"): [
        ("opt1_loss_remat",
         dict(loss_remat=True),
         "H1: with a 0.13B model and a 50k vocab, stacked fp32 chunk "
         "logits dominate HBM traffic outright -> expect the largest "
         "single t_mem win of any cell"),
        ("opt2_attn_remat",
         dict(loss_remat=True, attn_chunk_remat=True),
         "H2 (control): mamba2 has no attention -> expect no change; "
         "validates H1's attribution"),
        ("opt3_pure_dp",
         dict(loss_remat=True, pure_dp=True),
         "H3: same over-sharding argument as qwen2 at 0.13B -> t_coll "
         "down ~10x, t_mem also down (no SP resharding)"),
    ],
    # bonus: largest absolute t_coll (EP all-to-alls + TP all-reduce)
    ("qwen3-moe-235b-a22b", "train_4k"): [
        ("opt1_loss_remat",
         dict(loss_remat=True),
         "H1: logits residuals as above"),
        ("opt2_attn_remat",
         dict(loss_remat=True, attn_chunk_remat=True),
         "H2: 40.8 TB/dev of score-like traffic (94 layers x 64 heads) "
         "-> nested remat should remove most of it"),
        ("opt3_capacity10",
         dict(loss_remat=True, attn_chunk_remat=True,
              moe_capacity_factor=1.0),
         "H3: MoE dispatch/combine einsums + all-to-alls scale with "
         "capacity; cf 1.25 -> 1.0 cuts dispatch traffic 20%"),
        ("opt4_scatter_dispatch",
         dict(loss_remat=True, attn_chunk_remat=True,
              moe_capacity_factor=1.0, moe_scatter_dispatch=True),
         "H4: the dense GShard one-hot dispatch einsums cost "
         "O(S*E*C*d) FLOPs = ~3.3x MODEL_FLOPS (6ND/HLO=0.30); "
         "index-based scatter/gather dispatch moves the same tokens with "
         "O(S*k*d) work -> expect t_comp down toward the 6ND floor"),
    ],
}


def iter_for(arch: str, shape: str):
    return PLANS.get((arch, shape), [])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all",
                    help="arch:shape or 'all' planned cells")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    args = ap.parse_args()

    cells = list(PLANS) if args.cell == "all" else [
        tuple(args.cell.split(":"))]
    multis = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    for arch, shape in cells:
        for multi in multis:
            mesh_name = "pod2x16x16" if multi else "pod16x16"
            base_path = os.path.join(
                args.out, f"{arch}__{shape}__{mesh_name}.json")
            with open(base_path) as f:
                base = json.load(f)["roofline"]
            print(f"\n=== {arch} x {shape} x {mesh_name} ===")
            print(f"baseline: t_c {base['t_compute']*1e3:.1f} ms | "
                  f"t_m {base['t_memory']*1e3:.1f} ms | "
                  f"t_coll {base['t_collective']*1e3:.1f} ms | "
                  f"bottleneck {base['bottleneck']}")
            prev = base
            for tag, overrides, hypothesis in iter_for(arch, shape):
                print(f"\n[{tag}] {hypothesis}")
                with tuned(**overrides):
                    rec = run_cell(arch, shape, multi, args.out,
                                   force=True, tag="__" + tag)
                if rec["status"] != "ok":
                    print(f"  FAILED: {rec.get('error')}")
                    continue
                rf = rec["roofline"]
                dom = prev["bottleneck"]
                key = {"compute": "t_compute", "memory": "t_memory",
                       "collective": "t_collective"}[dom]
                delta = (prev[key] - rf[key]) / max(prev[key], 1e-12)
                print(f"  t_c {rf['t_compute']*1e3:9.1f} ms | "
                      f"t_m {rf['t_memory']*1e3:9.1f} ms | "
                      f"t_coll {rf['t_collective']*1e3:7.1f} ms | "
                      f"dominant({dom}) {'-' if delta >= 0 else '+'}"
                      f"{abs(delta):.1%} | frac "
                      f"{rf['roofline_fraction']:.2%}")
                prev = rf


if __name__ == "__main__":
    main()
