import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the
# device count at first init). This module is the multi-pod dry-run:
# for every (architecture x input-shape x mesh) cell it lowers + compiles
# the real train/prefill/decode step against ShapeDtypeStruct inputs on
# the production mesh, prints memory/cost analysis, and emits the
# roofline JSON consumed by EXPERIMENTS.md.

import argparse
import functools
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.analysis import roofline as RL
from repro.configs import ARCH_REGISTRY, EXTRA_REGISTRY, SHAPES_BY_NAME
from repro.configs.base import ArchConfig, ShapeConfig, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.parallel import sharding as shd
from repro.training import trainer as T

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")
ENC_LEN = 4096          # fixed encoder context for enc-dec decode cells


# ---------------------------------------------------------------------------
# per-cell sharding rules
# ---------------------------------------------------------------------------

def cell_rules(mesh, shape: ShapeConfig) -> shd.ShardingRules:
    from repro.models.tuning import TUNING
    if TUNING.pure_dp:
        all_axes = tuple(mesh.axis_names)
        n_dev = mesh.devices.size
        if shape.global_batch % n_dev == 0:
            return shd.ShardingRules(
                batch=all_axes, seq=None, heads=None, ff=None,
                vocab=None, experts=None, kv_seq=None)
        # fall through to standard rules if the batch cannot cover the mesh
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    total = 1
    for a in data_axes:
        total *= mesh.shape[a]
    if shape.global_batch % total == 0:
        batch = data_axes
    elif shape.global_batch % mesh.shape[data_axes[-1]] == 0:
        batch = (data_axes[-1],)
    else:
        batch = ()                      # replicate tiny batches (long_500k)
    return shd.ShardingRules(batch=batch if batch else (None,))


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------

def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(arch: ArchConfig, shape: ShapeConfig) -> Dict:
    """Batch inputs for the step of this cell (assignment deliverable)."""
    b, l = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        text_len = l - (arch.frontend_tokens
                        if arch.frontend == "vision_patches" else 0)
        batch = {
            "tokens": sds((b, text_len), jnp.int32),
            "labels": sds((b, text_len), jnp.int32),
            "loss_mask": sds((b, text_len), jnp.float32),
        }
        if arch.frontend == "vision_patches":
            batch["patch_embeds"] = sds((b, arch.frontend_tokens,
                                         arch.d_model), jnp.bfloat16)
        if arch.is_encdec:
            batch["enc_frames"] = sds((b, l, arch.d_model), jnp.bfloat16)
        return batch
    if shape.kind == "prefill":
        text_len = l - (arch.frontend_tokens
                        if arch.frontend == "vision_patches" else 0)
        batch = {"tokens": sds((b, text_len), jnp.int32)}
        if arch.frontend == "vision_patches":
            batch["patch_embeds"] = sds((b, arch.frontend_tokens,
                                         arch.d_model), jnp.bfloat16)
        if arch.is_encdec:
            batch["enc_frames"] = sds((b, l, arch.d_model), jnp.bfloat16)
        return batch
    # decode: one new token against a seq_len KV cache
    batch = {"token": sds((b,), jnp.int32), "pos": sds((), jnp.int32)}
    if arch.is_encdec:
        batch["enc_out"] = sds((b, ENC_LEN, arch.d_model), jnp.bfloat16)
    return batch


# ---------------------------------------------------------------------------
# sharding trees
# ---------------------------------------------------------------------------

def _ns(mesh, p, shape):
    return NamedSharding(mesh, shd.best_effort_spec(mesh, p, shape))


def batch_input_shardings(mesh, rules, batch_sds) -> Dict:
    out = {}
    for k, v in batch_sds.items():
        if v.ndim == 0:
            out[k] = NamedSharding(mesh, P())
        else:
            out[k] = _ns(mesh, P(rules.batch, *([None] * (v.ndim - 1))),
                         v.shape)
    return out


_CACHE_SPECS = {
    "k": lambda r: P(None, r.batch, None, r.kv_seq, None),
    "v": lambda r: P(None, r.batch, None, r.kv_seq, None),
    "c_kv": lambda r: P(None, r.batch, r.kv_seq, None),
    "k_rope": lambda r: P(None, r.batch, None, r.kv_seq, None),
    "conv": lambda r: P(None, r.batch, None, None),
    "ssd": lambda r: P(None, r.batch, None, None),
}


def cache_shardings(mesh, rules, cache_shapes):
    from repro.models.tuning import TUNING
    if TUNING.decode_batch_cache:
        # batch-only sharding: no seq-dim resharding around cache updates
        rules = shd.ShardingRules(batch=rules.batch, kv_seq=None,
                                  seq=rules.seq, heads=rules.heads,
                                  ff=rules.ff, vocab=rules.vocab,
                                  experts=rules.experts)

    def one(path, leaf):
        name = None
        for part in reversed(path):
            key = getattr(part, "key", None)
            if isinstance(key, str):
                name = key
                break
        p = _CACHE_SPECS[name](rules) if name in _CACHE_SPECS else P()
        return _ns(mesh, p, leaf.shape)

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def metrics_shardings(mesh, tree):
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), tree)


# ---------------------------------------------------------------------------
# cell builders: returns (lowered, n_devices)
# ---------------------------------------------------------------------------

KEY_SDS = sds((2,), jnp.uint32)


def build_train_cell(arch: ArchConfig, shape: ShapeConfig, mesh, rules,
                     train_cfg: Optional[T.TrainConfig] = None):
    tc = train_cfg or T.TrainConfig(microbatches=1)
    state_shape = jax.eval_shape(
        lambda k: T.init_state(arch, tc, k), KEY_SDS)
    with shd.use_mesh(mesh, rules):
        state_sh = T.state_shardings(mesh, state_shape)
        batch_sds = input_specs(arch, shape)
        batch_sh = batch_input_shardings(mesh, rules, batch_sds)
        step = T.make_train_step(arch, tc)
        metrics_shape = jax.eval_shape(step, state_shape, batch_sds)[1]
        jitted = jax.jit(
            step,
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, metrics_shardings(mesh, metrics_shape)),
            donate_argnums=(0,))
        lowered = jitted.lower(state_shape, batch_sds)
    return lowered


def build_prefill_cell(arch: ArchConfig, shape: ShapeConfig, mesh, rules):
    b, l = shape.global_batch, shape.seq_len
    params_shape = jax.eval_shape(
        lambda k: M.init_params(arch, k, jnp.bfloat16), KEY_SDS)
    cache_shape = jax.eval_shape(
        lambda: M.init_cache(arch, b, l, jnp.bfloat16))

    def serve_prefill(params, batch, cache):
        logits, cache, enc_out = M.prefill(
            params, arch, batch["tokens"], cache,
            prefix_embeds=batch.get("patch_embeds"),
            enc_frames=batch.get("enc_frames"))
        return logits, cache

    with shd.use_mesh(mesh, rules):
        params_sh = T.param_shardings(mesh, params_shape)
        batch_sds = input_specs(arch, shape)
        batch_sh = batch_input_shardings(mesh, rules, batch_sds)
        cache_sh = cache_shardings(mesh, rules, cache_shape)
        logits_shape = jax.eval_shape(serve_prefill, params_shape,
                                      batch_sds, cache_shape)[0]
        logits_sh = _ns(mesh, P(rules.batch, rules.vocab),
                        logits_shape.shape)
        jitted = jax.jit(serve_prefill,
                         in_shardings=(params_sh, batch_sh, cache_sh),
                         out_shardings=(logits_sh, cache_sh),
                         donate_argnums=(2,))
        lowered = jitted.lower(params_shape, batch_sds, cache_shape)
    return lowered


def build_decode_cell(arch: ArchConfig, shape: ShapeConfig, mesh, rules):
    b, l = shape.global_batch, shape.seq_len
    params_shape = jax.eval_shape(
        lambda k: M.init_params(arch, k, jnp.bfloat16), KEY_SDS)
    cache_shape = jax.eval_shape(
        lambda: M.init_cache(arch, b, l, jnp.bfloat16))

    def serve_decode(params, batch, cache):
        logits, cache = M.decode_step(
            params, arch, batch["token"], batch["pos"], cache,
            enc_out=batch.get("enc_out"))
        return logits, cache

    with shd.use_mesh(mesh, rules):
        params_sh = T.param_shardings(mesh, params_shape)
        batch_sds = input_specs(arch, shape)
        batch_sh = batch_input_shardings(mesh, rules, batch_sds)
        cache_sh = cache_shardings(mesh, rules, cache_shape)
        logits_shape = jax.eval_shape(serve_decode, params_shape,
                                      batch_sds, cache_shape)[0]
        logits_sh = _ns(mesh, P(rules.batch, rules.vocab),
                        logits_shape.shape)
        jitted = jax.jit(serve_decode,
                         in_shardings=(params_sh, batch_sh, cache_sh),
                         out_shardings=(logits_sh, cache_sh),
                         donate_argnums=(2,))
        lowered = jitted.lower(params_shape, batch_sds, cache_shape)
    return lowered


def build_chipletgym_cell(mesh):
    """The paper's own technique: distributed PPO update on the mesh."""
    from repro.core import env as chipenv
    from repro.rl import distributed as dist
    from repro.rl import ppo
    from repro.training.optim import Adam

    cfg = ppo.PPOConfig(n_steps=128, n_envs=8, batch_size=64)
    env_cfg = chipenv.EnvConfig()
    optimizer = Adam(learning_rate=cfg.learning_rate,
                     max_grad_norm=cfg.max_grad_norm)
    carry_shape = jax.eval_shape(
        lambda k: dist.init_carry(k, mesh, env_cfg, cfg, optimizer),
        KEY_SDS)
    update = dist.make_pod_update(mesh, env_cfg, cfg, optimizer)
    return update.lower(carry_shape)


# ---------------------------------------------------------------------------
# the runner
# ---------------------------------------------------------------------------

def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             out_dir: str, force: bool = False,
             train_cfg: Optional[T.TrainConfig] = None,
             tag: str = "") -> Optional[dict]:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(
        out_dir, f"{arch_name}__{shape_name}__{mesh_name}{tag}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            cached = json.load(f)
        if cached.get("status") in ("ok", "skipped"):
            return cached           # only successful cells are cached

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    record = {"arch": arch_name, "shape": shape_name, "mesh": mesh_name,
              "n_devices": int(n_dev), "status": "started", "tag": tag}

    try:
        if arch_name == "chipletgym":
            arch = EXTRA_REGISTRY["chipletgym"]
            shape = ShapeConfig("rl_rollout", 128, 8 * n_dev, "train")
            t0 = time.time()
            lowered = build_chipletgym_cell(mesh)
        else:
            arch = ARCH_REGISTRY[arch_name]
            shape = SHAPES_BY_NAME[shape_name]
            ok, reason = shape_applicable(arch, shape)
            if not ok:
                record.update(status="skipped", reason=reason)
                with open(out_path, "w") as f:
                    json.dump(record, f, indent=2)
                return record
            rules = cell_rules(mesh, shape)
            t0 = time.time()
            if shape.kind == "train":
                lowered = build_train_cell(arch, shape, mesh, rules,
                                           train_cfg)
            elif shape.kind == "prefill":
                lowered = build_prefill_cell(arch, shape, mesh, rules)
            else:
                lowered = build_decode_cell(arch, shape, mesh, rules)
        lower_s = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        cost = {k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float))}
        mem = compiled.memory_analysis()
        mem_str = str(mem)
        hlo_text = compiled.as_text()
        # persist the optimized HLO so rooflines can be recomputed without
        # recompiling (analysis/reanalyze path + hillclimb diffing)
        import gzip
        hlo_dir = os.path.join(out_dir, "hlo")
        os.makedirs(hlo_dir, exist_ok=True)
        with gzip.open(os.path.join(
                hlo_dir, f"{arch_name}__{shape_name}__{mesh_name}{tag}"
                ".hlo.gz"), "wt") as f:
            f.write(hlo_text)
        print(mem_str)
        print({k: v for k, v in sorted(cost.items())
               if k in ("flops", "bytes accessed", "utilization")})

        if arch_name == "chipletgym":
            coll, breakdown = __import__(
                "repro.analysis.hlo", fromlist=["x"]).collective_bytes(
                    hlo_text)
            report_dict = {
                "collective_bytes": coll,
                "collective_breakdown": breakdown,
                "flops_per_device": cost.get("flops", 0.0),
                "bytes_per_device": cost.get("bytes accessed", 0.0),
            }
        else:
            report = RL.analyze(arch, shape, mesh_name, n_dev, cost,
                                hlo_text, mem_str)
            report_dict = report.to_dict()

        record.update(
            status="ok", lower_s=lower_s, compile_s=compile_s,
            cost=cost, memory_analysis=mem_str,
            hlo_bytes=len(hlo_text), roofline=report_dict)
    except Exception as e:                                # noqa: BLE001
        record.update(status="error", error=repr(e),
                      traceback=traceback.format_exc()[-4000:])
        print(f"[dryrun] FAILED {arch_name} {shape_name} {mesh_name}: {e}")

    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    return record


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = (sorted(ARCH_REGISTRY) + ["chipletgym"]) \
        if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES_BY_NAME) if args.shape == "all" \
        else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    summary = []
    for multi in meshes:
        for arch in archs:
            for shape in (["rl_rollout"] if arch == "chipletgym"
                          else shapes):
                t0 = time.time()
                rec = run_cell(arch, shape, multi, args.out,
                               force=args.force)
                status = rec["status"] if rec else "?"
                print(f"[dryrun] {arch:26s} {shape:12s} "
                      f"{'multi' if multi else 'single':6s} -> {status} "
                      f"({time.time()-t0:.0f}s)", flush=True)
                summary.append((arch, shape, multi, status))
    bad = [s for s in summary if s[3] not in ("ok", "skipped")]
    print(f"\n[dryrun] {len(summary)} cells: "
          f"{sum(1 for s in summary if s[3]=='ok')} ok, "
          f"{sum(1 for s in summary if s[3]=='skipped')} skipped, "
          f"{len(bad)} failed")
    for b in bad:
        print("  FAILED:", b)
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
