"""Training launcher: LM training, distributed Chiplet-Gym PPO, or a
scenario-suite sweep.

    # LM training (reduced config on CPU; full config on a pod):
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \\
        --steps 50 --reduced --ckpt-dir /tmp/ckpt

    # the paper's own workload — PPO over Chiplet-Gym, data-parallel
    # across all local devices:
    PYTHONPATH=src python -m repro.launch.train --arch chipletgym --steps 5

    # scenario-batched DSE: portfolio-optimize every (workload x
    # reward-weight) scenario in one vectorized engine, report per-scenario
    # winners + the cross-scenario Pareto frontier:
    PYTHONPATH=src python -m repro.launch.train --arch scenario-suite \\
        --workloads mlperf --smoke --out /tmp/suite.json

On a real pod this module is the per-host entrypoint
(jax.distributed.initialize + the same code path).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCH_REGISTRY
from repro.data.pipeline import DataConfig, DataLoader
from repro.training import trainer as T
from repro.training.compression import CompressionConfig


def train_chipletgym(args):
    from repro.core import env as chipenv
    from repro.rl import distributed as dist
    from repro.rl import ppo
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",))
    cfg = ppo.PPOConfig(n_steps=256, n_envs=8)
    print(f"[train] distributed PPO on {n_dev} device(s), "
          f"{n_dev * cfg.n_envs} parallel environments")
    carry, log = dist.train_distributed(
        jax.random.PRNGKey(args.seed), mesh, chipenv.EnvConfig(), cfg,
        n_updates=args.steps)
    for i, r in enumerate(log.mean_episodic_reward):
        print(f"  update {i}: mean episodic reward {float(r):.1f}, "
              f"best {float(log.best_reward[i]):.1f}")
    from repro.core import params as ps
    print("\nbest design:")
    print(ps.describe(ps.from_flat(carry.best_action)))


def train_scenario_suite(args):
    import dataclasses

    import jax as _jax

    from repro.optimizer import scenario as suite

    cfg = suite.SMOKE_SUITE if args.smoke else suite.SuiteConfig()
    workloads = tuple(args.workloads.split(","))
    overrides = {"workloads": workloads}
    if args.weights:
        try:
            grid = tuple(tuple(float(x) for x in w.split(":"))
                         for w in args.weights.split(","))
            if any(len(w) != 3 for w in grid):
                raise ValueError
        except ValueError:
            raise SystemExit(
                f"--weights must be a comma list of alpha:beta:gamma "
                f"triples, e.g. 1:1:0.1,2:0.5:0.1 (got {args.weights!r})")
        overrides["weight_grid"] = grid
    if args.trace:
        from repro.core import traffic as tr
        tcfg = tr.resolve_trace(args.trace)
        if args.trace_steps or args.trace_load:
            tcfg = dataclasses.replace(
                tcfg,
                n_steps=args.trace_steps or tcfg.n_steps,
                load=args.trace_load or tcfg.load)
        overrides["trace"] = tcfg
    if args.surrogate:
        from repro.surrogate import ranker as srk
        from repro.surrogate import train as strain
        overrides["surrogate"] = (
            srk.SurrogateConfig(pool_size=16384, top_k=64, bootstrap=1024,
                                capacity=8192,
                                train=strain.TrainConfig(steps=800,
                                                         batch_size=512))
            if args.smoke else srk.SurrogateConfig())
    if args.telemetry:
        # journal wants the in-scan counters from every stage that has
        # them; the off-by-default flags flip on together here (each one
        # alone is bit-exact off, and on they only read computed values)
        overrides_tl = {
            "placement_sa": dataclasses.replace(cfg.placement_sa,
                                                telemetry=True),
            "evo": dataclasses.replace(cfg.evo, telemetry=True),
            "rl": dataclasses.replace(cfg.rl, telemetry=True),
        }
        overrides.update(overrides_tl)
    cfg = dataclasses.replace(cfg, **overrides)
    cfg = suite.with_hw_preset(cfg, args.hw_preset)
    print(f"[suite] workloads={workloads} x {len(cfg.weight_grid)} "
          f"weight settings, n_sa={cfg.n_sa}, n_rl={cfg.n_rl}, "
          f"surrogate={'on' if cfg.surrogate is not None else 'off'}, "
          f"trace={args.trace or 'off'}, hw-preset={args.hw_preset}")
    journal = None
    if args.telemetry:
        from repro.telemetry import journal as tj
        journal = tj.Journal(args.telemetry)
        print(f"[suite] telemetry journal -> {args.telemetry} "
              f"(run {journal.run_id})")
    try:
        res = suite.run_suite(_jax.random.PRNGKey(args.seed), cfg,
                              verbose=True, journal=journal)
    finally:
        if journal is not None:
            journal.close()
    print()
    print(suite.format_report(res))
    if args.out:
        suite.save_json(res, args.out)
        print(f"\n[suite] wrote {args.out}")
    if args.telemetry:
        print(f"[suite] render the journal with: "
              f"python scripts/telemetry_report.py {args.telemetry}")


def train_evo(args):
    """Standalone evolutionary arm: one GA + live Pareto archive per
    (workload x default weighting) scenario, all scenarios vmapped into
    one XLA program."""
    import jax as _jax
    import numpy as np

    from repro.core import costmodel as cm
    from repro.core import env as chipenv
    from repro.core import params as ps
    from repro.core import workload as wl
    from repro.optimizer import archive as ar
    from repro.optimizer import evo
    from repro.optimizer import scenario as suite

    wl_names, workloads = wl.resolve(tuple(args.workloads.split(",")))
    scenarios = cm.stack_scenarios(
        [cm.Scenario(workload=w) for w in workloads])
    cfg = evo.EvoConfig(pop_size=args.pop, n_generations=args.generations)
    if args.smoke:
        cfg = evo.EvoConfig(pop_size=8, n_generations=6,
                            archive_capacity=32)
    env_cfg = chipenv.EnvConfig(hw=suite.HW_PRESETS[args.hw_preset])
    print(f"[evo] {len(wl_names)} workloads x GA(pop={cfg.pop_size}, "
          f"generations={cfg.n_generations}), archive capacity "
          f"{cfg.archive_capacity}, hw-preset={args.hw_preset}")
    res = evo.evolve_scenario_population(
        _jax.random.PRNGKey(args.seed), scenarios, 1, env_cfg, cfg)
    for i, name in enumerate(wl_names):
        arc = _jax.tree_util.tree_map(lambda x: x[i, 0], res.archive)
        hv = float(ar.hypervolume(arc, ar.nadir_ref(arc.points, arc.valid)))
        print(f"  [evo] {name}: best reward "
              f"{float(res.best_reward[i, 0]):.1f}, archive "
              f"{int(arc.n_valid)} points, hypervolume {hv:.4g}")
    top = int(np.argmax(np.asarray(res.best_reward)[:, 0]))
    print(f"\nbest design ({wl_names[top]}):")
    print(ps.describe(_jax.tree_util.tree_map(
        lambda x: x[top, 0], res.best_design)))


def train_lm(args):
    arch = ARCH_REGISTRY[args.arch]
    if args.reduced:
        arch = arch.reduced()
    cfg = T.TrainConfig(
        learning_rate=args.lr,
        warmup_steps=max(args.steps // 20, 2),
        total_steps=args.steps,
        microbatches=args.microbatches,
        checkpoint_every=max(args.steps // 4, 10),
        compression=CompressionConfig(scheme=args.compression),
        param_dtype=jnp.float32 if args.reduced else jnp.bfloat16)
    data = DataLoader(DataConfig(batch_size=args.batch_size,
                                 seq_len=args.seq_len,
                                 vocab_size=arch.vocab_size), arch=arch)
    T.train_loop(arch, cfg, data, ckpt_dir=args.ckpt_dir,
                 n_steps=args.steps, key=jax.random.PRNGKey(args.seed))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chipletgym")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workloads", default="mlperf",
                    help="comma list of registry names / groups "
                         "(mlperf, archs:decode, archs:train, all)")
    ap.add_argument("--weights", default=None,
                    help="comma list of alpha:beta:gamma reward weightings")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny suite scale for CI")
    ap.add_argument("--pop", type=int, default=32,
                    help="GA population size (--arch evo)")
    ap.add_argument("--generations", type=int, default=50,
                    help="GA generations (--arch evo)")
    ap.add_argument("--hw-preset", default="default",
                    choices=["default", "placement-sensitive"],
                    help="scenario-suite HW calibration preset "
                         "(placement-sensitive: paper-literal Eq.-13 "
                         "traffic + amortization exponent 1)")
    ap.add_argument("--surrogate", action="store_true",
                    help="scenario-suite: add the learned-surrogate "
                         "front-filter arm (surrogate-rank a large pool, "
                         "analytically re-score the top-k; winners stay "
                         "analytic-scored)")
    ap.add_argument("--trace", default=None,
                    choices=["flat", "diurnal", "bursty", "multi-tenant"],
                    help="scenario-suite: score every scenario against a "
                         "sampled serving traffic trace (core/traffic.py) "
                         "instead of a point workload; adds SLO attainment "
                         "to the archive objectives")
    ap.add_argument("--trace-steps", type=int, default=None,
                    help="trace length T (default: preset's 32)")
    ap.add_argument("--trace-load", type=float, default=None,
                    help="mean offered load vs the monolithic baseline "
                         "rate (default: preset's 1.5)")
    ap.add_argument("--out", default=None,
                    help="write the scenario-suite JSON report here")
    ap.add_argument("--telemetry", default=None, metavar="OUT.jsonl",
                    help="scenario-suite: write a structured run journal "
                         "(JSONL spans/events; telemetry/journal.py) here "
                         "and switch the in-scan counters on for every "
                         "stage that has them; render with "
                         "scripts/telemetry_report.py")
    args = ap.parse_args()
    if args.arch == "chipletgym":
        train_chipletgym(args)
    elif args.arch == "scenario-suite":
        train_scenario_suite(args)
    elif args.arch == "evo":
        train_evo(args)
    else:
        train_lm(args)


if __name__ == "__main__":
    main()
