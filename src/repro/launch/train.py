"""Training launcher: LM training or distributed Chiplet-Gym PPO.

    # LM training (reduced config on CPU; full config on a pod):
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \\
        --steps 50 --reduced --ckpt-dir /tmp/ckpt

    # the paper's own workload — PPO over Chiplet-Gym, data-parallel
    # across all local devices:
    PYTHONPATH=src python -m repro.launch.train --arch chipletgym --steps 5

On a real pod this module is the per-host entrypoint
(jax.distributed.initialize + the same code path).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCH_REGISTRY
from repro.data.pipeline import DataConfig, DataLoader
from repro.training import trainer as T
from repro.training.compression import CompressionConfig


def train_chipletgym(args):
    from repro.core import env as chipenv
    from repro.rl import distributed as dist
    from repro.rl import ppo
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",))
    cfg = ppo.PPOConfig(n_steps=256, n_envs=8)
    print(f"[train] distributed PPO on {n_dev} device(s), "
          f"{n_dev * cfg.n_envs} parallel environments")
    carry, log = dist.train_distributed(
        jax.random.PRNGKey(args.seed), mesh, chipenv.EnvConfig(), cfg,
        n_updates=args.steps)
    for i, r in enumerate(log.mean_episodic_reward):
        print(f"  update {i}: mean episodic reward {float(r):.1f}, "
              f"best {float(log.best_reward[i]):.1f}")
    from repro.core import params as ps
    print("\nbest design:")
    print(ps.describe(ps.from_flat(carry.best_action)))


def train_lm(args):
    arch = ARCH_REGISTRY[args.arch]
    if args.reduced:
        arch = arch.reduced()
    cfg = T.TrainConfig(
        learning_rate=args.lr,
        warmup_steps=max(args.steps // 20, 2),
        total_steps=args.steps,
        microbatches=args.microbatches,
        checkpoint_every=max(args.steps // 4, 10),
        compression=CompressionConfig(scheme=args.compression),
        param_dtype=jnp.float32 if args.reduced else jnp.bfloat16)
    data = DataLoader(DataConfig(batch_size=args.batch_size,
                                 seq_len=args.seq_len,
                                 vocab_size=arch.vocab_size), arch=arch)
    T.train_loop(arch, cfg, data, ckpt_dir=args.ckpt_dir,
                 n_steps=args.steps, key=jax.random.PRNGKey(args.seed))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chipletgym")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.arch == "chipletgym":
        train_chipletgym(args)
    else:
        train_lm(args)


if __name__ == "__main__":
    main()
