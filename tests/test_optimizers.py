"""PPO / SA / portfolio optimizer tests (paper §4, Algorithms 1-2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costmodel as cm
from repro.core import env as chipenv
from repro.core import params as ps
from repro.optimizer import portfolio
from repro.rl import networks as nets
from repro.rl import ppo
from repro.sa import annealing as sa


class TestNetworks:
    def test_shapes(self):
        params = nets.init_actor_critic(jax.random.PRNGKey(0))
        obs = jnp.zeros((5, chipenv.OBS_DIM))
        logits, value = nets.policy_value(params, obs)
        assert logits.shape == (5, ps.TOTAL_LOGITS)
        assert value.shape == (5,)

    def test_action_sampling_in_range(self):
        params = nets.init_actor_critic(jax.random.PRNGKey(0))
        obs = jnp.zeros((64, chipenv.OBS_DIM))
        logits, _ = nets.policy_value(params, obs)
        a = nets.sample_action(jax.random.PRNGKey(1), logits)
        assert a.shape == (64, ps.N_PARAMS)
        assert chipenv.action_space.contains(np.asarray(a))

    def test_log_prob_matches_manual(self):
        params = nets.init_actor_critic(jax.random.PRNGKey(0))
        obs = jax.random.normal(jax.random.PRNGKey(2), (3, chipenv.OBS_DIM))
        logits, _ = nets.policy_value(params, obs)
        a = nets.sample_action(jax.random.PRNGKey(3), logits)
        lp = nets.log_prob(logits, a)
        manual = 0.0
        for i, head in enumerate(nets.split_logits(logits)):
            logp = jax.nn.log_softmax(head, -1)
            manual = manual + logp[jnp.arange(3), a[:, i]]
        np.testing.assert_allclose(np.asarray(lp), np.asarray(manual),
                                   rtol=1e-5)

    def test_entropy_positive_at_init(self):
        params = nets.init_actor_critic(jax.random.PRNGKey(0))
        obs = jnp.zeros((1, chipenv.OBS_DIM))
        logits, _ = nets.policy_value(params, obs)
        ent = float(nets.entropy(logits)[0])
        # near-uniform at init: entropy ~ sum(log(head_sizes)) ~ 42 nats
        expected = sum(np.log(h) for h in ps.HEAD_SIZES)
        assert ent == pytest.approx(expected, rel=0.05)


class TestEnv:
    def test_reset_step(self):
        state, obs = chipenv.reset(jax.random.PRNGKey(0))
        assert obs.shape == (chipenv.OBS_DIM,)
        action = chipenv.action_space.sample(jax.random.PRNGKey(1))
        state, obs, r, done, metrics = chipenv.step(state, action)
        assert obs.shape == (chipenv.OBS_DIM,)
        assert np.isfinite(float(r))
        assert not bool(done)
        state, _, _, done, _ = chipenv.step(state, action)
        assert bool(done)   # episode length 2 (paper Fig. 7)

    def test_vec_env(self):
        venv = chipenv.VecEnv(16)
        states, obs = venv.reset(jax.random.PRNGKey(0))
        assert obs.shape == (16, chipenv.OBS_DIM)
        actions = chipenv.action_space.sample(jax.random.PRNGKey(1), (16,))
        states, obs, r, done, _ = venv.step(states, actions)
        assert r.shape == (16,)

    def test_reward_equals_costmodel(self):
        state, _ = chipenv.reset(jax.random.PRNGKey(0))
        action = chipenv.action_space.sample(jax.random.PRNGKey(1))
        _, _, r, _, _ = chipenv.step(state, action)
        expect = cm.reward_only(ps.from_flat(action))
        np.testing.assert_allclose(float(r), float(expect), rtol=1e-6)


class TestSA:
    def test_improves_over_random(self):
        key = jax.random.PRNGKey(0)
        res = sa.run(key, cfg=sa.SAConfig(n_iters=5000))
        # random designs average well below 100; SA should beat 150
        assert float(res.best_reward) > 150.0

    def test_history_monotone(self):
        res = sa.run(jax.random.PRNGKey(1), cfg=sa.SAConfig(n_iters=3000))
        h = np.asarray(res.history)
        assert (np.diff(h) >= -1e-5).all()

    def test_population_stacks(self):
        res = sa.run_population(jax.random.PRNGKey(2), 4,
                                cfg=sa.SAConfig(n_iters=1000))
        assert res.best_reward.shape == (4,)

    def test_best_design_valid(self):
        res = sa.run(jax.random.PRNGKey(3), cfg=sa.SAConfig(n_iters=1000))
        flat = np.asarray(ps.to_flat(res.best_design))
        assert chipenv.action_space.contains(flat)


class TestPPO:
    def test_learns(self):
        cfg = ppo.PPOConfig(n_steps=128, n_envs=8, batch_size=64)
        res = ppo.train(jax.random.PRNGKey(0), cfg=cfg,
                        total_timesteps=128 * 8 * 6)
        r = np.asarray(res.log.mean_episodic_reward)
        assert r[-1] > r[0]            # reward increases
        assert float(res.best_reward) > 150.0

    def test_best_design_valid(self):
        cfg = ppo.PPOConfig(n_steps=64, n_envs=4, batch_size=32)
        res = ppo.train(jax.random.PRNGKey(1), cfg=cfg,
                        total_timesteps=64 * 4 * 2)
        flat = np.asarray(ps.to_flat(res.best_design))
        assert chipenv.action_space.contains(flat)

    def test_gae_shapes_and_terminal(self):
        T, E = 8, 3
        traj = ppo.Rollout(
            obs=jnp.zeros((T, E, chipenv.OBS_DIM)),
            actions=jnp.zeros((T, E, ps.N_PARAMS), jnp.int32),
            log_probs=jnp.zeros((T, E)),
            values=jnp.zeros((T, E)),
            rewards=jnp.ones((T, E)),
            dones=jnp.ones((T, E)),          # every step terminal
        )
        adv, ret = ppo.compute_gae(traj, jnp.zeros(E), ppo.PPOConfig())
        # with V=0 and every step terminal, advantage == reward
        np.testing.assert_allclose(np.asarray(adv), 1.0, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(ret), 1.0, rtol=1e-6)


class TestPortfolio:
    def test_runs_and_refines(self):
        from repro.optimizer import evo
        cfg = portfolio.PortfolioConfig(
            n_sa=2, n_rl=1,
            sa=sa.SAConfig(n_iters=2000),
            rl=ppo.PPOConfig(n_steps=64, n_envs=4, batch_size=32),
            rl_timesteps=64 * 4 * 2,
            evo=evo.EvoConfig(pop_size=8, n_generations=5),
            refine=True, max_refine_sweeps=2)
        res = portfolio.optimize(jax.random.PRNGKey(0), cfg=cfg)
        assert res.best_reward >= max(res.sa_rewards.max(),
                                      res.rl_rewards.max(),
                                      res.evo_rewards.max()) - 1e-5
        assert res.source in ("sa", "rl", "evo", "refined")
        flat = np.asarray(ps.to_flat(res.best_design))
        assert chipenv.action_space.contains(flat)

    def test_coordinate_refine_never_worsens(self):
        flat = jnp.zeros((ps.N_PARAMS,), jnp.int32)
        env_cfg = chipenv.EnvConfig()
        r0 = float(cm.reward_only(ps.from_flat(flat)))
        _, r1 = portfolio.coordinate_refine(flat, env_cfg, max_sweeps=1)
        assert r1 >= r0
