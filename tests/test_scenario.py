"""Tests for the scenario-batched DSE engine (Scenario pytrees, vmapped
PPO population, scenario-batched evaluation, ScenarioSuite)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel as cm
from repro.core import env as chipenv
from repro.core import params as ps
from repro.core import workload as wl
from repro.optimizer import portfolio
from repro.optimizer import scenario as suite
from repro.rl import ppo
from repro.sa import annealing as sa

TINY_PPO = ppo.PPOConfig(n_steps=32, n_envs=2, batch_size=32)
TINY_STEPS = 32 * 2 * 2


def _scenarios(n_workloads=3):
    names = list(wl.MLPERF)[:n_workloads]
    scalars = [cm.Scenario(workload=wl.MLPERF[n],
                           weights=cm.make_weights(1.0, 1.0, 0.1))
               for n in names]
    return names, cm.stack_scenarios(scalars)


class TestScenarioBatchedEval:
    def test_matches_per_scenario_scalar(self):
        names, scen = _scenarios()
        dp = ps.random_design(jax.random.PRNGKey(0))
        batched = cm.evaluate_scenarios(dp, scen)
        for i, n in enumerate(names):
            single = cm.evaluate(dp, wl.MLPERF[n], cm.RewardWeights())
            np.testing.assert_allclose(float(batched.reward[i]),
                                       float(single.reward), rtol=1e-6)
            np.testing.assert_allclose(float(batched.tasks_per_sec[i]),
                                       float(single.tasks_per_sec), rtol=1e-6)

    def test_batched_designs_pair_with_scenarios(self):
        names, scen = _scenarios()
        dps = ps.random_design(jax.random.PRNGKey(1), (len(names),))
        batched = cm.evaluate_scenarios(dps, scen)
        for i, n in enumerate(names):
            dp_i = jax.tree_util.tree_map(lambda x: x[i], dps)
            single = cm.evaluate(dp_i, wl.MLPERF[n], cm.RewardWeights())
            np.testing.assert_allclose(float(batched.reward[i]),
                                       float(single.reward), rtol=1e-6)

    def test_weight_grid_changes_reward_only(self):
        dp = ps.random_design(jax.random.PRNGKey(2))
        scalars = [cm.Scenario(weights=cm.make_weights(a, 1.0, 0.1))
                   for a in (0.5, 1.0, 2.0)]
        m = cm.evaluate_scenarios(dp, cm.stack_scenarios(scalars))
        # physics identical across weight settings, reward differs
        assert np.ptp(np.asarray(m.tasks_per_sec)) == 0.0
        assert np.ptp(np.asarray(m.reward)) > 0.0


class TestEnvScenario:
    def test_explicit_scenario_matches_config_default(self):
        key = jax.random.PRNGKey(0)
        cfg = chipenv.EnvConfig()
        s1, o1 = chipenv.reset(key, cfg)
        s2, o2 = chipenv.reset(key, cfg, cfg.scenario())
        np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
        a = chipenv.action_space.sample(jax.random.PRNGKey(1))
        _, _, r1, _, _ = chipenv.step(s1, a, cfg)
        _, _, r2, _, _ = chipenv.step(s2, a, cfg, cfg.scenario())
        np.testing.assert_allclose(float(r1), float(r2))

    def test_vmapped_scenarios_one_program(self):
        _, scen = _scenarios()
        keys = jax.random.split(jax.random.PRNGKey(3), 3)
        states, obs = jax.vmap(
            lambda k, s: chipenv.reset(k, chipenv.EnvConfig(), s)
        )(keys, scen)
        assert obs.shape == (3, chipenv.OBS_DIM)


class TestTrainPopulation:
    def test_matches_sequential_seed_for_seed(self):
        key = jax.random.PRNGKey(5)
        pop = ppo.train_population(key, 2, cfg=TINY_PPO,
                                   total_timesteps=TINY_STEPS)
        keys = jax.random.split(key, 2)
        for i in range(2):
            seq = ppo.train(keys[i], cfg=TINY_PPO,
                            total_timesteps=TINY_STEPS)
            np.testing.assert_allclose(float(pop.best_reward[i]),
                                       float(seq.best_reward), rtol=1e-4)
            np.testing.assert_array_equal(
                np.asarray(ps.to_flat(pop.best_design))[i],
                np.asarray(ps.to_flat(seq.best_design)))

    def test_population_shapes(self):
        pop = ppo.train_population(jax.random.PRNGKey(6), 3, cfg=TINY_PPO,
                                   total_timesteps=TINY_STEPS)
        assert pop.best_reward.shape == (3,)
        assert ps.to_flat(pop.best_design).shape == (3, ps.N_PARAMS)
        assert chipenv.action_space.contains(
            np.asarray(ps.to_flat(pop.best_design))[0])

    def test_scenario_population_shapes(self):
        _, scen = _scenarios(2)
        res = ppo.train_scenario_population(
            jax.random.PRNGKey(7), scen, 2, cfg=TINY_PPO,
            total_timesteps=TINY_STEPS)
        assert res.best_reward.shape == (2, 2)


class TestSAScenario:
    def test_scenario_population_shapes(self):
        _, scen = _scenarios(2)
        res = sa.run_scenario_population(
            jax.random.PRNGKey(8), scen, 3, cfg=sa.SAConfig(n_iters=500))
        assert res.best_reward.shape == (2, 3)

    def test_scenario_matches_env_cfg(self):
        w = wl.MLPERF["bert"]
        env_cfg = chipenv.EnvConfig(workload=w)
        r1 = sa.run(jax.random.PRNGKey(9), env_cfg,
                    sa.SAConfig(n_iters=300))
        r2 = sa.run(jax.random.PRNGKey(9), chipenv.EnvConfig(),
                    sa.SAConfig(n_iters=300),
                    scenario=cm.Scenario(workload=w))
        np.testing.assert_allclose(float(r1.best_reward),
                                   float(r2.best_reward))


class TestPortfolioVectorized:
    def test_optimize_uses_population_and_refines(self):
        from repro.optimizer import evo
        cfg = portfolio.PortfolioConfig(
            n_sa=2, n_rl=2, sa=sa.SAConfig(n_iters=1000),
            rl=TINY_PPO, rl_timesteps=TINY_STEPS,
            evo=evo.EvoConfig(pop_size=8, n_generations=5),
            refine=True, max_refine_sweeps=1)
        res = portfolio.optimize(jax.random.PRNGKey(0), cfg=cfg)
        assert res.rl_rewards.shape == (2,)
        assert res.best_reward >= max(res.sa_rewards.max(),
                                      res.rl_rewards.max()) - 1e-5

    def test_coordinate_refine_never_decreases_with_scenario(self):
        flat = jnp.zeros((ps.N_PARAMS,), jnp.int32)
        env_cfg = chipenv.EnvConfig()
        scen = cm.Scenario(workload=wl.MLPERF["bert"])
        r0 = float(cm.reward_only(ps.from_flat(flat), scen.workload,
                                  scen.weights))
        _, r1 = portfolio.coordinate_refine(flat, env_cfg, max_sweeps=1,
                                            scenario=scen)
        assert r1 >= r0


class TestSuite:
    def test_pareto_indices(self):
        pts = np.array([[10.0, 1.0, 5.0],    # frontier
                        [5.0, 1.0, 5.0],     # dominated by row 0
                        [10.0, 0.5, 9.0],    # frontier (better energy)
                        [1.0, 2.0, 9.0]])    # dominated by row 0
        idx = suite.pareto_indices(pts, maximize=(True, False, False))
        assert idx == [0, 2]

    def test_build_scenarios_grid(self):
        cfg = dataclasses.replace(
            suite.SMOKE_SUITE, workloads=("resnet50", "bert"),
            weight_grid=((1, 1, 0.1), (2, 1, 0.1), (1, 2, 0.1)))
        names, wnames, scen = suite.build_scenarios(cfg)
        assert len(names) == 6
        assert scen.weights.alpha.shape == (6,)
        assert wnames[0] == wnames[1] == wnames[2] == "resnet50"

    def test_run_suite_smoke(self):
        cfg = dataclasses.replace(
            suite.SMOKE_SUITE, workloads=("resnet50", "bert"),
            weight_grid=((1.0, 1.0, 0.1), (2.0, 0.5, 0.1)),
            n_sa=2, n_rl=1, sa=sa.SAConfig(n_iters=500),
            rl=TINY_PPO, rl_timesteps=TINY_STEPS,
            refine=True, max_refine_sweeps=1)
        res = suite.run_suite(jax.random.PRNGKey(0), cfg)
        assert len(res.outcomes) == 4
        assert 1 <= len(res.pareto) <= 4
        assert 1 <= len(res.pareto_normalized) <= 4
        for o in res.outcomes:
            assert np.isfinite(o.best_reward)
            assert chipenv.action_space.contains(o.best_flat)
            # ISSUE-2 acceptance: placement-refined winners never score
            # below the canonical floorplan on any scenario
            assert o.best_reward >= o.reward_canonical - 1e-5
            assert o.placement_cells is not None
        report = suite.format_report(res)
        assert "Pareto" in report
        js = suite.to_json(res)
        assert len(js["scenarios"]) == 4
        assert js["scenarios"][0]["placement_cells"] is not None
