"""ISSUE-10 telemetry subsystem: in-scan counters, run journal, hooks.

Contracts under test:

- ``telemetry=False`` (every default) returns ``telemetry=None`` and the
  program is the exact pre-telemetry one — covered transitively by the
  recorded-trajectory regressions (tests/test_placement_delta.py runs
  the default config) and re-asserted here for GA/PPO on fixed seeds.
- ``telemetry=True`` must not perturb a single trajectory bit: the SA
  recorded oracle (tests/data_sa_trajectory.json) must still reproduce
  bit-for-bit with counters on, and GA/PPO results must equal their
  telemetry-off twins on every non-telemetry leaf.
- The counters themselves must be *correct*: a 50-step pure-Python
  replay of the SA proposal/accept stream (same 8-way key split) is the
  oracle for propose/accept/improve counts and the accept curve.
- The journal round-trips records through JSONL, nests spans, and keeps
  an ambient current journal; the report renderer produces the expected
  sections from a representative journal.
- ``costmodel`` eval taps fire on concrete evaluations only (the
  compat.is_tracer guard skips traced calls instead of leaking tracers).
- The acceptance-band adaptive scheduler (`adapt_schedule`) reshapes
  phase segments from measured rates and merges round counters.
"""

import importlib.util
import io
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costmodel as cm
from repro.core import env as chipenv
from repro.core import params as ps
from repro.core import placement as pm
from repro.core import workload as wl
from repro.sa import annealing as sa
from repro.telemetry import counters as tl
from repro.telemetry import journal as tj

_HERE = os.path.dirname(os.path.abspath(__file__))


def _load_report_module():
    spec = importlib.util.spec_from_file_location(
        "telemetry_report", os.path.join(
            _HERE, os.pardir, "scripts", "telemetry_report.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# SA: telemetry ON reproduces the recorded oracle bit-for-bit
# ---------------------------------------------------------------------------

class TestSATelemetryIdentity:
    """Counters only read values the step already computes: the recorded
    PR-4 trajectories must reproduce bit-for-bit with telemetry ON."""

    @pytest.fixture(scope="class")
    def ref(self):
        with open(os.path.join(_HERE, "data_sa_trajectory.json")) as f:
            return json.load(f)

    def test_off_returns_none(self):
        dp = ps.random_design(jax.random.PRNGKey(0))
        res = sa.refine_placement(
            jax.random.PRNGKey(1), dp, chipenv.EnvConfig(),
            sa.PlacementSAConfig(n_iters=50, record_every=25))
        assert res.telemetry is None
        assert sa.PlacementSAConfig().telemetry is False

    def test_suite_trajectory_bit_for_bit_with_telemetry(self, ref):
        from repro.optimizer import scenario as suite
        env_cfg = chipenv.EnvConfig(hw=suite.PLACEMENT_SENSITIVE_HW)
        scen = cm.stack_scenarios([
            cm.Scenario(workload=wl.MLPERF[n])
            for n in ref["suite"]["workloads"]])
        dps = ps.random_design(
            jax.random.PRNGKey(ref["suite"]["design_seed"]),
            (len(ref["suite"]["workloads"]),))
        cfg = sa.PlacementSAConfig(n_iters=ref["n_iters"],
                                   record_every=ref["record_every"],
                                   telemetry=True)
        res = sa.refine_placement_scenarios(
            jax.random.PRNGKey(ref["suite"]["key_seed"]), dps, scen,
            env_cfg, cfg)
        np.testing.assert_array_equal(
            np.asarray(res.history, np.float64),
            np.asarray(ref["suite"]["history"]))
        np.testing.assert_array_equal(
            np.asarray(res.best_reward, np.float64),
            np.asarray(ref["suite"]["best_reward"]))
        np.testing.assert_array_equal(
            np.asarray(res.best_placement.chiplet_cell),
            np.asarray(ref["suite"]["best_cells"]))
        # and the counters account for every proposal
        n_scen = len(ref["suite"]["workloads"])
        s = tl.summarize_sa(res.telemetry)
        assert sum(s["propose"]) == n_scen * ref["n_iters"]
        assert sum(s["seg_propose"]) == n_scen * ref["n_iters"]
        assert all(a <= p for a, p in zip(s["accept"], s["propose"]))

    def test_single_trajectory_bit_for_bit_with_telemetry(self, ref):
        dp = ps.random_design(
            jax.random.PRNGKey(ref["single"]["design_seed"]))
        cfg = sa.PlacementSAConfig(n_iters=ref["n_iters"],
                                   record_every=ref["record_every"],
                                   telemetry=True)
        res = sa.refine_placement(
            jax.random.PRNGKey(ref["single"]["key_seed"]), dp,
            chipenv.EnvConfig(), cfg)
        np.testing.assert_array_equal(
            np.asarray(res.history, np.float64),
            np.asarray(ref["single"]["history"]))
        assert float(res.best_reward) == ref["single"]["best_reward"]
        assert res.telemetry is not None
        assert int(np.sum(np.asarray(res.telemetry.propose))) \
            == ref["n_iters"]
        # the accept curve shares the history stride (plus final sample)
        assert res.telemetry.accept_curve.shape == res.history.shape


# ---------------------------------------------------------------------------
# SA: counter correctness vs a pure-Python replay oracle
# ---------------------------------------------------------------------------

class TestSACounterReplayOracle:
    """Replay 50 SA steps eagerly in Python — same 8-way key split, same
    accept rule — and require the in-scan counters to match exactly."""

    N_ITERS = 50
    RECORD = 10

    def _cfg(self, **kw):
        return sa.PlacementSAConfig(
            n_iters=self.N_ITERS, record_every=self.RECORD,
            profile_guided=False, telemetry=True, **kw)

    def _replay(self, key, design, env_cfg, cfg):
        """Eager re-implementation of the full-recompute SA chain."""
        scenario = env_cfg.scenario()
        v = ps.decode(design)
        n_pos = cm.footprint_positions(v)
        m, n = cm.mesh_dims(n_pos)
        plc = pm.canonical(m, n, v.hbm_mask, v.arch_type)
        r0 = cm.scenario_reward(design, scenario, env_cfg.hw,
                                nop_fidelity=env_cfg.nop_fidelity)
        r_curr = r_best = r0
        propose = np.zeros(2, np.int64)
        accept_n = np.zeros(2, np.int64)
        improve = 0
        curve = []
        for it in range(cfg.n_iters):
            (key, k_kind, k_slot, k_cell, k_bit, k_anchor, k_acc,
             k_mix) = jax.random.split(key, 8)
            slot = jax.random.randint(k_slot, (), 0, pm.MAX_SLOTS)
            cell = pm.random_cell_in_box(k_cell, m, n)
            anchor = pm.random_hbm_anchor(k_anchor, m, n)
            bit = pm.select_placed_bit(k_bit, v.hbm_mask)
            kind = int(jax.random.uniform(k_kind) < cfg.p_hbm)
            move = pm.PlacementMove(kind=jnp.int32(kind), slot=slot,
                                    cell=cell, hbm=bit, anchor=anchor)
            cand = pm.apply_move(plc, move, n_pos)
            r_cand = cm.scenario_reward(design, scenario, env_cfg.hw,
                                        cand)
            propose[kind] += 1
            if float(r_cand) > float(r_best):
                improve += 1
                r_best = r_cand
            t = cfg.temperature / (it + 1.0)
            acc = (float(r_cand) > float(r_curr)
                   or float(jax.random.uniform(k_acc)) < t)
            if acc:
                accept_n[kind] += 1
                plc, r_curr = cand, r_cand
            curve.append(int(accept_n.sum()))
        curve = np.asarray(curve)
        curve = np.concatenate([curve[:: cfg.record_every], curve[-1:]])
        return propose, accept_n, improve, curve

    def test_mixed_stream_counters_match_replay(self):
        design = ps.random_design(jax.random.PRNGKey(12))
        env_cfg = chipenv.EnvConfig()
        cfg = self._cfg(delta_eval=False)
        key = jax.random.PRNGKey(13)
        res = sa.refine_placement(key, design, env_cfg, cfg)
        propose, accept, improve, curve = self._replay(
            key, design, env_cfg, cfg)
        c = res.telemetry
        np.testing.assert_array_equal(np.asarray(c.propose), propose)
        np.testing.assert_array_equal(np.asarray(c.accept), accept)
        assert int(c.improve) == improve
        np.testing.assert_array_equal(np.asarray(c.seg_propose),
                                      [self.N_ITERS])
        np.testing.assert_array_equal(np.asarray(c.seg_accept),
                                      [int(accept.sum())])
        np.testing.assert_array_equal(np.asarray(c.accept_curve), curve)

    def test_delta_and_full_counters_agree(self):
        """The delta-evaluated chain must count identically to the
        full-recompute chain (their trajectories are bit-equal)."""
        design = ps.random_design(jax.random.PRNGKey(21))
        env_cfg = chipenv.EnvConfig()
        key = jax.random.PRNGKey(22)
        a = sa.refine_placement(key, design, env_cfg,
                                self._cfg(delta_eval=True)).telemetry
        b = sa.refine_placement(key, design, env_cfg,
                                self._cfg(delta_eval=False)).telemetry
        for fa, fb in zip(a, b):
            np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))

    def test_phased_counters_bin_per_segment(self):
        """Pinned segments book every proposal into their own bin with
        the pinned kind: (chiplet 4, hbm 1) over 50 iters -> exactly 40
        chiplet and 10 hbm proposals in the matching bins."""
        design = ps.random_design(jax.random.PRNGKey(31))
        cfg = self._cfg(phase_schedule=(("chiplet", 4), ("hbm", 1)))
        res = sa.refine_placement(jax.random.PRNGKey(32), design,
                                  chipenv.EnvConfig(), cfg)
        c = res.telemetry
        np.testing.assert_array_equal(np.asarray(c.propose), [40, 10])
        np.testing.assert_array_equal(np.asarray(c.seg_propose), [40, 10])
        np.testing.assert_array_equal(
            np.asarray(c.seg_accept),
            np.asarray(c.accept))         # segment kinds are disjoint
        assert int(np.asarray(c.accept_curve)[-1]) \
            == int(np.asarray(c.accept).sum())

    def test_improve_count_matches_history_at_stride_one(self):
        """At record_every=1 the history is the full best-so-far trace;
        the improve counter must equal its strict-increase count."""
        design = ps.random_design(jax.random.PRNGKey(41))
        cfg = sa.PlacementSAConfig(n_iters=50, record_every=1,
                                   telemetry=True)
        res = sa.refine_placement(jax.random.PRNGKey(42), design,
                                  chipenv.EnvConfig(), cfg)
        h = np.asarray(res.history, np.float64)
        start = float(res.canonical_reward)
        trace = np.concatenate([[start], h[: cfg.n_iters]])
        assert int(res.telemetry.improve) == int((np.diff(trace) > 0).sum())

    def test_summarize_handles_batch_axes(self):
        c = tl.init_sa(2)
        c = tl.sa_update(c, 0, True, True, 0)
        c = tl.sa_update(c, 1, False, False, 1)
        stacked = jax.tree_util.tree_map(
            lambda x: jnp.stack([x, x]), c)
        s = tl.summarize_sa(stacked)
        assert s["propose"] == [2, 2] and s["accept"] == [2, 0]
        assert s["improve"] == 2
        assert s["seg_propose"] == [2, 2]
        assert s["accept_rate"][0] == 1.0 and s["accept_rate"][1] == 0.0


# ---------------------------------------------------------------------------
# GA / PPO: telemetry never perturbs the fixed-seed result
# ---------------------------------------------------------------------------

class TestEvoPPOTelemetryIdentity:

    def test_evo_on_off_bitwise(self):
        from repro.optimizer import evo
        key = jax.random.PRNGKey(5)
        res = {}
        for on in (False, True):
            cfg = evo.EvoConfig(pop_size=8, n_generations=6,
                                archive_capacity=32, telemetry=on)
            res[on] = jax.jit(
                lambda k, _c=cfg: evo.evolve_population(k, 2, cfg=_c))(key)
        assert res[False].telemetry is None
        stats = res[True].telemetry
        assert stats is not None
        off = res[False]._replace(telemetry=None)
        on = res[True]._replace(telemetry=None)
        for a, b in zip(jax.tree_util.tree_leaves(off),
                        jax.tree_util.tree_leaves(on)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # stats sanity: per-generation, diversity is a fraction, the
        # archive hypervolume samples are finite and non-negative
        div = np.asarray(stats.diversity)
        assert div.shape[-1] == 6
        assert ((div >= 0.0) & (div <= 1.0)).all()
        hv = np.asarray(stats.archive_hv)
        assert np.isfinite(hv).all() and (hv >= 0.0).all()
        s = tl.summarize_evo(stats)
        assert len(s["diversity"]) == 6
        assert s["archive_inserts"] >= s["final_archive_n"] >= 0

    def test_ppo_on_off_bitwise(self):
        from repro.rl import ppo
        key = jax.random.PRNGKey(6)
        env_cfg = chipenv.EnvConfig()
        res = {}
        for on in (False, True):
            cfg = ppo.PPOConfig(n_steps=32, n_envs=2, telemetry=on)
            res[on] = ppo.train(key, env_cfg, cfg, total_timesteps=128)
        assert res[False].telemetry is None
        stats = res[True].telemetry
        assert stats is not None
        off = res[False]._replace(telemetry=None)
        on = res[True]._replace(telemetry=None)
        for a, b in zip(jax.tree_util.tree_leaves(off),
                        jax.tree_util.tree_leaves(on)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for leaf in (stats.entropy, stats.approx_kl, stats.clip_frac,
                     stats.return_mean):
            assert np.isfinite(np.asarray(leaf)).all()
        cf = np.asarray(stats.clip_frac)
        assert ((cf >= 0.0) & (cf <= 1.0)).all()
        s = tl.summarize_ppo(stats)
        assert set(s) == {"return_mean", "entropy", "approx_kl",
                          "clip_frac"}


# ---------------------------------------------------------------------------
# Placement-episode env counters
# ---------------------------------------------------------------------------

class TestEnvCounters:

    def _roll(self, delta, n_steps=10, episode_len=4):
        cfg = chipenv.EnvConfig(placement_episode=True, telemetry=True,
                                episode_len=episode_len, delta_eval=delta)
        key = jax.random.PRNGKey(3)
        state, _ = chipenv.reset(key, cfg)
        rng = np.random.RandomState(0)
        rewards, dones = [], []
        for _ in range(n_steps):
            act = jnp.asarray(
                rng.randint(0, 8, (chipenv.action_dim(cfg),)), jnp.int32)
            state, _, r, done, _ = chipenv.auto_reset_step(state, act, cfg)
            rewards.append(float(r))
            dones.append(bool(done))
        return cfg, state, rewards, dones

    @pytest.mark.parametrize("delta", [True, False])
    def test_counters_track_steps_episodes_and_pricing(self, delta):
        cfg, state, rewards, dones = self._roll(delta)
        c = state.tel
        assert int(c.steps) == 10
        assert int(c.episodes) == sum(dones) == 2      # resets at t=4, 8
        assert int(c.delta_evals) == (10 if delta else 0)
        assert int(c.scratch_evals) == (0 if delta else 10)
        np.testing.assert_allclose(float(c.best_reward), max(rewards),
                                   rtol=1e-6)
        np.testing.assert_allclose(float(c.reward_sum), sum(rewards),
                                   rtol=1e-5)
        s = tl.summarize_env(c)
        assert s["steps"] == 10 and s["episodes"] == 2

    def test_off_path_has_no_counter_state(self):
        cfg = chipenv.EnvConfig(placement_episode=True, episode_len=4)
        state, _ = chipenv.reset(jax.random.PRNGKey(3), cfg)
        assert state.tel is None


# ---------------------------------------------------------------------------
# Journal: JSONL round-trip, span nesting, ambient current journal
# ---------------------------------------------------------------------------

class TestJournal:

    def test_round_trip_and_nesting(self, tmp_path):
        path = tmp_path / "run.jsonl"
        with tj.Journal(path, run_id="t1") as j:
            with j.span("suite", n=2):
                j.event("arm_convergence", arm="sa",
                        curve=np.arange(3.0), best=jnp.float32(7.5))
                with j.span("placement"):
                    j.event("sa_accept", propose=[4, 1])
        recs = tj.load(path)
        kinds = [r["kind"] for r in recs]
        assert kinds == ["run_begin", "span_begin", "event",
                         "span_begin", "event", "span", "span",
                         "run_end"]
        assert all(r["run"] == "t1" for r in recs)
        env = recs[0]["env"]
        assert "jax" in env and "python" in env and "backend" in env
        conv = recs[2]
        assert conv["span"] == "suite"
        assert conv["curve"] == [0.0, 1.0, 2.0]     # ndarray -> list
        assert conv["best"] == 7.5                  # jax scalar -> float
        inner = recs[4]
        assert inner["span"] == "placement"
        spans = [r for r in recs if r["kind"] == "span"]
        assert spans[0]["name"] == "placement"
        assert spans[0]["parent"] == "suite"
        assert spans[1]["parent"] is None
        assert all(r["dur_s"] >= 0 for r in spans)

    def test_close_is_idempotent_and_blocks_writes(self, tmp_path):
        path = tmp_path / "run.jsonl"
        j = tj.Journal(path, run_id="t2")
        j.close()
        j.close()
        j.event("late")
        recs = tj.load(path)
        assert [r["kind"] for r in recs] == ["run_begin", "run_end"]

    def test_file_like_sink(self):
        buf = io.StringIO()
        j = tj.Journal(buf, run_id="t3", fingerprint=False)
        j.event("x", v=1)
        recs = [json.loads(line) for line in
                buf.getvalue().strip().splitlines()]
        assert recs == [{"ts": recs[0]["ts"], "run": "t3",
                         "kind": "event", "name": "x", "span": None,
                         "v": 1}]

    def test_ambient_current_journal(self):
        assert tj.current() is None
        buf = io.StringIO()
        j = tj.Journal(buf, fingerprint=False)
        with tj.use(j):
            assert tj.current() is j
            tj.current_or_null().event("deep")
            with tj.use(None):
                assert tj.current() is None
                tj.current_or_null().event("dropped")   # no-op, no error
        assert tj.current() is None
        names = [json.loads(line)["name"]
                 for line in buf.getvalue().strip().splitlines()]
        assert names == ["deep"]

    def test_null_journal_is_inert(self):
        assert tj.or_null(None) is tj.NULL
        with tj.NULL.span("anything", x=1) as s:
            s.event("nothing")
        j = tj.Journal(io.StringIO(), fingerprint=False)
        assert tj.or_null(j) is j


# ---------------------------------------------------------------------------
# Report renderer
# ---------------------------------------------------------------------------

class TestReportRender:

    def _smoke_journal(self, tmp_path):
        """A representative journal: real SA counters + synthetic suite
        events in the schema scenario.py / portfolio.py emit."""
        design = ps.random_design(jax.random.PRNGKey(8))
        cfg = sa.PlacementSAConfig(n_iters=50, record_every=10,
                                   telemetry=True)
        res = sa.refine_placement(jax.random.PRNGKey(9), design,
                                  chipenv.EnvConfig(), cfg)
        path = tmp_path / "run.jsonl"
        with tj.Journal(path, run_id="render") as j:
            j.event("suite_config", n_scenarios=2, n_sa=4, n_rl=1,
                    n_evo=2, surrogate=False, mapping_refine=False,
                    trace=None)
            with j.span("arm:sa", key_stream="split(key, 3)[0]"):
                j.event("arm_convergence", arm="sa", best=[5.0, 6.0],
                        curve=[[1.0, 2.0, 5.0], [3.0, 4.0, 6.0]])
            with j.span("placement", key_stream="split(key, 3)[2]"):
                j.event("sa_accept", stage="placement", scenario="bert",
                        **tl.summarize_sa(res.telemetry))
            j.event("evo_stats", diversity=[0.9, 0.5, 0.3],
                    archive_hv=[0.0, 1.5, 2.0], archive_inserts=12,
                    archive_evicts=2, final_archive_n=10)
            j.event("ppo_stats", entropy=[2.0, 1.5], approx_kl=[0.01, 0.02],
                    clip_frac=[0.1, 0.2], return_mean=[3.0, 4.0])
            j.event("surrogate_bootstrap", n=64, tap_rows=8,
                    dataset_rows=72)
            j.event("surrogate_fit", chunk=0, dataset_rows=72)
            j.event("surrogate_rank_drift", chunk=1, spearman=0.97)
            j.event("compile", target="train_population", dur_s=12.5)
            j.event("suite_archive", hypervolume=3.25, n_points=11,
                    capacity=256)
            j.event("suite_end", wall_time_s=42.0, winners=[
                {"scenario": "bert x (1,1,0.1)", "reward": 123.4,
                 "source": "sa"}])
        return path

    def test_render_sections(self, tmp_path):
        rep = _load_report_module()
        out = io.StringIO()
        rep.render(tj.load(self._smoke_journal(tmp_path)), out=out)
        text = out.getvalue()
        for expected in (
                "telemetry run report",
                "run:      render",
                "suite: 2 scenario(s), arms sa=4 rl=1 evo=2",
                "stages",
                "arm:sa",
                "per-arm convergence",
                "placement-SA acceptance",
                "accept-rate/kind",
                "GA generation stats",
                "archive HV",
                "PPO update stats",
                "entropy",
                "surrogate",
                "rank drift @ chunk 1: spearman 0.970",
                "train_population",
                "12.5s",
                "suite archive: 11 non-dominated points",
                "winners",
                "bert x (1,1,0.1)",
        ):
            assert expected in text, f"missing section: {expected!r}"

    def test_sparkline(self):
        rep = _load_report_module()
        assert rep.sparkline([]) == "(no finite samples)"
        assert rep.sparkline([float("nan"), float("inf")]) \
            == "(no finite samples)"
        flat = rep.sparkline([2.0, 2.0, 2.0])
        assert flat.startswith("▁▁▁")
        ramp = rep.sparkline(list(range(9)))
        assert ramp[0] == "▁" and ramp[8] == "█"
        assert "[0 .. 8]" in ramp
        wide = rep.sparkline(list(range(1000)), width=48)
        assert len(wide.split()[0]) == 48

    def test_accept_rate_curve(self):
        rep = _load_report_module()
        ev = {"accept_curve": [0, 5, 5, 10], "propose": [20, 10]}
        rates = rep._accept_rate_curve(ev)
        np.testing.assert_allclose(rates, [0.5, 0.0, 0.5])
        assert rep._accept_rate_curve({"accept_curve": [3]}) is None
        assert rep._accept_rate_curve({}) is None


# ---------------------------------------------------------------------------
# Eval-tap tracer guard (compat.is_tracer)
# ---------------------------------------------------------------------------

class TestEvalTapTracerGuard:

    def test_tap_fires_concrete_skips_traced(self):
        calls = []
        tap = lambda dp, w, wt, m: calls.append(float(m.reward))
        cm.register_eval_tap(tap)
        try:
            dp = ps.random_design(jax.random.PRNGKey(2))
            concrete = cm.evaluate(dp)
            assert calls == [float(concrete.reward)]
            # traced evaluate must be silently skipped, not crash
            # (jit vs eager rewards can differ by an ulp — FMA contraction)
            jitted = jax.jit(cm.evaluate)(dp)
            assert len(calls) == 1
            np.testing.assert_allclose(np.asarray(jitted.reward),
                                       np.asarray(concrete.reward),
                                       rtol=1e-6)
        finally:
            cm.unregister_eval_tap(tap)
        cm.evaluate(dp)
        assert len(calls) == 1          # unregistered taps stay silent

    def test_is_tracer(self):
        from repro.parallel import compat
        assert not compat.is_tracer(jnp.float32(1.0))
        assert not compat.is_tracer(1.0)
        seen = []
        jax.jit(lambda x: seen.append(compat.is_tracer(x)) or x)(1.0)
        assert seen == [True]


# ---------------------------------------------------------------------------
# Acceptance-band adaptive phase scheduling
# ---------------------------------------------------------------------------

class TestAdaptiveSchedule:

    CFG = sa.PlacementSAConfig(phase_schedule=(("chiplet", 8), ("hbm", 2)),
                               adapt_band=(0.15, 0.45), adapt_factor=2.0,
                               adapt_max_scale=4)

    def test_adapted_schedule_grow_shrink_clamp(self):
        segs = (("chiplet", 8), ("hbm", 2))
        hot = sa._adapted_schedule(segs, [0.6, 0.3], self.CFG)
        assert hot == (("chiplet", 16), ("hbm", 2))     # grow / in-band
        cold = sa._adapted_schedule(segs, [0.05, 0.05], self.CFG)
        assert cold == (("chiplet", 4), ("hbm", 1))     # shrink
        cur = (("chiplet", 32), ("hbm", 1))
        capped = sa._adapted_schedule(cur, [0.9, 0.01], self.CFG,
                                      base_segs=segs)
        assert capped == (("chiplet", 32), ("hbm", 1))  # max-scale / floor

    def test_requires_phase_schedule(self):
        cfg = sa.PlacementSAConfig(n_iters=100, adapt_schedule=True)
        with pytest.raises(ValueError, match="phase_schedule"):
            sa.refine_placement(jax.random.PRNGKey(0),
                                ps.random_design(jax.random.PRNGKey(1)),
                                chipenv.EnvConfig(), cfg)

    def test_budget_too_small(self):
        cfg = sa.PlacementSAConfig(
            n_iters=20, phase_schedule=(("chiplet", 8), ("hbm", 2)),
            adapt_schedule=True, adapt_rounds=4)
        with pytest.raises(ValueError, match="rounds"):
            sa.refine_placement(jax.random.PRNGKey(0),
                                ps.random_design(jax.random.PRNGKey(1)),
                                chipenv.EnvConfig(), cfg)

    def test_end_to_end_rounds_merge_counters(self):
        import dataclasses
        cfg = dataclasses.replace(
            self.CFG, n_iters=200, record_every=50, adapt_schedule=True,
            adapt_rounds=2)
        design = ps.random_design(jax.random.PRNGKey(7))
        buf = io.StringIO()
        j = tj.Journal(buf, fingerprint=False)
        with tj.use(j):
            res = sa.refine_placement(jax.random.PRNGKey(8), design,
                                      chipenv.EnvConfig(), cfg)
        assert float(res.best_reward) >= float(res.canonical_reward) - 1e-6
        c = res.telemetry
        assert c is not None
        # round 1 spends its full 100-iter budget (10-iter cycle);
        # round 2's adapted schedule may have a longer cycle, so its
        # budget rounds down to whole cycles — total in (100, 200]
        total = int(np.asarray(c.propose).sum())
        assert 100 < total <= 200
        assert int(np.asarray(c.seg_propose).sum()) == total
        # merged accept curve stays cumulative across the round boundary
        curve = np.asarray(c.accept_curve)
        assert (np.diff(curve) >= 0).all()
        assert curve[-1] == int(np.asarray(c.accept).sum())
        events = [json.loads(line) for line in
                  buf.getvalue().strip().splitlines()]
        adapt = [e for e in events if e.get("name") == "sa_adapt"]
        assert len(adapt) == 1 and adapt[0]["rounds"] == 2
        assert len(adapt[0]["schedules"]) == 2
