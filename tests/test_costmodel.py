"""Cost-model tests: anchors from the paper + structural invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costmodel as cm
from repro.core import hw_constants as hw
from repro.core import monolithic as mono
from repro.core import params as ps
from repro.core import workload as wl


def case_i_design() -> ps.DesignPoint:
    """Paper Table 6, case (i): 60 chiplets (30 SoIC pairs, 5x6 EMIB mesh),
    4 HBMs @ top/right/bottom/middle, EMIB 20 Gbps."""
    return ps.DesignPoint(
        arch_type=jnp.int32(ps.ARCH_LOGIC_ON_LOGIC),
        n_chiplets=jnp.int32(59),            # index -> 60
        hbm_mask=jnp.int32(29),              # mask 30 = right,top,bottom,mid
        ai_ic_2p5d=jnp.int32(ps.IC_EMIB),
        ai_dr_2p5d=jnp.int32(19),            # 20 Gbps
        ai_links_2p5d=jnp.int32(61),         # 3100
        ai_trace_2p5d=jnp.int32(0),          # 1 mm
        ai_ic_3d=jnp.int32(ps.IC_SOIC),
        ai_dr_3d=jnp.int32(22),              # 42 Gbps
        ai_links_3d=jnp.int32(31),           # 3200
        hbm_ic_2p5d=jnp.int32(ps.IC_EMIB),
        hbm_dr_2p5d=jnp.int32(19),           # 20 Gbps
        hbm_links_2p5d=jnp.int32(97),        # 4900
        hbm_trace_2p5d=jnp.int32(0),         # 1 mm
    )


def case_ii_design() -> ps.DesignPoint:
    """Paper Table 6, case (ii): 112 chiplets (56 FOVEROS pairs, 7x8 mesh)."""
    return ps.DesignPoint(
        arch_type=jnp.int32(ps.ARCH_LOGIC_ON_LOGIC),
        n_chiplets=jnp.int32(111),
        hbm_mask=jnp.int32(26),              # mask 27 = left,right,bottom,mid
        ai_ic_2p5d=jnp.int32(ps.IC_EMIB),
        ai_dr_2p5d=jnp.int32(19),
        ai_links_2p5d=jnp.int32(28),         # 1450
        ai_trace_2p5d=jnp.int32(0),
        ai_ic_3d=jnp.int32(ps.IC_FOVEROS),
        ai_dr_3d=jnp.int32(14),              # 34 Gbps
        ai_links_3d=jnp.int32(43),           # 4400
        hbm_ic_2p5d=jnp.int32(ps.IC_EMIB),
        hbm_dr_2p5d=jnp.int32(19),
        hbm_links_2p5d=jnp.int32(76),        # 3850
        hbm_trace_2p5d=jnp.int32(0),
    )


class TestPaperAnchors:
    """Each assertion is traceable to a number in the paper."""

    def test_design_space_size(self):
        # §4: "more than 2x10^17 design points"
        assert ps.DESIGN_SPACE_SIZE > 2e17

    def test_case_i_geometry(self):
        m = cm.evaluate(case_i_design())
        assert float(m.n_dies) == 60
        assert float(m.n_positions) == 30
        assert (float(m.mesh_m), float(m.mesh_n)) == (5.0, 6.0)
        # §5.3.2: 60-chiplet die size ~26 mm^2
        assert 24.0 <= float(m.die_area_mm2) <= 28.0
        # §5.3.2: 97 % die yield at 7 nm
        assert 0.96 <= float(m.die_yield) <= 0.985

    def test_case_ii_geometry(self):
        m = cm.evaluate(case_ii_design())
        assert float(m.n_dies) == 112
        assert float(m.n_positions) == 56
        assert (float(m.mesh_m), float(m.mesh_n)) == (7.0, 8.0)
        # §5.3.2: ~14 mm^2 die, 98 % yield
        assert 12.0 <= float(m.die_area_mm2) <= 16.0
        assert float(m.die_yield) >= 0.975

    def test_monolithic_yield_48pct(self):
        y = cm.die_yield(hw.MONO_DIE_AREA_MM2, 0.10)
        assert 0.46 <= float(y) <= 0.50

    def test_yield_75pct_at_400mm2_14nm(self):
        y = cm.die_yield(400.0, hw.DEFECT_DENSITY_PER_CM2["14nm"])
        assert 0.73 <= float(y) <= 0.77

    def test_3d_logic_density_1p52x(self):
        # 2 tiers x (1 - keepout) = 1.52x at identical footprint
        density = 2.0 * (1.0 - hw.TSV_KEEPOUT_FRAC)
        assert abs(density - 1.52) < 1e-6

    def test_throughput_beats_monolithic(self):
        m = cm.evaluate(case_i_design())
        mm = mono.evaluate()
        ratio = float(m.eff_tops / mm.eff_tops)
        # paper: 1.52x; our physical model (mesh spacing + HBM footprint
        # accounted) gives ~1.3x — must at least clearly exceed 1x
        assert 1.2 <= ratio <= 1.7

    def test_package_cost_ratio(self):
        m = cm.evaluate(case_i_design())
        mm = mono.evaluate()                      # single monolithic package
        ratio = float(m.pkg_cost / mm.pkg_cost)
        # paper: 1.62x
        assert 1.3 <= ratio <= 2.0

    def test_paper_mode_die_cost_ratio(self):
        m = cm.evaluate(case_i_design())
        mm = mono.evaluate()
        ratio = float(mm.die_cost_paper / m.die_cost_paper)
        # paper: 76x under the A^(5/2) KGD form; ours lands same order
        assert 50.0 <= ratio <= 200.0

    def test_paper_mode_energy_ratio(self):
        cfgp = dataclasses.replace(hw.DEFAULT_HW, comm_reuse_systolic=False,
                                   e_bit_hbm_device_pj=0.0)
        w = wl.MLPERF["bert"]
        m = cm.evaluate(case_i_design(), w, cfg=cfgp)
        mm = mono.evaluate(w, cfg=cfgp, iso_tops=m.eff_tops)
        ratio = float(mm.energy_per_task_j / m.energy_per_task_j)
        # paper: 3.7x energy efficiency vs iso-throughput monolithic
        assert 2.5 <= ratio <= 5.0

    def test_reward_in_paper_band(self):
        # paper Fig. 11: best cost-model values ~178-185 (case i),
        # 188-194 (case ii) for alpha,beta,gamma=[1,1,0.1]
        r1 = float(cm.evaluate(case_i_design()).reward)
        r2 = float(cm.evaluate(case_ii_design()).reward)
        assert 120.0 <= r1 <= 220.0
        # note: under the physics-mode (SRAM-bounded traffic) model,
        # case (ii) ranks below case (i) — the paper's ordering only holds
        # in its literal-Eq.13 utilization model; see EXPERIMENTS.md.
        assert 80.0 <= r2 <= 240.0


class TestStructuralInvariants:
    def setup_method(self):
        self.key = jax.random.PRNGKey(42)
        self.batch = ps.random_design(self.key, (256,))
        self.metrics = cm.evaluate(self.batch)

    def test_finite_and_positive(self):
        m = self.metrics
        for field in m._fields:
            arr = np.asarray(getattr(m, field))
            assert np.isfinite(arr).all(), field
        assert (np.asarray(m.eff_tops) > 0).all()
        assert (np.asarray(m.die_cost) > 0).all()
        assert (np.asarray(m.pkg_cost) > 0).all()

    def test_utilization_bounded(self):
        u = np.asarray(self.metrics.u_sys)
        assert (u > 0).all() and (u <= 1.0 + 1e-6).all()

    def test_yield_monotone_decreasing_in_area(self):
        areas = jnp.linspace(10.0, 800.0, 64)
        y = np.asarray(cm.die_yield(areas, 0.10))
        assert (np.diff(y) < 0).all()
        assert (y > 0).all() and (y <= 1.0).all()

    def test_latency_increases_with_chiplets(self):
        # Fig. 3(b): NoP latency grows with chiplet count
        base = case_i_design()
        lat = []
        for n in [8, 16, 32, 64, 128]:
            m = cm.evaluate(base._replace(n_chiplets=jnp.int32(n - 1),
                                          arch_type=jnp.int32(0)))
            lat.append(float(m.lat_ai_ai_ns))
        assert all(b >= a for a, b in zip(lat, lat[1:]))

    def test_more_hbms_reduce_worst_hops(self):
        # Fig. 4: 5 HBMs cut worst-case hops vs 1 HBM
        base = case_i_design()
        one = cm.evaluate(base._replace(hbm_mask=jnp.int32(0)))    # left only
        five = cm.evaluate(base._replace(hbm_mask=jnp.int32(30)))  # 5 spots
        assert float(five.hops_hbm_ai) < float(one.hops_hbm_ai)

    def test_eff_at_most_peak(self):
        m = self.metrics
        assert (np.asarray(m.eff_tops) <= np.asarray(m.peak_tops) + 1e-5).all()

    def test_bw_act_matches_dr_times_links(self):
        # Eq. 14 (below the HBM physical cap)
        v = ps.decode(self.batch)
        act = np.asarray(self.metrics.bw_act_ai_gbps)
        expect = np.asarray(v.ai_dr_2p5d * v.ai_links_2p5d)
        np.testing.assert_allclose(act, expect, rtol=1e-6)

    def test_action_codec_roundtrip(self):
        flat = ps.to_flat(self.batch)
        back = ps.from_flat(flat)
        for a, b in zip(self.batch, back):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_vmap_jit_consistency(self):
        single = jax.tree_util.tree_map(lambda x: x[0], self.batch)
        m_single = cm.evaluate(single)
        m_jit = jax.jit(cm.evaluate)(single)
        np.testing.assert_allclose(float(m_single.reward),
                                   float(m_jit.reward), rtol=1e-6)
        np.testing.assert_allclose(float(m_single.reward),
                                   float(self.metrics.reward[0]), rtol=1e-6)

    def test_describe_runs(self):
        single = jax.tree_util.tree_map(lambda x: x[0], self.batch)
        text = ps.describe(single)
        assert "Architecture type" in text


class TestMeshDims:
    def test_known_factorizations(self):
        cases = {30: (5, 6), 56: (7, 8), 16: (4, 4), 12: (3, 4), 1: (1, 1)}
        for p, (em, en) in cases.items():
            m, n = cm.mesh_dims(jnp.int32(p))
            assert (float(m), float(n)) == (float(em), float(en)), p

    def test_all_counts_covered(self):
        for p in range(1, 129):
            m, n = cm.mesh_dims(jnp.int32(p))
            assert float(m) * float(n) >= p
            assert float(n) / float(m) <= 2.5  # aspect ratio kept near 1
