"""Surrogate subsystem tests (surrogate/, costmodel eval tap, wiring).

Covers: featurization invariants, the scenario-fold identity (score ==
alpha*r_t - beta*r_c - gamma*r_e of predict()), the EvalDataset ring
buffer, the costmodel tap's concrete/traced gating, the run_stage
exactness guard (every returned reward is analytic), and the
portfolio / suite key-stream isolation contract (enabling the stage
never perturbs the other arms).

Kernel parity (Pallas twin vs ref vs model) lives in tests/test_kernels.py;
the throughput + Spearman-at-scale gates live in scripts/ci.sh on top of
benchmarks/bench_optimizer.py --surrogate.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costmodel as cm
from repro.core import env as chipenv
from repro.core import params as ps
from repro.core import workload as wl
from repro.optimizer import evo
from repro.optimizer import portfolio
from repro.optimizer import scenario as suite
from repro.rl import ppo
from repro.sa import annealing as sa
from repro.surrogate import dataset as sds
from repro.surrogate import model as sm
from repro.surrogate import ranker as srk
from repro.surrogate import train as strain

TINY_STAGE = srk.SurrogateConfig(
    pool_size=2048, top_k=16, bootstrap=128, capacity=2048,
    train=strain.TrainConfig(steps=200, batch_size=128))


def _scenarios(n=2):
    return cm.stack_scenarios(
        [cm.Scenario(workload=wl.MLPERF[name])
         for name in list(wl.MLPERF)[:n]])


class TestFeaturize:
    def test_shape_dtype_and_batch_consistency(self):
        flats = srk.random_flats(jax.random.PRNGKey(0), 64)
        f = sm.featurize(flats)
        assert f.shape == (64, sm.N_FEATURES)
        assert f.dtype == jnp.float32
        assert bool(jnp.isfinite(f).all())
        # leading batch dims reshape through
        f3 = sm.featurize(flats.reshape(4, 16, ps.N_PARAMS))
        np.testing.assert_array_equal(
            np.asarray(f3.reshape(64, sm.N_FEATURES)), np.asarray(f))

    def test_featurize_t_transposed_twin(self):
        flats = srk.random_flats(jax.random.PRNGKey(1), 32)
        np.testing.assert_array_equal(
            np.asarray(sm.featurize_t(flats.T).T),
            np.asarray(sm.featurize(flats)))

    def test_distinct_designs_distinct_features(self):
        flats = srk.random_flats(jax.random.PRNGKey(2), 128)
        f = np.asarray(sm.featurize(flats))
        uniq_designs = np.unique(np.asarray(flats), axis=0).shape[0]
        uniq_feats = np.unique(f.round(6), axis=0).shape[0]
        assert uniq_feats == uniq_designs


class TestFoldScenario:
    def test_fold_matches_predict_combination(self):
        """score_folded must equal the Eq.-17 combination of the three
        denormalized reward-term heads of predict()."""
        params = sm.init_params(jax.random.PRNGKey(0))
        # non-trivial normalizers, like after training
        params["mu"] = jnp.arange(1.0, 7.0)
        params["sd"] = jnp.arange(0.5, 3.5, 0.5)
        scen = chipenv.EnvConfig().scenario()
        flats = srk.random_flats(jax.random.PRNGKey(3), 256)
        p = sm.predict(params, flats, scen)
        w = scen.weights
        want = (w.alpha * p[:, 0] - w.beta * p[:, 1] - w.gamma * p[:, 2])
        got = sm.score(params, flats, scen)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_rank_topk_matches_argsort(self):
        params = sm.init_params(jax.random.PRNGKey(1))
        scen = chipenv.EnvConfig().scenario()
        flats = srk.random_flats(jax.random.PRNGKey(4), 512)
        folded = sm.fold_scenario(params, scen)
        scores = np.asarray(sm.score_folded(folded, flats))
        _, idx = sm.rank_topk_jnp(folded, flats, 8)
        np.testing.assert_array_equal(
            scores[np.asarray(idx)],
            np.sort(scores)[::-1][:8])


class TestEvalDataset:
    def test_ring_wraps_newest_rows_win(self):
        ds = sds.empty(8)
        f1 = jnp.arange(5 * ps.N_PARAMS, dtype=jnp.int32).reshape(5, -1)
        t1 = jnp.ones((5, sm.N_TARGETS))
        s1 = jnp.zeros((5, sm.N_SCEN_FEATURES))
        ds = sds.add(ds, f1, t1, s1)
        assert int(sds.size(ds)) == 5
        f2 = 100 + jnp.arange(6 * ps.N_PARAMS, dtype=jnp.int32).reshape(6, -1)
        ds = sds.add(ds, f2, 2 * jnp.ones((6, sm.N_TARGETS)),
                     jnp.zeros((6, sm.N_SCEN_FEATURES)))
        assert int(ds.count) == 11
        assert int(sds.size(ds)) == 8
        rows = np.asarray(ds.flats)
        # all six newest rows present, oldest three evicted
        for r in np.asarray(f2):
            assert (rows == r).all(axis=1).any()
        assert not (rows == np.asarray(f1[0])).all(axis=1).any()

    def test_oversized_batch_keeps_tail(self):
        ds = sds.empty(4)
        f = jnp.arange(10 * ps.N_PARAMS, dtype=jnp.int32).reshape(10, -1)
        ds = sds.add(ds, f, jnp.zeros((10, sm.N_TARGETS)),
                     jnp.zeros((sm.N_SCEN_FEATURES,)))
        assert int(sds.size(ds)) == 4
        np.testing.assert_array_equal(
            np.sort(np.asarray(ds.flats), axis=0),
            np.sort(np.asarray(f[-4:]), axis=0))

    def test_targets_from_metrics_order(self):
        dp = ps.from_flat(srk.random_flats(jax.random.PRNGKey(5), 3))
        mtr = cm.evaluate(dp)
        t = np.asarray(sds.targets_from_metrics(mtr))
        assert t.shape == (3, sm.N_TARGETS)
        np.testing.assert_allclose(t[:, 0], np.asarray(mtr.reward_t),
                                   rtol=1e-6)
        np.testing.assert_allclose(t[:, 3],
                                   np.log(np.asarray(mtr.tasks_per_sec)),
                                   rtol=1e-6)


class TestEvalTap:
    def test_concrete_eval_tapped_traced_skipped(self):
        tap = sds.EvalTap(capacity=64)
        cm.register_eval_tap(tap)
        try:
            dp = ps.from_flat(srk.random_flats(jax.random.PRNGKey(6), 4))
            cm.evaluate(dp)                          # concrete -> tapped
            assert int(sds.size(tap.dataset)) == 4
            jax.jit(cm.evaluate)(dp)                 # traced -> skipped
            assert int(sds.size(tap.dataset)) == 4
            scen = _scenarios(2)
            cm.evaluate_scenarios(dp, scen, chipenv.EnvConfig().hw,
                                  paired=False)      # vmapped -> skipped
            assert int(sds.size(tap.dataset)) == 4
        finally:
            cm.unregister_eval_tap(tap)
        cm.evaluate(ps.from_flat(srk.random_flats(jax.random.PRNGKey(7), 2)))
        assert int(sds.size(tap.dataset)) == 4       # unregistered

    def test_tap_rows_are_training_rows(self):
        tap = sds.EvalTap(capacity=16)
        cm.register_eval_tap(tap)
        try:
            flats = srk.random_flats(jax.random.PRNGKey(8), 5)
            mtr = cm.evaluate(ps.from_flat(flats))
        finally:
            cm.unregister_eval_tap(tap)
        np.testing.assert_array_equal(np.asarray(tap.dataset.flats[:5]),
                                      np.asarray(flats))
        np.testing.assert_allclose(
            np.asarray(tap.dataset.targets[:5]),
            np.asarray(sds.targets_from_metrics(mtr)), rtol=1e-6)


class TestTrain:
    def test_fit_learns_ranking_signal(self):
        """200 steps on 128 bootstrap rows already rank far better than
        chance (full-scale Spearman gate lives in ci.sh)."""
        scen = _scenarios(1)
        ds, flats, rewards = srk.bootstrap_dataset(
            jax.random.PRNGKey(9), scen, 256, chipenv.EnvConfig().hw,
            nop_fidelity="fast", capacity=1024)
        params, _ = strain.fit(jax.random.PRNGKey(10), ds,
                               strain.TrainConfig(steps=400,
                                                  batch_size=128))
        scen0 = jax.tree_util.tree_map(lambda x: x[0], scen)
        pred = np.asarray(sm.score(params, flats, scen0))
        true = np.asarray(rewards[0])
        rank_p = np.argsort(np.argsort(pred))
        rank_t = np.argsort(np.argsort(true))
        rho = np.corrcoef(rank_p, rank_t)[0, 1]
        assert rho > 0.5, rho


class TestRunStage:
    def test_exactness_guard_all_rewards_analytic(self):
        """Every reward run_stage returns must reproduce from the
        analytic cost model on the returned flats."""
        scen = _scenarios(2)
        res = srk.run_stage(jax.random.PRNGKey(11), scen, TINY_STAGE,
                            chipenv.EnvConfig().hw, nop_fidelity="fast")
        assert res.cand_flats.shape == (2, TINY_STAGE.top_k + 1,
                                        ps.N_PARAMS)
        mtr = cm.evaluate_scenarios(
            ps.from_flat(res.cand_flats), scen, chipenv.EnvConfig().hw,
            paired=True, nop_fidelity="fast")
        np.testing.assert_allclose(np.asarray(res.cand_rewards),
                                   np.asarray(mtr.reward), rtol=1e-5)

    def test_modes_share_bootstrap_stream_and_budget(self):
        """mode='random' is a true control: same bootstrap key stream
        (identical free-rider candidate) and same analytic budget."""
        scen = _scenarios(1)
        r_sur = srk.run_stage(jax.random.PRNGKey(12), scen, TINY_STAGE,
                              chipenv.EnvConfig().hw, nop_fidelity="fast")
        r_rnd = srk.run_stage(
            jax.random.PRNGKey(12), scen,
            dataclasses.replace(TINY_STAGE, mode="random"),
            chipenv.EnvConfig().hw, nop_fidelity="fast")
        assert r_rnd.params is None
        # the bootstrap argmax free-rider (last candidate) is identical
        np.testing.assert_array_equal(
            np.asarray(r_sur.cand_flats[:, -1]),
            np.asarray(r_rnd.cand_flats[:, -1]))
        assert r_sur.cand_rewards.shape == r_rnd.cand_rewards.shape
        assert (srk.analytic_budget(TINY_STAGE)
                == TINY_STAGE.bootstrap + TINY_STAGE.top_k)

    def test_deterministic(self):
        scen = _scenarios(1)
        r1 = srk.run_stage(jax.random.PRNGKey(13), scen, TINY_STAGE,
                           chipenv.EnvConfig().hw, nop_fidelity="fast")
        r2 = srk.run_stage(jax.random.PRNGKey(13), scen, TINY_STAGE,
                           chipenv.EnvConfig().hw, nop_fidelity="fast")
        np.testing.assert_array_equal(np.asarray(r1.cand_flats),
                                      np.asarray(r2.cand_flats))
        np.testing.assert_allclose(np.asarray(r1.cand_rewards),
                                   np.asarray(r2.cand_rewards))


class TestPeriodicRefit:
    """ISSUE-7 satellite 2: SuiteConfig.surrogate_refit_every."""

    def test_refit_off_bit_exact(self):
        """refit_every=0 (the default) must stay on the single-fit PR-6
        code path bit-for-bit."""
        scen = _scenarios(2)
        r0 = srk.run_stage(jax.random.PRNGKey(17), scen, TINY_STAGE,
                           chipenv.EnvConfig().hw, nop_fidelity="fast")
        r1 = srk.run_stage(jax.random.PRNGKey(17), scen, TINY_STAGE,
                           chipenv.EnvConfig().hw, nop_fidelity="fast",
                           refit_every=0)
        np.testing.assert_array_equal(np.asarray(r0.cand_flats),
                                      np.asarray(r1.cand_flats))
        np.testing.assert_array_equal(np.asarray(r0.cand_rewards),
                                      np.asarray(r1.cand_rewards))

    def test_refit_grows_dataset_and_stays_analytic(self):
        """With refits on, each chunk's analytic re-scores are folded
        back into the dataset before the next fit (the stage's own
        eval-tap stream), the result shape is unchanged, and the
        exactness guard still holds on every returned reward."""
        scen = _scenarios(3)
        hw = chipenv.EnvConfig().hw
        r0 = srk.run_stage(jax.random.PRNGKey(18), scen, TINY_STAGE, hw,
                           nop_fidelity="fast")
        r2 = srk.run_stage(jax.random.PRNGKey(18), scen, TINY_STAGE, hw,
                           nop_fidelity="fast", refit_every=2)
        assert r2.cand_flats.shape == r0.cand_flats.shape
        assert int(sds.size(r2.dataset)) == (int(sds.size(r0.dataset))
                                             + 3 * TINY_STAGE.top_k)
        mtr = cm.evaluate_scenarios(
            ps.from_flat(r2.cand_flats), scen, hw, paired=True,
            nop_fidelity="fast")
        np.testing.assert_allclose(np.asarray(r2.cand_rewards),
                                   np.asarray(mtr.reward), rtol=1e-5)
        # the shared bootstrap argmax free-rider is unaffected by refits
        np.testing.assert_array_equal(np.asarray(r0.cand_flats[:, -1]),
                                      np.asarray(r2.cand_flats[:, -1]))

    def test_suite_wiring(self):
        """SuiteConfig carries the cadence and run_suite threads it to
        run_stage; enabled refits keep the suite running end-to-end."""
        assert suite.SuiteConfig().surrogate_refit_every == 0
        cfg = dataclasses.replace(
            suite.SMOKE_SUITE, workloads=("resnet50", "bert"),
            weight_grid=((1.0, 1.0, 0.1),),
            n_sa=1, n_rl=0, n_evo=0, sa=sa.SAConfig(n_iters=300),
            refine=False, placement_refine=False,
            surrogate=TINY_STAGE, surrogate_refit_every=1)
        res = suite.run_suite(jax.random.PRNGKey(19), cfg)
        assert len(res.outcomes) == 2
        for o in res.outcomes:
            assert np.isfinite(o.best_reward)


class TestSurrogateGuidedArms:
    def test_evo_surrogate_proposals_rewards_stay_analytic(self):
        params = sm.init_params(jax.random.PRNGKey(0))
        scen = chipenv.EnvConfig().scenario()
        folded = sm.fold_scenario(params, scen)
        cfg = evo.EvoConfig(pop_size=8, n_generations=5,
                            surrogate_proposals=16)
        res = evo.evolve(jax.random.PRNGKey(14), cfg=cfg,
                         surrogate=folded)
        r = cm.reward_only(res.best_design)
        np.testing.assert_allclose(float(r), float(res.best_reward),
                                   rtol=1e-5)

    def test_sa_surrogate_proposals_rewards_stay_analytic(self):
        params = sm.init_params(jax.random.PRNGKey(1))
        folded = sm.fold_scenario(params, chipenv.EnvConfig().scenario())
        cfg = sa.SAConfig(n_iters=300, surrogate_proposals=8)
        res = sa.run(jax.random.PRNGKey(15), cfg=cfg, surrogate=folded)
        r = cm.reward_only(res.best_design)
        np.testing.assert_allclose(float(r), float(res.best_reward),
                                   rtol=1e-5)

    def test_default_paths_ignore_surrogate_flag(self):
        """surrogate_proposals=0 (default) must not consume the folded
        params nor perturb the key stream."""
        params = sm.init_params(jax.random.PRNGKey(2))
        folded = sm.fold_scenario(params, chipenv.EnvConfig().scenario())
        e0 = evo.evolve(jax.random.PRNGKey(16),
                        cfg=evo.EvoConfig(pop_size=8, n_generations=4))
        e1 = evo.evolve(jax.random.PRNGKey(16),
                        cfg=evo.EvoConfig(pop_size=8, n_generations=4),
                        surrogate=folded)
        assert float(e0.best_reward) == float(e1.best_reward)
        s0 = sa.run(jax.random.PRNGKey(17), cfg=sa.SAConfig(n_iters=200))
        s1 = sa.run(jax.random.PRNGKey(17), cfg=sa.SAConfig(n_iters=200),
                    surrogate=folded)
        assert float(s0.best_reward) == float(s1.best_reward)


class TestPortfolioSurrogateStage:
    CFG = dict(
        n_sa=2, n_rl=1, n_evo=1,
        sa=sa.SAConfig(n_iters=500),
        rl=ppo.PPOConfig(n_steps=32, n_envs=2, batch_size=32),
        rl_timesteps=32 * 2 * 2,
        evo=evo.EvoConfig(pop_size=8, n_generations=5,
                          archive_capacity=32),
        refine=False, refine_placement=False)

    def test_stage_never_perturbs_other_arms(self):
        """ISSUE-6 acceptance shape: the surrogate stage only ADDS
        candidates under its own folded key (fold_in(key, 7)); the
        SA/RL/evo streams and rewards are bit-identical with it on."""
        cfg1 = portfolio.PortfolioConfig(surrogate=TINY_STAGE, **self.CFG)
        cfg0 = portfolio.PortfolioConfig(surrogate=None, **self.CFG)
        r1 = portfolio.optimize(jax.random.PRNGKey(0), cfg=cfg1)
        r0 = portfolio.optimize(jax.random.PRNGKey(0), cfg=cfg0)
        np.testing.assert_array_equal(r1.sa_rewards, r0.sa_rewards)
        np.testing.assert_array_equal(r1.rl_rewards, r0.rl_rewards)
        np.testing.assert_array_equal(r1.evo_rewards, r0.evo_rewards)
        assert r1.best_reward >= r0.best_reward - 1e-6
        assert r1.surrogate_rewards is not None
        assert r1.surrogate_rewards.shape == (TINY_STAGE.top_k + 1,)
        assert r0.surrogate_rewards is None
        assert r1.source in ("sa", "rl", "evo", "surrogate", "refined")

    def test_winner_design_reproducible(self):
        cfg = portfolio.PortfolioConfig(surrogate=TINY_STAGE, **self.CFG)
        res = portfolio.optimize(jax.random.PRNGKey(1), cfg=cfg)
        r = cm.reward_only(res.best_design)
        np.testing.assert_allclose(float(r), float(res.best_reward),
                                   rtol=1e-5)


class TestSuiteSurrogateArm:
    def _cfg(self, surrogate):
        return dataclasses.replace(
            suite.SMOKE_SUITE, workloads=("resnet50", "bert"),
            weight_grid=((1.0, 1.0, 0.1),),
            n_sa=2, n_rl=0, n_evo=0, sa=sa.SAConfig(n_iters=300),
            refine=False, placement_refine=False, surrogate=surrogate)

    def test_suite_winners_never_worse_with_stage(self):
        res1 = suite.run_suite(jax.random.PRNGKey(0),
                               self._cfg(TINY_STAGE))
        res0 = suite.run_suite(jax.random.PRNGKey(0), self._cfg(None))
        for o1, o0 in zip(res1.outcomes, res0.outcomes):
            assert o1.best_reward >= o0.best_reward - 1e-6
        assert all(o.source in ("sa", "surrogate") for o in res1.outcomes)
