"""Tuning-flag equivalence tests: every §Perf optimization must preserve
numerics (same loss / same logits as the baseline path)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_REGISTRY
from repro.models import model as M
from repro.models.tuning import TUNING, tuned

ARCH = ARCH_REGISTRY["llama3-8b"].reduced()
QWEN = ARCH_REGISTRY["qwen2-0.5b"].reduced()


def _train_loss(cfg, flags):
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0,
                                     cfg.vocab_size),
    }
    with tuned(**flags):
        loss, grads = jax.value_and_grad(M.train_loss)(params, cfg, batch)
    return float(loss), grads


class TestFlagEquivalence:
    def test_loss_remat_same_loss_and_grads(self):
        l0, g0 = _train_loss(QWEN, {})
        l1, g1 = _train_loss(QWEN, {"loss_remat": True})
        # remat keeps the forward math; with prevent_cse=False XLA may
        # fuse the checkpointed chunk body differently, so the fp32
        # vocab reductions can drift a few ulps (observed 3e-7 rel)
        np.testing.assert_allclose(l0, l1, rtol=1e-6)
        for a, b in zip(jax.tree_util.tree_leaves(g0),
                        jax.tree_util.tree_leaves(g1)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)

    def test_attn_chunk_remat_same_loss(self):
        l0, _ = _train_loss(QWEN, {})
        l1, _ = _train_loss(QWEN, {"attn_chunk_remat": True})
        assert abs(l0 - l1) < 1e-6

    def test_grouped_gqa_decode_matches_baseline(self):
        cfg = ARCH
        params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                  cfg.vocab_size)
        outs = {}
        for name, flags in (("base", {}),
                            ("grouped", {"gqa_grouped_einsum": True})):
            with tuned(**flags):
                cache = M.init_cache(cfg, 2, 16, jnp.float32)
                logits, cache, _ = M.prefill(params, cfg, toks[:, :8],
                                             cache)
                for t in range(4):
                    logits, cache = M.decode_step(
                        params, cfg, toks[:, 8 + t], 8 + t, cache)
                outs[name] = np.asarray(logits)
        np.testing.assert_allclose(outs["base"], outs["grouped"],
                                   rtol=1e-4, atol=1e-4)

    def test_bf16_einsum_decode_close(self):
        """bf16-accumulate path: looser tolerance (documented trade)."""
        cfg = ARCH
        params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                  cfg.vocab_size)
        outs = {}
        for name, flags in (
                ("base", {}),
                ("bf16", {"gqa_grouped_einsum": True,
                          "decode_bf16_einsum": True})):
            with tuned(**flags):
                cache = M.init_cache(cfg, 2, 16, jnp.float32)
                logits, cache, _ = M.prefill(params, cfg, toks[:, :8],
                                             cache)
                logits, _ = M.decode_step(params, cfg, toks[:, 8], 8,
                                          cache)
                outs[name] = np.asarray(logits)
        np.testing.assert_allclose(outs["base"], outs["bf16"],
                                   rtol=5e-2, atol=5e-2)

    def test_tuned_context_restores(self):
        assert not TUNING.loss_remat
        with tuned(loss_remat=True, moe_capacity_factor=2.0):
            assert TUNING.loss_remat
            assert TUNING.moe_capacity_factor == 2.0
        assert not TUNING.loss_remat
        assert TUNING.moe_capacity_factor == 1.25

    def test_moe_capacity_changes_drop_rate(self):
        cfg = ARCH_REGISTRY["qwen3-moe-235b-a22b"].reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32),
                                         0, cfg.vocab_size),
            "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 32),
                                         0, cfg.vocab_size),
        }
        with tuned(moe_capacity_factor=4.0):
            l_hi = float(M.train_loss(params, cfg, batch))
        with tuned(moe_capacity_factor=0.25):
            l_lo = float(M.train_loss(params, cfg, batch))
        # different capacity -> different routing drops -> different loss
        assert np.isfinite(l_hi) and np.isfinite(l_lo)
        assert l_hi != l_lo


class TestMoEScatterDispatch:
    def test_moe_scatter_matches_dense(self):
        """Scatter dispatch must be numerically identical to the dense
        GShard path (same top-k, capacity, drops, combine weights)."""
        cfg = ARCH_REGISTRY["qwen3-moe-235b-a22b"].reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32),
                                         0, cfg.vocab_size),
            "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 32),
                                         0, cfg.vocab_size),
        }
        l_dense = float(M.train_loss(params, cfg, batch))
        with tuned(moe_scatter_dispatch=True):
            l_scatter = float(M.train_loss(params, cfg, batch))
        np.testing.assert_allclose(l_scatter, l_dense, rtol=1e-5)

    def test_moe_scatter_grads_match(self):
        cfg = ARCH_REGISTRY["deepseek-v2-lite-16b"].reduced()
        l0, g0 = _train_loss(cfg, {})
        l1, g1 = _train_loss(cfg, {"moe_scatter_dispatch": True})
        np.testing.assert_allclose(l1, l0, rtol=1e-5)
        for a, b in zip(jax.tree_util.tree_leaves(g0),
                        jax.tree_util.tree_leaves(g1)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=5e-4, atol=1e-5)
