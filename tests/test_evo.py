"""Evolutionary arm + Pareto archive tests (optimizer/evo.py, archive.py).

The archive invariants (never holds a dominated point, order-insensitive
insertion up to ties, idempotent re-insert, hypervolume monotone under
insertion) run twice: as hypothesis properties when hypothesis is
installed, and as seeded-random checks that always run (the CI container
has no hypothesis).
"""

import dataclasses
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costmodel as cm
from repro.core import env as chipenv
from repro.core import params as ps
from repro.core import workload as wl
from repro.optimizer import archive as ar
from repro.optimizer import evo
from repro.optimizer import portfolio
from repro.optimizer import scenario as suite
from repro.rl import ppo
from repro.sa import annealing as sa

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

TINY_PPO = ppo.PPOConfig(n_steps=32, n_envs=2, batch_size=32)
TINY_STEPS = 32 * 2 * 2
TINY_EVO = evo.EvoConfig(pop_size=8, n_generations=5, archive_capacity=32)


def _random_points(key, n):
    """Raw-convention objective triples with genuine trade-offs."""
    u = jax.random.uniform(key, (n, 3))
    return jnp.stack([u[:, 0] * 100.0,             # tasks/s up
                      0.01 + u[:, 1],              # J/task down
                      10.0 + u[:, 2] * 90.0], -1)  # cost down


def _flats(n):
    return jnp.zeros((n, ps.N_PARAMS), jnp.int32)


def _sorted_rows(points):
    return np.asarray(points)[np.lexsort(np.asarray(points).T)]


class TestArchiveInvariants:
    def test_dominated_never_held(self):
        """Random insertion streams: every valid entry stays mutually
        non-dominated after every insert."""
        key = jax.random.PRNGKey(0)
        arc = ar.empty(16)
        for i in range(6):
            key, k = jax.random.split(key)
            arc = ar.insert_batch(arc, _random_points(k, 5), _flats(5))
            c = ar.contents(arc)
            nd = ar.non_dominated_mask(jnp.asarray(c["points"]))
            assert bool(np.asarray(nd).all()), f"dominated point at step {i}"

    def test_insert_order_insensitive(self):
        pts = _random_points(jax.random.PRNGKey(1), 12)
        perm = jax.random.permutation(jax.random.PRNGKey(2), 12)
        a = ar.insert_batch(ar.empty(16), pts, _flats(12))
        b = ar.insert_batch(ar.empty(16), pts[perm], _flats(12))
        np.testing.assert_allclose(_sorted_rows(ar.contents(a)["points"]),
                                   _sorted_rows(ar.contents(b)["points"]))

    def test_insert_split_vs_single_batch(self):
        pts = _random_points(jax.random.PRNGKey(3), 10)
        one = ar.insert_batch(ar.empty(16), pts, _flats(10))
        two = ar.insert_batch(ar.empty(16), pts[:5], _flats(5))
        two = ar.insert_batch(two, pts[5:], _flats(5))
        np.testing.assert_allclose(_sorted_rows(ar.contents(one)["points"]),
                                   _sorted_rows(ar.contents(two)["points"]))

    def test_reinsert_idempotent(self):
        pts = _random_points(jax.random.PRNGKey(4), 8)
        arc = ar.insert_batch(ar.empty(16), pts, _flats(8))
        before = _sorted_rows(ar.contents(arc)["points"])
        again = ar.insert_batch(arc, pts, _flats(8))
        np.testing.assert_allclose(
            before, _sorted_rows(ar.contents(again)["points"]))
        # re-inserting the archive's own contents is also a no-op
        merged = ar.merge(arc, arc)
        np.testing.assert_allclose(
            before, _sorted_rows(ar.contents(merged)["points"]))

    def test_hypervolume_monotone_under_insertion(self):
        ref = (0.0, 2.0, 120.0)
        arc = ar.empty(64)                 # ample: no eviction
        key, last = jax.random.PRNGKey(5), 0.0
        for _ in range(6):
            key, k = jax.random.split(key)
            arc = ar.insert_batch(arc, _random_points(k, 4), _flats(4))
            hv = float(ar.hypervolume(arc, ref))
            assert hv >= last - 1e-4
            last = hv
        assert last > 0.0

    def test_hypervolume_exact_boxes(self):
        arc = ar.insert_batch(ar.empty(8),
                              jnp.asarray([[10.0, 1.0, 5.0]]), _flats(1))
        assert float(ar.hypervolume(arc, (0.0, 2.0, 10.0))) == \
            pytest.approx(50.0, rel=1e-5)
        arc = ar.insert_batch(arc, jnp.asarray([[5.0, 0.5, 5.0]]), _flats(1))
        assert float(ar.hypervolume(arc, (0.0, 2.0, 10.0))) == \
            pytest.approx(62.5, rel=1e-5)
        arc = ar.insert_batch(arc, jnp.asarray([[10.0, 1.0, 2.0]]), _flats(1))
        assert float(ar.hypervolume(arc, (0.0, 2.0, 10.0))) == \
            pytest.approx(92.5, rel=1e-5)

    def test_capacity_eviction_keeps_boundaries(self):
        t = jnp.linspace(0.0, 1.0, 12)
        pts = jnp.stack([t * 10.0, 0.1 + 0.9 * t, jnp.full((12,), 5.0)], -1)
        arc = ar.insert_batch(ar.empty(4), pts, _flats(12))
        c = ar.contents(arc)
        assert c["points"].shape[0] == 4
        assert 0.0 in c["points"][:, 0] and 10.0 in c["points"][:, 0]

    def test_payload_and_reward_ride_along(self):
        pts = jnp.asarray([[10.0, 1.0, 5.0], [5.0, 2.0, 9.0]])  # 1 dominated
        arc = ar.insert_batch(ar.empty(4), pts, _flats(2),
                              reward=jnp.asarray([7.0, 1.0]),
                              payload=jnp.asarray([42, 43]))
        c = ar.contents(arc)
        assert c["payload"].tolist() == [42]
        assert c["reward"].tolist() == [7.0]

    def test_insert_batch_inside_scan(self):
        pts = _random_points(jax.random.PRNGKey(6), 8)

        def body(arc, p):
            return ar.insert_batch(arc, p[None], _flats(1)), 0

        arc, _ = jax.lax.scan(body, ar.empty(8), pts)
        scanned = _sorted_rows(ar.contents(arc)["points"])
        direct = ar.insert_batch(ar.empty(8), pts, _flats(8))
        np.testing.assert_allclose(scanned,
                                   _sorted_rows(ar.contents(direct)["points"]))


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
class TestArchiveHypothesis:
    """The same invariants as randomized properties."""

    @staticmethod
    def _points(rows):
        return jnp.asarray(rows, jnp.float32)

    if HAVE_HYPOTHESIS:
        point_row = st.tuples(
            st.floats(0.1, 100.0), st.floats(0.01, 2.0),
            st.floats(1.0, 100.0))
        point_lists = st.lists(point_row, min_size=1, max_size=12)

        @given(point_lists)
        @settings(max_examples=25, deadline=None)
        def test_never_holds_dominated(self, rows):
            arc = ar.insert_batch(ar.empty(16), self._points(rows),
                                  _flats(len(rows)))
            c = ar.contents(arc)
            nd = ar.non_dominated_mask(jnp.asarray(c["points"]))
            assert bool(np.asarray(nd).all())

        @given(point_lists, st.randoms(use_true_random=False))
        @settings(max_examples=25, deadline=None)
        def test_order_insensitive_up_to_ties(self, rows, rng):
            shuffled = list(rows)
            rng.shuffle(shuffled)
            a = ar.insert_batch(ar.empty(16), self._points(rows),
                                _flats(len(rows)))
            b = ar.insert_batch(ar.empty(16), self._points(shuffled),
                                _flats(len(rows)))
            np.testing.assert_allclose(
                _sorted_rows(ar.contents(a)["points"]),
                _sorted_rows(ar.contents(b)["points"]), rtol=1e-6)

        @given(point_lists)
        @settings(max_examples=25, deadline=None)
        def test_reinsert_idempotent(self, rows):
            pts = self._points(rows)
            arc = ar.insert_batch(ar.empty(16), pts, _flats(len(rows)))
            before = _sorted_rows(ar.contents(arc)["points"])
            again = ar.insert_batch(arc, pts, _flats(len(rows)))
            np.testing.assert_allclose(
                before, _sorted_rows(ar.contents(again)["points"]))

        @given(point_lists, point_lists)
        @settings(max_examples=25, deadline=None)
        def test_hypervolume_monotone(self, rows_a, rows_b):
            ref = (0.0, 3.0, 150.0)
            arc = ar.insert_batch(ar.empty(32), self._points(rows_a),
                                  _flats(len(rows_a)))
            hv_a = float(ar.hypervolume(arc, ref))
            arc = ar.insert_batch(arc, self._points(rows_b),
                                  _flats(len(rows_b)))
            assert float(ar.hypervolume(arc, ref)) >= hv_a - 1e-3


def _scan_body_kernels(fn, *args):
    """Fused-kernel count of the largest while-loop body of ``fn``."""
    txt = fn.lower(*args).compile().as_text()
    regions = {}
    for m in re.finditer(r"^(%[\w\.\-]+)[^\n]*\{(.*?)\n\}", txt,
                         re.M | re.S):
        regions[m.group(1)] = m.group(2)
    bodies = [regions[b] for b in re.findall(r"body=(%[\w\.\-]+)", txt)
              if b in regions]
    if not bodies:
        return 0
    return len(re.findall(r"= \S+ (?:fusion|reduce|gather|scatter|sort|dot)\(",
                          max(bodies, key=len)))


class TestEvolve:
    def test_fixed_seed_deterministic(self):
        r1 = evo.evolve(jax.random.PRNGKey(0), cfg=TINY_EVO)
        r2 = evo.evolve(jax.random.PRNGKey(0), cfg=TINY_EVO)
        assert float(r1.best_reward) == float(r2.best_reward)
        np.testing.assert_array_equal(np.asarray(r1.best_genome),
                                      np.asarray(r2.best_genome))
        np.testing.assert_array_equal(np.asarray(r1.archive.valid),
                                      np.asarray(r2.archive.valid))
        np.testing.assert_allclose(np.asarray(r1.archive.points),
                                   np.asarray(r2.archive.points))

    def test_improves_and_history_monotone(self):
        res = evo.evolve(jax.random.PRNGKey(1),
                         cfg=evo.EvoConfig(pop_size=16, n_generations=20))
        assert float(res.best_reward) > 150.0
        h = np.asarray(res.history)
        assert (np.diff(h) >= -1e-5).all()
        flat = np.asarray(ps.to_flat(res.best_design))
        assert chipenv.action_space.contains(flat)

    def test_archive_non_dominated_and_rewards_match(self):
        res = evo.evolve(jax.random.PRNGKey(2), cfg=TINY_EVO)
        c = ar.contents(res.archive)
        assert c["points"].shape[0] >= 1
        nd = ar.non_dominated_mask(jnp.asarray(c["points"]))
        assert bool(np.asarray(nd).all())
        # archived reward/triple really is evaluate() of the archived flats
        m = cm.evaluate(ps.from_flat(jnp.asarray(c["flats"])))
        np.testing.assert_allclose(np.asarray(m.reward), c["reward"],
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(m.tasks_per_sec),
                                   c["points"][:, 0], rtol=1e-5)

    def test_generation_is_single_program_kernels_pop_invariant(self):
        """One generation compiles to one XLA program whose kernel count
        does not scale with the population (no per-individual dispatch):
        the generation loop body schedules the same kernels at pop 8
        and pop 32."""
        counts = {}
        for pop in (8, 32):
            cfg = evo.EvoConfig(pop_size=pop, n_generations=3)
            fn = jax.jit(lambda k, _cfg=cfg: evo.evolve(
                k, cfg=_cfg).best_reward)
            counts[pop] = _scan_body_kernels(fn, jax.random.PRNGKey(0))
        assert counts[8] > 0
        # identical modulo small fusion-decision jitter
        assert abs(counts[8] - counts[32]) <= max(3, counts[8] // 10), counts

    def test_placement_genes(self):
        cfg = evo.EvoConfig(pop_size=8, n_generations=4,
                            placement_genes=True)
        res = evo.evolve(jax.random.PRNGKey(3), cfg=cfg)
        assert res.best_genome.shape == (ps.N_EXT_PARAMS,)
        assert np.isfinite(float(res.best_reward))
        # the genome's reward is reproducible from its design + placement
        design, plc = evo.genome_placement(res.best_genome)
        r = cm.reward_only(design, placement=plc)
        np.testing.assert_allclose(float(r), float(res.best_reward),
                                   rtol=1e-5)

    def test_creep_mutation_never_loses_fixed_seeds(self):
        """+-1 ordinal creep (p_creep=0.5) vs pure per-index resample on
        fixed seeds: the Table-1 heads are ordinal, so local steps keep
        fitness correlation and creep should not lose on these runs."""
        base = evo.EvoConfig(pop_size=16, n_generations=12)
        creep = dataclasses.replace(base, p_creep=0.5)
        for seed in (0, 1):
            key = jax.random.PRNGKey(seed)
            r_base = evo.evolve(key, cfg=base)
            r_creep = evo.evolve(key, cfg=creep)
            assert (float(r_creep.best_reward)
                    >= float(r_base.best_reward)), seed

    def test_creep_mutation_deterministic_and_in_grid(self):
        cfg = dataclasses.replace(TINY_EVO, p_creep=0.5)
        r1 = evo.evolve(jax.random.PRNGKey(0), cfg=cfg)
        r2 = evo.evolve(jax.random.PRNGKey(0), cfg=cfg)
        assert float(r1.best_reward) == float(r2.best_reward)
        flat = np.asarray(ps.to_flat(r1.best_design))
        assert chipenv.action_space.contains(flat)

    def test_population_and_scenario_population_shapes(self):
        pop = evo.evolve_population(jax.random.PRNGKey(4), 2, cfg=TINY_EVO)
        assert pop.best_reward.shape == (2,)
        scen = cm.stack_scenarios(
            [cm.Scenario(workload=wl.MLPERF[n])
             for n in list(wl.MLPERF)[:2]])
        res = evo.evolve_scenario_population(jax.random.PRNGKey(5), scen, 2,
                                             cfg=TINY_EVO)
        assert res.best_reward.shape == (2, 2)
        assert res.archive.valid.shape[:2] == (2, 2)


class TestPortfolioEvoArm:
    CFG = dict(
        n_sa=2, n_rl=1,
        sa=sa.SAConfig(n_iters=1000),
        rl=TINY_PPO, rl_timesteps=TINY_STEPS,
        refine=True, max_refine_sweeps=1, refine_placement=False,
        evo=TINY_EVO)

    def test_three_arms_never_worse_than_two(self):
        """ISSUE-5 acceptance: with the SA/RL key streams unchanged, the
        evo arm only grows the candidate + refine sets, so best_reward
        with the arm enabled is >= the SA+RL-only portfolio's."""
        cfg3 = portfolio.PortfolioConfig(n_evo=1, **self.CFG)
        cfg2 = portfolio.PortfolioConfig(n_evo=0, **self.CFG)
        r3 = portfolio.optimize(jax.random.PRNGKey(0), cfg=cfg3)
        r2 = portfolio.optimize(jax.random.PRNGKey(0), cfg=cfg2)
        np.testing.assert_array_equal(r3.sa_rewards, r2.sa_rewards)
        np.testing.assert_array_equal(r3.rl_rewards, r2.rl_rewards)
        assert r3.best_reward >= r2.best_reward - 1e-6
        assert r3.evo_rewards.shape == (1,)
        assert r3.source in ("sa", "rl", "evo", "refined")

    def test_placement_genes_winner_is_reproducible(self):
        """An evo winner whose reward came from a placement-gene mutation
        must hand that placement to the placement stage, keeping the
        placement_reward >= best_reward invariant."""
        cfg = portfolio.PortfolioConfig(
            n_sa=1, n_rl=0, n_evo=1,
            sa=sa.SAConfig(n_iters=300),
            evo=evo.EvoConfig(pop_size=8, n_generations=6,
                              placement_genes=True),
            refine=False, refine_placement=True,
            placement_sa=sa.PlacementSAConfig(n_iters=100))
        res = portfolio.optimize(jax.random.PRNGKey(3), cfg=cfg)
        assert res.placement_reward >= res.best_reward - 1e-5

    def test_shared_archive_feeds_all_arms(self):
        cfg = portfolio.PortfolioConfig(n_evo=1, **self.CFG)
        res = portfolio.optimize(jax.random.PRNGKey(1), cfg=cfg)
        assert res.archive is not None
        c = ar.contents(res.archive)
        assert c["points"].shape[0] >= 1
        nd = ar.non_dominated_mask(jnp.asarray(c["points"]))
        assert bool(np.asarray(nd).all())


class TestSuiteEvoArm:
    def _cfg(self, n_evo):
        return dataclasses.replace(
            suite.SMOKE_SUITE, workloads=("resnet50", "bert"),
            weight_grid=((1.0, 1.0, 0.1),),
            n_sa=2, n_rl=0, n_evo=n_evo, sa=sa.SAConfig(n_iters=500),
            evo=TINY_EVO, refine=True, max_refine_sweeps=1,
            placement_refine=False)

    def test_suite_three_arm_winners_and_archive(self):
        res = suite.run_suite(jax.random.PRNGKey(0), self._cfg(1))
        res0 = suite.run_suite(jax.random.PRNGKey(0), self._cfg(0))
        for o1, o0 in zip(res.outcomes, res0.outcomes):
            assert o1.best_reward >= o0.best_reward - 1e-6
        # the reported frontier is archive-backed and non-dominated
        assert res.archive is not None
        c = ar.contents(res.archive)
        nd = ar.non_dominated_mask(jnp.asarray(c["points"]))
        assert bool(np.asarray(nd).all())
        assert res.hypervolume > 0.0
        assert 1 <= len(res.pareto) <= len(res.outcomes)
        js = suite.to_json(res)
        assert js["hypervolume"] == res.hypervolume
        assert js["archive"]["n"] == int(res.archive.n_valid)
        report = suite.format_report(res)
        assert "hypervolume" in report

    def test_tied_winners_all_on_frontier(self):
        """Two identical scenarios share one winner triple; the archive
        collapses the duplicate point but the report must list both."""
        cfg = dataclasses.replace(
            suite.SMOKE_SUITE, workloads=("resnet50",),
            weight_grid=((1.0, 1.0, 0.1), (1.0, 1.0, 0.1)),
            n_sa=2, n_rl=0, n_evo=0, sa=sa.SAConfig(n_iters=300),
            refine=False, placement_refine=False)
        res = suite.run_suite(jax.random.PRNGKey(0), cfg)
        assert res.pareto == [0, 1]
        assert res.pareto_normalized == [0, 1]


class TestMultiChainPlacementSA:
    def test_chains_never_worse_same_key(self):
        """Chain 0 reuses the caller's key, so n_chains=4 is a strict
        superset of the n_chains=1 run on every design."""
        env_cfg = chipenv.EnvConfig(hw=suite.PLACEMENT_SENSITIVE_HW)
        dps = ps.random_design(jax.random.PRNGKey(11), (3,))
        keys = jax.random.split(jax.random.PRNGKey(12), 3)
        rewards = {}
        for nc in (1, 4):
            cfg = sa.PlacementSAConfig(n_iters=200, n_chains=nc)
            fn = jax.jit(jax.vmap(lambda k, d: sa.refine_placement(
                k, d, env_cfg, cfg).best_reward))
            rewards[nc] = np.asarray(fn(keys, dps))
        assert (rewards[4] >= rewards[1] - 1e-5).all()

    def test_single_chain_unchanged(self):
        """n_chains=1 must preserve the PR-4 trajectory bit-for-bit."""
        env_cfg = chipenv.EnvConfig(hw=suite.PLACEMENT_SENSITIVE_HW)
        dp = ps.random_design(jax.random.PRNGKey(21))
        key = jax.random.PRNGKey(22)
        r_default = sa.refine_placement(
            key, dp, env_cfg, sa.PlacementSAConfig(n_iters=200))
        r_explicit = sa.refine_placement(
            key, dp, env_cfg, sa.PlacementSAConfig(n_iters=200, n_chains=1))
        assert float(r_default.best_reward) == float(r_explicit.best_reward)
        np.testing.assert_array_equal(
            np.asarray(r_default.best_placement.chiplet_cell),
            np.asarray(r_explicit.best_placement.chiplet_cell))


class TestIslandMigration:
    """ISSUE-7 satellite 1a: ring migration in evolve_population."""

    MIG = dataclasses.replace(TINY_EVO, n_generations=12, migrate_every=3)

    def test_migrate_zero_bit_exact_with_pr5_path(self):
        """migrate_every=0 (default) must stay on the independent-island
        jit-vmap program bit-for-bit."""
        r0 = evo.evolve_population(jax.random.PRNGKey(0), 3, cfg=TINY_EVO)
        r1 = evo.evolve_population(
            jax.random.PRNGKey(0), 3,
            cfg=dataclasses.replace(TINY_EVO, migrate_every=0))
        np.testing.assert_array_equal(np.asarray(r0.best_reward),
                                      np.asarray(r1.best_reward))
        np.testing.assert_array_equal(np.asarray(r0.best_genome),
                                      np.asarray(r1.best_genome))

    def test_deterministic(self):
        r1 = evo.evolve_population(jax.random.PRNGKey(1), 3, cfg=self.MIG)
        r2 = evo.evolve_population(jax.random.PRNGKey(1), 3, cfg=self.MIG)
        np.testing.assert_array_equal(np.asarray(r1.best_reward),
                                      np.asarray(r2.best_reward))
        np.testing.assert_array_equal(np.asarray(r1.best_genome),
                                      np.asarray(r2.best_genome))

    @pytest.mark.parametrize("seed,n_islands", [(0, 4), (2, 2), (2, 4)])
    def test_migration_lifts_weak_islands_fixed_seed(self, seed, n_islands):
        """On the fixed test seeds, injecting each neighbor's best genome
        over the worst must not lose on the weakest island or on the
        island mean (a per-island guarantee would be false: a migrant
        reroutes the receiving island's later draws, which can cost an
        already-strong island a few reward points)."""
        base = dataclasses.replace(self.MIG, migrate_every=0)
        r_mig = evo.evolve_population(jax.random.PRNGKey(seed), n_islands,
                                      cfg=self.MIG)
        r_ind = evo.evolve_population(jax.random.PRNGKey(seed), n_islands,
                                      cfg=base)
        mig = np.asarray(r_mig.best_reward)
        ind = np.asarray(r_ind.best_reward)
        assert mig.min() >= ind.min() - 1e-5, (mig, ind)
        assert mig.mean() >= ind.mean() - 1e-5, (mig, ind)

    def test_history_and_shapes(self):
        res = evo.evolve_population(jax.random.PRNGKey(3), 2, cfg=self.MIG)
        assert res.best_reward.shape == (2,)
        h = np.asarray(res.history)
        assert h.shape == (2, self.MIG.n_generations)
        assert (np.diff(h, axis=1) >= -1e-5).all()
        for i in range(2):
            flat = np.asarray(res.best_genome[i, : ps.N_PARAMS])
            assert chipenv.action_space.contains(flat)

    def test_kernel_count_island_invariant(self):
        """ISSUE-7 acceptance: migration adds ONE one-hot select per
        epoch, not a per-island gather — the generation loop body
        schedules the same kernels at 2 and 4 islands."""
        counts = {}
        for n in (2, 4):
            fn = jax.jit(lambda k, _n=n: evo.evolve_population(
                k, _n, cfg=self.MIG).best_reward)
            counts[n] = _scan_body_kernels(fn, jax.random.PRNGKey(0))
        assert counts[2] > 0
        assert abs(counts[2] - counts[4]) <= max(3, counts[2] // 10), counts


class TestHVEviction:
    """ISSUE-7 satellite 1b: hypervolume-contribution eviction."""

    def _pressure_stream(self, key, rounds=6, batch=10):
        ks = jax.random.split(key, rounds)
        return [_random_points(k, batch) for k in ks]

    def test_under_capacity_identical_to_crowding(self):
        """Eviction mode only matters at capacity pressure: under
        capacity both modes hold exactly the non-dominated set."""
        pts = _random_points(jax.random.PRNGKey(20), 12)
        a = ar.insert_batch(ar.empty(32), pts, _flats(12))
        b = ar.insert_batch(ar.empty(32), pts, _flats(12), eviction="hv")
        np.testing.assert_allclose(_sorted_rows(ar.contents(a)["points"]),
                                   _sorted_rows(ar.contents(b)["points"]))

    def test_hv_eviction_never_loses_hypervolume_fixed_seed(self):
        """The acceptance contract: on the fixed-seed pressure stream the
        hv mode retains at least the crowding mode's hypervolume (it
        evicts the point whose removal costs the least exclusive HV)."""
        arcs = {m: ar.empty(8) for m in ("crowding", "hv")}
        for pts in self._pressure_stream(jax.random.PRNGKey(21)):
            for m in arcs:
                arcs[m] = ar.insert_batch(arcs[m], pts, _flats(10),
                                          eviction=m)
        ref = (0.0, 2.0, 120.0)
        hv_c = float(ar.hypervolume(arcs["crowding"], ref))
        hv_h = float(ar.hypervolume(arcs["hv"], ref))
        assert hv_h >= hv_c - 1e-4, (hv_h, hv_c)
        assert hv_h > 0.0

    def test_hv_mode_invariants(self):
        """Non-dominated invariant + determinism + scan safety hold in
        hv mode too."""
        key = jax.random.PRNGKey(22)
        arc = ar.empty(8)
        for pts in self._pressure_stream(key, rounds=4):
            arc = ar.insert_batch(arc, pts, _flats(10), eviction="hv")
            c = ar.contents(arc)
            nd = ar.non_dominated_mask(jnp.asarray(c["points"]))
            assert bool(np.asarray(nd).all())
        pts = _random_points(jax.random.PRNGKey(23), 8)

        def body(a, p):
            return ar.insert_batch(a, p[None], _flats(1), eviction="hv"), 0

        scanned, _ = jax.lax.scan(body, ar.empty(8), pts)
        direct = ar.insert_batch(ar.empty(8), pts, _flats(8), eviction="hv")
        np.testing.assert_allclose(
            _sorted_rows(ar.contents(scanned)["points"]),
            _sorted_rows(ar.contents(direct)["points"]))

    def test_bad_eviction_raises_and_evo_threads_it(self):
        with pytest.raises(ValueError, match="eviction"):
            ar.insert_batch(ar.empty(4),
                            _random_points(jax.random.PRNGKey(24), 2),
                            _flats(2), eviction="bogus")
        cfg = dataclasses.replace(TINY_EVO, archive_eviction="hv")
        res = evo.evolve(jax.random.PRNGKey(25), cfg=cfg)
        c = ar.contents(res.archive)
        nd = ar.non_dominated_mask(jnp.asarray(c["points"]))
        assert bool(np.asarray(nd).all())
        assert np.isfinite(float(res.best_reward))
