"""Traffic-trace scenarios: generators, trace evaluation, queueing proxy.

Covers the ROADMAP-3 layer end to end:

- generator invariants: determinism under a fixed PRNG key, mix rows
  summing to 1, dt-weighted QPS normalization to the configured load,
- ``costmodel.evaluate_trace`` degrading *bitwise* to the point path on
  a length-1 flat trace with the SLO / idle-energy channels disabled,
- the whole 32-step trace compiling to ONE XLA program (jit round-trip
  equals eager, no per-step dispatch),
- the analytic M/D/c p99 proxy staying in band against the
  discrete-event slot-scheduler twin of serving/engine.py,
- the 4-objective (PPAC + SLO attainment) archive path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costmodel as cm
from repro.core import hw_constants as hw
from repro.core import monolithic as mono
from repro.core import params as ps
from repro.core import traffic as tr
from repro.core import workload as wl
from repro.optimizer import archive as ar

DP = ps.from_flat(jnp.asarray(
    [1, 40, 31, 1, 10, 2, 1, 1, 1, 1, 1, 1, 1, 1], jnp.int32))
WORKLOAD = wl.registry()["llama3-8b:decode"]


def _weights():
    return cm.make_weights(1.0, 1.0, 0.1)


class TestGenerators:
    @pytest.mark.parametrize("kind", tr.KINDS)
    def test_deterministic_under_key(self, kind):
        cfg = tr.TraceConfig(kind=kind)
        key = jax.random.PRNGKey(3)
        wl_a, trace_a = tr.make_trace(key, WORKLOAD, cfg)
        wl_b, trace_b = tr.make_trace(key, WORKLOAD, cfg)
        for xa, xb in zip(jax.tree_util.tree_leaves((wl_a, trace_a)),
                          jax.tree_util.tree_leaves((wl_b, trace_b))):
            np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))

    @pytest.mark.parametrize("kind", tr.KINDS)
    def test_mix_rows_sum_to_one(self, kind):
        cfg = tr.TraceConfig(kind=kind)
        _, trace = tr.make_trace(jax.random.PRNGKey(0), WORKLOAD, cfg)
        rows = np.asarray(trace.mix)
        assert rows.shape[0] == cfg.n_steps
        np.testing.assert_allclose(rows.sum(axis=-1), 1.0, rtol=1e-6)
        assert (rows >= 0.0).all()
        # column 0 is the scenario's own workload at 1 - mix_spread
        np.testing.assert_allclose(rows[:, 0], 1.0 - cfg.mix_spread,
                                   rtol=1e-6)

    @pytest.mark.parametrize("kind", tr.KINDS)
    def test_qps_normalized_to_load(self, kind):
        cfg = tr.TraceConfig(kind=kind, load=2.25)
        traced_wl, trace = tr.make_trace(
            jax.random.PRNGKey(1), WORKLOAD, cfg)
        mu_ref = jax.vmap(
            lambda w: mono.evaluate(w, hw.DEFAULT_HW).tasks_per_sec)(
                traced_wl)
        offered = float(jnp.sum(trace.dt * trace.qps))
        reference = float(jnp.sum(trace.dt * mu_ref))
        assert offered == pytest.approx(cfg.load * reference, rel=1e-5)

    def test_distinct_kinds_distinct_loads(self):
        qps = {}
        for kind in tr.KINDS:
            _, trace = tr.make_trace(jax.random.PRNGKey(0), WORKLOAD,
                                     tr.TraceConfig(kind=kind))
            qps[kind] = np.asarray(trace.qps)
        assert np.ptp(qps["flat"]) == pytest.approx(0.0)
        assert np.ptp(qps["bursty"]) > 0.0
        assert np.ptp(qps["diurnal"]) > 0.0

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_multi_tenant_shared_phases(self, seed):
        """Regression: the offered load and the mix share tenant phases.

        A tenant's reconstructed intensity s_j(t) = qps(t) * mix_j(t) /
        mu_ref(t) is proportional to 1 + (peak-1)/2 * (1 - cos(2*pi*t/T
        + phi_j)), whose max/mean over a full integer-period grid is
        exactly 2*peak / (1 + peak) — 1.5 at peak=3. Before the fix
        ``_mix_rows`` drew its own independent phase vector, so the mix
        columns did not follow the load superposition and the ratio was
        off by 0.03-0.4 on these seeds.
        """
        cfg = tr.TraceConfig(kind="multi-tenant", peak=3.0)
        traced_wl, trace = tr.make_trace(
            jax.random.PRNGKey(seed), WORKLOAD, cfg)
        mu_ref = np.asarray(jax.vmap(
            lambda w: mono.evaluate(w, hw.DEFAULT_HW).tasks_per_sec)(
                traced_wl))
        mix = np.asarray(trace.mix)
        qps = np.asarray(trace.qps)
        tenant_cols = np.where(mix[0, 1:] > 0)[0] + 1
        assert len(tenant_cols) == cfg.n_tenants
        target = 2.0 * cfg.peak / (1.0 + cfg.peak)
        for c in tenant_cols:
            intensity = qps * mix[:, c] / mu_ref
            ratio = intensity.max() / intensity.mean()
            assert ratio == pytest.approx(target, abs=0.01)

    def test_resolve_trace(self):
        assert tr.resolve_trace(None) is None
        assert tr.resolve_trace("bursty").kind == "bursty"
        cfg = tr.TraceConfig(kind="diurnal", n_steps=8)
        assert tr.resolve_trace(cfg) is cfg
        with pytest.raises(ValueError, match="unknown trace preset"):
            tr.resolve_trace("nope")


class TestEvaluateTrace:
    def test_length1_flat_trace_bit_exact(self):
        """A T=1 flat trace with SLO + idle channels off == point path."""
        cfg = tr.TraceConfig(kind="flat", n_steps=1, mix_spread=0.0,
                             slo_weight=0.0, idle_frac=0.0)
        traced_wl, trace = tr.make_trace(
            jax.random.PRNGKey(0), WORKLOAD, cfg)
        scen = cm.Scenario(workload=traced_wl, weights=_weights(),
                           trace=trace)
        tm = cm.evaluate_trace(DP, scen)
        point = cm.evaluate(DP, WORKLOAD, _weights())
        for name in cm.Metrics._fields:
            a = np.asarray(getattr(tm.metrics, name))
            b = np.asarray(getattr(point, name))
            np.testing.assert_array_equal(
                a, b, err_msg=f"Metrics.{name} not bit-exact")
        # ... and through the Scenario dispatchers
        np.testing.assert_array_equal(
            np.asarray(cm.evaluate_scenario(DP, scen).reward),
            np.asarray(point.reward))
        np.testing.assert_array_equal(
            np.asarray(cm.scenario_reward(DP, scen)),
            np.asarray(point.reward))

    def test_one_compiled_program(self):
        """The full 32-step trace jits into one program == eager result."""
        scen = tr.traced_scenario(
            cm.Scenario(workload=WORKLOAD, weights=_weights()),
            tr.TraceConfig(kind="bursty"))
        fn = jax.jit(lambda d: cm.evaluate_trace(d, scen).reward)
        np.testing.assert_allclose(
            np.asarray(fn(DP)),
            np.asarray(cm.evaluate_trace(DP, scen).reward), rtol=1e-6)
        # design batches ride as extra trailing axes of one program
        batch = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (5,) + jnp.shape(x)), DP)
        tm = cm.evaluate_trace(batch, scen)
        assert tm.reward.shape == (5,)
        assert tm.p99_latency_s.shape == (32, 5)

    def test_trace_changes_ranking_under_load(self):
        """SLO + idle channels make the traced reward load-dependent."""
        scen = tr.traced_scenario(
            cm.Scenario(workload=WORKLOAD, weights=_weights()),
            tr.TraceConfig(kind="bursty", load=2.5))
        tm = cm.evaluate_trace(DP, scen)
        point = cm.evaluate(DP, WORKLOAD, _weights())
        assert float(tm.reward) != pytest.approx(float(point.reward))
        assert 0.0 <= float(tm.slo_attainment) <= 1.0
        # eq17 component stays the dt-weighted plain reward
        assert float(tm.reward) <= float(tm.reward_eq17) + 1e-6

    def test_evaluate_trace_scenarios_shapes(self):
        base = cm.stack_scenarios([
            cm.Scenario(workload=WORKLOAD, weights=_weights()),
            cm.Scenario(workload=wl.registry()["qwen2-0.5b:decode"],
                        weights=cm.make_weights(2.0, 0.5, 0.1))])
        scens = tr.apply_trace(base, tr.TraceConfig(kind="diurnal"))
        tm = cm.evaluate_trace_scenarios(DP, scens)
        assert tm.reward.shape == (2,)
        assert tm.slo_attainment.shape == (2,)
        assert tm.p99_latency_s.shape == (2, 32)
        # trace-aware evaluate_scenarios agrees with the TraceMetrics view
        np.testing.assert_array_equal(
            np.asarray(cm.evaluate_scenarios(DP, scens).reward),
            np.asarray(tm.reward))


class TestQueueingProxy:
    @pytest.mark.parametrize("rho", [0.3, 0.7, 0.9])
    def test_calibrated_against_slot_scheduler_sim(self, rho):
        mu, c = 40.0, 8
        qps = rho * mu
        _, p99 = cm.queueing_p99(jnp.float32(mu), jnp.float32(qps),
                                 jnp.float32(c))
        sim = tr.slot_scheduler_p99_sim(qps, mu, c, n_tasks=4000)
        ratio = float(p99) / sim
        assert 0.4 <= ratio <= 2.5, (
            f"analytic/sim p99 ratio {ratio:.2f} out of band at rho={rho}")

    def test_monotone_in_load_and_overload_penalized(self):
        mu, c = 40.0, 8
        loads = jnp.asarray([0.2, 0.5, 0.8, 0.95, 1.3]) * mu
        _, p99 = cm.queueing_p99(jnp.float32(mu), loads, jnp.float32(c))
        p = np.asarray(p99)
        assert (np.diff(p) > 0.0).all()
        d = c / mu
        assert p[-1] > d * cm._OVERLOAD_PEN * 0.2   # overload term bites


class TestFourObjectiveArchive:
    def test_insert_and_hypervolume(self):
        key = jax.random.PRNGKey(0)
        pts3 = jax.random.uniform(key, (24, 3), minval=0.5, maxval=4.0)
        slo = jax.random.uniform(jax.random.PRNGKey(1), (24, 1))
        pts4 = jnp.concatenate([pts3, slo], axis=-1)
        flats = jnp.zeros((24, ps.N_PARAMS), jnp.int32)
        a4 = ar.insert_batch(ar.empty(16, n_obj=4), pts4, flats)
        assert int(a4.n_valid) > 0
        hv = float(ar.hypervolume(
            a4, ar.nadir_ref(a4.points, a4.valid)))
        assert hv > 0.0
        # a strictly-better SLO at identical PPAC is non-dominated in 4-D
        base = jnp.asarray([[1.0, 1.0, 1.0, 0.5]], jnp.float32)
        better = jnp.asarray([[1.0, 1.0, 1.0, 0.9]], jnp.float32)
        both = jnp.concatenate([base, better])
        mask = ar.non_dominated_mask(both)
        assert not bool(mask[0]) and bool(mask[1])

    def test_three_objective_path_unchanged(self):
        key = jax.random.PRNGKey(2)
        pts = jax.random.uniform(key, (24, 3), minval=0.5, maxval=4.0)
        flats = jnp.zeros((24, ps.N_PARAMS), jnp.int32)
        a = ar.insert_batch(ar.empty(16), pts, flats)
        ref = ar.nadir_ref(pts)
        hv = float(ar.hypervolume(a, ref))
        # brute-force Monte Carlo cross-check of the recursive sweep
        rng = np.random.default_rng(0)
        refm = np.asarray(ar._to_min(ref))
        pm = np.asarray(ar._to_min(a.points))[np.asarray(a.valid)]
        lo = pm.min(0)
        samp = rng.uniform(lo, refm, (100000, 3))
        dom = ((samp[:, None, :] >= pm[None, :, :]).all(-1)).any(1)
        mc = float(dom.mean() * np.prod(refm - lo))
        assert hv == pytest.approx(mc, rel=0.05)


@pytest.mark.slow
class TestSuiteIntegration:
    def test_traced_smoke_suite(self):
        import dataclasses

        from repro.optimizer import scenario as sc

        cfg = dataclasses.replace(
            sc.SMOKE_SUITE, workloads=("qwen2-0.5b:decode",),
            weight_grid=((1.0, 1.0, 0.1),), trace="bursty")
        res = sc.run_suite(jax.random.PRNGKey(0), cfg)
        o = res.outcomes[0]
        assert o.slo_attainment is not None
        assert 0.0 <= o.slo_attainment <= 1.0
        assert o.p99_latency_s > 0.0
        assert res.archive.points.shape[-1] == 4
        assert "|trace=bursty" in o.name
        js = sc.to_json(res)
        assert js["scenarios"][0]["slo_attainment"] == o.slo_attainment
