"""Test-suite bootstrap: vendored hypothesis fallback.

The CI container ships no ``hypothesis`` wheel (and installing one is
not allowed), which previously left every property-based suite
(tests/test_properties.py, the TestArchiveHypothesis half of
tests/test_evo.py) permanently skipped. When the real library is
missing, expose the minimal vendored shim in tests/_vendor/ under the
same import name so those suites execute; a genuine install always
takes precedence (this hook only runs on ImportError).
"""

import os
import sys

try:
    import hypothesis  # noqa: F401  (real install wins)
except ImportError:
    sys.path.insert(
        0, os.path.join(os.path.dirname(__file__), "_vendor"))

collect_ignore_glob = ["_vendor/*"]
