"""Delta-evaluated placement SA: oracle-backed differential suite (ISSUE 4).

The contract under test: ``placement.nop_stats_delta`` (incremental,
O(slots)-per-move) must match a fresh ``placement.nop_stats`` recompute on
every stats/metrics field for every move kind — swap, relocate, HBM
re-anchor — including 50-move chains; and ``sa.refine_placement`` with
``delta_eval`` must reproduce the PR-3 full-recompute accept/reject
trajectory bit-for-bit (recorded oracle in tests/data_sa_trajectory.json,
re-recordable via scripts/record_sa_trajectory.py).
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costmodel as cm
from repro.core import env as chipenv
from repro.core import hw_constants as hw
from repro.core import params as ps
from repro.core import placement as pm
from repro.core import workload as wl
from repro.sa import annealing as sa

_HERE = os.path.dirname(os.path.abspath(__file__))


def _design_geometry(seed):
    dp = ps.random_design(jax.random.PRNGKey(seed))
    v = ps.decode(dp)
    n_pos = cm.footprint_positions(v)
    m, n = cm.mesh_dims(n_pos)
    return dp, v, n_pos, m, n


def _random_placement(rng, n_pos):
    """Collision-free random placement (mirrors test_properties)."""
    cells = rng.choice(pm.N_CELLS, size=n_pos, replace=False)
    cells = np.concatenate(
        [cells, rng.randint(0, pm.N_CELLS, pm.MAX_SLOTS - n_pos)])
    hbm_ij = rng.uniform(-1.0, 16.0, (pm.N_HBM, 2)).astype(np.float32)
    return pm.Placement(chiplet_cell=jnp.asarray(cells, jnp.int32),
                        hbm_ij=jnp.asarray(hbm_ij))


def _move(kind, slot=0, cell=0, hbm=0, anchor=(0.0, 0.0)):
    return pm.PlacementMove(kind=jnp.int32(kind), slot=jnp.int32(slot),
                            cell=jnp.int32(cell), hbm=jnp.int32(hbm),
                            anchor=jnp.asarray(anchor, jnp.float32))


def _moves_of_each_kind(rng, cells, n_pos):
    """One swap, one relocate-to-free-cell, one re-anchor move."""
    act = int(n_pos)
    occupied = set(int(c) for c in cells[:act])
    free = [c for c in range(pm.N_CELLS) if c not in occupied]
    s = rng.randint(0, act)
    swap_tgt = int(cells[rng.randint(0, act)])          # occupied -> swap
    reloc_tgt = int(free[rng.randint(0, len(free))])    # free -> relocate
    anchor = rng.uniform(-1.0, 16.0, 2)
    return {
        "swap": _move(0, slot=s, cell=swap_tgt),
        "relocate": _move(0, slot=s, cell=reloc_tgt),
        "reanchor": _move(1, hbm=rng.randint(0, pm.N_HBM), anchor=anchor),
    }


class TestDeltaOracle:
    """nop_stats_delta == fresh nop_stats on every field, all move kinds."""

    @pytest.mark.parametrize("seed", range(6))
    def test_single_moves_all_kinds(self, seed):
        rng = np.random.RandomState(seed)
        dp, v, n_pos, m, n = _design_geometry(seed)
        mesh_edges = m * (n - 1.0) + n * (m - 1.0)
        plc = _random_placement(rng, int(n_pos))
        cache = pm.nop_stats_cache(plc, n_pos, v.hbm_mask, v.arch_type,
                                   mesh_edges)
        for name, mv in _moves_of_each_kind(
                rng, np.asarray(plc.chiplet_cell), n_pos).items():
            cand = pm.nop_stats_delta(cache, mv, n_pos, v.hbm_mask,
                                      v.arch_type, mesh_edges)
            applied = pm.apply_move(plc, mv, n_pos)
            np.testing.assert_array_equal(
                np.asarray(cand.placement.chiplet_cell),
                np.asarray(applied.chiplet_cell), err_msg=name)
            np.testing.assert_array_equal(
                np.asarray(cand.placement.hbm_ij),
                np.asarray(applied.hbm_ij), err_msg=name)
            fresh = pm.nop_stats(applied, n_pos, v.hbm_mask, v.arch_type,
                                 mesh_edges)
            for field in pm.NoPStats._fields:
                np.testing.assert_allclose(
                    float(getattr(cand.stats, field)),
                    float(getattr(fresh, field)),
                    rtol=1e-5, atol=1e-5, err_msg=f"{name}:{field}")

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_chained_50_moves(self, seed):
        """Apply 50 random moves through the cache; after EVERY move the
        cached stats must equal a fresh full recompute to 1e-5."""
        rng = np.random.RandomState(100 + seed)
        dp, v, n_pos, m, n = _design_geometry(seed)
        mesh_edges = m * (n - 1.0) + n * (m - 1.0)
        plc = _random_placement(rng, int(n_pos))
        cache = pm.nop_stats_cache(plc, n_pos, v.hbm_mask, v.arch_type,
                                   mesh_edges)

        @jax.jit
        def delta_step(cache, mv):
            cand = pm.nop_stats_delta(cache, mv, n_pos, v.hbm_mask,
                                      v.arch_type, mesh_edges)
            return pm.commit_move(cache, cand, True)

        @jax.jit
        def fresh_stats(placement):
            return pm.nop_stats(placement, n_pos, v.hbm_mask, v.arch_type,
                                mesh_edges)

        for step in range(50):
            mv = _move(kind=rng.randint(2), slot=rng.randint(pm.MAX_SLOTS),
                       cell=rng.randint(pm.N_CELLS),
                       hbm=rng.randint(pm.N_HBM),
                       anchor=rng.uniform(-1.0, 16.0, 2))
            cache = delta_step(cache, mv)
            plc = pm.apply_move(plc, mv, n_pos)
            np.testing.assert_array_equal(
                np.asarray(cache.placement.chiplet_cell),
                np.asarray(plc.chiplet_cell), err_msg=f"step {step}")
            fresh = fresh_stats(plc)
            for field in pm.NoPStats._fields:
                np.testing.assert_allclose(
                    float(getattr(cache.stats, field)),
                    float(getattr(fresh, field)), rtol=1e-5, atol=1e-5,
                    err_msg=f"step {step}: {field}")

    def test_commit_reject_is_identity(self):
        rng = np.random.RandomState(7)
        dp, v, n_pos, m, n = _design_geometry(7)
        plc = _random_placement(rng, int(n_pos))
        cache = pm.nop_stats_cache(plc, n_pos, v.hbm_mask, v.arch_type)
        mv = _move(1, hbm=2, anchor=(3.5, -0.5))
        cand = pm.nop_stats_delta(cache, mv, n_pos, v.hbm_mask, v.arch_type)
        kept = pm.commit_move(cache, cand, False)
        for a, b in zip(jax.tree_util.tree_leaves(kept),
                        jax.tree_util.tree_leaves(cache)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_move_kinds_specialization(self):
        """The statically pruned 'chiplet'/'hbm' paths equal 'mixed' for
        pinned-kind moves; an unknown mode raises."""
        rng = np.random.RandomState(9)
        dp, v, n_pos, m, n = _design_geometry(9)
        plc = _random_placement(rng, int(n_pos))
        cache = pm.nop_stats_cache(plc, n_pos, v.hbm_mask, v.arch_type)
        moves = _moves_of_each_kind(rng, np.asarray(plc.chiplet_cell), n_pos)
        for name, mode in (("relocate", "chiplet"), ("reanchor", "hbm")):
            a = pm.nop_stats_delta(cache, moves[name], n_pos, v.hbm_mask,
                                   v.arch_type, move_kinds=mode)
            b = pm.nop_stats_delta(cache, moves[name], n_pos, v.hbm_mask,
                                   v.arch_type, move_kinds="mixed")
            for field in pm.NoPStats._fields:
                np.testing.assert_array_equal(
                    np.asarray(getattr(a.stats, field)),
                    np.asarray(getattr(b.stats, field)),
                    err_msg=f"{mode}:{field}")
        with pytest.raises(ValueError, match="move_kinds"):
            pm.nop_stats_delta(cache, moves["swap"], n_pos, v.hbm_mask,
                               v.arch_type, move_kinds="bogus")


class TestDeltaRewardPath:
    """costmodel.placement_ctx + reward/metrics_from_nop vs evaluate."""

    @pytest.mark.parametrize("seed", [3, 11, 23])
    def test_metrics_from_nop_matches_evaluate_every_field(self, seed):
        """Every Metrics field of the cached/delta path equals the
        explicit-placement evaluate() to 1e-5 (oracle acceptance)."""
        rng = np.random.RandomState(seed)
        dp, v, n_pos, m, n = _design_geometry(seed)
        plc = _random_placement(rng, int(n_pos))
        ctx = cm.placement_ctx(dp)
        cache = pm.nop_stats_cache(plc, n_pos, v.hbm_mask, v.arch_type,
                                   ctx.prefix.mesh_edges)
        mv = _move(kind=rng.randint(2), slot=rng.randint(pm.MAX_SLOTS),
                   cell=rng.randint(pm.N_CELLS), hbm=rng.randint(pm.N_HBM),
                   anchor=rng.uniform(-1.0, 16.0, 2))
        cand = pm.nop_stats_delta(cache, mv, n_pos, v.hbm_mask,
                                  v.arch_type, ctx.prefix.mesh_edges)
        got = cm.metrics_from_nop(ctx, cand.stats, hw.DEFAULT_HW)
        want = cm.evaluate(dp, placement=pm.apply_move(plc, mv, n_pos))
        for field in cm.Metrics._fields:
            np.testing.assert_allclose(
                np.asarray(getattr(got, field), np.float64),
                np.asarray(getattr(want, field), np.float64),
                rtol=1e-5, atol=1e-5, err_msg=field)

    def test_reward_from_nop_is_bitwise_equal(self):
        """The SA hot path must be *bit*-identical to evaluate().reward
        (this is what makes the trajectory regression possible)."""
        rng = np.random.RandomState(31)
        dp, v, n_pos, m, n = _design_geometry(31)
        plc = _random_placement(rng, int(n_pos))
        ctx = cm.placement_ctx(dp)
        cache = pm.nop_stats_cache(plc, n_pos, v.hbm_mask, v.arch_type,
                                   ctx.prefix.mesh_edges)
        r_ctx = cm.reward_from_nop(ctx, cache.stats, hw.DEFAULT_HW)
        r_full = cm.evaluate(dp, placement=plc).reward
        assert float(r_ctx) == float(r_full)

    def test_cache_stats_equal_nop_stats_bitwise(self):
        rng = np.random.RandomState(37)
        dp, v, n_pos, m, n = _design_geometry(37)
        mesh_edges = m * (n - 1.0) + n * (m - 1.0)
        plc = _random_placement(rng, int(n_pos))
        cache = pm.nop_stats_cache(plc, n_pos, v.hbm_mask, v.arch_type,
                                   mesh_edges)
        fresh = pm.nop_stats(plc, n_pos, v.hbm_mask, v.arch_type, mesh_edges)
        for field in pm.NoPStats._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(cache.stats, field)),
                np.asarray(getattr(fresh, field)), err_msg=field)


class TestSATrajectoryRegression:
    """refine_placement(delta_eval) == the recorded PR-3 trajectory."""

    @pytest.fixture(scope="class")
    def ref(self):
        with open(os.path.join(_HERE, "data_sa_trajectory.json")) as f:
            return json.load(f)

    def _suite_run(self, ref, delta):
        from repro.optimizer import scenario as suite
        env_cfg = chipenv.EnvConfig(hw=suite.PLACEMENT_SENSITIVE_HW)
        scen = cm.stack_scenarios([
            cm.Scenario(workload=wl.MLPERF[n])
            for n in ref["suite"]["workloads"]])
        dps = ps.random_design(
            jax.random.PRNGKey(ref["suite"]["design_seed"]),
            (len(ref["suite"]["workloads"]),))
        cfg = sa.PlacementSAConfig(n_iters=ref["n_iters"],
                                   record_every=ref["record_every"],
                                   delta_eval=delta)
        return sa.refine_placement_scenarios(
            jax.random.PRNGKey(ref["suite"]["key_seed"]), dps, scen,
            env_cfg, cfg)

    @pytest.mark.parametrize("delta", [True, False])
    def test_suite_trajectory_bit_for_bit(self, ref, delta):
        res = self._suite_run(ref, delta)
        np.testing.assert_array_equal(
            np.asarray(res.history, np.float64),
            np.asarray(ref["suite"]["history"]))
        np.testing.assert_array_equal(
            np.asarray(res.best_reward, np.float64),
            np.asarray(ref["suite"]["best_reward"]))
        np.testing.assert_array_equal(
            np.asarray(res.best_placement.chiplet_cell),
            np.asarray(ref["suite"]["best_cells"]))
        np.testing.assert_array_equal(
            np.asarray(res.best_placement.hbm_ij, np.float64),
            np.asarray(ref["suite"]["best_hbm_ij"]))

    @pytest.mark.parametrize("delta", [True, False])
    def test_single_trajectory_bit_for_bit(self, ref, delta):
        dp = ps.random_design(
            jax.random.PRNGKey(ref["single"]["design_seed"]))
        cfg = sa.PlacementSAConfig(n_iters=ref["n_iters"],
                                   record_every=ref["record_every"],
                                   delta_eval=delta)
        res = sa.refine_placement(
            jax.random.PRNGKey(ref["single"]["key_seed"]), dp,
            chipenv.EnvConfig(), cfg)
        np.testing.assert_array_equal(
            np.asarray(res.history, np.float64),
            np.asarray(ref["single"]["history"]))
        assert float(res.best_reward) == ref["single"]["best_reward"]
        np.testing.assert_array_equal(
            np.asarray(res.best_placement.chiplet_cell),
            np.asarray(ref["single"]["best_cells"]))

    def test_delta_equals_full_off_protocol(self):
        """Delta vs full on a protocol the recording never saw (odd
        iteration count, init_placement, per-phase p_hbm).

        The relocation-only phase stays bit-for-bit. The phases whose
        candidates exercise the anchor scan + congestion pow inside a
        *different* fusion context (p_hbm > 0 here, off the pinned
        protocol) can pick up 1-ulp reward differences from XLA's FMA
        contraction choices, so they get sanity bounds instead: the
        result must still dominate the canonical floorplan and land at
        the full path's best reward to 1%. The strict bit-for-bit
        contract is pinned by the recorded-trajectory tests above and
        the bench's trajectories_identical check at its own protocol.
        """
        dp = ps.random_design(jax.random.PRNGKey(77))
        v = ps.decode(dp)
        n_pos = cm.footprint_positions(v)
        m, n = cm.mesh_dims(n_pos)
        init = pm.canonical(m, n, v.hbm_mask, v.arch_type)
        init = pm.relocate_chiplet(init, 0, pm.N_CELLS - 1, n_pos)
        for p_hbm in (0.5, 0.0, 1.0):
            cfgs = [sa.PlacementSAConfig(n_iters=257, record_every=13,
                                         p_hbm=p_hbm, delta_eval=d)
                    for d in (True, False)]
            runs = [sa.refine_placement(jax.random.PRNGKey(5), dp,
                                        chipenv.EnvConfig(), c,
                                        init_placement=init)
                    for c in cfgs]
            if p_hbm == 0.0:
                np.testing.assert_array_equal(
                    np.asarray(runs[0].history),
                    np.asarray(runs[1].history))
                np.testing.assert_array_equal(
                    np.asarray(runs[0].best_placement.chiplet_cell),
                    np.asarray(runs[1].best_placement.chiplet_cell))
            else:
                for r in runs:
                    assert (float(r.best_reward)
                            >= float(r.canonical_reward) - 1e-6)
                np.testing.assert_allclose(
                    float(runs[0].best_reward), float(runs[1].best_reward),
                    rtol=1e-2, err_msg=f"p_hbm={p_hbm}")

    @pytest.mark.slow
    def test_scaled_budget_gain_at_least_pr3(self):
        """ISSUE-4 satellite: at the rescaled (4x) budget the mean reward
        gain under the placement-sensitive preset must be >= the PR-3
        +3.58 recorded baseline (measured +3.69 here).

        The argument is budget monotonicity on the same seeded chains —
        exact only while the 4000-iter chains reproduce the 1000-iter
        prefixes bit-for-bit (a different scan length compiles a
        different program, so an XLA change could flip an ulp and
        re-route a chain); the small slack absorbs that without letting
        a real regression through."""
        import importlib.util
        spec = importlib.util.spec_from_file_location(
            "bench_costmodel", os.path.join(
                _HERE, os.pardir, "benchmarks", "bench_costmodel.py"))
        bench = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(bench)
        out = bench._placement_gain_sweep(n_designs=16, n_iters=4000)
        assert (out["placement-sensitive"]["mean_gain"]
                >= bench.PR3_GAIN["placement-sensitive"] - 0.05)
        assert (out["default"]["mean_gain"]
                >= bench.PR3_GAIN["default"] - 0.05)


class TestDeltaAlgebraSeeded:
    """Deterministic mirror of tests/test_properties.TestDeltaProperties
    (inverse-move restore, disjoint-move commutation), so the delta
    algebra stays enforced on containers without `hypothesis`."""

    @staticmethod
    def _apply(cache, mv, n_pos, v):
        cand = pm.nop_stats_delta(cache, mv, n_pos, v.hbm_mask, v.arch_type)
        return pm.commit_move(cache, cand, True)

    def test_inverse_and_commutation(self):
        for seed in range(8):
            rng = np.random.RandomState(1000 + seed)
            dp, v, n_pos, m, n = _design_geometry(seed)
            act = int(n_pos)
            plc = _random_placement(rng, act)
            cache = pm.nop_stats_cache(plc, n_pos, v.hbm_mask, v.arch_type)
            cells = np.asarray(plc.chiplet_cell)
            free = np.setdiff1d(np.arange(pm.N_CELLS), cells[:act])

            # inverse chiplet relocate restores the cache exactly
            s = rng.randint(0, act)
            mv = _move(0, slot=s, cell=int(free[0]))
            inv = _move(0, slot=s, cell=int(cells[s]))
            restored = self._apply(self._apply(cache, mv, n_pos, v),
                                   inv, n_pos, v)
            for field in pm.NoPStats._fields:
                np.testing.assert_allclose(
                    float(getattr(restored.stats, field)),
                    float(getattr(cache.stats, field)),
                    rtol=1e-5, atol=1e-5, err_msg=field)
            np.testing.assert_array_equal(
                np.asarray(restored.placement.chiplet_cell), cells)

            # inverse HBM re-anchor restores the cache exactly
            b = rng.randint(0, pm.N_HBM)
            old = np.asarray(plc.hbm_ij)[b]
            mh = _move(1, hbm=b, anchor=rng.uniform(-1.0, 16.0, 2))
            invh = _move(1, hbm=b, anchor=old)
            restored = self._apply(self._apply(cache, mh, n_pos, v),
                                   invh, n_pos, v)
            for field in pm.NoPStats._fields:
                np.testing.assert_allclose(
                    float(getattr(restored.stats, field)),
                    float(getattr(cache.stats, field)),
                    rtol=1e-5, atol=1e-5, err_msg=field)

            # disjoint chiplet moves + a chiplet/HBM pair commute
            if act >= 2 and len(free) >= 2:
                s1, s2 = rng.choice(act, size=2, replace=False)
                m1 = _move(0, slot=int(s1), cell=int(free[0]))
                m2 = _move(0, slot=int(s2), cell=int(free[1]))
                for ma, mb in ((m1, m2), (m1, mh)):
                    ab = self._apply(self._apply(cache, ma, n_pos, v),
                                     mb, n_pos, v)
                    ba = self._apply(self._apply(cache, mb, n_pos, v),
                                     ma, n_pos, v)
                    np.testing.assert_array_equal(
                        np.asarray(ab.placement.chiplet_cell),
                        np.asarray(ba.placement.chiplet_cell))
                    for field in pm.NoPStats._fields:
                        np.testing.assert_allclose(
                            float(getattr(ab.stats, field)),
                            float(getattr(ba.stats, field)),
                            rtol=1e-5, atol=1e-5, err_msg=field)


class TestBudgetRescale:
    """ISSUE-4: default SA budgets rescaled now that steps are cheap."""

    def test_placement_sa_defaults(self):
        cfg = sa.PlacementSAConfig()
        assert cfg.delta_eval is True
        assert cfg.n_iters == 12_000          # 4x the PR-3 3000
        assert cfg.record_every == 200        # history length preserved
        assert cfg.n_iters // cfg.record_every == 3000 // 50

    def test_suite_defaults(self):
        from repro.optimizer import scenario as suite
        cfg = suite.SuiteConfig()
        assert cfg.placement_sa.n_iters == 8_000   # 4x the PR-3 2000
        assert cfg.placement_sa.delta_eval is True
        assert cfg.post_placement_sweep is True
        # the smoke preset stays small
        assert suite.SMOKE_SUITE.placement_sa.n_iters == 500


class TestPlacementAwareRefineBatch:
    """portfolio.coordinate_refine_batch with refined placements."""

    def test_sweep_with_placements_never_worse(self):
        from repro.optimizer import portfolio
        env_cfg = chipenv.EnvConfig()
        scen = cm.stack_scenarios([
            cm.Scenario(workload=wl.MLPERF[n])
            for n in ("resnet50", "bert")])
        dps = ps.random_design(jax.random.PRNGKey(13), (2,))
        flats = np.asarray(ps.to_flat(dps), np.int32)
        pres = sa.refine_placement_scenarios(
            jax.random.PRNGKey(14), dps, scen, env_cfg,
            sa.PlacementSAConfig(n_iters=150, record_every=50))
        placements = pres.best_placement
        # reward of the ORIGINAL designs under their refined placements
        base_r = np.asarray(cm.evaluate_scenarios(
            dps, scen, env_cfg.hw, placements=placements).reward)
        new_flats, new_r = portfolio.coordinate_refine_batch(
            flats, scen, env_cfg, max_sweeps=1, placements=placements)
        assert new_flats.shape == flats.shape
        assert (new_r >= base_r - 1e-5).all()

    def test_sweep_without_placements_unchanged_signature(self):
        from repro.optimizer import portfolio
        env_cfg = chipenv.EnvConfig()
        scen = cm.stack_scenarios([
            cm.Scenario(workload=wl.MLPERF["bert"])])
        flats = np.asarray(ps.to_flat(
            ps.random_design(jax.random.PRNGKey(15), (1,))), np.int32)
        new_flats, new_r = portfolio.coordinate_refine_batch(
            flats, scen, env_cfg, max_sweeps=1)
        assert new_flats.shape == flats.shape and new_r.shape == (1,)


class TestPhaseScheduledSA:
    """ISSUE-7 tentpole (a): phase-scheduled placement SA.

    Differential oracle: the phased delta path must equal the phased
    full-recompute path bit-for-bit (the pinned segments feed the same
    statically pruned nop_stats_delta modes the mixed stream already
    pins via p_hbm, and both share _stats_tail), and a single-segment
    schedule must reproduce the equivalent Bernoulli-pinned run exactly
    (propose() keeps the 8-way key-split layout, so pinning only skips
    the discarded kind draw)."""

    SCHED = (("chiplet", 20), ("hbm", 5))

    def _run(self, seed, **kw):
        dp = ps.random_design(jax.random.PRNGKey(seed))
        cfg = sa.PlacementSAConfig(n_iters=100, record_every=25, **kw)
        return sa.refine_placement(jax.random.PRNGKey(seed + 1), dp,
                                   chipenv.EnvConfig(), cfg)

    @pytest.mark.parametrize("sched", [SCHED, (("chiplet", 25),),
                                       (("hbm", 10), ("chiplet", 10))])
    def test_phased_delta_tracks_full_and_scratch_oracle(self, sched):
        """Differential oracle vs the full-recompute stream. The full
        path re-derives nop_stats from scratch inside the nested cycle
        scan — a different fusion context, so (exactly like the recorded
        off-protocol contract in TestSATrajectoryRegression) XLA's FMA
        contraction choices may flip an ulp: the paths get tight
        closeness bounds plus the canonical-dominance invariant instead
        of bit-equality, and the returned best_reward must reproduce
        from a scratch ``cm.evaluate`` of the returned placement."""
        a = self._run(21, phase_schedule=sched, delta_eval=True)
        b = self._run(21, phase_schedule=sched, delta_eval=False)
        for r in (a, b):
            assert float(r.best_reward) >= float(r.canonical_reward) - 1e-6
        np.testing.assert_allclose(np.asarray(a.history),
                                   np.asarray(b.history), rtol=1e-3)
        np.testing.assert_allclose(float(a.best_reward),
                                   float(b.best_reward), rtol=1e-3)
        dp = ps.random_design(jax.random.PRNGKey(21))
        scen = chipenv.EnvConfig().scenario()
        for r in (a, b):
            m = cm.evaluate(dp, scen.workload, scen.weights,
                            chipenv.EnvConfig().hw,
                            placement=r.best_placement)
            np.testing.assert_allclose(float(m.reward),
                                       float(r.best_reward), rtol=1e-4)

    @pytest.mark.parametrize("kind,p_hbm", [("chiplet", 0.0), ("hbm", 1.0)])
    def test_single_segment_equals_pinned_bernoulli(self, kind, p_hbm):
        """(('chiplet', L),) == p_hbm=0 and (('hbm', L),) == p_hbm=1,
        bit-for-bit: phases draw the same per-iteration randomness."""
        a = self._run(33, phase_schedule=((kind, 50),), p_hbm=p_hbm)
        b = self._run(33, phase_schedule=None, p_hbm=p_hbm)
        np.testing.assert_array_equal(np.asarray(a.history),
                                      np.asarray(b.history))
        np.testing.assert_array_equal(
            np.asarray(a.best_placement.chiplet_cell),
            np.asarray(b.best_placement.chiplet_cell))
        assert float(a.best_reward) == float(b.best_reward)

    def test_scan_unroll_bit_identical(self):
        base = self._run(44)
        for unroll in (4, 8):
            u = self._run(44, scan_unroll=unroll)
            np.testing.assert_array_equal(np.asarray(base.history),
                                          np.asarray(u.history))
            assert float(u.best_reward) == float(base.best_reward)
        ph = self._run(44, phase_schedule=self.SCHED)
        phu = self._run(44, phase_schedule=self.SCHED, scan_unroll=8)
        np.testing.assert_array_equal(np.asarray(ph.history),
                                      np.asarray(phu.history))

    def test_phased_never_below_canonical_and_history_shape(self):
        res = self._run(55, phase_schedule=self.SCHED)
        assert float(res.best_reward) >= float(res.canonical_reward) - 1e-6
        base = self._run(55)
        assert res.history.shape == base.history.shape
        h = np.asarray(res.history)
        assert (np.diff(h) >= -1e-5).all()          # best-so-far trace

    @pytest.mark.parametrize("sched,msg", [
        ((("walk", 5),), "kind"),
        ((("chiplet", 0),), "positive"),
        ((("chiplet", 7),), "multiple"),
    ])
    def test_validation_errors(self, sched, msg):
        dp = ps.random_design(jax.random.PRNGKey(3))
        cfg = sa.PlacementSAConfig(n_iters=100, record_every=25,
                                   phase_schedule=sched)
        with pytest.raises(ValueError, match=msg):
            sa.refine_placement(jax.random.PRNGKey(4), dp,
                                chipenv.EnvConfig(), cfg)

    def test_default_config_unchanged(self):
        cfg = sa.PlacementSAConfig()
        assert cfg.phase_schedule is None
        assert cfg.scan_unroll == 1
