"""Multi-device tests (8 virtual CPU devices via subprocess — XLA locks
the device count at first init, so these can't run in the main pytest
process): pjit train step, distributed PPO, elastic remesh, pipeline
parallelism, dry-run cell on a small mesh."""

import os
import subprocess
import sys

import pytest

# every case here spawns a fresh 8-virtual-device python subprocess
# (~2 min each on the 2-core CI container) — keep them out of the
# tier-1 fast lane (scripts/ci.sh runs `-m slow` as its own stage)
pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:{proc.stdout[-3000:]}\n"
            f"STDERR:{proc.stderr[-3000:]}")
    return proc.stdout


class TestPjitTrainStep:
    def test_sharded_train_step_matches_single_device(self):
        out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import ARCH_REGISTRY
from repro.training import trainer as T
from repro.parallel import sharding as shd
from repro.launch.mesh import make_test_mesh
from repro.data.pipeline import DataConfig, synthetic_batch

arch = ARCH_REGISTRY['qwen2-0.5b'].reduced()
cfg = T.TrainConfig(param_dtype=jnp.float32, warmup_steps=1, total_steps=10)
batch = synthetic_batch(DataConfig(batch_size=8, seq_len=32,
                                   vocab_size=arch.vocab_size), 0)

# single-device reference
state0 = T.init_state(arch, cfg, jax.random.PRNGKey(0))
step = T.make_train_step(arch, cfg)
_, m_ref = jax.jit(step)(state0, batch)

# sharded on a (2,4) data x model mesh
mesh = make_test_mesh((2, 4), ("data", "model"))
with shd.use_mesh(mesh):
    state = T.init_state(arch, cfg, jax.random.PRNGKey(0))
    st_sh = T.state_shardings(mesh, state)
    b_sh = T.batch_shardings(mesh, batch)
    jstep = jax.jit(step, in_shardings=(st_sh, b_sh))
    _, m = jstep(state, batch)
np.testing.assert_allclose(float(m['loss']), float(m_ref['loss']),
                           rtol=2e-3, atol=2e-3)
print('SHARDED_OK', float(m['loss']))
""")
        assert "SHARDED_OK" in out

    def test_distributed_ppo_learns(self):
        out = run_with_devices("""
import jax
from repro.core import env as chipenv
from repro.rl import ppo, distributed as dist
mesh = jax.make_mesh((2,2,2), ('pod','data','model'))
cfg = ppo.PPOConfig(n_steps=64, n_envs=4, batch_size=32)
carry, log = dist.train_distributed(jax.random.PRNGKey(0), mesh,
                                    chipenv.EnvConfig(), cfg, n_updates=3)
r = [float(x) for x in log.mean_episodic_reward]
assert r[-1] > r[0], r
assert float(carry.best_reward) > 100.0
print('DIST_PPO_OK', r)
""")
        assert "DIST_PPO_OK" in out

    def test_scenario_population_sharded_matches_unsharded(self):
        """The shard_mapped scenario axis must be seed-for-seed identical
        to the single-process ppo.train_scenario_population."""
        out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import costmodel as cm, workload as wl, params as ps
from repro.rl import ppo, distributed as dist

scen = cm.stack_scenarios([
    cm.Scenario(workload=wl.MLPERF[n], weights=cm.make_weights(1, 1, 0.1))
    for n in ('resnet50', 'bert')])
cfg = ppo.PPOConfig(n_steps=32, n_envs=2, batch_size=32)
key = jax.random.PRNGKey(3)
ref = ppo.train_scenario_population(key, scen, 2, cfg=cfg,
                                    total_timesteps=32 * 2 * 2)
mesh = jax.make_mesh((2,), ('scenario',))
res = dist.train_scenario_population_sharded(
    key, scen, 2, mesh, cfg=cfg, total_timesteps=32 * 2 * 2)
assert res.best_reward.shape == (2, 2), res.best_reward.shape
np.testing.assert_allclose(np.asarray(res.best_reward),
                           np.asarray(ref.best_reward), rtol=1e-5)
np.testing.assert_array_equal(np.asarray(ps.to_flat(res.best_design)),
                              np.asarray(ps.to_flat(ref.best_design)))
print('SHARDED_SCENARIOS_OK', np.asarray(res.best_reward).max())
""", n_devices=2)
        assert "SHARDED_SCENARIOS_OK" in out

    def test_elastic_remesh(self):
        out = run_with_devices("""
import jax, numpy as np
from repro.configs import ARCH_REGISTRY
from repro.training import trainer as T, fault
from repro.parallel import sharding as shd
from repro.launch.mesh import make_test_mesh
import jax.numpy as jnp

arch = ARCH_REGISTRY['qwen2-0.5b'].reduced()
cfg = T.TrainConfig(param_dtype=jnp.float32)
mesh8 = make_test_mesh((2, 4), ("data", "model"))
with shd.use_mesh(mesh8):
    state = T.init_state(arch, cfg, jax.random.PRNGKey(0))
mesh2 = make_test_mesh((1, 2), ("data", "model"))
state2 = fault.elastic_remesh(state, mesh8, mesh2)
a = jax.tree_util.tree_leaves(state)[3]
b = jax.tree_util.tree_leaves(state2)[3]
np.testing.assert_allclose(np.asarray(a), np.asarray(b))
print('ELASTIC_OK')
""")
        assert "ELASTIC_OK" in out

    def test_pipeline_parallel_matches_sequential(self):
        out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.parallel.pipeline import pipelined_forward, bubble_fraction
mesh = jax.make_mesh((4,), ('stage',))

def block(p, x):
    return jnp.tanh(x @ p['w'])

S, M, MB, D = 4, 8, 4, 16
key = jax.random.PRNGKey(0)
params = {'w': jax.random.normal(key, (S, D, D)) * 0.5}
xs = jax.random.normal(jax.random.PRNGKey(1), (M, MB, D))

pipe = pipelined_forward(mesh, 'stage', block, S, M)
out = pipe(params, xs)

ref = xs
for s in range(S):
    ref = jax.vmap(lambda x: block({'w': params['w'][s]}, x))(ref)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                           rtol=1e-4, atol=1e-4)
assert abs(bubble_fraction(4, 8) - 3/11) < 1e-9
print('PIPELINE_OK')
""")
        assert "PIPELINE_OK" in out


class TestDryRunSmall:
    """The dry-run machinery on a small (2,4) mesh — fast CI proxy for the
    512-device run (the real thing runs via launch/dryrun.py)."""

    def test_train_cell_lowers_and_compiles(self):
        out = run_with_devices("""
import jax, jax.numpy as jnp
from repro.configs import ARCH_REGISTRY
from repro.configs.base import ShapeConfig
from repro.launch import dryrun as D
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((2, 4), ("data", "model"))
arch = ARCH_REGISTRY['qwen2-0.5b'].reduced()
shape = ShapeConfig('tiny_train', 128, 8, 'train')
rules = D.cell_rules(mesh, shape)
lowered = D.build_train_cell(arch, shape, mesh, rules)
compiled = lowered.compile()
cost = compiled.cost_analysis()
if isinstance(cost, (list, tuple)):   # jax 0.4.x returns [dict]
    cost = cost[0]
assert cost.get('flops', 0) > 0
print('CELL_OK', compiled.memory_analysis() is not None)
""")
        assert "CELL_OK" in out

    def test_decode_cell_lowers_and_compiles(self):
        out = run_with_devices("""
import jax
from repro.configs import ARCH_REGISTRY
from repro.configs.base import ShapeConfig
from repro.launch import dryrun as D
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((2, 4), ("data", "model"))
for name in ['qwen2-0.5b', 'mamba2-130m']:
    arch = ARCH_REGISTRY[name].reduced()
    shape = ShapeConfig('tiny_decode', 256, 8, 'decode')
    rules = D.cell_rules(mesh, shape)
    lowered = D.build_decode_cell(arch, shape, mesh, rules)
    compiled = lowered.compile()
print('DECODE_CELL_OK')
""")
        assert "DECODE_CELL_OK" in out
